"""Layer-1 Pallas kernel: ELL-format SpMV.

The paper's downstream evaluation (PCG with the sparsifier preconditioner,
SS V) is dominated by the SpMV ``L_G . x`` with |E| >> |V|. This kernel is
the TPU-idiom formulation of that hot spot:

* **ELL layout**: every Laplacian row is padded to a fixed ``k`` slots
  (``values[n, k]``, ``indices[n, k]``). That turns the irregular CSR
  gather into a dense [n, k] elementwise multiply + row reduction -- fully
  vectorizable on the VPU lanes, the TPU analogue of the paper's
  row-parallel OpenMP loop. Hub rows with more than ``k`` entries go to a
  COO tail handled by the Rust coordinator (HYB split), keeping ``k`` small
  and the padding waste bounded.
* **BlockSpec tiling**: rows are processed in blocks of ``bn`` (grid over
  ``n // bn``), so each step stages a ``bn x k`` tile of values/indices
  plus the full ``x`` vector in VMEM: footprint ``bn*k*8 + n*4`` bytes,
  sized well under the ~16 MiB VMEM budget for every bucket we ship
  (see DESIGN.md SS Perf-L1).
* ``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
  custom-calls; lowering through the interpreter emits plain HLO that the
  Rust runtime executes byte-identically to the reference.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmv_kernel(values_ref, indices_ref, x_ref, y_ref):
    """One row-block: y = sum_j values * x[indices] over the k axis."""
    vals = values_ref[...]          # (bn, k) f32
    idx = indices_ref[...]          # (bn, k) i32
    x = x_ref[...]                  # (n,)   f32
    y_ref[...] = jnp.sum(vals * x[idx], axis=1)


def pick_block_rows(n: int) -> int:
    """Row-block size: biggest power-of-two tile <= 8192 dividing n (8192*k*8B <= 1 MiB per tile at k=16, well under the VMEM budget; fewer grid steps amortize the HBM->VMEM staging)."""
    bn = 1
    while bn * 2 <= min(n, 8192) and n % (bn * 2) == 0:
        bn *= 2
    return bn


@functools.partial(jax.jit, static_argnames=("bn",))
def spmv_ell(values, indices, x, bn=None):
    """Pallas ELL SpMV: y = A x with A in padded ELL form.

    Args:
      values: [n, k] float32 slot values (0.0 in padded slots).
      indices: [n, k] int32 slot column indices (in range [0, n)).
      x: [n] float32 input vector.
      bn: optional row-block size; must divide n. Default: pick_block_rows.

    Returns:
      [n] float32 y = A x.
    """
    n, k = values.shape
    if bn is None:
        bn = pick_block_rows(n)
    assert n % bn == 0, f"block rows {bn} must divide n={n}"
    grid = (n // bn,)
    return pl.pallas_call(
        _spmv_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), values.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, k), lambda i: (i, 0)),   # values tile
            pl.BlockSpec((bn, k), lambda i: (i, 0)),   # indices tile
            pl.BlockSpec((n,), lambda i: (0,)),        # full x each step
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        interpret=True,
    )(values, indices, x)


def vmem_bytes(n: int, k: int, bn: int) -> int:
    """Estimated VMEM footprint of one grid step (SS Perf-L1)."""
    tile = bn * k * (4 + 4)   # values f32 + indices i32
    xvec = n * 4              # full x staged per step
    out = bn * 4
    return tile + xvec + out
