"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: `python/tests/` asserts the Pallas
kernels (interpret=True) match these to float tolerance across shape/dtype
sweeps, and `aot.py` embeds the *kernels* (not these) into the exported
HLO.
"""

import jax.numpy as jnp


def spmv_ell_ref(values, indices, x):
    """ELL SpMV reference: y[i] = sum_j values[i, j] * x[indices[i, j]].

    Padding convention: padded slots carry value 0.0 (index arbitrary but
    in-range), so they contribute nothing.

    Args:
      values: [n, k] float array of per-row slot values.
      indices: [n, k] int32 array of per-row column indices.
      x: [n] float vector.

    Returns:
      [n] float vector y = A x.
    """
    return jnp.sum(values * x[indices], axis=1)


def jacobi_pcg_ref(values, indices, inv_diag, b, x0, iters):
    """Reference Jacobi-preconditioned CG on the ELL matrix.

    Mirrors MATLAB ``pcg`` (Hestenes-Stiefel, recursive residual). Returns
    (x, relres_history[iters]) where history[t] = ||r_{t+1}|| / ||b||.
    """
    bnorm = jnp.maximum(jnp.linalg.norm(b), jnp.finfo(b.dtype).tiny)
    x = x0
    r = b - spmv_ell_ref(values, indices, x)
    z = inv_diag * r
    p = z
    rz = jnp.dot(r, z)
    hist = []
    for _ in range(iters):
        ap = spmv_ell_ref(values, indices, p)
        pap = jnp.dot(p, ap)
        alpha = jnp.where(pap > 0, rz / jnp.where(pap > 0, pap, 1.0), 0.0)
        x = x + alpha * p
        r = r - alpha * ap
        hist.append(jnp.linalg.norm(r) / bnorm)
        z = inv_diag * r
        rz_new = jnp.dot(r, z)
        beta = jnp.where(rz > 0, rz_new / jnp.where(rz > 0, rz, 1.0), 0.0)
        rz = rz_new
        p = z + beta * p
    return x, jnp.stack(hist)
