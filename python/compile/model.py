"""Layer-2 JAX compute graph: the PCG evaluation step built on the Pallas
ELL-SpMV kernel (Layer 1).

Two exported computations per (n, k) shape bucket:

* ``spmv``      -- one SpMV dispatch: the Rust PCG loop (which owns the
                   sparsifier LDL^T preconditioner) calls this per iteration.
* ``pcg_step``  -- a fused half-iteration: given (p, x, r, rz) it computes
                   Ap, alpha, and the x/r updates plus ||r|| in ONE module,
                   so the hot path costs a single PJRT dispatch instead of
                   four (SS Perf-L2: fusion across the vector algebra).
* ``jacobi_pcg`` -- a fully self-contained T-iteration Jacobi-PCG via
                   ``lax.scan``, returning the relative-residual history;
                   used by the end-to-end XLA demo and the parity tests.

Python here runs at build time only; ``aot.py`` lowers these with
``jax.jit(...).lower(...)`` and writes HLO text for the Rust runtime.
"""

import functools

import jax
import jax.numpy as jnp

from compile.kernels.spmv_ell import spmv_ell


def spmv(values, indices, x):
    """y = A x (Pallas ELL kernel)."""
    return spmv_ell(values, indices, x)


def pcg_step(values, indices, p, x, r, rz):
    """Fused PCG half-iteration around the SpMV.

    Returns (x', r', relnum, pap) where relnum = ||r'||_2. The caller
    (Rust) applies its preconditioner to r', computes rz' and beta, and
    forms the next search direction p' = z' + beta p.
    """
    ap = spmv_ell(values, indices, p)
    pap = jnp.dot(p, ap)
    alpha = rz / pap
    x = x + alpha * p
    r = r - alpha * ap
    return x, r, jnp.linalg.norm(r), pap


@functools.partial(jax.jit, static_argnames=("iters",))
def jacobi_pcg(values, indices, inv_diag, b, x0, iters: int):
    """T-iteration Jacobi-preconditioned CG, scan-fused.

    Returns (x, hist[iters]) with hist[t] = ||r_{t+1}|| / ||b||. Runs a
    fixed number of iterations (shapes are static for AOT); the caller
    finds the first history entry under its tolerance.
    """
    bnorm = jnp.maximum(jnp.linalg.norm(b), jnp.finfo(b.dtype).tiny)
    r0 = b - spmv_ell(values, indices, x0)
    z0 = inv_diag * r0
    rz0 = jnp.dot(r0, z0)

    def body(carry, _):
        x, r, p, rz = carry
        ap = spmv_ell(values, indices, p)
        pap = jnp.dot(p, ap)
        # Safe divisions: once converged (rz, pap ~ 0) the iteration
        # freezes instead of producing NaNs in the fixed-length scan.
        alpha = jnp.where(pap > 0, rz / jnp.where(pap > 0, pap, 1.0), 0.0)
        x = x + alpha * p
        r = r - alpha * ap
        z = inv_diag * r
        rz_new = jnp.dot(r, z)
        beta = jnp.where(rz > 0, rz_new / jnp.where(rz > 0, rz, 1.0), 0.0)
        p = z + beta * p
        return (x, r, p, rz_new), jnp.linalg.norm(r) / bnorm

    (x, _, _, _), hist = jax.lax.scan(body, (x0, r0, z0, rz0), None, length=iters)
    return x, hist


def example_args_spmv(n: int, k: int):
    """ShapeDtypeStructs for lowering ``spmv`` at bucket (n, k)."""
    f = jax.ShapeDtypeStruct((n, k), jnp.float32)
    i = jax.ShapeDtypeStruct((n, k), jnp.int32)
    v = jax.ShapeDtypeStruct((n,), jnp.float32)
    return (f, i, v)


def example_args_pcg_step(n: int, k: int):
    """ShapeDtypeStructs for lowering ``pcg_step`` at bucket (n, k)."""
    f = jax.ShapeDtypeStruct((n, k), jnp.float32)
    i = jax.ShapeDtypeStruct((n, k), jnp.int32)
    v = jax.ShapeDtypeStruct((n,), jnp.float32)
    s = jax.ShapeDtypeStruct((), jnp.float32)
    return (f, i, v, v, v, s)


def example_args_jacobi(n: int, k: int):
    """ShapeDtypeStructs for lowering ``jacobi_pcg`` at bucket (n, k)."""
    f = jax.ShapeDtypeStruct((n, k), jnp.float32)
    i = jax.ShapeDtypeStruct((n, k), jnp.int32)
    v = jax.ShapeDtypeStruct((n,), jnp.float32)
    return (f, i, v, v, v)
