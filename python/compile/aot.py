"""AOT export: lower the Layer-2 computations to HLO text artifacts.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Emits, per (n, k) shape bucket:
  artifacts/spmv_n{n}_k{k}.hlo.txt        one ELL SpMV dispatch
  artifacts/pcg_step_n{n}_k{k}.hlo.txt    fused PCG half-iteration
and for the self-contained demo buckets:
  artifacts/jacobi_pcg_n{n}_k{k}_t{t}.hlo.txt

plus ``artifacts/manifest.tsv`` describing every artifact (the Rust
runtime reads this to pick buckets). Python runs ONCE at build time;
nothing here is on the request path.
"""

import argparse
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from compile import model

# Shape buckets. n must cover the grounded Laplacian sizes of the suite
# (max ~49k) and k the ELL width; hub rows beyond k go to the Rust COO
# tail. Keep the set small: artifacts are compiled once per bucket by the
# PJRT client at coordinator startup.
SPMV_BUCKETS = [
    (1024, 8), (1024, 16),
    (2048, 8), (2048, 16),
    (4096, 8), (4096, 16),
    (8192, 8), (8192, 16),
    (16384, 8), (16384, 16), (16384, 32),
    (32768, 8), (32768, 16),
    (65536, 8), (65536, 16),
]
JACOBI_BUCKETS = [
    (1024, 8, 200), (1024, 16, 200),
    (4096, 8, 200), (4096, 16, 200),
    (16384, 8, 200), (16384, 16, 200),
]
QUICK_SPMV = [(1024, 8)]
QUICK_JACOBI = [(1024, 8, 200)]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(fn, args, path: str) -> int:
    """Lower ``fn`` at ``args`` and write HLO text to ``path``."""
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="only the smallest bucket (smoke builds)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    spmv_buckets = QUICK_SPMV if args.quick else SPMV_BUCKETS
    jacobi_buckets = QUICK_JACOBI if args.quick else JACOBI_BUCKETS
    manifest = []

    for n, k in spmv_buckets:
        path = os.path.join(args.out_dir, f"spmv_n{n}_k{k}.hlo.txt")
        size = emit(model.spmv, model.example_args_spmv(n, k), path)
        manifest.append(("spmv", n, k, 0, os.path.basename(path)))
        print(f"wrote {path} ({size} chars)", file=sys.stderr)

        path = os.path.join(args.out_dir, f"pcg_step_n{n}_k{k}.hlo.txt")
        size = emit(model.pcg_step, model.example_args_pcg_step(n, k), path)
        manifest.append(("pcg_step", n, k, 0, os.path.basename(path)))
        print(f"wrote {path} ({size} chars)", file=sys.stderr)

    for n, k, t in jacobi_buckets:
        path = os.path.join(args.out_dir, f"jacobi_pcg_n{n}_k{k}_t{t}.hlo.txt")
        size = emit(
            lambda v, i, d, b, x0: model.jacobi_pcg(v, i, d, b, x0, iters=t),
            model.example_args_jacobi(n, k),
            path,
        )
        manifest.append(("jacobi_pcg", n, k, t, os.path.basename(path)))
        print(f"wrote {path} ({size} chars)", file=sys.stderr)

    with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as f:
        f.write("kind\tn\tk\titers\tfile\n")
        for kind, n, k, t, name in manifest:
            f.write(f"{kind}\t{n}\t{k}\t{t}\t{name}\n")
    print(f"manifest: {len(manifest)} artifacts", file=sys.stderr)


if __name__ == "__main__":
    main()
