"""L2 correctness: fused PCG step + scan-fused Jacobi PCG vs references,
and SPD convergence behaviour."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels.ref import jacobi_pcg_ref, spmv_ell_ref


def laplacian_ell(n, k=4, wmin=1.0, wmax=10.0, seed=0):
    """Grounded path-graph Laplacian with random weights, in ELL form."""
    rng = np.random.default_rng(seed)
    w = rng.uniform(wmin, wmax, size=n)  # edge i: (i, i+1)
    values = np.zeros((n, k), np.float32)
    indices = np.zeros((n, k), np.int32)
    for i in range(n):
        deg = w[i - 1] if i > 0 else 0.0
        if i < n - 1:
            deg += w[i]
        # grounding: vertex "n" (beyond the system) absorbs one edge end
        values[i, 0] = deg + (1.0 if i == 0 else 0.0)
        indices[i, 0] = i
        s = 1
        if i > 0:
            values[i, s] = -w[i - 1]
            indices[i, s] = i - 1
            s += 1
        if i < n - 1:
            values[i, s] = -w[i]
            indices[i, s] = i + 1
    return jnp.asarray(values), jnp.asarray(indices)


def test_pcg_step_matches_manual():
    n, k = 256, 4
    values, indices = laplacian_ell(n, k, seed=3)
    rng = np.random.default_rng(4)
    p = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    r = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    rz = jnp.float32(1.7)
    x2, r2, rnorm, pap = model.pcg_step(values, indices, p, x, r, rz)
    ap = spmv_ell_ref(values, indices, p)
    pap_ref = jnp.dot(p, ap)
    alpha = rz / pap_ref
    np.testing.assert_allclose(np.asarray(pap), np.asarray(pap_ref), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(x2), np.asarray(x + alpha * p), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(r2), np.asarray(r - alpha * ap), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(rnorm), np.linalg.norm(np.asarray(r2)), rtol=1e-5)


@pytest.mark.parametrize("n", [64, 256])
def test_jacobi_pcg_matches_ref(n):
    values, indices = laplacian_ell(n, seed=n)
    inv_diag = 1.0 / values[:, 0]
    rng = np.random.default_rng(7)
    b = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    x0 = jnp.zeros(n, jnp.float32)
    iters = 50
    x, hist = model.jacobi_pcg(values, indices, inv_diag, b, x0, iters)
    x_ref, hist_ref = jacobi_pcg_ref(values, indices, inv_diag, b, x0, iters)
    np.testing.assert_allclose(np.asarray(hist), np.asarray(hist_ref), rtol=1e-3, atol=1e-5)


def test_jacobi_pcg_converges_on_spd():
    # A pure path Laplacian has condition O(n^2) -- f32 CG stalls there, so
    # regularize to a strongly diagonally-dominant SPD system (grid-like
    # conditioning), which is what the real suite Laplacians behave like.
    n = 512
    values, indices = laplacian_ell(n, seed=11)
    values = values.at[:, 0].mul(1.05)
    inv_diag = 1.0 / values[:, 0]
    rng = np.random.default_rng(12)
    b = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    x0 = jnp.zeros(n, jnp.float32)
    x, hist = model.jacobi_pcg(values, indices, inv_diag, b, x0, 400)
    hist = np.asarray(hist)
    assert hist[-1] < 1e-3, f"relres {hist[-1]}"
    # true residual agrees
    r = np.asarray(b) - np.asarray(spmv_ell_ref(values, indices, x))
    assert np.linalg.norm(r) / np.linalg.norm(np.asarray(b)) < 5e-3
