"""L1 correctness: Pallas ELL SpMV vs the pure-jnp oracle.

Hypothesis sweeps shapes and data; every case asserts allclose against
``ref.py``. This is the core correctness signal for the kernel that ends
up inside every exported HLO artifact.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import jacobi_pcg_ref, spmv_ell_ref
from compile.kernels.spmv_ell import pick_block_rows, spmv_ell, vmem_bytes


def make_ell(rng, n, k, dtype=np.float32):
    """Random ELL operands with ~30% padded slots."""
    values = rng.standard_normal((n, k)).astype(dtype)
    indices = rng.integers(0, n, size=(n, k)).astype(np.int32)
    pad = rng.random((n, k)) < 0.3
    values[pad] = 0.0
    x = rng.standard_normal(n).astype(dtype)
    return jnp.asarray(values), jnp.asarray(indices), jnp.asarray(x)


@hypothesis.settings(max_examples=40, deadline=None)
@hypothesis.given(
    n_exp=st.integers(min_value=2, max_value=9),
    k=st.integers(min_value=1, max_value=17),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_spmv_matches_ref_swept(n_exp, k, seed):
    n = 2 ** n_exp
    rng = np.random.default_rng(seed)
    values, indices, x = make_ell(rng, n, k)
    got = spmv_ell(values, indices, x)
    want = spmv_ell_ref(values, indices, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,k", [(64, 4), (256, 8), (1024, 16)])
@pytest.mark.parametrize("bn_div", [1, 2, 4])
def test_block_size_invariance(n, k, bn_div):
    """The result must not depend on the BlockSpec row tiling."""
    rng = np.random.default_rng(n * 31 + k)
    values, indices, x = make_ell(rng, n, k)
    bn = max(1, pick_block_rows(n) // bn_div)
    got = spmv_ell(values, indices, x, bn=bn)
    want = spmv_ell_ref(values, indices, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_padded_slots_contribute_zero():
    n, k = 32, 4
    values = np.zeros((n, k), np.float32)
    indices = np.zeros((n, k), np.int32)
    x = np.random.default_rng(0).standard_normal(n).astype(np.float32)
    got = spmv_ell(jnp.asarray(values), jnp.asarray(indices), jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(got), np.zeros(n, np.float32))


def test_identity_matrix():
    n, k = 128, 3
    values = np.zeros((n, k), np.float32)
    indices = np.zeros((n, k), np.int32)
    values[:, 0] = 1.0
    indices[:, 0] = np.arange(n)
    x = np.random.default_rng(1).standard_normal(n).astype(np.float32)
    got = spmv_ell(jnp.asarray(values), jnp.asarray(indices), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), x, rtol=1e-6)


def test_laplacian_row_sums():
    """ELL encoding of a path-graph Laplacian: L @ ones == 0."""
    n, k = 64, 3
    values = np.zeros((n, k), np.float32)
    indices = np.zeros((n, k), np.int32)
    for i in range(n):
        deg = (1 if i > 0 else 0) + (1 if i < n - 1 else 0)
        values[i, 0] = deg
        indices[i, 0] = i
        s = 1
        if i > 0:
            values[i, s] = -1.0
            indices[i, s] = i - 1
            s += 1
        if i < n - 1:
            values[i, s] = -1.0
            indices[i, s] = i + 1
    ones = np.ones(n, np.float32)
    got = spmv_ell(jnp.asarray(values), jnp.asarray(indices), jnp.asarray(ones))
    np.testing.assert_allclose(np.asarray(got), np.zeros(n), atol=1e-5)


def test_pick_block_rows_divides():
    for n in [2, 64, 1024, 4096, 65536, 96, 100]:
        bn = pick_block_rows(n)
        assert n % bn == 0
        assert bn <= 8192


def test_vmem_budget_for_shipped_buckets():
    """Every shipped bucket must fit the 16 MiB VMEM budget (DESIGN SSPerf)."""
    from compile.aot import SPMV_BUCKETS

    for n, k in SPMV_BUCKETS:
        bn = pick_block_rows(n)
        assert vmem_bytes(n, k, bn) < 16 * 2**20, (n, k, bn)
