//! Structured errors for the library boundary.
//!
//! Every fallible operation in the session API ([`crate::session`]), the
//! pipeline ([`crate::coordinator::pipeline`]), and the config layer
//! ([`crate::config`]) returns this [`Error`] enum instead of a stringly
//! `anyhow::Error`, so callers can match on failure modes (bad parameter
//! vs. disconnected input vs. solver breakdown) instead of parsing
//! messages. The binaries keep `anyhow` at the very top: [`Error`]
//! implements [`std::error::Error`], so `?` converts it via `anyhow`'s
//! blanket `From` impl.

use std::fmt;

/// `Result` specialized to the library's typed [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Typed failure modes of the sparsification library.
#[derive(Debug)]
pub enum Error {
    /// The input graph is not connected (spectral sparsification is
    /// defined per component; run `graph::largest_component` first).
    Disconnected {
        /// Number of connected components found.
        components: usize,
    },
    /// A parameter failed validation.
    BadParam {
        /// Parameter name (e.g. `"alpha"`, `"run.scale"`).
        name: &'static str,
        /// What was wrong with it.
        why: String,
    },
    /// A graph name that is not a row of the evaluation suite.
    UnknownGraph {
        /// The offending name.
        name: String,
    },
    /// PCG exhausted its iteration budget above tolerance.
    NoConvergence {
        /// Iterations performed.
        iters: usize,
        /// Final relative residual.
        residual: f64,
    },
    /// Preconditioner factorization broke down: the sparsifier's grounded
    /// Laplacian is not positive definite.
    NotPositiveDefinite {
        /// Pivot index where the LDLᵀ factorization failed.
        at: usize,
        /// The offending pivot value.
        pivot: f64,
    },
    /// The serve daemon is at its in-flight request cap; the request was
    /// rejected up front instead of queued unboundedly. Retry later.
    Overloaded {
        /// Requests currently being served.
        in_flight: usize,
        /// The configured admission cap.
        cap: usize,
    },
    /// A request's deadline elapsed before its work completed. The work
    /// already done (e.g. a cache fill) is kept; only this response is
    /// abandoned.
    DeadlineExceeded {
        /// Wall time spent before the deadline check fired.
        elapsed_ms: u64,
        /// The deadline the request carried.
        deadline_ms: u64,
    },
    /// A `Prepared` snapshot failed validation: bad magic, unsupported
    /// format version, fingerprint mismatch, a section digest that does
    /// not match its bytes (truncation / bit-rot), or an internal
    /// inconsistency in the decoded arrays. The snapshot is rejected
    /// whole; callers fall back to a full prepare.
    Snapshot {
        /// What failed validation.
        why: String,
    },
    /// A graph is too large for the compact u32 CSR index mode: the
    /// vertex count or the CSR slot count (`2|E| + 1`) exceeds
    /// `u32::MAX`. The u64-offset fallback representation is future work
    /// (see ROADMAP); today such inputs are rejected up front rather
    /// than built with silently truncated offsets.
    IndexOverflow {
        /// Which quantity overflowed (e.g. `"vertex count"`, `"CSR slots"`).
        what: &'static str,
        /// The value that did not fit.
        needed: u64,
    },
    /// A bench artifact (`BENCH_*.json`) failed to parse, carried the
    /// wrong schema, or the `pdgrass benchdiff` comparison found a
    /// regression against the committed baseline.
    Bench(String),
    /// Config file is malformed (parse error or unknown key).
    Config(String),
    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Disconnected { components } => {
                write!(f, "graph is not connected ({components} components)")
            }
            Error::BadParam { name, why } => write!(f, "invalid parameter `{name}`: {why}"),
            Error::UnknownGraph { name } => write!(f, "unknown suite graph: {name}"),
            Error::NoConvergence { iters, residual } => {
                write!(f, "PCG did not converge: relres {residual:.3e} after {iters} iterations")
            }
            Error::NotPositiveDefinite { at, pivot } => {
                write!(
                    f,
                    "preconditioner factorization failed: non-positive pivot {pivot} at index {at}"
                )
            }
            Error::Overloaded { in_flight, cap } => {
                write!(f, "server overloaded: {in_flight} requests in flight (cap {cap})")
            }
            Error::DeadlineExceeded { elapsed_ms, deadline_ms } => {
                write!(f, "deadline exceeded: {elapsed_ms} ms elapsed (deadline {deadline_ms} ms)")
            }
            Error::IndexOverflow { what, needed } => {
                write!(
                    f,
                    "graph exceeds u32 index space: {what} needs {needed} (max {})",
                    u32::MAX
                )
            }
            Error::Snapshot { why } => write!(f, "snapshot rejected: {why}"),
            Error::Bench(msg) => write!(f, "bench: {msg}"),
            Error::Config(msg) => write!(f, "config: {msg}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::Io(e)
    }
}

impl From<crate::solver::chol::NotPositiveDefinite> for Error {
    fn from(e: crate::solver::chol::NotPositiveDefinite) -> Error {
        Error::NotPositiveDefinite { at: e.at, pivot: e.pivot }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_informative() {
        let e = Error::BadParam { name: "alpha", why: "must be positive".into() };
        assert!(e.to_string().contains("alpha"));
        assert!(e.to_string().contains("must be positive"));
        let e = Error::Disconnected { components: 3 };
        assert!(e.to_string().contains("3 components"));
        let e = Error::NoConvergence { iters: 10, residual: 0.5 };
        assert!(e.to_string().contains("10 iterations"));
        let e = Error::Overloaded { in_flight: 4, cap: 4 };
        assert!(e.to_string().contains("4 requests in flight"), "{e}");
        assert!(e.to_string().contains("cap 4"), "{e}");
        let e = Error::DeadlineExceeded { elapsed_ms: 120, deadline_ms: 100 };
        assert!(e.to_string().contains("120 ms"), "{e}");
        assert!(e.to_string().contains("deadline 100 ms"), "{e}");
        let e = Error::Snapshot { why: "section 3 digest mismatch".into() };
        assert!(e.to_string().contains("snapshot rejected"), "{e}");
        assert!(e.to_string().contains("section 3 digest mismatch"), "{e}");
        let e = Error::IndexOverflow { what: "CSR slots", needed: 5_000_000_000 };
        assert!(e.to_string().contains("u32 index space"), "{e}");
        assert!(e.to_string().contains("CSR slots"), "{e}");
        assert!(e.to_string().contains("5000000000"), "{e}");
        let e = Error::Bench("model mismatch".into());
        assert!(e.to_string().contains("bench"), "{e}");
        assert!(e.to_string().contains("model mismatch"), "{e}");
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
        assert!(std::error::Error::source(&e).is_some());
    }

    #[test]
    fn converts_into_anyhow_at_the_binary_boundary() {
        fn lib() -> Result<()> {
            Err(Error::UnknownGraph { name: "nope".into() })
        }
        fn bin() -> anyhow::Result<()> {
            lib()?;
            Ok(())
        }
        let err = bin().unwrap_err().to_string();
        assert!(err.contains("unknown suite graph"), "{err}");
    }
}
