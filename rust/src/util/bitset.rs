//! Compact bit set over `usize` indices.
//!
//! Used for vertex marking in the recovery phase (the feGRASS vertex-cover
//! marks and the pdGRASS visited sets) where a `HashSet<u32>` would thrash.

/// Fixed-capacity bit set with O(1) set/get and a fast epoch-style clear.
#[derive(Clone, Debug)]
pub struct BitSet {
    words: Vec<u64>,
    len: usize,
}

impl BitSet {
    /// New all-zero bit set with capacity for `len` bits.
    pub fn new(len: usize) -> Self {
        Self { words: vec![0; len.div_ceil(64)], len }
    }

    /// Number of addressable bits.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if no addressable bits.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Set bit `i`. Returns the previous value.
    pub fn set(&mut self, i: usize) -> bool {
        debug_assert!(i < self.len);
        let (w, b) = (i / 64, i % 64);
        let prev = (self.words[w] >> b) & 1 == 1;
        self.words[w] |= 1 << b;
        prev
    }

    /// Clear bit `i`.
    pub fn unset(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / 64] &= !(1 << (i % 64));
    }

    /// Read bit `i`.
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Zero every bit.
    pub fn clear(&mut self) {
        self.words.fill(0);
    }

    /// Population count.
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Iterate over set bit indices in increasing order.
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut w = w;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let b = w.trailing_zeros() as usize;
                    w &= w - 1;
                    Some(wi * 64 + b)
                }
            })
        })
    }
}

/// Epoch-stamped mark array: `clear()` is O(1) (bump the epoch).
///
/// The feGRASS recovery clears its vertex-cover marks between passes; with
/// thousands of passes (com-Youtube pathology) an O(V) clear per pass is a
/// real cost, so marks are epoch-stamped.
#[derive(Clone, Debug)]
pub struct EpochMarks {
    stamp: Vec<u32>,
    epoch: u32,
}

impl EpochMarks {
    /// New mark array for `len` items, all unmarked.
    pub fn new(len: usize) -> Self {
        Self { stamp: vec![0; len], epoch: 1 }
    }

    /// Number of addressable items.
    pub fn len(&self) -> usize {
        self.stamp.len()
    }

    /// True if no addressable items.
    pub fn is_empty(&self) -> bool {
        self.stamp.is_empty()
    }

    /// Mark item `i`; returns previous state.
    pub fn mark(&mut self, i: usize) -> bool {
        let prev = self.stamp[i] == self.epoch;
        self.stamp[i] = self.epoch;
        prev
    }

    /// Is item `i` marked in the current epoch?
    pub fn is_marked(&self, i: usize) -> bool {
        self.stamp[i] == self.epoch
    }

    /// Unmark everything in O(1) amortized (O(n) once per u32 wraparound).
    pub fn clear(&mut self) {
        if self.epoch == u32::MAX {
            self.stamp.fill(0);
            self.epoch = 1;
        } else {
            self.epoch += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_unset() {
        let mut b = BitSet::new(200);
        assert!(!b.get(131));
        assert!(!b.set(131));
        assert!(b.get(131));
        assert!(b.set(131));
        b.unset(131);
        assert!(!b.get(131));
    }

    #[test]
    fn count_and_iter() {
        let mut b = BitSet::new(300);
        for i in [0usize, 63, 64, 65, 199, 299] {
            b.set(i);
        }
        assert_eq!(b.count(), 6);
        assert_eq!(b.iter().collect::<Vec<_>>(), vec![0, 63, 64, 65, 199, 299]);
        b.clear();
        assert_eq!(b.count(), 0);
    }

    #[test]
    fn epoch_marks_fast_clear() {
        let mut m = EpochMarks::new(10);
        assert!(!m.mark(3));
        assert!(m.is_marked(3));
        m.clear();
        assert!(!m.is_marked(3));
        assert!(!m.mark(3));
        assert!(m.mark(3));
    }

    #[test]
    fn epoch_wraparound() {
        let mut m = EpochMarks::new(4);
        m.epoch = u32::MAX - 1;
        m.mark(0);
        m.clear(); // epoch == MAX
        m.mark(1);
        assert!(!m.is_marked(0));
        m.clear(); // wraps: fill(0), epoch=1
        assert!(!m.is_marked(1));
        m.mark(2);
        assert!(m.is_marked(2));
    }
}
