//! Minimal multiply-based hasher (FxHash-style) for integer keys.
//!
//! §Perf-L3: the recovery's per-subtask incidence maps are keyed by `u32`
//! vertex ids; std's SipHash is DoS-resistant but ~4× slower than a
//! multiply-mix for these hot lookups, and the keys are not
//! attacker-controlled.

use std::hash::{BuildHasherDefault, Hasher};

/// Multiply-mix hasher for small integer keys.
#[derive(Default)]
pub struct FxHasher {
    state: u64,
}

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl Hasher for FxHasher {
    fn finish(&self) -> u64 {
        self.state
    }

    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.write_u64(b as u64);
        }
    }

    fn write_u32(&mut self, i: u32) {
        self.write_u64(i as u64);
    }

    fn write_u64(&mut self, i: u64) {
        self.state = (self.state.rotate_left(5) ^ i).wrapping_mul(SEED);
    }

    fn write_usize(&mut self, i: usize) {
        self.write_u64(i as u64);
    }
}

/// `BuildHasher` for [`FxHasher`].
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// HashMap with the fast integer hasher.
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_works() {
        let mut m: FxHashMap<u32, u32> = FxHashMap::default();
        for i in 0..10_000u32 {
            m.insert(i, i * 2);
        }
        for i in 0..10_000u32 {
            assert_eq!(m.get(&i), Some(&(i * 2)));
        }
        assert_eq!(m.len(), 10_000);
    }

    #[test]
    fn distributes_sequential_keys() {
        // Sequential keys must not collide into the same few buckets:
        // check the low bits of hashes spread out.
        use std::hash::{BuildHasher, Hash};
        let bh = FxBuildHasher::default();
        let mut buckets = [0usize; 16];
        for i in 0..16_000u32 {
            let mut h = bh.build_hasher();
            i.hash(&mut h);
            buckets[(h.finish() & 15) as usize] += 1;
        }
        let (min, max) = (buckets.iter().min().unwrap(), buckets.iter().max().unwrap());
        assert!(*max < 2 * *min + 200, "skewed buckets: {buckets:?}");
    }
}
