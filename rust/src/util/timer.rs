//! Wall-clock timing helpers for the bench harness and experiment drivers.

use std::time::{Duration, Instant};

/// Simple scoped stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start a timer now.
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    /// Elapsed time since start.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed milliseconds as f64.
    pub fn ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }

    /// Elapsed microseconds as f64.
    pub fn us(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e6
    }
}

/// Time a closure, returning (result, elapsed ms).
pub fn time_ms<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.ms())
}

/// Run `f` `trials` times and return the minimum elapsed ms together with
/// the last result — the paper reports minimum-of-5 runtimes.
pub fn min_of<T>(trials: usize, mut f: impl FnMut() -> T) -> (T, f64) {
    assert!(trials > 0);
    let mut best = f64::INFINITY;
    let mut out = None;
    for _ in 0..trials {
        let (r, ms) = time_ms(&mut f);
        best = best.min(ms);
        out = Some(r);
    }
    (out.unwrap(), best)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_is_monotone() {
        let t = Timer::start();
        let a = t.us();
        std::thread::sleep(Duration::from_millis(2));
        let b = t.us();
        assert!(b > a);
        assert!(t.ms() >= 2.0);
    }

    #[test]
    fn time_ms_returns_result() {
        let (v, ms) = time_ms(|| 40 + 2);
        assert_eq!(v, 42);
        assert!(ms >= 0.0);
    }

    #[test]
    fn min_of_runs_n_times() {
        let mut count = 0;
        let (_, ms) = min_of(5, || count += 1);
        assert_eq!(count, 5);
        assert!(ms >= 0.0);
    }
}
