//! Shared utilities: deterministic PRNG, bit sets / epoch marks, timing,
//! statistics + table formatting, and a minimal property-testing driver.

pub mod bitset;
pub mod fxhash;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod timer;

pub use bitset::{BitSet, EpochMarks};
pub use fxhash::{FxBuildHasher, FxHashMap};
pub use rng::Rng;
pub use stats::{geomean, sci, sig3, Summary, Table};
pub use timer::{min_of, time_ms, Timer};
