//! Small statistics + table-formatting helpers shared by the bench harness
//! and the experiment drivers.

/// Summary statistics over a sample of f64 measurements.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    /// Number of samples.
    pub n: usize,
    /// Minimum (the paper reports min-of-5 runtimes).
    pub min: f64,
    /// Maximum.
    pub max: f64,
    /// Arithmetic mean.
    pub mean: f64,
    /// Median (50th percentile, linear interpolation).
    pub median: f64,
    /// Sample standard deviation.
    pub stddev: f64,
}

impl Summary {
    /// Compute summary statistics; panics on an empty slice.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Summary {
            n,
            min: sorted[0],
            max: sorted[n - 1],
            mean,
            median: percentile_sorted(&sorted, 50.0),
            stddev: var.sqrt(),
        }
    }
}

/// Percentile (0..=100) of an ascending-sorted slice, linear interpolation.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Geometric mean of positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Plain-text table renderer with right-aligned numeric columns, used by
/// every experiment driver so bench output visually matches the paper's
/// tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers.
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "table row arity");
        self.rows.push(cells);
    }

    /// Render to a string with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                // left-align first col (names), right-align the rest
                if i == 0 {
                    line.push_str(&format!("{:<w$}", c, w = width[i]));
                } else {
                    line.push_str(&format!("{:>w$}", c, w = width[i]));
                }
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &width));
        let total: usize = width.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &width));
        }
        out
    }
}

/// Format a float with engineering-style significant digits (paper tables
/// use 1–3 significant digits).
pub fn sig3(x: f64) -> String {
    if x == 0.0 {
        return "0".into();
    }
    let a = x.abs();
    if a >= 100.0 {
        format!("{:.0}", x)
    } else if a >= 10.0 {
        format!("{:.1}", x)
    } else if a >= 1.0 {
        format!("{:.2}", x)
    } else {
        format!("{:.3}", x)
    }
}

/// Scientific-notation count like the paper's `3.30E5`.
pub fn sci(x: f64) -> String {
    if x == 0.0 {
        return "0".into();
    }
    let exp = x.abs().log10().floor() as i32;
    let mant = x / 10f64.powi(exp);
    format!("{:.2}E{}", mant, exp)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.median, 7.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert_eq!(percentile_sorted(&v, 0.0), 0.0);
        assert_eq!(percentile_sorted(&v, 100.0), 10.0);
        assert_eq!(percentile_sorted(&v, 25.0), 2.5);
    }

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["graph", "|V|", "T(ms)"]);
        t.row(vec!["grid".into(), "100".into(), "1.5".into()]);
        t.row(vec!["rmat-big".into(), "100000".into(), "123.4".into()]);
        let s = t.render();
        assert!(s.contains("graph"));
        assert!(s.lines().count() == 4);
        // all lines equal width
        let w: Vec<usize> = s.lines().map(|l| l.len()).collect();
        assert_eq!(w[0], w[2]);
    }

    #[test]
    fn sci_matches_paper_style() {
        assert_eq!(sci(330_000.0), "3.30E5");
        assert_eq!(sci(1_130_000.0), "1.13E6");
    }
}
