//! Deterministic pseudo-random number generation.
//!
//! The whole repository is seeded: every generator, weight assignment and
//! right-hand side is reproducible from a `u64` seed. We implement
//! SplitMix64 (for seeding) and xoshiro256++ (for streams) from scratch —
//! the offline vendor set has no `rand` crate, and determinism across
//! platforms matters more than statistical extremes here.

/// SplitMix64: used to expand a single `u64` seed into stream state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new SplitMix64 stream from `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the workhorse PRNG for graph generation and workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Construct from a seed via SplitMix64 expansion (never all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    /// Derive an independent stream for a named sub-purpose.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next 64 random bits (xoshiro256++).
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `u32`.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform float in `[0, 1)` with 53 bits of precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)`. `bound` must be > 0.
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        // Lemire-style bounded rejection on 64 bits (bias negligible; we
        // use widening multiply without rejection for speed & determinism).
        let x = self.next_u64() as u128;
        ((x * bound as u128) >> 64) as usize
    }

    /// Uniform float in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (one value per call; simple, fine here).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(7);
        for bound in [1usize, 2, 3, 17, 1000] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        let mut lo = f64::MAX;
        let mut hi = f64::MIN;
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            lo = lo.min(x);
            hi = hi.max(x);
        }
        assert!(lo < 0.01 && hi > 0.99, "poor coverage: [{lo}, {hi}]");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
