//! Minimal property-based testing driver.
//!
//! The offline vendor set has no `proptest` crate, so we provide the core
//! of it: run a property over many PRNG-generated cases, and on failure
//! report the case seed so the exact input can be replayed by constructing
//! `Rng::new(seed)`. Used throughout `rust/tests/` for algorithm
//! invariants (LCA lemmas, subtask disjointness, PCG convergence, ...).

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of random cases to generate.
    pub cases: usize,
    /// Base seed; case `i` uses seed `base_seed + i`.
    pub base_seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, base_seed: 0xC0FFEE }
    }
}

/// Run `prop` over `cfg.cases` seeded RNGs. `prop` should panic or return
/// `Err(reason)` on a violated property. Panics with the offending seed on
/// first failure.
pub fn check<F>(cfg: Config, name: &str, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for i in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_add(i as u64);
        let mut rng = Rng::new(seed);
        if let Err(reason) = prop(&mut rng) {
            panic!("property '{name}' failed on case {i} (seed={seed:#x}): {reason}");
        }
    }
}

/// Convenience wrapper with default config.
pub fn check_default<F>(name: &str, prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    check(Config::default(), name, prop)
}

/// Property helper: assert two f64s are within `atol + rtol*|b|`.
pub fn close(a: f64, b: f64, rtol: f64, atol: f64) -> Result<(), String> {
    if (a - b).abs() <= atol + rtol * b.abs() {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (rtol={rtol}, atol={atol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check(Config { cases: 10, base_seed: 1 }, "count", |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic(expected = "property 'bad' failed")]
    fn failing_property_reports_seed() {
        check(Config { cases: 5, base_seed: 2 }, "bad", |r| {
            if r.next_u64() % 2 == 0 || true {
                Err("always fails".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6, 0.0).is_ok());
        assert!(close(1.0, 2.0, 1e-6, 0.0).is_err());
        assert!(close(0.0, 1e-9, 0.0, 1e-6).is_ok());
    }
}
