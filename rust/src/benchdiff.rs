//! Bench-artifact comparison: the no-regression gate behind
//! `pdgrass benchdiff <old.json> <new.json>`.
//!
//! `benches/micro.rs` writes a machine-readable dump per run (schema
//! `pdgrass-bench-v1`): every wall-clock sample in `bench_ms` and every
//! structural makespan/traffic model value in `model_units`. This module
//! parses two such dumps and compares them:
//!
//! - **`model_units` must match exactly.** The models (trisolve level
//!   schedule, prepare overlap, sharded makespan, SpMV traffic) are
//!   deterministic functions of the workload — machine-independent by
//!   construction — so any drift is a real structural change and fails
//!   the gate outright.
//! - **`bench_ms` must stay within a tolerance band.** Wall clocks are
//!   noisy; a new sample is a regression only when it exceeds
//!   `old * (1 + tolerance)`. Comparisons across different machines are
//!   meaningless — CI passes `models_only` and pins just the structural
//!   half.
//!
//! Keys present on only one side are reported as notes, not failures:
//! benches are added and retired PR by PR, and the committed artifact's
//! own diff makes that visible. The checked counts are printed so a gate
//! that silently compared nothing is conspicuous.
//!
//! The parser is hand-rolled like the TOML subset in [`crate::config`]
//! (no `serde_json` in the offline vendor set) and accepts exactly the
//! shape `micro.rs` emits: one object with `schema`/`pr` scalars and two
//! flat string→number objects. All failures are the typed
//! [`Error::Bench`].

use std::path::Path;

use crate::error::{Error, Result};

/// Schema identifier every artifact must carry.
pub const SCHEMA: &str = "pdgrass-bench-v1";

/// Default `bench_ms` tolerance band (new may be up to 50% slower —
/// generous because shared runners are noisy; `model_units` stay exact
/// regardless).
pub const DEFAULT_TOLERANCE: f64 = 0.5;

fn bench_err(why: impl Into<String>) -> Error {
    Error::Bench(why.into())
}

/// One parsed `BENCH_*.json` artifact. Entry order follows the file
/// (micro.rs writes benches in execution order).
#[derive(Clone, Debug, PartialEq)]
pub struct BenchReport {
    /// PR number the artifact was recorded for.
    pub pr: u64,
    /// Wall-clock samples: bench name → min-of-N milliseconds.
    pub bench_ms: Vec<(String, f64)>,
    /// Structural model values: model name → deterministic units.
    pub model_units: Vec<(String, u64)>,
}

impl BenchReport {
    /// Parse an artifact, validating the schema tag and rejecting
    /// duplicate or unknown top-level keys.
    pub fn parse(text: &str) -> Result<BenchReport> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        let mut schema: Option<String> = None;
        let mut pr: Option<u64> = None;
        let mut bench_ms: Option<Vec<(String, f64)>> = None;
        let mut model_units: Option<Vec<(String, u64)>> = None;
        p.object(|p, key| match key {
            "schema" => {
                if schema.replace(p.string()?).is_some() {
                    return Err(bench_err("duplicate key: schema"));
                }
                Ok(())
            }
            "pr" => {
                let v = p.number()?;
                if v < 0.0 || v.fract() != 0.0 {
                    return Err(bench_err(format!("pr must be a non-negative integer, got {v}")));
                }
                if pr.replace(v as u64).is_some() {
                    return Err(bench_err("duplicate key: pr"));
                }
                Ok(())
            }
            "bench_ms" => {
                let mut entries = Vec::new();
                p.object(|p, name| {
                    entries.push((name.to_string(), p.number()?));
                    Ok(())
                })?;
                if bench_ms.replace(entries).is_some() {
                    return Err(bench_err("duplicate key: bench_ms"));
                }
                Ok(())
            }
            "model_units" => {
                let mut entries = Vec::new();
                p.object(|p, name| {
                    let v = p.number()?;
                    if v < 0.0 || v.fract() != 0.0 {
                        return Err(bench_err(format!(
                            "model_units.{name} must be a non-negative integer, got {v}"
                        )));
                    }
                    entries.push((name.to_string(), v as u64));
                    Ok(())
                })?;
                if model_units.replace(entries).is_some() {
                    return Err(bench_err("duplicate key: model_units"));
                }
                Ok(())
            }
            other => Err(bench_err(format!("unknown top-level key: {other}"))),
        })?;
        p.ws();
        if p.i != p.b.len() {
            return Err(bench_err(format!("trailing bytes at offset {}", p.i)));
        }
        match schema.as_deref() {
            Some(SCHEMA) => {}
            Some(other) => {
                return Err(bench_err(format!("schema {other:?}, expected {SCHEMA:?}")))
            }
            None => return Err(bench_err("missing key: schema")),
        }
        Ok(BenchReport {
            pr: pr.ok_or_else(|| bench_err("missing key: pr"))?,
            bench_ms: bench_ms.ok_or_else(|| bench_err("missing key: bench_ms"))?,
            model_units: model_units.ok_or_else(|| bench_err("missing key: model_units"))?,
        })
    }

    /// Load and parse an artifact from disk.
    pub fn load(path: &Path) -> Result<BenchReport> {
        BenchReport::parse(&std::fs::read_to_string(path)?)
    }

    fn ms(&self, name: &str) -> Option<f64> {
        self.bench_ms.iter().find(|(n, _)| n == name).map(|&(_, v)| v)
    }

    fn units(&self, name: &str) -> Option<u64> {
        self.model_units.iter().find(|(n, _)| n == name).map(|&(_, v)| v)
    }
}

/// Outcome of one artifact comparison.
#[derive(Clone, Debug)]
pub struct Diff {
    /// PR number of the baseline artifact.
    pub old_pr: u64,
    /// PR number of the candidate artifact.
    pub new_pr: u64,
    /// Model values compared on both sides.
    pub checked_models: usize,
    /// Wall-clock samples compared on both sides (0 under `models_only`).
    pub checked_benches: usize,
    /// Gate failures: model drift or out-of-band slowdowns.
    pub violations: Vec<String>,
    /// Non-failing observations: added/removed keys, big speedups.
    pub notes: Vec<String>,
}

impl Diff {
    /// Did the candidate pass the gate?
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }

    /// Human-readable report (stable ordering; CI logs diff cleanly).
    pub fn render(&self) -> String {
        let mut out = format!(
            "benchdiff: baseline pr {} vs candidate pr {}\n  model_units: {} compared, {} \
             violation(s)\n  bench_ms:    {} compared\n",
            self.old_pr,
            self.new_pr,
            self.checked_models,
            self.violations.len(),
            self.checked_benches,
        );
        for v in &self.violations {
            out.push_str(&format!("  FAIL {v}\n"));
        }
        for n in &self.notes {
            out.push_str(&format!("  note {n}\n"));
        }
        out
    }
}

/// Compare `new` against the `old` baseline. `tolerance` is the
/// fractional `bench_ms` slowdown band (e.g. `0.5` = 50%); it must be
/// finite and non-negative. With `models_only` the wall clocks are
/// skipped entirely — the cross-machine (CI) mode.
pub fn diff(
    old: &BenchReport,
    new: &BenchReport,
    tolerance: f64,
    models_only: bool,
) -> Result<Diff> {
    if !tolerance.is_finite() || tolerance < 0.0 {
        return Err(Error::BadParam {
            name: "tolerance",
            why: format!("must be finite and non-negative, got {tolerance}"),
        });
    }
    let mut d = Diff {
        old_pr: old.pr,
        new_pr: new.pr,
        checked_models: 0,
        checked_benches: 0,
        violations: Vec::new(),
        notes: Vec::new(),
    };
    for (name, old_units) in &old.model_units {
        match new.units(name) {
            Some(new_units) if new_units == *old_units => d.checked_models += 1,
            Some(new_units) => {
                d.checked_models += 1;
                d.violations.push(format!(
                    "model {name}: {old_units} units -> {new_units} (models must match exactly)"
                ));
            }
            None => d.notes.push(format!("model removed: {name}")),
        }
    }
    for (name, _) in &new.model_units {
        if old.units(name).is_none() {
            d.notes.push(format!("model added: {name}"));
        }
    }
    if !models_only {
        for (name, old_ms) in &old.bench_ms {
            match new.ms(name) {
                Some(new_ms) => {
                    d.checked_benches += 1;
                    if new_ms > old_ms * (1.0 + tolerance) {
                        d.violations.push(format!(
                            "bench {name}: {old_ms:.3} ms -> {new_ms:.3} ms (band +{:.0}%)",
                            tolerance * 100.0
                        ));
                    } else if *old_ms > 0.0 && new_ms < old_ms * 0.5 {
                        d.notes.push(format!(
                            "bench {name}: {old_ms:.3} ms -> {new_ms:.3} ms (speedup)"
                        ));
                    }
                }
                None => d.notes.push(format!("bench removed: {name}")),
            }
        }
        for (name, _) in &new.bench_ms {
            if old.ms(name).is_none() {
                d.notes.push(format!("bench added: {name}"));
            }
        }
    }
    Ok(d)
}

/// Minimal JSON reader for the bench schema: objects, double-quoted
/// strings without escapes (bench identifiers), and plain decimal
/// numbers. Anything else is a typed error — artifacts are produced by
/// `micro.rs`, so deviation means corruption, not dialect.
struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        self.ws();
        if self.i < self.b.len() && self.b[self.i] == c {
            self.i += 1;
            Ok(())
        } else {
            Err(bench_err(format!("expected {:?} at offset {}", c as char, self.i)))
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.ws();
        self.b.get(self.i).copied()
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let start = self.i;
        while self.i < self.b.len() {
            match self.b[self.i] {
                b'"' => {
                    let s = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|_| bench_err("non-UTF-8 string"))?;
                    self.i += 1;
                    return Ok(s.to_string());
                }
                b'\\' => return Err(bench_err("escapes are not part of the bench schema")),
                _ => self.i += 1,
            }
        }
        Err(bench_err("unterminated string"))
    }

    fn number(&mut self) -> Result<f64> {
        self.ws();
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).expect("ascii slice");
        s.parse::<f64>().map_err(|_| bench_err(format!("bad number at offset {start}: {s:?}")))
    }

    /// Parse `{ "key": <value>, ... }`, handing each key to `f` with the
    /// cursor positioned at its value.
    fn object<F>(&mut self, mut f: F) -> Result<()>
    where
        F: FnMut(&mut Self, &str) -> Result<()>,
    {
        self.expect(b'{')?;
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(());
        }
        loop {
            let key = self.string()?;
            self.expect(b':')?;
            f(self, &key)?;
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(());
                }
                _ => return Err(bench_err(format!("expected ',' or '}}' at offset {}", self.i))),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact(pr: u64, benches: &[(&str, f64)], models: &[(&str, u64)]) -> BenchReport {
        BenchReport {
            pr,
            bench_ms: benches.iter().map(|&(n, v)| (n.to_string(), v)).collect(),
            model_units: models.iter().map(|&(n, v)| (n.to_string(), v)).collect(),
        }
    }

    /// Serialize in the exact format `benches/micro.rs` writes.
    fn render_artifact(r: &BenchReport) -> String {
        let mut out = format!("{{\n  \"schema\": \"{SCHEMA}\",\n  \"pr\": {},\n", r.pr);
        out.push_str("  \"bench_ms\": {\n");
        for (i, (name, ms)) in r.bench_ms.iter().enumerate() {
            let sep = if i + 1 == r.bench_ms.len() { "" } else { "," };
            out.push_str(&format!("    \"{name}\": {ms:.4}{sep}\n"));
        }
        out.push_str("  },\n  \"model_units\": {\n");
        for (i, (name, units)) in r.model_units.iter().enumerate() {
            let sep = if i + 1 == r.model_units.len() { "" } else { "," };
            out.push_str(&format!("    \"{name}\": {units}{sep}\n"));
        }
        out.push_str("  }\n}\n");
        out
    }

    #[test]
    fn parses_the_micro_bench_format() {
        let r = artifact(
            9,
            &[("spmv_csr_f64", 1.25), ("lca_query", 0.875)],
            &[("trisolve_makespan_serial_1t", 123_456)],
        );
        let parsed = BenchReport::parse(&render_artifact(&r)).unwrap();
        assert_eq!(parsed, r);
        // Key order and empty sections survive.
        let empty = artifact(10, &[], &[]);
        assert_eq!(BenchReport::parse(&render_artifact(&empty)).unwrap(), empty);
    }

    #[test]
    fn parse_rejects_malformed_artifacts() {
        fn doc(schema: &str, pr: &str, rest: &str) -> String {
            format!("{{\"schema\": \"{schema}\", \"pr\": {pr}{rest}}}")
        }
        const REST: &str = ", \"bench_ms\": {}, \"model_units\": {}";
        const FRAC: &str = ", \"bench_ms\": {}, \"model_units\": {\"m\": 1.5}";
        let cases = [
            (String::new(), "expected"),
            ("{}".to_string(), "missing key: schema"),
            (doc("other-v9", "1", REST), "schema"),
            (doc(SCHEMA, "1", ", \"bench_ms\": {}"), "missing key: model_units"),
            (doc(SCHEMA, "1.5", REST), "pr"),
            (doc(SCHEMA, "1", FRAC), "model_units.m"),
            (doc(SCHEMA, "1", ", \"bench_ms\": {}, \"model_units\": {}, \"x\": 1"), "unknown"),
            (doc(SCHEMA, "1", REST) + " junk", "trailing"),
            (doc(SCHEMA, "1, \"pr\": 2", REST), "duplicate"),
        ];
        for (text, needle) in cases {
            match BenchReport::parse(&text) {
                Err(Error::Bench(why)) => assert!(why.contains(needle), "{text:?}: {why}"),
                other => panic!("{text:?}: expected Bench error, got {other:?}"),
            }
        }
    }

    #[test]
    fn identical_artifacts_pass() {
        let r = artifact(9, &[("a", 1.0)], &[("m", 10)]);
        let d = diff(&r, &r, DEFAULT_TOLERANCE, false).unwrap();
        assert!(d.ok(), "{}", d.render());
        assert_eq!(d.checked_models, 1);
        assert_eq!(d.checked_benches, 1);
    }

    #[test]
    fn model_drift_fails_exactly() {
        let old = artifact(9, &[], &[("m", 10)]);
        let new = artifact(10, &[], &[("m", 11)]);
        let d = diff(&old, &new, DEFAULT_TOLERANCE, false).unwrap();
        assert!(!d.ok());
        assert!(d.violations[0].contains("m"), "{:?}", d.violations);
        assert!(d.render().contains("FAIL"), "{}", d.render());
        // Off by one in either direction — exact means exact.
        let new = artifact(10, &[], &[("m", 9)]);
        assert!(!diff(&old, &new, DEFAULT_TOLERANCE, false).unwrap().ok());
    }

    #[test]
    fn bench_band_tolerates_noise_but_not_regressions() {
        let old = artifact(9, &[("a", 10.0)], &[]);
        // 40% slower: inside the default 50% band.
        let d = diff(&old, &artifact(10, &[("a", 14.0)], &[]), DEFAULT_TOLERANCE, false).unwrap();
        assert!(d.ok(), "{}", d.render());
        // 60% slower: out of band.
        let d = diff(&old, &artifact(10, &[("a", 16.0)], &[]), DEFAULT_TOLERANCE, false).unwrap();
        assert!(!d.ok());
        assert!(d.violations[0].contains("a"), "{:?}", d.violations);
        // Big speedups are notes, never failures.
        let d = diff(&old, &artifact(10, &[("a", 2.0)], &[]), DEFAULT_TOLERANCE, false).unwrap();
        assert!(d.ok());
        assert!(d.notes.iter().any(|n| n.contains("speedup")), "{:?}", d.notes);
    }

    #[test]
    fn models_only_ignores_wall_clocks() {
        let old = artifact(9, &[("a", 1.0)], &[("m", 10)]);
        let new = artifact(10, &[("a", 100.0)], &[("m", 10)]);
        let d = diff(&old, &new, DEFAULT_TOLERANCE, true).unwrap();
        assert!(d.ok(), "{}", d.render());
        assert_eq!(d.checked_benches, 0);
        assert_eq!(d.checked_models, 1);
    }

    #[test]
    fn key_churn_is_a_note_not_a_failure() {
        let old = artifact(9, &[("gone", 1.0)], &[("old_m", 5)]);
        let new = artifact(10, &[("fresh", 1.0)], &[("new_m", 7)]);
        let d = diff(&old, &new, DEFAULT_TOLERANCE, false).unwrap();
        assert!(d.ok(), "{}", d.render());
        assert_eq!(d.checked_models, 0);
        assert_eq!(d.checked_benches, 0);
        let joined = d.notes.join("\n");
        let needles = [
            "model removed: old_m",
            "model added: new_m",
            "bench removed: gone",
            "bench added: fresh",
        ];
        for needle in needles {
            assert!(joined.contains(needle), "{joined}");
        }
    }

    #[test]
    fn bad_tolerance_is_a_typed_error() {
        let r = artifact(9, &[], &[]);
        for t in [-0.1, f64::NAN, f64::INFINITY] {
            match diff(&r, &r, t, false) {
                Err(Error::BadParam { name, .. }) => assert_eq!(name, "tolerance"),
                other => panic!("expected BadParam, got {other:?}"),
            }
        }
    }

    #[test]
    fn load_surfaces_io_errors() {
        match BenchReport::load(Path::new("/tmp/pdgrass-no-such-bench.json")) {
            Err(Error::Io(_)) => {}
            other => panic!("expected Io, got {other:?}"),
        }
    }
}
