//! Step 2 of Algorithm 1: sort off-tree edges by spectral criticality.
//!
//! Parallel *stable* sort (fork–join on the persistent pool), descending
//! by `score = w·R_T`; stability makes runs reproducible and matches the
//! serial feGRASS tie-break (edge-id order). Since the `par::sort`
//! rewrite the sort *moves* the 48-byte `OffTreeEdge` payloads through a
//! single ping-pong scratch buffer instead of cloning whole sub-buffers
//! at every merge level — this call site no longer clones any edge.
//!
//! # Streamed steps 1+2 ([`scored_sorted_streamed`])
//!
//! The barrier pipeline annotates *every* off-tree edge (step 1 joins),
//! then sorts the finished array (step 2 joins). The streamed pipeline
//! fuses them: fixed 4096-edge chunks are annotated **and locally
//! sorted** on pool workers, and the caller merges completed runs
//! ([`crate::par::sort::RunMerger`]) while later chunks are still being
//! scored — no barrier between resistance annotation and the score sort.
//! The comparator is a strict total order (score desc, ties by edge id),
//! so the merged output is the bitwise-identical sequence the barrier
//! sort produces, at every thread count.

use crate::par;
use crate::tree::{annotate_off_tree_edge, OffTreeEdge, Spanning};

/// Fixed chunk size of the streamed scoring producer (the chunk layout
/// depends only on the off-tree edge count, never on the thread count).
pub const SCORE_CHUNK: usize = 4096;

/// The recovery priority order: criticality score descending, ties broken
/// by edge id ascending — a strict total order over off-tree edges.
#[inline]
pub fn score_cmp(a: &OffTreeEdge, b: &OffTreeEdge) -> std::cmp::Ordering {
    b.score.partial_cmp(&a.score).unwrap_or(std::cmp::Ordering::Equal).then(a.eid.cmp(&b.eid))
}

/// Sort off-tree edges descending by score (stable), in parallel.
pub fn sort_by_score(off: &mut [OffTreeEdge], threads: usize) {
    par::sort::par_sort_by(off, threads, &score_cmp);
}

/// Streamed steps 1+2 fused: annotate off-tree edges chunk-by-chunk on
/// the pool (each chunk locally sorted by [`score_cmp`]), merge completed
/// runs on the caller while scoring is still producing, and return the
/// fully score-sorted list. `emit` is invoked once per edge **in final
/// sorted order during the last merge pass** — the hook the session layer
/// uses to fuse step 3 (LCA subtask grouping) into the merge tail instead
/// of re-walking the array behind another barrier.
///
/// Output is bitwise identical to `off_tree_edges` + [`sort_by_score`]
/// at every thread count: annotation is a pure per-edge function and the
/// comparator is a strict total order.
pub fn scored_sorted_streamed<E>(
    g: &crate::graph::Graph,
    sp: &Spanning,
    threads: usize,
    emit: E,
) -> Vec<OffTreeEdge>
where
    E: FnMut(&OffTreeEdge),
{
    let ids: Vec<u32> =
        (0..g.num_edges() as u32).filter(|&i| !sp.is_tree_edge[i as usize]).collect();
    let mut merger = par::sort::RunMerger::new(&score_cmp);
    par::stream::produce_sorted_runs(
        ids.len(),
        SCORE_CHUNK,
        threads,
        |k| annotate_off_tree_edge(g, sp, ids[k]),
        &score_cmp,
        |_, run| merger.push(run),
    );
    merger.finish_with(emit)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn mk(eid: u32, score: f64) -> OffTreeEdge {
        OffTreeEdge { eid, u: 0, v: 1, w: 1.0, lca: 0, resistance: score, score }
    }

    #[test]
    fn descending_and_stable() {
        let mut rng = Rng::new(1);
        let mut v: Vec<OffTreeEdge> =
            (0..10_000).map(|i| mk(i, (rng.next_u32() % 50) as f64)).collect();
        sort_by_score(&mut v, 4);
        for w in v.windows(2) {
            assert!(w[0].score >= w[1].score);
            if w[0].score == w[1].score {
                assert!(w[0].eid < w[1].eid);
            }
        }
    }

    #[test]
    fn streamed_scoring_matches_barrier_bitwise() {
        let g = crate::gen::grid(60, 60, 0.6, &mut Rng::new(3));
        let sp = crate::tree::build_spanning(&g);
        let mut barrier = crate::tree::off_tree_edges(&g, &sp);
        sort_by_score(&mut barrier, 2);
        assert!(barrier.len() > SCORE_CHUNK, "test graph must span multiple chunks");
        for threads in [1usize, 2, 8] {
            let mut emitted = 0usize;
            let streamed = scored_sorted_streamed(&g, &sp, threads, |_| emitted += 1);
            assert_eq!(emitted, barrier.len(), "threads={threads}");
            assert_eq!(streamed.len(), barrier.len(), "threads={threads}");
            for (s, b) in streamed.iter().zip(&barrier) {
                assert_eq!(s.eid, b.eid, "threads={threads}");
                assert_eq!(s.lca, b.lca, "threads={threads}");
                assert_eq!(s.score.to_bits(), b.score.to_bits(), "threads={threads}");
                assert_eq!(s.resistance.to_bits(), b.resistance.to_bits(), "threads={threads}");
            }
        }
    }
}
