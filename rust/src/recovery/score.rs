//! Step 2 of Algorithm 1: sort off-tree edges by spectral criticality.
//!
//! Parallel *stable* sort (fork–join on the persistent pool), descending
//! by `score = w·R_T`; stability makes runs reproducible and matches the
//! serial feGRASS tie-break (edge-id order). Since the `par::sort`
//! rewrite the sort *moves* the 48-byte `OffTreeEdge` payloads through a
//! single ping-pong scratch buffer instead of cloning whole sub-buffers
//! at every merge level — this call site no longer clones any edge.

use crate::par;
use crate::tree::OffTreeEdge;

/// Sort off-tree edges descending by score (stable), in parallel.
pub fn sort_by_score(off: &mut [OffTreeEdge], threads: usize) {
    par::sort::par_sort_by(off, threads, &|a: &OffTreeEdge, b: &OffTreeEdge| {
        b.score
            .partial_cmp(&a.score)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.eid.cmp(&b.eid))
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn mk(eid: u32, score: f64) -> OffTreeEdge {
        OffTreeEdge { eid, u: 0, v: 1, w: 1.0, lca: 0, resistance: score, score }
    }

    #[test]
    fn descending_and_stable() {
        let mut rng = Rng::new(1);
        let mut v: Vec<OffTreeEdge> =
            (0..10_000).map(|i| mk(i, (rng.next_u32() % 50) as f64)).collect();
        sort_by_score(&mut v, 4);
        for w in v.windows(2) {
            assert!(w[0].score >= w[1].score);
            if w[0].score == w[1].score {
                assert!(w[0].eid < w[1].eid);
            }
        }
    }
}
