//! feGRASS baseline: loose-similarity (Definition 4) off-tree edge
//! recovery, sequential, multi-pass.
//!
//! Loose similarity is a vertex cover: recovering `e = (u, v)` marks every
//! vertex within β = c tree hops of `u` or `v` as *covered*; a later edge
//! is similar if **either** endpoint is covered (Eq. 7). One pass over the
//! score-sorted off-tree edges recovers an independent-ish set; if fewer
//! than `α|V|` edges were recovered, the cover is cleared and the pass
//! repeats over the remaining edges (§II.B) — the behaviour that blows up
//! on hub graphs (com-Youtube: >6000 passes, §V).

use super::score::sort_by_score;
use super::{Params, Recovery, Stats};
use crate::graph::Graph;
use crate::tree::{off_tree_edges, OffTreeEdge, Spanning};
use crate::util::EpochMarks;

/// Run feGRASS off-tree edge recovery. Pure sequential reference
/// implementation (the paper's baseline is serial).
pub fn fegrass(g: &Graph, sp: &Spanning, params: &Params) -> Recovery {
    let mut off = off_tree_edges(g, sp);
    sort_by_score(&mut off, 1);
    fegrass_sorted(g.num_vertices(), &off, sp, params)
}

/// The core loose-similarity loop over an already scored, score-sorted
/// off-tree edge list — the primitive behind
/// [`crate::session::Prepared::fegrass`], which shares the scoring + sort
/// with the pdGRASS recoveries from the same session.
pub fn fegrass_sorted(
    n_vertices: usize,
    off: &[OffTreeEdge],
    sp: &Spanning,
    params: &Params,
) -> Recovery {
    let target = params.target(n_vertices).min(off.len());
    let mut covered = EpochMarks::new(n_vertices);
    let mut recovered: Vec<u32> = Vec::with_capacity(target);
    let mut remaining: Vec<u32> = (0..off.len() as u32).collect();
    let mut stats = Stats::default();
    let mut passes = 0usize;

    while recovered.len() < target && !remaining.is_empty() {
        passes += 1;
        covered.clear();
        let mut next_remaining: Vec<u32> = Vec::new();
        let mut done = false;
        for (scan, &idx) in remaining.iter().enumerate() {
            if done {
                next_remaining.extend_from_slice(&remaining[scan..]);
                break;
            }
            let e = &off[idx as usize];
            stats.check_units += 1;
            if covered.is_marked(e.u as usize) || covered.is_marked(e.v as usize) {
                next_remaining.push(idx);
                continue;
            }
            recovered.push(e.eid);
            stats.bfs_units += mark_neighborhood(sp, e.u, params.beta_cap, &mut covered);
            stats.bfs_units += mark_neighborhood(sp, e.v, params.beta_cap, &mut covered);
            if recovered.len() == target {
                done = true;
            }
        }
        if next_remaining.len() == remaining.len() {
            // No progress is impossible (an uncovered pass always recovers
            // its first edge), but guard against infinite loops anyway.
            break;
        }
        remaining = next_remaining;
    }
    Recovery { edges: recovered, passes, stats, trace: None, step_ms: [0.0; 4] }
}

/// Mark all vertices within `beta` tree hops of `u` as covered.
/// Returns visited-vertex work units.
fn mark_neighborhood(sp: &Spanning, u: u32, beta: u32, covered: &mut EpochMarks) -> u64 {
    let mut units = 1u64;
    covered.mark(u as usize);
    if beta == 0 {
        return units;
    }
    let mut frontier: Vec<(u32, u32)> = vec![(u, u)];
    for _ in 0..beta {
        let mut next = Vec::new();
        for &(v, from) in &frontier {
            for nb in sp.tree.tree_neighbors(v) {
                if nb != from {
                    covered.mark(nb as usize);
                    units += 1;
                    next.push((nb, v));
                }
            }
        }
        if next.is_empty() {
            break;
        }
        frontier = next;
    }
    units
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::recovery::Strategy;
    use crate::tree::build_spanning;
    use crate::util::Rng;

    fn params(alpha: f64, beta: u32) -> Params {
        Params {
            beta_cap: beta,
            strategy: Strategy::Serial,
            block: 1,
            ..Params::new(alpha, 1)
        }
    }

    #[test]
    fn recovers_target_count() {
        let g = gen::grid(30, 30, 0.6, &mut Rng::new(2));
        let sp = build_spanning(&g);
        let p = params(0.05, 8);
        let r = fegrass(&g, &sp, &p);
        assert_eq!(r.edges.len(), p.target(g.num_vertices()));
        // all recovered edges are off-tree and unique
        let mut seen = std::collections::HashSet::new();
        for &eid in &r.edges {
            assert!(!sp.is_tree_edge[eid as usize]);
            assert!(seen.insert(eid));
        }
        assert!(r.passes >= 1);
    }

    #[test]
    fn zero_beta_recovers_greedily() {
        // β = 0 covers only the endpoints → most edges recoverable in pass 1
        let g = gen::grid(20, 20, 0.7, &mut Rng::new(3));
        let sp = build_spanning(&g);
        let p = params(0.02, 0);
        let r = fegrass(&g, &sp, &p);
        assert_eq!(r.passes, 1);
        assert_eq!(r.edges.len(), p.target(g.num_vertices()));
    }

    #[test]
    fn hub_graph_needs_many_passes() {
        // Hub graph: covering a hub marks nearly everything (the
        // com-Youtube pathology). With large β, passes must exceed 1.
        let g = gen::hub_graph(2000, 2, 800, &mut Rng::new(4));
        let sp = build_spanning(&g);
        let p = params(0.05, 8);
        let r = fegrass(&g, &sp, &p);
        assert!(r.passes > 3, "expected many passes on hub graph, got {}", r.passes);
        assert_eq!(r.edges.len(), p.target(g.num_vertices()).min(sp.num_off_tree()));
    }

    #[test]
    fn recovered_are_top_scored_first() {
        let g = gen::tri_mesh(15, 15, &mut Rng::new(5));
        let sp = build_spanning(&g);
        let p = params(0.02, 2);
        let r = fegrass(&g, &sp, &p);
        assert!(!r.edges.is_empty());
        // First recovered edge must be the single best-scored off-tree edge
        let off = crate::tree::off_tree_edges(&g, &sp);
        let best = off
            .iter()
            .max_by(|a, b| a.score.partial_cmp(&b.score).unwrap())
            .unwrap();
        assert_eq!(r.edges[0], best.eid);
    }

    #[test]
    fn alpha_zero_recovers_nothing() {
        let g = gen::grid(10, 10, 0.5, &mut Rng::new(6));
        let sp = build_spanning(&g);
        let r = fegrass(&g, &sp, &params(0.0, 8));
        assert!(r.edges.is_empty());
        assert_eq!(r.passes, 0);
    }
}
