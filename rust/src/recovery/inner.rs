//! Subtask processing: serial, and blocked inner-parallel with the
//! *Judge-before-Parallel* optimization (§IV.A, Appendix C).
//!
//! Execution model (eager marking, [`super::subctx`]): processing an
//! *unmarked* edge recovers it and **explores** — BFS for its β\*-hop
//! neighborhoods plus enumeration of the strictly-similar later edges,
//! which get marked. A marked edge takes the O(1) continue branch.
//!
//! Lemma 8 (non-commutativity) forces in-order commits, so inner
//! parallelism uses pGRASS's blocked scheme: a block of `p` edges
//! explores **speculatively in parallel** (exploration only reads state);
//! a serial in-order commit then applies each edge's marks — an edge
//! marked by an earlier commit in the same block is a *false positive*
//! (its exploration was wasted work, Table III).
//!
//! Without Judge-before-Parallel the block is simply the next `p` edges,
//! so already-marked edges occupy block slots and idle their thread
//! ("skipped in parallel": 57% of com-Youtube iterations in the paper).
//! With JBP, a serial judge — now a cheap flag check — filters them out
//! first, so every thread explores: 100% utilization.
//!
//! The per-block `par_map` here runs on the persistent pool, and under
//! the Mixed strategy it runs *nested inside* an outer pooled task; one
//! block is dispatched per explore phase, so pooled dispatch (queue push
//! instead of thread spawn/join per block) matters for throughput.
//!
//! # Sharded speculation ([`process_sharded`])
//!
//! The blocked scheme still serializes a giant subtask at block
//! granularity: one commit barrier per `p` explores. On the feGRASS worst
//! cases (one dominant LCA subtask) that leaves the pool idle between
//! blocks. [`process_sharded`] removes the barrier: the subtask is cut
//! into contiguous score-order shards ([`super::subtask::shard_ranges`]),
//! each shard runs the *whole* strict pass speculatively against its own
//! local mark buffer (a pooled [`super::subctx::ShardScratch`]), and a
//! serial commit then replays the exact serial algorithm in fixed shard
//! order. The commit is sound because [`SubtaskCtx::explore`] is a *pure*
//! function of the position — the mark state only decides *whether* an
//! edge explores, never what its exploration returns — so speculative
//! explore results are a memo-cache the commit can consult: a position
//! the commit finds marked discards its speculative explore (a false
//! positive, wasted parallel work), and a position the commit finds
//! unmarked but that speculation skipped is explored inline (a *commit
//! miss*, rare because cross-shard marks are the only way speculation
//! diverges). The recovered set is therefore bitwise identical to
//! [`process_serial`] at every thread count, by construction.

use super::subctx::{ScratchArena, SubtaskCtx};
use super::subtask::shard_ranges;
use super::{Params, Stats};
use crate::par;
use crate::tree::{OffTreeEdge, Spanning};

/// Outcome of processing a single subtask.
#[derive(Clone, Debug, Default)]
pub struct SubtaskOutcome {
    /// Recovered entries: ascending indices into the sorted off-tree array.
    pub recovered: Vec<u32>,
    /// Entries marked similar (leftover for a fallback pass).
    pub leftover: Vec<u32>,
    /// Counters.
    pub stats: Stats,
    /// Per-edge `(check_units, explore_units)` in processing order, for
    /// the scheduling simulator.
    pub costs: Vec<(u32, u32)>,
}

/// Serial in-order processing of one subtask.
pub fn process_serial(
    off: &[OffTreeEdge],
    sp: &Spanning,
    idxs: &[u32],
    params: &Params,
) -> SubtaskOutcome {
    let ctx = SubtaskCtx::new(off, idxs);
    let m = idxs.len();
    let mut out = SubtaskOutcome::default();
    out.costs.reserve(m);
    let mut marked = vec![false; m];
    for pos in 0..m {
        out.stats.check_units += 1;
        if marked[pos] {
            out.leftover.push(idxs[pos]);
            out.costs.push((1, 0));
            continue;
        }
        let (marks, cost) = ctx.explore(sp, pos, params.beta_cap);
        for &p2 in &marks {
            marked[p2 as usize] = true;
        }
        out.recovered.push(idxs[pos]);
        out.costs.push((1, cost));
        out.stats.bfs_units += cost as u64;
    }
    out
}

/// Blocked inner-parallel processing of one subtask.
///
/// `params.jbp` toggles Judge-before-Parallel; `params.block` is the
/// block size (the paper sets it to the thread count `p`). Recovers
/// exactly the same edge set as [`process_serial`] — the serial commit
/// enforces Lemma 8's ordering.
pub fn process_inner(
    off: &[OffTreeEdge],
    sp: &Spanning,
    idxs: &[u32],
    params: &Params,
) -> SubtaskOutcome {
    let ctx = SubtaskCtx::new(off, idxs);
    let m = idxs.len();
    let mut out = SubtaskOutcome::default();
    out.costs.reserve(m);
    let mut marked = vec![false; m];
    let block_size = params.block.max(1);
    let mut pos = 0usize;

    while pos < m {
        // ---- form the block ----
        let mut block: Vec<u32> = Vec::with_capacity(block_size);
        if params.jbp {
            // Serial judge: O(1) flag checks until `block_size` unmarked
            // edges are found (or the subtask is exhausted).
            while block.len() < block_size && pos < m {
                out.stats.check_units += 1;
                if marked[pos] {
                    out.leftover.push(idxs[pos]);
                    out.costs.push((1, 0));
                } else {
                    block.push(pos as u32);
                }
                pos += 1;
            }
        } else {
            let end = (pos + block_size).min(m);
            block.extend((pos..end).map(|p| p as u32));
            pos = end;
        }
        if block.is_empty() {
            break;
        }
        out.stats.blocks += 1;
        out.stats.edges_in_blocks += block.len() as u64;

        // ---- parallel explore phase (speculative; reads `marked` only) ----
        let explored: Vec<Option<(Vec<u32>, u32)>> =
            par::par_map(&block, params.threads, |&bpos| {
                if !params.jbp && marked[bpos as usize] {
                    // continue-branch bubble: the thread idles this slot
                    return None;
                }
                Some(ctx.explore(sp, bpos as usize, params.beta_cap))
            });

        // ---- serial in-order commit (Lemma 8 ordering) ----
        for (slot, &bpos) in block.iter().enumerate() {
            let gidx = idxs[bpos as usize];
            match &explored[slot] {
                None => {
                    out.stats.skipped_in_parallel += 1;
                    out.stats.check_units += 1;
                    out.leftover.push(gidx);
                    out.costs.push((1, 0));
                }
                Some((marks, cost)) => {
                    out.stats.explored_in_parallel += 1;
                    out.stats.check_units += 1;
                    if marked[bpos as usize] {
                        // marked by an earlier commit in this very block:
                        // the parallel exploration was wasted
                        out.stats.false_positives += 1;
                        out.leftover.push(gidx);
                        out.costs.push((1, *cost));
                    } else {
                        for &p2 in marks {
                            marked[p2 as usize] = true;
                        }
                        out.recovered.push(gidx);
                        out.costs.push((1, *cost));
                        out.stats.bfs_units += *cost as u64;
                    }
                }
            }
        }
    }
    out.recovered.sort_unstable();
    out.leftover.sort_unstable();
    out
}

/// Per-shard speculation result: for each position in the shard's range
/// (in order), `None` if the shard's own speculation had already marked
/// it, else the pure exploration result `(marks, cost)`.
struct ShardSpec {
    explored: Vec<Option<(Vec<u32>, u32)>>,
}

/// Sharded speculative processing of one subtask (see the module docs
/// for the execution model and the correctness argument).
///
/// The shard layout depends only on `(idxs.len(), params.shard_min)`, so
/// the outcome — recovered set, leftovers, *and every counter in
/// [`Stats`]* — is identical at every `params.threads`; threads only
/// change how many shards speculate concurrently. Subtasks that fit in a
/// single shard skip speculation entirely and run [`process_serial`].
pub fn process_sharded(
    off: &[OffTreeEdge],
    sp: &Spanning,
    idxs: &[u32],
    params: &Params,
) -> SubtaskOutcome {
    process_sharded_with(off, sp, idxs, params, &ScratchArena::new())
}

/// As [`process_sharded`], speculating against scratch buffers from a
/// caller-owned [`ScratchArena`] — the pass loop in `recovery::pdgrass`
/// creates one arena per pass so consecutive giant subtasks reuse each
/// other's grown buffers instead of re-allocating from cold.
pub fn process_sharded_with(
    off: &[OffTreeEdge],
    sp: &Spanning,
    idxs: &[u32],
    params: &Params,
    scratch: &ScratchArena,
) -> SubtaskOutcome {
    let m = idxs.len();
    let ranges = shard_ranges(m, params.shard_min);
    if ranges.len() <= 1 {
        // One shard's speculation is exact — just run the serial pass.
        return process_serial(off, sp, idxs, params);
    }
    let ctx = SubtaskCtx::new(off, idxs);

    // ---- speculative phase: shards fan out across the pool ----
    // Each shard runs the strict pass as if it started the subtask:
    // local marks only, but the full mark lists (which may point into
    // later shards) are kept for the commit.
    let specs: Vec<ShardSpec> = par::par_map(&ranges, params.threads, |r| {
        let mut s = scratch.take(r.len());
        let mut explored: Vec<Option<(Vec<u32>, u32)>> = Vec::with_capacity(r.len());
        for pos in r.clone() {
            if s.marked[pos - r.start] {
                explored.push(None);
                continue;
            }
            let (marks, cost) = ctx.explore(sp, pos, params.beta_cap);
            for &p2 in &marks {
                if (p2 as usize) < r.end {
                    s.marked[p2 as usize - r.start] = true;
                }
            }
            explored.push(Some((marks, cost)));
        }
        scratch.put(s);
        ShardSpec { explored }
    });

    // ---- deterministic commit: the serial strict pass in fixed shard
    // order, with speculative explores as a memo-cache ----
    let mut out = SubtaskOutcome::default();
    out.costs.reserve(m);
    out.stats.sharded_subtasks = 1;
    out.stats.shards = ranges.len() as u64;
    let mut marked = vec![false; m];
    for (r, spec) in ranges.iter().zip(&specs) {
        for pos in r.clone() {
            let gidx = idxs[pos];
            let spec_entry = &spec.explored[pos - r.start];
            out.stats.check_units += 1;
            if spec_entry.is_some() {
                out.stats.explored_in_parallel += 1;
            }
            if marked[pos] {
                // Serial would skip this edge. A speculative explore for
                // it was wasted parallel work; its cost stays visible to
                // the scheduling simulator (as in the blocked scheme).
                match spec_entry {
                    Some((_, cost)) => {
                        out.stats.false_positives += 1;
                        out.costs.push((1, *cost));
                    }
                    None => out.costs.push((1, 0)),
                }
                out.leftover.push(gidx);
                continue;
            }
            // Serial would recover and explore this edge. Explore results
            // are pure, so the speculative one (if any) is exact; a miss
            // (speculation skipped it, but its in-shard marker turned out
            // to be a false positive) is explored inline.
            let computed;
            let (marks, cost): (&[u32], u32) = match spec_entry {
                Some((marks, cost)) => (marks, *cost),
                None => {
                    out.stats.commit_misses += 1;
                    computed = ctx.explore(sp, pos, params.beta_cap);
                    (&computed.0, computed.1)
                }
            };
            for &p2 in marks {
                marked[p2 as usize] = true;
            }
            out.recovered.push(gidx);
            out.costs.push((1, cost));
            out.stats.bfs_units += cost as u64;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::recovery::score::sort_by_score;
    use crate::recovery::strict::TagStore;
    use crate::recovery::{Params, Strategy};
    use crate::tree::{build_spanning, off_tree_edges};
    use crate::util::Rng;

    fn params(block: usize, jbp: bool) -> Params {
        Params {
            strategy: Strategy::Inner,
            block,
            jbp,
            shard_min: 32,
            ..Params::new(1.0, 4)
        }
    }

    /// Independent oracle: lazy tag-probing recovery (the [`TagStore`]
    /// formulation) — must select exactly the same edges as the eager
    /// marking implementation.
    fn process_lazy_oracle(
        off: &[crate::tree::OffTreeEdge],
        sp: &crate::tree::Spanning,
        idxs: &[u32],
        cap: u32,
    ) -> Vec<u32> {
        let mut tags = TagStore::new();
        let mut recovered = Vec::new();
        let mut k = 0u32;
        for &i in idxs {
            let e = &off[i as usize];
            let mut c = 0u32;
            if !tags.is_similar(e.u, e.v, &mut c) {
                let (su, sv, _) = crate::recovery::strict::neighborhoods(sp, e, cap);
                tags.add(k, &su, &sv);
                k += 1;
                recovered.push(i);
            }
        }
        recovered
    }

    #[test]
    fn eager_matches_lazy_oracle() {
        for seed in [1u64, 2, 3, 4] {
            let g = gen::community(
                gen::CommunityParams {
                    n: 600,
                    mean_size: 12.0,
                    tail: 1.7,
                    intra_p: 0.5,
                    bridges: 2,
                    max_size: 80,
                },
                &mut Rng::new(seed),
            );
            let sp = build_spanning(&g);
            let mut off = off_tree_edges(&g, &sp);
            sort_by_score(&mut off, 1);
            let subtasks = crate::recovery::subtask::make_subtasks(&off);
            for st in subtasks.iter().take(3) {
                let eager = process_serial(&off, &sp, &st.idxs, &params(8, true));
                let lazy = process_lazy_oracle(&off, &sp, &st.idxs, 8);
                assert_eq!(eager.recovered, lazy, "seed={seed} lca={}", st.lca);
            }
        }
    }

    #[test]
    fn blocked_matches_serial_oracle() {
        for seed in [1u64, 2, 3] {
            for jbp in [false, true] {
                let g = gen::community(
                    gen::CommunityParams {
                        n: 600,
                        mean_size: 12.0,
                        tail: 1.7,
                        intra_p: 0.5,
                        bridges: 2,
                        max_size: 80,
                    },
                    &mut Rng::new(seed),
                );
                let sp = build_spanning(&g);
                let mut off = off_tree_edges(&g, &sp);
                sort_by_score(&mut off, 1);
                let subtasks = crate::recovery::subtask::make_subtasks(&off);
                let big = &subtasks[0];
                let serial = process_serial(&off, &sp, &big.idxs, &params(8, jbp));
                let blocked = process_inner(&off, &sp, &big.idxs, &params(8, jbp));
                assert_eq!(serial.recovered, blocked.recovered, "seed={seed} jbp={jbp}");
                assert_eq!(serial.leftover, blocked.leftover, "seed={seed} jbp={jbp}");
            }
        }
    }

    #[test]
    fn jbp_eliminates_parallel_skips() {
        let g = gen::hub_graph(1500, 2, 700, &mut Rng::new(9));
        let sp = build_spanning(&g);
        let mut off = off_tree_edges(&g, &sp);
        sort_by_score(&mut off, 1);
        let subtasks = crate::recovery::subtask::make_subtasks(&off);
        let big = &subtasks[0];
        assert!(big.len() > 50, "need a real subtask, got {}", big.len());
        let without = process_inner(&off, &sp, &big.idxs, &params(8, false));
        let with = process_inner(&off, &sp, &big.idxs, &params(8, true));
        assert_eq!(with.stats.skipped_in_parallel, 0);
        assert!(without.stats.skipped_in_parallel > 0);
        // With JBP every blocked edge explores.
        assert_eq!(with.stats.edges_in_blocks, with.stats.explored_in_parallel);
        // Same recovery either way.
        assert_eq!(with.recovered, without.recovered);
    }

    #[test]
    fn sharded_matches_serial_oracle() {
        // Every shard size — including degenerate and boundary ones —
        // must reproduce the serial recovered/leftover sets exactly, at
        // every thread count.
        for seed in [1u64, 2, 3] {
            let g = gen::community(
                gen::CommunityParams {
                    n: 600,
                    mean_size: 12.0,
                    tail: 1.7,
                    intra_p: 0.5,
                    bridges: 2,
                    max_size: 80,
                },
                &mut Rng::new(seed),
            );
            let sp = build_spanning(&g);
            let mut off = off_tree_edges(&g, &sp);
            sort_by_score(&mut off, 1);
            let subtasks = crate::recovery::subtask::make_subtasks(&off);
            let big = &subtasks[0];
            let serial = process_serial(&off, &sp, &big.idxs, &params(8, true));
            for shard_min in [1usize, 2, 7, big.len() / 3 + 1, big.len(), big.len() + 100] {
                for threads in [1usize, 2, 8] {
                    let mut p = params(8, true);
                    p.shard_min = shard_min;
                    p.threads = threads;
                    let sharded = process_sharded(&off, &sp, &big.idxs, &p);
                    assert_eq!(
                        serial.recovered,
                        sharded.recovered,
                        "seed={seed} shard_min={shard_min} threads={threads}"
                    );
                    assert_eq!(
                        serial.leftover,
                        sharded.leftover,
                        "seed={seed} shard_min={shard_min} threads={threads}"
                    );
                }
            }
        }
    }

    #[test]
    fn sharded_accounting_counts_each_edge_once() {
        let g = gen::hub_graph(1500, 2, 700, &mut Rng::new(9));
        let sp = build_spanning(&g);
        let mut off = off_tree_edges(&g, &sp);
        sort_by_score(&mut off, 1);
        let subtasks = crate::recovery::subtask::make_subtasks(&off);
        let big = &subtasks[0];
        let m = big.len();
        assert!(m > 50, "need a real subtask, got {m}");
        let serial = process_serial(&off, &sp, &big.idxs, &params(8, true));
        let mut p = params(8, true);
        p.shard_min = 16;
        let sharded = process_sharded(&off, &sp, &big.idxs, &p);
        // Exactly one cost entry and one check per judged edge, and the
        // recovered/leftover split partitions the subtask.
        assert_eq!(sharded.costs.len(), m);
        assert_eq!(sharded.stats.check_units, m as u64);
        assert_eq!(sharded.recovered.len() + sharded.leftover.len(), m);
        assert_eq!(sharded.stats.shards, m.div_ceil(16) as u64);
        // Committed BFS work matches serial bitwise (explore is pure, so
        // committed recoveries charge identical unit costs).
        assert_eq!(sharded.stats.bfs_units, serial.stats.bfs_units);
        assert_eq!(sharded.recovered, serial.recovered);
        // Thread count changes nothing — not even the wasted-work stats.
        for threads in [1usize, 2, 8] {
            let mut pt = p;
            pt.threads = threads;
            let r = process_sharded(&off, &sp, &big.idxs, &pt);
            assert_eq!(r.recovered, sharded.recovered, "threads={threads}");
            assert_eq!(r.costs, sharded.costs, "threads={threads}");
            assert_eq!(
                format!("{:?}", r.stats),
                format!("{:?}", sharded.stats),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn shared_arena_matches_private_and_bounds_allocations() {
        // Processing several subtasks against ONE pass arena must (a)
        // change nothing about the outcomes and (b) create at most one
        // buffer per worker per concurrent shard wave — not one per
        // subtask — which is the allocator-churn fix the arena exists for.
        let g = gen::community(
            gen::CommunityParams {
                n: 600,
                mean_size: 12.0,
                tail: 1.7,
                intra_p: 0.5,
                bridges: 2,
                max_size: 80,
            },
            &mut Rng::new(3),
        );
        let sp = build_spanning(&g);
        let mut off = off_tree_edges(&g, &sp);
        sort_by_score(&mut off, 1);
        let subtasks = crate::recovery::subtask::make_subtasks(&off);
        let mut p = params(8, true);
        p.shard_min = 8;
        let sharded: Vec<_> =
            subtasks.iter().filter(|st| shard_ranges(st.len(), p.shard_min).len() > 1).collect();
        assert!(sharded.len() >= 2, "need several sharded subtasks, got {}", sharded.len());
        let arena = ScratchArena::new();
        for st in &sharded {
            let private = process_sharded(&off, &sp, &st.idxs, &p);
            let pooled = process_sharded_with(&off, &sp, &st.idxs, &p, &arena);
            assert_eq!(private.recovered, pooled.recovered, "lca={}", st.lca);
            assert_eq!(private.leftover, pooled.leftover, "lca={}", st.lca);
        }
        // Workers claim one scratch at a time, so the arena can never
        // need more live buffers than pool workers + the caller — far
        // fewer than the total shard count across all subtasks.
        let cap = crate::par::ThreadPool::global().workers() + 1;
        assert!(
            arena.buffers_created() <= cap,
            "created {} buffers for {} subtasks (cap {cap})",
            arena.buffers_created(),
            sharded.len()
        );
    }

    #[test]
    fn block_size_one_equals_serial_exactly() {
        let g = gen::grid(15, 15, 0.6, &mut Rng::new(11));
        let sp = build_spanning(&g);
        let mut off = off_tree_edges(&g, &sp);
        sort_by_score(&mut off, 1);
        let subtasks = crate::recovery::subtask::make_subtasks(&off);
        for st in subtasks.iter().take(5) {
            let serial = process_serial(&off, &sp, &st.idxs, &params(1, true));
            let blocked = process_inner(&off, &sp, &st.idxs, &params(1, true));
            assert_eq!(serial.recovered, blocked.recovered);
            // block of 1 can never have intra-block false positives
            assert_eq!(blocked.stats.false_positives, 0);
        }
    }
}
