//! Subtask processing: serial, and blocked inner-parallel with the
//! *Judge-before-Parallel* optimization (§IV.A, Appendix C).
//!
//! Execution model (eager marking, [`super::subctx`]): processing an
//! *unmarked* edge recovers it and **explores** — BFS for its β\*-hop
//! neighborhoods plus enumeration of the strictly-similar later edges,
//! which get marked. A marked edge takes the O(1) continue branch.
//!
//! Lemma 8 (non-commutativity) forces in-order commits, so inner
//! parallelism uses pGRASS's blocked scheme: a block of `p` edges
//! explores **speculatively in parallel** (exploration only reads state);
//! a serial in-order commit then applies each edge's marks — an edge
//! marked by an earlier commit in the same block is a *false positive*
//! (its exploration was wasted work, Table III).
//!
//! Without Judge-before-Parallel the block is simply the next `p` edges,
//! so already-marked edges occupy block slots and idle their thread
//! ("skipped in parallel": 57% of com-Youtube iterations in the paper).
//! With JBP, a serial judge — now a cheap flag check — filters them out
//! first, so every thread explores: 100% utilization.
//!
//! The per-block `par_map` here runs on the persistent pool, and under
//! the Mixed strategy it runs *nested inside* an outer pooled task; one
//! block is dispatched per explore phase, so pooled dispatch (queue push
//! instead of thread spawn/join per block) matters for throughput.

use super::subctx::SubtaskCtx;
use super::{Params, Stats};
use crate::par;
use crate::tree::{OffTreeEdge, Spanning};

/// Outcome of processing a single subtask.
#[derive(Clone, Debug, Default)]
pub struct SubtaskOutcome {
    /// Recovered entries: ascending indices into the sorted off-tree array.
    pub recovered: Vec<u32>,
    /// Entries marked similar (leftover for a fallback pass).
    pub leftover: Vec<u32>,
    /// Counters.
    pub stats: Stats,
    /// Per-edge `(check_units, explore_units)` in processing order, for
    /// the scheduling simulator.
    pub costs: Vec<(u32, u32)>,
}

/// Serial in-order processing of one subtask.
pub fn process_serial(
    off: &[OffTreeEdge],
    sp: &Spanning,
    idxs: &[u32],
    params: &Params,
) -> SubtaskOutcome {
    let ctx = SubtaskCtx::new(off, idxs);
    let m = idxs.len();
    let mut out = SubtaskOutcome::default();
    out.costs.reserve(m);
    let mut marked = vec![false; m];
    for pos in 0..m {
        out.stats.check_units += 1;
        if marked[pos] {
            out.leftover.push(idxs[pos]);
            out.costs.push((1, 0));
            continue;
        }
        let (marks, cost) = ctx.explore(sp, pos, params.beta_cap);
        for &p2 in &marks {
            marked[p2 as usize] = true;
        }
        out.recovered.push(idxs[pos]);
        out.costs.push((1, cost));
        out.stats.bfs_units += cost as u64;
    }
    out
}

/// Blocked inner-parallel processing of one subtask.
///
/// `params.jbp` toggles Judge-before-Parallel; `params.block` is the
/// block size (the paper sets it to the thread count `p`). Recovers
/// exactly the same edge set as [`process_serial`] — the serial commit
/// enforces Lemma 8's ordering.
pub fn process_inner(
    off: &[OffTreeEdge],
    sp: &Spanning,
    idxs: &[u32],
    params: &Params,
) -> SubtaskOutcome {
    let ctx = SubtaskCtx::new(off, idxs);
    let m = idxs.len();
    let mut out = SubtaskOutcome::default();
    out.costs.reserve(m);
    let mut marked = vec![false; m];
    let block_size = params.block.max(1);
    let mut pos = 0usize;

    while pos < m {
        // ---- form the block ----
        let mut block: Vec<u32> = Vec::with_capacity(block_size);
        if params.jbp {
            // Serial judge: O(1) flag checks until `block_size` unmarked
            // edges are found (or the subtask is exhausted).
            while block.len() < block_size && pos < m {
                out.stats.check_units += 1;
                if marked[pos] {
                    out.leftover.push(idxs[pos]);
                    out.costs.push((1, 0));
                } else {
                    block.push(pos as u32);
                }
                pos += 1;
            }
        } else {
            let end = (pos + block_size).min(m);
            block.extend((pos..end).map(|p| p as u32));
            pos = end;
        }
        if block.is_empty() {
            break;
        }
        out.stats.blocks += 1;
        out.stats.edges_in_blocks += block.len() as u64;

        // ---- parallel explore phase (speculative; reads `marked` only) ----
        let explored: Vec<Option<(Vec<u32>, u32)>> =
            par::par_map(&block, params.threads, |&bpos| {
                if !params.jbp && marked[bpos as usize] {
                    // continue-branch bubble: the thread idles this slot
                    return None;
                }
                Some(ctx.explore(sp, bpos as usize, params.beta_cap))
            });

        // ---- serial in-order commit (Lemma 8 ordering) ----
        for (slot, &bpos) in block.iter().enumerate() {
            let gidx = idxs[bpos as usize];
            match &explored[slot] {
                None => {
                    out.stats.skipped_in_parallel += 1;
                    out.stats.check_units += 1;
                    out.leftover.push(gidx);
                    out.costs.push((1, 0));
                }
                Some((marks, cost)) => {
                    out.stats.explored_in_parallel += 1;
                    out.stats.check_units += 1;
                    if marked[bpos as usize] {
                        // marked by an earlier commit in this very block:
                        // the parallel exploration was wasted
                        out.stats.false_positives += 1;
                        out.leftover.push(gidx);
                        out.costs.push((1, *cost));
                    } else {
                        for &p2 in marks {
                            marked[p2 as usize] = true;
                        }
                        out.recovered.push(gidx);
                        out.costs.push((1, *cost));
                        out.stats.bfs_units += *cost as u64;
                    }
                }
            }
        }
    }
    out.recovered.sort_unstable();
    out.leftover.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::recovery::score::sort_by_score;
    use crate::recovery::strict::TagStore;
    use crate::recovery::{Params, Strategy};
    use crate::tree::{build_spanning, off_tree_edges};
    use crate::util::Rng;

    fn params(block: usize, jbp: bool) -> Params {
        Params {
            alpha: 1.0,
            beta_cap: 8,
            strategy: Strategy::Inner,
            threads: 4,
            block,
            cutoff_edges: 100_000,
            cutoff_frac: 0.10,
            jbp,
        }
    }

    /// Independent oracle: lazy tag-probing recovery (the [`TagStore`]
    /// formulation) — must select exactly the same edges as the eager
    /// marking implementation.
    fn process_lazy_oracle(
        off: &[crate::tree::OffTreeEdge],
        sp: &crate::tree::Spanning,
        idxs: &[u32],
        cap: u32,
    ) -> Vec<u32> {
        let mut tags = TagStore::new();
        let mut recovered = Vec::new();
        let mut k = 0u32;
        for &i in idxs {
            let e = &off[i as usize];
            let mut c = 0u32;
            if !tags.is_similar(e.u, e.v, &mut c) {
                let (su, sv, _) = crate::recovery::strict::neighborhoods(sp, e, cap);
                tags.add(k, &su, &sv);
                k += 1;
                recovered.push(i);
            }
        }
        recovered
    }

    #[test]
    fn eager_matches_lazy_oracle() {
        for seed in [1u64, 2, 3, 4] {
            let g = gen::community(
                gen::CommunityParams {
                    n: 600,
                    mean_size: 12.0,
                    tail: 1.7,
                    intra_p: 0.5,
                    bridges: 2,
                    max_size: 80,
                },
                &mut Rng::new(seed),
            );
            let sp = build_spanning(&g);
            let mut off = off_tree_edges(&g, &sp);
            sort_by_score(&mut off, 1);
            let subtasks = crate::recovery::subtask::make_subtasks(&off);
            for st in subtasks.iter().take(3) {
                let eager = process_serial(&off, &sp, &st.idxs, &params(8, true));
                let lazy = process_lazy_oracle(&off, &sp, &st.idxs, 8);
                assert_eq!(eager.recovered, lazy, "seed={seed} lca={}", st.lca);
            }
        }
    }

    #[test]
    fn blocked_matches_serial_oracle() {
        for seed in [1u64, 2, 3] {
            for jbp in [false, true] {
                let g = gen::community(
                    gen::CommunityParams {
                        n: 600,
                        mean_size: 12.0,
                        tail: 1.7,
                        intra_p: 0.5,
                        bridges: 2,
                        max_size: 80,
                    },
                    &mut Rng::new(seed),
                );
                let sp = build_spanning(&g);
                let mut off = off_tree_edges(&g, &sp);
                sort_by_score(&mut off, 1);
                let subtasks = crate::recovery::subtask::make_subtasks(&off);
                let big = &subtasks[0];
                let serial = process_serial(&off, &sp, &big.idxs, &params(8, jbp));
                let blocked = process_inner(&off, &sp, &big.idxs, &params(8, jbp));
                assert_eq!(serial.recovered, blocked.recovered, "seed={seed} jbp={jbp}");
                assert_eq!(serial.leftover, blocked.leftover, "seed={seed} jbp={jbp}");
            }
        }
    }

    #[test]
    fn jbp_eliminates_parallel_skips() {
        let g = gen::hub_graph(1500, 2, 700, &mut Rng::new(9));
        let sp = build_spanning(&g);
        let mut off = off_tree_edges(&g, &sp);
        sort_by_score(&mut off, 1);
        let subtasks = crate::recovery::subtask::make_subtasks(&off);
        let big = &subtasks[0];
        assert!(big.len() > 50, "need a real subtask, got {}", big.len());
        let without = process_inner(&off, &sp, &big.idxs, &params(8, false));
        let with = process_inner(&off, &sp, &big.idxs, &params(8, true));
        assert_eq!(with.stats.skipped_in_parallel, 0);
        assert!(without.stats.skipped_in_parallel > 0);
        // With JBP every blocked edge explores.
        assert_eq!(with.stats.edges_in_blocks, with.stats.explored_in_parallel);
        // Same recovery either way.
        assert_eq!(with.recovered, without.recovered);
    }

    #[test]
    fn block_size_one_equals_serial_exactly() {
        let g = gen::grid(15, 15, 0.6, &mut Rng::new(11));
        let sp = build_spanning(&g);
        let mut off = off_tree_edges(&g, &sp);
        sort_by_score(&mut off, 1);
        let subtasks = crate::recovery::subtask::make_subtasks(&off);
        for st in subtasks.iter().take(5) {
            let serial = process_serial(&off, &sp, &st.idxs, &params(1, true));
            let blocked = process_inner(&off, &sp, &st.idxs, &params(1, true));
            assert_eq!(serial.recovered, blocked.recovered);
            // block of 1 can never have intra-block false positives
            assert_eq!(blocked.stats.false_positives, 0);
        }
    }
}
