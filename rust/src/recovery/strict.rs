//! Strict similarity (Definition 5) machinery.
//!
//! When pdGRASS recovers an off-tree edge `e = (u, v)` it computes the two
//! β\*-hop tree neighborhoods `S_u`, `S_v` with
//! `β* = min(dist(u, lca), dist(v, lca), c)` (Eq. 8). A later candidate
//! `e' = (u', v')` is *strictly similar* to `e` iff
//! `(u'∈S_u ∧ v'∈S_v) ∨ (u'∈S_v ∧ v'∈S_u)`.
//!
//! This module holds the shared β\* computation plus the **lazy
//! tag-probing** formulation of the condition: per-vertex tag lists
//! remember which recovered edges' `S_u`/`S_v` contain each vertex, and a
//! candidate check intersects two short sorted lists. The production
//! recovery uses the *eager marking* formulation ([`super::subctx`],
//! which parallelizes better — see Fig. 7); this one is kept as an
//! independently-implemented equivalence oracle for the tests.

use crate::tree::{OffTreeEdge, Spanning};
use crate::util::FxHashMap;

/// β\* for a recovered edge (Eq. 8).
pub fn beta_star(sp: &Spanning, e: &OffTreeEdge, cap: u32) -> u32 {
    let dl = sp.tree.depth[e.lca as usize];
    let du = sp.tree.depth[e.u as usize] - dl;
    let dv = sp.tree.depth[e.v as usize] - dl;
    du.min(dv).min(cap)
}

/// Per-vertex tag lists for a single subtask.
///
/// Tags are recovered-edge indices local to the subtask, pushed in
/// increasing order (so the lists stay sorted for linear intersection).
#[derive(Debug, Default)]
pub struct TagStore {
    /// vertex → (tags on the S_u side, tags on the S_v side).
    tags: FxHashMap<u32, (Vec<u32>, Vec<u32>)>,
}

impl TagStore {
    /// Fresh empty store.
    pub fn new() -> TagStore {
        TagStore { tags: FxHashMap::default() }
    }

    /// Record recovered edge `k`'s neighborhoods.
    pub fn add(&mut self, k: u32, s_u: &[u32], s_v: &[u32]) {
        for &x in s_u {
            self.tags.entry(x).or_default().0.push(k);
        }
        for &x in s_v {
            self.tags.entry(x).or_default().1.push(k);
        }
    }

    /// Is candidate `(u, v)` strictly similar to any recorded edge?
    /// Returns the probe cost in work units via `cost`.
    pub fn is_similar(&self, u: u32, v: u32, cost: &mut u32) -> bool {
        let empty: (Vec<u32>, Vec<u32>) = (Vec::new(), Vec::new());
        let tu = self.tags.get(&u).unwrap_or(&empty);
        let tv = self.tags.get(&v).unwrap_or(&empty);
        *cost += (tu.0.len() + tu.1.len() + tv.0.len() + tv.1.len()) as u32 + 1;
        // (u ∈ S_u^k ∧ v ∈ S_v^k)  ⇔  k ∈ tagsA(u) ∩ tagsB(v)
        sorted_intersects(&tu.0, &tv.1) || sorted_intersects(&tu.1, &tv.0)
    }

    /// Is candidate similar, considering only tags from edges with local
    /// index `< upto`? Used by the serial commit after a speculative
    /// parallel block (tags added within the block must count, tags from
    /// *later* edges must not — list order gives us that for free since we
    /// only ever append increasing indices; `upto` guards replay).
    pub fn is_similar_upto(&self, u: u32, v: u32, upto: u32, cost: &mut u32) -> bool {
        let empty: (Vec<u32>, Vec<u32>) = (Vec::new(), Vec::new());
        let tu = self.tags.get(&u).unwrap_or(&empty);
        let tv = self.tags.get(&v).unwrap_or(&empty);
        *cost += (tu.0.len() + tu.1.len() + tv.0.len() + tv.1.len()) as u32 + 1;
        sorted_intersects_below(&tu.0, &tv.1, upto) || sorted_intersects_below(&tu.1, &tv.0, upto)
    }
}

/// Do two ascending u32 slices share an element?
fn sorted_intersects(a: &[u32], b: &[u32]) -> bool {
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Equal => return true,
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
        }
    }
    false
}

/// Shared element strictly below `upto`?
fn sorted_intersects_below(a: &[u32], b: &[u32], upto: u32) -> bool {
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() && a[i] < upto && b[j] < upto {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Equal => return true,
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
        }
    }
    false
}

/// Compute the β\*-hop neighborhoods of a recovered edge's endpoints.
/// Returns `(S_u, S_v, bfs_cost_units)`.
pub fn neighborhoods(sp: &Spanning, e: &OffTreeEdge, cap: u32) -> (Vec<u32>, Vec<u32>, u32) {
    let beta = beta_star(sp, e, cap);
    let s_u = sp.tree.neighborhood(e.u, beta);
    let s_v = sp.tree.neighborhood(e.v, beta);
    let cost = (s_u.len() + s_v.len()) as u32;
    (s_u, s_v, cost)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::tree::build_spanning;

    /// Tree: path 0-1-2-3-4-5 (heavy), off-tree edges light.
    fn path_setup() -> (Graph, Spanning) {
        let g = Graph::from_edges(
            6,
            &[
                (0, 1, 100.0),
                (1, 2, 100.0),
                (2, 3, 100.0),
                (3, 4, 100.0),
                (4, 5, 100.0),
                (1, 4, 0.1),
                (2, 4, 0.1),
                (0, 5, 0.1),
            ],
        );
        let sp = build_spanning(&g);
        (g, sp)
    }

    fn off(g: &Graph, sp: &Spanning, u: u32, v: u32) -> OffTreeEdge {
        crate::tree::off_tree_edges(g, sp)
            .into_iter()
            .find(|e| e.u == u && e.v == v)
            .expect("edge not off-tree")
    }

    #[test]
    fn beta_star_capped_by_lca_distance() {
        let (g, sp) = path_setup();
        // Root is a path endpoint or max-degree vertex; for edge (1,4) on a
        // path tree, lca is the shallower endpoint → β* = min(d(u,l), d(v,l), 8)
        let e = off(&g, &sp, 1, 4);
        let dl = sp.tree.depth[e.lca as usize];
        let du = sp.tree.depth[1] - dl;
        let dv = sp.tree.depth[4] - dl;
        assert_eq!(beta_star(&sp, &e, 8), du.min(dv).min(8));
        assert_eq!(beta_star(&sp, &e, 0), 0);
    }

    #[test]
    fn tag_store_detects_strict_similarity() {
        let mut ts = TagStore::new();
        // recovered edge 0: S_u = {1,2}, S_v = {4,5}
        ts.add(0, &[1, 2], &[4, 5]);
        let mut cost = 0;
        // both endpoints inside respective sets → similar
        assert!(ts.is_similar(2, 4, &mut cost));
        // swapped orientation also similar
        assert!(ts.is_similar(4, 2, &mut cost));
        // only one endpoint inside → NOT similar (this is the strict AND)
        assert!(!ts.is_similar(2, 9, &mut cost));
        assert!(!ts.is_similar(9, 4, &mut cost));
        assert!(cost > 0);
    }

    #[test]
    fn loose_would_match_but_strict_does_not() {
        // Candidate with one endpoint in S_u and the other nowhere:
        // loose (OR) would mark it similar, strict (AND) must not.
        let mut ts = TagStore::new();
        ts.add(0, &[10, 11], &[20, 21]);
        let mut c = 0;
        assert!(!ts.is_similar(10, 99, &mut c));
        assert!(!ts.is_similar(99, 21, &mut c));
        assert!(ts.is_similar(11, 20, &mut c));
    }

    #[test]
    fn upto_guards_commit_order() {
        let mut ts = TagStore::new();
        ts.add(0, &[1], &[2]);
        ts.add(1, &[3], &[4]);
        let mut c = 0;
        assert!(ts.is_similar_upto(3, 4, 2, &mut c)); // edge 1 visible
        assert!(!ts.is_similar_upto(3, 4, 1, &mut c)); // edge 1 hidden
        assert!(ts.is_similar_upto(1, 2, 1, &mut c)); // edge 0 visible
    }

    #[test]
    fn neighborhoods_and_cost() {
        let (g, sp) = path_setup();
        let e = off(&g, &sp, 0, 5);
        let (su, sv, cost) = neighborhoods(&sp, &e, 1);
        assert_eq!(cost as usize, su.len() + sv.len());
        assert!(su.contains(&0));
        assert!(sv.contains(&5));
    }
}
