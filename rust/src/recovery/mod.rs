//! Off-tree edge recovery — the paper's core contribution.
//!
//! Both algorithms rank off-tree edges by spectral criticality
//! (`w·R_T`, Def. 2) and recover the top `α|V|` that are not *similar* to
//! an already-recovered edge:
//!
//! * [`fegrass()`] — the baseline: *loose* similarity (Def. 4, vertex
//!   cover): an edge is skipped if **either** endpoint is covered by any
//!   recovered edge's β-hop tree neighborhood (β = c, a constant). One
//!   sequential pass may recover too few edges → multiple passes.
//! * [`pdgrass()`] — the paper's algorithm: *strict* similarity (Def. 5):
//!   skipped only if **both** endpoints fall in the respective β\*-hop
//!   neighborhoods, with `β* = min(dist(u,lca), dist(v,lca), c)` (Eq. 8).
//!   Strictly-similar edges provably share their LCA (Lemma 6), so edges
//!   are grouped by LCA into **independent subtasks** (Lemma 7), processed
//!   with serial / outer / inner / mixed / sharded parallel strategies
//!   (§IV; sharded is this repo's extension for skewed inputs whose one
//!   giant subtask would otherwise serialize the inner-parallel phase).

pub mod fegrass;
pub mod inner;
pub mod pdgrass;
pub mod score;
pub mod strict;
pub mod subctx;
pub mod subtask;

pub use fegrass::fegrass;
pub use pdgrass::pdgrass;

use crate::graph::{Edge, Graph};
use crate::tree::Spanning;

/// Parallelization strategy for pdGRASS step 4 (§IV.A).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// All subtasks sequentially, each processed serially.
    Serial,
    /// Parallel across subtasks only (embarrassingly parallel by Lemma 7).
    Outer,
    /// Subtasks one-by-one, each using blocked inner parallelism.
    Inner,
    /// Paper default: large subtasks inner-parallel one-by-one first, then
    /// the small ones outer-parallel.
    Mixed,
    /// Like [`Strategy::Mixed`], but each large subtask is split into
    /// contiguous shards of ~`shard_min` edges that speculate concurrently
    /// on the pool; a serial commit in fixed shard order then reproduces
    /// the strict-condition pass exactly (see [`inner::process_sharded`]).
    Sharded,
}

impl std::str::FromStr for Strategy {
    type Err = crate::error::Error;

    /// Parse a strategy name (case-insensitive): `serial`, `outer`,
    /// `inner`, `mixed`, or `sharded` — the config-file / CLI spelling.
    fn from_str(s: &str) -> Result<Strategy, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "serial" => Ok(Strategy::Serial),
            "outer" => Ok(Strategy::Outer),
            "inner" => Ok(Strategy::Inner),
            "mixed" => Ok(Strategy::Mixed),
            "sharded" => Ok(Strategy::Sharded),
            _ => Err(crate::error::Error::BadParam {
                name: "strategy",
                why: format!(
                    "unknown strategy {s:?} (expected serial|outer|inner|mixed|sharded)"
                ),
            }),
        }
    }
}

/// Stage-handoff discipline of the Algorithm-1 pipeline.
///
/// Outputs are **bitwise identical** under both disciplines at every
/// thread count (annotation is pure, every sort key is a strict total
/// order, and outcome absorption is order-insensitive where the streamed
/// order differs) — the knob only changes *when* stages run relative to
/// each other, which is exactly what the overlap-makespan model in
/// `coordinator::schedsim` quantifies.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Pipeline {
    /// Each Algorithm-1 stage joins completely before the next starts —
    /// the paper's presentation, and the conservative default.
    #[default]
    Barrier,
    /// Adjacent stages overlap on the pool via `par::produce_stream`:
    /// scoring chunks merge into the sort while later chunks are in
    /// flight, subtask grouping is fused into the final merge pass, and
    /// recovery outcomes are absorbed while later subtasks are still
    /// being processed.
    Streamed,
}

impl std::str::FromStr for Pipeline {
    type Err = crate::error::Error;

    /// Parse a pipeline name (case-insensitive): `barrier` or `streamed`
    /// — the config-file / CLI spelling.
    fn from_str(s: &str) -> Result<Pipeline, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "barrier" => Ok(Pipeline::Barrier),
            "streamed" => Ok(Pipeline::Streamed),
            _ => Err(crate::error::Error::BadParam {
                name: "pipeline",
                why: format!("unknown pipeline {s:?} (expected barrier|streamed)"),
            }),
        }
    }
}

/// Recovery parameters (paper defaults).
#[derive(Clone, Copy, Debug)]
pub struct Params {
    /// Fraction of |V| edges to recover (paper: 0.02 / 0.05 / 0.10).
    pub alpha: f64,
    /// BFS step-size constant `c` (Def. 3 default 8).
    pub beta_cap: u32,
    /// Parallel strategy for pdGRASS.
    pub strategy: Strategy,
    /// Worker threads `p`.
    pub threads: usize,
    /// Inner-parallel block size (paper sets it to `p`).
    pub block: usize,
    /// A subtask is "large" if it has ≥ this many edges (paper: 1e5)...
    pub cutoff_edges: usize,
    /// ...or covers ≥ this fraction of all off-tree edges (paper: 0.10).
    pub cutoff_frac: f64,
    /// Judge-before-Parallel optimization (Appendix C) enabled?
    pub jbp: bool,
    /// Target shard size for [`Strategy::Sharded`]: a large subtask is
    /// split into `ceil(len / shard_min)` near-equal contiguous shards
    /// (so a subtask needs more than `shard_min` edges to actually shard).
    /// Shard shapes depend only on the subtask size, never on the thread
    /// count, keeping sharded stats and traces thread-count independent.
    pub shard_min: usize,
    /// Stage-handoff discipline: barrier-synced stages (default) or the
    /// streamed overlap pipeline. Outputs are bitwise identical either
    /// way; see [`Pipeline`].
    pub pipeline: Pipeline,
}

impl Params {
    /// Paper-default parameters for a given `alpha` and thread count.
    pub fn new(alpha: f64, threads: usize) -> Params {
        Params {
            alpha,
            beta_cap: 8,
            strategy: Strategy::Mixed,
            threads,
            block: threads.max(1),
            cutoff_edges: 100_000,
            cutoff_frac: 0.10,
            jbp: true,
            shard_min: 4096,
            pipeline: Pipeline::Barrier,
        }
    }

    /// Number of edges to recover for a graph with `n` vertices.
    pub fn target(&self, n: usize) -> usize {
        (self.alpha * n as f64).ceil() as usize
    }
}

/// Instrumentation counters shared by both algorithms.
///
/// `work` fields count abstract work units (tag probes for cheap
/// similarity checks, visited vertices for BFS expansions) and feed the
/// scheduling simulator; the remaining fields feed Tables III and IV.
#[derive(Clone, Debug, Default)]
pub struct Stats {
    /// Cheap similarity-check work units (tag/cover probes).
    pub check_units: u64,
    /// BFS-expansion work units (vertices visited building neighborhoods).
    pub bfs_units: u64,
    /// Edges that entered the continue branch inside a parallel block
    /// ("# edges skipped in parallel", Table III).
    pub skipped_in_parallel: u64,
    /// Edges that performed neighborhood exploration inside a parallel
    /// block ("# edges explored in parallel").
    pub explored_in_parallel: u64,
    /// Edges recovered speculatively in a block but rejected by the serial
    /// commit ("# false positive edges").
    pub false_positives: u64,
    /// Total edges routed through parallel blocks.
    pub edges_in_blocks: u64,
    /// Number of parallel blocks executed.
    pub blocks: u64,
    /// Size of the biggest subtask (off-tree edges).
    pub biggest_subtask: usize,
    /// Number of subtasks.
    pub subtasks: usize,
    /// Subtasks processed with inner parallelism.
    pub inner_subtasks: usize,
    /// Subtasks processed with sharded speculation ([`Strategy::Sharded`]).
    pub sharded_subtasks: usize,
    /// Shard speculation tasks run by the Sharded strategy.
    pub shards: u64,
    /// Sharded commits that had to explore serially because the position
    /// was speculatively skipped but no earlier commit actually marked it.
    pub commit_misses: u64,
}

impl Stats {
    /// Merge counters from another stats block.
    pub fn merge(&mut self, o: &Stats) {
        self.check_units += o.check_units;
        self.bfs_units += o.bfs_units;
        self.skipped_in_parallel += o.skipped_in_parallel;
        self.explored_in_parallel += o.explored_in_parallel;
        self.false_positives += o.false_positives;
        self.edges_in_blocks += o.edges_in_blocks;
        self.blocks += o.blocks;
        self.biggest_subtask = self.biggest_subtask.max(o.biggest_subtask);
        self.subtasks += o.subtasks;
        self.inner_subtasks += o.inner_subtasks;
        self.sharded_subtasks += o.sharded_subtasks;
        self.shards += o.shards;
        self.commit_misses += o.commit_misses;
    }
}

/// Per-edge cost trace used by the scheduling simulator: for each off-tree
/// edge *considered*, the cheap-check cost and (if it explored) the BFS
/// cost, in work units, in processing order per subtask.
#[derive(Clone, Debug, Default)]
pub struct CostTrace {
    /// For each subtask (in processed order): per-edge `(check, explore)`
    /// unit costs, `explore == 0` when the edge was skipped cheaply.
    pub subtask_costs: Vec<Vec<(u32, u32)>>,
}

/// Result of a recovery run.
#[derive(Clone, Debug)]
pub struct Recovery {
    /// Recovered off-tree edge ids (graph edge ids), best-score-first,
    /// truncated to the `α|V|` target.
    pub edges: Vec<u32>,
    /// Passes over the off-tree edge list (pdGRASS: expected 1).
    pub passes: usize,
    /// Instrumentation.
    pub stats: Stats,
    /// Optional per-edge cost trace for the scheduling simulator.
    pub trace: Option<CostTrace>,
    /// Wall-clock per Algorithm-1 step, ms:
    /// [resistance, sort, subtasks, recovery]. All zero for feGRASS
    /// (which has no step structure).
    pub step_ms: [f64; 4],
}

/// Assemble the sparsifier `P`: spanning tree + recovered off-tree edges.
/// The result has `|V| − 1 + α|V|` edges as in §II.B.
pub fn sparsifier(g: &Graph, sp: &Spanning, recovered: &[u32]) -> Graph {
    let mut edges: Vec<Edge> = Vec::with_capacity(g.num_vertices() - 1 + recovered.len());
    for (eid, &in_tree) in sp.is_tree_edge.iter().enumerate() {
        if in_tree {
            edges.push(g.edge(eid as u32));
        }
    }
    for &eid in recovered {
        debug_assert!(!sp.is_tree_edge[eid as usize], "recovered edge must be off-tree");
        edges.push(g.edge(eid));
    }
    edges.sort_by(|a, b| (a.u, a.v).cmp(&(b.u, b.v)));
    Graph::from_unique_edges(g.num_vertices(), edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::build_spanning;
    use crate::util::Rng;

    #[test]
    fn params_target() {
        let p = Params::new(0.02, 4);
        assert_eq!(p.target(1000), 20);
        assert_eq!(p.target(1001), 21); // ceil
        assert_eq!(p.block, 4);
    }

    #[test]
    fn sparsifier_contains_tree_plus_recovered() {
        let g = crate::gen::grid(10, 10, 0.5, &mut Rng::new(1));
        let sp = build_spanning(&g);
        let off: Vec<u32> = (0..g.num_edges() as u32)
            .filter(|&i| !sp.is_tree_edge[i as usize])
            .take(5)
            .collect();
        let p = sparsifier(&g, &sp, &off);
        assert_eq!(p.num_vertices(), g.num_vertices());
        assert_eq!(p.num_edges(), g.num_vertices() - 1 + 5);
        assert!(crate::graph::is_connected(&p));
    }

    #[test]
    fn stats_merge_adds() {
        let mut a = Stats { check_units: 1, biggest_subtask: 5, shards: 2, ..Default::default() };
        let b = Stats {
            check_units: 2,
            biggest_subtask: 9,
            subtasks: 3,
            shards: 4,
            commit_misses: 5,
            sharded_subtasks: 1,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.check_units, 3);
        assert_eq!(a.biggest_subtask, 9);
        assert_eq!(a.subtasks, 3);
        assert_eq!(a.shards, 6);
        assert_eq!(a.commit_misses, 5);
        assert_eq!(a.sharded_subtasks, 1);
    }

    #[test]
    fn pipeline_parses_and_defaults_to_barrier() {
        assert_eq!("barrier".parse::<Pipeline>().unwrap(), Pipeline::Barrier);
        assert_eq!("Streamed".parse::<Pipeline>().unwrap(), Pipeline::Streamed);
        assert_eq!("STREAMED".parse::<Pipeline>().unwrap(), Pipeline::Streamed);
        assert!("overlapped".parse::<Pipeline>().is_err());
        assert_eq!(Pipeline::default(), Pipeline::Barrier);
        assert_eq!(Params::new(0.05, 2).pipeline, Pipeline::Barrier);
    }

    #[test]
    fn strategy_parses_all_spellings() {
        for (s, want) in [
            ("serial", Strategy::Serial),
            ("OUTER", Strategy::Outer),
            ("Inner", Strategy::Inner),
            ("mixed", Strategy::Mixed),
            ("sharded", Strategy::Sharded),
            ("ShArDeD", Strategy::Sharded),
        ] {
            assert_eq!(s.parse::<Strategy>().unwrap(), want, "{s}");
        }
        assert!("warp".parse::<Strategy>().is_err());
    }
}
