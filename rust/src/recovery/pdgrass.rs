//! pdGRASS (Algorithm 1): strict-similarity recovery over LCA subtasks
//! with serial / outer / inner / mixed / sharded parallel strategies.
//!
//! All parallel strategies dispatch onto the persistent pool
//! (`par::pool`): Outer fans subtasks out with `par_map`, Mixed
//! additionally runs inner-parallel blocks *from inside* pooled tasks —
//! the nested-submission shape the pool's scoped execution model exists
//! for. Outputs are scheduling-independent (`all_strategies_agree`).
//!
//! Sharded is the repo's answer to the skewed worst cases (§V): where
//! Mixed walks a giant subtask one block of `p` edges at a time —
//! explore-barrier-commit, over and over — Sharded cuts the subtask into
//! contiguous score-order shards that each speculate the *entire* strict
//! pass concurrently on the pool, then a serial commit in fixed shard
//! order replays the serial algorithm using the speculative explorations
//! as a memo-cache (exploration is a pure function of the position, so
//! cached results are exact; see `inner::process_sharded`). The recovered
//! edge set is bitwise identical to the serial pass at every thread
//! count, and the stats/trace are thread-count independent because shard
//! shapes depend only on the subtask size and `shard_min`.
//!
//! Steps: 1) resistance distances per off-tree edge (one LCA query each),
//! 2) parallel stable sort by criticality, 3) subtask creation by shared
//! LCA + size sort, 4) recovery under the strict condition with the chosen
//! strategy. The strict condition recovers enough edges in a **single
//! pass** on every suite graph; a fallback pass loop keeps the target
//! guarantee airtight anyway.

use super::inner::{process_inner, process_serial, process_sharded_with, SubtaskOutcome};
use super::score::{scored_sorted_streamed, sort_by_score};
use super::subctx::ScratchArena;
use super::subtask::{make_subtasks, split_large, Subtask, SubtaskBuilder};
use super::{CostTrace, Params, Pipeline, Recovery, Stats, Strategy};
use crate::graph::Graph;
use crate::par;
use crate::tree::{off_tree_edges, OffTreeEdge, Spanning};

/// Run pdGRASS off-tree edge recovery with `params`.
pub fn pdgrass(g: &Graph, sp: &Spanning, params: &Params) -> Recovery {
    pdgrass_traced(g, sp, params, false)
}

/// As [`pdgrass`], optionally capturing the per-edge cost trace consumed
/// by the scheduling simulator (`coordinator::schedsim`).
///
/// Under [`Pipeline::Streamed`] the stage barriers disappear: steps 1+2
/// are fused (annotation chunks merge into the score sort while later
/// chunks are in flight), step 3 grouping is fused into the final merge
/// pass, and step 4 absorbs outcomes as they stream off the pool — see
/// [`recover_sorted`]. The recovery output is bitwise identical either
/// way; only `step_ms` attribution changes (streamed reports the fused
/// steps 1+2 in `step_ms[0]` and leaves `step_ms[1]` at zero).
pub fn pdgrass_traced(g: &Graph, sp: &Spanning, params: &Params, trace: bool) -> Recovery {
    if params.pipeline == Pipeline::Streamed {
        // Steps 1–3 streamed: scoring chunks → run merge → grouping, all
        // overlapped; the builder consumes the final merge's output as it
        // is emitted, so no stage re-walks a finished array.
        let t = crate::util::Timer::start();
        let mut builder = SubtaskBuilder::new();
        let off = scored_sorted_streamed(g, sp, params.threads, |e| builder.push(e));
        let fused_ms = t.ms();
        let t = crate::util::Timer::start();
        let subtasks = builder.finish();
        let subtask_ms = t.ms();
        let mut rec = recover_sorted(g.num_vertices(), &off, &subtasks, sp, params, trace);
        rec.step_ms[0] = fused_ms;
        rec.step_ms[1] = 0.0;
        rec.step_ms[2] = subtask_ms;
        return rec;
    }
    // Step 1: resistance distance for each off-tree edge (parallel).
    let t = crate::util::Timer::start();
    let mut off = off_tree_edges(g, sp);
    let resistance_ms = t.ms();
    // Step 2: parallel stable sort by criticality, descending (moves
    // payloads via the sort's scratch buffer; clone-free since the
    // par::sort rewrite).
    let t = crate::util::Timer::start();
    sort_by_score(&mut off, params.threads);
    let sort_ms = t.ms();
    // Step 3: subtasks by LCA, sorted by size.
    let t = crate::util::Timer::start();
    let subtasks = make_subtasks(&off);
    let subtask_ms = t.ms();

    let mut rec = recover_sorted(g.num_vertices(), &off, &subtasks, sp, params, trace);
    rec.step_ms[0] = resistance_ms;
    rec.step_ms[1] = sort_ms;
    rec.step_ms[2] = subtask_ms;
    rec
}

/// Step 4 only, over precomputed steps 1–3: a score-sorted off-tree edge
/// list and its LCA subtasks. This is the primitive behind
/// [`crate::session::Prepared::recover`] — the prepare-once/recover-many
/// split that lets α-sweeps amortize steps 1–3. `step_ms[0..3]` of the
/// result are zero (the caller owns those timings); `step_ms[3]` is this
/// call's wall-clock.
///
/// `params.pipeline` selects the pass discipline: barrier (fan out, join,
/// then absorb every outcome) or streamed ([`run_pass_streamed`]:
/// outcomes absorbed as they complete, payloads moved instead of cloned).
/// The recovery is bitwise identical either way.
pub fn recover_sorted(
    n_vertices: usize,
    off: &[OffTreeEdge],
    subtasks: &[Subtask],
    sp: &Spanning,
    params: &Params,
    trace: bool,
) -> Recovery {
    let target = params.target(n_vertices).min(off.len());
    let mut stats = Stats::default();
    stats.subtasks = subtasks.len();
    stats.biggest_subtask = subtasks.first().map(|s| s.len()).unwrap_or(0);

    let mut passes = 0usize;
    let mut recovered_global: Vec<u32> = Vec::new();
    let mut cost_trace = CostTrace::default();
    let t = crate::util::Timer::start();

    if params.pipeline == Pipeline::Streamed {
        // Streamed step 4: each pass hands completed outcomes to the
        // caller while later subtasks are still being processed — no
        // barrier between the processing fan-out and absorption, and
        // outcome payloads are moved, not cloned. Bitwise identical to
        // the barrier flow: the pass-1 consume order equals the slot
        // order (the large subtasks are a prefix of the size-sorted
        // list), stats merging is commutative, and the final selection
        // sorts `recovered_global` anyway.
        if target > 0 && subtasks.iter().any(|s| !s.is_empty()) {
            passes = 1;
            let mut leftovers: Vec<Subtask> = Vec::new();
            run_pass_streamed(off, sp, subtasks, params, &mut stats, |st, oc| {
                if trace {
                    cost_trace.subtask_costs.push(oc.costs);
                }
                recovered_global.extend_from_slice(&oc.recovered);
                if !oc.leftover.is_empty() {
                    leftovers.push(Subtask { lca: st.lca, idxs: oc.leftover });
                }
            });
            let mut active = leftovers;
            while recovered_global.len() < target && active.iter().any(|s| !s.is_empty()) {
                passes += 1;
                let mut next: Vec<Subtask> = Vec::new();
                run_pass_streamed(off, sp, &active, params, &mut stats, |st, oc| {
                    recovered_global.extend_from_slice(&oc.recovered);
                    if !oc.leftover.is_empty() {
                        next.push(Subtask { lca: st.lca, idxs: oc.leftover });
                    }
                });
                active = next;
                if passes > 64 {
                    break; // safety net; never hit in practice
                }
            }
        }
    } else {
        // Pass 1 runs over the *borrowed* subtask list — the strict
        // condition recovers the target in a single pass on every suite
        // graph, so the common case copies nothing. Only leftovers (rare
        // fallback passes) are materialized.
        let mut active: Vec<Subtask> = Vec::new();
        if target > 0 && subtasks.iter().any(|s| !s.is_empty()) {
            passes = 1;
            let outcomes = run_pass(off, sp, subtasks, params, &mut stats);
            if trace {
                for oc in &outcomes {
                    cost_trace.subtask_costs.push(oc.costs.clone());
                }
            }
            active = absorb(subtasks, &outcomes, &mut recovered_global);
        }
        while recovered_global.len() < target && active.iter().any(|s| !s.is_empty()) {
            passes += 1;
            let outcomes = run_pass(off, sp, &active, params, &mut stats);
            active = absorb(&active, &outcomes, &mut recovered_global);
            if passes > 64 {
                break; // safety net; never hit in practice (single pass suffices)
            }
        }
    }

    // Global selection: best-scored `target` among recovered.
    // `recovered_global` holds indices into the score-sorted array, so
    // ascending index order IS descending score order.
    let mut step_ms = [0f64; 4];
    step_ms[3] = t.ms();
    recovered_global.sort_unstable();
    recovered_global.truncate(target);
    let edges: Vec<u32> = recovered_global.iter().map(|&i| off[i as usize].eid).collect();

    Recovery { edges, passes, stats, trace: trace.then_some(cost_trace), step_ms }
}

/// Collect a pass's recovered edges and materialize the leftover
/// subtasks for the (rare) next pass.
fn absorb(
    active: &[Subtask],
    outcomes: &[SubtaskOutcome],
    recovered_global: &mut Vec<u32>,
) -> Vec<Subtask> {
    let mut leftovers: Vec<Subtask> = Vec::new();
    for (st, oc) in active.iter().zip(outcomes) {
        recovered_global.extend_from_slice(&oc.recovered);
        if !oc.leftover.is_empty() {
            leftovers.push(Subtask { lca: st.lca, idxs: oc.leftover.clone() });
        }
    }
    leftovers
}

/// One full pass over the active subtasks under the configured strategy.
fn run_pass(
    off: &[OffTreeEdge],
    sp: &Spanning,
    active: &[Subtask],
    params: &Params,
    stats: &mut Stats,
) -> Vec<SubtaskOutcome> {
    let total_off: usize = active.iter().map(|s| s.len()).sum::<usize>();
    match params.strategy {
        Strategy::Serial => active
            .iter()
            .map(|st| {
                let oc = process_serial(off, sp, &st.idxs, params);
                stats.merge(&oc.stats);
                oc
            })
            .collect(),
        Strategy::Outer => {
            let outcomes =
                par::par_map(active, params.threads, |st| process_serial(off, sp, &st.idxs, params));
            for oc in &outcomes {
                stats.merge(&oc.stats);
            }
            outcomes
        }
        Strategy::Inner => active
            .iter()
            .map(|st| {
                let oc = process_inner(off, sp, &st.idxs, params);
                stats.inner_subtasks += 1;
                stats.merge(&oc.stats);
                oc
            })
            .collect(),
        // Large subtasks first, one by one (blocked inner parallelism for
        // Mixed, concurrent shard speculation for Sharded — see
        // `inner::process_sharded`); then the small ones across threads
        // (paper §IV.A).
        Strategy::Mixed => run_split_pass(off, sp, active, params, stats, total_off, false),
        Strategy::Sharded => run_split_pass(off, sp, active, params, stats, total_off, true),
    }
}

/// Shared Mixed/Sharded pass body: process the large subtasks one by one
/// with the strategy's large-subtask processor, then the small ones
/// outer-parallel, keeping outcomes in the original subtask order.
fn run_split_pass(
    off: &[OffTreeEdge],
    sp: &Spanning,
    active: &[Subtask],
    params: &Params,
    stats: &mut Stats,
    total_off: usize,
    sharded: bool,
) -> Vec<SubtaskOutcome> {
    let (large, small) = split_large(active, total_off, params.cutoff_edges, params.cutoff_frac);
    // One scratch arena for the whole pass: consecutive giant subtasks
    // reuse each other's grown shard buffers instead of re-allocating.
    let arena = ScratchArena::new();
    let mut slots: Vec<Option<SubtaskOutcome>> = vec![None; active.len()];
    for &li in &large {
        let oc = if sharded {
            // counts itself in `stats.sharded_subtasks` only when it
            // actually speculates (a single-shard subtask runs serially)
            process_sharded_with(off, sp, &active[li].idxs, params, &arena)
        } else {
            stats.inner_subtasks += 1;
            process_inner(off, sp, &active[li].idxs, params)
        };
        stats.merge(&oc.stats);
        slots[li] = Some(oc);
    }
    let small_outcomes = par::par_map(&small, params.threads, |&si| {
        process_serial(off, sp, &active[si].idxs, params)
    });
    for (&si, oc) in small.iter().zip(small_outcomes) {
        stats.merge(&oc.stats);
        slots[si] = Some(oc);
    }
    slots.into_iter().map(|s| s.expect("subtask slot unfilled")).collect()
}

/// One full pass under [`Pipeline::Streamed`]: subtasks are dispatched to
/// pool workers through [`par::produce_stream`] and completed outcomes
/// are handed to `sink` in dispatch order while later subtasks are still
/// being processed — the processing fan-out and the absorption overlap.
///
/// Dispatch order is the large subtasks (in `split_large` order, each
/// nesting its own strategy-specific inner parallelism inside the stream
/// task) followed by the small ones; on the first pass the large group is
/// a prefix of the size-sorted list, so the sink order coincides with the
/// barrier path's slot order and traces pin bitwise. Unlike the barrier
/// split pass, large subtasks here overlap both each other and the small
/// subtasks — sound because LCA subtasks are independent (Lemma 7) and
/// exploration is pure.
///
/// [`Strategy::Serial`] and [`Strategy::Inner`] keep their inherently
/// ordered one-by-one shape (their definition, not a barrier artifact).
fn run_pass_streamed<S>(
    off: &[OffTreeEdge],
    sp: &Spanning,
    active: &[Subtask],
    params: &Params,
    stats: &mut Stats,
    mut sink: S,
) where
    S: FnMut(&Subtask, SubtaskOutcome) + Send,
{
    let total_off: usize = active.iter().map(|s| s.len()).sum::<usize>();
    match params.strategy {
        Strategy::Serial => {
            for st in active {
                let oc = process_serial(off, sp, &st.idxs, params);
                stats.merge(&oc.stats);
                sink(st, oc);
            }
        }
        Strategy::Inner => {
            for st in active {
                let oc = process_inner(off, sp, &st.idxs, params);
                stats.inner_subtasks += 1;
                stats.merge(&oc.stats);
                sink(st, oc);
            }
        }
        Strategy::Outer => {
            par::produce_stream(
                active.len(),
                params.threads,
                |i| process_serial(off, sp, &active[i].idxs, params),
                |i, oc| {
                    stats.merge(&oc.stats);
                    sink(&active[i], oc);
                },
            );
        }
        Strategy::Mixed | Strategy::Sharded => {
            let sharded = params.strategy == Strategy::Sharded;
            let (large, small) =
                split_large(active, total_off, params.cutoff_edges, params.cutoff_frac);
            let n_large = large.len();
            let order: Vec<usize> = large.into_iter().chain(small).collect();
            // Pass-lifetime scratch arena shared across the streamed
            // subtasks (the Mutex inside makes `&arena` Sync).
            let arena = ScratchArena::new();
            par::produce_stream(
                order.len(),
                params.threads,
                |k| {
                    let st = &active[order[k]];
                    if k >= n_large {
                        process_serial(off, sp, &st.idxs, params)
                    } else if sharded {
                        process_sharded_with(off, sp, &st.idxs, params, &arena)
                    } else {
                        process_inner(off, sp, &st.idxs, params)
                    }
                },
                |k, oc| {
                    if k < n_large && !sharded {
                        stats.inner_subtasks += 1;
                    }
                    stats.merge(&oc.stats);
                    sink(&active[order[k]], oc);
                },
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::tree::build_spanning;
    use crate::util::Rng;

    fn params(alpha: f64, strategy: Strategy) -> Params {
        Params {
            strategy,
            cutoff_edges: 200, // small graphs in tests → exercise inner path
            shard_min: 64,     // small graphs in tests → exercise sharding
            ..Params::new(alpha, 4)
        }
    }

    fn test_graph(seed: u64) -> Graph {
        gen::community(
            gen::CommunityParams { n: 1200, mean_size: 10.0, tail: 1.7, intra_p: 0.5, bridges: 2, max_size: 80 },
            &mut Rng::new(seed),
        )
    }

    #[test]
    fn recovers_target_in_single_pass() {
        let g = test_graph(1);
        let sp = build_spanning(&g);
        let p = params(0.05, Strategy::Serial);
        let r = pdgrass(&g, &sp, &p);
        assert_eq!(r.edges.len(), p.target(g.num_vertices()));
        assert_eq!(r.passes, 1, "strict condition should recover enough in one pass");
    }

    #[test]
    fn all_strategies_agree() {
        let g = test_graph(2);
        let sp = build_spanning(&g);
        let base = pdgrass(&g, &sp, &params(0.05, Strategy::Serial));
        for strat in [Strategy::Outer, Strategy::Inner, Strategy::Mixed, Strategy::Sharded] {
            let r = pdgrass(&g, &sp, &params(0.05, strat));
            assert_eq!(r.edges, base.edges, "strategy {strat:?} diverged");
        }
    }

    #[test]
    fn streamed_pipeline_is_bitwise_identical_to_barrier() {
        let g = test_graph(7);
        let sp = build_spanning(&g);
        let strategies = [
            Strategy::Serial,
            Strategy::Outer,
            Strategy::Inner,
            Strategy::Mixed,
            Strategy::Sharded,
        ];
        for strat in strategies {
            let barrier = pdgrass_traced(&g, &sp, &params(0.05, strat), true);
            for threads in [1usize, 2, 8] {
                let p = Params {
                    pipeline: crate::recovery::Pipeline::Streamed,
                    threads,
                    ..params(0.05, strat)
                };
                let streamed = pdgrass_traced(&g, &sp, &p, true);
                assert_eq!(streamed.edges, barrier.edges, "{strat:?} t={threads}");
                assert_eq!(streamed.passes, barrier.passes, "{strat:?} t={threads}");
                assert_eq!(
                    format!("{:?}", streamed.stats),
                    format!("{:?}", barrier.stats),
                    "{strat:?} t={threads}: stats diverged"
                );
                assert_eq!(
                    streamed.trace.as_ref().unwrap().subtask_costs,
                    barrier.trace.as_ref().unwrap().subtask_costs,
                    "{strat:?} t={threads}: trace diverged"
                );
            }
        }
    }

    #[test]
    fn recovered_edges_are_offtree_unique_sorted_by_score() {
        let g = test_graph(3);
        let sp = build_spanning(&g);
        let r = pdgrass(&g, &sp, &params(0.10, Strategy::Mixed));
        let mut seen = std::collections::HashSet::new();
        for &eid in &r.edges {
            assert!(!sp.is_tree_edge[eid as usize]);
            assert!(seen.insert(eid));
        }
    }

    #[test]
    fn alpha_one_recovers_everything_nonsimilar_or_target() {
        let g = gen::grid(12, 12, 0.7, &mut Rng::new(4));
        let sp = build_spanning(&g);
        let p = params(10.0, Strategy::Serial); // absurd target → capped at |off|
        let r = pdgrass(&g, &sp, &p);
        // With fallback passes, every off-tree edge is eventually recovered.
        assert_eq!(r.edges.len(), sp.num_off_tree());
    }

    #[test]
    fn trace_captures_first_pass_subtasks() {
        let g = test_graph(5);
        let sp = build_spanning(&g);
        let r = pdgrass_traced(&g, &sp, &params(0.05, Strategy::Serial), true);
        let t = r.trace.expect("trace requested");
        assert_eq!(t.subtask_costs.len(), r.stats.subtasks);
        let edges_traced: usize = t.subtask_costs.iter().map(|c| c.len()).sum();
        assert_eq!(edges_traced, sp.num_off_tree());
    }

    #[test]
    fn subtask_disjointness_lemma7() {
        // Edges recovered in different subtasks must have different LCAs;
        // within a subtask all edges share the LCA.
        let g = test_graph(6);
        let sp = build_spanning(&g);
        let mut off = crate::tree::off_tree_edges(&g, &sp);
        crate::recovery::score::sort_by_score(&mut off, 1);
        let subtasks = crate::recovery::subtask::make_subtasks(&off);
        let mut lcas = std::collections::HashSet::new();
        for st in &subtasks {
            assert!(lcas.insert(st.lca), "duplicate subtask LCA");
            for &i in &st.idxs {
                assert_eq!(off[i as usize].lca, st.lca);
            }
        }
        let total: usize = subtasks.iter().map(|s| s.len()).sum();
        assert_eq!(total, off.len());
    }
}
