//! Eager-marking subtask context — the paper's actual execution model.
//!
//! When pdGRASS recovers an off-tree edge `e = (u, v)` it *explores*:
//! computes the β\*-hop tree neighborhoods `S_u`, `S_v` and **marks every
//! later edge of the subtask that is strictly similar to `e`** (both
//! endpoints in the respective neighborhoods, Def. 5). A later edge's
//! similarity test is then an O(1) flag check — the "already marked"
//! continue-branch of §IV.A. This puts the expensive work (BFS +
//! mark-set enumeration) in the *parallel* phase of the blocked scheme,
//! which is exactly why the inner-parallel strategy scales (Fig. 7);
//! the lazy tag-probing formulation in [`super::strict`] is kept as an
//! independently-implemented oracle for equivalence tests.

use super::strict::beta_star;
use crate::tree::{OffTreeEdge, Spanning};
use crate::util::FxHashMap;

/// Per-subtask context: local edge table + vertex-incidence lists.
pub struct SubtaskCtx<'a> {
    /// Off-tree edge array (score-sorted, global).
    off: &'a [OffTreeEdge],
    /// Subtask members: indices into `off`, in score order.
    idxs: &'a [u32],
    /// vertex → [(local position, other endpoint)] over subtask edges.
    incident: FxHashMap<u32, Vec<(u32, u32)>>,
}

impl<'a> SubtaskCtx<'a> {
    /// Build the incidence lists (O(|S|) time/space).
    pub fn new(off: &'a [OffTreeEdge], idxs: &'a [u32]) -> SubtaskCtx<'a> {
        let mut incident: FxHashMap<u32, Vec<(u32, u32)>> = FxHashMap::default();
        for (pos, &i) in idxs.iter().enumerate() {
            let e = &off[i as usize];
            incident.entry(e.u).or_default().push((pos as u32, e.v));
            incident.entry(e.v).or_default().push((pos as u32, e.u));
        }
        SubtaskCtx { off, idxs, incident }
    }

    /// Number of edges in the subtask.
    pub fn len(&self) -> usize {
        self.idxs.len()
    }

    /// True when the subtask is empty.
    pub fn is_empty(&self) -> bool {
        self.idxs.is_empty()
    }

    /// Global off-array index at local position `pos`.
    pub fn off_index(&self, pos: usize) -> u32 {
        self.idxs[pos]
    }

    /// Explore the edge at local position `pos`: compute its β\*-hop
    /// neighborhoods and return the positions (> `pos`) of all strictly
    /// similar edges, plus the work cost in units (BFS visits + incidence
    /// scans). Read-only — safe to run for a whole block in parallel.
    pub fn explore(&self, sp: &Spanning, pos: usize, cap: u32) -> (Vec<u32>, u32) {
        let e = &self.off[self.idxs[pos] as usize];
        let beta = beta_star(sp, e, cap);
        let mut s_u = sp.tree.neighborhood(e.u, beta);
        let mut s_v = sp.tree.neighborhood(e.v, beta);
        let mut cost = (s_u.len() + s_v.len()) as u32;
        s_u.sort_unstable();
        s_v.sort_unstable();
        let mut marks: Vec<u32> = Vec::new();
        // Any strictly-similar edge has one endpoint in S_u and the other
        // in S_v, so scanning the incidence lists of ONE set finds them
        // all (each edge is listed under both endpoints). Scan the
        // smaller set and membership-test against the bigger one.
        let (small, big) = if s_u.len() <= s_v.len() { (&s_u, &s_v) } else { (&s_v, &s_u) };
        for &x in small {
            if let Some(list) = self.incident.get(&x) {
                for &(p2, y) in list {
                    cost += 1;
                    if p2 as usize > pos && big.binary_search(&y).is_ok() {
                        marks.push(p2);
                    }
                }
            }
        }
        marks.sort_unstable();
        marks.dedup();
        (marks, cost)
    }
}

/// Reusable per-shard speculation scratch: the shard-local mark bits
/// (`marked[pos - shard_start]`) used by
/// [`super::inner::process_sharded`]'s speculative phase.
///
/// Shards far outnumber workers, so scratches live in a
/// [`ScratchArena`] and are reused across shards — and across *subtasks*
/// — instead of being allocated per shard: a worker takes one,
/// speculates a shard, and returns it.
#[derive(Default)]
pub struct ShardScratch {
    /// Shard-local speculative mark bits.
    pub marked: Vec<bool>,
}

impl ShardScratch {
    /// Clear and resize for a shard of `len` edges. `Vec::resize` after
    /// `clear` keeps the existing capacity, so a scratch grows
    /// monotonically to the pass's largest shard (bump-style high
    /// watermark) and then stops touching the allocator.
    fn reset(&mut self, len: usize) {
        self.marked.clear();
        self.marked.resize(len, false);
    }
}

/// Pass-lifetime arena of [`ShardScratch`] buffers.
///
/// Pre-PR-10 each sharded subtask created its own scratch pool, so a
/// pass over a skewed graph (many giant subtasks) re-allocated every
/// subtask's mark buffers from cold — allocator churn proportional to
/// the subtask count. The arena is created **once per recovery pass**
/// (see `recovery::pdgrass`) and shared by every subtask in it: buffers
/// grow to the pass's high watermark and steady-state at one allocation
/// per concurrent worker for the whole pass.
///
/// `take`/`put` use a mutex, but each lock guards a single `Vec`
/// pop/push — negligible next to a shard's BFS work. Determinism is
/// untouched: a scratch is always reset before use, so *which* buffer a
/// worker gets can never influence results.
pub struct ScratchArena {
    state: std::sync::Mutex<ArenaState>,
}

#[derive(Default)]
struct ArenaState {
    /// Buffers not currently checked out.
    free: Vec<ShardScratch>,
    /// Total buffers ever created (diagnostics: allocator churn metric).
    created: usize,
}

impl ScratchArena {
    /// An empty arena; scratches are created on first [`ScratchArena::take`].
    pub fn new() -> ScratchArena {
        ScratchArena { state: std::sync::Mutex::new(ArenaState::default()) }
    }

    /// Take a scratch sized (and cleared) for a shard of `len` edges.
    pub fn take(&self, len: usize) -> ShardScratch {
        let mut s = {
            let mut st = self.state.lock().unwrap();
            match st.free.pop() {
                Some(s) => s,
                None => {
                    st.created += 1;
                    ShardScratch::default()
                }
            }
        };
        s.reset(len);
        s
    }

    /// Return a scratch for reuse by the next shard (of any subtask).
    pub fn put(&self, s: ShardScratch) {
        self.state.lock().unwrap().free.push(s);
    }

    /// Total buffers ever created by this arena — with cross-subtask
    /// reuse this is bounded by the peak number of concurrent workers,
    /// not the shard or subtask count.
    pub fn buffers_created(&self) -> usize {
        self.state.lock().unwrap().created
    }
}

impl Default for ScratchArena {
    fn default() -> ScratchArena {
        ScratchArena::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::recovery::score::sort_by_score;
    use crate::recovery::strict::neighborhoods;
    use crate::recovery::subtask::make_subtasks;
    use crate::tree::{build_spanning, off_tree_edges};
    use crate::util::Rng;

    #[test]
    fn explore_matches_direct_definition() {
        // For random graphs, explore(pos) must mark exactly the later
        // edges that satisfy Definition 5 against the recovered edge.
        crate::util::proptest::check_default("explore_def5", |rng: &mut Rng| {
            let g = crate::gen::community(
                crate::gen::CommunityParams {
                    n: 150 + rng.below(200),
                    mean_size: 9.0,
                    tail: 1.7,
                    intra_p: 0.5,
                    bridges: 2,
                    max_size: 50,
                },
                rng,
            );
            let sp = build_spanning(&g);
            let mut off = off_tree_edges(&g, &sp);
            sort_by_score(&mut off, 1);
            let subtasks = make_subtasks(&off);
            let Some(st) = subtasks.first() else { return Ok(()) };
            let ctx = SubtaskCtx::new(&off, &st.idxs);
            let pos = rng.below(st.idxs.len());
            let (marks, _) = ctx.explore(&sp, pos, 8);
            let e1 = &off[st.idxs[pos] as usize];
            let (su, sv, _) = neighborhoods(&sp, e1, 8);
            for (p2, &i2) in st.idxs.iter().enumerate() {
                if p2 <= pos {
                    continue;
                }
                let e2 = &off[i2 as usize];
                let direct = (su.contains(&e2.u) && sv.contains(&e2.v))
                    || (sv.contains(&e2.u) && su.contains(&e2.v));
                let marked = marks.binary_search(&(p2 as u32)).is_ok();
                if direct != marked {
                    return Err(format!(
                        "pos {pos} edge ({},{}) vs pos {p2} edge ({},{}): direct={direct} marked={marked}",
                        e1.u, e1.v, e2.u, e2.v
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn scratch_arena_reuses_and_resets() {
        let arena = ScratchArena::new();
        let mut s = arena.take(4);
        assert_eq!(s.marked, vec![false; 4]);
        s.marked[2] = true;
        arena.put(s);
        // Reused scratch comes back cleared and resized.
        let s2 = arena.take(2);
        assert_eq!(s2.marked, vec![false; 2]);
        assert_eq!(arena.buffers_created(), 1, "serial take/put must reuse one buffer");
        let s3 = arena.take(6);
        assert_eq!(s3.marked, vec![false; 6]);
        assert_eq!(arena.buffers_created(), 2, "concurrent checkout needs a second buffer");
        arena.put(s2);
        arena.put(s3);
        let _s4 = arena.take(100);
        assert_eq!(arena.buffers_created(), 2, "returned buffers are reused across sizes");
    }

    #[test]
    fn explore_never_marks_earlier_positions() {
        let g = crate::gen::grid(12, 12, 0.7, &mut Rng::new(4));
        let sp = build_spanning(&g);
        let mut off = off_tree_edges(&g, &sp);
        sort_by_score(&mut off, 1);
        let subtasks = make_subtasks(&off);
        for st in subtasks.iter().take(4) {
            let ctx = SubtaskCtx::new(&off, &st.idxs);
            for pos in 0..st.idxs.len() {
                let (marks, _) = ctx.explore(&sp, pos, 8);
                assert!(marks.iter().all(|&p| p as usize > pos));
            }
        }
    }
}
