//! Step 3 of Algorithm 1: create subtasks by shared LCA and sort them by
//! size.
//!
//! Lemma 6 (strictly similar edges share their LCA) + Lemma 7
//! (contraposition) make LCA groups **independent**: no strict-similarity
//! relation can cross groups, so the groups can be processed in parallel
//! with no data dependencies. Lemma 8 (non-commutativity) forces
//! *in-order* processing inside each group.

use crate::tree::OffTreeEdge;
use crate::util::FxHashMap;

/// A subtask: the off-tree edges sharing one LCA, in score order.
#[derive(Clone, Debug)]
pub struct Subtask {
    /// The shared LCA vertex.
    pub lca: u32,
    /// Indices into the score-sorted off-tree edge array, ascending
    /// (i.e. best score first — Lemma 8's required processing order).
    pub idxs: Vec<u32>,
}

impl Subtask {
    /// Number of edges in the subtask.
    pub fn len(&self) -> usize {
        self.idxs.len()
    }

    /// True if the subtask has no edges.
    pub fn is_empty(&self) -> bool {
        self.idxs.is_empty()
    }
}

/// Group score-sorted off-tree edges into subtasks keyed by LCA, then sort
/// subtasks by size descending (stable: equal sizes keep first-seen
/// order). One serial pass + sort, `O(|E| lg |E|)` work as in Table I.
pub fn make_subtasks(off_sorted: &[OffTreeEdge]) -> Vec<Subtask> {
    let mut by_lca: FxHashMap<u32, Vec<u32>> = FxHashMap::default();
    for (i, e) in off_sorted.iter().enumerate() {
        by_lca.entry(e.lca).or_default().push(i as u32);
    }
    let mut subtasks: Vec<Subtask> =
        by_lca.into_iter().map(|(lca, idxs)| Subtask { lca, idxs }).collect();
    // Deterministic: sort by (size desc, lca asc).
    subtasks.sort_by(|a, b| b.len().cmp(&a.len()).then(a.lca.cmp(&b.lca)));
    subtasks
}

/// Split subtasks into (large, small) index lists per the paper's cutoff:
/// a subtask is large if it has ≥ `cutoff_edges` edges or covers ≥
/// `cutoff_frac` of all off-tree edges.
pub fn split_large(
    subtasks: &[Subtask],
    total_off_tree: usize,
    cutoff_edges: usize,
    cutoff_frac: f64,
) -> (Vec<usize>, Vec<usize>) {
    let frac_cut = (cutoff_frac * total_off_tree as f64).ceil() as usize;
    let mut large = Vec::new();
    let mut small = Vec::new();
    for (i, s) in subtasks.iter().enumerate() {
        if s.len() >= cutoff_edges || (frac_cut > 0 && s.len() >= frac_cut) {
            large.push(i);
        } else {
            small.push(i);
        }
    }
    (large, small)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(lca: u32, score: f64, eid: u32) -> OffTreeEdge {
        OffTreeEdge { eid, u: 0, v: 1, w: 1.0, lca, resistance: score, score }
    }

    #[test]
    fn groups_by_lca_preserving_order() {
        // already score-sorted
        let off = vec![mk(5, 9.0, 0), mk(3, 8.0, 1), mk(5, 7.0, 2), mk(3, 6.0, 3), mk(5, 5.0, 4)];
        let st = make_subtasks(&off);
        assert_eq!(st.len(), 2);
        assert_eq!(st[0].lca, 5); // bigger first
        assert_eq!(st[0].idxs, vec![0, 2, 4]); // ascending = score order
        assert_eq!(st[1].idxs, vec![1, 3]);
    }

    #[test]
    fn size_ties_break_by_lca() {
        let off = vec![mk(9, 4.0, 0), mk(2, 3.0, 1), mk(9, 2.0, 2), mk(2, 1.0, 3)];
        let st = make_subtasks(&off);
        assert_eq!(st[0].lca, 2);
        assert_eq!(st[1].lca, 9);
    }

    #[test]
    fn split_by_edges_and_frac() {
        let st = vec![
            Subtask { lca: 0, idxs: (0..50).collect() },
            Subtask { lca: 1, idxs: (50..58).collect() },
            Subtask { lca: 2, idxs: (58..60).collect() },
        ];
        // total 60, frac 0.10 → cut at 6 edges
        let (large, small) = split_large(&st, 60, 100_000, 0.10);
        assert_eq!(large, vec![0, 1]);
        assert_eq!(small, vec![2]);
        // absolute cutoff only
        let (large, small) = split_large(&st, 60, 10, 1.1);
        assert_eq!(large, vec![0]);
        assert_eq!(small, vec![1, 2]);
    }
}
