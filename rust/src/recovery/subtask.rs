//! Step 3 of Algorithm 1: create subtasks by shared LCA and sort them by
//! size.
//!
//! Lemma 6 (strictly similar edges share their LCA) + Lemma 7
//! (contraposition) make LCA groups **independent**: no strict-similarity
//! relation can cross groups, so the groups can be processed in parallel
//! with no data dependencies. Lemma 8 (non-commutativity) forces
//! *in-order* processing inside each group.

use crate::tree::OffTreeEdge;
use crate::util::FxHashMap;

/// A subtask: the off-tree edges sharing one LCA, in score order.
#[derive(Clone, Debug)]
pub struct Subtask {
    /// The shared LCA vertex.
    pub lca: u32,
    /// Indices into the score-sorted off-tree edge array, ascending
    /// (i.e. best score first — Lemma 8's required processing order).
    pub idxs: Vec<u32>,
}

impl Subtask {
    /// Number of edges in the subtask.
    pub fn len(&self) -> usize {
        self.idxs.len()
    }

    /// True if the subtask has no edges.
    pub fn is_empty(&self) -> bool {
        self.idxs.is_empty()
    }
}

/// Incremental LCA grouper — step 3 consumed one edge at a time, in
/// score-sorted position order. The streamed pipeline pushes edges into
/// this builder **from inside the final sort-merge pass**
/// (`par::sort::RunMerger::finish_with`), fusing subtask grouping into
/// the merge tail instead of re-walking the finished array behind a
/// barrier; the barrier [`make_subtasks`] is the same builder fed by a
/// plain loop, so both pipelines produce identical subtask lists.
#[derive(Debug, Default)]
pub struct SubtaskBuilder {
    by_lca: FxHashMap<u32, Vec<u32>>,
    next_pos: u32,
}

impl SubtaskBuilder {
    /// Empty builder.
    pub fn new() -> SubtaskBuilder {
        SubtaskBuilder::default()
    }

    /// Consume the next edge in sorted-position order.
    pub fn push(&mut self, e: &OffTreeEdge) {
        self.by_lca.entry(e.lca).or_default().push(self.next_pos);
        self.next_pos += 1;
    }

    /// Number of edges consumed so far.
    pub fn len(&self) -> usize {
        self.next_pos as usize
    }

    /// True if no edges were consumed.
    pub fn is_empty(&self) -> bool {
        self.next_pos == 0
    }

    /// Finalize into the canonical subtask list: size descending, ties by
    /// LCA ascending — a strict total order (LCAs are unique per group),
    /// so the list is independent of hash-map iteration order.
    pub fn finish(self) -> Vec<Subtask> {
        let mut subtasks: Vec<Subtask> =
            self.by_lca.into_iter().map(|(lca, idxs)| Subtask { lca, idxs }).collect();
        subtasks.sort_by(|a, b| b.len().cmp(&a.len()).then(a.lca.cmp(&b.lca)));
        subtasks
    }
}

/// Group score-sorted off-tree edges into subtasks keyed by LCA, then sort
/// subtasks by size descending (stable: equal sizes keep first-seen
/// order). One serial pass + sort, `O(|E| lg |E|)` work as in Table I.
pub fn make_subtasks(off_sorted: &[OffTreeEdge]) -> Vec<Subtask> {
    let mut b = SubtaskBuilder::new();
    for e in off_sorted {
        b.push(e);
    }
    b.finish()
}

/// Split `0..m` into near-equal contiguous shard ranges with target size
/// `shard_size` (the `shard_min` knob of [`crate::recovery::Params`]):
/// `k = ceil(m / shard_size)` shards whose lengths differ by at most one,
/// the remainder spread over the leading shards. Deterministic in
/// `(m, shard_size)` alone — the thread count never changes shard shapes,
/// which keeps sharded stats and cost traces thread-count independent.
/// `m == 0` yields no shards; `0 < m <= shard_size` yields exactly one
/// (the threshold-exactly-met case degenerates to the serial pass).
pub fn shard_ranges(m: usize, shard_size: usize) -> Vec<std::ops::Range<usize>> {
    if m == 0 {
        return Vec::new();
    }
    let size = shard_size.max(1);
    let k = m.div_ceil(size);
    let base = m / k;
    let rem = m % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0usize;
    for i in 0..k {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, m, "shards must cover 0..m exactly");
    out
}

/// Split subtasks into (large, small) index lists per the paper's cutoff:
/// a subtask is large if it has ≥ `cutoff_edges` edges or covers ≥
/// `cutoff_frac` of all off-tree edges.
pub fn split_large(
    subtasks: &[Subtask],
    total_off_tree: usize,
    cutoff_edges: usize,
    cutoff_frac: f64,
) -> (Vec<usize>, Vec<usize>) {
    let frac_cut = (cutoff_frac * total_off_tree as f64).ceil() as usize;
    let mut large = Vec::new();
    let mut small = Vec::new();
    for (i, s) in subtasks.iter().enumerate() {
        if s.len() >= cutoff_edges || (frac_cut > 0 && s.len() >= frac_cut) {
            large.push(i);
        } else {
            small.push(i);
        }
    }
    (large, small)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(lca: u32, score: f64, eid: u32) -> OffTreeEdge {
        OffTreeEdge { eid, u: 0, v: 1, w: 1.0, lca, resistance: score, score }
    }

    #[test]
    fn groups_by_lca_preserving_order() {
        // already score-sorted
        let off = vec![mk(5, 9.0, 0), mk(3, 8.0, 1), mk(5, 7.0, 2), mk(3, 6.0, 3), mk(5, 5.0, 4)];
        let st = make_subtasks(&off);
        assert_eq!(st.len(), 2);
        assert_eq!(st[0].lca, 5); // bigger first
        assert_eq!(st[0].idxs, vec![0, 2, 4]); // ascending = score order
        assert_eq!(st[1].idxs, vec![1, 3]);
    }

    #[test]
    fn incremental_builder_matches_batch_grouping() {
        let mut rng = crate::util::Rng::new(5);
        let off: Vec<OffTreeEdge> =
            (0..500).map(|i| mk(rng.next_u32() % 23, 500.0 - i as f64, i)).collect();
        let batch = make_subtasks(&off);
        let mut b = SubtaskBuilder::new();
        assert!(b.is_empty());
        for e in &off {
            b.push(e);
        }
        assert_eq!(b.len(), off.len());
        let incremental = b.finish();
        assert_eq!(incremental.len(), batch.len());
        for (a, c) in incremental.iter().zip(&batch) {
            assert_eq!(a.lca, c.lca);
            assert_eq!(a.idxs, c.idxs);
        }
    }

    #[test]
    fn size_ties_break_by_lca() {
        let off = vec![mk(9, 4.0, 0), mk(2, 3.0, 1), mk(9, 2.0, 2), mk(2, 1.0, 3)];
        let st = make_subtasks(&off);
        assert_eq!(st[0].lca, 2);
        assert_eq!(st[1].lca, 9);
    }

    #[test]
    fn split_by_edges_and_frac() {
        let st = vec![
            Subtask { lca: 0, idxs: (0..50).collect() },
            Subtask { lca: 1, idxs: (50..58).collect() },
            Subtask { lca: 2, idxs: (58..60).collect() },
        ];
        // total 60, frac 0.10 → cut at 6 edges
        let (large, small) = split_large(&st, 60, 100_000, 0.10);
        assert_eq!(large, vec![0, 1]);
        assert_eq!(small, vec![2]);
        // absolute cutoff only
        let (large, small) = split_large(&st, 60, 10, 1.1);
        assert_eq!(large, vec![0]);
        assert_eq!(small, vec![1, 2]);
    }

    #[test]
    fn split_large_boundaries() {
        let st = vec![
            Subtask { lca: 0, idxs: (0..10).collect() },
            Subtask { lca: 1, idxs: (10..19).collect() },
        ];
        // edge-count threshold exactly met is large (>=, not >)
        let (large, small) = split_large(&st, 19, 10, 1.1);
        assert_eq!(large, vec![0]);
        assert_eq!(small, vec![1]);
        // fraction threshold exactly met is large: frac_cut = ceil(0.5*19) = 10
        let (large, _) = split_large(&st, 19, 100_000, 0.5);
        assert_eq!(large, vec![0]);
        // empty subtask list
        let (large, small) = split_large(&[], 0, 10, 0.1);
        assert!(large.is_empty() && small.is_empty());
    }

    #[test]
    fn shard_ranges_threshold_exactly_met_is_one_shard() {
        assert_eq!(shard_ranges(8, 8), vec![0..8]);
        assert_eq!(shard_ranges(7, 8), vec![0..7]);
        // one past the threshold splits near-equally
        assert_eq!(shard_ranges(9, 8), vec![0..5, 5..9]);
    }

    #[test]
    fn shard_ranges_empty_and_degenerate() {
        assert!(shard_ranges(0, 8).is_empty());
        // shard size clamps to 1: one shard per element
        assert_eq!(shard_ranges(3, 0), vec![0..1, 1..2, 2..3]);
        assert_eq!(shard_ranges(1, 1), vec![0..1]);
    }

    #[test]
    fn shard_ranges_cover_and_balance() {
        for m in [1usize, 2, 7, 63, 64, 65, 100, 1000, 1001] {
            for size in [1usize, 2, 7, 64, 1000, 4096] {
                let ranges = shard_ranges(m, size);
                // contiguous cover of 0..m
                assert_eq!(ranges.first().unwrap().start, 0, "m={m} size={size}");
                assert_eq!(ranges.last().unwrap().end, m, "m={m} size={size}");
                for w in ranges.windows(2) {
                    assert_eq!(w[0].end, w[1].start, "m={m} size={size}");
                }
                // near-equal: lengths differ by at most one, none empty
                let lens: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
                let (lo, hi) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
                assert!(*lo >= 1 && hi - lo <= 1, "m={m} size={size} lens={lens:?}");
                // shard count is the ceil-division contract
                assert_eq!(ranges.len(), m.div_ceil(size.max(1)), "m={m} size={size}");
            }
        }
    }
}
