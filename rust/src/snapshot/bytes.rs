//! Little-endian codecs and the CRC-32 section digest for snapshots.
//!
//! Everything in a snapshot is flat little-endian scalars: `u32`/`u64`
//! words and `f64` values stored as their IEEE-754 bit patterns (so a
//! round trip is bitwise, never a reformat through decimal). Sections are
//! digested with CRC-32 (IEEE, reflected polynomial `0xEDB88320`), chosen
//! over a fast non-cryptographic hash because CRC-32 detects *every*
//! single-byte corruption — the property the corruption fuzz suite
//! (`rust/tests/snapshot.rs`) exercises byte-by-byte.

use crate::error::{Error, Result};

/// Construct the typed snapshot-rejection error.
pub fn snap_err(why: impl Into<String>) -> Error {
    Error::Snapshot { why: why.into() }
}

/// CRC-32 (IEEE) lookup table, built at compile time.
const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut crc = i as u32;
        let mut k = 0;
        while k < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            k += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// CRC-32 (IEEE) of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = u32::MAX;
    for &b in bytes {
        crc = (crc >> 8) ^ CRC_TABLE[((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

/// Append a `u32`, little-endian.
pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append a `u64`, little-endian.
pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append a `u32` slice, little-endian.
pub fn put_u32s(out: &mut Vec<u8>, vs: &[u32]) {
    out.reserve(vs.len() * 4);
    for &v in vs {
        put_u32(out, v);
    }
}

/// Append a `u64` slice, little-endian.
pub fn put_u64s(out: &mut Vec<u8>, vs: &[u64]) {
    out.reserve(vs.len() * 8);
    for &v in vs {
        put_u64(out, v);
    }
}

/// Append an `f64` slice as IEEE-754 bit patterns, little-endian.
pub fn put_f64s(out: &mut Vec<u8>, vs: &[f64]) {
    out.reserve(vs.len() * 8);
    for &v in vs {
        put_u64(out, v.to_bits());
    }
}

/// Decode a section body as a `u32` array.
pub fn get_u32s(bytes: &[u8], what: &str) -> Result<Vec<u32>> {
    if bytes.len() % 4 != 0 {
        return Err(snap_err(format!(
            "{what}: section length {} is not a multiple of 4",
            bytes.len()
        )));
    }
    Ok(bytes.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

/// Decode a section body as a `u64` array.
pub fn get_u64s(bytes: &[u8], what: &str) -> Result<Vec<u64>> {
    if bytes.len() % 8 != 0 {
        return Err(snap_err(format!(
            "{what}: section length {} is not a multiple of 8",
            bytes.len()
        )));
    }
    Ok(bytes
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect())
}

/// Decode a section body as an `f64` array (bit patterns, no reformat).
pub fn get_f64s(bytes: &[u8], what: &str) -> Result<Vec<f64>> {
    Ok(get_u64s(bytes, what)?.into_iter().map(f64::from_bits).collect())
}

/// Bounds-checked sequential reader over a byte slice (used for the
/// variable-layout META section; the array sections decode whole).
pub struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
    what: &'static str,
}

impl<'a> Cursor<'a> {
    /// Reader over `bytes`, labelled `what` in errors.
    pub fn new(bytes: &'a [u8], what: &'static str) -> Cursor<'a> {
        Cursor { bytes, pos: 0, what }
    }

    /// Take the next `n` raw bytes.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.bytes.len()).ok_or_else(|| {
            snap_err(format!(
                "{}: truncated at byte {} (wanted {} more of {})",
                self.what,
                self.pos,
                n,
                self.bytes.len()
            ))
        })?;
        let out = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    /// Read a little-endian `u32`.
    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Read a little-endian `u64`.
    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// Assert the reader consumed the section exactly — trailing garbage
    /// is corruption, not slack.
    pub fn finish(self) -> Result<()> {
        if self.pos != self.bytes.len() {
            return Err(snap_err(format!(
                "{}: {} trailing bytes after the last field",
                self.what,
                self.bytes.len() - self.pos
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_the_ieee_check_value() {
        // The standard CRC-32 check value: CRC32("123456789").
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn crc32_detects_every_single_byte_flip() {
        let base: Vec<u8> = (0..64u8).collect();
        let digest = crc32(&base);
        for i in 0..base.len() {
            for bit in 0..8 {
                let mut mutated = base.clone();
                mutated[i] ^= 1 << bit;
                assert_ne!(crc32(&mutated), digest, "flip byte {i} bit {bit} undetected");
            }
        }
    }

    #[test]
    fn scalar_arrays_round_trip_bitwise() {
        let u32s = vec![0u32, 1, u32::MAX, 0xDEAD_BEEF];
        let u64s = vec![0u64, u64::MAX, 0x0123_4567_89AB_CDEF];
        let f64s = vec![0.0f64, -0.0, 1.5, f64::MIN_POSITIVE, 1.0 / 3.0];
        let (mut a, mut b, mut c) = (Vec::new(), Vec::new(), Vec::new());
        put_u32s(&mut a, &u32s);
        put_u64s(&mut b, &u64s);
        put_f64s(&mut c, &f64s);
        assert_eq!(get_u32s(&a, "a").unwrap(), u32s);
        assert_eq!(get_u64s(&b, "b").unwrap(), u64s);
        let back = get_f64s(&c, "c").unwrap();
        assert_eq!(back.len(), f64s.len());
        for (x, y) in back.iter().zip(&f64s) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn misaligned_section_lengths_are_typed_errors() {
        assert!(matches!(get_u32s(&[0u8; 5], "x"), Err(Error::Snapshot { .. })));
        assert!(matches!(get_u64s(&[0u8; 12], "x"), Err(Error::Snapshot { .. })));
        assert!(matches!(get_f64s(&[0u8; 7], "x"), Err(Error::Snapshot { .. })));
    }

    #[test]
    fn cursor_is_bounds_checked_and_exact() {
        let mut body = Vec::new();
        put_u32(&mut body, 7);
        put_u64(&mut body, 9);
        let mut c = Cursor::new(&body, "META");
        assert_eq!(c.u32().unwrap(), 7);
        assert_eq!(c.u64().unwrap(), 9);
        c.finish().unwrap();

        // Reading past the end is typed.
        let mut c = Cursor::new(&body, "META");
        assert_eq!(c.u64().unwrap(), 7 | (9 << 32));
        assert!(matches!(c.u64(), Err(Error::Snapshot { .. })));

        // Trailing bytes are typed.
        let c = Cursor::new(&body, "META");
        assert!(matches!(c.finish(), Err(Error::Snapshot { .. })));
    }
}
