//! Versioned, checksummed binary snapshots of [`Prepared`] state.
//!
//! Algorithm 1 steps 1–3 — spanning tree, resistance-scored off-tree
//! order, LCA subtasks — are pure functions of the graph, yet every
//! process historically paid them again. A snapshot persists exactly that
//! prepared state so a warm start is O(read + validate): the session API
//! exposes it as [`Prepared::save`] / [`Prepared::load`], the CLI as
//! `pdgrass prepare --save/--load`, and the serve daemon tries a
//! snapshot load on every cache miss when `[serve] snapshot_dir` is
//! configured (see `serve::server`).
//!
//! # Container format (version 2)
//!
//! Flat little-endian arrays behind a 40-byte header and a section
//! table. All offsets are 8-aligned and sections sit at canonical
//! sequential positions, so a later mmap mode can point straight into
//! the file:
//!
//! ```text
//! header   (40 B)  magic "PDGRSNAP" · version u32 · section count u32
//!                  · graph fingerprint u64 · payload length u64
//!                  · CRC-32 of the section table u32 · reserved u32 (0)
//! table    (18×24) per section: id u32 · CRC-32 u32 · offset u64 · len u64
//! payload          section bodies in id order, zero-padded to 8 bytes
//! ```
//!
//! The 18 sections carry the CSR edge list (`u`/`v`/`w`), the rooted
//! tree's per-vertex arrays, the tree-edge flags, the score-sorted
//! off-tree list, the subtask grouping (CSR of indices), and the optional
//! relabel permutation, plus a META section with dimensions, root,
//! pipeline tag, relabel tag, and the optional session name. Wall-clock
//! timings are *not* serialized — a loaded `Prepared` reports zero prep
//! timings — and neither is the thread count, which is an execution
//! parameter, not prepared state.
//!
//! Version 2 (the giant-graph scaling pass) narrowed the subtask CSR
//! offsets from `u64` to `u32` — the prepared state itself is u32-indexed
//! throughout, so the wider offsets bought nothing — and added the PERM
//! section: relabeled sessions persist `perm[new] = old` so a warm load
//! can rebuild the original-space graph (the working graph with its
//! endpoints mapped back) without re-running the relabeling. Version-1
//! files are rejected with a typed version error.
//!
//! # Validation: corruption is typed, wrong content is rejected
//!
//! [`from_bytes`] accepts a byte string only if **every** byte is
//! accounted for: magic, version, section count, reserved word, exact
//! file length, table digest, canonical per-section offsets, per-section
//! CRC-32 digests, and zero padding. Any single-byte corruption or
//! truncation anywhere in the file therefore surfaces as the typed
//! [`Error::Snapshot`] — never a panic, never a silently-wrong
//! `Prepared` (the fuzz suite in `rust/tests/snapshot.rs` flips every
//! byte and checks exactly this).
//!
//! Beyond integrity, the decoder re-validates *semantics*: the graph
//! must re-hash to the header fingerprint, the tree arrays must be a
//! consistent rooted spanning tree over flagged graph edges (bitwise
//! `rdepth` recurrence included), every off-tree entry is compared
//! against a fresh [`annotate_off_tree_edge`] recomputation, the score
//! order must be the strict [`score_cmp`] total order, and the subtask
//! grouping must be the unique (size-desc, lca-asc) partition. A file
//! with valid digests but wrong content is still rejected, and an
//! accepted load is bitwise identical to a fresh prepare.

pub mod bytes;

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::graph::{self, Edge, Graph, Relabel};
use crate::recovery::score::score_cmp;
use crate::recovery::subtask::Subtask;
use crate::recovery::Pipeline;
use crate::session::Prepared;
use crate::tree::{annotate_off_tree_edge, OffTreeEdge, RootedTree, SkipTable, Spanning};

use bytes::{crc32, get_f64s, get_u32s, put_f64s, put_u32, put_u32s, put_u64, snap_err, Cursor};

/// File magic: first 8 bytes of every snapshot.
pub const MAGIC: [u8; 8] = *b"PDGRSNAP";
/// Current container format version.
pub const VERSION: u32 = 2;
/// Fixed header length in bytes.
const HEADER_LEN: usize = 40;
/// Section-table entry length in bytes (id, crc, offset, len).
const TABLE_ENTRY_LEN: usize = 24;

/// Dimensions, root, pipeline tag, optional name.
const SEC_META: u32 = 1;
/// CSR edge endpoints `u` (`m × u32`).
const SEC_EDGE_U: u32 = 2;
/// CSR edge endpoints `v` (`m × u32`).
const SEC_EDGE_V: u32 = 3;
/// CSR edge weights (`m × f64`).
const SEC_EDGE_W: u32 = 4;
/// Tree parent per vertex (`n × u32`).
const SEC_TREE_PARENT: u32 = 5;
/// Parent-edge weight per vertex (`n × f64`).
const SEC_TREE_PARENT_W: u32 = 6;
/// Unweighted depth per vertex (`n × u32`).
const SEC_TREE_DEPTH: u32 = 7;
/// Resistive depth per vertex (`n × f64`).
const SEC_TREE_RDEPTH: u32 = 8;
/// BFS order from the root (`n × u32`).
const SEC_TREE_ORDER: u32 = 9;
/// Per-edge tree flag (`m × u8`, each 0/1).
const SEC_TREE_FLAGS: u32 = 10;
/// Off-tree edge ids, score order (`k × u32`).
const SEC_OFF_EID: u32 = 11;
/// Off-tree LCAs (`k × u32`).
const SEC_OFF_LCA: u32 = 12;
/// Off-tree tree-path resistances (`k × f64`).
const SEC_OFF_RESISTANCE: u32 = 13;
/// Off-tree criticality scores (`k × f64`).
const SEC_OFF_SCORE: u32 = 14;
/// Subtask LCAs (`s × u32`).
const SEC_SUB_LCA: u32 = 15;
/// Subtask index-CSR offsets (`(s+1) × u32` — compact since version 2;
/// the off-tree count is bounded by the u32-indexed edge count).
const SEC_SUB_PTR: u32 = 16;
/// Subtask index-CSR ids (`k × u32`).
const SEC_SUB_IDXS: u32 = 17;
/// Relabel permutation `perm[new] = old` (`n × u32` when the session
/// relabeled, empty under `Relabel::None`).
const SEC_PERM: u32 = 18;

/// Canonical section layout: every version-2 snapshot contains exactly
/// these sections, in exactly this order. The decoder enforces the list
/// entry-for-entry, so section ids double as indices (`id - 1`).
const SECTIONS: [(u32, &str); 18] = [
    (SEC_META, "META"),
    (SEC_EDGE_U, "EDGE_U"),
    (SEC_EDGE_V, "EDGE_V"),
    (SEC_EDGE_W, "EDGE_W"),
    (SEC_TREE_PARENT, "TREE_PARENT"),
    (SEC_TREE_PARENT_W, "TREE_PARENT_W"),
    (SEC_TREE_DEPTH, "TREE_DEPTH"),
    (SEC_TREE_RDEPTH, "TREE_RDEPTH"),
    (SEC_TREE_ORDER, "TREE_ORDER"),
    (SEC_TREE_FLAGS, "TREE_FLAGS"),
    (SEC_OFF_EID, "OFF_EID"),
    (SEC_OFF_LCA, "OFF_LCA"),
    (SEC_OFF_RESISTANCE, "OFF_RESISTANCE"),
    (SEC_OFF_SCORE, "OFF_SCORE"),
    (SEC_SUB_LCA, "SUB_LCA"),
    (SEC_SUB_PTR, "SUB_PTR"),
    (SEC_SUB_IDXS, "SUB_IDXS"),
    (SEC_PERM, "PERM"),
];

/// Assembles sections into the final container byte string.
struct Writer {
    sections: Vec<(u32, Vec<u8>)>,
}

impl Writer {
    fn new() -> Writer {
        Writer { sections: Vec::with_capacity(SECTIONS.len()) }
    }

    fn push(&mut self, id: u32, body: Vec<u8>) {
        self.sections.push((id, body));
    }

    /// Header + table + payload. Sections land at sequential 8-aligned
    /// offsets (zero-padded), which the decoder requires exactly.
    fn finish(self, fingerprint: u64) -> Vec<u8> {
        let mut table = Vec::with_capacity(self.sections.len() * TABLE_ENTRY_LEN);
        let mut payload = Vec::new();
        for (id, body) in &self.sections {
            put_u32(&mut table, *id);
            put_u32(&mut table, crc32(body));
            put_u64(&mut table, payload.len() as u64);
            put_u64(&mut table, body.len() as u64);
            payload.extend_from_slice(body);
            while payload.len() % 8 != 0 {
                payload.push(0);
            }
        }
        let mut out = Vec::with_capacity(HEADER_LEN + table.len() + payload.len());
        out.extend_from_slice(&MAGIC);
        put_u32(&mut out, VERSION);
        put_u32(&mut out, self.sections.len() as u32);
        put_u64(&mut out, fingerprint);
        put_u64(&mut out, payload.len() as u64);
        put_u32(&mut out, crc32(&table));
        put_u32(&mut out, 0); // reserved, validated zero on load
        out.extend_from_slice(&table);
        out.extend_from_slice(&payload);
        out
    }
}

/// Serialize `p` into a version-2 snapshot container.
pub fn to_bytes(p: &Prepared) -> Vec<u8> {
    let g = p.graph();
    let sp = p.spanning();
    let off = p.off_tree();
    let subs = p.subtasks();
    let (n, m) = (g.num_vertices(), g.num_edges());

    let mut meta = Vec::new();
    put_u64(&mut meta, n as u64);
    put_u64(&mut meta, m as u64);
    put_u64(&mut meta, off.len() as u64);
    put_u64(&mut meta, subs.len() as u64);
    put_u32(&mut meta, sp.root);
    put_u32(&mut meta, match p.pipeline() {
        Pipeline::Barrier => 0,
        Pipeline::Streamed => 1,
    });
    put_u32(&mut meta, match p.relabel() {
        Relabel::None => 0,
        Relabel::Bfs => 1,
        Relabel::Degree => 2,
    });
    match p.name() {
        None => put_u32(&mut meta, 0),
        Some(nm) => {
            put_u32(&mut meta, 1);
            put_u32(&mut meta, nm.len() as u32);
            meta.extend_from_slice(nm.as_bytes());
        }
    }

    let mut w = Writer::new();
    w.push(SEC_META, meta);

    let (mut eu, mut ev, mut ew) = (Vec::new(), Vec::new(), Vec::new());
    for e in g.edges() {
        eu.push(e.u);
        ev.push(e.v);
        ew.push(e.w);
    }
    let mut body = Vec::new();
    put_u32s(&mut body, &eu);
    w.push(SEC_EDGE_U, body);
    let mut body = Vec::new();
    put_u32s(&mut body, &ev);
    w.push(SEC_EDGE_V, body);
    let mut body = Vec::new();
    put_f64s(&mut body, &ew);
    w.push(SEC_EDGE_W, body);

    let t = &sp.tree;
    let mut body = Vec::new();
    put_u32s(&mut body, &t.parent);
    w.push(SEC_TREE_PARENT, body);
    let mut body = Vec::new();
    put_f64s(&mut body, &t.parent_w);
    w.push(SEC_TREE_PARENT_W, body);
    let mut body = Vec::new();
    put_u32s(&mut body, &t.depth);
    w.push(SEC_TREE_DEPTH, body);
    let mut body = Vec::new();
    put_f64s(&mut body, &t.rdepth);
    w.push(SEC_TREE_RDEPTH, body);
    let mut body = Vec::new();
    put_u32s(&mut body, &t.order);
    w.push(SEC_TREE_ORDER, body);
    w.push(SEC_TREE_FLAGS, sp.is_tree_edge.iter().map(|&b| b as u8).collect());

    let (mut eid, mut lca, mut res, mut score) = (Vec::new(), Vec::new(), Vec::new(), Vec::new());
    for e in off {
        eid.push(e.eid);
        lca.push(e.lca);
        res.push(e.resistance);
        score.push(e.score);
    }
    let mut body = Vec::new();
    put_u32s(&mut body, &eid);
    w.push(SEC_OFF_EID, body);
    let mut body = Vec::new();
    put_u32s(&mut body, &lca);
    w.push(SEC_OFF_LCA, body);
    let mut body = Vec::new();
    put_f64s(&mut body, &res);
    w.push(SEC_OFF_RESISTANCE, body);
    let mut body = Vec::new();
    put_f64s(&mut body, &score);
    w.push(SEC_OFF_SCORE, body);

    let mut sub_lca = Vec::with_capacity(subs.len());
    let mut sub_ptr: Vec<u32> = Vec::with_capacity(subs.len() + 1);
    let mut sub_idxs = Vec::with_capacity(off.len());
    sub_ptr.push(0u32);
    for s in subs {
        sub_lca.push(s.lca);
        sub_idxs.extend_from_slice(&s.idxs);
        // Compact offsets: the off-tree count is bounded by the graph's
        // u32-indexed edge count, so u32 always suffices.
        sub_ptr.push(sub_idxs.len() as u32);
    }
    let mut body = Vec::new();
    put_u32s(&mut body, &sub_lca);
    w.push(SEC_SUB_LCA, body);
    let mut body = Vec::new();
    put_u32s(&mut body, &sub_ptr);
    w.push(SEC_SUB_PTR, body);
    let mut body = Vec::new();
    put_u32s(&mut body, &sub_idxs);
    w.push(SEC_SUB_IDXS, body);

    let mut body = Vec::new();
    put_u32s(&mut body, p.perm().unwrap_or(&[]));
    w.push(SEC_PERM, body);

    w.finish(p.fingerprint())
}

/// Convert a stored `u64` dimension to `usize`, typed on overflow.
fn usize_of(v: u64, what: &str) -> Result<usize> {
    usize::try_from(v).map_err(|_| snap_err(format!("{what} {v} overflows usize")))
}

/// Assert a decoded array has the META-implied length.
fn expect_len<T>(xs: &[T], want: usize, what: &str) -> Result<()> {
    if xs.len() != want {
        return Err(snap_err(format!("{what}: {} entries, META implies {want}", xs.len())));
    }
    Ok(())
}

/// Header + table + integrity-validated section bodies.
struct Container<'a> {
    fingerprint: u64,
    sections: Vec<&'a [u8]>,
}

impl Container<'_> {
    /// Body of section `id` (layout guarantees `id - 1` indexes it).
    fn sec(&self, id: u32) -> &[u8] {
        self.sections[(id - 1) as usize]
    }
}

/// Validate the container envelope: magic, version, exact length, table
/// digest, canonical offsets, per-section digests, zero padding. After
/// this returns, every byte of the file is covered by some check.
fn parse_container(data: &[u8]) -> Result<Container<'_>> {
    if data.len() < HEADER_LEN {
        return Err(snap_err(format!(
            "truncated header: {} bytes, need {HEADER_LEN}",
            data.len()
        )));
    }
    if data[0..8] != MAGIC {
        return Err(snap_err("bad magic: not a pdGRASS snapshot"));
    }
    let word32 = |at: usize| u32::from_le_bytes(data[at..at + 4].try_into().unwrap());
    let word64 = |at: usize| u64::from_le_bytes(data[at..at + 8].try_into().unwrap());
    let version = word32(8);
    if version != VERSION {
        return Err(snap_err(format!(
            "unsupported format version {version} (this build reads version {VERSION})"
        )));
    }
    let count = word32(12) as usize;
    if count != SECTIONS.len() {
        return Err(snap_err(format!("section count {count}, expected {}", SECTIONS.len())));
    }
    let fingerprint = word64(16);
    let payload_len = usize_of(word64(24), "payload length")?;
    let table_crc = word32(32);
    let reserved = word32(36);
    if reserved != 0 {
        return Err(snap_err(format!("reserved header word is {reserved}, expected 0")));
    }
    let table_len = count * TABLE_ENTRY_LEN;
    let expected = HEADER_LEN
        .checked_add(table_len)
        .and_then(|x| x.checked_add(payload_len))
        .ok_or_else(|| snap_err("header-implied file length overflows"))?;
    if data.len() != expected {
        return Err(snap_err(format!(
            "file length {} does not match header-implied {expected}",
            data.len()
        )));
    }
    let table = &data[HEADER_LEN..HEADER_LEN + table_len];
    if crc32(table) != table_crc {
        return Err(snap_err("section table digest mismatch"));
    }
    let payload = &data[HEADER_LEN + table_len..];

    let mut sections = Vec::with_capacity(count);
    let mut at = 0usize; // canonical next offset within the payload
    for (i, &(id, name)) in SECTIONS.iter().enumerate() {
        let e = &table[i * TABLE_ENTRY_LEN..(i + 1) * TABLE_ENTRY_LEN];
        let got_id = u32::from_le_bytes(e[0..4].try_into().unwrap());
        let got_crc = u32::from_le_bytes(e[4..8].try_into().unwrap());
        let got_off = u64::from_le_bytes(e[8..16].try_into().unwrap());
        let got_len = usize_of(u64::from_le_bytes(e[16..24].try_into().unwrap()), "section len")?;
        if got_id != id {
            return Err(snap_err(format!(
                "table entry {i}: section id {got_id}, expected {id} ({name})"
            )));
        }
        if got_off != at as u64 {
            return Err(snap_err(format!(
                "section {name}: offset {got_off}, canonical layout requires {at}"
            )));
        }
        let end = at
            .checked_add(got_len)
            .filter(|&e| e <= payload.len())
            .ok_or_else(|| snap_err(format!("section {name} overruns the payload")))?;
        let body = &payload[at..end];
        if crc32(body) != got_crc {
            return Err(snap_err(format!("section {name} digest mismatch")));
        }
        at = end;
        while at % 8 != 0 {
            if at >= payload.len() {
                return Err(snap_err(format!("section {name}: padding truncated")));
            }
            if payload[at] != 0 {
                return Err(snap_err(format!("section {name}: nonzero alignment padding")));
            }
            at += 1;
        }
        sections.push(body);
    }
    if at != payload.len() {
        return Err(snap_err(format!("{} trailing payload bytes", payload.len() - at)));
    }
    Ok(Container { fingerprint, sections })
}

/// Deserialize and fully validate a snapshot, reconstructing [`Prepared`]
/// without re-running Algorithm-1 steps 1–3. Rejects (typed
/// [`Error::Snapshot`]) anything that is not bitwise equivalent to the
/// state a fresh prepare of the same graph would produce.
pub fn from_bytes(data: &[u8]) -> Result<Prepared> {
    let c = parse_container(data)?;

    // META: dimensions and tags.
    let mut meta = Cursor::new(c.sec(SEC_META), "META");
    let n = usize_of(meta.u64()?, "vertex count")?;
    let m = usize_of(meta.u64()?, "edge count")?;
    let k = usize_of(meta.u64()?, "off-tree count")?;
    let s = usize_of(meta.u64()?, "subtask count")?;
    let root = meta.u32()?;
    let pipe_tag = meta.u32()?;
    let relabel_tag = meta.u32()?;
    let name = match meta.u32()? {
        0 => None,
        1 => {
            let len = meta.u32()? as usize;
            let raw = meta.take(len)?;
            Some(
                String::from_utf8(raw.to_vec())
                    .map_err(|_| snap_err("META: session name is not UTF-8"))?,
            )
        }
        other => return Err(snap_err(format!("META: bad name flag {other}"))),
    };
    meta.finish()?;
    let pipeline = match pipe_tag {
        0 => Pipeline::Barrier,
        1 => Pipeline::Streamed,
        other => return Err(snap_err(format!("META: bad pipeline tag {other}"))),
    };
    let relabel = match relabel_tag {
        0 => Relabel::None,
        1 => Relabel::Bfs,
        2 => Relabel::Degree,
        other => return Err(snap_err(format!("META: bad relabel tag {other}"))),
    };
    if n < 2 || m < 1 {
        return Err(snap_err(format!("META: degenerate dimensions n={n} m={m}")));
    }
    if n > u32::MAX as usize || m > u32::MAX as usize {
        return Err(snap_err(format!("META: dimensions n={n} m={m} exceed u32 ids")));
    }
    if m < n - 1 || k != m - (n - 1) {
        return Err(snap_err(format!(
            "META: off-tree count {k} inconsistent with n={n}, m={m} (expected m-(n-1))"
        )));
    }
    if (root as usize) >= n {
        return Err(snap_err(format!("META: root {root} out of range for n={n}")));
    }
    if s > k {
        return Err(snap_err(format!("META: {s} subtasks over {k} off-tree edges")));
    }

    // Graph: validated CSR edges, then the fingerprint cross-check.
    let eu = get_u32s(c.sec(SEC_EDGE_U), "EDGE_U")?;
    let ev = get_u32s(c.sec(SEC_EDGE_V), "EDGE_V")?;
    let ew = get_f64s(c.sec(SEC_EDGE_W), "EDGE_W")?;
    expect_len(&eu, m, "EDGE_U")?;
    expect_len(&ev, m, "EDGE_V")?;
    expect_len(&ew, m, "EDGE_W")?;
    let mut edges = Vec::with_capacity(m);
    let mut prev: Option<(u32, u32)> = None;
    for i in 0..m {
        let (u, v, w) = (eu[i], ev[i], ew[i]);
        if u >= v || (v as usize) >= n {
            return Err(snap_err(format!("edge {i}: endpoints ({u},{v}) invalid for n={n}")));
        }
        if !w.is_finite() || w <= 0.0 {
            return Err(snap_err(format!("edge {i}: weight {w} is not finite-positive")));
        }
        if let Some(p) = prev {
            if (u, v) <= p {
                return Err(snap_err(format!("edge {i}: ids not strictly ascending by (u,v)")));
            }
        }
        prev = Some((u, v));
        edges.push(Edge { u, v, w });
    }
    let g = Graph::from_unique_edges(n, edges);
    let fp = graph::fingerprint(&g);
    if fp != c.fingerprint {
        return Err(snap_err(format!(
            "graph fingerprint mismatch: header says {}, content hashes to {}",
            graph::fingerprint_hex(c.fingerprint),
            graph::fingerprint_hex(fp)
        )));
    }

    // Spanning tree: arrays must form a rooted tree over flagged graph
    // edges, with the exact bitwise rdepth recurrence `build` uses.
    let parent = get_u32s(c.sec(SEC_TREE_PARENT), "TREE_PARENT")?;
    let parent_w = get_f64s(c.sec(SEC_TREE_PARENT_W), "TREE_PARENT_W")?;
    let depth = get_u32s(c.sec(SEC_TREE_DEPTH), "TREE_DEPTH")?;
    let rdepth = get_f64s(c.sec(SEC_TREE_RDEPTH), "TREE_RDEPTH")?;
    let order = get_u32s(c.sec(SEC_TREE_ORDER), "TREE_ORDER")?;
    let flags = c.sec(SEC_TREE_FLAGS);
    expect_len(&parent, n, "TREE_PARENT")?;
    expect_len(&parent_w, n, "TREE_PARENT_W")?;
    expect_len(&depth, n, "TREE_DEPTH")?;
    expect_len(&rdepth, n, "TREE_RDEPTH")?;
    expect_len(&order, n, "TREE_ORDER")?;
    expect_len(flags, m, "TREE_FLAGS")?;

    let r = root as usize;
    if parent[r] != root || parent_w[r].to_bits() != 0 || depth[r] != 0 || rdepth[r].to_bits() != 0
    {
        return Err(snap_err("tree: root row is not (parent=root, w=0, depth=0, rdepth=0)"));
    }
    if order[0] != root {
        return Err(snap_err(format!("tree: order starts at {}, root is {root}", order[0])));
    }
    let mut pos = vec![usize::MAX; n];
    for (i, &v) in order.iter().enumerate() {
        if (v as usize) >= n || pos[v as usize] != usize::MAX {
            return Err(snap_err(format!("tree: order entry {i} ({v}) out of range or repeated")));
        }
        pos[v as usize] = i;
    }
    for v in 0..n as u32 {
        if v == root {
            continue;
        }
        let vi = v as usize;
        let p = parent[vi];
        if (p as usize) >= n || p == v {
            return Err(snap_err(format!("tree: vertex {v} has invalid parent {p}")));
        }
        if pos[p as usize] >= pos[vi] {
            return Err(snap_err(format!("tree: parent {p} does not precede {v} in order")));
        }
        if depth[vi] != depth[p as usize] + 1 {
            return Err(snap_err(format!("tree: depth of {v} is not parent depth + 1")));
        }
        let w = parent_w[vi];
        if !w.is_finite() || w <= 0.0 {
            return Err(snap_err(format!("tree: parent weight of {v} is {w}")));
        }
        if rdepth[vi].to_bits() != (rdepth[p as usize] + 1.0 / w).to_bits() {
            return Err(snap_err(format!("tree: rdepth of {v} breaks the bitwise recurrence")));
        }
        // The parent link must be a flagged graph edge of the same weight.
        let linked = g.neighbors(v).any(|(nb, nw, eid)| {
            nb == p && flags[eid as usize] == 1 && nw.to_bits() == w.to_bits()
        });
        if !linked {
            return Err(snap_err(format!(
                "tree: ({v},{p}) is not a flagged graph edge of weight {w}"
            )));
        }
    }
    let mut tree_edges = 0usize;
    for (i, &f) in flags.iter().enumerate() {
        if f > 1 {
            return Err(snap_err(format!("tree: flag {i} is {f}, expected 0/1")));
        }
        tree_edges += f as usize;
    }
    if tree_edges != n - 1 {
        return Err(snap_err(format!("tree: {tree_edges} flagged edges, expected {}", n - 1)));
    }
    let tree = RootedTree::from_parts(root, parent, parent_w, depth, rdepth, order);
    let skip = SkipTable::build(&tree);
    let is_tree_edge: Vec<bool> = flags.iter().map(|&b| b == 1).collect();
    let spanning = Spanning { tree, skip, is_tree_edge, root };

    // Off-tree list: every entry re-derived from the graph + tree and
    // compared bitwise, order checked against the strict score order.
    let off_eid = get_u32s(c.sec(SEC_OFF_EID), "OFF_EID")?;
    let off_lca = get_u32s(c.sec(SEC_OFF_LCA), "OFF_LCA")?;
    let off_res = get_f64s(c.sec(SEC_OFF_RESISTANCE), "OFF_RESISTANCE")?;
    let off_score = get_f64s(c.sec(SEC_OFF_SCORE), "OFF_SCORE")?;
    expect_len(&off_eid, k, "OFF_EID")?;
    expect_len(&off_lca, k, "OFF_LCA")?;
    expect_len(&off_res, k, "OFF_RESISTANCE")?;
    expect_len(&off_score, k, "OFF_SCORE")?;
    let mut seen = vec![false; m];
    let mut off: Vec<OffTreeEdge> = Vec::with_capacity(k);
    for i in 0..k {
        let eid = off_eid[i];
        if (eid as usize) >= m || spanning.is_tree_edge[eid as usize] {
            return Err(snap_err(format!("off-tree entry {i}: edge {eid} invalid or a tree edge")));
        }
        if seen[eid as usize] {
            return Err(snap_err(format!("off-tree entry {i}: edge {eid} repeated")));
        }
        seen[eid as usize] = true;
        let e = annotate_off_tree_edge(&g, &spanning, eid);
        if e.lca != off_lca[i]
            || e.resistance.to_bits() != off_res[i].to_bits()
            || e.score.to_bits() != off_score[i].to_bits()
        {
            return Err(snap_err(format!(
                "off-tree entry {i} (edge {eid}) does not match recomputation"
            )));
        }
        if let Some(last) = off.last() {
            if score_cmp(last, &e) != std::cmp::Ordering::Less {
                return Err(snap_err(format!("off-tree entry {i}: list is not score-sorted")));
            }
        }
        off.push(e);
    }

    // Subtasks: the unique partition of 0..k grouped by LCA, ordered
    // size-desc with lca-asc tie-break (exactly `make_subtasks`' order).
    let sub_lca = get_u32s(c.sec(SEC_SUB_LCA), "SUB_LCA")?;
    let sub_ptr = get_u32s(c.sec(SEC_SUB_PTR), "SUB_PTR")?;
    let sub_idxs = get_u32s(c.sec(SEC_SUB_IDXS), "SUB_IDXS")?;
    expect_len(&sub_lca, s, "SUB_LCA")?;
    expect_len(&sub_ptr, s + 1, "SUB_PTR")?;
    expect_len(&sub_idxs, k, "SUB_IDXS")?;
    if sub_ptr[0] != 0 || sub_ptr[s] != k as u32 {
        return Err(snap_err("subtasks: CSR offsets do not span the off-tree list"));
    }
    let mut used = vec![false; k];
    let mut lca_seen = vec![false; n];
    let mut subtasks: Vec<Subtask> = Vec::with_capacity(s);
    for j in 0..s {
        let lo = sub_ptr[j] as usize;
        let hi = sub_ptr[j + 1] as usize;
        if hi <= lo || hi > k {
            return Err(snap_err(format!("subtask {j}: empty or non-monotone CSR range")));
        }
        let lca = sub_lca[j];
        if (lca as usize) >= n || lca_seen[lca as usize] {
            return Err(snap_err(format!("subtask {j}: LCA {lca} out of range or repeated")));
        }
        lca_seen[lca as usize] = true;
        let idxs = sub_idxs[lo..hi].to_vec();
        for (t, &ix) in idxs.iter().enumerate() {
            if (ix as usize) >= k || used[ix as usize] {
                return Err(snap_err(format!("subtask {j}: index {ix} out of range or repeated")));
            }
            used[ix as usize] = true;
            if t > 0 && idxs[t - 1] >= ix {
                return Err(snap_err(format!("subtask {j}: indices not strictly ascending")));
            }
            if off[ix as usize].lca != lca {
                return Err(snap_err(format!(
                    "subtask {j}: index {ix} has LCA {}, subtask claims {lca}",
                    off[ix as usize].lca
                )));
            }
        }
        if let Some(prev) = subtasks.last() {
            let ordered =
                idxs.len() < prev.len() || (idxs.len() == prev.len() && prev.lca < lca);
            if !ordered {
                return Err(snap_err(format!(
                    "subtask {j}: grouping is not (size-desc, lca-asc) ordered"
                )));
            }
        }
        subtasks.push(Subtask { lca, idxs });
    }
    // sub_ptr spans 0..k with no repeats, so every off-tree index is
    // covered; no separate `used` sweep needed.

    // PERM: empty under Relabel::None, a validated bijection otherwise.
    // The permutation is genuine state (it was derived from the original
    // graph, which is not serialized), so the decoder can only check it
    // is a bijection — the original graph is rebuilt through it.
    let perm_raw = get_u32s(c.sec(SEC_PERM), "PERM")?;
    let perm = if relabel.is_none() {
        if !perm_raw.is_empty() {
            return Err(snap_err(format!(
                "PERM: {} entries but META says relabel=none",
                perm_raw.len()
            )));
        }
        None
    } else {
        graph::validate_perm(&perm_raw, n).map_err(|e| snap_err(format!("PERM: {e}")))?;
        Some(perm_raw)
    };

    Ok(Prepared::from_snapshot_parts(name, g, spanning, off, subtasks, pipeline, relabel, perm))
}

/// Canonical snapshot filename for a graph fingerprint inside `dir`:
/// `<fingerprint-hex>.pdsnap` — the key the serve daemon probes on a
/// cache miss.
pub fn file_path(dir: &Path, fingerprint: u64) -> PathBuf {
    dir.join(format!("{}.pdsnap", graph::fingerprint_hex(fingerprint)))
}

/// Write `p` to `path` atomically (temp file + rename), so a concurrent
/// loader never observes a half-written snapshot.
pub fn save(p: &Prepared, path: &Path) -> Result<()> {
    let data = to_bytes(p);
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    std::fs::write(&tmp, &data)?;
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        return Err(Error::Io(e));
    }
    Ok(())
}

/// Read and validate a snapshot file. A missing/unreadable file is
/// [`Error::Io`]; a present-but-invalid one is [`Error::Snapshot`].
pub fn load(path: &Path) -> Result<Prepared> {
    let data = std::fs::read(path)?;
    from_bytes(&data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::Sparsify;
    use crate::util::Rng;

    fn prepared() -> Prepared {
        let g = crate::gen::grid(9, 9, 0.5, &mut Rng::new(7));
        Sparsify::graph(g).named("snap-unit").prepare().unwrap()
    }

    fn assert_equivalent(a: &Prepared, b: &Prepared) {
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_eq!(a.name(), b.name());
        assert_eq!(a.pipeline(), b.pipeline());
        assert_eq!(a.graph().num_vertices(), b.graph().num_vertices());
        assert_eq!(a.graph().edges().len(), b.graph().edges().len());
        assert_eq!(a.num_off_tree(), b.num_off_tree());
        for (x, y) in a.off_tree().iter().zip(b.off_tree()) {
            assert_eq!(x.eid, y.eid);
            assert_eq!(x.lca, y.lca);
            assert_eq!(x.score.to_bits(), y.score.to_bits());
        }
        assert_eq!(a.subtasks().len(), b.subtasks().len());
        for (x, y) in a.subtasks().iter().zip(b.subtasks()) {
            assert_eq!(x.lca, y.lca);
            assert_eq!(x.idxs, y.idxs);
        }
    }

    #[test]
    fn round_trips_bitwise() {
        let p = prepared();
        let data = to_bytes(&p);
        let q = from_bytes(&data).unwrap();
        assert_equivalent(&p, &q);
        // Timings are not state: a loaded snapshot reports zero.
        assert_eq!(q.spanning_ms(), 0.0);
        assert_eq!(q.prep_ms(), [0.0; 3]);
        // Re-encoding the loaded state reproduces the file byte-for-byte.
        assert_eq!(to_bytes(&q), data);
    }

    #[test]
    fn relabeled_state_round_trips_with_perm() {
        let g = crate::gen::grid(9, 9, 0.5, &mut Rng::new(8));
        for mode in [Relabel::Bfs, Relabel::Degree] {
            let p = Sparsify::graph(g.clone()).relabel(mode).prepare().unwrap();
            let data = to_bytes(&p);
            let q = from_bytes(&data).unwrap();
            assert_equivalent(&p, &q);
            assert_eq!(q.relabel(), mode);
            assert_eq!(q.perm(), p.perm());
            // The original graph is rebuilt through the perm, bitwise.
            assert_eq!(q.original_fingerprint(), p.original_fingerprint());
            assert_eq!(to_bytes(&q), data);
        }
        // Any corruption of the PERM section (the file's tail) trips the
        // section CRC or the padding check — typed rejection either way.
        let p = Sparsify::graph(g).relabel(Relabel::Bfs).prepare().unwrap();
        let data = to_bytes(&p);
        for back in [1, 5, 9, 64] {
            let mut bad = data.clone();
            let at = data.len() - back;
            bad[at] ^= 0x01;
            assert!(
                matches!(from_bytes(&bad), Err(Error::Snapshot { .. })),
                "flip at {at} not rejected"
            );
        }
    }

    #[test]
    fn file_save_load_round_trips() {
        let p = prepared();
        let path = std::env::temp_dir().join(format!("pdg-snap-unit-{}.pdsnap", std::process::id()));
        save(&p, &path).unwrap();
        let q = load(&path).unwrap();
        std::fs::remove_file(&path).unwrap();
        assert_equivalent(&p, &q);
    }

    #[test]
    fn missing_file_is_io_not_snapshot() {
        let path = std::env::temp_dir().join("pdg-snap-missing-definitely.pdsnap");
        assert!(matches!(load(&path), Err(Error::Io(_))));
    }

    #[test]
    fn wrong_version_and_magic_are_typed() {
        let p = prepared();
        let data = to_bytes(&p);
        let mut bad = data.clone();
        bad[0] = b'X';
        assert!(matches!(from_bytes(&bad), Err(Error::Snapshot { .. })));
        let mut bad = data;
        bad[8] = 99; // version word
        let err = from_bytes(&bad).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
    }

    #[test]
    fn wrong_fingerprint_is_typed() {
        let p = prepared();
        let mut data = to_bytes(&p);
        data[16] ^= 0xFF; // fingerprint word
        let err = from_bytes(&data).unwrap_err();
        assert!(matches!(err, Error::Snapshot { .. }));
        assert!(err.to_string().contains("fingerprint"), "{err}");
    }

    #[test]
    fn every_truncation_is_typed() {
        let p = prepared();
        let data = to_bytes(&p);
        for len in [0, 8, 39, 40, 100, data.len() / 2, data.len() - 1] {
            assert!(
                matches!(from_bytes(&data[..len]), Err(Error::Snapshot { .. })),
                "truncation to {len} not rejected"
            );
        }
    }

    #[test]
    fn flip_smoke_across_regions() {
        // The exhaustive every-byte fuzz lives in rust/tests/snapshot.rs;
        // here a smoke pass over one byte per region.
        let p = prepared();
        let data = to_bytes(&p);
        let header_and_table = HEADER_LEN + SECTIONS.len() * TABLE_ENTRY_LEN;
        for at in [4, 13, 20, 28, 33, 37, HEADER_LEN + 5, header_and_table + 3, data.len() - 2] {
            let mut bad = data.clone();
            bad[at] ^= 0x01;
            assert!(
                matches!(from_bytes(&bad), Err(Error::Snapshot { .. })),
                "flip at byte {at} not rejected"
            );
        }
    }

    #[test]
    fn filename_is_fingerprint_keyed() {
        let path = file_path(Path::new("/tmp/snaps"), 0xABCD);
        assert_eq!(path, Path::new("/tmp/snaps/0x000000000000abcd.pdsnap"));
    }
}
