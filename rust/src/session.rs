//! Staged sparsification sessions — the crate's primary entry point.
//!
//! The paper's Algorithm 1 is explicitly staged: spanning tree (step 1),
//! resistance scoring (step 2), sort (step 3), and subtask recovery
//! (step 4). Only step 4 depends on the recovery parameters (α, strategy,
//! thread count), so this module splits the pipeline at exactly that
//! boundary:
//!
//! ```text
//! Sparsify::graph(g) ─┐
//! Sparsify::suite(..) ─┴─ prepare() ──► Prepared        (steps 1–3, once)
//!                                          │ recover(&RecoverOpts)   (step 4, many)
//!                                          ▼
//!                                       Recovered ── sparsifier() ──► Sparsifier
//!                                                                        │ pcg(..)
//!                                                                        │ write_mtx(..)
//! ```
//!
//! A [`Prepared`] owns the graph, its spanning tree, and the scored +
//! score-sorted off-tree edge list with its LCA subtasks. It is `Sync`:
//! any number of [`Prepared::recover`] calls — different α, strategy, or
//! thread count — can run repeatedly and concurrently against the same
//! prepared state, each paying only step 4. The α-sweep experiment
//! drivers (`coordinator::experiments`) lean on this to pay steps 1–3
//! once per graph instead of once per (graph, α) pair.
//!
//! Preparation (and recovery) can run under either stage-handoff
//! discipline ([`enum@Pipeline`]): the default **barrier** pipeline joins
//! each Algorithm-1 stage before the next starts, while the **streamed**
//! pipeline ([`Sparsify::prepare_streamed`] / [`RecoverOpts::pipeline`])
//! overlaps adjacent stages on the persistent pool via
//! `par::produce_stream` — scoring chunks merge into the sort while
//! later chunks are in flight, subtask grouping is fused into the final
//! merge pass, and recovery outcomes are absorbed while later subtasks
//! are still being processed. Both disciplines produce bitwise-identical
//! state and results; the streamed one just keeps the pool busy across
//! stage boundaries (see `coordinator::schedsim`'s overlap-makespan
//! model, and the `lib.rs` architecture overview for the timeline
//! diagram).
//!
//! All fallibility is the typed [`enum@Error`]: bad parameters are
//! [`Error::BadParam`], disconnected inputs are [`Error::Disconnected`],
//! solver breakdowns are [`Error::NotPositiveDefinite`] /
//! [`Error::NoConvergence`].

use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::{Error, Result};
use crate::graph::{self, Graph, Relabel};
use crate::recovery::score::{scored_sorted_streamed, sort_by_score};
use crate::recovery::subtask::{make_subtasks, Subtask, SubtaskBuilder};
use crate::recovery::{self, CostTrace, Params, Pipeline, Stats, Strategy};
use crate::tree::{build_spanning, build_spanning_streamed, off_tree_edges, OffTreeEdge, Spanning};
use crate::util::Timer;

/// Monotone id source for [`Prepared`] instances (instrumentation: lets
/// tests assert that a driver reused one `Prepared` across a sweep).
static NEXT_PREPARED_ID: AtomicU64 = AtomicU64::new(1);
/// Process-wide count of [`Sparsify::prepare`] calls (steps 1–3 paid).
static PREPARE_COUNT: AtomicU64 = AtomicU64::new(0);
/// Process-wide count of [`Prepared::recover`] calls (step 4 paid).
static RECOVER_COUNT: AtomicU64 = AtomicU64::new(0);

/// Total [`Sparsify::prepare`] calls in this process so far.
pub fn prepare_count() -> u64 {
    PREPARE_COUNT.load(Ordering::Relaxed)
}

/// Total [`Prepared::recover`] / [`Prepared::recover_traced`] calls in
/// this process so far.
pub fn recover_count() -> u64 {
    RECOVER_COUNT.load(Ordering::Relaxed)
}

/// Session builder: pick the input graph, then [`Sparsify::prepare`].
#[derive(Debug)]
pub struct Sparsify {
    graph: Graph,
    name: Option<String>,
    threads: usize,
    pipeline: Pipeline,
    relabel: Relabel,
}

impl Sparsify {
    /// Start a session from an arbitrary graph (e.g. `graph::read_mtx`
    /// output or a generator).
    pub fn graph(g: Graph) -> Sparsify {
        Sparsify {
            graph: g,
            name: None,
            threads: crate::par::num_threads(),
            pipeline: Pipeline::Barrier,
            relabel: Relabel::None,
        }
    }

    /// Start a session from an evaluation-suite row (built at `scale`
    /// with `seed`). Fails with [`Error::UnknownGraph`] for names outside
    /// the 18-row suite and [`Error::BadParam`] for a non-positive scale.
    pub fn suite(name: &str, scale: f64, seed: u64) -> Result<Sparsify> {
        if !crate::gen::SUITE.iter().any(|e| e.name == name) {
            return Err(Error::UnknownGraph { name: name.to_string() });
        }
        if !scale.is_finite() || scale <= 0.0 {
            return Err(Error::BadParam {
                name: "scale",
                why: format!("must be positive and finite, got {scale}"),
            });
        }
        let g = crate::gen::suite::build(name, scale, seed);
        Ok(Sparsify { name: Some(name.to_string()), ..Sparsify::graph(g) })
    }

    /// Label the session (reports fall back to `"graph"` otherwise).
    pub fn named(mut self, name: &str) -> Sparsify {
        self.name = Some(name.to_string());
        self
    }

    /// Thread count for the preparation and for downstream PCG
    /// evaluations ([`Sparsifier::pcg`] dispatches its iteration across
    /// this many pool workers). Under the barrier pipeline preparation
    /// uses it only for step 2's criticality sort (the spanning tree and
    /// resistance annotation use the environment's thread count, exactly
    /// as the pre-session pipeline did); under the streamed pipeline it
    /// sizes every `produce_stream` stage. Prepared state and PCG results
    /// are thread-count independent either way, so this only affects
    /// timing.
    pub fn threads(mut self, threads: usize) -> Sparsify {
        self.threads = threads.max(1);
        self
    }

    /// Stage-handoff discipline for [`Sparsify::prepare`]:
    /// [`Pipeline::Barrier`] (default) joins each Algorithm-1 stage before
    /// the next starts; [`Pipeline::Streamed`] overlaps them on the pool
    /// (scoring chunks merge into the sort while later chunks are in
    /// flight; subtask grouping is fused into the final merge pass). The
    /// resulting [`Prepared`] state is bitwise identical either way.
    pub fn pipeline(mut self, pipeline: Pipeline) -> Sparsify {
        self.pipeline = pipeline;
        self
    }

    /// Opt-in locality relabeling ([`Relabel`], default
    /// [`Relabel::None`]): permute vertex ids once at ingest so the
    /// pipeline's CSR walks touch memory in a cache-friendlier order on
    /// giant graphs. The pipeline then runs in permuted space;
    /// [`Recovered::sparsifier`] maps the result back to the original
    /// ids and [`Sparsifier::pcg`] evaluates in the original space, so
    /// callers never see permuted ids. On tie-free inputs (distinct
    /// effective weights and scores — ties break by edge id, which
    /// relabeling reorders) the recovered edge set and the PCG iteration
    /// count match the unrelabeled run exactly.
    pub fn relabel(mut self, relabel: Relabel) -> Sparsify {
        self.relabel = relabel;
        self
    }

    /// Convenience for [`Sparsify::pipeline`]`(Pipeline::Streamed)` +
    /// [`Sparsify::prepare`]: run steps 1–3 as the streamed overlap
    /// pipeline.
    pub fn prepare_streamed(self) -> Result<Prepared> {
        self.pipeline(Pipeline::Streamed).prepare()
    }

    /// Deterministic content hash of the session graph
    /// ([`graph::fingerprint`]), available *before* [`Sparsify::prepare`]
    /// — so a caller can probe a snapshot cache (and skip steps 1–3
    /// entirely via [`Prepared::load`]) before committing to a full
    /// prepare. Equal to [`Prepared::original_fingerprint`] of the
    /// prepared state — and to [`Prepared::fingerprint`] unless the
    /// session relabels, in which case the prepared state is keyed by
    /// the permuted working graph.
    pub fn fingerprint(&self) -> u64 {
        graph::fingerprint(&self.graph)
    }

    /// Run steps 1–3 once: spanning tree on effective weights, resistance
    /// scoring of every off-tree edge, score sort, LCA subtask grouping.
    /// The worker pool is warmed before any timed stage.
    ///
    /// Under [`Pipeline::Streamed`] the stages overlap instead of
    /// barrier-syncing (see [`Sparsify::pipeline`]); `prep_ms` then
    /// reports the fused annotate+sort stage in its first entry and zero
    /// for the sort entry, since no separate sort stage exists.
    pub fn prepare(mut self) -> Result<Prepared> {
        if self.graph.num_vertices() == 0 || self.graph.num_edges() == 0 {
            return Err(Error::BadParam {
                name: "graph",
                why: "graph has no vertices or no edges".into(),
            });
        }
        let (_, components) = graph::components(&self.graph);
        if components != 1 {
            return Err(Error::Disconnected { components });
        }
        // Warm the persistent pool outside the timed stages.
        crate::par::ThreadPool::global();

        // Opt-in locality relabeling: swap the working graph for its
        // permuted twin once, here; everything downstream runs in the
        // permuted id space (see `graph::relabel` for the contract).
        let original = match graph::relabel_perm(&self.graph, self.relabel) {
            Some(perm) => {
                let working = graph::apply_perm(&self.graph, &perm);
                Some((std::mem::replace(&mut self.graph, working), perm))
            }
            None => None,
        };

        if self.pipeline == Pipeline::Streamed {
            return Ok(self.prepare_streamed_impl(original));
        }
        let t = Timer::start();
        let spanning = build_spanning(&self.graph);
        let spanning_ms = t.ms();

        let t = Timer::start();
        let mut off = off_tree_edges(&self.graph, &spanning);
        let resistance_ms = t.ms();

        let t = Timer::start();
        sort_by_score(&mut off, self.threads);
        let sort_ms = t.ms();

        let t = Timer::start();
        let subtasks = make_subtasks(&off);
        let subtask_ms = t.ms();

        PREPARE_COUNT.fetch_add(1, Ordering::Relaxed);
        let fingerprint = graph::fingerprint(&self.graph);
        Ok(Prepared {
            id: NEXT_PREPARED_ID.fetch_add(1, Ordering::Relaxed),
            name: self.name,
            fingerprint,
            graph: self.graph,
            spanning,
            off,
            subtasks,
            pipeline: Pipeline::Barrier,
            threads: self.threads,
            relabel: self.relabel,
            original,
            spanning_ms,
            prep_ms: [resistance_ms, sort_ms, subtask_ms],
        })
    }

    /// The streamed prepare body (graph already validated): every stage
    /// boundary is a [`crate::par::produce_stream`] handoff instead of a
    /// join —
    ///
    /// * effective-weight chunks merge into the Kruskal order while later
    ///   chunks are still being scored ([`build_spanning_streamed`]);
    /// * off-tree annotation chunks merge into the score sort the same
    ///   way, and the LCA subtask grouping consumes the final merge's
    ///   output as it is emitted ([`scored_sorted_streamed`] +
    ///   [`SubtaskBuilder`]);
    ///
    /// so the pool never idles at a stage boundary. Every sort key is a
    /// strict total order and every per-edge computation is pure, hence
    /// the returned state is bitwise identical to the barrier path.
    fn prepare_streamed_impl(self, original: Option<(Graph, Vec<u32>)>) -> Prepared {
        let t = Timer::start();
        let spanning = build_spanning_streamed(&self.graph, self.threads);
        let spanning_ms = t.ms();

        let t = Timer::start();
        let mut builder = SubtaskBuilder::new();
        let emit = |e: &OffTreeEdge| builder.push(e);
        let off = scored_sorted_streamed(&self.graph, &spanning, self.threads, emit);
        let fused_ms = t.ms();

        let t = Timer::start();
        let subtasks = builder.finish();
        let subtask_ms = t.ms();

        PREPARE_COUNT.fetch_add(1, Ordering::Relaxed);
        let fingerprint = graph::fingerprint(&self.graph);
        Prepared {
            id: NEXT_PREPARED_ID.fetch_add(1, Ordering::Relaxed),
            name: self.name,
            fingerprint,
            graph: self.graph,
            spanning,
            off,
            subtasks,
            pipeline: Pipeline::Streamed,
            threads: self.threads,
            relabel: self.relabel,
            original,
            spanning_ms,
            prep_ms: [fused_ms, 0.0, subtask_ms],
        }
    }
}

/// Recovery options for one [`Prepared::recover`] call — everything
/// step 4 depends on. Validated against the graph size when used.
#[derive(Clone, Copy, Debug)]
pub struct RecoverOpts {
    /// Edge-recovery ratio α: recover `⌈α|V|⌉` off-tree edges.
    pub alpha: f64,
    /// BFS step-size constant `c` (Def. 3; paper default 8).
    pub beta_cap: u32,
    /// Parallel strategy for step 4 (paper default: Mixed).
    pub strategy: Strategy,
    /// Worker threads `p`.
    pub threads: usize,
    /// Inner-parallel block size (paper sets it to `p`).
    pub block: usize,
    /// A subtask is "large" if it has ≥ this many edges (paper: 1e5)...
    pub cutoff_edges: usize,
    /// ...or covers ≥ this fraction of all off-tree edges (paper: 0.10).
    pub cutoff_frac: f64,
    /// Judge-before-Parallel optimization (Appendix C) enabled?
    pub jbp: bool,
    /// Target shard size for [`Strategy::Sharded`]: large subtasks split
    /// into `ceil(len / shard_min)` near-equal shards that speculate
    /// concurrently (default 4096; must be ≥ 1).
    pub shard_min: usize,
    /// Stage-handoff discipline for step 4: barrier-synced pass phases
    /// (default) or streamed outcome absorption. Recovered edges, stats,
    /// and traces are bitwise identical either way; see
    /// [`enum@Pipeline`].
    pub pipeline: Pipeline,
}

impl Default for RecoverOpts {
    fn default() -> RecoverOpts {
        RecoverOpts::with_threads(0.02, crate::par::num_threads())
    }
}

impl RecoverOpts {
    /// Paper-default options at `alpha`, threads from the environment.
    pub fn new(alpha: f64) -> RecoverOpts {
        RecoverOpts { alpha, ..RecoverOpts::default() }
    }

    /// Paper-default options at `alpha` with an explicit thread count.
    pub fn with_threads(alpha: f64, threads: usize) -> RecoverOpts {
        let threads = threads.max(1);
        RecoverOpts {
            alpha,
            beta_cap: 8,
            strategy: Strategy::Mixed,
            threads,
            block: threads,
            cutoff_edges: 100_000,
            cutoff_frac: 0.10,
            jbp: true,
            shard_min: 4096,
            pipeline: Pipeline::Barrier,
        }
    }

    /// Validate against a graph with `n_vertices` vertices. Returns
    /// [`Error::BadParam`] naming the offending field.
    pub fn validate(&self, n_vertices: usize) -> Result<()> {
        if !self.alpha.is_finite() || self.alpha <= 0.0 {
            return Err(Error::BadParam {
                name: "alpha",
                why: format!("must be positive and finite, got {}", self.alpha),
            });
        }
        if self.alpha * n_vertices as f64 < 1.0 {
            return Err(Error::BadParam {
                name: "alpha",
                why: format!(
                    "alpha * |V| = {:.3} < 1: the recovery budget is below one edge \
                     (|V| = {n_vertices}); raise alpha or use a larger graph",
                    self.alpha * n_vertices as f64
                ),
            });
        }
        if !self.cutoff_frac.is_finite() || self.cutoff_frac <= 0.0 || self.cutoff_frac > 1.0 {
            return Err(Error::BadParam {
                name: "cutoff_frac",
                why: format!("must lie in (0, 1], got {}", self.cutoff_frac),
            });
        }
        if self.block == 0 {
            return Err(Error::BadParam { name: "block", why: "must be at least 1".into() });
        }
        if self.threads == 0 {
            return Err(Error::BadParam { name: "threads", why: "must be at least 1".into() });
        }
        if self.shard_min == 0 {
            return Err(Error::BadParam { name: "shard_min", why: "must be at least 1".into() });
        }
        Ok(())
    }

    /// The equivalent low-level [`recovery::Params`].
    pub fn params(&self) -> Params {
        Params {
            alpha: self.alpha,
            beta_cap: self.beta_cap,
            strategy: self.strategy,
            threads: self.threads,
            block: self.block,
            cutoff_edges: self.cutoff_edges,
            cutoff_frac: self.cutoff_frac,
            jbp: self.jbp,
            shard_min: self.shard_min,
            pipeline: self.pipeline,
        }
    }
}

/// Steps 1–3 of Algorithm 1, computed once: the graph, its spanning tree,
/// and the scored, score-sorted off-tree edge list grouped into LCA
/// subtasks. `Sync` — recover from as many threads as you like.
#[derive(Debug)]
pub struct Prepared {
    id: u64,
    name: Option<String>,
    /// Deterministic content hash of the graph ([`graph::fingerprint`]):
    /// the serving layer's cache key. Unlike `id`, equal graphs get equal
    /// fingerprints across processes, platforms, and time.
    fingerprint: u64,
    graph: Graph,
    spanning: Spanning,
    /// Off-tree edges, score-sorted descending (step 2's output).
    off: Vec<OffTreeEdge>,
    /// LCA subtasks over `off`, size-sorted descending (step 3's output).
    subtasks: Vec<Subtask>,
    /// Discipline the preparation ran under (the state itself is bitwise
    /// identical either way; step 4's discipline is chosen per recovery
    /// via [`RecoverOpts::pipeline`]).
    pipeline: Pipeline,
    /// Session thread count ([`Sparsify::threads`]) — carried through to
    /// [`Sparsifier::pcg`], which dispatches the evaluation across this
    /// many pool workers (bitwise identical results at any count).
    threads: usize,
    /// Relabel mode the session ran under ([`Sparsify::relabel`]).
    relabel: Relabel,
    /// Original-space state when relabeled: the ingest graph and the
    /// permutation (`perm[new] = old`). `None` under [`Relabel::None`],
    /// where `graph` *is* the original.
    original: Option<(Graph, Vec<u32>)>,
    spanning_ms: f64,
    /// Wall-clock of [resistance annotation, sort, subtask grouping], ms.
    /// Under the streamed pipeline the first entry is the fused
    /// annotate+sort stage and the second is zero.
    prep_ms: [f64; 3],
}

impl Prepared {
    /// Unique id of this prepared state (instrumentation: sweeps sharing
    /// one `Prepared` produce reports with equal ids).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Session label, if any.
    pub fn name(&self) -> Option<&str> {
        self.name.as_deref()
    }

    /// Deterministic content hash of the session graph
    /// ([`graph::fingerprint`]) — byte-stable across platforms and
    /// processes, so it can key a cross-process cache of prepared state
    /// (the serve daemon's `Prepared` cache keys on exactly this).
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// The session's working graph — the ingest graph under
    /// [`Relabel::None`], its id-permuted twin otherwise (see
    /// [`Prepared::original_graph`] for the ingest-space view).
    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// The relabel mode the session ran under.
    pub fn relabel(&self) -> Relabel {
        self.relabel
    }

    /// The relabel permutation (`perm[new] = old`), when one is active.
    pub fn perm(&self) -> Option<&[u32]> {
        self.original.as_ref().map(|(_, p)| p.as_slice())
    }

    /// The graph in its original (ingest) vertex ids — identical to
    /// [`Prepared::graph`] unless the session relabels. PCG evaluation
    /// and exported sparsifiers live in this space.
    pub fn original_graph(&self) -> &Graph {
        match &self.original {
            Some((g, _)) => g,
            None => &self.graph,
        }
    }

    /// [`graph::fingerprint`] of [`Prepared::original_graph`] — equal to
    /// [`Prepared::fingerprint`] unless the session relabels. Relabeled
    /// sessions thus report both hashes: the working (permuted) one keys
    /// prepared-state caches, this one identifies the ingest graph.
    pub fn original_fingerprint(&self) -> u64 {
        match &self.original {
            Some((g, _)) => graph::fingerprint(g),
            None => self.fingerprint,
        }
    }

    /// The spanning tree (shared by every recovery from this session).
    pub fn spanning(&self) -> &Spanning {
        &self.spanning
    }

    /// Number of off-tree edges available for recovery.
    pub fn num_off_tree(&self) -> usize {
        self.off.len()
    }

    /// The score-sorted off-tree edge list (step 2's output) — exposed so
    /// equivalence tests and diagnostics can compare prepared state
    /// bitwise across pipelines.
    pub fn off_tree(&self) -> &[OffTreeEdge] {
        &self.off
    }

    /// The LCA subtasks over [`Prepared::off_tree`] (step 3's output),
    /// size-sorted descending.
    pub fn subtasks(&self) -> &[Subtask] {
        &self.subtasks
    }

    /// The stage-handoff discipline this state was prepared under.
    pub fn pipeline(&self) -> Pipeline {
        self.pipeline
    }

    /// The session's thread count ([`Sparsify::threads`]), used by
    /// [`Sparsifier::pcg`] evaluations from this session.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Wall-clock of the spanning-tree build, ms.
    pub fn spanning_ms(&self) -> f64 {
        self.spanning_ms
    }

    /// Wall-clock of [resistance annotation, sort, subtask grouping], ms
    /// — the steps every recovery from this session amortizes.
    pub fn prep_ms(&self) -> [f64; 3] {
        self.prep_ms
    }

    /// Step 4 only: pdGRASS strict-similarity recovery over the cached
    /// subtasks. Callable repeatedly (and concurrently) with any options.
    pub fn recover(&self, opts: &RecoverOpts) -> Result<Recovered<'_>> {
        self.recover_impl(opts, false)
    }

    /// As [`Prepared::recover`], additionally capturing the per-edge cost
    /// trace consumed by the scheduling simulator.
    pub fn recover_traced(&self, opts: &RecoverOpts) -> Result<Recovered<'_>> {
        self.recover_impl(opts, true)
    }

    fn recover_impl(&self, opts: &RecoverOpts, trace: bool) -> Result<Recovered<'_>> {
        opts.validate(self.graph.num_vertices())?;
        let params = opts.params();
        let mut rec = recovery::pdgrass::recover_sorted(
            self.graph.num_vertices(),
            &self.off,
            &self.subtasks,
            &self.spanning,
            &params,
            trace,
        );
        rec.step_ms = [self.prep_ms[0], self.prep_ms[1], self.prep_ms[2], rec.step_ms[3]];
        RECOVER_COUNT.fetch_add(1, Ordering::Relaxed);
        Ok(Recovered { prepared: self, rec })
    }

    /// Reassemble a `Prepared` from snapshot-decoded parts (the
    /// validated output of `snapshot::from_bytes`). Gets a fresh session
    /// id and the environment's thread count; timings are zeroed —
    /// they are execution history, not prepared state. Does *not* bump
    /// [`prepare_count`]: no steps 1–3 were paid, which is exactly what
    /// warm-start tests assert.
    pub(crate) fn from_snapshot_parts(
        name: Option<String>,
        graph: Graph,
        spanning: Spanning,
        off: Vec<OffTreeEdge>,
        subtasks: Vec<Subtask>,
        pipeline: Pipeline,
        relabel: Relabel,
        perm: Option<Vec<u32>>,
    ) -> Prepared {
        let fingerprint = graph::fingerprint(&graph);
        // The original graph is not serialized: it is exactly the working
        // graph with its endpoints mapped back through the permutation
        // (weights untouched, CSR canonical), so rebuild it here.
        let original = perm.map(|p| (graph::unapply_perm(&graph, &p), p));
        Prepared {
            id: NEXT_PREPARED_ID.fetch_add(1, Ordering::Relaxed),
            name,
            fingerprint,
            graph,
            spanning,
            off,
            subtasks,
            pipeline,
            threads: crate::par::num_threads(),
            relabel,
            original,
            spanning_ms: 0.0,
            prep_ms: [0.0; 3],
        }
    }

    /// Replace the session thread count (used by [`Sparsifier::pcg`])
    /// on a loaded snapshot — thread count is an execution parameter,
    /// not serialized state, so the serve daemon re-applies its resolved
    /// count after a warm load. Results are bitwise identical at every
    /// count; this only affects scheduling.
    pub fn with_threads(mut self, threads: usize) -> Prepared {
        self.threads = threads.max(1);
        self
    }

    /// Serialize this prepared state into the versioned, checksummed
    /// snapshot container (see [`crate::snapshot`] for the format).
    pub fn to_snapshot_bytes(&self) -> Vec<u8> {
        crate::snapshot::to_bytes(self)
    }

    /// Deserialize and fully validate a snapshot produced by
    /// [`Prepared::to_snapshot_bytes`]. Corruption, truncation, version
    /// or fingerprint mismatch — anything not bitwise equivalent to a
    /// fresh prepare — is the typed [`Error::Snapshot`].
    pub fn from_snapshot_bytes(data: &[u8]) -> Result<Prepared> {
        crate::snapshot::from_bytes(data)
    }

    /// Persist this prepared state to `path` (atomic temp-file +
    /// rename). A later [`Prepared::load`] — in this process or any
    /// other — skips Algorithm-1 steps 1–3 entirely.
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        crate::snapshot::save(self, path)
    }

    /// Load a prepared state saved by [`Prepared::save`]. A missing file
    /// is [`Error::Io`]; an invalid one is [`Error::Snapshot`]. The
    /// loaded state recovers and evaluates bitwise identically to the
    /// `Prepared` that was saved.
    pub fn load(path: &std::path::Path) -> Result<Prepared> {
        crate::snapshot::load(path)
    }

    /// feGRASS baseline (loose similarity, serial, multi-pass) over the
    /// same cached scored edge list — so quality comparisons are
    /// apples-to-apples with [`Prepared::recover`].
    pub fn fegrass(&self, opts: &RecoverOpts) -> Result<Recovered<'_>> {
        opts.validate(self.graph.num_vertices())?;
        let params = opts.params();
        let rec = recovery::fegrass::fegrass_sorted(
            self.graph.num_vertices(),
            &self.off,
            &self.spanning,
            &params,
        );
        Ok(Recovered { prepared: self, rec })
    }
}

/// The outcome of one recovery (step 4) against a [`Prepared`] session.
#[derive(Debug)]
pub struct Recovered<'p> {
    prepared: &'p Prepared,
    rec: recovery::Recovery,
}

impl<'p> Recovered<'p> {
    /// Recovered off-tree edge ids (graph edge ids), best-score-first.
    pub fn edges(&self) -> &[u32] {
        &self.rec.edges
    }

    /// Passes over the off-tree edge list (pdGRASS: expected 1).
    pub fn passes(&self) -> usize {
        self.rec.passes
    }

    /// Instrumentation counters.
    pub fn stats(&self) -> &Stats {
        &self.rec.stats
    }

    /// Per-edge cost trace (present after [`Prepared::recover_traced`]).
    pub fn trace(&self) -> Option<&CostTrace> {
        self.rec.trace.as_ref()
    }

    /// Per-step wall-clock, ms: `[resistance, sort, subtasks, recovery]`.
    /// The first three are the shared preparation timings; only the
    /// fourth was paid by this call. (All zero for the feGRASS baseline,
    /// which has no step structure.)
    pub fn step_ms(&self) -> [f64; 4] {
        self.rec.step_ms
    }

    /// The underlying low-level [`recovery::Recovery`].
    pub fn recovery(&self) -> &recovery::Recovery {
        &self.rec
    }

    /// Assemble the sparsifier handle: spanning tree + recovered edges,
    /// `|V| − 1 + ⌈α|V|⌉` edges as in §II.B. Always expressed in the
    /// original (ingest) vertex ids: under an active relabel the
    /// permuted-space sparsifier's endpoints are mapped back through the
    /// permutation (weights untouched), so exports and PCG evaluation
    /// never see permuted ids.
    pub fn sparsifier(&self) -> Sparsifier<'p> {
        let p = recovery::sparsifier(&self.prepared.graph, &self.prepared.spanning, &self.rec.edges);
        let p = match &self.prepared.original {
            Some((_, perm)) => graph::unapply_perm(&p, perm),
            None => p,
        };
        Sparsifier { prepared: self.prepared, sparsifier: p }
    }
}

/// A sparsifier `P` of the session graph `G`, ready for evaluation or
/// export.
#[derive(Debug)]
pub struct Sparsifier<'p> {
    prepared: &'p Prepared,
    sparsifier: Graph,
}

impl Sparsifier<'_> {
    /// The sparsifier graph itself.
    pub fn graph(&self) -> &Graph {
        &self.sparsifier
    }

    /// Edge count of the sparsifier.
    pub fn num_edges(&self) -> usize {
        self.sparsifier.num_edges()
    }

    /// The paper's quality metric: solve `L_G x = b` by PCG with this
    /// sparsifier as the preconditioner, `b` drawn deterministically from
    /// `rhs_seed`. The iteration — SpMV, reductions, and the
    /// preconditioner's level-scheduled triangular solves — runs across
    /// the session's thread count ([`Sparsify::threads`]); results are
    /// bitwise identical at every count, so histories and golden rows do
    /// not depend on it. Non-convergence is reported in the outcome (use
    /// [`PcgOutcome::require_converged`] to turn it into a typed error);
    /// a factorization breakdown is [`Error::NotPositiveDefinite`].
    pub fn pcg(&self, rhs_seed: u64, tol: f64, maxit: usize) -> Result<PcgOutcome> {
        if !tol.is_finite() || tol <= 0.0 {
            return Err(Error::BadParam {
                name: "tol",
                why: format!("must be positive and finite, got {tol}"),
            });
        }
        if maxit == 0 {
            return Err(Error::BadParam { name: "maxit", why: "must be at least 1".into() });
        }
        // Always evaluate in the original id space: floating point is
        // not permutation-invariant, so relabeled sessions must ground
        // and seed PCG exactly like unrelabeled ones to keep residual
        // histories comparable (the sparsifier is already mapped back).
        let res = crate::solver::pcg_eval_par(
            self.prepared.original_graph(),
            &self.sparsifier,
            rhs_seed,
            tol,
            maxit,
            self.prepared.threads,
        )?;
        Ok(PcgOutcome {
            iterations: res.iterations,
            relres: res.relres,
            converged: res.converged,
            history: res.history,
        })
    }

    /// Write the sparsifier as `coordinate real symmetric` MatrixMarket.
    pub fn write_mtx(&self, path: &std::path::Path) -> Result<()> {
        graph::write_mtx(&self.sparsifier, path)?;
        Ok(())
    }
}

/// Result of a [`Sparsifier::pcg`] evaluation.
#[derive(Clone, Debug)]
pub struct PcgOutcome {
    /// Iterations performed.
    pub iterations: usize,
    /// Final relative residual `‖r‖/‖b‖`.
    pub relres: f64,
    /// True iff the tolerance was met within the iteration cap.
    pub converged: bool,
    /// Relative residual after each iteration (for convergence plots).
    pub history: Vec<f64>,
}

impl PcgOutcome {
    /// Promote non-convergence to the typed [`Error::NoConvergence`].
    pub fn require_converged(self) -> Result<PcgOutcome> {
        if self.converged {
            Ok(self)
        } else {
            Err(Error::NoConvergence { iters: self.iterations, residual: self.relres })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn prepared_is_sync_and_send() {
        fn assert_bounds<T: Sync + Send>() {}
        assert_bounds::<Prepared>();
    }

    fn badparam_name(err: Error) -> &'static str {
        match err {
            Error::BadParam { name, .. } => name,
            other => panic!("expected BadParam, got {other:?}"),
        }
    }

    #[test]
    fn rejects_nonpositive_alpha() {
        for alpha in [0.0, -0.5, f64::NAN, f64::INFINITY] {
            let err = RecoverOpts::new(alpha).validate(1000).unwrap_err();
            assert_eq!(badparam_name(err), "alpha", "alpha={alpha}");
        }
    }

    #[test]
    fn rejects_alpha_below_one_recovered_edge() {
        // α·|V| = 0.5 < 1 → nothing would be recovered.
        let err = RecoverOpts::new(0.005).validate(100).unwrap_err();
        assert_eq!(badparam_name(err), "alpha");
        // …but exactly one edge is fine.
        RecoverOpts::new(0.01).validate(100).unwrap();
    }

    #[test]
    fn rejects_cutoff_frac_outside_unit_interval() {
        for frac in [0.0, -0.1, 1.5, f64::NAN] {
            let opts = RecoverOpts { cutoff_frac: frac, ..RecoverOpts::new(0.05) };
            let err = opts.validate(1000).unwrap_err();
            assert_eq!(badparam_name(err), "cutoff_frac", "frac={frac}");
        }
        // The boundary 1.0 is inclusive.
        RecoverOpts { cutoff_frac: 1.0, ..RecoverOpts::new(0.05) }.validate(1000).unwrap();
    }

    #[test]
    fn rejects_zero_block() {
        let opts = RecoverOpts { block: 0, ..RecoverOpts::new(0.05) };
        assert_eq!(badparam_name(opts.validate(1000).unwrap_err()), "block");
    }

    #[test]
    fn rejects_zero_threads() {
        let opts = RecoverOpts { threads: 0, ..RecoverOpts::new(0.05) };
        assert_eq!(badparam_name(opts.validate(1000).unwrap_err()), "threads");
    }

    #[test]
    fn rejects_zero_shard_min() {
        let opts = RecoverOpts { shard_min: 0, ..RecoverOpts::new(0.05) };
        assert_eq!(badparam_name(opts.validate(1000).unwrap_err()), "shard_min");
        // …and the boundary 1 (one shard per edge) is valid.
        RecoverOpts { shard_min: 1, ..RecoverOpts::new(0.05) }.validate(1000).unwrap();
    }

    #[test]
    fn shard_min_reaches_recovery_params() {
        let opts = RecoverOpts { shard_min: 7, ..RecoverOpts::new(0.05) };
        assert_eq!(opts.params().shard_min, 7);
    }

    #[test]
    fn pipeline_reaches_recovery_params() {
        let opts = RecoverOpts::new(0.05);
        assert_eq!(opts.pipeline, Pipeline::Barrier);
        assert_eq!(opts.params().pipeline, Pipeline::Barrier);
        let opts = RecoverOpts { pipeline: Pipeline::Streamed, ..RecoverOpts::new(0.05) };
        assert_eq!(opts.params().pipeline, Pipeline::Streamed);
    }

    #[test]
    fn prepare_streamed_smoke_and_tagging() {
        let g = crate::gen::grid(12, 12, 0.5, &mut Rng::new(3));
        let barrier = Sparsify::graph(g.clone()).prepare().unwrap();
        assert_eq!(barrier.pipeline(), Pipeline::Barrier);
        let streamed = Sparsify::graph(g).prepare_streamed().unwrap();
        assert_eq!(streamed.pipeline(), Pipeline::Streamed);
        assert_eq!(streamed.num_off_tree(), barrier.num_off_tree());
        assert_eq!(streamed.subtasks().len(), barrier.subtasks().len());
        // Streamed prep_ms convention: no separate sort stage.
        assert_eq!(streamed.prep_ms()[1], 0.0);
        let r = streamed.recover(&RecoverOpts::new(0.05)).unwrap();
        assert!(!r.edges().is_empty());
    }

    #[test]
    fn fingerprint_is_content_keyed_unlike_id() {
        let g = crate::gen::grid(10, 10, 0.5, &mut Rng::new(1));
        let a = Sparsify::graph(g.clone()).prepare().unwrap();
        let b = Sparsify::graph(g).prepare_streamed().unwrap();
        // Same graph → same fingerprint, even across pipelines…
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_eq!(a.fingerprint(), crate::graph::fingerprint(a.graph()));
        // …but distinct session ids.
        assert_ne!(a.id(), b.id());
        let other = crate::gen::grid(10, 10, 0.5, &mut Rng::new(2));
        let c = Sparsify::graph(other).prepare().unwrap();
        assert_ne!(c.fingerprint(), a.fingerprint());
    }

    #[test]
    fn relabel_none_is_bitwise_inert() {
        let g = crate::gen::grid(10, 10, 0.5, &mut Rng::new(6));
        let plain = Sparsify::graph(g.clone()).prepare().unwrap();
        let none = Sparsify::graph(g).relabel(Relabel::None).prepare().unwrap();
        assert_eq!(none.relabel(), Relabel::None);
        assert!(none.perm().is_none());
        assert_eq!(none.fingerprint(), plain.fingerprint());
        assert_eq!(none.original_fingerprint(), none.fingerprint());
        assert_eq!(
            crate::graph::fingerprint(none.original_graph()),
            crate::graph::fingerprint(none.graph())
        );
    }

    #[test]
    fn relabeled_session_reports_both_fingerprints() {
        let g = crate::gen::community(
            crate::gen::CommunityParams {
                n: 300,
                mean_size: 9.0,
                tail: 1.7,
                intra_p: 0.5,
                bridges: 2,
                max_size: 50,
            },
            &mut Rng::new(6),
        );
        let input_fp = crate::graph::fingerprint(&g);
        for mode in [Relabel::Bfs, Relabel::Degree] {
            let p = Sparsify::graph(g.clone()).relabel(mode).prepare().unwrap();
            assert_eq!(p.relabel(), mode);
            // The ingest graph is identified by its original fingerprint…
            assert_eq!(p.original_fingerprint(), input_fp);
            // …while the working (permuted) graph keys the prepared state.
            assert_eq!(p.fingerprint(), crate::graph::fingerprint(p.graph()));
            let perm = p.perm().expect("relabeled session must expose its perm");
            crate::graph::validate_perm(perm, p.graph().num_vertices()).unwrap();
        }
    }

    #[test]
    fn recover_rejects_before_doing_work() {
        let g = crate::gen::grid(10, 10, 0.5, &mut Rng::new(1));
        let prepared = Sparsify::graph(g).prepare().unwrap();
        let err = prepared.recover(&RecoverOpts::new(-1.0)).unwrap_err();
        assert_eq!(badparam_name(err), "alpha");
    }

    #[test]
    fn unknown_suite_graph_is_typed() {
        match Sparsify::suite("not-a-row", 1.0, 1) {
            Err(Error::UnknownGraph { name }) => assert_eq!(name, "not-a-row"),
            other => panic!("expected UnknownGraph, got {other:?}"),
        }
        match Sparsify::suite("15-M6", -1.0, 1) {
            Err(Error::BadParam { name, .. }) => assert_eq!(name, "scale"),
            other => panic!("expected BadParam, got {other:?}"),
        }
    }

    #[test]
    fn disconnected_graph_is_typed() {
        let g = Graph::from_edges(4, &[(0, 1, 1.0), (2, 3, 1.0)]);
        match Sparsify::graph(g).prepare() {
            Err(Error::Disconnected { components }) => assert_eq!(components, 2),
            other => panic!("expected Disconnected, got {other:?}"),
        }
    }

    #[test]
    fn pcg_outcome_promotes_nonconvergence() {
        let ok = PcgOutcome { iterations: 3, relres: 1e-5, converged: true, history: vec![] };
        assert_eq!(ok.require_converged().unwrap().iterations, 3);
        let bad = PcgOutcome { iterations: 7, relres: 0.2, converged: false, history: vec![] };
        match bad.require_converged() {
            Err(Error::NoConvergence { iters, residual }) => {
                assert_eq!(iters, 7);
                assert!((residual - 0.2).abs() < 1e-12);
            }
            other => panic!("expected NoConvergence, got {other:?}"),
        }
    }

    #[test]
    fn pcg_rejects_bad_tol_and_maxit() {
        let g = crate::gen::grid(8, 8, 0.5, &mut Rng::new(2));
        let prepared = Sparsify::graph(g).prepare().unwrap();
        let r = prepared.recover(&RecoverOpts::new(0.05)).unwrap();
        let p = r.sparsifier();
        assert_eq!(badparam_name(p.pcg(1, 0.0, 100).unwrap_err()), "tol");
        assert_eq!(badparam_name(p.pcg(1, 1e-3, 0).unwrap_err()), "maxit");
    }
}
