//! `pdgrass audit` — a self-contained static-analysis pass over the
//! crate's own sources.
//!
//! The repo's core claim is bitwise-identical sparsifiers and PCG
//! histories across strategies, pipelines, and thread counts. That
//! property rests on a handful of structural invariants in the parallel
//! substrate (fixed reduction trees, pool-only threading, reviewed
//! atomic orderings, no randomized iteration in the algorithm modules).
//! Example-based tests can only sample those invariants; this module
//! checks them on every build, with zero dependencies beyond std (a
//! hand-rolled lexer, consistent with the offline `vendor/` policy —
//! see [`lexer`]).
//!
//! Submodules: [`lexer`] (tokens), [`context`] (enclosing items +
//! `#[cfg(test)]` regions), [`rules`] (the checks), [`allow`] (the
//! atomics allowlist). Entry points: [`run_audit`] for a directory
//! tree, [`audit_sources`] for in-memory sources (fixtures, tests).
//!
//! The dynamic counterpart is [`crate::par::chaos`]: the audit proves
//! the invariants are *stated*, the chaos harness perturbs schedules to
//! check the determinism they *imply*.

pub mod allow;
pub mod context;
pub mod lexer;
pub mod rules;

pub use allow::{AllowEntry, Allowlist};
pub use rules::{AuditConfig, Violation};

use crate::config::Doc;
use crate::error::{Error, Result};
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

/// Outcome of one audit run.
#[derive(Debug)]
pub struct AuditReport {
    /// Number of `.rs` files scanned.
    pub files: usize,
    /// All violations, ordered by (file, line).
    pub violations: Vec<Violation>,
    /// Allowlist entries never matched by the scan (warnings: stale
    /// entries rot the review record but don't fail the build).
    pub unused_allow: Vec<String>,
    /// Total allowlist entries consulted.
    pub allow_entries: usize,
}

impl AuditReport {
    /// True when the audit found no violations.
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }

    /// Human-readable report: one line per violation, warnings for
    /// stale allowlist entries, and a one-line summary.
    pub fn render(&self) -> String {
        let mut s = String::new();
        for v in &self.violations {
            let _ = writeln!(s, "{}:{}: [{}] {}", v.file, v.line, v.rule, v.msg);
        }
        for u in &self.unused_allow {
            let _ = writeln!(s, "warning: unused allowlist entry: {u}");
        }
        let _ = writeln!(
            s,
            "audit: {} file(s) scanned, {} violation(s), {} allowlist entr{} ({} unused)",
            self.files,
            self.violations.len(),
            self.allow_entries,
            if self.allow_entries == 1 { "y" } else { "ies" },
            self.unused_allow.len()
        );
        s
    }
}

/// Audit in-memory sources: `(relative path, contents)` pairs. This is
/// the pure core — [`run_audit`] is a thin filesystem wrapper, and the
/// fixture tests call this directly.
pub fn audit_sources(
    sources: &[(String, String)],
    allow: &Allowlist,
    cfg: &AuditConfig,
) -> AuditReport {
    let mut violations = Vec::new();
    let mut used = vec![false; allow.entries().len()];
    for (rel, text) in sources {
        let tokens = lexer::lex(text);
        rules::audit_tokens(rel, &tokens, cfg, allow, &mut used, &mut violations);
    }
    violations.sort_by(|a, b| (a.file.as_str(), a.line).cmp(&(b.file.as_str(), b.line)));
    let unused_allow = allow
        .entries()
        .iter()
        .zip(&used)
        .filter(|(_, &u)| !u)
        .map(|(e, _)| {
            format!("{} | {} | {} (allowlist line {})", e.file, e.item, e.ordering, e.line)
        })
        .collect();
    AuditReport {
        files: sources.len(),
        violations,
        unused_allow,
        allow_entries: allow.entries().len(),
    }
}

/// Collect `.rs` files under `root` (sorted for deterministic reports),
/// load the allowlist, and audit the tree with `cfg`.
pub fn run_audit_with(root: &Path, allow_path: &Path, cfg: &AuditConfig) -> Result<AuditReport> {
    let allow = Allowlist::load(allow_path)?;
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut sources = Vec::with_capacity(files.len());
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("cannot read {}: {e}", path.display())))?;
        sources.push((rel, text));
    }
    Ok(audit_sources(&sources, &allow, cfg))
}

/// [`run_audit_with`] under the repo's default [`AuditConfig`].
pub fn run_audit(root: &Path, allow_path: &Path) -> Result<AuditReport> {
    run_audit_with(root, allow_path, &AuditConfig::default())
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| Error::Config(format!("cannot read dir {}: {e}", dir.display())))?;
    for entry in entries {
        let entry = entry.map_err(Error::Io)?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Paths for an audit run, resolvable from a config file's `[audit]`
/// section (`audit.root`, `audit.allowlist`) with CLI flags taking
/// precedence. Defaults match the repository layout.
#[derive(Clone, Debug)]
pub struct AuditOptions {
    /// Directory tree to scan.
    pub root: String,
    /// Allowlist file.
    pub allowlist: String,
}

impl Default for AuditOptions {
    fn default() -> Self {
        AuditOptions { root: "rust/src".into(), allowlist: "rust/analysis/atomics.allow".into() }
    }
}

impl AuditOptions {
    /// Read `audit.*` keys from a parsed config [`Doc`], rejecting
    /// unknown ones (same typo-catching policy as `RunConfig`).
    pub fn from_doc(doc: &Doc) -> Result<AuditOptions> {
        let known = ["audit.root", "audit.allowlist"];
        for key in doc.keys() {
            if key.starts_with("audit.") && !known.contains(&key) {
                return Err(Error::Config(format!("unknown config key: {key}")));
            }
        }
        let mut opts = AuditOptions::default();
        if let Some(v) = doc.get("audit.root") {
            opts.root = v
                .as_str()
                .ok_or_else(|| Error::Config("audit.root must be a string".into()))?
                .to_string();
        }
        if let Some(v) = doc.get("audit.allowlist") {
            opts.allowlist = v
                .as_str()
                .ok_or_else(|| Error::Config("audit.allowlist must be a string".into()))?
                .to_string();
        }
        Ok(opts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders_and_sorts() {
        let allow = Allowlist::parse("b.rs | f | Relaxed | why\n", "t").unwrap();
        let sources = vec![
            ("b.rs".to_string(), "fn g() { unsafe { x() } }".to_string()),
            ("a.rs".to_string(), "fn h() { unsafe { y() } }".to_string()),
        ];
        let cfg = AuditConfig::default();
        let report = audit_sources(&sources, &allow, &cfg);
        assert!(!report.ok());
        assert_eq!(report.violations.len(), 2);
        // sorted by file despite input order
        assert_eq!(report.violations[0].file, "a.rs");
        assert_eq!(report.unused_allow.len(), 1);
        let text = report.render();
        assert!(text.contains("a.rs:1: [safety-comment]"), "{text}");
        assert!(text.contains("unused allowlist entry"), "{text}");
        assert!(text.contains("2 violation(s)"), "{text}");
    }

    #[test]
    fn unused_allowlist_entries_warn_but_do_not_fail() {
        let allow = Allowlist::parse("gone.rs | old | SeqCst | obsolete\n", "t").unwrap();
        let report = audit_sources(&[], &allow, &AuditConfig::default());
        assert!(report.ok());
        assert_eq!(report.unused_allow.len(), 1);
    }

    #[test]
    fn audit_options_from_doc() {
        let doc = Doc::parse("[audit]\nroot = \"src\"\nallowlist = \"a.allow\"\n").unwrap();
        let opts = AuditOptions::from_doc(&doc).unwrap();
        assert_eq!(opts.root, "src");
        assert_eq!(opts.allowlist, "a.allow");
        let bad = Doc::parse("[audit]\nroots = \"src\"\n").unwrap();
        assert!(AuditOptions::from_doc(&bad).is_err());
        let empty = Doc::parse("[run]\nname = \"x\"\n").unwrap();
        let d = AuditOptions::from_doc(&empty).unwrap();
        assert_eq!(d.root, "rust/src");
    }
}
