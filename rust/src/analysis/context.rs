//! Structural context over the token stream: which item encloses each
//! token, and which token ranges sit under `#[cfg(test)]`.
//!
//! The atomics allowlist is keyed by `file | enclosing item | ordering`,
//! so the audit needs a "what item am I in" answer per token. A full
//! parse is overkill; a brace-matching pass that remembers the names
//! introduced by `fn`/`impl`/`mod`/`struct`/`enum`/`trait`/`union`
//! headers is enough for this codebase, with four deliberate guards:
//!
//! * `fn` only opens a pending item when followed by an identifier —
//!   `fn(usize)` pointer *types* in signatures do not;
//! * a pending item is cancelled by `;` before its `{` — tuple structs
//!   (`struct Abort<'a, T>(&'a Stream<T>);`) and trait method
//!   signatures never get a body;
//! * `impl` only opens an impl header when no item is pending —
//!   `-> impl Fn(…)` return types inside a signature do not;
//! * impl-header name collection stops at `where` — bounds like
//!   `where F: Fn(&T, &T) -> Ordering` would otherwise corrupt the
//!   angle-bracket depth (the `->`'s `>`) and steal the name.

use super::lexer::{TokKind, Token};

/// Scope kinds that matter for key construction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ItemKind {
    Fn,
    Impl,
    Mod,
    /// struct / enum / trait / union bodies.
    Other,
}

#[derive(Clone, Debug)]
struct Named {
    kind: ItemKind,
    name: String,
}

/// Per-token enclosing-item keys plus `#[cfg(test)]` region spans.
pub struct Context {
    /// For each token index, the enclosing-item key: `"Type::fn_name"`
    /// inside an impl'd fn, `"fn_name"` inside a free fn, the type /
    /// module name inside other items, `"-"` at the top level.
    pub item_keys: Vec<String>,
    /// Token-index ranges (inclusive) covered by a `#[cfg(test)]` item.
    test_ranges: Vec<(usize, usize)>,
}

impl Context {
    /// True when token `idx` lies inside a `#[cfg(test)]` item.
    pub fn in_test(&self, idx: usize) -> bool {
        self.test_ranges.iter().any(|&(lo, hi)| lo <= idx && idx <= hi)
    }
}

/// Build the context for one file's token stream.
pub fn build(tokens: &[Token]) -> Context {
    Context { item_keys: item_keys(tokens), test_ranges: test_ranges(tokens) }
}

/// Index of the next non-comment token at or after `i`.
fn next_code(tokens: &[Token], mut i: usize) -> Option<usize> {
    while i < tokens.len() {
        if !tokens[i].is_comment() {
            return Some(i);
        }
        i += 1;
    }
    None
}

fn is_item_keyword(text: &str) -> Option<ItemKind> {
    match text {
        "fn" => Some(ItemKind::Fn),
        "mod" => Some(ItemKind::Mod),
        "struct" | "enum" | "trait" | "union" => Some(ItemKind::Other),
        _ => None,
    }
}

fn key_for(stack: &[Option<Named>]) -> String {
    // Innermost named scope decides; an fn gets qualified by the nearest
    // impl/type scope beneath it (`Unmove::drop` for a Drop impl nested
    // inside `sort_inplace`).
    for (depth, named) in stack.iter().enumerate().rev() {
        let Some(named) = named else { continue };
        if named.kind != ItemKind::Fn {
            return named.name.clone();
        }
        for below in stack[..depth].iter().rev() {
            if let Some(q) = below {
                if matches!(q.kind, ItemKind::Impl | ItemKind::Other) {
                    return format!("{}::{}", q.name, named.name);
                }
                break;
            }
        }
        return named.name.clone();
    }
    "-".to_string()
}

fn item_keys(tokens: &[Token]) -> Vec<String> {
    let mut keys = Vec::with_capacity(tokens.len());
    let mut stack: Vec<Option<Named>> = Vec::new();
    let mut pending: Option<Named> = None;
    // impl-header state
    let mut in_impl_header = false;
    let mut impl_candidate: Option<String> = None;
    let mut impl_angle = 0i32;
    let mut impl_seen_where = false;
    let mut current = key_for(&stack);

    let mut i = 0;
    while i < tokens.len() {
        keys.push(current.clone());
        let t = &tokens[i];
        if t.is_comment() {
            i += 1;
            continue;
        }
        if in_impl_header {
            match t.kind {
                TokKind::Ident if !impl_seen_where => match t.text.as_str() {
                    "for" => impl_candidate = None,
                    "where" => impl_seen_where = true,
                    "dyn" | "unsafe" | "pub" | "crate" | "self" | "super" => {}
                    name if impl_angle == 0 => impl_candidate = Some(name.to_string()),
                    _ => {}
                },
                TokKind::Punct if !impl_seen_where => match t.text.as_str() {
                    "<" => impl_angle += 1,
                    // `->` in a bound is not a generic close; plain `>` is.
                    ">" if i > 0 && tokens[i - 1].text == "-" => {}
                    ">" => impl_angle = (impl_angle - 1).max(0),
                    _ => {}
                },
                _ => {}
            }
            if t.kind == TokKind::Punct && t.text == "{" {
                let name = impl_candidate.take().unwrap_or_else(|| "impl".to_string());
                stack.push(Some(Named { kind: ItemKind::Impl, name }));
                in_impl_header = false;
                current = key_for(&stack);
            } else if t.kind == TokKind::Punct && t.text == ";" {
                in_impl_header = false;
                impl_candidate = None;
            }
            i += 1;
            continue;
        }
        match t.kind {
            TokKind::Ident => {
                if let Some(kind) = is_item_keyword(&t.text) {
                    if pending.is_none() {
                        if let Some(j) = next_code(tokens, i + 1) {
                            if tokens[j].kind == TokKind::Ident {
                                pending =
                                    Some(Named { kind, name: tokens[j].text.clone() });
                            }
                        }
                    }
                } else if t.text == "impl" && pending.is_none() {
                    in_impl_header = true;
                    impl_candidate = None;
                    impl_angle = 0;
                    impl_seen_where = false;
                }
            }
            TokKind::Punct => match t.text.as_str() {
                "{" => {
                    stack.push(pending.take());
                    current = key_for(&stack);
                }
                "}" => {
                    stack.pop();
                    current = key_for(&stack);
                }
                ";" => pending = None,
                _ => {}
            },
            _ => {}
        }
        i += 1;
    }
    keys
}

/// Find `#[cfg(test)]` attributes and the token span of the item each
/// one gates (to the matching `}` of the item's first `{`, or to `;`
/// for body-less items).
fn test_ranges(tokens: &[Token]) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    let mut i = 0;
    while i < tokens.len() {
        if let Some(end) = match_cfg_test(tokens, i) {
            let close = item_end(tokens, end + 1).unwrap_or(tokens.len() - 1);
            ranges.push((i, close));
            i = end + 1;
            continue;
        }
        i += 1;
    }
    ranges
}

/// If tokens starting at `i` spell `#[cfg(…test…)]`, return the index of
/// the closing `]`.
fn match_cfg_test(tokens: &[Token], i: usize) -> Option<usize> {
    let code = |k: usize| -> Option<&Token> {
        let idx = next_code(tokens, k)?;
        tokens.get(idx)
    };
    if tokens[i].text != "#" || tokens[i].is_comment() {
        return None;
    }
    let mut j = next_code(tokens, i + 1)?;
    if tokens[j].text != "[" {
        return None;
    }
    j = next_code(tokens, j + 1)?;
    if tokens[j].kind != TokKind::Ident || tokens[j].text != "cfg" {
        return None;
    }
    j = next_code(tokens, j + 1)?;
    if tokens[j].text != "(" {
        return None;
    }
    // Scan the cfg predicate for a bare `test` ident.
    let mut depth = 1i32;
    let mut saw_test = false;
    let mut k = j + 1;
    while k < tokens.len() && depth > 0 {
        let t = code(k)?;
        match t.text.as_str() {
            "(" => depth += 1,
            ")" => depth -= 1,
            "test" if t.kind == TokKind::Ident => saw_test = true,
            _ => {}
        }
        k = next_code(tokens, k)? + 1;
    }
    if !saw_test {
        return None;
    }
    let close = next_code(tokens, k)?;
    if tokens[close].text != "]" {
        return None;
    }
    Some(close)
}

/// Token index where the item starting at `i` ends: the matching `}` of
/// its first `{`, or the first `;` met before any `{`.
fn item_end(tokens: &[Token], i: usize) -> Option<usize> {
    let mut j = i;
    loop {
        j = next_code(tokens, j)?;
        match tokens[j].text.as_str() {
            "{" => break,
            ";" => return Some(j),
            _ => j += 1,
        }
    }
    let mut depth = 0i32;
    while j < tokens.len() {
        if !tokens[j].is_comment() {
            match tokens[j].text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    if depth == 0 {
                        return Some(j);
                    }
                }
                _ => {}
            }
        }
        j += 1;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::super::lexer::lex;
    use super::*;

    fn key_at(src: &str, needle: &str) -> String {
        let toks = lex(src);
        let ctx = build(&toks);
        let idx = toks
            .iter()
            .position(|t| t.text == needle && !t.is_comment())
            .unwrap_or_else(|| panic!("token {needle:?} not found"));
        ctx.item_keys[idx].clone()
    }

    #[test]
    fn free_fn_and_impl_method_keys() {
        let src = "fn alpha() { MARK1; }\n\
                   struct S;\n\
                   impl S { fn beta(&self) { MARK2; } }\n\
                   MARK3";
        assert_eq!(key_at(src, "MARK1"), "alpha");
        assert_eq!(key_at(src, "MARK2"), "S::beta");
        assert_eq!(key_at(src, "MARK3"), "-");
    }

    #[test]
    fn trait_impl_names_the_self_type() {
        let src = "impl<T: Send> Drop for Unmove<T> { fn drop(&mut self) { MARK; } }";
        assert_eq!(key_at(src, "MARK"), "Unmove::drop");
    }

    #[test]
    fn where_clause_with_fn_bound_does_not_steal_the_name() {
        let src = "impl<'f, T, F> RunMerger<'f, T, F>\n\
                   where\n    F: Fn(&T, &T) -> Ordering + Sync,\n\
                   { fn go(&self) { MARK; } }";
        assert_eq!(key_at(src, "MARK"), "RunMerger::go");
    }

    #[test]
    fn tuple_struct_semicolon_cancels_pending() {
        let src = "struct Abort<'a, T>(&'a Stream<T>);\nfn after() { MARK; }";
        assert_eq!(key_at(src, "MARK"), "after");
    }

    #[test]
    fn impl_in_return_position_is_not_a_header() {
        let src = "fn mk() -> impl Fn(usize) -> usize { MARK; }";
        assert_eq!(key_at(src, "MARK"), "mk");
    }

    #[test]
    fn fn_pointer_type_does_not_open_an_item() {
        let src = "fn take(cb: fn(usize) -> usize) { MARK; }";
        assert_eq!(key_at(src, "MARK"), "take");
    }

    #[test]
    fn drop_guard_nested_inside_fn_qualifies_by_impl() {
        let src = "unsafe fn sort_inplace() {\n\
                   struct Unmove<T> { p: T }\n\
                   impl<T> Drop for Unmove<T> { fn drop(&mut self) { MARK; } }\n\
                   OUTER;\n}";
        assert_eq!(key_at(src, "MARK"), "Unmove::drop");
        assert_eq!(key_at(src, "OUTER"), "sort_inplace");
    }

    #[test]
    fn cfg_test_regions_cover_the_gated_item() {
        let src = "fn live() { A; }\n\
                   #[cfg(test)]\nmod tests {\n    fn t() { B; }\n}\n\
                   fn live2() { C; }";
        let toks = lex(src);
        let ctx = build(&toks);
        let idx = |needle: &str| toks.iter().position(|t| t.text == needle).unwrap();
        assert!(!ctx.in_test(idx("A")));
        assert!(ctx.in_test(idx("B")));
        assert!(!ctx.in_test(idx("C")));
    }

    #[test]
    fn cfg_feature_is_not_a_test_region() {
        let src = "#[cfg(feature = \"x\")]\nfn gated() { A; }";
        let toks = lex(src);
        let ctx = build(&toks);
        let idx = toks.iter().position(|t| t.text == "A").unwrap();
        assert!(!ctx.in_test(idx));
    }

    #[test]
    fn cfg_all_test_counts() {
        let src = "#[cfg(all(test, unix))]\nfn helper() { A; }";
        let toks = lex(src);
        let ctx = build(&toks);
        let idx = toks.iter().position(|t| t.text == "A").unwrap();
        assert!(ctx.in_test(idx));
    }

    #[test]
    fn closure_unsafe_and_anon_braces_stay_balanced() {
        let src = "fn outer() {\n\
                   let f = move || unsafe { MARK1 };\n\
                   if let Some(x) = opt { MARK2; }\n\
                   AFTER;\n}";
        assert_eq!(key_at(src, "MARK1"), "outer");
        assert_eq!(key_at(src, "MARK2"), "outer");
        assert_eq!(key_at(src, "AFTER"), "outer");
    }
}
