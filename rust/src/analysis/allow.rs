//! The atomics allowlist: `rust/analysis/atomics.allow`.
//!
//! Every atomic `Ordering::*` use in non-test code must be covered by an
//! entry keyed `(file, enclosing item, ordering variant)` and carrying a
//! non-empty justification, so each ordering decision in the tree is a
//! reviewed artifact rather than an accident. Format, one entry per
//! line, `#` comments and blank lines ignored:
//!
//! ```text
//! par/pool.rs | Scope::run | AcqRel | publishes chunk writes to is_done readers
//! ```
//!
//! Paths are relative to the audited root with `/` separators. Entries
//! never matched by a scan are reported as warnings (not violations):
//! the audit stays actionable when code moves, while the diff to this
//! file still surfaces every new ordering in review.

use crate::error::{Error, Result};
use std::collections::HashMap;
use std::path::Path;

/// The five `std::sync::atomic::Ordering` variants.
pub const ORDERINGS: [&str; 5] = ["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

/// One parsed allowlist entry.
#[derive(Clone, Debug)]
pub struct AllowEntry {
    /// Path relative to the audit root, `/`-separated.
    pub file: String,
    /// Enclosing-item key as computed by [`super::context`].
    pub item: String,
    /// Ordering variant name (`Relaxed`, …, `SeqCst`).
    pub ordering: String,
    /// Human rationale; must be non-empty.
    pub justification: String,
    /// 1-based line in the allowlist file.
    pub line: u32,
}

impl AllowEntry {
    fn key(&self) -> (String, String, String) {
        (self.file.clone(), self.item.clone(), self.ordering.clone())
    }
}

/// Parsed allowlist with O(1) lookup by `(file, item, ordering)`.
#[derive(Debug, Default)]
pub struct Allowlist {
    entries: Vec<AllowEntry>,
    index: HashMap<(String, String, String), usize>,
}

impl Allowlist {
    /// Parse allowlist text. `origin` labels parse errors.
    pub fn parse(text: &str, origin: &str) -> Result<Allowlist> {
        let mut entries = Vec::new();
        let mut index = HashMap::new();
        for (i, raw) in text.lines().enumerate() {
            let line = i as u32 + 1;
            let trimmed = raw.trim();
            if trimmed.is_empty() || trimmed.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = trimmed.split('|').map(str::trim).collect();
            if fields.len() != 4 {
                return Err(Error::Config(format!(
                    "{origin}:{line}: expected `file | item | ordering | justification`, \
                     got {} field(s)",
                    fields.len()
                )));
            }
            let entry = AllowEntry {
                file: fields[0].to_string(),
                item: fields[1].to_string(),
                ordering: fields[2].to_string(),
                justification: fields[3].to_string(),
                line,
            };
            if !ORDERINGS.contains(&entry.ordering.as_str()) {
                return Err(Error::Config(format!(
                    "{origin}:{line}: unknown ordering {:?} (expected one of {:?})",
                    entry.ordering, ORDERINGS
                )));
            }
            if entry.file.is_empty() || entry.item.is_empty() {
                return Err(Error::Config(format!(
                    "{origin}:{line}: file and item fields must be non-empty"
                )));
            }
            if entry.justification.is_empty() {
                return Err(Error::Config(format!(
                    "{origin}:{line}: every allowlist entry needs a justification"
                )));
            }
            if let Some(prev) = index.insert(entry.key(), entries.len()) {
                let prev: &AllowEntry = &entries[prev];
                return Err(Error::Config(format!(
                    "{origin}:{line}: duplicate entry for ({}, {}, {}) — first at line {}",
                    entry.file, entry.item, entry.ordering, prev.line
                )));
            }
            entries.push(entry);
        }
        Ok(Allowlist { entries, index })
    }

    /// Load and parse an allowlist file.
    pub fn load(path: &Path) -> Result<Allowlist> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            Error::Config(format!("cannot read allowlist {}: {e}", path.display()))
        })?;
        Self::parse(&text, &path.display().to_string())
    }

    /// Look up `(file, item, ordering)`; returns the entry index.
    pub fn lookup(&self, file: &str, item: &str, ordering: &str) -> Option<usize> {
        self.index
            .get(&(file.to_string(), item.to_string(), ordering.to_string()))
            .copied()
    }

    /// All parsed entries, in file order.
    pub fn entries(&self) -> &[AllowEntry] {
        &self.entries
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_entries_and_skips_comments() {
        let text = "# header\n\
                    \n\
                    par/pool.rs | Scope::run | Relaxed | chunk counter\n\
                    par/pool.rs | Scope::run | AcqRel | completion edge\n";
        let a = Allowlist::parse(text, "t").unwrap();
        assert_eq!(a.entries().len(), 2);
        assert!(a.lookup("par/pool.rs", "Scope::run", "Relaxed").is_some());
        assert!(a.lookup("par/pool.rs", "Scope::run", "SeqCst").is_none());
        assert!(a.lookup("par/pool.rs", "Scope::is_done", "Relaxed").is_none());
    }

    #[test]
    fn rejects_bad_shapes() {
        for bad in [
            "just | three | fields",
            "f.rs | item | NotAnOrdering | why",
            "f.rs | item | Relaxed |",
            " | item | Relaxed | why",
            "f.rs | item | Relaxed | a\nf.rs | item | Relaxed | b",
        ] {
            assert!(Allowlist::parse(bad, "t").is_err(), "accepted: {bad:?}");
        }
    }
}
