//! The audit rules.
//!
//! Four rule families, each independently testable against fixture
//! sources (`rust/tests/analysis_fixtures/`):
//!
//! * **safety-comment** — every `unsafe` block, `unsafe fn`, and
//!   `unsafe impl` is documented by a `// SAFETY:` comment on the same
//!   line or in the contiguous comment block directly above (`# Safety`
//!   doc sections count for `unsafe fn`). Applies to test code too: a
//!   test's unsafe is as capable of UB as anyone's.
//! * **thread-outside-pool** — `thread::{spawn, scope, Builder}` are
//!   banned outside `par/pool.rs`; every worker must come from the
//!   shared pool or determinism/span accounting silently break. Test
//!   regions are exempt (tests legitimately probe concurrent use).
//! * **atomic-allowlist** — every atomic `Ordering::*` variant used in
//!   non-test code must match an entry in the checked-in allowlist
//!   (alias-insensitive: `AtOrd::Relaxed` is still `Relaxed`; the
//!   `cmp::Ordering` variants `Less`/`Equal`/`Greater` never match).
//! * **det-collections / det-timing / det-float-fold** — determinism
//!   lints for the scoped modules (`recovery/`, `tree/`, `solver/`):
//!   no std `HashMap`/`HashSet` (iteration order is randomized; use
//!   `util`'s Fx variants), no `Instant::now`/`SystemTime::now`
//!   (route timing through `util::Timer`), and no iterator `.sum()` /
//!   `.fold()` unless the turbofish proves an integer accumulator —
//!   float accumulation must go through `par_reduce`'s fixed chunk
//!   tree or an explicit fixed-order loop. `// audit-ok: <reason>`
//!   on or directly above the line acknowledges a reviewed exception.

use super::allow::{Allowlist, ORDERINGS};
use super::context::{self, Context};
use super::lexer::{TokKind, Token};

/// Tunable audit scope; [`Default`] matches this repository's layout.
#[derive(Clone, Debug)]
pub struct AuditConfig {
    /// Path prefixes (relative to the audit root) subject to the
    /// determinism lints.
    pub det_scopes: Vec<String>,
    /// Files (relative to the audit root) allowed to create threads.
    pub thread_exempt: Vec<String>,
}

impl Default for AuditConfig {
    fn default() -> Self {
        AuditConfig {
            det_scopes: vec!["recovery/".into(), "tree/".into(), "solver/".into()],
            thread_exempt: vec!["par/pool.rs".into()],
        }
    }
}

/// One rule violation at a source location.
#[derive(Clone, Debug)]
pub struct Violation {
    /// Path relative to the audit root, `/`-separated.
    pub file: String,
    /// 1-based source line.
    pub line: u32,
    /// Stable rule identifier (e.g. `safety-comment`).
    pub rule: &'static str,
    /// Human-readable description with the copy-pasteable fix key.
    pub msg: String,
}

/// Integer accumulator types that make `.sum::<T>()` deterministic.
const INT_TYPES: [&str; 12] = [
    "u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64", "i128", "isize",
];

/// Per-line facts used by the comment-proximity checks.
struct Lines {
    has_code: Vec<bool>,
    has_comment: Vec<bool>,
    has_safety: Vec<bool>,
    has_audit_ok: Vec<bool>,
}

impl Lines {
    fn build(tokens: &[Token], attr: &[bool]) -> Lines {
        let max = tokens.iter().map(|t| t.end_line() as usize).max().unwrap_or(0);
        let mut l = Lines {
            has_code: vec![false; max + 1],
            has_comment: vec![false; max + 1],
            has_safety: vec![false; max + 1],
            has_audit_ok: vec![false; max + 1],
        };
        for (i, t) in tokens.iter().enumerate() {
            let span = t.line as usize..=t.end_line() as usize;
            if t.is_comment() {
                let safety = t.text.contains("SAFETY:") || t.text.contains("# Safety");
                let audit_ok = t.text.contains("audit-ok");
                for ln in span {
                    l.has_comment[ln] = true;
                    l.has_safety[ln] |= safety;
                    l.has_audit_ok[ln] |= audit_ok;
                }
            } else if !attr[i] {
                // Attribute tokens (`#[inline]`, doc markers) are neutral:
                // they neither document unsafe nor break a comment block.
                for ln in span {
                    l.has_code[ln] = true;
                }
            }
        }
        l
    }

    /// Is `marker` present on `line` or in the contiguous run of
    /// comment/attribute-only lines directly above it?
    fn marker_near(&self, line: u32, marker: impl Fn(&Lines, usize) -> bool) -> bool {
        let line = line as usize;
        if line < self.has_code.len() && marker(self, line) {
            return true;
        }
        for ln in (1..line).rev() {
            if self.has_code[ln] {
                return false;
            }
            if marker(self, ln) {
                return true;
            }
            if !self.has_comment[ln] {
                // Blank (or attribute-only) line: attributes continue the
                // run, a truly blank line would too — both are harmless,
                // so only code terminates the walk. Cap the walk at the
                // file top via the range.
                continue;
            }
        }
        false
    }

    fn safety_near(&self, line: u32) -> bool {
        self.marker_near(line, |l, ln| l.has_safety[ln])
    }

    fn audit_ok_near(&self, line: u32) -> bool {
        self.marker_near(line, |l, ln| l.has_audit_ok[ln])
    }
}

/// Mark tokens belonging to outer/inner attributes (`#[…]`, `#![…]`).
fn attr_mask(tokens: &[Token]) -> Vec<bool> {
    let mut mask = vec![false; tokens.len()];
    let mut i = 0;
    while i < tokens.len() {
        if !tokens[i].is_comment() && tokens[i].text == "#" {
            let mut j = i + 1;
            while j < tokens.len() && tokens[j].is_comment() {
                j += 1;
            }
            if j < tokens.len() && tokens[j].text == "!" {
                j += 1;
                while j < tokens.len() && tokens[j].is_comment() {
                    j += 1;
                }
            }
            if j < tokens.len() && tokens[j].text == "[" {
                let mut depth = 0i32;
                let mut k = j;
                while k < tokens.len() {
                    if !tokens[k].is_comment() {
                        match tokens[k].text.as_str() {
                            "[" => depth += 1,
                            "]" => {
                                depth -= 1;
                                if depth == 0 {
                                    break;
                                }
                            }
                            _ => {}
                        }
                    }
                    k += 1;
                }
                for m in mask.iter_mut().take((k + 1).min(tokens.len())).skip(i) {
                    *m = true;
                }
                i = k + 1;
                continue;
            }
        }
        i += 1;
    }
    mask
}

/// Scan one file's tokens against every rule, appending violations and
/// flagging which allowlist entries were exercised.
pub fn audit_tokens(
    rel: &str,
    tokens: &[Token],
    cfg: &AuditConfig,
    allow: &Allowlist,
    allow_used: &mut [bool],
    out: &mut Vec<Violation>,
) {
    let ctx = context::build(tokens);
    let attr = attr_mask(tokens);
    let lines = Lines::build(tokens, &attr);
    let code: Vec<usize> = (0..tokens.len()).filter(|&i| !tokens[i].is_comment()).collect();
    let in_det_scope = cfg.det_scopes.iter().any(|p| rel.starts_with(p.as_str()));
    let thread_exempt = cfg.thread_exempt.iter().any(|f| f == rel);

    let tok = |p: usize| -> Option<&Token> { code.get(p).map(|&i| &tokens[i]) };
    let text = |p: usize| -> &str { tok(p).map(|t| t.text.as_str()).unwrap_or("") };

    for (p, &idx) in code.iter().enumerate() {
        let t = &tokens[idx];
        let in_test = ctx.in_test(idx);

        // Rule: safety-comment (applies in test regions too).
        if t.kind == TokKind::Ident && t.text == "unsafe" {
            let (what, doc_ok) = match text(p + 1) {
                "fn" | "extern" => ("`unsafe fn`", true),
                "impl" => ("`unsafe impl`", false),
                "trait" => ("`unsafe trait`", false),
                _ => ("unsafe block", false),
            };
            // The marker set already includes `# Safety`, so one walk
            // covers both comment styles; `doc_ok` only shapes the hint.
            if !lines.safety_near(t.line) {
                out.push(Violation {
                    file: rel.to_string(),
                    line: t.line,
                    rule: "safety-comment",
                    msg: format!(
                        "{what} without a `// SAFETY:` comment on the same line or \
                         directly above{}",
                        if doc_ok { " (a `# Safety` doc section also counts)" } else { "" }
                    ),
                });
            }
        }

        // Rule: thread-outside-pool (test regions exempt).
        if !in_test
            && !thread_exempt
            && t.kind == TokKind::Ident
            && t.text == "thread"
            && text(p + 1) == ":"
            && text(p + 2) == ":"
        {
            let callee = text(p + 3);
            if matches!(callee, "spawn" | "scope" | "Builder") {
                out.push(Violation {
                    file: rel.to_string(),
                    line: t.line,
                    rule: "thread-outside-pool",
                    msg: format!(
                        "`thread::{callee}` outside par/pool.rs — all workers must come \
                         from the shared pool (`par::ThreadPool`)"
                    ),
                });
            }
        }

        // Rule: atomic-allowlist (test regions exempt).
        if !in_test
            && t.kind == TokKind::Ident
            && ORDERINGS.contains(&t.text.as_str())
            && p >= 3
            && text(p - 1) == ":"
            && text(p - 2) == ":"
            && tok(p - 3).map(|q| q.kind == TokKind::Ident).unwrap_or(false)
        {
            let item = ctx.item_keys[idx].as_str();
            match allow.lookup(rel, item, &t.text) {
                Some(entry) => allow_used[entry] = true,
                None => out.push(Violation {
                    file: rel.to_string(),
                    line: t.line,
                    rule: "atomic-allowlist",
                    msg: format!(
                        "atomic ordering `{}` in `{item}` has no allowlist entry — add \
                         `{rel} | {item} | {} | <justification>` to the allowlist after \
                         review",
                        t.text, t.text
                    ),
                }),
            }
        }

        // Determinism lints: only in scoped modules, never in tests.
        if !in_det_scope || in_test {
            continue;
        }
        if t.kind == TokKind::Ident && (t.text == "HashMap" || t.text == "HashSet") {
            if !lines.audit_ok_near(t.line) {
                out.push(Violation {
                    file: rel.to_string(),
                    line: t.line,
                    rule: "det-collections",
                    msg: format!(
                        "std `{}` in a determinism-scoped module: iteration order is \
                         randomized per process — use `util`'s Fx{} instead",
                        t.text, t.text
                    ),
                });
            }
        }
        if t.kind == TokKind::Ident
            && (t.text == "Instant" || t.text == "SystemTime")
            && text(p + 1) == ":"
            && text(p + 2) == ":"
            && text(p + 3) == "now"
            && !lines.audit_ok_near(t.line)
        {
            out.push(Violation {
                file: rel.to_string(),
                line: t.line,
                rule: "det-timing",
                msg: format!(
                    "`{}::now` in a determinism-scoped module: route timing through \
                     `util::Timer` so measurement stays out of the algorithm",
                    t.text
                ),
            });
        }
        if t.kind == TokKind::Punct
            && t.text == "."
            && tok(p + 1).map(|q| q.kind == TokKind::Ident).unwrap_or(false)
            && matches!(text(p + 1), "sum" | "fold")
        {
            let method = text(p + 1);
            let call_like = matches!(text(p + 2), "(" | ":");
            let int_turbofish = text(p + 2) == ":"
                && text(p + 3) == ":"
                && text(p + 4) == "<"
                && INT_TYPES.contains(&text(p + 5));
            let site = tok(p + 1).map(|q| q.line).unwrap_or(t.line);
            if call_like && !int_turbofish && !lines.audit_ok_near(site) {
                out.push(Violation {
                    file: rel.to_string(),
                    line: site,
                    rule: "det-float-fold",
                    msg: format!(
                        "iterator `.{method}` in a determinism-scoped module without an \
                         integer turbofish: float accumulation must use `par_reduce`'s \
                         fixed chunk tree or an explicit loop (or mark a reviewed \
                         exception with `// audit-ok: <reason>`)"
                    ),
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::lexer::lex;
    use super::*;

    fn scan(rel: &str, src: &str, allow_text: &str) -> Vec<Violation> {
        let allow = Allowlist::parse(allow_text, "t").unwrap();
        let mut used = vec![false; allow.entries().len()];
        let mut out = Vec::new();
        let cfg = AuditConfig {
            det_scopes: vec![String::new()], // everything in det scope
            thread_exempt: vec!["par/pool.rs".into()],
        };
        audit_tokens(rel, &lex(src), &cfg, &allow, &mut used, &mut out);
        out
    }

    fn rules(v: &[Violation]) -> Vec<&'static str> {
        v.iter().map(|x| x.rule).collect()
    }

    #[test]
    fn undocumented_unsafe_flavors_are_flagged_once_each() {
        let src = "fn f() { let x = unsafe { g() }; }\n\
                   pub struct W(*mut u8);\n\
                   unsafe impl Send for W {}\n\
                   pub unsafe fn raw() {}\n";
        let v = scan("a.rs", src, "");
        assert_eq!(rules(&v), vec!["safety-comment"; 3], "{v:?}");
        assert_eq!(v[0].line, 1);
        assert_eq!(v[1].line, 3);
        assert_eq!(v[2].line, 4);
    }

    #[test]
    fn safety_comments_same_line_above_and_doc_section_pass() {
        let src = "fn f() {\n\
                   // SAFETY: g upholds its contract here.\n\
                   let x = unsafe { g() };\n\
                   let y = unsafe { h() }; // SAFETY: same-line form.\n\
                   }\n\
                   pub struct W(*mut u8);\n\
                   // SAFETY: W is only touched from one thread.\n\
                   unsafe impl Send for W {}\n\
                   /// Reads a byte.\n\
                   ///\n\
                   /// # Safety\n\
                   /// `p` must be valid.\n\
                   #[inline]\n\
                   pub unsafe fn raw(p: *const u8) {}\n";
        let v = scan("a.rs", src, "");
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn unsafe_in_strings_and_comments_is_ignored() {
        let src = "// unsafe is mentioned here\nfn f() { let s = \"unsafe { }\"; }";
        assert!(scan("a.rs", src, "").is_empty());
    }

    #[test]
    fn thread_spawn_scope_builder_flagged_outside_pool_only() {
        let src = "fn f() {\n\
                   std::thread::spawn(|| {});\n\
                   std::thread::scope(|s| {});\n\
                   let b = std::thread::Builder::new();\n\
                   std::thread::yield_now();\n\
                   }";
        let v = scan("x.rs", src, "");
        assert_eq!(rules(&v), vec!["thread-outside-pool"; 3], "{v:?}");
        assert!(scan("par/pool.rs", src, "").is_empty());
        let in_test = format!("#[cfg(test)]\nmod tests {{ {src} }}");
        assert!(scan("x.rs", &in_test, "").is_empty());
    }

    #[test]
    fn atomics_match_allowlist_by_enclosing_item_alias_insensitively() {
        let src = "use std::sync::atomic::Ordering as AtOrd;\n\
                   struct C;\n\
                   impl C {\n\
                   fn bump(&self) { HITS.fetch_add(1, AtOrd::Relaxed); }\n\
                   fn peek(&self) { HITS.load(AtOrd::Acquire); }\n\
                   }\n\
                   fn cmp_is_fine() -> std::cmp::Ordering { std::cmp::Ordering::Less }";
        let ok = "x.rs | C::bump | Relaxed | counter only\n\
                  x.rs | C::peek | Acquire | pairs with a Release store";
        assert!(scan("x.rs", src, ok).is_empty());
        let missing = "x.rs | C::bump | Relaxed | counter only";
        let v = scan("x.rs", src, missing);
        assert_eq!(rules(&v), vec!["atomic-allowlist"], "{v:?}");
        assert!(v[0].msg.contains("C::peek"), "{}", v[0].msg);
        assert!(v[0].msg.contains("Acquire"));
    }

    #[test]
    fn det_lints_flag_and_release() {
        let src = "use std::collections::HashMap;\n\
                   fn f(xs: &[f64]) -> f64 {\n\
                   let t = std::time::Instant::now();\n\
                   let bad: f64 = xs.iter().sum();\n\
                   let worse = xs.iter().fold(0.0, |a, b| a + b);\n\
                   let fine: usize = xs.iter().map(|_| 1usize).sum::<usize>();\n\
                   // audit-ok: fixed-order fold over a slice\n\
                   let ok = xs.iter().fold(0.0, |a, b| a + b);\n\
                   bad + worse + ok + fine as f64 + t.elapsed().as_secs_f64()\n\
                   }";
        let v = scan("recovery/f.rs", src, "");
        let mut r = rules(&v);
        r.sort_unstable();
        assert_eq!(
            r,
            vec!["det-collections", "det-float-fold", "det-float-fold", "det-timing"],
            "{v:?}"
        );
        // Outside the determinism scope the same source is clean.
        let cfg = AuditConfig::default();
        let allow = Allowlist::parse("", "t").unwrap();
        let mut out = Vec::new();
        audit_tokens("util/f.rs", &lex(src), &cfg, &allow, &mut [], &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn float_turbofish_is_still_a_violation() {
        let src = "fn f(xs: &[f64]) -> f64 { xs.iter().sum::<f64>() }";
        let v = scan("recovery/f.rs", src, "");
        assert_eq!(rules(&v), vec!["det-float-fold"], "{v:?}");
    }
}
