//! A small hand-rolled Rust lexer — just enough token structure for the
//! audit rules, with no `syn` (the offline `vendor/` set has none).
//!
//! The rules need three things a plain regex scan cannot deliver:
//!
//! * **comments vs code**: the word `unsafe` inside a doc comment or a
//!   string literal must not look like an unsafe block;
//! * **line attribution**: the SAFETY-comment rule reasons about "the
//!   contiguous comment block directly above line L";
//! * **path shape**: `Ordering::Relaxed` is two idents joined by `::`
//!   whatever the import alias (`AtOrd::Relaxed`, `AtomOrd::Relaxed`),
//!   while `Ordering::Less` (the `cmp` enum) must not match.
//!
//! The lexer is intentionally forgiving: it never fails, and unknown
//! bytes become single-character [`TokKind::Punct`] tokens. It handles
//! the token classes that matter for correctness of the rules — line and
//! nested block comments, plain/raw/byte strings, char literals vs
//! lifetimes, identifiers, and numbers (including `1e-3` exponents so a
//! float literal is never split into a spurious ident).

/// Lexical class of one token.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword.
    Ident,
    /// Single punctuation character.
    Punct,
    /// Numeric literal (integers, floats, any suffix).
    Num,
    /// String literal (plain, raw, or byte; may span lines).
    Str,
    /// Character or byte-character literal.
    Char,
    /// Lifetime (`'a`, `'static`, `'_`).
    Lifetime,
    /// `// …` comment (incl. `///` and `//!` doc comments).
    LineComment,
    /// `/* … */` comment (nesting honored; may span lines).
    BlockComment,
}

/// One token with its starting line (1-based) and raw text.
#[derive(Clone, Debug)]
pub struct Token {
    /// Lexical class.
    pub kind: TokKind,
    /// 1-based line of the token's first character.
    pub line: u32,
    /// Raw source text of the token.
    pub text: String,
}

impl Token {
    /// True for comment tokens (line or block).
    pub fn is_comment(&self) -> bool {
        matches!(self.kind, TokKind::LineComment | TokKind::BlockComment)
    }

    /// 1-based line of the token's last character (comments and strings
    /// may span several lines).
    pub fn end_line(&self) -> u32 {
        self.line + self.text.bytes().filter(|&b| b == b'\n').count() as u32
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Tokenize `src`. Never fails; see the module docs for the guarantees.
pub fn lex(src: &str) -> Vec<Token> {
    Lexer { chars: src.chars().collect(), pos: 0, line: 1, out: Vec::new() }.run()
}

struct Lexer {
    chars: Vec<char>,
    pos: usize,
    line: u32,
    out: Vec<Token>,
}

impl Lexer {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.pos + ahead).copied()
    }

    /// Consume one char, tracking newlines.
    fn bump(&mut self, buf: &mut String) {
        let c = self.chars[self.pos];
        if c == '\n' {
            self.line += 1;
        }
        buf.push(c);
        self.pos += 1;
    }

    fn emit(&mut self, kind: TokKind, line: u32, text: String) {
        self.out.push(Token { kind, line, text });
    }

    fn run(mut self) -> Vec<Token> {
        while self.pos < self.chars.len() {
            let c = self.chars[self.pos];
            let line = self.line;
            if c.is_whitespace() {
                let mut sink = String::new();
                self.bump(&mut sink);
                continue;
            }
            if c == '/' && self.peek(1) == Some('/') {
                self.line_comment(line);
                continue;
            }
            if c == '/' && self.peek(1) == Some('*') {
                self.block_comment(line);
                continue;
            }
            if c == '"' {
                self.string(line);
                continue;
            }
            if c == '\'' {
                self.char_or_lifetime(line);
                continue;
            }
            if is_ident_start(c) {
                if self.try_raw_or_byte_string(line) {
                    continue;
                }
                self.ident(line);
                continue;
            }
            if c.is_ascii_digit() {
                self.number(line);
                continue;
            }
            let mut text = String::new();
            self.bump(&mut text);
            self.emit(TokKind::Punct, line, text);
        }
        self.out
    }

    fn line_comment(&mut self, line: u32) {
        let mut text = String::new();
        while self.pos < self.chars.len() && self.chars[self.pos] != '\n' {
            self.bump(&mut text);
        }
        self.emit(TokKind::LineComment, line, text);
    }

    fn block_comment(&mut self, line: u32) {
        let mut text = String::new();
        self.bump(&mut text); // '/'
        self.bump(&mut text); // '*'
        let mut depth = 1usize;
        while self.pos < self.chars.len() && depth > 0 {
            if self.chars[self.pos] == '/' && self.peek(1) == Some('*') {
                depth += 1;
                self.bump(&mut text);
                self.bump(&mut text);
            } else if self.chars[self.pos] == '*' && self.peek(1) == Some('/') {
                depth -= 1;
                self.bump(&mut text);
                self.bump(&mut text);
            } else {
                self.bump(&mut text);
            }
        }
        self.emit(TokKind::BlockComment, line, text);
    }

    /// Plain `"…"` string with backslash escapes; may span lines.
    fn string(&mut self, line: u32) {
        let mut text = String::new();
        self.bump(&mut text); // opening quote
        while self.pos < self.chars.len() {
            let c = self.chars[self.pos];
            if c == '\\' {
                self.bump(&mut text);
                if self.pos < self.chars.len() {
                    self.bump(&mut text);
                }
                continue;
            }
            self.bump(&mut text);
            if c == '"' {
                break;
            }
        }
        self.emit(TokKind::Str, line, text);
    }

    /// `r"…"`, `r#"…"#`, `b"…"`, `br#"…"#`, `b'…'` — raw and byte forms.
    /// Returns false if the upcoming ident is not actually a literal
    /// prefix, leaving the position untouched.
    fn try_raw_or_byte_string(&mut self, line: u32) -> bool {
        let c = self.chars[self.pos];
        if c != 'r' && c != 'b' {
            return false;
        }
        let mut j = self.pos + 1;
        if c == 'b' && self.chars.get(j) == Some(&'r') {
            j += 1;
        }
        let raw = c == 'r' || j > self.pos + 1;
        let mut hashes = 0usize;
        while self.chars.get(j) == Some(&'#') {
            hashes += 1;
            j += 1;
        }
        // Byte char literal: b'…'
        if c == 'b' && hashes == 0 && j == self.pos + 1 && self.chars.get(j) == Some(&'\'') {
            let mut text = String::new();
            self.bump(&mut text); // 'b'
            self.char_body(&mut text);
            self.emit(TokKind::Char, line, text);
            return true;
        }
        if self.chars.get(j) != Some(&'"') {
            return false;
        }
        if !raw && hashes > 0 {
            return false;
        }
        let mut text = String::new();
        while self.pos <= j {
            self.bump(&mut text); // prefix, hashes, opening quote
        }
        if raw {
            // Scan for `"` followed by `hashes` '#' characters.
            'outer: while self.pos < self.chars.len() {
                if self.chars[self.pos] == '"' {
                    for k in 0..hashes {
                        if self.peek(1 + k) != Some('#') {
                            self.bump(&mut text);
                            continue 'outer;
                        }
                    }
                    for _ in 0..=hashes {
                        self.bump(&mut text);
                    }
                    break;
                }
                self.bump(&mut text);
            }
        } else {
            // b"…" with escapes.
            while self.pos < self.chars.len() {
                let ch = self.chars[self.pos];
                if ch == '\\' {
                    self.bump(&mut text);
                    if self.pos < self.chars.len() {
                        self.bump(&mut text);
                    }
                    continue;
                }
                self.bump(&mut text);
                if ch == '"' {
                    break;
                }
            }
        }
        self.emit(TokKind::Str, line, text);
        true
    }

    /// Consume a `'…'` char body (opening quote, contents, closing
    /// quote) into `text`. Assumes the current char is `'`.
    fn char_body(&mut self, text: &mut String) {
        self.bump(text); // opening quote
        if self.pos < self.chars.len() && self.chars[self.pos] == '\\' {
            self.bump(text);
            if self.pos < self.chars.len() {
                self.bump(text);
            }
        } else if self.pos < self.chars.len() {
            self.bump(text);
        }
        // Consume up to the closing quote (covers `'\u{…}'`).
        while self.pos < self.chars.len() && self.chars[self.pos] != '\'' {
            self.bump(text);
        }
        if self.pos < self.chars.len() {
            self.bump(text); // closing quote
        }
    }

    fn char_or_lifetime(&mut self, line: u32) {
        // Lifetime: `'ident` NOT followed by a closing quote.
        let is_lifetime = match self.peek(1) {
            Some(c) if is_ident_start(c) => {
                let mut k = 2;
                while self.peek(k).map(is_ident_continue).unwrap_or(false) {
                    k += 1;
                }
                self.peek(k) != Some('\'')
            }
            _ => false,
        };
        let mut text = String::new();
        if is_lifetime {
            self.bump(&mut text); // quote
            while self.pos < self.chars.len() && is_ident_continue(self.chars[self.pos]) {
                self.bump(&mut text);
            }
            self.emit(TokKind::Lifetime, line, text);
        } else {
            self.char_body(&mut text);
            self.emit(TokKind::Char, line, text);
        }
    }

    fn ident(&mut self, line: u32) {
        let mut text = String::new();
        while self.pos < self.chars.len() && is_ident_continue(self.chars[self.pos]) {
            self.bump(&mut text);
        }
        self.emit(TokKind::Ident, line, text);
    }

    fn number(&mut self, line: u32) {
        let mut text = String::new();
        loop {
            let Some(c) = self.peek(0) else { break };
            if c.is_alphanumeric() || c == '_' {
                self.bump(&mut text);
                continue;
            }
            // `1.5` continues the number; `0..n` and `x.0.abs()` stop it.
            if c == '.' && self.peek(1).map(|d| d.is_ascii_digit()).unwrap_or(false) {
                self.bump(&mut text);
                continue;
            }
            // Exponent sign: `1e-3`, `2.5E+7`.
            if (c == '+' || c == '-')
                && text.ends_with(['e', 'E'])
                && self.peek(1).map(|d| d.is_ascii_digit()).unwrap_or(false)
            {
                self.bump(&mut text);
                continue;
            }
            break;
        }
        self.emit(TokKind::Num, line, text);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn comments_strings_and_idents_are_distinguished() {
        let toks = kinds("let s = \"unsafe // not code\"; // unsafe trailing\nunsafe {}");
        assert_eq!(toks[0], (TokKind::Ident, "let".into()));
        assert_eq!(toks[3].0, TokKind::Str);
        assert!(toks[3].1.contains("unsafe"));
        assert_eq!(toks[5].0, TokKind::LineComment);
        assert_eq!(toks[6], (TokKind::Ident, "unsafe".into()));
    }

    #[test]
    fn nested_block_comments_and_lines() {
        let toks = lex("/* a /* b */ c */ x\ny");
        assert_eq!(toks[0].kind, TokKind::BlockComment);
        assert_eq!(toks[1].text, "x");
        assert_eq!(toks[1].line, 1);
        assert_eq!(toks[2].text, "y");
        assert_eq!(toks[2].line, 2);
    }

    #[test]
    fn multiline_block_comment_end_line() {
        let toks = lex("/* one\ntwo\nthree */ after");
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[0].end_line(), 3);
        assert_eq!(toks[1].line, 3);
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let toks = kinds("&'static str; let c = 'x'; let q = '\\''; let u = '\\u{1F600}'; '_");
        let lifes: Vec<_> = toks.iter().filter(|t| t.0 == TokKind::Lifetime).collect();
        let chars: Vec<_> = toks.iter().filter(|t| t.0 == TokKind::Char).collect();
        assert_eq!(lifes.len(), 2, "{toks:?}"); // 'static and '_
        assert_eq!(chars.len(), 3, "{toks:?}"); // 'x', '\'', '\u{1F600}'
    }

    #[test]
    fn raw_and_byte_strings() {
        let toks = kinds("r\"raw\" r#\"ra\"w\"# b\"bytes\" br#\"b\"# b'x' rx b2");
        assert_eq!(toks[0].0, TokKind::Str);
        assert_eq!(toks[1].0, TokKind::Str);
        assert_eq!(toks[1].1, "r#\"ra\"w\"#");
        assert_eq!(toks[2].0, TokKind::Str);
        assert_eq!(toks[3].0, TokKind::Str);
        assert_eq!(toks[4].0, TokKind::Char);
        // Plain idents that merely start with r/b stay idents.
        assert_eq!(toks[5], (TokKind::Ident, "rx".into()));
        assert_eq!(toks[6], (TokKind::Ident, "b2".into()));
    }

    #[test]
    fn numbers_ranges_and_exponents() {
        let toks = kinds("0..n 1.5 1e-3 0x9E37_79B9 x.0");
        assert_eq!(toks[0], (TokKind::Num, "0".into()));
        assert_eq!(toks[1], (TokKind::Punct, ".".into()));
        assert_eq!(toks[2], (TokKind::Punct, ".".into()));
        assert_eq!(toks[3], (TokKind::Ident, "n".into()));
        assert_eq!(toks[4], (TokKind::Num, "1.5".into()));
        assert_eq!(toks[5], (TokKind::Num, "1e-3".into()));
        assert_eq!(toks[6], (TokKind::Num, "0x9E37_79B9".into()));
        assert_eq!(toks[7], (TokKind::Ident, "x".into()));
        assert_eq!(toks[8], (TokKind::Punct, ".".into()));
        assert_eq!(toks[9], (TokKind::Num, "0".into()));
    }

    #[test]
    fn path_tokens_survive_for_rule_matching() {
        let toks = kinds("m.load(AtOrd::Relaxed)");
        let idents: Vec<&str> = toks
            .iter()
            .filter(|t| t.0 == TokKind::Ident)
            .map(|t| t.1.as_str())
            .collect();
        assert_eq!(idents, vec!["m", "load", "AtOrd", "Relaxed"]);
    }
}
