//! R-MAT recursive power-law generator — the analogue of the paper's SNAP
//! social graphs, in particular the *com-Youtube* pathology graph.
//!
//! com-Youtube is "a highly skewed graph where a few high-degree vertices
//! connect to many others" (§V): once such a vertex is covered, feGRASS's
//! loose vertex-cover condition marks nearly all incident edges similar,
//! forcing thousands of recovery passes. R-MAT with a strong `a` corner
//! reproduces exactly that hub structure, and the resulting spanning tree
//! concentrates off-tree edge LCAs in a handful of giant subtasks — the
//! *skewed subtask distribution* regime of Figs. 7–8.

use crate::graph::{Edge, Graph};
use crate::util::Rng;

/// R-MAT parameters (quadrant probabilities, a+b+c+d = 1).
#[derive(Clone, Copy, Debug)]
pub struct RmatParams {
    /// Top-left quadrant probability (skew knob).
    pub a: f64,
    /// Top-right.
    pub b: f64,
    /// Bottom-left.
    pub c: f64,
}

impl RmatParams {
    /// Classic skewed social-network setting.
    pub fn skewed() -> Self {
        RmatParams { a: 0.57, b: 0.19, c: 0.19 }
    }

    /// Extra-skewed setting used for the com-Youtube analogue.
    pub fn youtube_like() -> Self {
        RmatParams { a: 0.7, b: 0.14, c: 0.14 }
    }
}

/// Generate an R-MAT graph with `2^scale` vertices and ~`avg_deg·n/2`
/// undirected edges, random weights in `[1, 10]`.
///
/// Duplicate edges are merged (summing weights, as conductances); the
/// caller typically extracts the largest connected component.
pub fn rmat(scale: u32, avg_deg: f64, p: RmatParams, rng: &mut Rng) -> Graph {
    let n = 1usize << scale;
    let m = (avg_deg * n as f64 / 2.0) as usize;
    let d = 1.0 - p.a - p.b - p.c;
    assert!(d >= 0.0, "rmat params must sum to <= 1");
    let mut raw: Vec<(u32, u32, f64)> = Vec::with_capacity(m);
    for _ in 0..m {
        let (mut u, mut v) = (0usize, 0usize);
        for level in (0..scale).rev() {
            // Per-level noise keeps the degree sequence from being too
            // regular (standard "smoothing" in R-MAT implementations).
            let r = rng.next_f64();
            let (du, dv) = if r < p.a {
                (0, 0)
            } else if r < p.a + p.b {
                (0, 1)
            } else if r < p.a + p.b + p.c {
                (1, 0)
            } else {
                (1, 1)
            };
            u |= du << level;
            v |= dv << level;
        }
        if u != v {
            raw.push((u as u32, v as u32, rng.range_f64(1.0, 10.0)));
        }
    }
    Graph::from_edges(n, &raw)
}

/// A "hub" graph: `hubs` star centers each connected to a random subset of
/// the `n` vertices, plus a random tree backbone keeping it connected.
/// This is the most extreme feGRASS worst case: covering one hub marks
/// almost every off-tree edge loosely similar.
pub fn hub_graph(n: usize, hubs: usize, hub_deg: usize, rng: &mut Rng) -> Graph {
    assert!(hubs >= 1 && n > hubs);
    let mut edges: Vec<Edge> = Vec::new();
    // Random backbone tree: vertex i attaches to a random earlier vertex.
    for i in 1..n {
        let j = rng.below(i);
        edges.push(Edge {
            u: (i.min(j)) as u32,
            v: (i.max(j)) as u32,
            w: rng.range_f64(1.0, 10.0),
        });
    }
    // Hubs: the first `hubs` vertices get `hub_deg` random spokes each.
    for h in 0..hubs as u32 {
        for _ in 0..hub_deg {
            let t = rng.below(n) as u32;
            if t != h {
                edges.push(Edge { u: h.min(t), v: h.max(t), w: rng.range_f64(1.0, 10.0) });
            }
        }
    }
    let raw: Vec<(u32, u32, f64)> = edges.iter().map(|e| (e.u, e.v, e.w)).collect();
    Graph::from_edges(n, &raw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{is_connected, largest_component};

    #[test]
    fn rmat_is_power_lawish() {
        let mut rng = Rng::new(11);
        let g = rmat(12, 8.0, RmatParams::youtube_like(), &mut rng);
        let (cc, _) = largest_component(&g);
        assert!(cc.num_vertices() > 1000);
        // Skew: max degree far above average.
        assert!(cc.max_degree() as f64 > 10.0 * cc.avg_degree(),
            "max {} avg {}", cc.max_degree(), cc.avg_degree());
    }

    #[test]
    fn rmat_deterministic() {
        let a = rmat(10, 6.0, RmatParams::skewed(), &mut Rng::new(5));
        let b = rmat(10, 6.0, RmatParams::skewed(), &mut Rng::new(5));
        assert_eq!(a.num_edges(), b.num_edges());
    }

    #[test]
    fn hub_graph_connected_and_skewed() {
        let mut rng = Rng::new(13);
        let g = hub_graph(5000, 3, 2000, &mut rng);
        assert!(is_connected(&g));
        assert!(g.degree(0) > 1000);
        assert!(g.max_degree() > 100 * 2 * g.num_edges() / g.num_vertices() / 10);
    }

    #[test]
    fn hub_graph_small() {
        let mut rng = Rng::new(17);
        let g = hub_graph(10, 1, 5, &mut rng);
        assert!(is_connected(&g));
        assert!(g.num_edges() >= 9);
    }
}
