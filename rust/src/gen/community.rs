//! Planted-community generators — the analogue of the paper's coauthor /
//! citation / co-purchase graphs (`com-DBLP`, `com-Amazon`,
//! `coAuthorsCiteseer`, `citationsCiteseer`, `coAuthorsDBLP`,
//! `coPapersDBLP`).
//!
//! Collaboration networks are unions of small dense cliques (papers) glued
//! by shared authors, with a heavy-tailed community-size distribution.
//! That structure yields a *moderately skewed* subtask distribution: a few
//! larger LCA groups plus a long tail — the regime where feGRASS needs a
//! handful of extra recovery passes (Table II rows 07–13).

use crate::graph::{Edge, Graph};
use crate::util::Rng;

/// Parameters for the planted-community generator.
#[derive(Clone, Copy, Debug)]
pub struct CommunityParams {
    /// Number of vertices.
    pub n: usize,
    /// Expected community size (geometric-ish, heavy tail via pareto mix).
    pub mean_size: f64,
    /// Pareto exponent for the size tail (smaller → heavier tail).
    pub tail: f64,
    /// Probability an intra-community pair is connected.
    pub intra_p: f64,
    /// Number of random inter-community "bridge" edges per community.
    pub bridges: usize,
    /// Hard cap on community size (keeps the Pareto tail from producing
    /// quadratic-blowup cliques).
    pub max_size: usize,
}

/// Generate a planted-community graph with random weights in `[1, 10]`.
/// A backbone path through community representatives guarantees
/// connectivity.
pub fn community(p: CommunityParams, rng: &mut Rng) -> Graph {
    assert!(p.n >= 4);
    // 1. Partition vertices into communities with Pareto-distributed sizes.
    let mut comms: Vec<(usize, usize)> = Vec::new(); // (start, len)
    let mut at = 0usize;
    while at < p.n {
        // Pareto(x_m = mean*(tail-1)/tail, alpha = tail), clamped.
        let u = rng.next_f64().max(1e-12);
        let xm = p.mean_size * (p.tail - 1.0) / p.tail;
        let size = (xm / u.powf(1.0 / p.tail)).round() as usize;
        let size = size.clamp(2, p.max_size.max(2)).min(p.n - at).max(1);
        if size == 0 {
            break;
        }
        comms.push((at, size));
        at += size;
    }
    if let Some(last) = comms.last_mut() {
        // absorb any 1-vertex remainder
        if last.0 + last.1 < p.n {
            last.1 = p.n - last.0;
        }
    }
    let mut edges: Vec<Edge> = Vec::new();
    let wt = |rng: &mut Rng| rng.range_f64(1.0, 10.0);
    // 2. Intra-community edges: Erdos-Renyi within, but cap the quadratic
    //    blowup for giant communities by sampling.
    for &(start, len) in &comms {
        let pairs = len * (len - 1) / 2;
        let expect = (p.intra_p * pairs as f64).ceil() as usize;
        if pairs <= 4 * expect {
            for i in 0..len {
                for j in (i + 1)..len {
                    if rng.next_f64() < p.intra_p {
                        edges.push(Edge {
                            u: (start + i) as u32,
                            v: (start + j) as u32,
                            w: wt(rng),
                        });
                    }
                }
            }
        } else {
            for _ in 0..expect {
                let i = rng.below(len);
                let j = rng.below(len);
                if i != j {
                    let (a, b) = (start + i.min(j), start + i.max(j));
                    edges.push(Edge { u: a as u32, v: b as u32, w: wt(rng) });
                }
            }
        }
        // ensure each community is internally connected (star fallback)
        for i in 1..len {
            if rng.next_f64() < 0.35 {
                edges.push(Edge { u: start as u32, v: (start + i) as u32, w: wt(rng) });
            }
        }
    }
    // 3. Backbone: chain community representatives (guarantees one CC),
    //    plus random bridges (shared authors).
    for k in 1..comms.len() {
        let (a, _) = comms[k - 1];
        let (b, _) = comms[k];
        edges.push(Edge { u: a.min(b) as u32, v: a.max(b) as u32, w: wt(rng) });
    }
    // Spanning star fallback inside each community
    for &(start, len) in &comms {
        for i in 1..len {
            edges.push(Edge { u: start as u32, v: (start + i) as u32, w: wt(rng) });
        }
    }
    for &(start, len) in &comms {
        for _ in 0..p.bridges {
            let s = start + rng.below(len);
            let t = rng.below(p.n);
            if s != t {
                edges.push(Edge { u: s.min(t) as u32, v: s.max(t) as u32, w: wt(rng) });
            }
        }
    }
    let raw: Vec<(u32, u32, f64)> = edges.iter().map(|e| (e.u, e.v, e.w)).collect();
    Graph::from_edges(p.n, &raw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::is_connected;

    fn small_params() -> CommunityParams {
        CommunityParams { n: 3000, mean_size: 12.0, tail: 1.8, intra_p: 0.4, bridges: 2, max_size: 300 }
    }

    #[test]
    fn max_size_caps_density() {
        let mut p = small_params();
        p.tail = 1.2; // very heavy tail
        p.max_size = 40;
        let g = community(p, &mut Rng::new(33));
        assert!(g.avg_degree() < 40.0, "avg {}", g.avg_degree());
    }

    #[test]
    fn connected_and_clustered() {
        let g = community(small_params(), &mut Rng::new(21));
        assert_eq!(g.num_vertices(), 3000);
        assert!(is_connected(&g));
        // denser than a tree, sparser than quadratic
        assert!(g.avg_degree() > 2.5 && g.avg_degree() < 60.0, "avg {}", g.avg_degree());
    }

    #[test]
    fn has_degree_skew() {
        let g = community(small_params(), &mut Rng::new(22));
        assert!(
            (g.max_degree() as f64) > 3.0 * g.avg_degree(),
            "max {} avg {}",
            g.max_degree(),
            g.avg_degree()
        );
    }

    #[test]
    fn deterministic() {
        let a = community(small_params(), &mut Rng::new(5));
        let b = community(small_params(), &mut Rng::new(5));
        assert_eq!(a.num_edges(), b.num_edges());
    }
}
