//! Finite-element triangle-mesh generators — the analogue of the paper's
//! DIMACS10 numerical meshes (`NACA0015`, `M6`, `333SP`, `AS365`, `NLR`).
//!
//! Those are 2-D airfoil / multigrid triangulations: near-constant degree
//! (≈6), huge diameter, no hubs. On this family the off-tree edge LCAs
//! spread over very many small subtasks — the *uniform* regime where outer
//! parallelism alone achieves near-ideal scaling (Fig. 6).

use crate::graph::{Edge, Graph};
use crate::util::Rng;

/// Structured triangle mesh on a `w × h` vertex grid: every grid cell gets
/// one diagonal (alternating orientation, like a union-jack-ish pattern),
/// so interior vertices have degree ≈ 6. Weights uniform in `[1, 10]`.
pub fn tri_mesh(w: usize, h: usize, rng: &mut Rng) -> Graph {
    assert!(w >= 2 && h >= 2);
    let id = |x: usize, y: usize| -> u32 { (y * w + x) as u32 };
    let mut edges: Vec<Edge> = Vec::with_capacity(3 * w * h);
    let wt = |rng: &mut Rng| rng.range_f64(1.0, 10.0);
    for y in 0..h {
        for x in 0..w {
            if x + 1 < w {
                edges.push(Edge { u: id(x, y), v: id(x + 1, y), w: wt(rng) });
            }
            if y + 1 < h {
                edges.push(Edge { u: id(x, y), v: id(x, y + 1), w: wt(rng) });
            }
            if x + 1 < w && y + 1 < h {
                // alternate the diagonal to even out degrees
                if (x + y) % 2 == 0 {
                    edges.push(Edge { u: id(x, y), v: id(x + 1, y + 1), w: wt(rng) });
                } else {
                    edges.push(Edge { u: id(x + 1, y), v: id(x, y + 1), w: wt(rng) });
                }
            }
        }
    }
    Graph::from_unique_edges(w * h, edges)
}

/// Annular mesh: a triangulated ring (like an airfoil boundary layer),
/// `rings` concentric circles of `seg` vertices each. Produces the same
/// degree profile as `tri_mesh` but with a cyclic structure so the BFS
/// tree has two long "arms" — a stress test for deep LCA paths.
pub fn ring_mesh(rings: usize, seg: usize, rng: &mut Rng) -> Graph {
    assert!(rings >= 2 && seg >= 3);
    let id = |r: usize, s: usize| -> u32 { (r * seg + (s % seg)) as u32 };
    let mut edges: Vec<Edge> = Vec::with_capacity(3 * rings * seg);
    let wt = |rng: &mut Rng| rng.range_f64(1.0, 10.0);
    for r in 0..rings {
        for s in 0..seg {
            edges.push(Edge {
                u: id(r, s).min(id(r, s + 1)),
                v: id(r, s).max(id(r, s + 1)),
                w: wt(rng),
            });
            if r + 1 < rings {
                edges.push(Edge { u: id(r, s), v: id(r + 1, s), w: wt(rng) });
                // diagonal
                edges.push(Edge {
                    u: id(r, s).min(id(r + 1, (s + 1) % seg)),
                    v: id(r, s).max(id(r + 1, (s + 1) % seg)),
                    w: wt(rng),
                });
            }
        }
    }
    let raw: Vec<(u32, u32, f64)> = edges.iter().map(|e| (e.u, e.v, e.w)).collect();
    Graph::from_edges(rings * seg, &raw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::is_connected;

    #[test]
    fn tri_mesh_degree_profile() {
        let g = tri_mesh(30, 30, &mut Rng::new(1));
        assert_eq!(g.num_vertices(), 900);
        assert!(is_connected(&g));
        assert!(g.max_degree() <= 8);
        // interior degree ~6 → avg degree close to 6 for a big mesh
        assert!(g.avg_degree() > 5.0, "avg {}", g.avg_degree());
    }

    #[test]
    fn tri_mesh_edge_count() {
        // (w-1)h + w(h-1) + (w-1)(h-1)
        let g = tri_mesh(5, 4, &mut Rng::new(2));
        assert_eq!(g.num_edges(), 4 * 4 + 5 * 3 + 4 * 3);
    }

    #[test]
    fn ring_mesh_connected_cyclic() {
        let g = ring_mesh(10, 40, &mut Rng::new(3));
        assert_eq!(g.num_vertices(), 400);
        assert!(is_connected(&g));
        assert!(g.num_edges() > g.num_vertices()); // has cycles
    }
}
