//! The 18-graph evaluation suite.
//!
//! Mirrors Table II of the paper row by row. The original rows are
//! SuiteSparse matrices (census/redistricting, SNAP social, coauthor /
//! citation, DIMACS10 FE meshes); this box is offline, so each row is a
//! synthetic graph from the same structural family at a scale that fits a
//! single-core container (≈20–80× smaller).
//! Family → regime correspondences that matter for the algorithms:
//!
//! * census grids → uniform small subtasks, feGRASS needs 1–6 passes;
//! * social R-MAT (`youtube`) → hub-dominated; feGRASS pass blow-up,
//!   pdGRASS giant single subtask (inner-parallel regime);
//! * coauthor communities → moderate skew, a few extra passes;
//! * FE meshes → near-uniform, outer-parallel near-ideal scaling.

use super::community::{community, CommunityParams};
use super::grid::grid;
use super::mesh::{ring_mesh, tri_mesh};
use super::rmat::{rmat, RmatParams};
use crate::graph::{largest_component, Graph};
use crate::util::Rng;

/// Structural family of a suite graph (drives expectations in benches).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    /// Census / redistricting contact graph (grid-like).
    Census,
    /// SNAP social network (power-law hubs).
    Social,
    /// Coauthor / citation / co-purchase community graph.
    Coauthor,
    /// DIMACS10 finite-element mesh.
    Mesh,
}

/// One row of the evaluation suite.
#[derive(Clone, Copy, Debug)]
pub struct SuiteEntry {
    /// Row id matching the paper's numbering, e.g. `"09-com-Youtube"`.
    pub name: &'static str,
    /// Structural family.
    pub family: Family,
    /// Paper's |V| (for the substitution record).
    pub paper_v: f64,
    /// Paper's |E|.
    pub paper_e: f64,
}

/// All 18 rows in paper order.
pub const SUITE: [SuiteEntry; 18] = [
    SuiteEntry { name: "01-mi2010", family: Family::Census, paper_v: 3.30e5, paper_e: 7.89e5 },
    SuiteEntry { name: "02-mo2010", family: Family::Census, paper_v: 3.44e5, paper_e: 8.28e5 },
    SuiteEntry { name: "03-oh2010", family: Family::Census, paper_v: 3.65e5, paper_e: 8.84e5 },
    SuiteEntry { name: "04-pa2010", family: Family::Census, paper_v: 4.22e5, paper_e: 1.03e6 },
    SuiteEntry { name: "05-il2010", family: Family::Census, paper_v: 4.52e5, paper_e: 1.08e6 },
    SuiteEntry { name: "06-tx2010", family: Family::Census, paper_v: 9.14e5, paper_e: 2.23e6 },
    SuiteEntry { name: "07-com-DBLP", family: Family::Coauthor, paper_v: 3.17e5, paper_e: 1.05e6 },
    SuiteEntry { name: "08-com-Amazon", family: Family::Coauthor, paper_v: 3.35e5, paper_e: 9.26e5 },
    SuiteEntry { name: "09-com-Youtube", family: Family::Social, paper_v: 1.13e6, paper_e: 2.99e6 },
    SuiteEntry { name: "10-coAuthorsCiteseer", family: Family::Coauthor, paper_v: 2.27e5, paper_e: 8.14e5 },
    SuiteEntry { name: "11-citationCiteseer", family: Family::Coauthor, paper_v: 2.68e5, paper_e: 1.16e6 },
    SuiteEntry { name: "12-coAuthorsDBLP", family: Family::Coauthor, paper_v: 2.99e5, paper_e: 9.78e5 },
    SuiteEntry { name: "13-coPapersDBLP", family: Family::Coauthor, paper_v: 5.40e5, paper_e: 1.52e7 },
    SuiteEntry { name: "14-NACA0015", family: Family::Mesh, paper_v: 1.04e6, paper_e: 3.11e6 },
    SuiteEntry { name: "15-M6", family: Family::Mesh, paper_v: 3.50e6, paper_e: 1.05e7 },
    SuiteEntry { name: "16-333SP", family: Family::Mesh, paper_v: 3.71e6, paper_e: 1.11e7 },
    SuiteEntry { name: "17-AS365", family: Family::Mesh, paper_v: 3.80e6, paper_e: 1.14e7 },
    SuiteEntry { name: "18-NLR", family: Family::Mesh, paper_v: 4.16e6, paper_e: 1.25e7 },
];

/// Scale knob for the whole suite. `1.0` is the default container scale
/// (|V| ≈ 10–45k); smaller values shrink every graph for smoke tests.
pub fn build(name: &str, scale: f64, seed: u64) -> Graph {
    // Warm the persistent worker pool while the graph is being built, so
    // downstream timed phases (spanning tree, recovery, PCG) never pay
    // lazy pool construction inside a measured region.
    crate::par::ThreadPool::global();
    let mut rng = Rng::new(seed ^ hash_name(name));
    let s = |x: usize| -> usize { ((x as f64 * scale.sqrt()).round() as usize).max(8) };
    let n = |x: usize| -> usize { ((x as f64 * scale).round() as usize).max(64) };
    let g = match name {
        "01-mi2010" => grid(s(125), s(125), 0.40, &mut rng),
        "02-mo2010" => grid(s(128), s(128), 0.41, &mut rng),
        "03-oh2010" => grid(s(132), s(132), 0.42, &mut rng),
        "04-pa2010" => grid(s(142), s(142), 0.44, &mut rng),
        "05-il2010" => grid(s(147), s(147), 0.39, &mut rng),
        "06-tx2010" => grid(s(209), s(209), 0.44, &mut rng),
        "07-com-DBLP" => community(
            CommunityParams { n: n(15_000), mean_size: 9.0, tail: 1.7, intra_p: 0.55, bridges: 2, max_size: 60 },
            &mut rng,
        ),
        "08-com-Amazon" => community(
            CommunityParams { n: n(16_000), mean_size: 5.0, tail: 2.0, intra_p: 0.45, bridges: 1, max_size: 40 },
            &mut rng,
        ),
        "09-com-Youtube" => {
            let sc = ((n(32_000) as f64).log2().ceil() as u32).max(8);
            rmat(sc, 8.0, RmatParams::youtube_like(), &mut rng)
        }
        "10-coAuthorsCiteseer" => community(
            CommunityParams { n: n(11_000), mean_size: 8.0, tail: 1.8, intra_p: 0.6, bridges: 1, max_size: 50 },
            &mut rng,
        ),
        "11-citationCiteseer" => community(
            CommunityParams { n: n(13_000), mean_size: 11.0, tail: 1.6, intra_p: 0.5, bridges: 3, max_size: 70 },
            &mut rng,
        ),
        "12-coAuthorsDBLP" => community(
            CommunityParams { n: n(14_500), mean_size: 8.5, tail: 1.8, intra_p: 0.55, bridges: 2, max_size: 55 },
            &mut rng,
        ),
        "13-coPapersDBLP" => community(
            CommunityParams { n: n(13_000), mean_size: 26.0, tail: 1.5, intra_p: 0.8, bridges: 2, max_size: 90 },
            &mut rng,
        ),
        "14-NACA0015" => tri_mesh(s(160), s(160), &mut rng),
        "15-M6" => tri_mesh(s(210), s(210), &mut rng),
        "16-333SP" => tri_mesh(s(215), s(215), &mut rng),
        "17-AS365" => ring_mesh(s(150), s(300), &mut rng),
        "18-NLR" => tri_mesh(s(222), s(222), &mut rng),
        other => panic!("unknown suite graph: {other}"),
    };
    // Paper selects single-connected-component matrices; R-MAT may emit
    // stragglers, so normalize here.
    let (cc, _) = largest_component(&g);
    cc
}

/// Default seed used by the experiment drivers.
pub const DEFAULT_SEED: u64 = 20250701;

/// Build a suite row at default scale/seed.
pub fn build_default(name: &str) -> Graph {
    build(name, 1.0, DEFAULT_SEED)
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a, so each row gets a distinct deterministic stream.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::is_connected;

    #[test]
    fn all_rows_build_small_scale() {
        for e in &SUITE {
            let g = build(e.name, 0.02, 7);
            assert!(g.num_vertices() >= 8, "{} too small", e.name);
            assert!(is_connected(&g), "{} disconnected", e.name);
        }
    }

    #[test]
    fn youtube_row_is_skewed_and_mesh_is_not() {
        let yt = build("09-com-Youtube", 0.1, DEFAULT_SEED);
        let m6 = build("15-M6", 0.1, DEFAULT_SEED);
        assert!(yt.max_degree() as f64 / yt.avg_degree() > 8.0);
        assert!((m6.max_degree() as f64) < 2.0 * m6.avg_degree() + 4.0);
    }

    #[test]
    fn deterministic_builds() {
        let a = build("07-com-DBLP", 0.05, 9);
        let b = build("07-com-DBLP", 0.05, 9);
        assert_eq!(a.num_edges(), b.num_edges());
        assert_eq!(a.num_vertices(), b.num_vertices());
    }

    #[test]
    #[should_panic(expected = "unknown suite graph")]
    fn unknown_name_panics() {
        build("nope", 1.0, 1);
    }
}
