//! Synthetic graph generators and the 18-row evaluation suite
//! (substitutes for the paper's SuiteSparse datasets; see `gen::suite`
//! for the per-row substitution rationale).

pub mod community;
pub mod grid;
pub mod mesh;
pub mod rmat;
pub mod suite;

pub use community::{community, CommunityParams};
pub use grid::grid;
pub use mesh::{ring_mesh, tri_mesh};
pub use rmat::{hub_graph, rmat, RmatParams};
pub use suite::{build as build_suite_graph, build_default, Family, SuiteEntry, DEFAULT_SEED, SUITE};
