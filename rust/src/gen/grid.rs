//! 2-D grid-family generators — the analogue of the paper's census /
//! redistricting graphs (`mi2010` … `tx2010`, DIMACS10).
//!
//! Those graphs are contact graphs of census blocks: planar-ish, low
//! maximum degree, |E|/|V| ≈ 2.4. A 4-neighbor grid plus a random sprinkle
//! of diagonals matches that density and produces the same *uniform*
//! subtask distribution regime (many small LCA groups) that drives the
//! paper's behaviour on this family.

use crate::graph::{Edge, Graph};
use crate::util::Rng;

/// Generate a `w × h` grid graph with 4-neighbor connectivity, plus each
/// cell's diagonal with probability `diag_p`, with weights uniform in
/// `[1, 10]` (the paper assigns uniform \[1,10\] weights to unweighted
/// inputs).
pub fn grid(w: usize, h: usize, diag_p: f64, rng: &mut Rng) -> Graph {
    assert!(w >= 2 && h >= 2);
    let id = |x: usize, y: usize| -> u32 { (y * w + x) as u32 };
    let mut edges: Vec<Edge> = Vec::with_capacity(2 * w * h + (diag_p * (w * h) as f64) as usize);
    let wt = |rng: &mut Rng| rng.range_f64(1.0, 10.0);
    for y in 0..h {
        for x in 0..w {
            if x + 1 < w {
                edges.push(Edge { u: id(x, y), v: id(x + 1, y), w: wt(rng) });
            }
            if y + 1 < h {
                edges.push(Edge { u: id(x, y), v: id(x, y + 1), w: wt(rng) });
            }
            if x + 1 < w && y + 1 < h && rng.next_f64() < diag_p {
                // one of the two diagonals, at random
                if rng.next_f64() < 0.5 {
                    edges.push(Edge { u: id(x, y), v: id(x + 1, y + 1), w: wt(rng) });
                } else {
                    edges.push(Edge { u: id(x + 1, y), v: id(x, y + 1), w: wt(rng) });
                }
            }
        }
    }
    Graph::from_unique_edges(w * h, edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::is_connected;

    #[test]
    fn grid_shape() {
        let mut rng = Rng::new(1);
        let g = grid(10, 7, 0.0, &mut rng);
        assert_eq!(g.num_vertices(), 70);
        // 4-neighbor grid: (w-1)h + w(h-1) edges
        assert_eq!(g.num_edges(), 9 * 7 + 10 * 6);
        assert!(is_connected(&g));
        assert!(g.max_degree() <= 4);
    }

    #[test]
    fn diagonals_increase_density() {
        let mut rng = Rng::new(2);
        let g0 = grid(20, 20, 0.0, &mut rng);
        let mut rng = Rng::new(2);
        let g1 = grid(20, 20, 0.9, &mut rng);
        assert!(g1.num_edges() > g0.num_edges());
        assert!(is_connected(&g1));
    }

    #[test]
    fn weights_in_range() {
        let mut rng = Rng::new(3);
        let g = grid(8, 8, 0.5, &mut rng);
        assert!(g.edges().iter().all(|e| (1.0..10.0).contains(&e.w)));
    }

    #[test]
    fn deterministic() {
        let a = grid(12, 12, 0.3, &mut Rng::new(7));
        let b = grid(12, 12, 0.3, &mut Rng::new(7));
        assert_eq!(a.num_edges(), b.num_edges());
        for (x, y) in a.edges().iter().zip(b.edges()) {
            assert_eq!((x.u, x.v), (y.u, y.v));
            assert_eq!(x.w, y.w);
        }
    }
}
