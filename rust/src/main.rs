//! `pdgrass` binary — leader entrypoint + CLI.
//!
//! See `pdgrass help` for verbs. The binary is self-contained after
//! `make artifacts`: Python never runs on the request path.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = pdgrass::cli::run(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
