//! Parallel stable merge sort without `T: Clone`.
//!
//! pdGRASS steps 2–3 sort the off-tree edges by resistance distance and
//! the subtasks by size; the paper's span analysis assumes an
//! `O(lg² n)`-span parallel merge sort. This is an **out-of-place merge
//! sort over a single scratch buffer**: one `Vec<MaybeUninit<T>>` is
//! allocated up front and every merge level *moves* elements bitwise
//! between `v` and the scratch (ping-pong), so nothing is cloned and no
//! per-merge buffers are allocated — the pre-rewrite implementation
//! required `T: Clone` and cloned whole sub-buffers at every level, an
//! O(n lg n) clone bill that `recovery`'s `OffTreeEdge` score sort paid
//! on every pass. Merges of large runs are **splitter-parallel**: the
//! longer run's median is ranked into the other run by binary search and
//! the two halves merge concurrently, forked via
//! [`pool::ThreadPool::join`](super::pool::ThreadPool::join) onto the
//! persistent pool. Stability holds (ties keep `v`-order, which the
//! subtask linked lists rely on), and the merge structure is independent
//! of scheduling, so output is deterministic for any pool state.
//!
//! # Panic safety
//!
//! The comparator is arbitrary user code and may panic mid-merge while
//! elements live partly in `v` and partly in the scratch. Every unsafe
//! phase is covered by a drop guard that, on unwind, moves the
//! not-yet-merged remainder so that **each element is live in `v` exactly
//! once** when the panic reaches the caller — no double drops, no leaks;
//! only the order is unspecified. The scratch buffer is `MaybeUninit`
//! and is never dropped as `T`.

use crate::par::ThreadPool;
use std::cmp::Ordering;
use std::mem::MaybeUninit;
use std::ptr;
use std::sync::atomic::{AtomicBool, Ordering as AtOrd};
use std::sync::OnceLock;

/// Below this many elements a slice is sorted or merged serially.
const SEQ_CUTOFF: usize = 4096;

/// The live serial cutoff: [`SEQ_CUTOFF`] unless `PDGRASS_SORT_CUTOFF`
/// overrides it (read once, values below 2 ignored). Sanitizer CI
/// shrinks it so Miri/TSan exercise the parallel merge paths at tiny
/// inputs. Output is unaffected: the sort produces the stable order of
/// the comparator whatever the cutoff, so the override is observable
/// only in timing.
fn seq_cutoff() -> usize {
    static CUTOFF: OnceLock<usize> = OnceLock::new();
    *CUTOFF.get_or_init(|| {
        std::env::var("PDGRASS_SORT_CUTOFF")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&c| c >= 2)
            .unwrap_or(SEQ_CUTOFF)
    })
}

/// Parallel stable sort by a key-extraction function.
///
/// `key` is evaluated **exactly once per element** (in parallel, via
/// [`super::par_map`]): keys are cached up front, an index permutation is
/// sorted against the cache, and the permutation is applied in place by
/// cycle-following swaps. The pre-rewrite version re-invoked `key` inside
/// the comparator on *every comparison* — Θ(n lg n) evaluations, which
/// made expensive keys dominate the sort.
pub fn par_sort_by_key<T, K, F>(v: &mut [T], threads: usize, key: F)
where
    T: Sync,
    K: PartialOrd + Send + Sync,
    F: Fn(&T) -> K + Sync,
{
    let n = v.len();
    if n <= 1 {
        return;
    }
    assert!(n <= u32::MAX as usize, "par_sort_by_key: slice longer than u32 index space");
    let keys: Vec<K> = super::par_map(v, threads, |t| key(t));
    let mut idx: Vec<u32> = (0..n as u32).collect();
    // Ties broken by original index → stable. Incomparable key pairs
    // (NaN) fall back to Equal like the pre-rewrite comparator did; as
    // with `slice::sort_by_key`, keys that violate total order give an
    // unspecified (but memory-safe) permutation.
    par_sort_by(&mut idx, threads, &|&a: &u32, &b: &u32| {
        keys[a as usize]
            .partial_cmp(&keys[b as usize])
            .unwrap_or(Ordering::Equal)
            .then(a.cmp(&b))
    });
    // idx[new] = old. Invert to target slots, then place every element by
    // cycle-following swaps — no clones, no key recomputation.
    let mut inv = vec![0u32; n];
    for (new_pos, &old_pos) in idx.iter().enumerate() {
        inv[old_pos as usize] = new_pos as u32;
    }
    for i in 0..n {
        while inv[i] as usize != i {
            let j = inv[i] as usize;
            v.swap(i, j);
            inv.swap(i, j);
        }
    }
}

/// Parallel stable sort with an explicit comparator. `T` only needs to be
/// `Send` — elements are moved, never cloned.
pub fn par_sort_by<T, F>(v: &mut [T], threads: usize, cmp: &F)
where
    T: Send,
    F: Fn(&T, &T) -> Ordering + Sync,
{
    let threads = threads.max(1);
    let n = v.len();
    // ZSTs: sorting is a permutation of identical values; run std's sort
    // for the comparator side effects (raw-pointer distance math below
    // is not defined for zero-sized T).
    if threads == 1 || n < seq_cutoff() || std::mem::size_of::<T>() == 0 {
        v.sort_by(cmp);
        return;
    }
    // The single scratch allocation for the whole sort; merge levels
    // ping-pong elements between `v` and this buffer. Never dropped as
    // `T` — liveness always ends (and, on panic, is restored) in `v`.
    let mut scratch: Vec<MaybeUninit<T>> = Vec::with_capacity(n);
    // SAFETY: MaybeUninit<T> requires no initialization.
    unsafe { scratch.set_len(n) };
    let depth = super::fork_depth(threads);
    // SAFETY: scratch has length n and does not alias v; `sort_inplace`'s
    // contract leaves all n elements live in `v` on return and on unwind.
    unsafe {
        sort_inplace(v.as_mut_ptr(), n, scratch.as_mut_ptr() as *mut T, depth, cmp);
    }
}

/// `Send`-able raw pointer for moving sub-slices into fork closures.
///
/// Access goes through [`Raw::p`] so closures capture the whole wrapper:
/// edition-2021 disjoint capture would otherwise capture the inner
/// `*mut T` field directly, which is neither `Send` nor `Sync`. Same
/// pattern as `par::SendPtr`, but kept separate on purpose: the sort
/// moves `T` values across threads, so `Raw`'s marker impls are gated on
/// `T: Send` (compiler-checked), whereas `SendPtr` is unconditionally
/// `Send`/`Sync` for disjoint-index writes.
struct Raw<T>(*mut T);
impl<T> Clone for Raw<T> {
    fn clone(&self) -> Self {
        Raw(self.0)
    }
}
impl<T> Copy for Raw<T> {}
// SAFETY: a raw pointer to `T: Send` values may cross threads; the fork
// closures only touch disjoint sub-ranges (see the merge contracts).
unsafe impl<T: Send> Send for Raw<T> {}
// SAFETY: shared `Raw`s only hand out the pointer via `p()`; disjoint
// access across the fork is each call site's documented obligation.
unsafe impl<T: Send> Sync for Raw<T> {}

impl<T> Raw<T> {
    fn p(&self) -> *mut T {
        self.0
    }
}

/// Sort `v[0..n]` in place, using `scratch[0..n]` (uninitialized, no
/// live elements) as workspace.
///
/// Liveness contract: on return **and on unwind**, all `n` elements are
/// live in `v` and `scratch` holds none.
///
/// # Safety
/// `v` and `scratch` must each be valid for `n` elements, must not
/// overlap, and `scratch` must hold no live elements on entry.
unsafe fn sort_inplace<T, F>(v: *mut T, n: usize, scratch: *mut T, depth: usize, cmp: &F)
where
    T: Send,
    F: Fn(&T, &T) -> Ordering + Sync,
{
    if depth == 0 || n < seq_cutoff() {
        // std's sort is stable and panic-safe (slice stays a permutation).
        std::slice::from_raw_parts_mut(v, n).sort_by(cmp);
        return;
    }
    let mid = n / 2;
    let moved_l = AtomicBool::new(false);
    let moved_r = AtomicBool::new(false);
    // On unwind out of the join: any half whose `moved` flag is set is
    // live in its scratch half (per `sort_move`'s contract) — copy it
    // back so `v` is fully live again. Order is irrelevant mid-unwind;
    // only exactly-once liveness matters.
    struct Unmove<T> {
        v: *mut T,
        scratch: *mut T,
        mid: usize,
        n: usize,
        moved_l: *const AtomicBool,
        moved_r: *const AtomicBool,
    }
    impl<T> Drop for Unmove<T> {
        fn drop(&mut self) {
            // SAFETY: a set `moved` flag means that half is fully live in
            // its scratch range (per `sort_move`'s contract) and `v`'s
            // matching range is stale, so the copy restores exactly-once
            // liveness; the flag pointers outlive the guard (same frame).
            unsafe {
                if (*self.moved_l).load(AtOrd::Acquire) {
                    ptr::copy_nonoverlapping(self.scratch, self.v, self.mid);
                }
                if (*self.moved_r).load(AtOrd::Acquire) {
                    ptr::copy_nonoverlapping(
                        self.scratch.add(self.mid),
                        self.v.add(self.mid),
                        self.n - self.mid,
                    );
                }
            }
        }
    }
    let guard = Unmove { v, scratch, mid, n, moved_l: &moved_l, moved_r: &moved_r };
    {
        let (vl, sl) = (Raw(v), Raw(scratch));
        let (vr, sr) = (Raw(v.add(mid)), Raw(scratch.add(mid)));
        let (ml, mr) = (&moved_l, &moved_r);
        ThreadPool::global().join(
            // SAFETY: left half — `v[..mid]` / `scratch[..mid]` are valid,
            // disjoint from the right half's ranges, and live-in-`v`.
            move || unsafe { sort_move(vl.p(), mid, sl.p(), depth - 1, cmp, ml) },
            // SAFETY: right half — same contract over `[mid..n]`.
            move || unsafe { sort_move(vr.p(), n - mid, sr.p(), depth - 1, cmp, mr) },
        );
    }
    // Both sorted halves are now live in scratch; the merge below owns
    // liveness restoration from here (its contract: dst fully live even
    // on unwind), so the join guard is disarmed.
    std::mem::forget(guard);
    par_merge(scratch, mid, scratch.add(mid), n - mid, v, depth, cmp);
}

/// Sort `src[0..n]`, leaving the sorted run in `dst` (uninitialized on
/// entry); `src` is stale afterwards.
///
/// Liveness contract: on success `dst` is fully live and `moved` is set.
/// On unwind, *if `moved` is set* the elements are fully live in `dst`,
/// otherwise fully live in `src`. The flag flips exactly at the point
/// where liveness transitions (no panic is possible between the store
/// and the guarded region that upholds the `dst` side).
///
/// # Safety
/// `src` and `dst` must each be valid for `n` elements and must not
/// overlap; `src` is fully live and `dst` holds no live elements on
/// entry.
unsafe fn sort_move<T, F>(
    src: *mut T,
    n: usize,
    dst: *mut T,
    depth: usize,
    cmp: &F,
    moved: &AtomicBool,
) where
    T: Send,
    F: Fn(&T, &T) -> Ordering + Sync,
{
    if depth == 0 || n < seq_cutoff() {
        // Panic here leaves src live (std sort is in-place) with the
        // flag still unset — contract holds.
        std::slice::from_raw_parts_mut(src, n).sort_by(cmp);
        ptr::copy_nonoverlapping(src, dst, n);
        moved.store(true, AtOrd::Release);
        return;
    }
    let mid = n / 2;
    {
        let (sl, dl) = (Raw(src), Raw(dst));
        let (sr, dr) = (Raw(src.add(mid)), Raw(dst.add(mid)));
        // Each half sorts *in place* in src (its dst half is only
        // workspace), so on unwind out of this join both halves are
        // live in src and the flag is correctly still unset.
        ThreadPool::global().join(
            // SAFETY: left half of src sorts in place using the left half
            // of dst as workspace — valid, disjoint, live-in-src.
            move || unsafe { sort_inplace(sl.p(), mid, dl.p(), depth - 1, cmp) },
            // SAFETY: right half — same contract over `[mid..n]`.
            move || unsafe { sort_inplace(sr.p(), n - mid, dr.p(), depth - 1, cmp) },
        );
    }
    // Liveness transitions to dst now: par_merge guarantees dst fully
    // live on success and on unwind, and nothing between the store and
    // its entry can panic.
    moved.store(true, AtOrd::Release);
    par_merge(src, mid, src.add(mid), n - mid, dst, depth, cmp);
}

/// Merge sorted runs `a[0..an]` and `b[0..bn]` into `dst[0..an+bn]`,
/// splitter-parallel: rank the longer run's median into the other run,
/// fork the two halves. Ties keep `a` before `b` → stable.
///
/// Liveness contract: entry — `a`, `b` live, `dst` uninitialized; on
/// success **and on unwind** `dst` is fully live and the runs are stale.
///
/// # Safety
/// `a`, `b`, and `dst` must be valid for `an`, `bn`, and `an + bn`
/// elements respectively, pairwise non-overlapping, with `a`/`b` fully
/// live and `dst` holding no live elements on entry.
unsafe fn par_merge<T, F>(
    a: *mut T,
    an: usize,
    b: *mut T,
    bn: usize,
    dst: *mut T,
    depth: usize,
    cmp: &F,
) where
    T: Send,
    F: Fn(&T, &T) -> Ordering + Sync,
{
    if depth == 0 || an + bn < seq_cutoff() || an == 0 || bn == 0 {
        serial_merge(a, an, b, bn, dst, cmp);
        return;
    }
    // The splitter binary search calls `cmp`; nothing is consumed yet,
    // so on unwind simply move both runs into dst wholesale.
    struct AllIn<T> {
        a: *mut T,
        an: usize,
        b: *mut T,
        bn: usize,
        dst: *mut T,
    }
    impl<T> Drop for AllIn<T> {
        fn drop(&mut self) {
            // SAFETY: the guard is armed only while both runs are still
            // fully live and `dst` is untouched (the splitter search
            // consumes nothing), so a wholesale move is exactly-once.
            unsafe {
                ptr::copy_nonoverlapping(self.a, self.dst, self.an);
                ptr::copy_nonoverlapping(self.b, self.dst.add(self.an), self.bn);
            }
        }
    }
    let guard = AllIn { a, an, b, bn, dst };
    let (ha, hb);
    if an >= bn {
        ha = an / 2;
        // Rank a's median in b counting strict `Less`: b-elements equal
        // to the pivot stay right, after all equal a-elements → stable.
        hb = lower_bound(b, bn, &*a.add(ha), cmp);
    } else {
        hb = bn / 2;
        // Pivot from b: equal a-elements must land *left* (a precedes b
        // on ties), so count `<=` in a.
        ha = upper_bound(a, an, &*b.add(hb), cmp);
    }
    std::mem::forget(guard);
    // Fork the two sub-merges over disjoint (a, b, dst) triples. A side
    // that panics restores its own dst part (recursive contract); a side
    // that never ran (skipped after the other panicked) is restored here.
    let entered_l = AtomicBool::new(false);
    let entered_r = AtomicBool::new(false);
    struct FillSkipped<T> {
        a: *mut T,
        an: usize,
        b: *mut T,
        bn: usize,
        ha: usize,
        hb: usize,
        dst: *mut T,
        entered_l: *const AtomicBool,
        entered_r: *const AtomicBool,
    }
    impl<T> Drop for FillSkipped<T> {
        fn drop(&mut self) {
            // SAFETY: a clear `entered` flag means that side's sub-merge
            // never started, so its (a, b) parts are still live and its
            // dst part unwritten; the flag pointers outlive the guard
            // (same frame), and each side's ranges are disjoint.
            unsafe {
                if !(*self.entered_l).load(AtOrd::Acquire) {
                    ptr::copy_nonoverlapping(self.a, self.dst, self.ha);
                    ptr::copy_nonoverlapping(self.b, self.dst.add(self.ha), self.hb);
                }
                if !(*self.entered_r).load(AtOrd::Acquire) {
                    let off = self.ha + self.hb;
                    ptr::copy_nonoverlapping(
                        self.a.add(self.ha),
                        self.dst.add(off),
                        self.an - self.ha,
                    );
                    ptr::copy_nonoverlapping(
                        self.b.add(self.hb),
                        self.dst.add(off + self.an - self.ha),
                        self.bn - self.hb,
                    );
                }
            }
        }
    }
    let guard2 = FillSkipped {
        a,
        an,
        b,
        bn,
        ha,
        hb,
        dst,
        entered_l: &entered_l,
        entered_r: &entered_r,
    };
    {
        let (pa, pb, pd) = (Raw(a), Raw(b), Raw(dst));
        let (el, er) = (&entered_l, &entered_r);
        ThreadPool::global().join(
            move || {
                el.store(true, AtOrd::Release);
                // SAFETY: left sub-merge over `(a[..ha], b[..hb],
                // dst[..ha+hb])` — valid, live, disjoint from the right's.
                unsafe { par_merge(pa.p(), ha, pb.p(), hb, pd.p(), depth - 1, cmp) }
            },
            move || {
                er.store(true, AtOrd::Release);
                // SAFETY: right sub-merge over the complementary ranges —
                // same contract, disjoint from the left's.
                unsafe {
                    par_merge(
                        pa.p().add(ha),
                        an - ha,
                        pb.p().add(hb),
                        bn - hb,
                        pd.p().add(ha + hb),
                        depth - 1,
                        cmp,
                    )
                }
            },
        );
    }
    std::mem::forget(guard2);
}

/// Serial stable merge of `a[0..an]`, `b[0..bn]` into `dst` by bitwise
/// moves. The tail guard doubles as the success-path epilogue: whatever
/// remains unconsumed (on completion of the loop *or* on a comparator
/// panic) is copied into the unwritten remainder of `dst`, so `dst` ends
/// fully live on every exit path.
///
/// # Safety
/// Same contract as [`par_merge`]: valid, pairwise non-overlapping
/// ranges with `a`/`b` live and `dst` uninitialized on entry.
unsafe fn serial_merge<T, F>(a: *mut T, an: usize, b: *mut T, bn: usize, dst: *mut T, cmp: &F)
where
    F: Fn(&T, &T) -> Ordering,
{
    struct Tail<T> {
        a: *mut T,
        a_end: *mut T,
        b: *mut T,
        b_end: *mut T,
        dst: *mut T,
    }
    impl<T> Drop for Tail<T> {
        fn drop(&mut self) {
            // SAFETY: the cursors always bound the unconsumed (still
            // live) tails of each run and the unwritten suffix of `dst`,
            // so moving the remainders completes `dst` exactly once.
            unsafe {
                let ra = self.a_end.offset_from(self.a) as usize;
                ptr::copy_nonoverlapping(self.a, self.dst, ra);
                let rb = self.b_end.offset_from(self.b) as usize;
                ptr::copy_nonoverlapping(self.b, self.dst.add(ra), rb);
            }
        }
    }
    let mut g = Tail { a, a_end: a.add(an), b, b_end: b.add(bn), dst };
    while g.a < g.a_end && g.b < g.b_end {
        // `<=` keeps elements of `a` first on ties → stability.
        if cmp(&*g.a, &*g.b) != Ordering::Greater {
            ptr::copy_nonoverlapping(g.a, g.dst, 1);
            g.a = g.a.add(1);
        } else {
            ptr::copy_nonoverlapping(g.b, g.dst, 1);
            g.b = g.b.add(1);
        }
        g.dst = g.dst.add(1);
    }
    // Exactly one run has a remaining tail; the guard's Drop moves it.
    drop(g);
}

/// Stable merge of two sorted runs by **moving** elements (`T` needs no
/// `Clone`). Ties keep `a` before `b`, so merging locally-sorted chunk
/// runs with the earlier chunk on the `a` side reproduces exactly what a
/// global stable sort would produce.
///
/// This is the safe, caller-side counterpart of the ping-pong merges
/// above: the streamed pipeline merges completed runs on the consumer
/// thread while producers are still scoring later chunks, so it wants a
/// simple allocation-per-merge move merge rather than scratch-buffer
/// machinery.
pub fn merge_runs<T, F>(a: Vec<T>, b: Vec<T>, cmp: &F) -> Vec<T>
where
    F: Fn(&T, &T) -> Ordering,
{
    merge_runs_with(a, b, cmp, |_| {})
}

/// The one move-merge loop behind both [`merge_runs`] and
/// [`RunMerger::finish_with`]: merge `a` and `b` stably (ties keep `a`
/// first), invoking `emit` on every element in output order as it lands.
/// Keeping a single implementation is load-bearing — the streamed
/// pipeline's bitwise-parity guarantee rests on every merge agreeing on
/// the tie-handling.
fn merge_runs_with<T, F, E>(a: Vec<T>, b: Vec<T>, cmp: &F, mut emit: E) -> Vec<T>
where
    F: Fn(&T, &T) -> Ordering,
    E: FnMut(&T),
{
    if a.is_empty() {
        for x in &b {
            emit(x);
        }
        return b;
    }
    if b.is_empty() {
        for x in &a {
            emit(x);
        }
        return a;
    }
    let mut out = Vec::with_capacity(a.len() + b.len());
    let mut ia = a.into_iter();
    let mut ib = b.into_iter();
    let mut xa = ia.next();
    let mut xb = ib.next();
    loop {
        match (xa.take(), xb.take()) {
            (Some(va), Some(vb)) => {
                // `<=` keeps `a` first on ties → stability.
                if cmp(&va, &vb) != Ordering::Greater {
                    emit(&va);
                    out.push(va);
                    xa = ia.next();
                    xb = Some(vb);
                } else {
                    emit(&vb);
                    out.push(vb);
                    xb = ib.next();
                    xa = Some(va);
                }
            }
            (Some(va), None) => {
                emit(&va);
                out.push(va);
                for x in ia {
                    emit(&x);
                    out.push(x);
                }
                return out;
            }
            (None, Some(vb)) => {
                emit(&vb);
                out.push(vb);
                for x in ib {
                    emit(&x);
                    out.push(x);
                }
                return out;
            }
            (None, None) => return out,
        }
    }
}

/// Incremental merger of sorted runs arriving in stream order — the
/// consumer half of the streamed sort: push each locally-sorted chunk as
/// it is produced; the merger maintains a binary-counter stack (runs of
/// equal level merge immediately, like a bottom-up merge sort), so the
/// total merge work is `O(n lg k)` for `k` chunks and the merge
/// *structure* depends only on the number of pushes — never on timing —
/// keeping the output deterministic.
///
/// Stability: pushes must arrive in ascending chunk order; every merge
/// keeps the earlier run on the left, so ties preserve chunk order and
/// the result equals a global stable sort of the concatenated runs. (The
/// pipeline's comparators are strict total orders — ties broken by edge
/// id — so the result is the unique sorted sequence either way.)
pub struct RunMerger<'f, T, F> {
    /// `(level, run)` stack; levels strictly decrease bottom-to-top
    /// between merges, exactly one run per binary-counter bit.
    runs: Vec<(u32, Vec<T>)>,
    cmp: &'f F,
}

impl<'f, T, F> RunMerger<'f, T, F>
where
    F: Fn(&T, &T) -> Ordering,
{
    /// Empty merger over `cmp`.
    pub fn new(cmp: &'f F) -> RunMerger<'f, T, F> {
        RunMerger { runs: Vec::new(), cmp }
    }

    /// Push the next sorted run (ascending chunk order), merging
    /// equal-level runs eagerly.
    pub fn push(&mut self, run: Vec<T>) {
        let mut level = 0u32;
        let mut cur = run;
        while let Some(&(top_level, _)) = self.runs.last() {
            if top_level != level {
                break;
            }
            let (_, older) = self.runs.pop().expect("top run just observed");
            cur = merge_runs(older, cur, self.cmp);
            level += 1;
        }
        self.runs.push((level, cur));
    }

    /// Total elements currently held.
    pub fn len(&self) -> usize {
        self.runs.iter().map(|(_, r)| r.len()).sum()
    }

    /// True if no elements were pushed.
    pub fn is_empty(&self) -> bool {
        self.runs.iter().all(|(_, r)| r.is_empty())
    }

    /// Merge the remaining stack down to the final sorted vector.
    pub fn finish(self) -> Vec<T> {
        self.finish_with(|_| {})
    }

    /// As [`RunMerger::finish`], additionally invoking `emit` on each
    /// element of the **final** merge in output order, as it lands — the
    /// hook the streamed pipeline uses to fuse the next stage (LCA
    /// subtask grouping) into the last merge pass instead of re-walking
    /// the finished array behind another barrier.
    pub fn finish_with(mut self, mut emit: impl FnMut(&T)) -> Vec<T> {
        // Collapse to at most two runs with ordinary merges…
        while self.runs.len() > 2 {
            let (_, newer) = self.runs.pop().expect("len checked");
            let (lvl, older) = self.runs.pop().expect("len checked");
            self.runs.push((lvl, merge_runs(older, newer, self.cmp)));
        }
        // …then run the last merge through `emit` (same merge loop as
        // every other level — see `merge_runs_with`).
        match (self.runs.pop(), self.runs.pop()) {
            (None, _) => Vec::new(),
            (Some((_, only)), None) => {
                for x in &only {
                    emit(x);
                }
                only
            }
            (Some((_, newer)), Some((_, older))) => merge_runs_with(older, newer, self.cmp, emit),
        }
    }
}

/// Count of elements in sorted `run[0..len]` strictly less than `pivot`.
///
/// # Safety
/// `run` must be valid for `len` live elements.
unsafe fn lower_bound<T, F>(run: *const T, len: usize, pivot: &T, cmp: &F) -> usize
where
    F: Fn(&T, &T) -> Ordering,
{
    let (mut lo, mut hi) = (0usize, len);
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if cmp(&*run.add(mid), pivot) == Ordering::Less {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Count of elements in sorted `run[0..len]` less than or equal to
/// `pivot` (i.e. comparing not-`Greater`).
///
/// # Safety
/// `run` must be valid for `len` live elements.
unsafe fn upper_bound<T, F>(run: *const T, len: usize, pivot: &T, cmp: &F) -> usize
where
    F: Fn(&T, &T) -> Ordering,
{
    let (mut lo, mut hi) = (0usize, len);
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if cmp(&*run.add(mid), pivot) != Ordering::Greater {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::sync::atomic::{AtomicUsize, Ordering as AtomOrd};

    #[test]
    fn sorts_like_std() {
        let mut rng = Rng::new(5);
        for n in [0usize, 1, 10, 5000, 20_000] {
            let mut v: Vec<u64> = (0..n).map(|_| rng.next_u64() % 1000).collect();
            let mut expect = v.clone();
            expect.sort();
            par_sort_by_key(&mut v, 4, |x| *x);
            assert_eq!(v, expect, "n={n}");
        }
    }

    #[test]
    fn stability_preserved() {
        let mut rng = Rng::new(6);
        // (key, original index); ties on key must keep index order.
        let mut v: Vec<(u32, usize)> =
            (0..30_000).map(|i| ((rng.next_u32() % 16), i)).collect();
        par_sort_by(&mut v, 8, &|a: &(u32, usize), b: &(u32, usize)| a.0.cmp(&b.0));
        for w in v.windows(2) {
            if w[0].0 == w[1].0 {
                assert!(w[0].1 < w[1].1, "stability violated: {:?}", w);
            }
        }
    }

    #[test]
    fn sorts_floats_descending() {
        let mut rng = Rng::new(7);
        let mut v: Vec<f64> = (0..10_000).map(|_| rng.next_f64()).collect();
        par_sort_by(&mut v, 4, &|a: &f64, b: &f64| b.partial_cmp(a).unwrap());
        for w in v.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }

    /// A payload that is deliberately `!Clone` (and `!Copy`): the whole
    /// point of the rewrite. Holds (key, original index) for stability
    /// checking.
    struct NoClone {
        key: u64,
        idx: u32,
    }

    #[test]
    fn sorts_non_clone_payload_stably() {
        let mut rng = Rng::new(8);
        let mut v: Vec<NoClone> =
            (0..25_000).map(|i| NoClone { key: rng.next_u64() % 64, idx: i }).collect();
        par_sort_by(&mut v, 4, &|a: &NoClone, b: &NoClone| a.key.cmp(&b.key));
        for w in v.windows(2) {
            assert!(w[0].key <= w[1].key);
            if w[0].key == w[1].key {
                assert!(w[0].idx < w[1].idx, "stability violated");
            }
        }
        // Every element survived the ping-pong exactly once.
        let mut seen: Vec<u32> = v.iter().map(|e| e.idx).collect();
        seen.sort_unstable();
        assert!(seen.iter().enumerate().all(|(i, &x)| x == i as u32));
    }

    #[test]
    fn key_function_called_exactly_once_per_element() {
        let calls = AtomicUsize::new(0);
        let mut rng = Rng::new(9);
        let n = 20_000usize;
        let mut v: Vec<u64> = (0..n).map(|_| rng.next_u64() % 500).collect();
        let mut expect = v.clone();
        expect.sort();
        par_sort_by_key(&mut v, 4, |x: &u64| {
            calls.fetch_add(1, AtomOrd::Relaxed);
            *x
        });
        assert_eq!(v, expect);
        assert_eq!(
            calls.load(AtomOrd::Relaxed),
            n,
            "expensive key must be cached, not recomputed per comparison"
        );
    }

    #[test]
    fn adversarial_shapes_match_std() {
        let n = 3 * SEQ_CUTOFF;
        let cases: Vec<Vec<u64>> = vec![
            (0..n as u64).collect(),                  // sorted
            (0..n as u64).rev().collect(),            // reversed
            vec![7; n],                               // all equal
            vec![],                                   // empty
            vec![42],                                 // single
        ];
        for mut v in cases {
            let mut expect = v.clone();
            expect.sort();
            par_sort_by(&mut v, 8, &|a: &u64, b: &u64| a.cmp(b));
            assert_eq!(v, expect);
        }
    }

    #[test]
    fn merge_runs_is_stable_and_complete() {
        // (key, origin) pairs: ties must keep the `a` run first.
        let a: Vec<(u32, u8)> = vec![(1, 0), (3, 0), (3, 0), (7, 0)];
        let b: Vec<(u32, u8)> = vec![(0, 1), (3, 1), (8, 1)];
        let cmp = |x: &(u32, u8), y: &(u32, u8)| x.0.cmp(&y.0);
        let m = merge_runs(a, b, &cmp);
        assert_eq!(m, vec![(0, 1), (1, 0), (3, 0), (3, 0), (3, 1), (7, 0), (8, 1)]);
        // Empty sides pass through.
        assert_eq!(merge_runs(Vec::new(), vec![(2u32, 1u8)], &cmp), vec![(2, 1)]);
        assert_eq!(merge_runs(vec![(2u32, 0u8)], Vec::new(), &cmp), vec![(2, 0)]);
    }

    #[test]
    fn run_merger_matches_global_stable_sort() {
        let mut rng = Rng::new(12);
        for chunks in [1usize, 2, 3, 7, 16, 33] {
            let cmp = |x: &(u32, u32), y: &(u32, u32)| x.0.cmp(&y.0);
            let mut merger = RunMerger::new(&cmp);
            let mut all: Vec<(u32, u32)> = Vec::new();
            let mut idx = 0u32;
            for c in 0..chunks {
                let len = 1 + (rng.next_u32() as usize % 50);
                let mut run: Vec<(u32, u32)> = (0..len)
                    .map(|_| {
                        let v = (rng.next_u32() % 8, idx);
                        idx += 1;
                        v
                    })
                    .collect();
                run.sort_by(cmp);
                all.extend(run.iter().copied());
                merger.push(run);
                assert!(!merger.is_empty(), "chunk {c} pushed");
            }
            assert_eq!(merger.len(), all.len());
            let merged = merger.finish();
            // Ties on key must keep chunk-concatenation (= push) order,
            // which is what a global stable sort of `all` produces.
            all.sort_by(cmp);
            assert_eq!(merged, all, "chunks={chunks}");
        }
    }

    #[test]
    fn run_merger_finish_with_emits_final_order_exactly_once() {
        let cmp = |x: &u64, y: &u64| x.cmp(y);
        for chunks in [0usize, 1, 2, 5, 9] {
            let mut rng = Rng::new(40 + chunks as u64);
            let mut merger = RunMerger::new(&cmp);
            for _ in 0..chunks {
                let mut run: Vec<u64> = (0..20).map(|_| rng.next_u64() % 100).collect();
                run.sort();
                merger.push(run);
            }
            let mut emitted: Vec<u64> = Vec::new();
            let out = merger.finish_with(|&x| emitted.push(x));
            assert_eq!(emitted, out, "chunks={chunks}: emit order must be output order");
            assert!(out.windows(2).all(|w| w[0] <= w[1]), "chunks={chunks}");
            assert_eq!(out.len(), chunks * 20);
        }
    }

    #[test]
    fn run_merger_moves_non_clone_payloads() {
        let cmp = |x: &NoClone, y: &NoClone| x.key.cmp(&y.key);
        let mut merger = RunMerger::new(&cmp);
        for c in 0..4u32 {
            let mut run: Vec<NoClone> = (0..100)
                .map(|k| NoClone { key: ((k * 37 + c) % 50) as u64, idx: c * 100 + k })
                .collect();
            run.sort_by(cmp);
            merger.push(run);
        }
        let out = merger.finish();
        assert_eq!(out.len(), 400);
        let mut seen: Vec<u32> = out.iter().map(|e| e.idx).collect();
        seen.sort_unstable();
        assert!(seen.iter().enumerate().all(|(i, &x)| x == i as u32));
    }

    /// Comparator panics mid-sort on a `Drop` payload: afterwards every
    /// element must be live in `v` exactly once (no double drop, no
    /// leak), and the eventual `Vec` drop must run n destructors.
    #[test]
    fn comparator_panic_preserves_liveness() {
        static DROPS: AtomicUsize = AtomicUsize::new(0);
        struct Tracked(u64);
        impl Drop for Tracked {
            fn drop(&mut self) {
                DROPS.fetch_add(1, AtomOrd::Relaxed);
            }
        }
        let n = 20_000usize;
        let mut rng = Rng::new(10);
        {
            let mut v: Vec<Tracked> = {
                let mut vals: Vec<u64> = (0..n as u64).collect();
                // scramble so merges do real work
                for i in (1..vals.len()).rev() {
                    let j = (rng.next_u64() % (i as u64 + 1)) as usize;
                    vals.swap(i, j);
                }
                vals.into_iter().map(Tracked).collect()
            };
            let budget = AtomicUsize::new(60_000);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                par_sort_by(&mut v, 4, &|a: &Tracked, b: &Tracked| {
                    if budget.fetch_sub(1, AtomOrd::Relaxed) == 0 {
                        panic!("comparator budget exhausted");
                    }
                    a.0.cmp(&b.0)
                });
            }));
            assert!(result.is_err(), "comparator panic must propagate");
            // No element was dropped during the unwind...
            assert_eq!(DROPS.load(AtomOrd::Relaxed), 0);
            // ...and the multiset is intact: each value exactly once.
            let mut seen: Vec<u64> = v.iter().map(|t| t.0).collect();
            seen.sort_unstable();
            assert!(seen.iter().enumerate().all(|(i, &x)| x == i as u64));
        }
        // Dropping the Vec runs each destructor exactly once.
        assert_eq!(DROPS.load(AtomOrd::Relaxed), n);

        // Second scenario: each leaf range is already sorted (adaptive
        // leaf sorts spend ~n comparisons), so a mid-sized budget lands
        // the panic inside the splitter-parallel merge phase instead,
        // exercising the AllIn/FillSkipped/Tail guards.
        DROPS.store(0, AtomOrd::Relaxed);
        {
            // 4 leaves of 5000 (threads=4 → fork depth 2, exact halving):
            // leaf j holds j, j+4, j+8, … ascending, so every merge
            // interleaves maximally.
            let mut v: Vec<Tracked> = Vec::with_capacity(n);
            for leaf in 0..4u64 {
                for k in 0..(n as u64 / 4) {
                    v.push(Tracked(leaf + 4 * k));
                }
            }
            let budget = AtomicUsize::new(35_000);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                par_sort_by(&mut v, 4, &|a: &Tracked, b: &Tracked| {
                    if budget.fetch_sub(1, AtomOrd::Relaxed) == 0 {
                        panic!("comparator budget exhausted (merge phase)");
                    }
                    a.0.cmp(&b.0)
                });
            }));
            assert!(result.is_err(), "merge-phase panic must propagate");
            assert_eq!(DROPS.load(AtomOrd::Relaxed), 0);
            let mut seen: Vec<u64> = v.iter().map(|t| t.0).collect();
            seen.sort_unstable();
            assert!(seen.iter().enumerate().all(|(i, &x)| x == i as u64));
        }
        assert_eq!(DROPS.load(AtomOrd::Relaxed), n);
    }
}
