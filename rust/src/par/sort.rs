//! Parallel stable merge sort.
//!
//! pdGRASS steps 2–3 sort the off-tree edges by resistance distance and the
//! subtasks by size; the paper's span analysis assumes an `O(lg² n)`-span
//! parallel merge sort. This is a fork–join merge sort dispatched onto the
//! persistent pool ([`super::pool::ThreadPool::join`]) with a sequential
//! cutoff — no per-call thread spawns; stability matters because the paper
//! specifies a *stable* sort of edges (ties keep insertion order, which
//! the subtask linked lists rely on). The merge structure is independent
//! of scheduling, so output is deterministic for any pool state.

/// Parallel stable sort by a key-extraction function.
pub fn par_sort_by_key<T, K, F>(v: &mut [T], threads: usize, key: F)
where
    T: Send + Clone,
    K: PartialOrd,
    F: Fn(&T) -> K + Sync,
{
    let cmp = |a: &T, b: &T| key(a).partial_cmp(&key(b)).unwrap_or(std::cmp::Ordering::Equal);
    par_sort_by(v, threads, &cmp);
}

/// Parallel stable sort with an explicit comparator.
pub fn par_sort_by<T, F>(v: &mut [T], threads: usize, cmp: &F)
where
    T: Send + Clone,
    F: Fn(&T, &T) -> std::cmp::Ordering + Sync,
{
    let threads = threads.max(1);
    if threads == 1 || v.len() < 4096 {
        v.sort_by(cmp);
        return;
    }
    let mut buf = v.to_vec();
    let depth = (threads as f64).log2().ceil() as usize;
    msort(v, &mut buf, cmp, depth);
}

/// Recursive fork–join merge sort. `depth` levels of forking, then serial.
/// Forks run on the persistent pool; the caller works the right half
/// while a pool worker (or the caller itself) sorts the left.
fn msort<T, F>(v: &mut [T], buf: &mut [T], cmp: &F, depth: usize)
where
    T: Send + Clone,
    F: Fn(&T, &T) -> std::cmp::Ordering + Sync,
{
    if depth == 0 || v.len() < 4096 {
        v.sort_by(cmp);
        return;
    }
    let mid = v.len() / 2;
    let (vl, vr) = v.split_at_mut(mid);
    let (bl, br) = buf.split_at_mut(mid);
    crate::par::ThreadPool::global().join(
        || msort(vl, bl, cmp, depth - 1),
        || msort(vr, br, cmp, depth - 1),
    );
    // Stable merge into buf, copy back.
    merge(vl, vr, buf, cmp);
    v.clone_from_slice(buf);
}

/// Stable two-way merge of sorted `a`, `b` into `out` (len a+b).
fn merge<T, F>(a: &[T], b: &[T], out: &mut [T], cmp: &F)
where
    T: Clone,
    F: Fn(&T, &T) -> std::cmp::Ordering,
{
    debug_assert_eq!(a.len() + b.len(), out.len());
    let (mut i, mut j, mut k) = (0, 0, 0);
    while i < a.len() && j < b.len() {
        // `<=` keeps elements of `a` first on ties → stability.
        if cmp(&a[i], &b[j]) != std::cmp::Ordering::Greater {
            out[k] = a[i].clone();
            i += 1;
        } else {
            out[k] = b[j].clone();
            j += 1;
        }
        k += 1;
    }
    while i < a.len() {
        out[k] = a[i].clone();
        i += 1;
        k += 1;
    }
    while j < b.len() {
        out[k] = b[j].clone();
        j += 1;
        k += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn sorts_like_std() {
        let mut rng = Rng::new(5);
        for n in [0usize, 1, 10, 5000, 20_000] {
            let mut v: Vec<u64> = (0..n).map(|_| rng.next_u64() % 1000).collect();
            let mut expect = v.clone();
            expect.sort();
            par_sort_by_key(&mut v, 4, |x| *x);
            assert_eq!(v, expect, "n={n}");
        }
    }

    #[test]
    fn stability_preserved() {
        let mut rng = Rng::new(6);
        // (key, original index); ties on key must keep index order.
        let mut v: Vec<(u32, usize)> =
            (0..30_000).map(|i| ((rng.next_u32() % 16), i)).collect();
        par_sort_by_key(&mut v, 8, |x| x.0);
        for w in v.windows(2) {
            if w[0].0 == w[1].0 {
                assert!(w[0].1 < w[1].1, "stability violated: {:?}", w);
            }
        }
    }

    #[test]
    fn sorts_floats_descending() {
        let mut rng = Rng::new(7);
        let mut v: Vec<f64> = (0..10_000).map(|_| rng.next_f64()).collect();
        par_sort_by(&mut v, 4, &|a: &f64, b: &f64| b.partial_cmp(a).unwrap());
        for w in v.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }
}
