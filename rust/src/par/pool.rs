//! Persistent work-stealing thread pool — the substrate's answer to a
//! long-lived OpenMP runtime.
//!
//! Before this module existed, every `par_for`/`par_chunks`/`par_map`/
//! `par_sort_by` call spawned and joined fresh OS threads via
//! `std::thread::scope`, so hot loops (one `spmv_par` per PCG iteration,
//! one inner-parallel block per recovery step) paid thread-creation cost
//! thousands of times per run. The pool is created **once**, lazily, and
//! every parallel primitive dispatches onto it.
//!
//! # Architecture
//!
//! * A global singleton ([`ThreadPool::global`]) sized by
//!   [`super::num_threads`] (the `PDGRASS_THREADS` override is read at
//!   first use). Worker threads sleep on a condvar when idle.
//! * Tasks land in a shared **injector** queue when submitted from
//!   outside the pool, or in the submitting worker's **per-worker slot**
//!   when submitted from inside (nested parallelism). Workers drain their
//!   own slot first (FIFO), then the injector, then **steal** from other
//!   workers' slots (LIFO end).
//! * [`ThreadPool::run_scope`] is the core primitive: a dynamically
//!   scheduled index loop `f(0..n)` with an atomic claim cursor, the
//!   direct analogue of `#pragma omp parallel for schedule(dynamic,
//!   grain)`. The *caller participates*: it runs the same claim loop
//!   inline, so a scope always makes progress even if every worker is
//!   busy — this is what makes **nested** submission (the Mixed-strategy
//!   shape: `par_map` inside a `par_for` task) deadlock-free. Waiting
//!   happens only on chunks that some thread is actively executing, and
//!   a chunk's nested scopes are strictly younger than the scope being
//!   waited on, so the wait-for relation follows scope-creation order
//!   and cannot cycle.
//! * The per-call `threads` argument bounds how many pool workers are
//!   recruited for that scope (`threads - 1` helper tasks + the caller),
//!   so callers can run narrower than the pool, or wider — extra helper
//!   tasks beyond the worker count simply drain as no-ops.
//!
//! # Panics
//!
//! A panic inside a pooled task is caught on the worker, recorded on the
//! scope, and **re-thrown on the calling thread** once the scope drains —
//! the join never hangs, and workers survive to serve the next scope.
//!
//! # Safety
//!
//! `run_scope` lifetime-erases the borrowed closure into the scope
//! object. This is sound because `run_scope` does not return until every
//! claimed index has been accounted for (`pending == 0`), and a stale
//! queued task whose scope already drained observes `next >= n` and
//! exits without ever dereferencing the closure pointer.

use super::chaos::{chaos_point, ChaosPoint};
use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

/// One dynamically-scheduled parallel loop in flight.
struct Scope {
    /// Index-space size.
    n: usize,
    /// Indices claimed per atomic fetch.
    grain: usize,
    /// Claim cursor.
    next: AtomicUsize,
    /// Indices not yet executed-or-skipped; the scope is complete at 0.
    pending: AtomicUsize,
    /// Set when any chunk panicked; later chunks are skipped (but still
    /// drained so `pending` reaches 0 and the join cannot hang).
    panicked: AtomicBool,
    /// First panic payload, re-thrown by the caller.
    payload: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    /// Lifetime-erased `&dyn Fn(usize) + Sync`. Only dereferenced after a
    /// successful claim (`start < n`), which can only happen while the
    /// owning `run_scope` frame is still alive.
    func: *const (dyn Fn(usize) + Sync),
    /// Completion signal for the owning `run_scope`.
    done_lock: Mutex<()>,
    done_cv: Condvar,
}

// SAFETY: `func` is only dereferenced under the `pending > 0` liveness
// protocol documented on the module; all other fields are Sync.
unsafe impl Send for Scope {}
// SAFETY: shared access follows the same liveness protocol — `func` is
// read-only after construction and only dereferenced by live claims.
unsafe impl Sync for Scope {}

impl Scope {
    /// Claim-and-run loop. Executed by recruited workers and inline by
    /// the scope's creator.
    fn run(&self) {
        loop {
            chaos_point(ChaosPoint::PoolClaim);
            let start = self.next.fetch_add(self.grain, Ordering::Relaxed);
            if start >= self.n {
                break;
            }
            let end = (start + self.grain).min(self.n);
            if !self.panicked.load(Ordering::Relaxed) {
                // SAFETY: claim succeeded, so the creator is still inside
                // `run_scope` and the closure borrow is live.
                let f = unsafe { &*self.func };
                let result = catch_unwind(AssertUnwindSafe(|| {
                    for i in start..end {
                        f(i);
                    }
                }));
                if let Err(p) = result {
                    self.panicked.store(true, Ordering::Relaxed);
                    let mut slot = self.payload.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(p);
                    }
                }
            }
            if self.pending.fetch_sub(end - start, Ordering::AcqRel) == end - start {
                let _g = self.done_lock.lock().unwrap();
                self.done_cv.notify_all();
            }
        }
    }

    fn is_done(&self) -> bool {
        self.pending.load(Ordering::Acquire) == 0
    }
}

/// A queued unit of work: one claim loop over a scope.
type Task = Arc<Scope>;

/// State shared between the pool handle and its workers.
struct Shared {
    /// Global queue for submissions from non-pool threads.
    injector: Mutex<VecDeque<Task>>,
    /// Per-worker slots for nested submissions (stolen by other workers).
    slots: Vec<Mutex<VecDeque<Task>>>,
    /// Sleep/wake protocol for idle workers.
    sleep_lock: Mutex<()>,
    wake_cv: Condvar,
}

impl Shared {
    fn pop_for_worker(&self, idx: usize) -> Option<Task> {
        if let Some(t) = self.slots[idx].lock().unwrap().pop_front() {
            return Some(t);
        }
        if let Some(t) = self.injector.lock().unwrap().pop_front() {
            return Some(t);
        }
        chaos_point(ChaosPoint::PoolSteal);
        let k = self.slots.len();
        for d in 1..k {
            if let Some(t) = self.slots[(idx + d) % k].lock().unwrap().pop_back() {
                return Some(t);
            }
        }
        None
    }

    fn has_work(&self) -> bool {
        if !self.injector.lock().unwrap().is_empty() {
            return true;
        }
        self.slots.iter().any(|s| !s.lock().unwrap().is_empty())
    }
}

thread_local! {
    /// `(pool identity, worker index)` for pool worker threads.
    static WORKER: Cell<Option<(usize, usize)>> = const { Cell::new(None) };
}

fn worker_loop(shared: Arc<Shared>, idx: usize) {
    WORKER.with(|w| w.set(Some((Arc::as_ptr(&shared) as usize, idx))));
    loop {
        if let Some(task) = shared.pop_for_worker(idx) {
            task.run();
            continue;
        }
        chaos_point(ChaosPoint::PoolPark);
        let guard = shared.sleep_lock.lock().unwrap();
        if shared.has_work() {
            continue;
        }
        // Submitters push first, then lock `sleep_lock` and notify, so a
        // task enqueued between the check above and this wait still wakes
        // us: the notifier blocks on the lock until we are waiting.
        drop(shared.wake_cv.wait(guard).unwrap());
    }
}

/// Persistent worker pool; see the module docs for the execution model.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: usize,
}

static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();

impl ThreadPool {
    /// The process-wide pool, created on first use with
    /// `num_threads().max(2)` workers (so explicit `threads > 1` calls
    /// parallelize even when `PDGRASS_THREADS=1` serializes defaults).
    pub fn global() -> &'static ThreadPool {
        GLOBAL.get_or_init(|| ThreadPool::new(super::num_threads().max(2)))
    }

    /// Build a pool with `workers` threads. Workers live for the process
    /// lifetime; prefer [`ThreadPool::global`] outside of tests.
    fn new(workers: usize) -> ThreadPool {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            injector: Mutex::new(VecDeque::new()),
            slots: (0..workers).map(|_| Mutex::new(VecDeque::new())).collect(),
            sleep_lock: Mutex::new(()),
            wake_cv: Condvar::new(),
        });
        for i in 0..workers {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name(format!("pdgrass-pool-{i}"))
                .spawn(move || worker_loop(shared, i))
                .expect("spawn pool worker");
        }
        ThreadPool { shared, workers }
    }

    /// Number of worker threads (excluding participating callers).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Worker index of the current thread *in this pool*, if any.
    fn current_worker(&self) -> Option<usize> {
        let me = Arc::as_ptr(&self.shared) as usize;
        WORKER
            .with(|w| w.get())
            .and_then(|(pool, idx)| if pool == me { Some(idx) } else { None })
    }

    /// Enqueue `count` claim-loop tasks for `scope` and wake workers.
    fn submit(&self, scope: &Task, count: usize) {
        if count == 0 {
            return;
        }
        match self.current_worker() {
            Some(idx) => {
                let mut q = self.shared.slots[idx].lock().unwrap();
                for _ in 0..count {
                    q.push_back(scope.clone());
                }
            }
            None => {
                let mut q = self.shared.injector.lock().unwrap();
                for _ in 0..count {
                    q.push_back(scope.clone());
                }
            }
        }
        // Wake at most `count` sleepers (tasks were pushed above, so a
        // worker racing past the wake re-checks the queues under
        // `sleep_lock` before sleeping and cannot miss them).
        let _g = self.shared.sleep_lock.lock().unwrap();
        for _ in 0..count.min(self.workers) {
            self.shared.wake_cv.notify_one();
        }
    }

    /// Dynamically-scheduled parallel loop: run `f(i)` for `i in 0..n`
    /// with `grain` indices claimed per atomic fetch, recruiting up to
    /// `threads - 1` pool workers alongside the calling thread.
    ///
    /// Serial fast path when `threads <= 1` or `n <= grain` (same
    /// contract the pre-pool `par_for` had). Nested calls are safe from
    /// any thread, including pool workers. A panic in `f` propagates to
    /// the caller after the scope drains.
    pub fn run_scope<F>(&self, n: usize, threads: usize, grain: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        let threads = threads.max(1).min(n.max(1));
        let grain = grain.max(1);
        if threads == 1 || n <= grain {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let f_ref: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: lifetime erasure; see the module-level safety notes.
        let func: *const (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f_ref)
        };
        let scope: Task = Arc::new(Scope {
            n,
            grain,
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(n),
            panicked: AtomicBool::new(false),
            payload: Mutex::new(None),
            func,
            done_lock: Mutex::new(()),
            done_cv: Condvar::new(),
        });
        let chunks = n.div_ceil(grain);
        let helpers = (threads - 1).min(chunks - 1).min(self.workers);
        self.submit(&scope, helpers);
        // The caller participates — guarantees progress under nesting.
        scope.run();
        // Wait for chunks still in flight on recruited workers. The
        // notify protocol alone is miss-free (the final decrement takes
        // `done_lock` before notifying; we check under the same lock);
        // the timeout is deliberate belt-and-braces so a future protocol
        // regression degrades to a 10 ms-poll stall instead of a hang.
        let mut guard = scope.done_lock.lock().unwrap();
        while !scope.is_done() {
            let (g, _) = scope
                .done_cv
                .wait_timeout(guard, Duration::from_millis(10))
                .unwrap();
            guard = g;
        }
        drop(guard);
        if scope.panicked.load(Ordering::Relaxed) {
            match scope.payload.lock().unwrap().take() {
                Some(p) => resume_unwind(p),
                None => panic!("pdgrass pool: worker task panicked"),
            }
        }
    }

    /// Fork–join pair: runs `a` and `b`, potentially in parallel (`a` may
    /// be picked up by a worker while the caller runs `b`, or the caller
    /// runs both). Returns after both complete; panics propagate.
    pub fn join<A, B>(&self, a: A, b: B)
    where
        A: FnOnce() + Send,
        B: FnOnce() + Send,
    {
        self.join_map(a, b);
    }

    /// Value-returning fork–join: runs `a` and `b`, potentially in
    /// parallel, and returns `(a(), b())` — the reduce-friendly form of
    /// [`ThreadPool::join`] that `par::par_reduce` and the merge-sort fork
    /// tree build on. The caller claims slot 0 first, so it runs `b`
    /// inline while a worker (if one is free) picks up `a`; with no free
    /// worker the caller simply runs both. If either closure panics the
    /// panic is re-thrown here after both slots are accounted for, and no
    /// partial result escapes.
    pub fn join_map<RA, RB, A, B>(&self, a: A, b: B) -> (RA, RB)
    where
        RA: Send,
        RB: Send,
        A: FnOnce() -> RA + Send,
        B: FnOnce() -> RB + Send,
    {
        let fa = Mutex::new(Some(a));
        let fb = Mutex::new(Some(b));
        let ra: Mutex<Option<RA>> = Mutex::new(None);
        let rb: Mutex<Option<RB>> = Mutex::new(None);
        self.run_scope(2, 2, 1, |i| {
            if i == 0 {
                let f = fb.lock().unwrap().take().expect("join slot b claimed twice");
                *rb.lock().unwrap() = Some(f());
            } else {
                let f = fa.lock().unwrap().take().expect("join slot a claimed twice");
                *ra.lock().unwrap() = Some(f());
            }
        });
        // `run_scope` returned without re-throwing, so both closures ran
        // to completion and both slots are filled.
        let ra = ra.into_inner().unwrap().expect("join_map side a incomplete");
        let rb = rb.into_inner().unwrap().expect("join_map side b incomplete");
        (ra, rb)
    }
}

/// Handle to a service thread started by [`spawn_service`]; join it to
/// wait for the service to exit.
#[derive(Debug)]
pub struct ServiceHandle {
    inner: std::thread::JoinHandle<()>,
}

impl ServiceHandle {
    /// Wait for the service thread to finish. Panics from the service
    /// body propagate here, same as `std::thread::JoinHandle::join` +
    /// unwrap.
    pub fn join(self) {
        if let Err(p) = self.inner.join() {
            std::panic::resume_unwind(p);
        }
    }

    /// Whether the service thread has exited (join would not block).
    pub fn is_finished(&self) -> bool {
        self.inner.is_finished()
    }
}

/// Spawn a named **service** thread — a thread that spends its life
/// blocked on I/O (a socket accept loop, a per-connection reader), not
/// computing. Compute must go through the pool ([`ThreadPool::run_scope`]
/// and the `par_*` primitives): parking a pool worker on a socket would
/// starve every parallel loop in the process, and conversely a service
/// thread that wants parallelism calls into the pool like any other
/// caller (its `run_scope` participates, so this composes deadlock-free).
///
/// This is the crate's only sanctioned thread-creation site outside the
/// pool's own workers — the `pdgrass audit` thread rule pins thread
/// spawning to this file, and the serve daemon goes through here rather
/// than widening that exemption.
pub fn spawn_service<F>(name: &str, f: F) -> ServiceHandle
where
    F: FnOnce() + Send + 'static,
{
    let inner = std::thread::Builder::new()
        .name(format!("pdgrass-svc-{name}"))
        .spawn(f)
        .expect("spawn service thread");
    ServiceHandle { inner }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::par::{par_for, par_map};
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_scope_visits_every_index_once() {
        let pool = ThreadPool::global();
        for threads in [2usize, 3, 8, 64] {
            for grain in [1usize, 7, 1000] {
                let hits: Vec<AtomicU64> = (0..500).map(|_| AtomicU64::new(0)).collect();
                pool.run_scope(500, threads, grain, |i| {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                });
                assert!(
                    hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                    "threads={threads} grain={grain}"
                );
            }
        }
    }

    #[test]
    fn zero_and_tiny_scopes() {
        let pool = ThreadPool::global();
        pool.run_scope(0, 8, 1, |_| panic!("must not run"));
        let hit = AtomicU64::new(0);
        pool.run_scope(1, 8, 1, |_| {
            hit.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hit.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn threads_exceeding_pool_and_n() {
        // More threads than indices and than pool workers: every index
        // still runs exactly once and the call returns.
        let hits: Vec<AtomicU64> = (0..3).map(|_| AtomicU64::new(0)).collect();
        par_for(3, 1024, 1, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn nested_par_map_inside_par_for() {
        // The Mixed-strategy shape from recovery/pdgrass.rs: an outer
        // dynamic loop whose body runs an inner parallel map.
        let totals: Vec<AtomicU64> = (0..12).map(|_| AtomicU64::new(0)).collect();
        par_for(12, 4, 1, |i| {
            let xs: Vec<u64> = (0..200).collect();
            let ys = par_map(&xs, 4, |&x| x * 2);
            let sum: u64 = ys.iter().sum();
            totals[i].store(sum, Ordering::Relaxed);
        });
        let expect: u64 = (0..200u64).map(|x| x * 2).sum();
        for t in &totals {
            assert_eq!(t.load(Ordering::Relaxed), expect);
        }
    }

    #[test]
    fn deeply_nested_scopes_terminate() {
        fn level(depth: usize, counter: &AtomicU64) {
            if depth == 0 {
                counter.fetch_add(1, Ordering::Relaxed);
                return;
            }
            par_for(2, 2, 1, |_| level(depth - 1, counter));
        }
        let c = AtomicU64::new(0);
        level(5, &c);
        assert_eq!(c.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn panic_in_pooled_task_fails_caller_without_hanging() {
        let result = std::panic::catch_unwind(|| {
            par_for(256, 4, 1, |i| {
                if i == 97 {
                    panic!("expected test panic at 97");
                }
            });
        });
        assert!(result.is_err(), "worker panic must reach the caller");
        // The pool must remain fully usable after a panicked scope.
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
        par_for(100, 4, 3, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn panic_payload_is_preserved() {
        let result = std::panic::catch_unwind(|| {
            ThreadPool::global().run_scope(64, 8, 1, |i| {
                if i == 13 {
                    panic!("boom-13");
                }
            });
        });
        let payload = result.unwrap_err();
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .map(str::to_string)
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("boom-13"), "payload lost: {msg:?}");
    }

    #[test]
    fn join_runs_both_sides() {
        let (a, b) = (AtomicU64::new(0), AtomicU64::new(0));
        ThreadPool::global().join(
            || {
                a.store(11, Ordering::Relaxed);
            },
            || {
                b.store(22, Ordering::Relaxed);
            },
        );
        assert_eq!(a.load(Ordering::Relaxed), 11);
        assert_eq!(b.load(Ordering::Relaxed), 22);
    }

    #[test]
    fn join_propagates_panics() {
        let ok = AtomicU64::new(0);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            ThreadPool::global().join(
                || panic!("left side fails"),
                || {
                    ok.fetch_add(1, Ordering::Relaxed);
                },
            );
        }));
        assert!(result.is_err());
    }

    #[test]
    fn join_map_returns_both_values() {
        let (a, b) = ThreadPool::global().join_map(|| 6u64 * 7, || "forty-two".to_string());
        assert_eq!(a, 42);
        assert_eq!(b, "forty-two");
        // Nested: each side forks again.
        let (l, r) = ThreadPool::global().join_map(
            || ThreadPool::global().join_map(|| 1u64, || 2u64),
            || ThreadPool::global().join_map(|| 3u64, || 4u64),
        );
        assert_eq!((l, r), ((1, 2), (3, 4)));
    }

    #[test]
    fn join_map_panic_propagates_before_unwrap() {
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            ThreadPool::global().join_map(|| 1u64, || -> u64 { panic!("side b fails") })
        }));
        assert!(result.is_err());
    }

    #[test]
    fn spawn_service_runs_and_joins() {
        let flag = Arc::new(AtomicBool::new(false));
        let f2 = flag.clone();
        let h = spawn_service("test", move || {
            // A service thread may recruit the pool like any caller.
            let hits: Vec<AtomicU64> = (0..64).map(|_| AtomicU64::new(0)).collect();
            par_for(64, 4, 1, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
            f2.store(true, Ordering::Release);
        });
        h.join();
        assert!(flag.load(Ordering::Acquire));
    }

    #[test]
    fn global_pool_is_singleton_and_sized() {
        let p1 = ThreadPool::global();
        let p2 = ThreadPool::global();
        assert!(std::ptr::eq(p1, p2));
        assert!(p1.workers() >= 2);
    }
}
