//! Seeded schedule-chaos injection for the parallel substrate.
//!
//! The determinism claim — bitwise-identical outputs across strategies,
//! pipelines, and thread counts — must hold for *every* interleaving,
//! but an idle CI machine explores very few. This module plants cheap
//! perturbation points at the scheduler's decision sites (chunk claim,
//! steal, park in [`super::pool`]; chunk claim and await in
//! [`super::stream`]) that inject seeded `yield_now`/micro-sleep noise,
//! so the equivalence suites can be replayed under many distinct
//! schedules:
//!
//! ```text
//! PDGRASS_CHAOS_SEED=11 cargo test --test session
//! ```
//!
//! Off by default: with no seed configured, a perturbation point is two
//! relaxed-ish loads. Decisions are a pure hash of
//! `(seed, thread salt, point, per-thread counter)`, so a failing seed
//! reported by a test reproduces the same *decision sequence* (the OS
//! still owns actual scheduling — chaos widens the explored set, it
//! does not replay an exact interleaving).
//!
//! Perturbation only ever delays a thread; it cannot reorder the
//! substrate's synchronization edges, so enabling chaos must not change
//! any output bit — that is precisely what the chaos tests assert.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::OnceLock;

/// Scheduler decision sites that accept injected noise.
#[derive(Clone, Copy, Debug)]
pub enum ChaosPoint {
    /// A pool worker (or the caller) about to claim the next chunk.
    PoolClaim,
    /// A pool worker about to scan sibling slots for work.
    PoolSteal,
    /// A pool worker about to park on the wakeup condvar.
    PoolPark,
    /// The stream producer about to claim the next stage chunk.
    StreamClaim,
    /// A stream consumer waiting for a chunk to be published.
    StreamAwait,
}

/// In-process override state: 0 = defer to the environment,
/// 1 = forced off, 2 = forced on with [`OVERRIDE_SEED`].
static OVERRIDE_STATE: AtomicU8 = AtomicU8::new(0);
static OVERRIDE_SEED: AtomicU64 = AtomicU64::new(0);
/// Monotone source of per-thread salts.
static NEXT_SALT: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// Per-thread (salt, event counter); salt 0 means "not yet drawn".
    static THREAD_STATE: Cell<(u64, u64)> = const { Cell::new((0, 0)) };
}

/// Force the chaos seed for this process, overriding the environment:
/// `Some(seed)` enables injection, `None` disables it. Tests use this
/// to compare perturbed runs against a chaos-free baseline without
/// respawning the process.
pub fn set_seed(seed: Option<u64>) {
    match seed {
        Some(s) => {
            OVERRIDE_SEED.store(s, Ordering::Release);
            OVERRIDE_STATE.store(2, Ordering::Release);
        }
        None => OVERRIDE_STATE.store(1, Ordering::Release),
    }
}

/// The active chaos seed, if any: an in-process [`set_seed`] override
/// first, else `PDGRASS_CHAOS_SEED` from the environment (read once).
pub fn seed() -> Option<u64> {
    match OVERRIDE_STATE.load(Ordering::Acquire) {
        1 => None,
        2 => Some(OVERRIDE_SEED.load(Ordering::Acquire)),
        _ => env_seed(),
    }
}

fn env_seed() -> Option<u64> {
    static ENV: OnceLock<Option<u64>> = OnceLock::new();
    *ENV.get_or_init(|| {
        let raw = std::env::var("PDGRASS_CHAOS_SEED").ok()?;
        parse_seed(&raw)
    })
}

/// Parse a seed string: decimal, or hex with an `0x` prefix.
fn parse_seed(raw: &str) -> Option<u64> {
    let s = raw.trim();
    if let Some(hex) = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).ok()
    } else {
        s.parse().ok()
    }
}

/// A perturbation point. Near-free when chaos is disabled; otherwise
/// hashes the site identity into a yield / micro-sleep / no-op choice.
#[inline]
pub fn chaos_point(p: ChaosPoint) {
    if let Some(seed) = seed() {
        perturb(seed, p);
    }
}

#[cold]
fn perturb(seed: u64, p: ChaosPoint) {
    let (salt, n) = THREAD_STATE.with(|st| {
        let (mut salt, n) = st.get();
        if salt == 0 {
            salt = NEXT_SALT.fetch_add(1, Ordering::Relaxed);
        }
        st.set((salt, n.wrapping_add(1)));
        (salt, n)
    });
    match decide(seed, salt, p as u64, n) {
        Action::Nothing => {}
        Action::Yield => std::thread::yield_now(),
        Action::Sleep(us) => std::thread::sleep(std::time::Duration::from_micros(us)),
    }
}

#[derive(Debug, PartialEq, Eq)]
enum Action {
    Nothing,
    Yield,
    Sleep(u64),
}

/// Pure decision function: ~1/4 of events yield, ~1/32 sleep 1–40 µs,
/// the rest do nothing (enough reordering pressure to move chunk
/// boundaries between threads without drowning the test wall-clock).
fn decide(seed: u64, salt: u64, point: u64, n: u64) -> Action {
    let mut key = seed;
    key ^= salt.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    key ^= (point + 1).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    key ^= n.wrapping_mul(0x94D0_49BB_1331_11EB);
    let h = splitmix64(key);
    match h % 32 {
        0..=7 => Action::Yield,
        8 => Action::Sleep(1 + (h >> 32) % 40),
        _ => Action::Nothing,
    }
}

/// splitmix64 finalizer — a strong 64-bit mix with cheap constants.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_parsing_accepts_decimal_and_hex() {
        assert_eq!(parse_seed("42"), Some(42));
        assert_eq!(parse_seed(" 42 "), Some(42));
        assert_eq!(parse_seed("0xC0FFEE"), Some(0xC0FFEE));
        assert_eq!(parse_seed("0Xff"), Some(0xff));
        assert_eq!(parse_seed("nope"), None);
        assert_eq!(parse_seed(""), None);
    }

    #[test]
    fn decisions_are_deterministic_and_seed_sensitive() {
        for (salt, point, n) in [(1u64, 0u64, 0u64), (2, 3, 17), (9, 4, 1000)] {
            assert_eq!(decide(7, salt, point, n), decide(7, salt, point, n));
        }
        // Different seeds must produce different decision sequences
        // somewhere in a short window.
        let differs = (0..256u64).any(|n| decide(1, 1, 0, n) != decide(2, 1, 0, n));
        assert!(differs);
    }

    #[test]
    fn decide_mixes_all_actions() {
        let mut yields = 0;
        let mut sleeps = 0;
        let mut nothings = 0;
        for n in 0..4096u64 {
            match decide(0xC0FFEE, 3, 1, n) {
                Action::Yield => yields += 1,
                Action::Sleep(us) => {
                    assert!((1..=40).contains(&us));
                    sleeps += 1;
                }
                Action::Nothing => nothings += 1,
            }
        }
        assert!(yields > 512, "yields={yields}");
        assert!(sleeps > 32, "sleeps={sleeps}");
        assert!(nothings > 2048, "nothings={nothings}");
    }
}
