//! Cross-stage streaming handoff: produce chunks on the pool, consume
//! them on the caller in deterministic ascending order.
//!
//! The Algorithm-1 pipeline used to barrier-sync every stage: resistance
//! annotation finished before the score sort started, the sort finished
//! before subtasks were grouped, and every recovery pass joined a full
//! `par_map` before a single outcome was absorbed. [`produce_stream`] is
//! the primitive that removes those barriers: a fixed index space of `n`
//! chunks is claimed by pool workers (and, when useful, by the consumer
//! itself), each claimed chunk is produced exactly once, and the consumer
//! receives the chunks **in ascending index order** as they become
//! available — chunk `i+1` can be produced while chunk `i` is being
//! consumed.
//!
//! # Determinism
//!
//! `consume(i, value)` is always invoked for `i = 0, 1, …, n-1` in that
//! order, on a single thread, and `produce(i)` is required to be a pure
//! function of `i`. Scheduling therefore affects only timing, never the
//! consumed sequence — the same contract the rest of the `par` substrate
//! keeps (fixed reduce trees, scheduling-independent sorts).
//!
//! # Deadlock freedom inside the claim loop
//!
//! The consumer never waits on an *unclaimed* chunk: when the chunk it
//! needs is not ready it first claims and produces pending chunks itself
//! (the same caller-participation trick [`ThreadPool::run_scope`] uses),
//! and only blocks once every chunk is claimed — at which point the
//! awaited chunk is being actively produced by some thread and the wait
//! is finite. Producers that claim far ahead of the consumer park on a
//! **bounded window** (`consumed + window` chunks in flight), and the
//! consumer is exempt from the window, so the producer of the very chunk
//! the consumer awaits is never parked: the wait-for graph has no cycle.
//!
//! # Panics
//!
//! A panic in `produce` (on any thread) aborts the stream: remaining
//! producers drain without running, the consumer stops, and the first
//! payload is re-thrown on the calling thread. A panic in `consume`
//! propagates through the pool join after in-flight producers finish.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use super::chaos::{chaos_point, ChaosPoint};
use super::ThreadPool;

/// Shared state of one stream; lives on the [`produce_stream`] frame.
struct Stream<T> {
    /// One slot per chunk, filled exactly once by its producer.
    slots: Vec<Mutex<Option<T>>>,
    /// Claim cursor over `0..slots.len()`.
    next: AtomicUsize,
    /// Consumer watermark: chunks `< consumed` have been consumed.
    consumed: AtomicUsize,
    /// Producers park while their claim is `>= consumed + window`.
    window: usize,
    /// Set when any `produce` call panicked; aborts the stream.
    failed: AtomicBool,
    /// First panic payload, re-thrown on the calling thread.
    payload: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    /// Wake protocol for slot fills, watermark bumps, and failure.
    signal: Mutex<()>,
    cv: Condvar,
}

impl<T> Stream<T> {
    fn notify_all(&self) {
        let _g = self.signal.lock().unwrap();
        self.cv.notify_all();
    }

    /// Produce chunk `j` into its slot, recording a panic instead of
    /// unwinding (workers must survive to serve the next scope).
    fn fill<P>(&self, j: usize, produce: &P)
    where
        P: Fn(usize) -> T + Sync,
    {
        if self.failed.load(Ordering::Acquire) {
            return;
        }
        match catch_unwind(AssertUnwindSafe(|| produce(j))) {
            Ok(v) => {
                *self.slots[j].lock().unwrap() = Some(v);
            }
            Err(p) => {
                let mut slot = self.payload.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(p);
                }
                self.failed.store(true, Ordering::Release);
            }
        }
        self.notify_all();
    }

    /// Worker-side loop: claim chunks and produce them, parking while the
    /// claim is outside the in-flight window.
    fn producer_loop<P>(&self, produce: &P)
    where
        P: Fn(usize) -> T + Sync,
    {
        let n = self.slots.len();
        loop {
            chaos_point(ChaosPoint::StreamClaim);
            if self.failed.load(Ordering::Acquire) {
                return;
            }
            let j = self.next.fetch_add(1, Ordering::Relaxed);
            if j >= n {
                return;
            }
            // Bounded handoff: park until the consumer is within `window`
            // chunks of this claim. The consumer bypasses the window and
            // its awaited chunk `i` always satisfies `i < consumed +
            // window`, so the park cannot be part of a wait cycle. The
            // timeout mirrors the pool's belt-and-braces wakeup.
            while j >= self.consumed.load(Ordering::Acquire) + self.window
                && !self.failed.load(Ordering::Acquire)
            {
                let guard = self.signal.lock().unwrap();
                if j < self.consumed.load(Ordering::Acquire) + self.window
                    || self.failed.load(Ordering::Acquire)
                {
                    break;
                }
                drop(self.cv.wait_timeout(guard, Duration::from_millis(10)).unwrap());
            }
            self.fill(j, produce);
        }
    }

    /// Consumer-side wait for chunk `i`: take it if ready, otherwise help
    /// produce pending chunks, and only then block. Returns `None` when
    /// the stream failed (the payload is re-thrown by the caller).
    fn await_chunk<P>(&self, i: usize, produce: &P) -> Option<T>
    where
        P: Fn(usize) -> T + Sync,
    {
        let n = self.slots.len();
        loop {
            chaos_point(ChaosPoint::StreamAwait);
            if let Some(v) = self.slots[i].lock().unwrap().take() {
                return Some(v);
            }
            if self.failed.load(Ordering::Acquire) {
                return None;
            }
            let j = self.next.fetch_add(1, Ordering::Relaxed);
            if j < n {
                // Caller-participation: produce a pending chunk (possibly
                // `i` itself) instead of blocking. Exempt from the window
                // — the consumer can never overtake itself.
                self.fill(j, produce);
                continue;
            }
            // Every chunk is claimed; `i` is in flight on some thread.
            let guard = self.signal.lock().unwrap();
            if self.slots[i].lock().unwrap().is_some() || self.failed.load(Ordering::Acquire) {
                continue;
            }
            drop(self.cv.wait_timeout(guard, Duration::from_millis(10)).unwrap());
        }
    }
}

/// Streamed producer/consumer pipeline over `n` chunks: `produce(i)` runs
/// exactly once per chunk on the pool (plus the consumer when it would
/// otherwise block), and `consume(i, value)` runs in ascending `i` order
/// as chunks become available — stage `i+1`'s production overlaps stage
/// `i`'s consumption. See the module docs for the determinism, bounding,
/// and deadlock-freedom contracts.
///
/// `threads <= 1` (or `n <= 1`) is the serial fast path: produce and
/// consume strictly alternate on the caller, which is exactly the barrier
/// semantics chunk by chunk.
pub fn produce_stream<T, P, C>(n: usize, threads: usize, produce: P, mut consume: C)
where
    T: Send,
    P: Fn(usize) -> T + Sync,
    C: FnMut(usize, T) + Send,
{
    let threads = threads.max(1);
    if threads == 1 || n <= 1 {
        for i in 0..n {
            consume(i, produce(i));
        }
        return;
    }
    let stream: Stream<T> = Stream {
        slots: (0..n).map(|_| Mutex::new(None)).collect(),
        next: AtomicUsize::new(0),
        consumed: AtomicUsize::new(0),
        window: (2 * threads).max(4),
        failed: AtomicBool::new(false),
        payload: Mutex::new(None),
        signal: Mutex::new(()),
        cv: Condvar::new(),
    };
    let helpers = (threads - 1).min(n);
    let st = &stream;
    let producer = &produce;
    ThreadPool::global().join(
        move || {
            // Each helper task runs a full claim loop; extra helpers
            // beyond the pending chunks drain as no-ops.
            ThreadPool::global().run_scope(helpers, helpers, 1, |_| st.producer_loop(producer));
        },
        move || {
            // If `consume` unwinds, window-parked producers would wait
            // forever on a frozen watermark and the join would never
            // drain: this guard marks the stream failed (producers bail
            // out of both the park loop and the claim loop) and wakes
            // them before the panic leaves the closure. Disarmed on the
            // normal exit path below.
            struct Abort<'a, T>(&'a Stream<T>);
            impl<T> Drop for Abort<'_, T> {
                fn drop(&mut self) {
                    self.0.failed.store(true, Ordering::Release);
                    self.0.notify_all();
                }
            }
            let guard = Abort(st);
            for i in 0..n {
                st.consumed.store(i, Ordering::Release);
                st.notify_all();
                match st.await_chunk(i, producer) {
                    Some(v) => consume(i, v),
                    None => break, // producer panicked; re-thrown below
                }
            }
            st.consumed.store(n, Ordering::Release);
            std::mem::forget(guard);
            st.notify_all();
        },
    );
    if stream.failed.load(Ordering::Acquire) {
        match stream.payload.lock().unwrap().take() {
            Some(p) => resume_unwind(p),
            None => panic!("pdgrass stream: producer panicked"),
        }
    }
}

/// Chunked scoring producer shared by the streamed pipeline stages:
/// split `0..n` into fixed `chunk`-sized ranges (the layout depends only
/// on `(n, chunk)` — never on the thread count, which is what keeps
/// streamed outputs thread-count independent), produce each chunk on the
/// pool by mapping the pure `item` function over its range and locally
/// sorting with `cmp`, and hand the sorted runs to `consume` in
/// ascending chunk order. `cmp` is expected to be a strict total order
/// so the downstream run merge yields the unique sorted sequence.
pub fn produce_sorted_runs<T, I, F, C>(
    n: usize,
    chunk: usize,
    threads: usize,
    item: I,
    cmp: &F,
    consume: C,
) where
    T: Send,
    I: Fn(usize) -> T + Sync,
    F: Fn(&T, &T) -> std::cmp::Ordering + Sync,
    C: FnMut(usize, Vec<T>) + Send,
{
    let chunk = chunk.max(1);
    let n_chunks = n.div_ceil(chunk);
    produce_stream(
        n_chunks,
        threads,
        |ci| {
            let lo = ci * chunk;
            let hi = (lo + chunk).min(n);
            let mut run: Vec<T> = (lo..hi).map(&item).collect();
            run.sort_by(cmp);
            run
        },
        consume,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn consumes_every_chunk_in_order() {
        for threads in [1usize, 2, 4, 8] {
            let mut seen: Vec<(usize, u64)> = Vec::new();
            produce_stream(100, threads, |i| (i as u64) * 3 + 1, |i, v| seen.push((i, v)));
            assert_eq!(seen.len(), 100, "threads={threads}");
            for (k, &(i, v)) in seen.iter().enumerate() {
                assert_eq!(i, k, "threads={threads}: out-of-order consume");
                assert_eq!(v, (i as u64) * 3 + 1, "threads={threads}");
            }
        }
    }

    #[test]
    fn zero_and_single_chunk() {
        produce_stream::<u32, _, _>(0, 8, |_| panic!("must not produce"), |_, _| {
            panic!("must not consume")
        });
        let mut got = Vec::new();
        produce_stream(1, 8, |i| i + 10, |_, v| got.push(v));
        assert_eq!(got, vec![10]);
    }

    #[test]
    fn slow_consumer_still_sees_everything() {
        // Producers race far ahead of a deliberately slow consumer; the
        // bounded window parks them but every chunk still arrives once.
        let mut total = 0u64;
        produce_stream(
            64,
            4,
            |i| i as u64,
            |_, v| {
                std::thread::sleep(Duration::from_micros(200));
                total += v;
            },
        );
        assert_eq!(total, (0..64u64).sum());
    }

    #[test]
    fn producer_panic_propagates() {
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            produce_stream(
                50,
                4,
                |i| {
                    if i == 23 {
                        panic!("stream-boom-23");
                    }
                    i
                },
                |_, _| {},
            );
        }));
        let payload = result.expect_err("producer panic must reach the caller");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .map(str::to_string)
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("stream-boom-23"), "payload lost: {msg:?}");
        // The pool survives a failed stream.
        let hits = AtomicU64::new(0);
        produce_stream(
            10,
            4,
            |i| i,
            |_, _| {
                hits.fetch_add(1, Ordering::Relaxed);
            },
        );
        assert_eq!(hits.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn consumer_panic_propagates() {
        // n far beyond the in-flight window with a panic early in the
        // consume order: window-parked producers must be released (the
        // abort guard), not left waiting on a frozen watermark.
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            produce_stream(
                50,
                4,
                |i| i,
                |i, _| {
                    if i == 7 {
                        panic!("consume-boom");
                    }
                },
            );
        }));
        assert!(result.is_err(), "consumer panic must reach the caller");
        // The pool (and fresh streams) survive an aborted stream.
        let hits = AtomicU64::new(0);
        produce_stream(
            10,
            4,
            |i| i,
            |_, _| {
                hits.fetch_add(1, Ordering::Relaxed);
            },
        );
        assert_eq!(hits.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn nested_parallelism_inside_produce() {
        // The recovery shape: a streamed chunk whose producer itself runs
        // a pooled parallel map (Mixed-strategy nesting) must not deadlock.
        let mut sums = Vec::new();
        produce_stream(
            8,
            4,
            |i| {
                let xs: Vec<u64> = (0..200).collect();
                let ys = crate::par::par_map(&xs, 4, |&x| x + i as u64);
                ys.iter().sum::<u64>()
            },
            |_, s| sums.push(s),
        );
        for (i, s) in sums.iter().enumerate() {
            let expect: u64 = (0..200u64).map(|x| x + i as u64).sum();
            assert_eq!(*s, expect);
        }
    }

    #[test]
    fn produce_sorted_runs_covers_and_sorts_every_chunk() {
        let cmp = |a: &u64, b: &u64| a.cmp(b);
        for (n, chunk) in [(0usize, 8usize), (5, 8), (64, 8), (65, 8), (100, 1)] {
            let mut runs: Vec<(usize, Vec<u64>)> = Vec::new();
            produce_sorted_runs(
                n,
                chunk,
                4,
                |k| (k as u64).wrapping_mul(0x9E37_79B9) % 97,
                &cmp,
                |ci, run| runs.push((ci, run)),
            );
            assert_eq!(runs.len(), n.div_ceil(chunk.max(1)), "n={n} chunk={chunk}");
            let mut total = 0usize;
            for (k, (ci, run)) in runs.iter().enumerate() {
                assert_eq!(*ci, k, "n={n}: runs must arrive in order");
                assert!(run.windows(2).all(|w| w[0] <= w[1]), "n={n}: run not sorted");
                total += run.len();
            }
            assert_eq!(total, n, "n={n}: every index exactly once");
        }
    }

    #[test]
    fn results_identical_across_thread_counts() {
        let run = |threads: usize| -> Vec<u64> {
            let mut out = Vec::new();
            produce_stream(
                37,
                threads,
                |i| (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                |_, v| out.push(v),
            );
            out
        };
        let base = run(1);
        for threads in [2usize, 3, 8] {
            assert_eq!(run(threads), base, "threads={threads}");
        }
    }
}
