//! Parallel substrate — the repo's stand-in for OpenMP 4.5.
//!
//! The paper implements pdGRASS in C++17 + OpenMP. The offline vendor set
//! has neither `rayon` nor OpenMP bindings, so this module implements the
//! primitives the algorithm needs from `std::thread` scoped threads:
//!
//! - [`par_for`] — dynamically-scheduled parallel index loop (the OpenMP
//!   `parallel for schedule(dynamic)` used for outer subtask parallelism),
//! - [`par_chunks`] — statically chunked loop (OpenMP `schedule(static)`),
//! - [`par_map`] — parallel map collecting results in order,
//! - [`sort::par_sort_by`] — parallel stable merge sort (steps 2–3 of
//!   pdGRASS sort off-tree edges and subtasks).
//!
//! Thread count comes from [`num_threads`]: the `PDGRASS_THREADS` env var
//! if set, else `std::thread::available_parallelism()`.

pub mod sort;

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use by default.
pub fn num_threads() -> usize {
    if let Ok(s) = std::env::var("PDGRASS_THREADS") {
        if let Ok(n) = s.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Dynamically-scheduled parallel for over `0..n`, with `grain` indices
/// claimed per atomic fetch. `f` is called once per index.
///
/// Equivalent OpenMP: `#pragma omp parallel for schedule(dynamic, grain)`.
pub fn par_for<F>(n: usize, threads: usize, grain: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n <= grain {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let grain = grain.max(1);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let start = next.fetch_add(grain, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + grain).min(n);
                for i in start..end {
                    f(i);
                }
            });
        }
    });
}

/// Statically chunked parallel loop: splits `0..n` into `threads`
/// near-equal ranges and calls `f(thread_id, range)` on each.
pub fn par_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        f(0, 0..n);
        return;
    }
    let per = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let f = &f;
            s.spawn(move || {
                let lo = t * per;
                let hi = ((t + 1) * per).min(n);
                if lo < hi {
                    f(t, lo..hi);
                }
            });
        }
    });
}

/// Parallel map over a slice, preserving order of results.
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    {
        let slots = as_send_ptr(&mut out);
        par_for(n, threads, 1, |i| {
            let r = f(&items[i]);
            // SAFETY: each index i is written by exactly one task.
            unsafe { slots.write(i, Some(r)) };
        });
    }
    out.into_iter().map(|o| o.expect("par_map slot unfilled")).collect()
}

/// Wrapper making a raw pointer Send+Sync for disjoint-index writes.
///
/// Edition-2021 disjoint closure capture would otherwise capture the inner
/// `*mut T` field directly (which is neither Send nor Sync), so access goes
/// through the [`SendPtr::write`] method which captures `&SendPtr`.
pub(crate) struct SendPtr<T>(pub *mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// Write `val` at offset `i`.
    ///
    /// # Safety
    /// Caller must guarantee `i` is in bounds and that no other thread
    /// reads or writes offset `i` concurrently.
    pub(crate) unsafe fn write(&self, i: usize, val: T) {
        *self.0.add(i) = val;
    }
}

pub(crate) fn as_send_ptr<T>(v: &mut [T]) -> SendPtr<T> {
    SendPtr(v.as_mut_ptr())
}

/// Parallel fill of a mutable slice by index: `out[i] = f(i)`.
/// Disjoint writes, so no synchronization is needed beyond the scope join.
pub fn par_fill<T, F>(out: &mut [T], threads: usize, grain: usize, f: F)
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let n = out.len();
    let ptr = as_send_ptr(out);
    par_for(n, threads, grain, |i| {
        // SAFETY: each index written exactly once; slice outlives the scope.
        unsafe { ptr.write(i, f(i)) };
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_for_visits_every_index_once() {
        for threads in [1, 2, 4, 8] {
            let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
            par_for(1000, threads, 7, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        }
    }

    #[test]
    fn par_chunks_covers_range_disjointly() {
        let seen: Vec<AtomicU64> = (0..103).map(|_| AtomicU64::new(0)).collect();
        par_chunks(103, 4, |_, range| {
            for i in range {
                seen[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(seen.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_map_preserves_order() {
        let xs: Vec<u64> = (0..500).collect();
        let ys = par_map(&xs, 4, |x| x * x);
        assert_eq!(ys, xs.iter().map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn par_fill_writes_all() {
        let mut out = vec![0usize; 256];
        par_fill(&mut out, 3, 5, |i| i + 1);
        assert!(out.iter().enumerate().all(|(i, &v)| v == i + 1));
    }

    #[test]
    fn zero_len_is_fine() {
        par_for(0, 4, 1, |_| panic!("should not run"));
        let v: Vec<u32> = vec![];
        assert!(par_map(&v, 4, |x| *x).is_empty());
    }

    #[test]
    fn num_threads_env_override() {
        // Can't mutate env safely in parallel tests; just sanity-check >= 1.
        assert!(num_threads() >= 1);
    }
}
