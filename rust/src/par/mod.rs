//! Parallel substrate — the repo's stand-in for OpenMP 4.5.
//!
//! The paper implements pdGRASS in C++17 + OpenMP. The offline vendor set
//! has neither `rayon` nor OpenMP bindings, so this module implements the
//! primitives the algorithm needs on top of a **persistent work-stealing
//! thread pool** ([`pool::ThreadPool`]) — the analogue of OpenMP's
//! long-lived runtime. Workers are created once, lazily, and every
//! primitive dispatches onto them; nothing here spawns per-call OS
//! threads anymore (spawn-per-call cost used to dominate small hot loops
//! like the per-PCG-iteration `spmv_par`; `benches/micro.rs` measures the
//! difference).
//!
//! - [`par_for`] — dynamically-scheduled parallel index loop (the OpenMP
//!   `parallel for schedule(dynamic)` used for outer subtask parallelism),
//! - [`par_chunks`] — statically chunked loop (OpenMP `schedule(static)`),
//! - [`par_map`] — parallel map collecting results in order,
//! - [`par_fill`] — parallel disjoint-index slice fill,
//! - [`par_update`] — parallel in-place elementwise update (the
//!   `axpy`-shaped BLAS-1 kernel: disjoint writes, zero allocation),
//! - [`par_reduce`] — deterministic fixed-tree reduction (`dot`/`norm2`
//!   in the PCG loop), see below,
//! - [`sort::par_sort_by`] — parallel stable merge sort (steps 2–3 of
//!   pdGRASS sort off-tree edges and subtasks): out-of-place ping-pong
//!   merges over one scratch buffer, splitter-parallel merge forked via
//!   [`pool::ThreadPool::join`], no `T: Clone` bound,
//! - [`stream::produce_stream`] — the cross-stage streaming handoff:
//!   chunks produced on the pool, consumed on the caller in ascending
//!   order with a bounded in-flight window, so adjacent pipeline stages
//!   overlap instead of barrier-syncing (the streamed
//!   prepare/recover pipeline is built on this; see `session`),
//! - [`chaos`] — seeded schedule perturbation (`PDGRASS_CHAOS_SEED`) at
//!   the pool/stream decision sites, so the determinism contracts above
//!   can be re-checked under many distinct interleavings.
//!
//! Every primitive keeps a serial fast path for `threads == 1` (or
//! trivially small inputs), takes a per-call `threads` override, and
//! produces output independent of scheduling (`all_strategies_agree` in
//! `recovery::pdgrass` pins this down). Nested use — e.g. `par_map`
//! inside a `par_for` task, the Mixed-strategy shape — is supported and
//! deadlock-free; a panic inside a pooled task propagates to the caller
//! instead of hanging the join (see `pool` for the execution model).
//!
//! # Determinism contract of [`par_reduce`]
//!
//! [`par_reduce`] folds leaf partials over a **fixed binary chunk tree**
//! whose shape (leaf boundaries and combine order) depends only on
//! `(n, grain)` — never on the thread count, pool state, or claim order.
//! `threads` only chooses how many tree levels are forked onto the pool.
//! Consequently, for non-associative combines (floating-point `+`) the
//! result is bitwise identical across repeated runs **and across thread
//! counts** at fixed `(n, grain)`. This is load-bearing for
//! `solver::pcg_par`: every `dot`/`norm2` in the iteration reduces over
//! the same tree at every thread count, so parallel PCG reproduces the
//! serial iterate sequence exactly, not merely to rounding.
//!
//! Thread count comes from [`num_threads`]: the `PDGRASS_THREADS` env var
//! if it parses to a positive integer (`0` clamps to 1, garbage falls
//! back), else `std::thread::available_parallelism()`. The global pool is
//! sized from this value at first use.

pub mod chaos;
pub mod pool;
pub mod reduce;
pub mod sort;
pub mod stream;

pub use pool::{spawn_service, ServiceHandle, ThreadPool};
pub use reduce::par_reduce;
pub use stream::produce_stream;

/// Fork depth for binary fork–join trees: `ceil(log2(threads))` levels,
/// so a tree forked this deep exposes at least `threads` leaves.
/// Shared by [`par_reduce`] and [`sort::par_sort_by`].
pub(crate) fn fork_depth(threads: usize) -> usize {
    if threads <= 1 {
        0
    } else {
        (usize::BITS - (threads - 1).leading_zeros()) as usize
    }
}

/// Number of worker threads to use by default.
pub fn num_threads() -> usize {
    num_threads_from(std::env::var("PDGRASS_THREADS").ok().as_deref())
}

/// Resolve a thread count from the raw `PDGRASS_THREADS` value.
///
/// Split out of [`num_threads`] so the override semantics are testable
/// without mutating process-global environment from parallel tests:
/// a parseable positive integer wins, `0` clamps to 1, anything else
/// (unset, garbage, negative, empty) falls back to
/// `available_parallelism`.
pub fn num_threads_from(var: Option<&str>) -> usize {
    match var.and_then(|s| s.trim().parse::<usize>().ok()) {
        Some(n) => n.max(1),
        None => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    }
}

/// Dynamically-scheduled parallel for over `0..n`, with `grain` indices
/// claimed per atomic fetch. `f` is called once per index, on the global
/// pool plus the calling thread.
///
/// Equivalent OpenMP: `#pragma omp parallel for schedule(dynamic, grain)`.
pub fn par_for<F>(n: usize, threads: usize, grain: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    ThreadPool::global().run_scope(n, threads, grain, f);
}

/// Statically chunked parallel loop: splits `0..n` into `threads`
/// near-equal ranges and calls `f(thread_id, range)` on each.
pub fn par_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        f(0, 0..n);
        return;
    }
    let per = n.div_ceil(threads);
    ThreadPool::global().run_scope(threads, threads, 1, |t| {
        let lo = t * per;
        let hi = ((t + 1) * per).min(n);
        if lo < hi {
            f(t, lo..hi);
        }
    });
}

/// Parallel map over a slice, preserving order of results.
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    {
        let slots = as_send_ptr(&mut out);
        par_for(n, threads, 1, |i| {
            let r = f(&items[i]);
            // SAFETY: each index i is written by exactly one task.
            unsafe { slots.write(i, Some(r)) };
        });
    }
    out.into_iter().map(|o| o.expect("par_map slot unfilled")).collect()
}

/// Wrapper making a raw pointer Send+Sync for disjoint-index writes.
///
/// Edition-2021 disjoint closure capture would otherwise capture the inner
/// `*mut T` field directly (which is neither Send nor Sync), so access goes
/// through the [`SendPtr::write`] method which captures `&SendPtr`.
pub(crate) struct SendPtr<T>(pub *mut T);
// SAFETY: the pointer is only dereferenced through the unsafe methods
// below, whose contracts require in-bounds, non-aliasing access.
unsafe impl<T> Send for SendPtr<T> {}
// SAFETY: shared references only hand out raw offsets via the unsafe
// methods; disjointness across threads is the callers' obligation.
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// Write `val` at offset `i`.
    ///
    /// # Safety
    /// Caller must guarantee `i` is in bounds and that no other thread
    /// reads or writes offset `i` concurrently.
    pub(crate) unsafe fn write(&self, i: usize, val: T) {
        *self.0.add(i) = val;
    }

    /// Raw pointer to offset `i`.
    ///
    /// # Safety
    /// Same contract as [`SendPtr::write`]: `i` in bounds, and the caller
    /// must not create aliasing accesses to offset `i` across threads.
    pub(crate) unsafe fn at(&self, i: usize) -> *mut T {
        self.0.add(i)
    }
}

pub(crate) fn as_send_ptr<T>(v: &mut [T]) -> SendPtr<T> {
    SendPtr(v.as_mut_ptr())
}

/// Parallel fill of a mutable slice by index: `out[i] = f(i)`.
/// Disjoint writes, so no synchronization is needed beyond the scope join.
pub fn par_fill<T, F>(out: &mut [T], threads: usize, grain: usize, f: F)
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let n = out.len();
    let ptr = as_send_ptr(out);
    par_for(n, threads, grain, |i| {
        // SAFETY: each index written exactly once; slice outlives the scope.
        unsafe { ptr.write(i, f(i)) };
    });
}

/// Parallel in-place elementwise update: `f(i, &mut v[i])` for every
/// index — the shape of every BLAS-1 `axpy`-style kernel in the PCG
/// loop. Disjoint writes, zero allocation; `grain` indices are claimed
/// per atomic fetch as in [`par_for`].
pub fn par_update<T, F>(v: &mut [T], threads: usize, grain: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let n = v.len();
    let ptr = as_send_ptr(v);
    par_for(n, threads, grain, |i| {
        // SAFETY: each index is visited exactly once per scope and the
        // slice outlives the scope join.
        unsafe { f(i, &mut *ptr.at(i)) };
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn par_for_visits_every_index_once() {
        for threads in [1, 2, 4, 8] {
            let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
            par_for(1000, threads, 7, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        }
    }

    #[test]
    fn par_chunks_covers_range_disjointly() {
        let seen: Vec<AtomicU64> = (0..103).map(|_| AtomicU64::new(0)).collect();
        par_chunks(103, 4, |_, range| {
            for i in range {
                seen[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(seen.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_chunks_ranges_are_static() {
        // Static schedule contract: thread t always gets the t-th
        // contiguous block, independent of execution order.
        let n = 103usize;
        let threads = 4usize;
        let per = n.div_ceil(threads);
        let starts: Vec<AtomicU64> = (0..threads).map(|_| AtomicU64::new(u64::MAX)).collect();
        par_chunks(n, threads, |t, range| {
            starts[t].store(range.start as u64, Ordering::Relaxed);
        });
        for (t, s) in starts.iter().enumerate() {
            assert_eq!(s.load(Ordering::Relaxed), (t * per) as u64);
        }
    }

    #[test]
    fn par_map_preserves_order() {
        let xs: Vec<u64> = (0..500).collect();
        let ys = par_map(&xs, 4, |x| x * x);
        assert_eq!(ys, xs.iter().map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn par_fill_writes_all() {
        let mut out = vec![0usize; 256];
        par_fill(&mut out, 3, 5, |i| i + 1);
        assert!(out.iter().enumerate().all(|(i, &v)| v == i + 1));
    }

    #[test]
    fn par_update_applies_in_place() {
        let mut v: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        par_update(&mut v, 4, 16, |i, x| *x = 2.0 * *x + i as f64);
        assert!(v.iter().enumerate().all(|(i, &x)| x == 3.0 * i as f64));
    }

    #[test]
    fn zero_len_is_fine() {
        par_for(0, 4, 1, |_| panic!("should not run"));
        let v: Vec<u32> = vec![];
        assert!(par_map(&v, 4, |x| *x).is_empty());
        par_chunks(0, 4, |_, range| assert!(range.is_empty()));
        let mut empty: [u8; 0] = [];
        par_fill(&mut empty, 4, 1, |_| 0);
        let mut e2: [f64; 0] = [];
        par_update(&mut e2, 4, 1, |_, _| panic!("should not run"));
    }

    #[test]
    fn fork_depth_covers_thread_counts() {
        assert_eq!(fork_depth(0), 0);
        assert_eq!(fork_depth(1), 0);
        assert_eq!(fork_depth(2), 1);
        assert_eq!(fork_depth(3), 2);
        assert_eq!(fork_depth(4), 2);
        assert_eq!(fork_depth(5), 3);
        assert_eq!(fork_depth(8), 3);
        assert_eq!(fork_depth(9), 4);
        // 2^depth >= threads always.
        for t in 1usize..=64 {
            assert!(1usize << fork_depth(t) >= t, "t={t}");
        }
    }

    #[test]
    fn num_threads_env_override() {
        // Valid values win.
        assert_eq!(num_threads_from(Some("3")), 3);
        assert_eq!(num_threads_from(Some(" 5 ")), 5);
        assert_eq!(num_threads_from(Some("1")), 1);
        // Zero clamps to 1 instead of disabling the substrate.
        assert_eq!(num_threads_from(Some("0")), 1);
        // Garbage, negatives, and empty fall back to autodetection.
        let auto = num_threads_from(None);
        assert!(auto >= 1);
        assert_eq!(num_threads_from(Some("not-a-number")), auto);
        assert_eq!(num_threads_from(Some("-2")), auto);
        assert_eq!(num_threads_from(Some("")), auto);
        // And the live value is always usable.
        assert!(num_threads() >= 1);
    }
}
