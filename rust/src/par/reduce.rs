//! Deterministic parallel reduction over an index range.
//!
//! [`par_reduce`] evaluates `map` on leaf sub-ranges of `0..n` and folds
//! the partials with `combine` over a **fixed-shape binary chunk tree**.
//! The tree shape — leaf boundaries and combine order — is a pure
//! function of `(n, grain)`: the range is viewed as `ceil(n/grain)`
//! grain-sized chunks and split at the chunk midpoint, recursively, until
//! a single chunk remains. The `threads` argument only sets how many
//! levels of the tree are *forked* onto the pool (via
//! [`super::pool::ThreadPool::join_map`]); it never changes the shape.
//!
//! # Determinism contract
//!
//! For non-associative `combine` (floating-point `+`), the result is
//! therefore **bitwise identical** across runs *and across thread
//! counts* for a fixed `(n, grain)` — strictly stronger than
//! run-to-run reproducibility. This is what lets `solver::pcg_par`
//! produce the exact same iterate sequence at every thread count, and
//! `pcg` (threads = 1) to be the same arithmetic as the pooled path.
//!
//! Scheduling cannot perturb the result because each tree node's value is
//! produced by exactly one closure and combined at exactly one parent;
//! there is no claim-order-dependent accumulation anywhere.

use std::ops::Range;

/// Reduce `0..n`: `combine(map(leaf₀), map(leaf₁), …)` over the fixed
/// chunk tree described in the module docs.
///
/// * `map` folds one leaf range serially (it must accept the empty range
///   when `n == 0` and return the identity).
/// * `combine` joins two subtree partials; called in tree order,
///   left-to-right.
/// * `threads` bounds fork depth (`ceil(log2(threads))` levels); `1`
///   runs entirely on the calling thread with the same tree shape.
/// * `grain` is the leaf size (clamped to ≥ 1); leaves are
///   `grain`-aligned so the shape is independent of everything but
///   `(n, grain)`.
///
/// Panics in `map`/`combine` propagate to the caller (see `pool`).
pub fn par_reduce<T, M, C>(n: usize, threads: usize, grain: usize, map: M, combine: C) -> T
where
    T: Send,
    M: Fn(Range<usize>) -> T + Sync,
    C: Fn(T, T) -> T + Sync,
{
    let grain = grain.max(1);
    let depth = super::fork_depth(threads.max(1));
    reduce_node(0, n, grain, depth, &map, &combine)
}

/// One node of the chunk tree over `lo..hi`. Forks while `depth > 0`;
/// the split point is the same either way, so forked and serial
/// evaluation produce identical combine trees.
fn reduce_node<T, M, C>(
    lo: usize,
    hi: usize,
    grain: usize,
    depth: usize,
    map: &M,
    combine: &C,
) -> T
where
    T: Send,
    M: Fn(Range<usize>) -> T + Sync,
    C: Fn(T, T) -> T + Sync,
{
    let chunks = (hi - lo).div_ceil(grain);
    if chunks <= 1 {
        return map(lo..hi);
    }
    // Grain-aligned midpoint: left subtree gets ceil(chunks/2) chunks.
    let mid = lo + chunks.div_ceil(2) * grain;
    if depth == 0 {
        let left = reduce_node(lo, mid, grain, 0, map, combine);
        let right = reduce_node(mid, hi, grain, 0, map, combine);
        combine(left, right)
    } else {
        let (left, right) = super::ThreadPool::global().join_map(
            || reduce_node(lo, mid, grain, depth - 1, map, combine),
            || reduce_node(mid, hi, grain, depth - 1, map, combine),
        );
        combine(left, right)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sum_range(xs: &[f64]) -> impl Fn(Range<usize>) -> f64 + Sync + '_ {
        move |r: Range<usize>| {
            let mut s = 0.0;
            for i in r {
                s += xs[i];
            }
            s
        }
    }

    #[test]
    fn reduce_matches_serial_sum() {
        let mut rng = Rng::new(11);
        for n in [0usize, 1, 5, 100, 4096, 10_001] {
            let xs: Vec<f64> = (0..n).map(|_| rng.next_f64() - 0.5).collect();
            let serial: f64 = xs.iter().sum();
            for threads in [1usize, 2, 4, 8] {
                for grain in [1usize, 64, 4096] {
                    let s = par_reduce(n, threads, grain, sum_range(&xs), |a, b| a + b);
                    assert!(
                        (s - serial).abs() <= 1e-12 * serial.abs().max(1.0),
                        "n={n} threads={threads} grain={grain}: {s} vs {serial}"
                    );
                }
            }
        }
    }

    #[test]
    fn reduce_is_bitwise_identical_across_thread_counts() {
        let mut rng = Rng::new(12);
        let xs: Vec<f64> = (0..30_000).map(|_| rng.next_f64() - 0.5).collect();
        for grain in [1usize, 17, 1024] {
            let reference = par_reduce(xs.len(), 1, grain, sum_range(&xs), |a, b| a + b);
            for threads in [2usize, 3, 4, 8, 64] {
                for _run in 0..3 {
                    let s = par_reduce(xs.len(), threads, grain, sum_range(&xs), |a, b| a + b);
                    assert_eq!(
                        s.to_bits(),
                        reference.to_bits(),
                        "grain={grain} threads={threads}"
                    );
                }
            }
        }
    }

    #[test]
    fn reduce_generic_non_float() {
        // max over u64 with an identity-producing empty leaf.
        let xs: Vec<u64> = (0..50_000u64).map(|i| (i * 2654435761) % 1_000_003).collect();
        let expect = *xs.iter().max().unwrap();
        let got = par_reduce(
            xs.len(),
            4,
            128,
            |r: Range<usize>| r.map(|i| xs[i]).max().unwrap_or(0),
            u64::max,
        );
        assert_eq!(got, expect);
    }

    #[test]
    fn reduce_empty_range_hits_map_once() {
        let calls = std::sync::atomic::AtomicUsize::new(0);
        let s = par_reduce(
            0,
            8,
            4,
            |r: Range<usize>| {
                calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                assert!(r.is_empty());
                0.0f64
            },
            |a, b| a + b,
        );
        assert_eq!(s, 0.0);
        assert_eq!(calls.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn reduce_panic_propagates() {
        let result = std::panic::catch_unwind(|| {
            par_reduce(
                10_000,
                4,
                8,
                |r: Range<usize>| {
                    if r.contains(&7777) {
                        panic!("leaf boom");
                    }
                    r.len() as u64
                },
                |a, b| a + b,
            )
        });
        assert!(result.is_err());
        // Pool remains serviceable.
        let s = par_reduce(1000, 4, 8, |r: Range<usize>| r.len() as u64, |a, b| a + b);
        assert_eq!(s, 1000);
    }
}
