//! # pdGRASS — parallel density-aware graph spectral sparsification
//!
//! Reproduction of *pdGRASS: A Fast Parallel Density-Aware Algorithm for
//! Graph Spectral Sparsification* (CS.DC 2025) as a three-layer
//! Rust + JAX + Pallas system. See `DESIGN.md` for the system inventory and
//! the per-experiment index, and `EXPERIMENTS.md` for paper-vs-measured.
//!
//! Pipeline: build/load a graph → spanning tree on *effective weights*
//! (Def. 1) → score off-tree edges by weighted *resistance distance*
//! (Def. 2) → recover `α|V|` off-tree edges (feGRASS loose condition, or
//! pdGRASS strict condition over LCA-grouped subtasks) → evaluate the
//! sparsifier as a PCG preconditioner (pure-Rust path, or the XLA path
//! executing the AOT-compiled Pallas SpMV kernel).

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod gen;
pub mod graph;
pub mod par;
pub mod recovery;
pub mod runtime;
pub mod solver;
pub mod tree;
pub mod util;
