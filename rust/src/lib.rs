//! # pdGRASS — parallel density-aware graph spectral sparsification
//!
//! Reproduction of *pdGRASS: A Fast Parallel Density-Aware Algorithm for
//! Graph Spectral Sparsification* (CS.DC 2025) as a pure-Rust system with
//! an optional XLA-compiled kernel path.
//!
//! ## Architecture
//!
//! The crate is layered bottom-up:
//!
//! * [`graph`] — CSR graphs, MatrixMarket I/O, connectivity, Laplacians.
//! * [`tree`] — spanning-tree substrate: effective weights (Def. 1),
//!   maximum spanning tree, binary-lifting LCA, resistance distances
//!   (Def. 2).
//! * [`recovery`] — off-tree edge recovery: the feGRASS baseline (loose
//!   similarity) and pdGRASS (strict similarity over LCA subtasks, the
//!   paper's core contribution). Step 4 runs under one of five
//!   strategies; beyond the paper's serial/outer/inner/mixed, the
//!   `sharded` strategy splits a giant subtask into contiguous
//!   score-order shards that speculate concurrently on the pool
//!   (exploration is a pure function of the edge, so speculative results
//!   are a memo-cache), then commits serially in fixed shard order —
//!   bitwise identical to the serial pass at any thread count, which is
//!   what lets the skewed worst cases (one dominant LCA subtask) scale
//!   past one block at a time.
//! * [`par`] — the parallel substrate: a persistent work-stealing thread
//!   pool with deterministic reductions, a move-based parallel sort, and
//!   the `produce_stream` cross-stage handoff (chunks produced on the
//!   pool, consumed in deterministic order with a bounded in-flight
//!   window) that the streamed pipeline is built on.
//! * [`solver`] — CSR SpMV, RCM ordering, sparse LDLᵀ with a
//!   level-scheduled parallel triangular solve (the factor's dependency
//!   DAG is bucketed into level sets at factor time; both sweeps then
//!   dispatch whole levels across the pool, bitwise identical to the
//!   serial solve at every thread count), and the PCG evaluation
//!   harness (the paper's sparsifier-quality metric) — fully pooled,
//!   including the preconditioner application.
//! * [`session`] — **the primary API**: staged
//!   `Sparsify → Prepared → Recovered → Sparsifier` sessions that compute
//!   the invariant state (steps 1–3 of Algorithm 1) once and recover any
//!   number of (α, strategy, threads) variants from it.
//! * [`snapshot`] — the warm-start story: [`Prepared::save`] /
//!   [`Prepared::load`] persist that invariant state as a versioned,
//!   checksummed flat-array container (CRC-32 per section, fingerprint
//!   cross-check, full semantic re-validation on load), so a *different
//!   process* — a restarted daemon, another fleet worker, a later CLI
//!   run — skips steps 1–3 entirely and pays O(read + validate). A
//!   loaded snapshot recovers and evaluates bitwise identically to the
//!   `Prepared` that was saved; anything corrupt or stale is the typed
//!   [`Error::Snapshot`], never a silently-wrong state.
//!
//! ## Pipeline disciplines: barrier vs streamed
//!
//! Every stage handoff runs under one of two disciplines
//! ([`Pipeline`], selectable per session via [`Sparsify::pipeline`] /
//! `prepare_streamed`, per recovery via `RecoverOpts::pipeline`, and via
//! the `pipeline = "streamed"` config key or `--pipeline` CLI flag). The
//! **barrier** timeline joins every Algorithm-1 stage; the **streamed**
//! timeline overlaps them on the pool — workers score chunk `i+1` while
//! the consumer merges chunk `i`, the subtask grouping rides the final
//! merge pass, and recovery outcomes are absorbed as they complete:
//!
//! ```text
//! barrier   workers ▕ score score score ▏▁▁idle▁▁▕ recover recover
//!           caller  ▕▁▁▁▁▁▁▁idle▁▁▁▁▁▁▁▏ sort+group ▕▁▁▁▁absorb▁▁▁▁
//!                                       ^ join      ^ join     ^ join
//!
//! streamed  workers ▕ score score score ▏ recover recover recover
//!           caller  ▕▁▁▏ merge ▏ merge+group ▏ absorb absorb
//!                        (overlapped — no stage joins)
//! ```
//!
//! Both disciplines produce **bitwise-identical** results at every
//! thread count: per-edge computations are pure, every sort key is a
//! strict total order (ties broken by edge id), and outcome absorption
//! is order-insensitive. `coordinator::schedsim`'s `PrepSim` models the
//! two timelines and quantifies the overlap win (`pdgrass pipeline`
//! prints it per suite graph).
//! * [`error`] — the typed [`Error`] enum every library-boundary
//!   function returns.
//! * [`coordinator`] / [`cli`] / [`config`] — experiment drivers
//!   reproducing the paper's tables and figures, all wired through the
//!   session API; plus the launcher surface.
//! * [`serve`] — the serving layer: `pdgrass serve` runs a long-lived
//!   daemon that owns an LRU cache of [`Prepared`] states keyed by the
//!   deterministic graph fingerprint ([`graph::fingerprint`]) and
//!   answers line-delimited-JSON `prepare`/`recover`/`pcg` requests over
//!   a Unix-domain socket — prepare once per *graph*, serve step 4 at
//!   any (α, strategy, pipeline) to any number of clients. Bounded
//!   admission rejects excess load with a typed `overloaded` error
//!   instead of queueing; per-request deadlines and per-spec failure
//!   caps degrade gracefully; every request emits a JSON-lines run
//!   summary. `pdgrass bombard` replays seeded deterministic traffic
//!   against it and reports throughput and tail latency. With a
//!   configured `[serve] snapshot_dir`, cache misses first try a
//!   snapshot load ([`snapshot`]) and successful prepares are written
//!   back — so a restarted daemon answers its first request from a warm
//!   load instead of re-running steps 1–3.
//! * [`benchdiff`] — the bench no-regression gate: parses the
//!   `BENCH_*.json` artifacts `benches/micro.rs` emits and compares two
//!   of them (`pdgrass benchdiff old.json new.json`): structural
//!   `model_units` must match exactly (they are machine-independent cost
//!   models), wall-clock `bench_ms` within a tolerance band.
//! * [`gen`], [`runtime`], [`util`] — the synthetic evaluation suite, the
//!   XLA/Pallas kernel runtime, and shared utilities.
//!
//! ## Memory layout & scaling
//!
//! Giant inputs are a first-class concern; the layers above share a few
//! layout decisions made for them:
//!
//! * **Compact u32 indexing** — every CSR offset array (graph adjacency,
//!   Laplacian rowptr, LDLᵀ factor columns, rooted-tree children) is
//!   `u32`, halving index memory and cache traffic. Construction checks
//!   the bound once up front and rejects oversized inputs with the typed
//!   [`Error::IndexOverflow`] instead of silently truncating (u64
//!   fallback: see ROADMAP).
//! * **Locality relabeling** — [`Sparsify::relabel`] (config
//!   `relabel = "bfs" | "degree"`, CLI `--relabel`) permutes vertex ids
//!   at ingest so BFS/tree walks touch near-contiguous memory; the whole
//!   pipeline runs in permuted space, while sparsifiers and the PCG
//!   evaluation are expressed in original ids — on tie-free inputs the
//!   recovered edge set and PCG iteration counts are unchanged
//!   ([`graph::relabel`] documents the equivariance argument).
//! * **Cache-blocked SpMV** — `solver::spmv_par` partitions rows by
//!   prefix-summed nnz (not row count) and sweeps heavy rows through
//!   column blocks in row tiles; `solver::spmv_traffic_model` is the
//!   deterministic cost model the benches pin.
//! * **Arena-backed recovery scratch** — sharded subtask exploration
//!   draws its visit buffers from a per-pass arena, bounding allocation
//!   by pool width instead of subtask count.
//!
//! ## Quick start: prepare once, recover many
//!
//! Steps 1–3 (spanning tree on effective weights, resistance scoring,
//! criticality sort) do not depend on the recovery parameters, so they
//! are computed once per [`Prepared`] session; each
//! [`Prepared::recover`] call pays only step 4:
//!
//! ```
//! use pdgrass::{RecoverOpts, Sparsify};
//!
//! # fn main() -> pdgrass::Result<()> {
//! let g = pdgrass::gen::grid(20, 20, 0.5, &mut pdgrass::util::Rng::new(1));
//! let prepared = Sparsify::graph(g).named("demo").prepare()?;
//!
//! // Any number of recoveries reuse the prepared state (step 4 only):
//! let sparse = prepared.recover(&RecoverOpts::new(0.05))?;
//! let dense = prepared.recover(&RecoverOpts::new(0.10))?;
//! assert!(dense.edges().len() > sparse.edges().len());
//!
//! // Evaluate a sparsifier as a PCG preconditioner (the paper's metric):
//! let outcome = sparse.sparsifier().pcg(42, 1e-3, 10_000)?.require_converged()?;
//! assert!(outcome.iterations > 0);
//! # Ok(()) }
//! ```
//!
//! ## Correctness toolchain
//!
//! The determinism guarantee above is enforced by a static pass and a
//! dynamic one, both in-tree:
//!
//! * **`pdgrass audit`** ([`analysis`]) lints `rust/src` with a
//!   dependency-free lexer: every `unsafe` needs a `// SAFETY:` /
//!   `# Safety` justification, thread spawning is confined to the pool,
//!   every non-test atomic `Ordering` must appear in
//!   `rust/analysis/atomics.allow` with a reviewed justification, and the
//!   algorithm modules (`recovery/`, `tree/`, `solver/`) may not use
//!   randomized-iteration collections, wall-clock timing, or
//!   float-accumulator `.sum()`/`.fold()` (annotate deliberate
//!   exceptions with `// audit-ok: reason`). To allow a new ordering,
//!   add a `file | item | ordering | justification` line to the
//!   allowlist — the audit's violation message prints the exact line.
//! * **Schedule chaos** ([`par::chaos`]) injects seeded yield/sleep
//!   noise at the pool's claim/steal/park and the stream's claim/await
//!   sites when `PDGRASS_CHAOS_SEED` is set, and the chaos test suite
//!   replays the bitwise-equivalence checks under several distinct
//!   schedules. A failure report names the seed to replay.

pub mod analysis;
pub mod benchdiff;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod gen;
pub mod graph;
pub mod par;
pub mod recovery;
pub mod runtime;
pub mod serve;
pub mod session;
pub mod snapshot;
pub mod solver;
pub mod tree;
pub mod util;

pub use error::{Error, Result};
pub use recovery::{Pipeline, Strategy};
pub use session::{PcgOutcome, Prepared, RecoverOpts, Recovered, Sparsifier, Sparsify};
