//! Sparse LDLᵀ factorization (up-looking, elimination-tree based — the
//! classic Davis `LDL` algorithm) and triangular solves, serial and
//! level-scheduled parallel.
//!
//! The PCG evaluation uses `L_P` (the sparsifier Laplacian, grounded) as
//! the preconditioner; it is factored **once** and each PCG iteration
//! applies two triangular solves — the same cost profile as MATLAB's
//! `pcg(L_G, b, tol, maxit, L_chol, L_chol')` setup the paper uses.
//!
//! # Level-scheduled parallel solve
//!
//! A triangular solve is a DAG traversal: forward row `i` waits on every
//! column `j < i` with `L[i,j] ≠ 0`, and the backward sweep on the
//! transposed edges. At factor time [`LevelSchedule`] groups rows into
//! *level sets* (row level = 1 + max level over its dependencies), so
//! all rows of one level are pairwise independent and a level can be
//! dispatched across the pool with a join per level
//! ([`LdlFactor::solve_par`]). To keep the parallel forward sweep
//! bitwise identical to the serial scatter in [`LdlFactor::solve`], the
//! strict-lower factor is stored **twice**: CSC (`lp`/`li`/`lx`, what
//! the factorization and backward sweep walk) and a row-oriented CSR
//! mirror (`rp`/`ri`/`rx`) whose per-row gather folds the same operands
//! in the same (ascending-column) order as the serial scatter applies
//! them — a fixed per-row op sequence independent of thread count,
//! matching the parity discipline of `par::par_reduce`.

use crate::graph::CsrMatrix;

/// Rows claimed per atomic fetch when a level is dispatched on the pool.
const LEVEL_GRAIN: usize = 32;

/// Minimum level width before a level is dispatched onto the pool;
/// narrower levels run inline on the caller. The per-row fold is
/// identical either way, so the cutoff is a pure scheduling choice with
/// no effect on results (a path graph's width-1 levels never pay a
/// dispatch).
const LEVEL_PAR_CUTOFF: usize = 128;

/// Indices claimed per fetch for the elementwise diagonal scale.
const DIAG_GRAIN: usize = 4096;

/// Level sets of the triangular-solve dependency DAG, derived once at
/// factor time. Rows within a level are pairwise independent; levels
/// execute in ascending order with a join between levels. Rows are
/// stored in ascending index order inside each level (deterministic,
/// though the solves are order-insensitive within a level: writes are
/// disjoint and operands come from earlier levels).
#[derive(Clone, Debug)]
pub struct LevelSchedule {
    /// Forward (`L`) level pointers into `fwd_rows`, length `levels + 1`.
    fwd_ptr: Vec<usize>,
    /// Rows grouped by forward level, ascending within each level.
    fwd_rows: Vec<u32>,
    /// Backward (`Lᵀ`) level pointers into `bwd_rows`.
    bwd_ptr: Vec<usize>,
    /// Columns grouped by backward level, ascending within each level.
    bwd_rows: Vec<u32>,
}

impl LevelSchedule {
    /// Derive both sweeps' level sets from the factor's sparsity pattern
    /// (CSC `lp`/`li` plus the row mirror `rp`/`ri`).
    fn build(n: usize, lp: &[u32], li: &[u32], rp: &[u32], ri: &[u32]) -> LevelSchedule {
        // Forward: row i waits on every column j < i with L[i,j] ≠ 0.
        // Ascending i visits dependencies before dependents.
        let mut lvl = vec![0u32; n];
        for i in 0..n {
            let mut l = 0u32;
            for p in rp[i] as usize..rp[i + 1] as usize {
                l = l.max(lvl[ri[p] as usize] + 1);
            }
            lvl[i] = l;
        }
        let (fwd_ptr, fwd_rows) = bucket_levels(&lvl);
        // Backward: column j waits on every row i > j with L[i,j] ≠ 0.
        // Descending j visits dependencies first, so `lvl` can be
        // overwritten in place with the backward levels.
        for j in (0..n).rev() {
            let mut l = 0u32;
            for p in lp[j] as usize..lp[j + 1] as usize {
                l = l.max(lvl[li[p] as usize] + 1);
            }
            lvl[j] = l;
        }
        let (bwd_ptr, bwd_rows) = bucket_levels(&lvl);
        LevelSchedule { fwd_ptr, fwd_rows, bwd_ptr, bwd_rows }
    }

    /// Number of forward (`L`) levels.
    pub fn num_forward_levels(&self) -> usize {
        self.fwd_ptr.len() - 1
    }

    /// Rows of forward level `l`, ascending.
    pub fn forward_level(&self, l: usize) -> &[u32] {
        &self.fwd_rows[self.fwd_ptr[l]..self.fwd_ptr[l + 1]]
    }

    /// Number of backward (`Lᵀ`) levels.
    pub fn num_backward_levels(&self) -> usize {
        self.bwd_ptr.len() - 1
    }

    /// Columns of backward level `l`, ascending.
    pub fn backward_level(&self, l: usize) -> &[u32] {
        &self.bwd_rows[self.bwd_ptr[l]..self.bwd_ptr[l + 1]]
    }
}

/// Counting-sort rows into level buckets: returns `(ptr, rows)` with
/// `rows[ptr[l]..ptr[l+1]]` = the rows of level `l`, ascending (the
/// enumeration below visits rows in index order).
fn bucket_levels(lvl: &[u32]) -> (Vec<usize>, Vec<u32>) {
    let n = lvl.len();
    let nlev = lvl.iter().map(|&l| l as usize + 1).max().unwrap_or(0);
    let mut ptr = vec![0usize; nlev + 1];
    for &l in lvl {
        ptr[l as usize + 1] += 1;
    }
    for l in 0..nlev {
        ptr[l + 1] += ptr[l];
    }
    let mut rows = vec![0u32; n];
    let mut fill = ptr.clone();
    for (i, &l) in lvl.iter().enumerate() {
        rows[fill[l as usize]] = i as u32;
        fill[l as usize] += 1;
    }
    (ptr, rows)
}

/// Row-oriented CSR mirror of the strict-lower CSC factor. Iterating
/// columns in ascending order fills each row's entries in ascending
/// column order — exactly the order the serial forward scatter applies
/// its updates to any fixed slot.
type LowerCsr = (Vec<u32>, Vec<u32>, Vec<f64>);

fn lower_csr_mirror(n: usize, lp: &[u32], li: &[u32], lx: &[f64]) -> LowerCsr {
    let mut rp = vec![0u32; n + 1];
    for &i in li {
        rp[i as usize + 1] += 1;
    }
    for i in 0..n {
        rp[i + 1] += rp[i];
    }
    let mut ri = vec![0u32; li.len()];
    let mut rx = vec![0f64; lx.len()];
    let mut fill = rp.clone();
    for j in 0..n {
        for p in lp[j] as usize..lp[j + 1] as usize {
            let i = li[p] as usize;
            ri[fill[i] as usize] = j as u32;
            rx[fill[i] as usize] = lx[p];
            fill[i] += 1;
        }
    }
    (rp, ri, rx)
}

/// Total and max per-row cost (1 + gathered nnz) of one schedule level.
fn level_cost(rows: &[u32], ptr: &[u32]) -> (u64, u64) {
    let mut work = 0u64;
    let mut max_row = 0u64;
    for &i in rows {
        let i = i as usize;
        let c = 1 + u64::from(ptr[i + 1] - ptr[i]);
        work += c;
        max_row = max_row.max(c);
    }
    (work, max_row)
}

/// LDLᵀ factors: unit lower-triangular `L` (strict part stored CSC) and
/// diagonal `D`, plus the row-oriented mirror of `L` and the
/// [`LevelSchedule`] backing [`LdlFactor::solve_par`].
#[derive(Clone, Debug)]
pub struct LdlFactor {
    n: usize,
    /// Column pointers of strict-lower L (CSC), length n+1, compact u32
    /// (factorization asserts the fill-in fits the u32 index space).
    lp: Vec<u32>,
    /// Row indices of L entries.
    li: Vec<u32>,
    /// Values of L entries.
    lx: Vec<f64>,
    /// Row pointers of the CSR mirror of strict-lower L, length n+1.
    rp: Vec<u32>,
    /// Column indices of mirror entries (ascending within each row).
    ri: Vec<u32>,
    /// Values of mirror entries.
    rx: Vec<f64>,
    /// Diagonal of D.
    d: Vec<f64>,
    /// Level sets of both triangular sweeps.
    sched: LevelSchedule,
}

/// Factorization failure: a non-positive pivot (matrix not positive
/// definite to working precision).
#[derive(Debug)]
pub struct NotPositiveDefinite {
    /// Pivot index where factorization broke down.
    pub at: usize,
    /// The offending pivot value.
    pub pivot: f64,
}

impl std::fmt::Display for NotPositiveDefinite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "non-positive pivot {} at index {}", self.pivot, self.at)
    }
}

impl std::error::Error for NotPositiveDefinite {}

impl LdlFactor {
    /// Factor a symmetric positive-definite CSR matrix (full storage;
    /// only the upper triangle is read, by symmetry of access).
    pub fn factor(a: &CsrMatrix) -> Result<LdlFactor, NotPositiveDefinite> {
        let n = a.n;
        // --- symbolic: elimination tree + column counts ---
        let mut parent = vec![usize::MAX; n];
        let mut flag = vec![usize::MAX; n];
        let mut lnz = vec![0usize; n];
        for k in 0..n {
            flag[k] = k;
            let (cols, _) = a.row(k);
            for &c in cols {
                let mut i = c as usize;
                if i >= k {
                    continue;
                }
                while flag[i] != k {
                    if parent[i] == usize::MAX {
                        parent[i] = k;
                    }
                    lnz[i] += 1;
                    flag[i] = k;
                    i = parent[i];
                }
            }
        }
        let nnz_total: u64 = lnz.iter().map(|&c| c as u64).sum();
        assert!(nnz_total + 1 < u32::MAX as u64, "LDL fill-in exceeds u32 index space");
        let mut lp = vec![0u32; n + 1];
        for i in 0..n {
            lp[i + 1] = lp[i] + lnz[i] as u32;
        }
        let nnz_l = lp[n] as usize;
        let mut li = vec![0u32; nnz_l];
        let mut lx = vec![0f64; nnz_l];
        let mut d = vec![0f64; n];
        // --- numeric ---
        let mut y = vec![0f64; n];
        let mut pattern = vec![0usize; n];
        let mut lfill = lp.clone(); // next free slot per column
        let mut flag = vec![usize::MAX; n];
        let mut stack = vec![0usize; n];
        for k in 0..n {
            let mut top = n;
            flag[k] = k;
            y[k] = 0.0;
            let (cols, vals) = a.row(k);
            for (&c, &v) in cols.iter().zip(vals) {
                let i0 = c as usize;
                if i0 > k {
                    continue;
                }
                y[i0] += v;
                // walk up the etree collecting the row-k pattern
                let mut len = 0usize;
                let mut i = i0;
                while flag[i] != k {
                    stack[len] = i;
                    len += 1;
                    flag[i] = k;
                    i = parent[i];
                }
                while len > 0 {
                    len -= 1;
                    top -= 1;
                    pattern[top] = stack[len];
                }
            }
            d[k] = y[k];
            y[k] = 0.0;
            for s in top..n {
                let i = pattern[s];
                let yi = y[i];
                y[i] = 0.0;
                for p in lp[i] as usize..lfill[i] as usize {
                    y[li[p] as usize] -= lx[p] * yi;
                }
                let dii = d[i];
                let lki = yi / dii;
                d[k] -= lki * yi;
                li[lfill[i] as usize] = k as u32;
                lx[lfill[i] as usize] = lki;
                lfill[i] += 1;
            }
            if d[k] <= 0.0 || !d[k].is_finite() {
                return Err(NotPositiveDefinite { at: k, pivot: d[k] });
            }
        }
        let (rp, ri, rx) = lower_csr_mirror(n, &lp, &li, &lx);
        let sched = LevelSchedule::build(n, &lp, &li, &rp, &ri);
        Ok(LdlFactor { n, lp, li, lx, rp, ri, rx, d, sched })
    }

    /// Dimension.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True if the factor is empty.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Nonzeros in the strict lower factor (fill-in metric).
    pub fn nnz_l(&self) -> usize {
        self.lx.len()
    }

    /// The level schedule derived at factor time (diagnostics, benches).
    pub fn schedule(&self) -> &LevelSchedule {
        &self.sched
    }

    /// Solve `L D Lᵀ x = b` in place.
    pub fn solve(&self, x: &mut [f64]) {
        debug_assert_eq!(x.len(), self.n);
        // forward: L y = b   (unit diagonal)
        for j in 0..self.n {
            let xj = x[j];
            if xj != 0.0 {
                for p in self.lp[j] as usize..self.lp[j + 1] as usize {
                    x[self.li[p] as usize] -= self.lx[p] * xj;
                }
            }
        }
        // diagonal
        for j in 0..self.n {
            x[j] /= self.d[j];
        }
        // backward: Lᵀ x = y
        for j in (0..self.n).rev() {
            let mut acc = x[j];
            for p in self.lp[j] as usize..self.lp[j + 1] as usize {
                acc -= self.lx[p] * x[self.li[p] as usize];
            }
            x[j] = acc;
        }
    }

    /// As [`LdlFactor::solve`], with each [`LevelSchedule`] level
    /// dispatched across `threads` pool workers — **bitwise identical**
    /// to the serial solve at every thread count.
    ///
    /// Why parity holds: for any slot, the serial forward scatter
    /// applies its updates in ascending column order, each operand
    /// `x[j]` already final, skipping zero operands; the per-row gather
    /// over the CSR mirror ([`LdlFactor::forward_row`]) folds exactly
    /// that operand sequence (the zero-skip is replicated because
    /// `acc -= l·0.0` is not an IEEE 754 no-op — it can flip a −0.0
    /// accumulator to +0.0). The backward sweep is already a per-column
    /// gather in the serial code, reproduced verbatim per column. Writes
    /// within a level are disjoint and levels are separated by pool
    /// joins, so scheduling cannot reorder any fold.
    ///
    /// `threads <= 1` takes the serial path unchanged. Level-0 rows
    /// (resp. columns) have no dependencies and empty gathers — the
    /// identity — so both sweeps start at level 1.
    pub fn solve_par(&self, x: &mut [f64], threads: usize) {
        debug_assert_eq!(x.len(), self.n);
        if threads <= 1 {
            self.solve(x);
            return;
        }
        // forward: L y = b, level by level over the row mirror
        {
            let ptr = crate::par::as_send_ptr(x);
            for l in 1..self.sched.num_forward_levels() {
                let rows = self.sched.forward_level(l);
                if rows.len() < LEVEL_PAR_CUTOFF {
                    for &i in rows {
                        // SAFETY: row i's dependencies finished in earlier
                        // levels and this loop is single-threaded, so no
                        // slot is accessed concurrently.
                        unsafe { self.forward_row(&ptr, i as usize) };
                    }
                } else {
                    crate::par::par_for(rows.len(), threads, LEVEL_GRAIN, |k| {
                        // SAFETY: rows within a level are pairwise
                        // independent and distinct (disjoint writes, reads
                        // only from earlier levels); the per-level scope
                        // join orders cross-level accesses.
                        unsafe { self.forward_row(&ptr, rows[k] as usize) };
                    });
                }
            }
        }
        // diagonal: disjoint elementwise scale, same expression per slot
        // as the serial loop
        let d = &self.d;
        crate::par::par_update(x, threads, DIAG_GRAIN, |j, xj| *xj /= d[j]);
        // backward: Lᵀ x = y, level by level over the CSC columns
        let ptr = crate::par::as_send_ptr(x);
        for l in 1..self.sched.num_backward_levels() {
            let cols = self.sched.backward_level(l);
            if cols.len() < LEVEL_PAR_CUTOFF {
                for &j in cols {
                    // SAFETY: column j's dependencies finished in earlier
                    // levels and this loop is single-threaded, so no slot
                    // is accessed concurrently.
                    unsafe { self.backward_row(&ptr, j as usize) };
                }
            } else {
                crate::par::par_for(cols.len(), threads, LEVEL_GRAIN, |k| {
                    // SAFETY: columns within a level are pairwise
                    // independent and distinct (disjoint writes, reads
                    // only from earlier levels); the per-level scope join
                    // orders cross-level accesses.
                    unsafe { self.backward_row(&ptr, cols[k] as usize) };
                });
            }
        }
    }

    /// One row of the forward substitution as a gather over the CSR
    /// mirror: fold `x[i] -= L[i,j]·x[j]` over ascending `j` — the exact
    /// operand sequence the serial scatter applies to slot `i`,
    /// including the zero-operand skip (see [`LdlFactor::solve_par`]).
    ///
    /// # Safety
    /// Every column of row `i` must already hold its final forward
    /// value (i.e. belong to an earlier schedule level), and no other
    /// thread may access slot `i` concurrently.
    unsafe fn forward_row(&self, x: &crate::par::SendPtr<f64>, i: usize) {
        let mut acc = *x.at(i);
        for p in self.rp[i] as usize..self.rp[i + 1] as usize {
            let xj = *x.at(self.ri[p] as usize);
            if xj != 0.0 {
                acc -= self.rx[p] * xj;
            }
        }
        x.write(i, acc);
    }

    /// One column of the backward substitution — the serial per-column
    /// gather verbatim.
    ///
    /// # Safety
    /// Every row entry of column `j` must already hold its final
    /// backward value (i.e. belong to an earlier schedule level), and no
    /// other thread may access slot `j` concurrently.
    unsafe fn backward_row(&self, x: &crate::par::SendPtr<f64>, j: usize) {
        let mut acc = *x.at(j);
        for p in self.lp[j] as usize..self.lp[j + 1] as usize {
            acc -= self.lx[p] * *x.at(self.li[p] as usize);
        }
        x.write(j, acc);
    }

    /// Deterministic work–span model of the two solve variants, in
    /// abstract row-cost units (1 + nnz gathered per row/column, plus
    /// one unit per row for the diagonal scale): returns
    /// `(serial_units, levelled_units)`, where a level costs the
    /// list-scheduling bound `max(ceil(work/threads), max_row_cost)`.
    /// At `threads == 1` the two sides are equal by construction.
    /// `benches/micro.rs` asserts the 8-thread model win on the
    /// grid-sparsifier workload (wall clock is printed alongside but not
    /// asserted — CI cores vary).
    pub fn solve_makespan_model(&self, threads: usize) -> (u64, u64) {
        let t = threads.max(1) as u64;
        let mut serial = self.n as u64;
        let mut levelled = (self.n as u64).div_ceil(t);
        for l in 0..self.sched.num_forward_levels() {
            let (work, max_row) = level_cost(self.sched.forward_level(l), &self.rp);
            serial += work;
            levelled += work.div_ceil(t).max(max_row);
        }
        for l in 0..self.sched.num_backward_levels() {
            let (work, max_row) = level_cost(self.sched.backward_level(l), &self.lp);
            serial += work;
            levelled += work.div_ceil(t).max(max_row);
        }
        (serial, levelled)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{grounded_laplacian, CsrMatrix};
    use crate::solver::spmv::spmv;
    use crate::util::Rng;

    /// Dense Cholesky-solve oracle for testing.
    fn dense_solve(a: &CsrMatrix, b: &[f64]) -> Vec<f64> {
        let n = a.n;
        let mut m = a.to_dense();
        let mut x = b.to_vec();
        // Gaussian elimination with partial pivoting
        for k in 0..n {
            let piv = (k..n).max_by(|&i, &j| m[i][k].abs().partial_cmp(&m[j][k].abs()).unwrap()).unwrap();
            m.swap(k, piv);
            x.swap(k, piv);
            for i in k + 1..n {
                let f = m[i][k] / m[k][k];
                for j in k..n {
                    m[i][j] -= f * m[k][j];
                }
                x[i] -= f * x[k];
            }
        }
        for k in (0..n).rev() {
            for j in k + 1..n {
                x[k] -= m[k][j] * x[j];
            }
            x[k] /= m[k][k];
        }
        x
    }

    #[test]
    fn factor_solve_small() {
        // SPD tridiagonal
        let a = CsrMatrix::from_triplets(
            3,
            vec![
                (0, 0, 4.0),
                (0, 1, -1.0),
                (1, 0, -1.0),
                (1, 1, 4.0),
                (1, 2, -1.0),
                (2, 1, -1.0),
                (2, 2, 4.0),
            ],
        );
        let f = LdlFactor::factor(&a).unwrap();
        let b = vec![1.0, 2.0, 3.0];
        let mut x = b.clone();
        f.solve(&mut x);
        let mut ax = vec![0.0; 3];
        spmv(&a, &x, &mut ax);
        for (u, v) in ax.iter().zip(&b) {
            assert!((u - v).abs() < 1e-12, "{ax:?} vs {b:?}");
        }
    }

    #[test]
    fn matches_dense_oracle_on_random_laplacians() {
        crate::util::proptest::check_default("ldl_vs_dense", |rng: &mut Rng| {
            let n = 5 + rng.below(40);
            // random connected graph: path + random extra edges
            let mut edges: Vec<(u32, u32, f64)> = (0..n as u32 - 1)
                .map(|i| (i, i + 1, 0.5 + rng.next_f64() * 5.0))
                .collect();
            for _ in 0..n {
                let a = rng.below(n) as u32;
                let b = rng.below(n) as u32;
                if a != b {
                    edges.push((a, b, 0.5 + rng.next_f64() * 5.0));
                }
            }
            let g = crate::graph::Graph::from_edges(n, &edges);
            let a = grounded_laplacian(&g, 0);
            let f = LdlFactor::factor(&a).map_err(|e| e.to_string())?;
            let b: Vec<f64> = (0..a.n).map(|_| rng.normal()).collect();
            let mut x = b.clone();
            f.solve(&mut x);
            let oracle = dense_solve(&a, &b);
            for (u, v) in x.iter().zip(&oracle) {
                crate::util::proptest::close(*u, *v, 1e-8, 1e-8)?;
            }
            Ok(())
        });
    }

    #[test]
    fn rejects_indefinite() {
        let a = CsrMatrix::from_triplets(2, vec![(0, 0, 1.0), (0, 1, 2.0), (1, 0, 2.0), (1, 1, 1.0)]);
        assert!(LdlFactor::factor(&a).is_err());
    }

    #[test]
    fn tree_factor_has_no_fill() {
        // A path Laplacian (already banded) must factor with nnz(L) = n-1.
        let g = crate::graph::Graph::from_edges(
            6,
            &[(0, 1, 1.0), (1, 2, 1.0), (2, 3, 1.0), (3, 4, 1.0), (4, 5, 1.0)],
        );
        let a = grounded_laplacian(&g, 5);
        let f = LdlFactor::factor(&a).unwrap();
        assert_eq!(f.nnz_l(), a.n - 1);
    }

    #[test]
    fn solve_par_is_bitwise_identical_to_solve() {
        // Random Laplacians, RCM-permuted as the preconditioner does it:
        // the levelled solve must reproduce the serial one bit for bit
        // at every thread count.
        crate::util::proptest::check_default("trisolve_parity", |rng: &mut Rng| {
            let n = 5 + rng.below(60);
            let mut edges: Vec<(u32, u32, f64)> = (0..n as u32 - 1)
                .map(|i| (i, i + 1, 0.5 + rng.next_f64() * 5.0))
                .collect();
            for _ in 0..2 * n {
                let a = rng.below(n) as u32;
                let b = rng.below(n) as u32;
                if a != b {
                    edges.push((a, b, 0.5 + rng.next_f64() * 5.0));
                }
            }
            let g = crate::graph::Graph::from_edges(n, &edges);
            let a = grounded_laplacian(&g, 0);
            let ap = crate::solver::permute_sym(&a, &crate::solver::rcm(&a));
            let f = LdlFactor::factor(&ap).map_err(|e| e.to_string())?;
            let b: Vec<f64> = (0..ap.n).map(|_| rng.normal()).collect();
            let mut serial = b.clone();
            f.solve(&mut serial);
            for threads in [1usize, 2, 8] {
                let mut par = b.clone();
                f.solve_par(&mut par, threads);
                for (i, (u, v)) in par.iter().zip(&serial).enumerate() {
                    if u.to_bits() != v.to_bits() {
                        return Err(format!("threads={threads} slot {i}: {u:e} vs {v:e}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn path_schedule_is_fully_sequential_and_parity_holds() {
        // Tridiagonal factor: every row depends on the previous one — n
        // width-1 levels in both sweeps, the adversarial fully-serial
        // case (solve_par must degrade to the serial order, not break).
        let n = 300usize;
        let edges: Vec<(u32, u32, f64)> =
            (0..n as u32 - 1).map(|i| (i, i + 1, 1.0 + f64::from(i) * 0.01)).collect();
        let g = crate::graph::Graph::from_edges(n, &edges);
        let a = grounded_laplacian(&g, 0);
        let f = LdlFactor::factor(&a).unwrap();
        let sched = f.schedule();
        assert_eq!(sched.num_forward_levels(), a.n);
        assert_eq!(sched.num_backward_levels(), a.n);
        for l in 0..a.n {
            assert_eq!(sched.forward_level(l).len(), 1);
            assert_eq!(sched.backward_level(l).len(), 1);
        }
        let mut rng = Rng::new(3);
        let b: Vec<f64> = (0..a.n).map(|_| rng.normal()).collect();
        let mut serial = b.clone();
        f.solve(&mut serial);
        for threads in [2usize, 8] {
            let mut par = b.clone();
            f.solve_par(&mut par, threads);
            assert!(
                par.iter().zip(&serial).all(|(u, v)| u.to_bits() == v.to_bits()),
                "threads={threads}"
            );
        }
        // All levels are width 1, so the model finds no span win beyond
        // the diagonal scale; at 1 thread the sides are exactly equal.
        let (s1, l1) = f.solve_makespan_model(1);
        assert_eq!(s1, l1);
    }

    #[test]
    fn star_schedule_is_two_wide_levels_and_parity_holds() {
        // Arrow matrix (star with the hub ordered last): every leaf row
        // is dependency-free — one wide forward level — and the hub row
        // gathers them all. Wide enough to actually dispatch on the pool
        // (width > LEVEL_PAR_CUTOFF).
        let n = 400usize;
        let hub = (n - 1) as u32;
        let mut t: Vec<(u32, u32, f64)> = Vec::new();
        for i in 0..n as u32 - 1 {
            t.push((i, i, 2.0 + f64::from(i) * 0.001));
            t.push((i, hub, -1.0));
            t.push((hub, i, -1.0));
        }
        t.push((hub, hub, n as f64));
        let a = CsrMatrix::from_triplets(n, t);
        let f = LdlFactor::factor(&a).unwrap();
        let sched = f.schedule();
        assert_eq!(sched.num_forward_levels(), 2);
        assert_eq!(sched.forward_level(0).len(), n - 1);
        assert_eq!(sched.forward_level(1), &[hub][..]);
        assert_eq!(sched.num_backward_levels(), 2);
        assert_eq!(sched.backward_level(0), &[hub][..]);
        assert_eq!(sched.backward_level(1).len(), n - 1);
        let mut rng = Rng::new(4);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut serial = b.clone();
        f.solve(&mut serial);
        for threads in [2usize, 8] {
            let mut par = b.clone();
            f.solve_par(&mut par, threads);
            assert!(
                par.iter().zip(&serial).all(|(u, v)| u.to_bits() == v.to_bits()),
                "threads={threads}"
            );
        }
        // The wide levels split across workers: the 8-thread model must
        // beat serial, and the 1-thread model must equal it.
        let (s1, l1) = f.solve_makespan_model(1);
        assert_eq!(s1, l1);
        let (s8, l8) = f.solve_makespan_model(8);
        assert!(l8 < s8, "levelled {l8} vs serial {s8}");
    }
}
