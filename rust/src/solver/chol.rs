//! Sparse LDLᵀ factorization (up-looking, elimination-tree based — the
//! classic Davis `LDL` algorithm) and triangular solves.
//!
//! The PCG evaluation uses `L_P` (the sparsifier Laplacian, grounded) as
//! the preconditioner; it is factored **once** and each PCG iteration
//! applies two triangular solves — the same cost profile as MATLAB's
//! `pcg(L_G, b, tol, maxit, L_chol, L_chol')` setup the paper uses.

use crate::graph::CsrMatrix;

/// LDLᵀ factors: unit lower-triangular `L` (strict part stored CSC) and
/// diagonal `D`.
#[derive(Clone, Debug)]
pub struct LdlFactor {
    n: usize,
    /// Column pointers of strict-lower L (CSC), length n+1.
    lp: Vec<usize>,
    /// Row indices of L entries.
    li: Vec<u32>,
    /// Values of L entries.
    lx: Vec<f64>,
    /// Diagonal of D.
    d: Vec<f64>,
}

/// Factorization failure: a non-positive pivot (matrix not positive
/// definite to working precision).
#[derive(Debug)]
pub struct NotPositiveDefinite {
    /// Pivot index where factorization broke down.
    pub at: usize,
    /// The offending pivot value.
    pub pivot: f64,
}

impl std::fmt::Display for NotPositiveDefinite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "non-positive pivot {} at index {}", self.pivot, self.at)
    }
}

impl std::error::Error for NotPositiveDefinite {}

impl LdlFactor {
    /// Factor a symmetric positive-definite CSR matrix (full storage;
    /// only the upper triangle is read, by symmetry of access).
    pub fn factor(a: &CsrMatrix) -> Result<LdlFactor, NotPositiveDefinite> {
        let n = a.n;
        // --- symbolic: elimination tree + column counts ---
        let mut parent = vec![usize::MAX; n];
        let mut flag = vec![usize::MAX; n];
        let mut lnz = vec![0usize; n];
        for k in 0..n {
            flag[k] = k;
            let (cols, _) = a.row(k);
            for &c in cols {
                let mut i = c as usize;
                if i >= k {
                    continue;
                }
                while flag[i] != k {
                    if parent[i] == usize::MAX {
                        parent[i] = k;
                    }
                    lnz[i] += 1;
                    flag[i] = k;
                    i = parent[i];
                }
            }
        }
        let mut lp = vec![0usize; n + 1];
        for i in 0..n {
            lp[i + 1] = lp[i] + lnz[i];
        }
        let nnz_l = lp[n];
        let mut li = vec![0u32; nnz_l];
        let mut lx = vec![0f64; nnz_l];
        let mut d = vec![0f64; n];
        // --- numeric ---
        let mut y = vec![0f64; n];
        let mut pattern = vec![0usize; n];
        let mut lfill = lp.clone(); // next free slot per column
        let mut flag = vec![usize::MAX; n];
        let mut stack = vec![0usize; n];
        for k in 0..n {
            let mut top = n;
            flag[k] = k;
            y[k] = 0.0;
            let (cols, vals) = a.row(k);
            for (&c, &v) in cols.iter().zip(vals) {
                let i0 = c as usize;
                if i0 > k {
                    continue;
                }
                y[i0] += v;
                // walk up the etree collecting the row-k pattern
                let mut len = 0usize;
                let mut i = i0;
                while flag[i] != k {
                    stack[len] = i;
                    len += 1;
                    flag[i] = k;
                    i = parent[i];
                }
                while len > 0 {
                    len -= 1;
                    top -= 1;
                    pattern[top] = stack[len];
                }
            }
            d[k] = y[k];
            y[k] = 0.0;
            for s in top..n {
                let i = pattern[s];
                let yi = y[i];
                y[i] = 0.0;
                for p in lp[i]..lfill[i] {
                    y[li[p] as usize] -= lx[p] * yi;
                }
                let dii = d[i];
                let lki = yi / dii;
                d[k] -= lki * yi;
                li[lfill[i]] = k as u32;
                lx[lfill[i]] = lki;
                lfill[i] += 1;
            }
            if d[k] <= 0.0 || !d[k].is_finite() {
                return Err(NotPositiveDefinite { at: k, pivot: d[k] });
            }
        }
        Ok(LdlFactor { n, lp, li, lx, d })
    }

    /// Dimension.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True if the factor is empty.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Nonzeros in the strict lower factor (fill-in metric).
    pub fn nnz_l(&self) -> usize {
        self.lx.len()
    }

    /// Solve `L D Lᵀ x = b` in place.
    pub fn solve(&self, x: &mut [f64]) {
        debug_assert_eq!(x.len(), self.n);
        // forward: L y = b   (unit diagonal)
        for j in 0..self.n {
            let xj = x[j];
            if xj != 0.0 {
                for p in self.lp[j]..self.lp[j + 1] {
                    x[self.li[p] as usize] -= self.lx[p] * xj;
                }
            }
        }
        // diagonal
        for j in 0..self.n {
            x[j] /= self.d[j];
        }
        // backward: Lᵀ x = y
        for j in (0..self.n).rev() {
            let mut acc = x[j];
            for p in self.lp[j]..self.lp[j + 1] {
                acc -= self.lx[p] * x[self.li[p] as usize];
            }
            x[j] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{grounded_laplacian, CsrMatrix};
    use crate::solver::spmv::spmv;
    use crate::util::Rng;

    /// Dense Cholesky-solve oracle for testing.
    fn dense_solve(a: &CsrMatrix, b: &[f64]) -> Vec<f64> {
        let n = a.n;
        let mut m = a.to_dense();
        let mut x = b.to_vec();
        // Gaussian elimination with partial pivoting
        for k in 0..n {
            let piv = (k..n).max_by(|&i, &j| m[i][k].abs().partial_cmp(&m[j][k].abs()).unwrap()).unwrap();
            m.swap(k, piv);
            x.swap(k, piv);
            for i in k + 1..n {
                let f = m[i][k] / m[k][k];
                for j in k..n {
                    m[i][j] -= f * m[k][j];
                }
                x[i] -= f * x[k];
            }
        }
        for k in (0..n).rev() {
            for j in k + 1..n {
                x[k] -= m[k][j] * x[j];
            }
            x[k] /= m[k][k];
        }
        x
    }

    #[test]
    fn factor_solve_small() {
        // SPD tridiagonal
        let a = CsrMatrix::from_triplets(
            3,
            vec![
                (0, 0, 4.0),
                (0, 1, -1.0),
                (1, 0, -1.0),
                (1, 1, 4.0),
                (1, 2, -1.0),
                (2, 1, -1.0),
                (2, 2, 4.0),
            ],
        );
        let f = LdlFactor::factor(&a).unwrap();
        let b = vec![1.0, 2.0, 3.0];
        let mut x = b.clone();
        f.solve(&mut x);
        let mut ax = vec![0.0; 3];
        spmv(&a, &x, &mut ax);
        for (u, v) in ax.iter().zip(&b) {
            assert!((u - v).abs() < 1e-12, "{ax:?} vs {b:?}");
        }
    }

    #[test]
    fn matches_dense_oracle_on_random_laplacians() {
        crate::util::proptest::check_default("ldl_vs_dense", |rng: &mut Rng| {
            let n = 5 + rng.below(40);
            // random connected graph: path + random extra edges
            let mut edges: Vec<(u32, u32, f64)> = (0..n as u32 - 1)
                .map(|i| (i, i + 1, 0.5 + rng.next_f64() * 5.0))
                .collect();
            for _ in 0..n {
                let a = rng.below(n) as u32;
                let b = rng.below(n) as u32;
                if a != b {
                    edges.push((a, b, 0.5 + rng.next_f64() * 5.0));
                }
            }
            let g = crate::graph::Graph::from_edges(n, &edges);
            let a = grounded_laplacian(&g, 0);
            let f = LdlFactor::factor(&a).map_err(|e| e.to_string())?;
            let b: Vec<f64> = (0..a.n).map(|_| rng.normal()).collect();
            let mut x = b.clone();
            f.solve(&mut x);
            let oracle = dense_solve(&a, &b);
            for (u, v) in x.iter().zip(&oracle) {
                crate::util::proptest::close(*u, *v, 1e-8, 1e-8)?;
            }
            Ok(())
        });
    }

    #[test]
    fn rejects_indefinite() {
        let a = CsrMatrix::from_triplets(2, vec![(0, 0, 1.0), (0, 1, 2.0), (1, 0, 2.0), (1, 1, 1.0)]);
        assert!(LdlFactor::factor(&a).is_err());
    }

    #[test]
    fn tree_factor_has_no_fill() {
        // A path Laplacian (already banded) must factor with nnz(L) = n-1.
        let g = crate::graph::Graph::from_edges(
            6,
            &[(0, 1, 1.0), (1, 2, 1.0), (2, 3, 1.0), (3, 4, 1.0), (4, 5, 1.0)],
        );
        let a = grounded_laplacian(&g, 5);
        let f = LdlFactor::factor(&a).unwrap();
        assert_eq!(f.nnz_l(), a.n - 1);
    }
}
