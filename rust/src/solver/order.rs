//! Reverse Cuthill–McKee (RCM) fill-reducing ordering.
//!
//! The sparsifier Laplacian `L_P` is a tree plus `α|V|` extra edges; a
//! bandwidth-reducing order keeps the LDLᵀ factor's fill-in small enough
//! that the preconditioner solve stays `O(|V|)`-ish per PCG iteration
//! (matching the cost profile of MATLAB's `pcg` with a pre-factored
//! preconditioner).

use crate::graph::CsrMatrix;

/// Compute the RCM permutation: `perm[new] = old`.
pub fn rcm(a: &CsrMatrix) -> Vec<u32> {
    let n = a.n;
    let deg = |v: usize| a.rowptr[v + 1] - a.rowptr[v];
    let mut visited = vec![false; n];
    let mut order: Vec<u32> = Vec::with_capacity(n);
    // Process every component: start from a pseudo-peripheral low-degree
    // vertex each time.
    loop {
        let start = match (0..n).filter(|&v| !visited[v]).min_by_key(|&v| deg(v)) {
            Some(s) => pseudo_peripheral(a, s, &visited),
            None => break,
        };
        // BFS with neighbors in ascending-degree order (Cuthill–McKee).
        let mut queue = std::collections::VecDeque::new();
        visited[start] = true;
        queue.push_back(start as u32);
        while let Some(u) = queue.pop_front() {
            order.push(u);
            let (s, e) = (a.rowptr[u as usize], a.rowptr[u as usize + 1]);
            let mut nbrs: Vec<u32> = a.colidx[s..e]
                .iter()
                .copied()
                .filter(|&v| v != u && !visited[v as usize])
                .collect();
            nbrs.sort_by_key(|&v| deg(v as usize));
            for v in nbrs {
                if !visited[v as usize] {
                    visited[v as usize] = true;
                    queue.push_back(v);
                }
            }
        }
    }
    order.reverse(); // the "R" in RCM
    order
}

/// Find a pseudo-peripheral vertex via repeated BFS eccentricity climbs.
fn pseudo_peripheral(a: &CsrMatrix, start: usize, visited: &[bool]) -> usize {
    let mut cur = start;
    let mut ecc = 0usize;
    for _ in 0..4 {
        let (far, e) = bfs_far(a, cur, visited);
        if e <= ecc {
            break;
        }
        ecc = e;
        cur = far;
    }
    cur
}

/// BFS within the unvisited region; return (farthest min-degree vertex on
/// the last level, eccentricity).
fn bfs_far(a: &CsrMatrix, start: usize, visited: &[bool]) -> (usize, usize) {
    let n = a.n;
    let mut dist = vec![u32::MAX; n];
    let mut q = std::collections::VecDeque::new();
    dist[start] = 0;
    q.push_back(start);
    let mut last = start;
    let mut ecc = 0usize;
    while let Some(u) = q.pop_front() {
        let (s, e) = (a.rowptr[u], a.rowptr[u + 1]);
        for &v in &a.colidx[s..e] {
            let v = v as usize;
            if v != u && !visited[v] && dist[v] == u32::MAX {
                dist[v] = dist[u] + 1;
                if dist[v] as usize > ecc {
                    ecc = dist[v] as usize;
                    last = v;
                }
                q.push_back(v);
            }
        }
    }
    (last, ecc)
}

/// Symmetric permutation: `B = P A Pᵀ` with `perm[new] = old`.
pub fn permute_sym(a: &CsrMatrix, perm: &[u32]) -> CsrMatrix {
    let n = a.n;
    assert_eq!(perm.len(), n);
    let mut inv = vec![0u32; n];
    for (new, &old) in perm.iter().enumerate() {
        inv[old as usize] = new as u32;
    }
    let mut t = Vec::with_capacity(a.nnz());
    for i in 0..n {
        let (cols, vals) = a.row(i);
        for (c, v) in cols.iter().zip(vals) {
            t.push((inv[i], inv[*c as usize], *v));
        }
    }
    CsrMatrix::from_triplets(n, t)
}

/// Apply permutation to a vector: `out[new] = x[perm[new]]`.
pub fn permute_vec(x: &[f64], perm: &[u32], out: &mut [f64]) {
    for (new, &old) in perm.iter().enumerate() {
        out[new] = x[old as usize];
    }
}

/// Inverse-apply: `out[perm[new]] = x[new]`.
pub fn unpermute_vec(x: &[f64], perm: &[u32], out: &mut [f64]) {
    for (new, &old) in perm.iter().enumerate() {
        out[old as usize] = x[new];
    }
}

/// Bandwidth of a symmetric CSR matrix (max |i − j| over entries).
pub fn bandwidth(a: &CsrMatrix) -> usize {
    let mut bw = 0usize;
    for i in 0..a.n {
        let (cols, _) = a.row(i);
        for &c in cols {
            bw = bw.max((c as isize - i as isize).unsigned_abs());
        }
    }
    bw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{grounded_laplacian, Graph};
    use crate::util::Rng;

    #[test]
    fn rcm_is_permutation() {
        let g = crate::gen::grid(8, 8, 0.4, &mut Rng::new(1));
        let a = grounded_laplacian(&g, 0);
        let p = rcm(&a);
        let mut sorted = p.clone();
        sorted.sort();
        assert_eq!(sorted, (0..a.n as u32).collect::<Vec<_>>());
    }

    #[test]
    fn rcm_reduces_bandwidth_on_shuffled_path() {
        // Path graph with shuffled labels has terrible natural bandwidth.
        let n = 200usize;
        let mut rng = Rng::new(2);
        let mut labels: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut labels);
        let edges: Vec<(u32, u32, f64)> =
            (0..n - 1).map(|i| (labels[i], labels[i + 1], 1.0)).collect();
        let g = Graph::from_edges(n, &edges);
        let a = grounded_laplacian(&g, labels[0]);
        let before = bandwidth(&a);
        let b = permute_sym(&a, &rcm(&a));
        let after = bandwidth(&b);
        assert!(after <= 2, "path should get bandwidth ≤2, got {after} (before {before})");
    }

    #[test]
    fn permute_roundtrip_vec() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let perm = [2u32, 0, 3, 1];
        let mut y = [0.0; 4];
        let mut z = [0.0; 4];
        permute_vec(&x, &perm, &mut y);
        assert_eq!(y, [3.0, 1.0, 4.0, 2.0]);
        unpermute_vec(&y, &perm, &mut z);
        assert_eq!(z, x);
    }

    #[test]
    fn permute_sym_preserves_values() {
        let a = CsrMatrix::from_triplets(
            3,
            vec![(0, 0, 1.0), (1, 1, 2.0), (2, 2, 3.0), (0, 2, -1.0), (2, 0, -1.0)],
        );
        let b = permute_sym(&a, &[2, 1, 0]);
        assert_eq!(b.diagonal(), vec![3.0, 2.0, 1.0]);
        let d = b.to_dense();
        assert_eq!(d[0][2], -1.0);
        assert_eq!(d[2][0], -1.0);
    }
}
