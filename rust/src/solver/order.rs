//! Reverse Cuthill–McKee (RCM) fill-reducing ordering.
//!
//! The sparsifier Laplacian `L_P` is a tree plus `α|V|` extra edges; a
//! bandwidth-reducing order keeps the LDLᵀ factor's fill-in small enough
//! that the preconditioner solve stays `O(|V|)`-ish per PCG iteration
//! (matching the cost profile of MATLAB's `pcg` with a pre-factored
//! preconditioner).

use crate::graph::CsrMatrix;

/// Compute the RCM permutation: `perm[new] = old`.
pub fn rcm(a: &CsrMatrix) -> Vec<u32> {
    let n = a.n;
    let deg = |v: usize| a.row_nnz(v);
    let mut visited = vec![false; n];
    let mut order: Vec<u32> = Vec::with_capacity(n);
    // Process every component: start from a pseudo-peripheral low-degree
    // vertex each time.
    loop {
        let start = match (0..n).filter(|&v| !visited[v]).min_by_key(|&v| deg(v)) {
            Some(s) => pseudo_peripheral(a, s, &visited),
            None => break,
        };
        // BFS with neighbors in ascending-degree order (Cuthill–McKee).
        let mut queue = std::collections::VecDeque::new();
        visited[start] = true;
        queue.push_back(start as u32);
        while let Some(u) = queue.pop_front() {
            order.push(u);
            let (s, e) = (a.rowptr[u as usize] as usize, a.rowptr[u as usize + 1] as usize);
            let mut nbrs: Vec<u32> = a.colidx[s..e]
                .iter()
                .copied()
                .filter(|&v| v != u && !visited[v as usize])
                .collect();
            nbrs.sort_by_key(|&v| deg(v as usize));
            for v in nbrs {
                if !visited[v as usize] {
                    visited[v as usize] = true;
                    queue.push_back(v);
                }
            }
        }
    }
    order.reverse(); // the "R" in RCM
    order
}

/// Cap on the pseudo-peripheral eccentricity climb. Each round is a full
/// BFS of the component; the eccentricity is non-decreasing and nearly
/// always saturates in 2–3 rounds (George & Liu report the same), so the
/// cap trades a marginally better start vertex for a bounded, small
/// constant number of sweeps on huge components. The result stays
/// deterministic: the climb path is a pure function of the matrix.
const PERIPHERAL_CLIMB_CAP: usize = 4;

/// Find a pseudo-peripheral vertex via repeated BFS eccentricity climbs
/// (capped at [`PERIPHERAL_CLIMB_CAP`] rounds).
fn pseudo_peripheral(a: &CsrMatrix, start: usize, visited: &[bool]) -> usize {
    let mut cur = start;
    let mut ecc = 0usize;
    for _ in 0..PERIPHERAL_CLIMB_CAP {
        let (far, e) = bfs_far(a, cur, visited);
        if e <= ecc {
            break;
        }
        ecc = e;
        cur = far;
    }
    cur
}

/// BFS within the unvisited region; return (min-degree vertex on the
/// last BFS level, eccentricity). Ties on degree break to the smallest
/// index, so the choice is deterministic and independent of queue order.
/// Starting the next Cuthill–McKee sweep from a low-degree peripheral
/// vertex is the George–Liu heuristic for long, thin level structures.
fn bfs_far(a: &CsrMatrix, start: usize, visited: &[bool]) -> (usize, usize) {
    let n = a.n;
    let mut dist = vec![u32::MAX; n];
    let mut q = std::collections::VecDeque::new();
    dist[start] = 0;
    q.push_back(start);
    let mut ecc = 0usize;
    while let Some(u) = q.pop_front() {
        let (s, e) = (a.rowptr[u] as usize, a.rowptr[u + 1] as usize);
        for &v in &a.colidx[s..e] {
            let v = v as usize;
            if v != u && !visited[v] && dist[v] == u32::MAX {
                dist[v] = dist[u] + 1;
                if dist[v] as usize > ecc {
                    ecc = dist[v] as usize;
                }
                q.push_back(v);
            }
        }
    }
    // Min-degree vertex of the deepest level, smallest index on degree
    // ties (ascending scan).
    let mut best = start;
    let mut best_deg = usize::MAX;
    for v in 0..n {
        if dist[v] != u32::MAX && dist[v] as usize == ecc {
            let deg = a.row_nnz(v);
            if deg < best_deg {
                best = v;
                best_deg = deg;
            }
        }
    }
    (best, ecc)
}

/// Symmetric permutation: `B = P A Pᵀ` with `perm[new] = old`.
pub fn permute_sym(a: &CsrMatrix, perm: &[u32]) -> CsrMatrix {
    let n = a.n;
    assert_eq!(perm.len(), n);
    let mut inv = vec![0u32; n];
    for (new, &old) in perm.iter().enumerate() {
        inv[old as usize] = new as u32;
    }
    let mut t = Vec::with_capacity(a.nnz());
    for i in 0..n {
        let (cols, vals) = a.row(i);
        for (c, v) in cols.iter().zip(vals) {
            t.push((inv[i], inv[*c as usize], *v));
        }
    }
    CsrMatrix::from_triplets(n, t)
}

/// Apply permutation to a vector: `out[new] = x[perm[new]]`.
pub fn permute_vec(x: &[f64], perm: &[u32], out: &mut [f64]) {
    for (new, &old) in perm.iter().enumerate() {
        out[new] = x[old as usize];
    }
}

/// Inverse-apply: `out[perm[new]] = x[new]`.
pub fn unpermute_vec(x: &[f64], perm: &[u32], out: &mut [f64]) {
    for (new, &old) in perm.iter().enumerate() {
        out[old as usize] = x[new];
    }
}

/// Indices claimed per fetch by the pooled permutation kernels — the
/// BLAS-1 grain (these are pure gather/scatter memory ops).
const PERM_GRAIN: usize = 4096;

/// As [`permute_vec`], gathered across `threads` pool workers: each slot
/// is written once from the same expression as the serial loop, so the
/// result is bitwise identical at every thread count.
pub fn permute_vec_par(x: &[f64], perm: &[u32], out: &mut [f64], threads: usize) {
    debug_assert_eq!(perm.len(), out.len());
    if threads <= 1 {
        permute_vec(x, perm, out);
        return;
    }
    crate::par::par_fill(out, threads, PERM_GRAIN, |new| x[perm[new] as usize]);
}

/// As [`unpermute_vec`], scattered across `threads` pool workers.
pub fn unpermute_vec_par(x: &[f64], perm: &[u32], out: &mut [f64], threads: usize) {
    debug_assert_eq!(perm.len(), x.len());
    debug_assert_eq!(x.len(), out.len());
    if threads <= 1 {
        unpermute_vec(x, perm, out);
        return;
    }
    let ptr = crate::par::as_send_ptr(out);
    crate::par::par_for(x.len(), threads, PERM_GRAIN, |new| {
        // SAFETY: `perm` is a permutation, so each target slot is written
        // by exactly one task; `out` outlives the scope join.
        unsafe { ptr.write(perm[new] as usize, x[new]) };
    });
}

/// Bandwidth of a symmetric CSR matrix (max |i − j| over entries).
pub fn bandwidth(a: &CsrMatrix) -> usize {
    let mut bw = 0usize;
    for i in 0..a.n {
        let (cols, _) = a.row(i);
        for &c in cols {
            bw = bw.max((c as isize - i as isize).unsigned_abs());
        }
    }
    bw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{grounded_laplacian, Graph};
    use crate::util::Rng;

    #[test]
    fn rcm_is_permutation() {
        let g = crate::gen::grid(8, 8, 0.4, &mut Rng::new(1));
        let a = grounded_laplacian(&g, 0);
        let p = rcm(&a);
        let mut sorted = p.clone();
        sorted.sort();
        assert_eq!(sorted, (0..a.n as u32).collect::<Vec<_>>());
    }

    #[test]
    fn rcm_reduces_bandwidth_on_shuffled_path() {
        // Path graph with shuffled labels has terrible natural bandwidth.
        let n = 200usize;
        let mut rng = Rng::new(2);
        let mut labels: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut labels);
        let edges: Vec<(u32, u32, f64)> =
            (0..n - 1).map(|i| (labels[i], labels[i + 1], 1.0)).collect();
        let g = Graph::from_edges(n, &edges);
        let a = grounded_laplacian(&g, labels[0]);
        let before = bandwidth(&a);
        let b = permute_sym(&a, &rcm(&a));
        let after = bandwidth(&b);
        assert!(after <= 2, "path should get bandwidth ≤2, got {after} (before {before})");
    }

    #[test]
    fn permute_roundtrip_vec() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let perm = [2u32, 0, 3, 1];
        let mut y = [0.0; 4];
        let mut z = [0.0; 4];
        permute_vec(&x, &perm, &mut y);
        assert_eq!(y, [3.0, 1.0, 4.0, 2.0]);
        unpermute_vec(&y, &perm, &mut z);
        assert_eq!(z, x);
    }

    /// Symmetric adjacency-pattern matrix from undirected edge pairs
    /// (values are irrelevant to the ordering code under test).
    fn pattern(n: usize, edges: &[(u32, u32)]) -> CsrMatrix {
        let mut t: Vec<(u32, u32, f64)> = Vec::with_capacity(2 * edges.len());
        for &(u, v) in edges {
            t.push((u, v, 1.0));
            t.push((v, u, 1.0));
        }
        CsrMatrix::from_triplets(n, t)
    }

    #[test]
    fn rcm_handles_disconnected_components() {
        // Two shuffled paths plus an isolated vertex: RCM must emit a
        // full permutation, restart cleanly per component, and keep each
        // path banded.
        let n = 101usize;
        let mut rng = Rng::new(5);
        let mut labels: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut labels);
        let mut edges: Vec<(u32, u32)> = Vec::new();
        for i in 0..49 {
            edges.push((labels[i], labels[i + 1]));
        }
        for i in 50..99 {
            edges.push((labels[i], labels[i + 1]));
        }
        // labels[100] has no edges (empty matrix row).
        let a = pattern(n, &edges);
        let p = rcm(&a);
        let mut sorted = p.clone();
        sorted.sort();
        assert_eq!(sorted, (0..n as u32).collect::<Vec<_>>());
        let b = permute_sym(&a, &p);
        assert!(bandwidth(&b) <= 2, "got {}", bandwidth(&b));
    }

    #[test]
    fn bfs_far_prefers_min_degree_then_min_index_on_last_level() {
        // Degree tie on the deepest level → smallest index. Star with
        // hub 0 and leaves {1, 2, 3}: the last level is all three
        // degree-1 leaves, so the pick must be 1 regardless of queue
        // discovery order.
        let star = pattern(4, &[(0, 1), (0, 2), (0, 3)]);
        let (far, ecc) = bfs_far(&star, 0, &vec![false; star.n]);
        assert_eq!(ecc, 1);
        assert_eq!(far, 1);

        // Min-degree beats discovery order AND smaller index. From 0 the
        // levels are {1, 2, 5} then {3, 4}; deg(3) = |{1, 5}| = 2,
        // deg(4) = |{2}| = 1, so 4 must win even though 3 is discovered
        // first (via neighbor 1) and has the smaller index.
        let g = pattern(6, &[(0, 1), (0, 2), (0, 5), (1, 3), (2, 4), (3, 5)]);
        let (far2, ecc2) = bfs_far(&g, 0, &vec![false; g.n]);
        assert_eq!(ecc2, 2);
        assert_eq!(far2, 4);

        // The `visited` mask restricts the region: with 4 visited, the
        // deepest unvisited level from 0 is {3} alone.
        let mut visited = vec![false; g.n];
        visited[4] = true;
        let (far3, ecc3) = bfs_far(&g, 0, &visited);
        assert_eq!(ecc3, 2);
        assert_eq!(far3, 3);
    }

    #[test]
    fn permute_par_variants_match_serial_bitwise() {
        let n = 10_000usize;
        let mut rng = Rng::new(9);
        let mut perm: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut perm);
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut serial_p = vec![0.0; n];
        let mut serial_u = vec![0.0; n];
        permute_vec(&x, &perm, &mut serial_p);
        unpermute_vec(&x, &perm, &mut serial_u);
        for threads in [1usize, 2, 8] {
            let mut par_p = vec![f64::NAN; n];
            let mut par_u = vec![f64::NAN; n];
            permute_vec_par(&x, &perm, &mut par_p, threads);
            unpermute_vec_par(&x, &perm, &mut par_u, threads);
            assert!(serial_p.iter().zip(&par_p).all(|(a, b)| a.to_bits() == b.to_bits()));
            assert!(serial_u.iter().zip(&par_u).all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }

    #[test]
    fn permute_sym_preserves_values() {
        let a = CsrMatrix::from_triplets(
            3,
            vec![(0, 0, 1.0), (1, 1, 2.0), (2, 2, 3.0), (0, 2, -1.0), (2, 0, -1.0)],
        );
        let b = permute_sym(&a, &[2, 1, 0]);
        assert_eq!(b.diagonal(), vec![3.0, 2.0, 1.0]);
        let d = b.to_dense();
        assert_eq!(d[0][2], -1.0);
        assert_eq!(d[2][0], -1.0);
    }
}
