//! Linear-solver substrate: CSR SpMV, RCM ordering, sparse LDLᵀ with a
//! level-scheduled parallel triangular solve, and the PCG evaluation
//! harness (the paper's sparsifier-quality metric).

pub mod chol;
pub mod order;
pub mod pcg;
pub mod spmv;

pub use chol::{LdlFactor, LevelSchedule, NotPositiveDefinite};
pub use order::{
    bandwidth, permute_sym, permute_vec, permute_vec_par, rcm, unpermute_vec, unpermute_vec_par,
};
pub use pcg::{
    pcg, pcg_eval, pcg_eval_par, pcg_iterations, pcg_par, Identity, Jacobi, PcgResult,
    Preconditioner, SparsifierPrecond,
};
pub use spmv::{
    axpy, axpy_par, dot, dot_par, nnz_balanced_ranges, norm2, norm2_par, spmv, spmv_par,
    spmv_traffic_model, xpay, xpay_par,
};
