//! Linear-solver substrate: CSR SpMV, RCM ordering, sparse LDLᵀ, and the
//! PCG evaluation harness (the paper's sparsifier-quality metric).

pub mod chol;
pub mod order;
pub mod pcg;
pub mod spmv;

pub use chol::{LdlFactor, NotPositiveDefinite};
pub use order::{bandwidth, permute_sym, rcm};
pub use pcg::{
    pcg, pcg_eval, pcg_iterations, pcg_par, Identity, Jacobi, PcgResult, Preconditioner,
    SparsifierPrecond,
};
pub use spmv::{
    axpy, axpy_par, dot, dot_par, norm2, norm2_par, spmv, spmv_par, xpay, xpay_par,
};
