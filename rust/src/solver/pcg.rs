//! Preconditioned conjugate gradient — the paper's quality metric.
//!
//! §V: "given a subgraph P of the original graph G, the PCG solver uses
//! `L_P` as the preconditioner to solve `‖L_G x − b‖ ≤ 1e-3 ‖b‖`
//! iteratively. A lower iteration count indicates a higher-quality
//! sparsifier." This module reproduces MATLAB `pcg` semantics: the
//! Hestenes–Stiefel recurrence with the recursive residual, and the same
//! relative-residual stopping rule.

use super::chol::{LdlFactor, NotPositiveDefinite};
use super::order::{
    permute_sym, permute_vec, permute_vec_par, rcm, unpermute_vec, unpermute_vec_par,
};
use super::spmv::{axpy_par, dot_par, norm2_par, spmv_par, xpay_par};
use crate::graph::{grounded_laplacian, CsrMatrix, Graph};

/// Preconditioner interface: `z = M⁻¹ r`.
pub trait Preconditioner {
    /// Apply the preconditioner.
    fn apply(&self, r: &[f64], z: &mut [f64]);

    /// Apply across `threads` pool workers. The default falls back to the
    /// serial [`Preconditioner::apply`]; implementations that override it
    /// (the elementwise [`Jacobi`] path) must be **bitwise identical** to
    /// the serial apply at every thread count — `pcg_par`'s exact-parity
    /// guarantee depends on it.
    fn apply_par(&self, r: &[f64], z: &mut [f64], threads: usize) {
        let _ = threads;
        self.apply(r, z);
    }
}

/// Identity (no preconditioning) — the plain-CG baseline.
pub struct Identity;

impl Preconditioner for Identity {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        z.copy_from_slice(r);
    }
}

/// Jacobi (diagonal) preconditioner — cheap baseline, and the
/// preconditioner baked into the XLA PCG step (L2 kernel).
pub struct Jacobi {
    inv_diag: Vec<f64>,
}

impl Jacobi {
    /// Build from a matrix's diagonal. A zero, negative, or non-finite
    /// diagonal entry (an isolated or grounded-out vertex) would turn
    /// every subsequent apply into silent `inf`/NaN deep inside PCG, so
    /// it is rejected up front as [`NotPositiveDefinite`] — the same
    /// error the LDLᵀ factorization surfaces for the sparsifier
    /// preconditioner.
    pub fn new(a: &CsrMatrix) -> Result<Jacobi, NotPositiveDefinite> {
        let diag = a.diagonal();
        let mut inv_diag = Vec::with_capacity(diag.len());
        for (i, &d) in diag.iter().enumerate() {
            if d <= 0.0 || !d.is_finite() {
                return Err(NotPositiveDefinite { at: i, pivot: d });
            }
            inv_diag.push(1.0 / d);
        }
        Ok(Jacobi { inv_diag })
    }
}

impl Preconditioner for Jacobi {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        for i in 0..r.len() {
            z[i] = r[i] * self.inv_diag[i];
        }
    }

    /// Pooled diagonal apply: each slot is written from the same
    /// expression as the serial loop (`z[i] = r[i] · d⁻¹[i]`, disjoint
    /// indices, no reduction), so the result is bitwise identical at
    /// every thread count.
    fn apply_par(&self, r: &[f64], z: &mut [f64], threads: usize) {
        let inv = &self.inv_diag;
        crate::par::par_update(z, threads, 4096, |i, zi| *zi = r[i] * inv[i]);
    }
}

/// Sparsifier preconditioner: RCM-permuted LDLᵀ factorization of the
/// grounded `L_P`, applied via two triangular solves.
///
/// `Sync + Send`: the permutation scratch lives in a small pooled
/// free-list (a `Mutex`-guarded stack of buffers, à la
/// `recovery::subctx::ScratchPool`) rather than the `RefCell` it used
/// to be, so one factored preconditioner can be shared by concurrent
/// PCG runs and called from pool workers. The lock is held only for a
/// `Vec` pop/push around each apply — never across the solve itself.
pub struct SparsifierPrecond {
    perm: Vec<u32>,
    factor: LdlFactor,
    /// Free-list of permutation buffers, each of length `factor.len()`.
    scratch: std::sync::Mutex<Vec<Vec<f64>>>,
}

impl SparsifierPrecond {
    /// Factor the grounded Laplacian of sparsifier `p` (ground vertex 0).
    pub fn new(p: &Graph) -> Result<SparsifierPrecond, NotPositiveDefinite> {
        let lp = grounded_laplacian(p, 0);
        Self::from_matrix(&lp)
    }

    /// Factor an arbitrary SPD matrix with RCM reordering.
    pub fn from_matrix(a: &CsrMatrix) -> Result<SparsifierPrecond, NotPositiveDefinite> {
        let perm = rcm(a);
        let ap = permute_sym(a, &perm);
        let factor = LdlFactor::factor(&ap)?;
        Ok(SparsifierPrecond { perm, factor, scratch: std::sync::Mutex::new(Vec::new()) })
    }

    /// Fill-in of the factor (diagnostics).
    pub fn nnz_l(&self) -> usize {
        self.factor.nnz_l()
    }

    /// Pop a scratch buffer off the free-list, or allocate one. Every
    /// buffer is fully overwritten by `permute_vec` before use, so no
    /// clearing is needed. A poisoned lock (a panicked apply elsewhere)
    /// only guards a buffer free-list, so it is safe to keep using.
    fn take_buf(&self) -> Vec<f64> {
        let popped = self.scratch.lock().unwrap_or_else(|e| e.into_inner()).pop();
        popped.unwrap_or_else(|| vec![0.0; self.factor.len()])
    }

    /// Return a scratch buffer to the free-list.
    fn put_buf(&self, buf: Vec<f64>) {
        self.scratch.lock().unwrap_or_else(|e| e.into_inner()).push(buf);
    }
}

impl Preconditioner for SparsifierPrecond {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        let mut buf = self.take_buf();
        permute_vec(r, &self.perm, &mut buf);
        self.factor.solve(&mut buf);
        unpermute_vec(&buf, &self.perm, z);
        self.put_buf(buf);
    }

    /// Pooled apply: permutation gather/scatter as disjoint elementwise
    /// writes and the triangular solves level-scheduled across the pool
    /// ([`LdlFactor::solve_par`]) — bitwise identical to the serial
    /// [`Preconditioner::apply`] at every thread count, which `pcg_par`'s
    /// exact-parity guarantee depends on.
    fn apply_par(&self, r: &[f64], z: &mut [f64], threads: usize) {
        if threads <= 1 {
            self.apply(r, z);
            return;
        }
        let mut buf = self.take_buf();
        permute_vec_par(r, &self.perm, &mut buf, threads);
        self.factor.solve_par(&mut buf, threads);
        unpermute_vec_par(&buf, &self.perm, z, threads);
        self.put_buf(buf);
    }
}

/// PCG outcome.
#[derive(Clone, Debug)]
pub struct PcgResult {
    /// Solution estimate.
    pub x: Vec<f64>,
    /// Iterations performed (MATLAB `iter`).
    pub iterations: usize,
    /// Final relative residual `‖r‖/‖b‖`.
    pub relres: f64,
    /// True iff the tolerance was met within `maxit`.
    pub converged: bool,
    /// Relative residual after each iteration (for convergence plots).
    pub history: Vec<f64>,
}

/// Solve `A x = b` by PCG with preconditioner `m`, tolerance
/// `‖r‖ ≤ tol·‖b‖`, at most `maxit` iterations. x₀ = 0.
pub fn pcg<M: Preconditioner>(
    a: &CsrMatrix,
    b: &[f64],
    m: &M,
    tol: f64,
    maxit: usize,
) -> PcgResult {
    pcg_par(a, b, m, tol, maxit, 1)
}

/// As [`pcg`], with **every** per-iteration vector op — the SpMV, both
/// dots, the three axpy-shaped updates, and the residual norm —
/// dispatched onto the persistent thread pool across `threads` workers.
///
/// The iteration loop performs **zero heap allocations** (all vectors
/// and the residual history are sized up front), and none of its BLAS-1
/// tail remains serial: `x`/`r` updates go through `axpy_par`, the
/// direction update through `xpay_par`, the reductions through
/// `dot_par`/`norm2_par`, and the preconditioner through
/// [`Preconditioner::apply_par`] (pooled for the elementwise [`Jacobi`]
/// path, and for [`SparsifierPrecond`], whose two triangular solves run
/// level-scheduled on the pool — see `solver::chol::LevelSchedule`).
///
/// Results are bitwise identical at every thread count, not merely
/// close: the row-parallel SpMV performs the same per-row folds, the
/// elementwise kernels write each slot from the same expression, and the
/// reductions fold over `par::par_reduce`'s fixed chunk tree whose shape
/// is independent of `threads` (see `par::reduce`). `threads == 1` is
/// exactly [`pcg`] — same arithmetic, same iterate sequence, same
/// iteration counts.
pub fn pcg_par<M: Preconditioner>(
    a: &CsrMatrix,
    b: &[f64],
    m: &M,
    tol: f64,
    maxit: usize,
    threads: usize,
) -> PcgResult {
    let n = a.n;
    assert_eq!(b.len(), n);
    let bnorm = norm2_par(b, threads).max(f64::MIN_POSITIVE);
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut z = vec![0.0; n];
    m.apply_par(&r, &mut z, threads);
    let mut p = z.clone();
    let mut rz = dot_par(&r, &z, threads);
    let mut ap = vec![0.0; n];
    // Pre-size so `push` never reallocates for any realistic cap: the
    // loop below is allocation-free end to end. Bounded so an
    // astronomically large `maxit` cannot demand gigabytes up front —
    // beyond the bound the history simply grows amortized.
    let mut history = Vec::with_capacity(maxit.min(1 << 20));
    let mut relres = norm2_par(&r, threads) / bnorm;
    if relres <= tol {
        return PcgResult { x, iterations: 0, relres, converged: true, history };
    }
    for it in 1..=maxit {
        spmv_par(a, &p, &mut ap, threads);
        let pap = dot_par(&p, &ap, threads);
        if pap <= 0.0 || !pap.is_finite() {
            // matrix not SPD along p (numerical breakdown)
            return PcgResult { x, iterations: it - 1, relres, converged: false, history };
        }
        let alpha = rz / pap;
        axpy_par(alpha, &p, &mut x, threads);
        axpy_par(-alpha, &ap, &mut r, threads);
        relres = norm2_par(&r, threads) / bnorm;
        history.push(relres);
        if relres <= tol {
            return PcgResult { x, iterations: it, relres, converged: true, history };
        }
        m.apply_par(&r, &mut z, threads);
        let rz_new = dot_par(&r, &z, threads);
        let beta = rz_new / rz;
        rz = rz_new;
        xpay_par(beta, &z, &mut p, threads);
    }
    PcgResult { x, iterations: maxit, relres, converged: false, history }
}

/// The paper's quality measurement, one place: solve `L_G x = b` (ground
/// vertex 0) with the sparsifier preconditioner and a deterministic
/// seeded-normal RHS. Serial convenience wrapper over [`pcg_eval_par`];
/// shared by [`pcg_iterations`]. The session API's `Sparsifier::pcg`
/// goes through [`pcg_eval_par`] with the session's thread count — the
/// two evaluate exactly the same system and, by [`pcg_par`]'s parity
/// guarantee, produce identical results.
pub fn pcg_eval(
    g: &Graph,
    sparsifier: &Graph,
    rhs_seed: u64,
    tol: f64,
    maxit: usize,
) -> Result<PcgResult, NotPositiveDefinite> {
    pcg_eval_par(g, sparsifier, rhs_seed, tol, maxit, 1)
}

/// As [`pcg_eval`], with the PCG iteration — SpMV, reductions, BLAS-1
/// tail, and the preconditioner's level-scheduled triangular solves —
/// dispatched across `threads` pool workers. Results (iterates, history,
/// iteration count) are bitwise identical at every thread count.
pub fn pcg_eval_par(
    g: &Graph,
    sparsifier: &Graph,
    rhs_seed: u64,
    tol: f64,
    maxit: usize,
    threads: usize,
) -> Result<PcgResult, NotPositiveDefinite> {
    let lg = grounded_laplacian(g, 0);
    let m = SparsifierPrecond::new(sparsifier)?;
    let mut rng = crate::util::Rng::new(rhs_seed);
    let b: Vec<f64> = (0..lg.n).map(|_| rng.normal()).collect();
    Ok(pcg_par(&lg, &b, &m, tol, maxit, threads))
}

/// Convenience: PCG iteration count for solving `L_G x = b` with the
/// sparsifier preconditioner — the paper's quality measurement. The RHS is
/// deterministic per `seed`; tolerance and cap follow §V (1e-3; cap high
/// enough that all suite runs converge).
pub fn pcg_iterations(
    g: &Graph,
    sparsifier: &Graph,
    seed: u64,
    tol: f64,
    maxit: usize,
) -> anyhow::Result<(usize, bool)> {
    let res = pcg_eval(g, sparsifier, seed, tol, maxit)
        .map_err(|e| anyhow::anyhow!("preconditioner factorization failed: {e}"))?;
    Ok((res.iterations, res.converged))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::solver::spmv::{axpy, norm2, spmv};
    use crate::util::Rng;

    fn laplacian_system(seed: u64) -> (CsrMatrix, Vec<f64>, Graph) {
        let g = gen::grid(15, 15, 0.5, &mut Rng::new(seed));
        let a = grounded_laplacian(&g, 0);
        let mut rng = Rng::new(seed + 1);
        let b: Vec<f64> = (0..a.n).map(|_| rng.normal()).collect();
        (a, b, g)
    }

    #[test]
    fn cg_converges_on_spd() {
        let (a, b, _) = laplacian_system(1);
        let res = pcg(&a, &b, &Identity, 1e-8, 5000);
        assert!(res.converged, "relres {}", res.relres);
        // verify actual residual
        let mut ax = vec![0.0; a.n];
        spmv(&a, &res.x, &mut ax);
        axpy(-1.0, &b, &mut ax);
        assert!(norm2(&ax) / norm2(&b) < 1e-7);
    }

    #[test]
    fn jacobi_no_worse_than_identity() {
        let (a, b, _) = laplacian_system(2);
        let plain = pcg(&a, &b, &Identity, 1e-6, 5000);
        let jac = pcg(&a, &b, &Jacobi::new(&a).unwrap(), 1e-6, 5000);
        assert!(jac.converged && plain.converged);
        assert!(jac.iterations <= plain.iterations + 15);
    }

    #[test]
    fn exact_preconditioner_converges_immediately() {
        // Preconditioning with A itself → 1 iteration.
        let (a, b, _) = laplacian_system(3);
        let m = SparsifierPrecond::from_matrix(&a).unwrap();
        let res = pcg(&a, &b, &m, 1e-10, 50);
        assert!(res.converged);
        assert!(res.iterations <= 2, "got {}", res.iterations);
    }

    #[test]
    fn sparsifier_preconditioner_beats_jacobi() {
        let (a, b, g) = laplacian_system(4);
        // sparsifier = spanning tree + some recovered edges
        let sp = crate::tree::build_spanning(&g);
        let params = crate::recovery::Params::new(0.10, 2);
        let r = crate::recovery::pdgrass(&g, &sp, &params);
        let p = crate::recovery::sparsifier(&g, &sp, &r.edges);
        let m = SparsifierPrecond::new(&p).unwrap();
        let with_p = pcg(&a, &b, &m, 1e-3, 5000);
        let with_j = pcg(&a, &b, &Jacobi::new(&a).unwrap(), 1e-3, 5000);
        assert!(with_p.converged);
        assert!(
            with_p.iterations < with_j.iterations,
            "sparsifier {} vs jacobi {}",
            with_p.iterations,
            with_j.iterations
        );
    }

    #[test]
    fn history_is_monotonic_enough_and_matches_iterations() {
        let (a, b, _) = laplacian_system(5);
        let res = pcg(&a, &b, &Jacobi::new(&a).unwrap(), 1e-6, 5000);
        assert_eq!(res.history.len(), res.iterations);
        assert!(res.history.last().unwrap() <= &1e-6);
    }

    #[test]
    fn jacobi_apply_par_is_bitwise_identical_to_serial() {
        let (a, _, _) = laplacian_system(8);
        let m = Jacobi::new(&a).unwrap();
        let mut rng = Rng::new(17);
        // Pad well past the pooled kernel's grain so several chunks run.
        let n = 20_000usize.max(a.n);
        let r: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let inv: Vec<f64> = (0..n).map(|_| 1.0 + rng.normal().abs()).collect();
        let m_big = Jacobi { inv_diag: inv };
        let mut serial = vec![0.0; n];
        m_big.apply(&r, &mut serial);
        for threads in [1usize, 2, 8] {
            let mut par = vec![f64::NAN; n];
            m_big.apply_par(&r, &mut par, threads);
            for i in 0..n {
                assert_eq!(par[i].to_bits(), serial[i].to_bits(), "threads={threads} i={i}");
            }
        }
        // The small real-matrix preconditioner agrees too.
        let rb: Vec<f64> = (0..a.n).map(|i| (i as f64).sin()).collect();
        let mut s = vec![0.0; a.n];
        m.apply(&rb, &mut s);
        let mut p = vec![0.0; a.n];
        m.apply_par(&rb, &mut p, 4);
        assert!(s.iter().zip(&p).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn sparsifier_apply_par_is_bitwise_identical_to_serial() {
        // SparsifierPrecond overrides apply_par with the level-scheduled
        // solve: both entry points must produce identical bits at every
        // thread count.
        let (a, b, _) = laplacian_system(9);
        let m = SparsifierPrecond::from_matrix(&a).unwrap();
        let mut serial = vec![0.0; a.n];
        m.apply(&b, &mut serial);
        for threads in [1usize, 2, 8] {
            let mut par = vec![f64::NAN; a.n];
            m.apply_par(&b, &mut par, threads);
            assert!(
                serial.iter().zip(&par).all(|(x, y)| x.to_bits() == y.to_bits()),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn jacobi_rejects_zero_and_negative_diagonal() {
        // An isolated/grounded-out vertex yields a zero diagonal; the
        // old code silently produced an `inf` inverse that only surfaced
        // as NaN deep inside PCG.
        let a = CsrMatrix::from_triplets(2, vec![(0, 0, 1.0), (1, 1, 0.0)]);
        let err = Jacobi::new(&a).unwrap_err();
        assert_eq!(err.at, 1);
        assert_eq!(err.pivot, 0.0);
        let neg = CsrMatrix::from_triplets(2, vec![(0, 0, -2.0), (1, 1, 1.0)]);
        assert_eq!(Jacobi::new(&neg).unwrap_err().at, 0);
        // A missing diagonal entry reads as zero and is rejected too.
        let missing = CsrMatrix::from_triplets(2, vec![(0, 0, 3.0), (0, 1, 1.0), (1, 0, 1.0)]);
        assert_eq!(Jacobi::new(&missing).unwrap_err().at, 1);
    }

    #[test]
    fn sparsifier_precond_is_sync_and_shareable_across_threads() {
        // The scratch free-list (not a RefCell) makes the preconditioner
        // Sync: one factored instance must serve concurrent callers and
        // give every caller the serial answer.
        fn assert_sync_send<T: Sync + Send>() {}
        assert_sync_send::<SparsifierPrecond>();

        let (a, b, _) = laplacian_system(10);
        let m = SparsifierPrecond::from_matrix(&a).unwrap();
        let mut expect = vec![0.0; a.n];
        m.apply(&b, &mut expect);
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    s.spawn(|| {
                        let mut z = vec![0.0; b.len()];
                        m.apply(&b, &mut z);
                        z
                    })
                })
                .collect();
            for h in handles {
                let z = h.join().unwrap();
                assert!(expect.iter().zip(&z).all(|(x, y)| x.to_bits() == y.to_bits()));
            }
        });
    }

    #[test]
    fn pcg_par_with_sparsifier_precond_matches_serial_exactly() {
        // The acceptance bar for the levelled solve: a full PCG run with
        // a real sparsifier preconditioner (tree + recovered edges) must
        // reproduce the serial iterate sequence, history, and iteration
        // count bit for bit at every thread count.
        let (a, b, g) = laplacian_system(11);
        let sp = crate::tree::build_spanning(&g);
        let r = crate::recovery::pdgrass(&g, &sp, &crate::recovery::Params::new(0.10, 2));
        let p = crate::recovery::sparsifier(&g, &sp, &r.edges);
        let m = SparsifierPrecond::new(&p).unwrap();
        let serial = pcg(&a, &b, &m, 1e-6, 5000);
        assert!(serial.converged);
        for threads in [2usize, 8] {
            let par = pcg_par(&a, &b, &m, 1e-6, 5000, threads);
            assert_eq!(par.iterations, serial.iterations, "threads={threads}");
            assert_eq!(par.converged, serial.converged);
            assert_eq!(par.history, serial.history, "threads={threads}");
            assert_eq!(par.x, serial.x, "threads={threads}");
        }
    }

    #[test]
    fn pcg_eval_par_matches_pcg_eval_exactly() {
        let g = gen::grid(12, 12, 0.5, &mut Rng::new(13));
        let sp = crate::tree::build_spanning(&g);
        let r = crate::recovery::pdgrass(&g, &sp, &crate::recovery::Params::new(0.05, 1));
        let p = crate::recovery::sparsifier(&g, &sp, &r.edges);
        let serial = pcg_eval(&g, &p, 42, 1e-3, 10_000).unwrap();
        for threads in [2usize, 8] {
            let par = pcg_eval_par(&g, &p, 42, 1e-3, 10_000, threads).unwrap();
            assert_eq!(par.iterations, serial.iterations, "threads={threads}");
            assert_eq!(par.history, serial.history, "threads={threads}");
            assert_eq!(par.x, serial.x, "threads={threads}");
        }
    }

    #[test]
    fn pcg_par_matches_serial_exactly() {
        // Row-parallel SpMV does the same per-row folds and every
        // dot/norm reduces over the thread-count-independent fixed chunk
        // tree, so the iterate sequence (and thus iteration count and
        // history) must be identical, not merely close.
        let (a, b, _) = laplacian_system(7);
        let m = Jacobi::new(&a).unwrap();
        let serial = pcg(&a, &b, &m, 1e-6, 5000);
        for threads in [2usize, 4, 8] {
            let par = pcg_par(&a, &b, &m, 1e-6, 5000, threads);
            assert_eq!(par.iterations, serial.iterations, "threads={threads}");
            assert_eq!(par.converged, serial.converged);
            assert_eq!(par.history, serial.history, "threads={threads}");
            assert_eq!(par.x, serial.x, "threads={threads}");
        }
    }

    #[test]
    fn pcg_iterations_helper() {
        let g = gen::grid(12, 12, 0.5, &mut Rng::new(6));
        let sp = crate::tree::build_spanning(&g);
        let r = crate::recovery::pdgrass(&g, &sp, &crate::recovery::Params::new(0.05, 1));
        let p = crate::recovery::sparsifier(&g, &sp, &r.edges);
        let (iters, conv) = pcg_iterations(&g, &p, 42, 1e-3, 10_000).unwrap();
        assert!(conv);
        assert!(iters > 0 && iters < 10_000);
    }
}
