//! CSR sparse matrix–vector product — the PCG hot loop.
//!
//! Two paths exist in the repo: this pure-Rust CSR kernel, and the
//! XLA-compiled Pallas ELL kernel (`runtime::`). They are cross-validated
//! in `rust/tests/xla_parity.rs`.

use crate::graph::CsrMatrix;
use crate::par;

/// `y = A·x`, serial.
pub fn spmv(a: &CsrMatrix, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), a.n);
    debug_assert_eq!(y.len(), a.n);
    for i in 0..a.n {
        let (s, e) = (a.rowptr[i], a.rowptr[i + 1]);
        let mut acc = 0.0;
        for p in s..e {
            acc += a.vals[p] * x[a.colidx[p] as usize];
        }
        y[i] = acc;
    }
}

/// `y = A·x`, rows split across threads (row-disjoint writes).
///
/// Dispatches onto the persistent pool (`par::pool`), so the per-call
/// cost is a queue push + condvar wake rather than thread spawn/join —
/// this runs once per PCG iteration, which is exactly the spawn-per-call
/// hot loop the pool exists for.
pub fn spmv_par(a: &CsrMatrix, x: &[f64], y: &mut [f64], threads: usize) {
    debug_assert_eq!(x.len(), a.n);
    debug_assert_eq!(y.len(), a.n);
    if threads <= 1 {
        spmv(a, x, y);
        return;
    }
    let ptr = par::as_send_ptr(y);
    par::par_chunks(a.n, threads, |_, range| {
        for i in range {
            let (s, e) = (a.rowptr[i], a.rowptr[i + 1]);
            let mut acc = 0.0;
            for p in s..e {
                acc += a.vals[p] * x[a.colidx[p] as usize];
            }
            // SAFETY: row ranges are disjoint across threads.
            unsafe { ptr.write(i, acc) };
        }
    });
}

/// Dot product (serial left-to-right fold).
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// `y ← y + alpha·x`, serial.
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..y.len() {
        y[i] += alpha * x[i];
    }
}

/// `p ← z + beta·p`, serial — the PCG direction update ("xpay").
pub fn xpay(beta: f64, z: &[f64], p: &mut [f64]) {
    debug_assert_eq!(z.len(), p.len());
    for i in 0..p.len() {
        p[i] = z[i] + beta * p[i];
    }
}

/// Euclidean norm (serial).
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// Leaf size of the fixed reduction tree used by [`dot_par`] /
/// [`norm2_par`], and the claim grain of the pooled elementwise kernels.
/// One constant for all BLAS-1 call sites so every reduction over a
/// length-n vector shares the same tree shape (see `par::reduce` for why
/// that makes results bitwise thread-count-independent).
const BLAS1_GRAIN: usize = 4096;

/// Dot product on the pool over the fixed chunk tree.
///
/// Bitwise-deterministic: the reduction tree depends only on the vector
/// length (grain is fixed), so the result is identical across runs *and*
/// thread counts — `threads` only sets fork depth. `threads == 1` runs
/// serially but folds over the same tree, hence `dot_par(a, b, 1) ==
/// dot_par(a, b, t)` bitwise for every `t`.
pub fn dot_par(a: &[f64], b: &[f64], threads: usize) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    par::par_reduce(
        a.len(),
        threads,
        BLAS1_GRAIN,
        |r: std::ops::Range<usize>| {
            let mut s = 0.0;
            for i in r {
                s += a[i] * b[i];
            }
            s
        },
        |x, y| x + y,
    )
}

/// Euclidean norm on the pool; same determinism contract as [`dot_par`].
pub fn norm2_par(x: &[f64], threads: usize) -> f64 {
    dot_par(x, x, threads).sqrt()
}

/// `y ← y + alpha·x` on the pool (disjoint elementwise writes — exact at
/// any thread count).
pub fn axpy_par(alpha: f64, x: &[f64], y: &mut [f64], threads: usize) {
    debug_assert_eq!(x.len(), y.len());
    if threads <= 1 {
        axpy(alpha, x, y);
        return;
    }
    par::par_update(y, threads, BLAS1_GRAIN, |i, yi| *yi += alpha * x[i]);
}

/// `p ← z + beta·p` on the pool (disjoint elementwise writes — exact at
/// any thread count).
pub fn xpay_par(beta: f64, z: &[f64], p: &mut [f64], threads: usize) {
    debug_assert_eq!(z.len(), p.len());
    if threads <= 1 {
        xpay(beta, z, p);
        return;
    }
    par::par_update(p, threads, BLAS1_GRAIN, |i, pi| *pi = z[i] + beta * *pi);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn small() -> CsrMatrix {
        // [[2,-1,0],[-1,2,-1],[0,-1,2]]
        CsrMatrix::from_triplets(
            3,
            vec![
                (0, 0, 2.0),
                (0, 1, -1.0),
                (1, 0, -1.0),
                (1, 1, 2.0),
                (1, 2, -1.0),
                (2, 1, -1.0),
                (2, 2, 2.0),
            ],
        )
    }

    #[test]
    fn spmv_small() {
        let a = small();
        let x = [1.0, 2.0, 3.0];
        let mut y = [0.0; 3];
        spmv(&a, &x, &mut y);
        assert_eq!(y, [0.0, 0.0, 4.0]);
    }

    #[test]
    fn spmv_par_matches_serial() {
        let mut rng = Rng::new(8);
        let n = 500;
        let mut t = Vec::new();
        for i in 0..n as u32 {
            t.push((i, i, 4.0 + rng.next_f64()));
            for _ in 0..5 {
                let j = rng.below(n) as u32;
                t.push((i, j, rng.next_f64() - 0.5));
            }
        }
        let a = CsrMatrix::from_triplets(n, t);
        let x: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        let mut y1 = vec![0.0; n];
        let mut y2 = vec![0.0; n];
        spmv(&a, &x, &mut y1);
        spmv_par(&a, &x, &mut y2, 4);
        for (u, v) in y1.iter().zip(&y2) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    fn blas1_helpers() {
        let a = [1.0, 2.0, 3.0];
        let mut b = [1.0, 1.0, 1.0];
        assert_eq!(dot(&a, &b), 6.0);
        axpy(2.0, &a, &mut b);
        assert_eq!(b, [3.0, 5.0, 7.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
        let mut p = [1.0, 2.0, 3.0];
        xpay(2.0, &[10.0, 20.0, 30.0], &mut p);
        assert_eq!(p, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn pooled_blas1_matches_serial_and_is_thread_invariant() {
        let mut rng = Rng::new(21);
        for n in [0usize, 1, 100, 4096, 50_000] {
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let serial = dot(&a, &b);
            let reference = dot_par(&a, &b, 1);
            // Tree fold vs left fold: equal to rounding.
            assert!(
                (reference - serial).abs() <= 1e-12 * serial.abs().max(1.0),
                "n={n}: {reference} vs {serial}"
            );
            for threads in [2usize, 4, 8] {
                // Bitwise identical across thread counts.
                assert_eq!(dot_par(&a, &b, threads).to_bits(), reference.to_bits(), "n={n}");
                assert_eq!(norm2_par(&a, threads).to_bits(), norm2_par(&a, 1).to_bits());
            }
        }
    }

    #[test]
    fn pooled_axpy_and_xpay_match_serial_exactly() {
        let mut rng = Rng::new(22);
        let n = 30_000;
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let z: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let y0: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut ys = y0.clone();
        axpy(0.37, &x, &mut ys);
        let mut ps = y0.clone();
        xpay(-1.25, &z, &mut ps);
        for threads in [2usize, 4, 8] {
            let mut yp = y0.clone();
            axpy_par(0.37, &x, &mut yp, threads);
            assert_eq!(yp, ys, "axpy threads={threads}");
            let mut pp = y0.clone();
            xpay_par(-1.25, &z, &mut pp, threads);
            assert_eq!(pp, ps, "xpay threads={threads}");
        }
    }
}
