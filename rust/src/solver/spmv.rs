//! CSR sparse matrix–vector product — the PCG hot loop.
//!
//! Two paths exist in the repo: this pure-Rust CSR kernel, and the
//! XLA-compiled Pallas ELL kernel (`runtime::`). They are cross-validated
//! in `rust/tests/xla_parity.rs`.
//!
//! # Partitioning and blocking
//!
//! [`spmv_par`] splits rows by **prefix-summed nnz**, not row count:
//! `rowptr` *is* the nnz prefix sum, so each thread boundary is one binary
//! search ([`nnz_balanced_ranges`]) and every thread streams a near-equal
//! share of the matrix regardless of degree skew (a hub row no longer
//! serializes its whole chunk). Rows at or above [`HEAVY_ROW_NNZ`] are
//! additionally swept in tiles over [`SPMV_COL_BLOCK`]-wide column blocks
//! so their scattered `x` gathers stay within an L2-sized window that the
//! tile's rows share.
//!
//! Both changes are bitwise-neutral: columns ascend within every CSR row
//! (`from_triplets` sorts by `(r, c)`), so the blocked sweep visits each
//! row's entries in exactly the serial order, each row folds into its own
//! accumulator, and row-disjoint writes make the partition irrelevant to
//! the result. [`spmv_traffic_model`] is the deterministic cost model
//! backing the `benches/micro.rs` assertion that the balanced blocked
//! kernel wins on skewed graphs.

use crate::graph::CsrMatrix;
use crate::par;

/// `y = A·x`, serial.
pub fn spmv(a: &CsrMatrix, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), a.n);
    debug_assert_eq!(y.len(), a.n);
    for i in 0..a.n {
        let (s, e) = (a.rowptr[i] as usize, a.rowptr[i + 1] as usize);
        let mut acc = 0.0;
        for p in s..e {
            acc += a.vals[p] * x[a.colidx[p] as usize];
        }
        y[i] = acc;
    }
}

/// Row nnz at or above which [`spmv_par`] sweeps the row through
/// column-blocked tiles instead of a straight gather. Light rows touch
/// too few `x` entries for blocking to pay for its cursor bookkeeping.
pub const HEAVY_ROW_NNZ: usize = 512;

/// Column-block width (in `x` entries) of the heavy-row sweep:
/// 2¹⁵ doubles = 256 KiB of `x`, sized to sit inside a typical
/// 512 KiB–1 MiB per-core L2 alongside the streamed CSR arrays.
pub const SPMV_COL_BLOCK: usize = 1 << 15;

/// Heavy rows swept together per tile: the tile's rows share each
/// resident column block, and 8 cursor/accumulator pairs stay in
/// registers/L1.
const TILE_ROWS: usize = 8;

/// nnz-balanced row partition: thread `t` starts at the first row whose
/// prefix nnz reaches `t·nnz/threads`. `rowptr` is the prefix sum, so
/// each boundary is a single binary search. Ranges are contiguous,
/// disjoint, and cover `0..n`; some may be empty when a single row holds
/// more than `1/threads` of the matrix.
pub fn nnz_balanced_ranges(a: &CsrMatrix, threads: usize) -> Vec<std::ops::Range<usize>> {
    let t = threads.max(1);
    let total = a.nnz() as u64;
    let mut bounds: Vec<usize> = Vec::with_capacity(t + 1);
    bounds.push(0);
    for k in 1..t {
        let target = (total * k as u64 / t as u64) as u32;
        let row = a.rowptr.partition_point(|&p| p < target).min(a.n);
        bounds.push(row.max(*bounds.last().expect("nonempty")));
    }
    bounds.push(a.n);
    bounds.windows(2).map(|w| w[0]..w[1]).collect()
}

/// `y = A·x`, nnz-balanced across threads with column-blocked heavy rows
/// (row-disjoint writes) — bitwise identical to [`spmv`] at every thread
/// count (see the module docs for why).
///
/// Dispatches onto the persistent pool (`par::pool`), so the per-call
/// cost is a queue push + condvar wake rather than thread spawn/join —
/// this runs once per PCG iteration, which is exactly the spawn-per-call
/// hot loop the pool exists for.
pub fn spmv_par(a: &CsrMatrix, x: &[f64], y: &mut [f64], threads: usize) {
    debug_assert_eq!(x.len(), a.n);
    debug_assert_eq!(y.len(), a.n);
    if threads <= 1 {
        spmv(a, x, y);
        return;
    }
    let ranges = nnz_balanced_ranges(a, threads);
    let ptr = par::as_send_ptr(y);
    par::par_map(&ranges, threads, |range| {
        spmv_rows(a, x, &ptr, range.clone());
    });
}

/// One thread's share of [`spmv_par`]: light rows gather straight
/// through; heavy rows are buffered into [`TILE_ROWS`]-row tiles and
/// swept by [`spmv_tile`].
///
/// The writes through `y` are safe because the caller hands each row
/// range to exactly one task and ranges are disjoint.
fn spmv_rows(a: &CsrMatrix, x: &[f64], y: &par::SendPtr<f64>, rows: std::ops::Range<usize>) {
    let mut tile = [0usize; TILE_ROWS];
    let mut tlen = 0usize;
    for i in rows {
        let (s, e) = (a.rowptr[i] as usize, a.rowptr[i + 1] as usize);
        if e - s >= HEAVY_ROW_NNZ {
            tile[tlen] = i;
            tlen += 1;
            if tlen == TILE_ROWS {
                spmv_tile(a, x, y, &tile[..tlen]);
                tlen = 0;
            }
        } else {
            let mut acc = 0.0;
            for p in s..e {
                acc += a.vals[p] * x[a.colidx[p] as usize];
            }
            // SAFETY: row ranges are disjoint across tasks and each row
            // is written exactly once; `y` outlives the scope join.
            unsafe { y.write(i, acc) };
        }
    }
    if tlen > 0 {
        spmv_tile(a, x, y, &tile[..tlen]);
    }
}

/// Sweep a tile of heavy rows through ascending column blocks: every row
/// keeps a cursor and a private accumulator, and each block is visited at
/// most once per tile, during which all the tile's entries in that block
/// gather from the same resident `x` window. Because columns ascend
/// within a row, each accumulator folds its entries in exactly the
/// serial order — the blocking is invisible to the floating-point result.
fn spmv_tile(a: &CsrMatrix, x: &[f64], y: &par::SendPtr<f64>, tile: &[usize]) {
    debug_assert!(tile.len() <= TILE_ROWS);
    let mut cur = [0usize; TILE_ROWS];
    let mut end = [0usize; TILE_ROWS];
    let mut acc = [0f64; TILE_ROWS];
    for (k, &i) in tile.iter().enumerate() {
        cur[k] = a.rowptr[i] as usize;
        end[k] = a.rowptr[i + 1] as usize;
    }
    loop {
        // Next block = the one holding the smallest pending column.
        let mut next_col = usize::MAX;
        for k in 0..tile.len() {
            if cur[k] < end[k] {
                next_col = next_col.min(a.colidx[cur[k]] as usize);
            }
        }
        if next_col == usize::MAX {
            break;
        }
        let block_end = (next_col / SPMV_COL_BLOCK + 1) * SPMV_COL_BLOCK;
        for k in 0..tile.len() {
            while cur[k] < end[k] && (a.colidx[cur[k]] as usize) < block_end {
                acc[k] += a.vals[cur[k]] * x[a.colidx[cur[k]] as usize];
                cur[k] += 1;
            }
        }
    }
    for (k, &i) in tile.iter().enumerate() {
        // SAFETY: tile rows come from this task's disjoint row range and
        // each row is written exactly once; `y` outlives the scope join.
        unsafe { y.write(i, acc[k]) };
    }
}

/// Cache line width in `x` entries (64 B / 8 B doubles).
const LINE: usize = 8;

/// Deterministic memory-traffic model comparing the pre-PR-10 row-count
/// kernel with the nnz-balanced blocked one, in abstract units: one unit
/// per streamed CSR entry plus one unit per `x` cache line faulted in.
/// Returns `(row_count_units, balanced_blocked_units)`, each the
/// list-scheduling makespan (max over threads) of its partition.
///
/// Traffic accounting: columns ascend within a row, so consecutive
/// same-line gathers coalesce in both kernels; the unblocked kernel gets
/// no reuse *across* rows (by the time the next row runs, a giant
/// working set has evicted the line), while the blocked kernel charges
/// each line once per heavy-row **tile** — the whole point of sweeping
/// the tile through a resident column block. Like
/// `LdlFactor::solve_makespan_model` and `schedsim::PrepSim`, this is a
/// cost model, not a measurement: `benches/micro.rs` asserts the model
/// win on a hub-star graph at 8 threads and records both sides in
/// `model_units`, where `pdgrass benchdiff` pins them exactly.
pub fn spmv_traffic_model(a: &CsrMatrix, threads: usize) -> (u64, u64) {
    let t = threads.max(1).min(a.n.max(1));
    // Distinct x cache lines one row touches (ascending columns).
    let row_lines = |i: usize| -> u64 {
        let mut lines = 0u64;
        let mut last = usize::MAX;
        let (cols, _) = a.row(i);
        for &c in cols {
            let l = c as usize / LINE;
            if l != last {
                lines += 1;
                last = l;
            }
        }
        lines
    };
    // Legacy kernel: ceil-division row chunks (par_chunks), straight
    // gather per row.
    let per = a.n.div_ceil(t);
    let mut row_count_units = 0u64;
    for c in 0..t {
        let (lo, hi) = (c * per, ((c + 1) * per).min(a.n));
        let mut units = 0u64;
        for i in lo..hi {
            units += a.row_nnz(i) as u64 + row_lines(i);
        }
        row_count_units = row_count_units.max(units);
    }
    // Balanced blocked kernel: nnz-balanced ranges; heavy rows tiled,
    // with x lines charged once per tile (union across the tile's rows,
    // swept block by block exactly as spmv_tile does).
    let mut balanced_units = 0u64;
    for range in nnz_balanced_ranges(a, t) {
        let mut units = 0u64;
        let mut tile: Vec<usize> = Vec::with_capacity(TILE_ROWS);
        let mut flush = |tile: &mut Vec<usize>, units: &mut u64| {
            if tile.is_empty() {
                return;
            }
            let mut cur: Vec<usize> =
                tile.iter().map(|&i| a.rowptr[i] as usize).collect();
            let end: Vec<usize> =
                tile.iter().map(|&i| a.rowptr[i + 1] as usize).collect();
            let mut last_line = usize::MAX;
            loop {
                // Smallest pending column across the tile = the union
                // sweep order; distinct lines of the merged stream are
                // exactly the lines the tile faults in.
                let mut kmin = usize::MAX;
                let mut cmin = usize::MAX;
                for k in 0..tile.len() {
                    if cur[k] < end[k] {
                        let c = a.colidx[cur[k]] as usize;
                        if c < cmin {
                            cmin = c;
                            kmin = k;
                        }
                    }
                }
                if kmin == usize::MAX {
                    break;
                }
                *units += 1; // streamed entry
                let l = cmin / LINE;
                if l != last_line {
                    *units += 1; // line fault, shared across the tile
                    last_line = l;
                }
                cur[kmin] += 1;
            }
            tile.clear();
        };
        for i in range {
            if a.row_nnz(i) >= HEAVY_ROW_NNZ {
                tile.push(i);
                if tile.len() == TILE_ROWS {
                    flush(&mut tile, &mut units);
                }
            } else {
                units += a.row_nnz(i) as u64 + row_lines(i);
            }
        }
        flush(&mut tile, &mut units);
        balanced_units = balanced_units.max(units);
    }
    (row_count_units, balanced_units)
}

/// Dot product (serial left-to-right fold).
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// `y ← y + alpha·x`, serial.
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..y.len() {
        y[i] += alpha * x[i];
    }
}

/// `p ← z + beta·p`, serial — the PCG direction update ("xpay").
pub fn xpay(beta: f64, z: &[f64], p: &mut [f64]) {
    debug_assert_eq!(z.len(), p.len());
    for i in 0..p.len() {
        p[i] = z[i] + beta * p[i];
    }
}

/// Euclidean norm (serial).
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// Leaf size of the fixed reduction tree used by [`dot_par`] /
/// [`norm2_par`], and the claim grain of the pooled elementwise kernels.
/// One constant for all BLAS-1 call sites so every reduction over a
/// length-n vector shares the same tree shape (see `par::reduce` for why
/// that makes results bitwise thread-count-independent).
const BLAS1_GRAIN: usize = 4096;

/// Dot product on the pool over the fixed chunk tree.
///
/// Bitwise-deterministic: the reduction tree depends only on the vector
/// length (grain is fixed), so the result is identical across runs *and*
/// thread counts — `threads` only sets fork depth. `threads == 1` runs
/// serially but folds over the same tree, hence `dot_par(a, b, 1) ==
/// dot_par(a, b, t)` bitwise for every `t`.
pub fn dot_par(a: &[f64], b: &[f64], threads: usize) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    par::par_reduce(
        a.len(),
        threads,
        BLAS1_GRAIN,
        |r: std::ops::Range<usize>| {
            let mut s = 0.0;
            for i in r {
                s += a[i] * b[i];
            }
            s
        },
        |x, y| x + y,
    )
}

/// Euclidean norm on the pool; same determinism contract as [`dot_par`].
pub fn norm2_par(x: &[f64], threads: usize) -> f64 {
    dot_par(x, x, threads).sqrt()
}

/// `y ← y + alpha·x` on the pool (disjoint elementwise writes — exact at
/// any thread count).
pub fn axpy_par(alpha: f64, x: &[f64], y: &mut [f64], threads: usize) {
    debug_assert_eq!(x.len(), y.len());
    if threads <= 1 {
        axpy(alpha, x, y);
        return;
    }
    par::par_update(y, threads, BLAS1_GRAIN, |i, yi| *yi += alpha * x[i]);
}

/// `p ← z + beta·p` on the pool (disjoint elementwise writes — exact at
/// any thread count).
pub fn xpay_par(beta: f64, z: &[f64], p: &mut [f64], threads: usize) {
    debug_assert_eq!(z.len(), p.len());
    if threads <= 1 {
        xpay(beta, z, p);
        return;
    }
    par::par_update(p, threads, BLAS1_GRAIN, |i, pi| *pi = z[i] + beta * *pi);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn small() -> CsrMatrix {
        // [[2,-1,0],[-1,2,-1],[0,-1,2]]
        CsrMatrix::from_triplets(
            3,
            vec![
                (0, 0, 2.0),
                (0, 1, -1.0),
                (1, 0, -1.0),
                (1, 1, 2.0),
                (1, 2, -1.0),
                (2, 1, -1.0),
                (2, 2, 2.0),
            ],
        )
    }

    #[test]
    fn spmv_small() {
        let a = small();
        let x = [1.0, 2.0, 3.0];
        let mut y = [0.0; 3];
        spmv(&a, &x, &mut y);
        assert_eq!(y, [0.0, 0.0, 4.0]);
    }

    #[test]
    fn spmv_par_matches_serial() {
        let mut rng = Rng::new(8);
        let n = 500;
        let mut t = Vec::new();
        for i in 0..n as u32 {
            t.push((i, i, 4.0 + rng.next_f64()));
            for _ in 0..5 {
                let j = rng.below(n) as u32;
                t.push((i, j, rng.next_f64() - 0.5));
            }
        }
        let a = CsrMatrix::from_triplets(n, t);
        let x: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        let mut y1 = vec![0.0; n];
        let mut y2 = vec![0.0; n];
        spmv(&a, &x, &mut y1);
        spmv_par(&a, &x, &mut y2, 4);
        for (u, v) in y1.iter().zip(&y2) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    fn nnz_ranges_partition_all_rows() {
        let mut rng = Rng::new(31);
        let g = crate::gen::hub_graph(400, 3, 200, &mut rng);
        let a = crate::graph::grounded_laplacian(&g, 0);
        for threads in [1usize, 2, 3, 8, 17] {
            let ranges = nnz_balanced_ranges(&a, threads);
            assert_eq!(ranges.len(), threads.min(a.n.max(1)).max(1));
            let mut next = 0usize;
            for r in &ranges {
                assert_eq!(r.start, next, "ranges must be contiguous");
                assert!(r.end >= r.start);
                next = r.end;
            }
            assert_eq!(next, a.n, "ranges must cover every row");
        }
    }

    #[test]
    fn spmv_par_bitwise_identical_on_skewed_graph() {
        // Hub-star: a few rows carry most of the nnz, exercising both the
        // nnz-balanced boundaries and the heavy-row tile sweep. The
        // result must match serial bit for bit at every thread count.
        let mut rng = Rng::new(12);
        let g = crate::gen::hub_graph(3000, 4, 1500, &mut rng);
        let a = crate::graph::grounded_laplacian(&g, 0);
        assert!(
            (0..a.n).any(|i| a.row_nnz(i) >= HEAVY_ROW_NNZ),
            "test graph must have heavy rows"
        );
        let x: Vec<f64> = (0..a.n).map(|_| rng.normal()).collect();
        let mut serial = vec![0.0; a.n];
        spmv(&a, &x, &mut serial);
        for threads in [1usize, 2, 8] {
            let mut par = vec![f64::NAN; a.n];
            spmv_par(&a, &x, &mut par, threads);
            for (i, (u, v)) in par.iter().zip(&serial).enumerate() {
                assert_eq!(u.to_bits(), v.to_bits(), "threads={threads} row {i}: {u:e} vs {v:e}");
            }
        }
    }

    #[test]
    fn traffic_model_prefers_balanced_blocked_on_hub_star() {
        let mut rng = Rng::new(13);
        let g = crate::gen::hub_graph(4000, 2, 2000, &mut rng);
        let a = crate::graph::grounded_laplacian(&g, 0);
        let (row_count, balanced) = spmv_traffic_model(&a, 8);
        assert!(
            balanced < row_count,
            "balanced blocked {balanced} must beat row-count {row_count} on skew"
        );
        // At one thread there is no balance win; the model may still
        // credit tile line sharing, so only require no regression.
        let (rc1, bal1) = spmv_traffic_model(&a, 1);
        assert!(bal1 <= rc1, "single-thread model must not regress: {bal1} vs {rc1}");
    }

    #[test]
    fn blas1_helpers() {
        let a = [1.0, 2.0, 3.0];
        let mut b = [1.0, 1.0, 1.0];
        assert_eq!(dot(&a, &b), 6.0);
        axpy(2.0, &a, &mut b);
        assert_eq!(b, [3.0, 5.0, 7.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
        let mut p = [1.0, 2.0, 3.0];
        xpay(2.0, &[10.0, 20.0, 30.0], &mut p);
        assert_eq!(p, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn pooled_blas1_matches_serial_and_is_thread_invariant() {
        let mut rng = Rng::new(21);
        for n in [0usize, 1, 100, 4096, 50_000] {
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let serial = dot(&a, &b);
            let reference = dot_par(&a, &b, 1);
            // Tree fold vs left fold: equal to rounding.
            assert!(
                (reference - serial).abs() <= 1e-12 * serial.abs().max(1.0),
                "n={n}: {reference} vs {serial}"
            );
            for threads in [2usize, 4, 8] {
                // Bitwise identical across thread counts.
                assert_eq!(dot_par(&a, &b, threads).to_bits(), reference.to_bits(), "n={n}");
                assert_eq!(norm2_par(&a, threads).to_bits(), norm2_par(&a, 1).to_bits());
            }
        }
    }

    #[test]
    fn pooled_axpy_and_xpay_match_serial_exactly() {
        let mut rng = Rng::new(22);
        let n = 30_000;
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let z: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let y0: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut ys = y0.clone();
        axpy(0.37, &x, &mut ys);
        let mut ps = y0.clone();
        xpay(-1.25, &z, &mut ps);
        for threads in [2usize, 4, 8] {
            let mut yp = y0.clone();
            axpy_par(0.37, &x, &mut yp, threads);
            assert_eq!(yp, ys, "axpy threads={threads}");
            let mut pp = y0.clone();
            xpay_par(-1.25, &z, &mut pp, threads);
            assert_eq!(pp, ps, "xpay threads={threads}");
        }
    }
}
