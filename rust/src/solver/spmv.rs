//! CSR sparse matrix–vector product — the PCG hot loop.
//!
//! Two paths exist in the repo: this pure-Rust CSR kernel, and the
//! XLA-compiled Pallas ELL kernel (`runtime::`). They are cross-validated
//! in `rust/tests/xla_parity.rs`.

use crate::graph::CsrMatrix;
use crate::par;

/// `y = A·x`, serial.
pub fn spmv(a: &CsrMatrix, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), a.n);
    debug_assert_eq!(y.len(), a.n);
    for i in 0..a.n {
        let (s, e) = (a.rowptr[i], a.rowptr[i + 1]);
        let mut acc = 0.0;
        for p in s..e {
            acc += a.vals[p] * x[a.colidx[p] as usize];
        }
        y[i] = acc;
    }
}

/// `y = A·x`, rows split across threads (row-disjoint writes).
///
/// Dispatches onto the persistent pool (`par::pool`), so the per-call
/// cost is a queue push + condvar wake rather than thread spawn/join —
/// this runs once per PCG iteration, which is exactly the spawn-per-call
/// hot loop the pool exists for.
pub fn spmv_par(a: &CsrMatrix, x: &[f64], y: &mut [f64], threads: usize) {
    debug_assert_eq!(x.len(), a.n);
    debug_assert_eq!(y.len(), a.n);
    if threads <= 1 {
        spmv(a, x, y);
        return;
    }
    let ptr = par::as_send_ptr(y);
    par::par_chunks(a.n, threads, |_, range| {
        for i in range {
            let (s, e) = (a.rowptr[i], a.rowptr[i + 1]);
            let mut acc = 0.0;
            for p in s..e {
                acc += a.vals[p] * x[a.colidx[p] as usize];
            }
            // SAFETY: row ranges are disjoint across threads.
            unsafe { ptr.write(i, acc) };
        }
    });
}

/// Dot product.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// `y ← y + alpha·x`.
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..y.len() {
        y[i] += alpha * x[i];
    }
}

/// Euclidean norm.
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn small() -> CsrMatrix {
        // [[2,-1,0],[-1,2,-1],[0,-1,2]]
        CsrMatrix::from_triplets(
            3,
            vec![
                (0, 0, 2.0),
                (0, 1, -1.0),
                (1, 0, -1.0),
                (1, 1, 2.0),
                (1, 2, -1.0),
                (2, 1, -1.0),
                (2, 2, 2.0),
            ],
        )
    }

    #[test]
    fn spmv_small() {
        let a = small();
        let x = [1.0, 2.0, 3.0];
        let mut y = [0.0; 3];
        spmv(&a, &x, &mut y);
        assert_eq!(y, [0.0, 0.0, 4.0]);
    }

    #[test]
    fn spmv_par_matches_serial() {
        let mut rng = Rng::new(8);
        let n = 500;
        let mut t = Vec::new();
        for i in 0..n as u32 {
            t.push((i, i, 4.0 + rng.next_f64()));
            for _ in 0..5 {
                let j = rng.below(n) as u32;
                t.push((i, j, rng.next_f64() - 0.5));
            }
        }
        let a = CsrMatrix::from_triplets(n, t);
        let x: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        let mut y1 = vec![0.0; n];
        let mut y2 = vec![0.0; n];
        spmv(&a, &x, &mut y1);
        spmv_par(&a, &x, &mut y2, 4);
        for (u, v) in y1.iter().zip(&y2) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    fn blas1_helpers() {
        let a = [1.0, 2.0, 3.0];
        let mut b = [1.0, 1.0, 1.0];
        assert_eq!(dot(&a, &b), 6.0);
        axpy(2.0, &a, &mut b);
        assert_eq!(b, [3.0, 5.0, 7.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }
}
