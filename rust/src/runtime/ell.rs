//! CSR → padded-ELL conversion with shape buckets and the HYB split.
//!
//! The AOT artifacts are compiled for fixed `[n, k]` shapes (XLA is
//! static-shape). A matrix is placed in the smallest bucket with
//! `n_bucket ≥ n`; rows are padded with zero-valued slots (index 0), and
//! rows with more than `k` entries spill the excess into a COO *tail*
//! that the Rust coordinator applies after the XLA dispatch — the classic
//! HYB (ELL + COO) split, which keeps `k` small even when a hub vertex has
//! thousands of incident edges.

use crate::graph::CsrMatrix;

/// Padded ELL matrix + COO tail targeting one artifact bucket.
#[derive(Clone, Debug)]
pub struct EllMatrix {
    /// Logical dimension (rows of the original matrix).
    pub n: usize,
    /// Bucket dimension (`values.len() / k`), ≥ `n`.
    pub n_bucket: usize,
    /// ELL slot count per row.
    pub k: usize,
    /// Row-major `[n_bucket, k]` slot values (f32 for the XLA path).
    pub values: Vec<f32>,
    /// Row-major `[n_bucket, k]` slot column indices.
    pub indices: Vec<i32>,
    /// COO tail: entries that did not fit in `k` slots.
    pub tail: Vec<(u32, u32, f64)>,
}

impl EllMatrix {
    /// Convert a CSR matrix to ELL form for bucket `(n_bucket, k)`.
    ///
    /// Panics if `n_bucket < a.n`.
    pub fn from_csr(a: &CsrMatrix, n_bucket: usize, k: usize) -> EllMatrix {
        assert!(n_bucket >= a.n, "bucket {n_bucket} too small for n={}", a.n);
        let mut values = vec![0f32; n_bucket * k];
        let mut indices = vec![0i32; n_bucket * k];
        let mut tail = Vec::new();
        for i in 0..a.n {
            let (cols, vals) = a.row(i);
            for (slot, (&c, &v)) in cols.iter().zip(vals).enumerate() {
                if slot < k {
                    values[i * k + slot] = v as f32;
                    indices[i * k + slot] = c as i32;
                } else {
                    tail.push((i as u32, c, v));
                }
            }
        }
        EllMatrix { n: a.n, n_bucket, k, values, indices, tail }
    }

    /// Fraction of ELL slots that are padding (diagnostics / perf model).
    pub fn padding_ratio(&self) -> f64 {
        let nnz_ell: usize = self.values.iter().filter(|&&v| v != 0.0).count();
        1.0 - nnz_ell as f64 / (self.n_bucket * self.k) as f64
    }

    /// Apply the COO tail: `y += tail · x` (f64 accumulate on the Rust
    /// side; the tail is tiny by construction).
    pub fn apply_tail(&self, x: &[f64], y: &mut [f64]) {
        for &(i, j, v) in &self.tail {
            y[i as usize] += v * x[j as usize];
        }
    }
}

/// Shape buckets shipped in `artifacts/manifest.tsv` (kept in sync with
/// `python/compile/aot.py::SPMV_BUCKETS`).
pub const N_BUCKETS: [usize; 7] = [1024, 2048, 4096, 8192, 16384, 32768, 65536];

/// Pick the smallest shipped `n` bucket that fits `n` rows.
pub fn pick_n_bucket(n: usize) -> Option<usize> {
    N_BUCKETS.iter().copied().find(|&b| b >= n)
}

/// Pick the ELL width for a matrix: smallest shipped `k` covering ≥ the
/// `coverage` fraction of rows fully (the rest spill to the COO tail).
pub fn pick_k(a: &CsrMatrix, ks: &[usize], coverage: f64) -> usize {
    let mut row_nnz: Vec<usize> = (0..a.n).map(|i| a.row_nnz(i)).collect();
    row_nnz.sort_unstable();
    let idx = ((coverage * (a.n.saturating_sub(1)) as f64).floor() as usize)
        .min(a.n.saturating_sub(1));
    let need = row_nnz.get(idx).copied().unwrap_or(0);
    for &k in ks {
        if k >= need {
            return k;
        }
    }
    *ks.last().expect("empty k list")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{grounded_laplacian, CsrMatrix};
    use crate::solver::spmv;
    use crate::util::Rng;

    fn ell_matvec_ref(e: &EllMatrix, x: &[f64]) -> Vec<f64> {
        // emulate the XLA kernel in f64 for testing the conversion
        let mut y = vec![0.0; e.n];
        for i in 0..e.n {
            let mut acc = 0.0;
            for s in 0..e.k {
                acc += e.values[i * e.k + s] as f64 * x[e.indices[i * e.k + s] as usize];
            }
            y[i] = acc;
        }
        e.apply_tail(x, &mut y);
        y
    }

    #[test]
    fn conversion_preserves_matvec() {
        let g = crate::gen::hub_graph(300, 2, 150, &mut Rng::new(5));
        let a = grounded_laplacian(&g, 0);
        let k = 8; // hub rows will overflow into the tail
        let e = EllMatrix::from_csr(&a, 1024, k);
        assert!(!e.tail.is_empty(), "hub graph must produce a COO tail at k=8");
        let mut rng = Rng::new(6);
        let mut x = vec![0.0; 1024];
        for v in x.iter_mut().take(a.n) {
            *v = rng.normal();
        }
        let got = ell_matvec_ref(&e, &x);
        let mut want = vec![0.0; a.n];
        spmv(&a, &x[..a.n], &mut want);
        for (u, v) in got.iter().zip(&want) {
            assert!((u - v).abs() < 1e-3 * (1.0 + v.abs()), "{u} vs {v}");
        }
    }

    #[test]
    fn bucket_selection() {
        assert_eq!(pick_n_bucket(100), Some(1024));
        assert_eq!(pick_n_bucket(1024), Some(1024));
        assert_eq!(pick_n_bucket(1025), Some(2048));
        assert_eq!(pick_n_bucket(50_000), Some(65536));
        assert_eq!(pick_n_bucket(20_000), Some(32768));
        assert_eq!(pick_n_bucket(100_000), None);
    }

    #[test]
    fn pick_k_covers_most_rows() {
        // 10 rows of nnz 3, one row of nnz 50
        let mut t = Vec::new();
        for i in 0..10u32 {
            for j in 0..3u32 {
                t.push((i, (i + j) % 11, 1.0));
            }
        }
        for j in 0..50u32 {
            t.push((10, j % 11, 1.0));
        }
        let a = CsrMatrix::from_triplets(11, t);
        let k = pick_k(&a, &[4, 8, 16, 32], 0.9);
        assert_eq!(k, 4);
    }

    #[test]
    fn padding_ratio_sane() {
        let a = CsrMatrix::from_triplets(2, vec![(0, 0, 1.0), (1, 1, 1.0)]);
        let e = EllMatrix::from_csr(&a, 4, 2);
        // 2 nonzeros in 8 slots → 75% padding
        assert!((e.padding_ratio() - 0.75).abs() < 1e-12);
    }
}
