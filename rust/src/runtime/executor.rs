//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute on the
//! request path.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin): artifacts produced by
//! `python/compile/aot.py` are parsed with `HloModuleProto::from_text_file`
//! (text re-assigns instruction ids — the jax≥0.5 / xla_extension 0.5.1
//! compatibility path), compiled once per bucket, and cached. Python never
//! runs here.

use super::ell::EllMatrix;
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

thread_local! {
    /// Per-thread PJRT CPU client (the `xla` crate's client is `Rc`-based,
    /// so it cannot cross threads; the XLA request path is single-threaded
    /// by design — PCG is a sequential recurrence).
    static CLIENT: RefCell<Option<Rc<xla::PjRtClient>>> = const { RefCell::new(None) };
}

/// Get (or create) this thread's PJRT CPU client.
pub fn client() -> anyhow::Result<Rc<xla::PjRtClient>> {
    CLIENT.with(|slot| {
        let mut slot = slot.borrow_mut();
        if let Some(c) = slot.as_ref() {
            return Ok(c.clone());
        }
        let c = Rc::new(
            xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?,
        );
        *slot = Some(c.clone());
        Ok(c)
    })
}

/// Artifact registry: locates `*.hlo.txt` files via `manifest.tsv` and
/// caches compiled executables per file. Single-threaded (PJRT handles in
/// the published `xla` crate are `Rc`-based).
pub struct Runtime {
    dir: PathBuf,
    manifest: Vec<ManifestRow>,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

/// One row of `artifacts/manifest.tsv`.
#[derive(Clone, Debug, PartialEq)]
pub struct ManifestRow {
    /// Artifact kind: `spmv`, `pcg_step`, `jacobi_pcg`.
    pub kind: String,
    /// Row-dimension bucket.
    pub n: usize,
    /// ELL width.
    pub k: usize,
    /// Scan length (jacobi_pcg only; 0 otherwise).
    pub iters: usize,
    /// File name within the artifact dir.
    pub file: String,
}

impl Runtime {
    /// Open the artifact directory (defaults to `$PDGRASS_ARTIFACTS` or
    /// `artifacts/` relative to the workspace root).
    pub fn open_default() -> anyhow::Result<Runtime> {
        let dir = std::env::var("PDGRASS_ARTIFACTS").unwrap_or_else(|_| default_dir());
        Self::open(Path::new(&dir))
    }

    /// Open a specific artifact directory (reads `manifest.tsv`).
    pub fn open(dir: &Path) -> anyhow::Result<Runtime> {
        let manifest_path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} — run `make artifacts` first ({e})",
                manifest_path.display()
            )
        })?;
        let mut manifest = Vec::new();
        for line in text.lines().skip(1) {
            let f: Vec<&str> = line.split('\t').collect();
            if f.len() != 5 {
                continue;
            }
            manifest.push(ManifestRow {
                kind: f[0].to_string(),
                n: f[1].parse()?,
                k: f[2].parse()?,
                iters: f[3].parse()?,
                file: f[4].to_string(),
            });
        }
        anyhow::ensure!(!manifest.is_empty(), "empty manifest at {}", manifest_path.display());
        Ok(Runtime { dir: dir.to_path_buf(), manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// All manifest rows.
    pub fn manifest(&self) -> &[ManifestRow] {
        &self.manifest
    }

    /// Shipped `k` widths for a given kind and n-bucket.
    pub fn ks_for(&self, kind: &str, n_bucket: usize) -> Vec<usize> {
        let mut ks: Vec<usize> = self
            .manifest
            .iter()
            .filter(|r| r.kind == kind && r.n == n_bucket)
            .map(|r| r.k)
            .collect();
        ks.sort_unstable();
        ks
    }

    /// Find the manifest row for `(kind, n, k)`.
    pub fn find(&self, kind: &str, n: usize, k: usize) -> Option<&ManifestRow> {
        self.manifest.iter().find(|r| r.kind == kind && r.n == n && r.k == k)
    }

    /// Compile (or fetch cached) executable for a manifest row.
    pub fn load(&self, row: &ManifestRow) -> anyhow::Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(&row.file) {
            return Ok(e.clone());
        }
        let path = self.dir.join(&row.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client()?
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e:?}", path.display()))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(row.file.clone(), exe.clone());
        Ok(exe)
    }
}

fn default_dir() -> String {
    // workspace root = dir containing Cargo.toml; fall back to ./artifacts
    for base in [".", "..", "../.."] {
        let p = Path::new(base).join("artifacts/manifest.tsv");
        if p.exists() {
            return Path::new(base).join("artifacts").to_string_lossy().into_owned();
        }
    }
    "artifacts".to_string()
}

/// A compiled SpMV bound to one ELL matrix.
///
/// §Perf-L3: the (static) matrix operands are uploaded to **device
/// buffers once** at construction and every `apply` uses `execute_b`, so
/// the per-dispatch traffic is just the `x` vector — uploading the 2·n·k
/// matrix literals per call dominated the dispatch cost before this
/// (measured by `benches/micro.rs`).
pub struct XlaSpmv {
    exe: Rc<xla::PjRtLoadedExecutable>,
    vals_buf: xla::PjRtBuffer,
    idx_buf: xla::PjRtBuffer,
    /// Scratch for the padded f32 input (avoids per-call allocation).
    xpad: RefCell<Vec<f32>>,
    /// The ELL split (owned for the COO tail + dimensions).
    pub ell: EllMatrix,
}

impl XlaSpmv {
    /// Prepare an XLA SpMV for matrix `ell` using runtime `rt`:
    /// compile (cached) + upload the matrix operands to the device.
    pub fn new(rt: &Runtime, ell: EllMatrix) -> anyhow::Result<XlaSpmv> {
        let row = rt
            .find("spmv", ell.n_bucket, ell.k)
            .ok_or_else(|| anyhow::anyhow!("no spmv artifact for n={} k={}", ell.n_bucket, ell.k))?
            .clone();
        let exe = rt.load(&row)?;
        let c = client()?;
        let vals_lit = xla::Literal::vec1(&ell.values)
            .reshape(&[ell.n_bucket as i64, ell.k as i64])
            .map_err(|e| anyhow::anyhow!("reshape values: {e:?}"))?;
        let idx_lit = xla::Literal::vec1(&ell.indices)
            .reshape(&[ell.n_bucket as i64, ell.k as i64])
            .map_err(|e| anyhow::anyhow!("reshape indices: {e:?}"))?;
        let vals_buf = c
            .buffer_from_host_literal(None, &vals_lit)
            .map_err(|e| anyhow::anyhow!("upload values: {e:?}"))?;
        let idx_buf = c
            .buffer_from_host_literal(None, &idx_lit)
            .map_err(|e| anyhow::anyhow!("upload indices: {e:?}"))?;
        // `BufferFromHostLiteral` copies ASYNCHRONOUSLY and the published
        // wrapper exposes no readiness future; fence with a synchronous
        // readback so the source literals (dropped at return) outlive the
        // transfer. One-time cost at preparation.
        vals_buf
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fence values upload: {e:?}"))?;
        idx_buf
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fence indices upload: {e:?}"))?;
        let xpad = RefCell::new(vec![0f32; ell.n_bucket]);
        Ok(XlaSpmv { exe, vals_buf, idx_buf, xpad, ell })
    }

    /// `y = A x` through the compiled Pallas kernel (+ COO tail in Rust).
    /// `x` and `y` are logical-length (`ell.n`) f64 slices.
    pub fn apply(&self, x: &[f64], y: &mut [f64]) -> anyhow::Result<()> {
        assert_eq!(x.len(), self.ell.n);
        assert_eq!(y.len(), self.ell.n);
        let c = client()?;
        let x_buf = {
            let mut xpad = self.xpad.borrow_mut();
            for (i, &v) in x.iter().enumerate() {
                xpad[i] = v as f32;
            }
            c.buffer_from_host_buffer(&xpad[..], &[self.ell.n_bucket], None)
                .map_err(|e| anyhow::anyhow!("upload x: {e:?}"))?
        };
        let result = self
            .exe
            .execute_b(&[&self.vals_buf, &self.idx_buf, &x_buf])
            .map_err(|e| anyhow::anyhow!("execute spmv: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        let yv: Vec<f32> = out.to_vec().map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
        for i in 0..self.ell.n {
            y[i] = yv[i] as f64;
        }
        self.ell.apply_tail(x, y);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing() {
        let dir = std::env::temp_dir().join("pdgrass_rt_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.tsv"),
            "kind\tn\tk\titers\tfile\nspmv\t1024\t8\t0\tspmv_n1024_k8.hlo.txt\n",
        )
        .unwrap();
        let rt = Runtime::open(&dir).unwrap();
        assert_eq!(rt.manifest().len(), 1);
        assert_eq!(rt.ks_for("spmv", 1024), vec![8]);
        assert!(rt.find("spmv", 1024, 8).is_some());
        assert!(rt.find("spmv", 1024, 16).is_none());
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        match Runtime::open(Path::new("/nonexistent/dir")) {
            Ok(_) => panic!("expected error"),
            Err(e) => assert!(e.to_string().contains("make artifacts")),
        }
    }
}
