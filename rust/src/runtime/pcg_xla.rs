//! PCG with the SpMV hot path executed by the compiled Pallas kernel.
//!
//! Two entry points:
//!
//! * [`pcg_xla`] — the paper's quality evaluation on the XLA path: the
//!   outer PCG loop (and the sparsifier LDLᵀ preconditioner solve) stay in
//!   Rust f64, while every `L_G·p` dispatches the AOT-compiled ELL kernel
//!   (f32). Cross-validated against `solver::pcg` in
//!   `rust/tests/xla_parity.rs`.
//! * [`jacobi_pcg_xla`] — fully self-contained: one PJRT dispatch runs a
//!   whole `lax.scan` of Jacobi-PCG iterations and returns the residual
//!   history (used by the end-to-end demo and as the L2-fusion perf
//!   reference).

use super::ell::{pick_k, pick_n_bucket, EllMatrix};
use super::executor::{Runtime, XlaSpmv};
use crate::graph::CsrMatrix;
use crate::solver::pcg::{PcgResult, Preconditioner};
use crate::solver::spmv::{axpy, dot, norm2};

/// Build the [`XlaSpmv`] for a matrix, picking shipped buckets.
pub fn prepare_spmv(rt: &Runtime, a: &CsrMatrix) -> anyhow::Result<XlaSpmv> {
    let n_bucket = pick_n_bucket(a.n)
        .ok_or_else(|| anyhow::anyhow!("matrix n={} exceeds largest artifact bucket", a.n))?;
    let ks = rt.ks_for("spmv", n_bucket);
    anyhow::ensure!(!ks.is_empty(), "no spmv artifacts for n-bucket {n_bucket}");
    let k = pick_k(a, &ks, 0.85);
    let ell = EllMatrix::from_csr(a, n_bucket, k);
    XlaSpmv::new(rt, ell)
}

/// PCG solving `A x = b` with preconditioner `m`; the SpMV runs on the
/// XLA/Pallas path. Semantics match [`crate::solver::pcg::pcg`].
pub fn pcg_xla<M: Preconditioner>(
    rt: &Runtime,
    a: &CsrMatrix,
    b: &[f64],
    m: &M,
    tol: f64,
    maxit: usize,
) -> anyhow::Result<PcgResult> {
    let spmv = prepare_spmv(rt, a)?;
    let n = a.n;
    let bnorm = norm2(b).max(f64::MIN_POSITIVE);
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut z = vec![0.0; n];
    m.apply(&r, &mut z);
    let mut p = z.clone();
    let mut rz = dot(&r, &z);
    let mut ap = vec![0.0; n];
    let mut history = Vec::new();
    let mut relres = norm2(&r) / bnorm;
    if relres <= tol {
        return Ok(PcgResult { x, iterations: 0, relres, converged: true, history });
    }
    for it in 1..=maxit {
        spmv.apply(&p, &mut ap)?;
        let pap = dot(&p, &ap);
        if pap <= 0.0 || !pap.is_finite() {
            return Ok(PcgResult { x, iterations: it - 1, relres, converged: false, history });
        }
        let alpha = rz / pap;
        axpy(alpha, &p, &mut x);
        axpy(-alpha, &ap, &mut r);
        relres = norm2(&r) / bnorm;
        history.push(relres);
        if relres <= tol {
            return Ok(PcgResult { x, iterations: it, relres, converged: true, history });
        }
        m.apply(&r, &mut z);
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz;
        rz = rz_new;
        for i in 0..n {
            p[i] = z[i] + beta * p[i];
        }
    }
    Ok(PcgResult { x, iterations: maxit, relres, converged: false, history })
}

/// Run the scan-fused Jacobi-PCG artifact: a single PJRT dispatch performs
/// the whole fixed-length iteration. Returns `(x, relres_history)`.
pub fn jacobi_pcg_xla(
    rt: &Runtime,
    a: &CsrMatrix,
    b: &[f64],
) -> anyhow::Result<(Vec<f64>, Vec<f64>)> {
    let max_nnz = (0..a.n).map(|i| a.row_nnz(i)).max().unwrap_or(0);
    let row = rt
        .manifest()
        .iter()
        .filter(|r| r.kind == "jacobi_pcg" && r.n >= a.n && r.k >= max_nnz)
        .min_by_key(|r| (r.n, r.k))
        .ok_or_else(|| {
            anyhow::anyhow!(
                "no jacobi_pcg artifact fits n={} with k ≥ {max_nnz} \
                 (the scan-fused path has no COO tail; use pcg_xla instead)",
                a.n
            )
        })?;
    let ell = EllMatrix::from_csr(a, row.n, row.k);
    debug_assert!(ell.tail.is_empty());
    let exe = rt.load(row)?;
    let nb = row.n;
    let diag = a.diagonal();
    // Padded rows: inv_diag = 1.0 and b = 0 keeps them inert (r ≡ 0).
    let mut inv_diag = vec![1f32; nb];
    for (i, &d) in diag.iter().enumerate() {
        inv_diag[i] = (1.0 / d) as f32;
    }
    let mut bpad = vec![0f32; nb];
    for (i, &v) in b.iter().enumerate() {
        bpad[i] = v as f32;
    }
    let vals_lit = xla::Literal::vec1(&ell.values)
        .reshape(&[nb as i64, row.k as i64])
        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))?;
    let idx_lit = xla::Literal::vec1(&ell.indices)
        .reshape(&[nb as i64, row.k as i64])
        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))?;
    let d_lit = xla::Literal::vec1(&inv_diag);
    let b_lit = xla::Literal::vec1(&bpad);
    let x0_lit = xla::Literal::vec1(&vec![0f32; nb]);
    let result = exe
        .execute(&[&vals_lit, &idx_lit, &d_lit, &b_lit, &x0_lit])
        .map_err(|e| anyhow::anyhow!("execute jacobi_pcg: {e:?}"))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("fetch: {e:?}"))?;
    let (x_lit, hist_lit) =
        result.to_tuple2().map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
    let x32: Vec<f32> = x_lit.to_vec().map_err(|e| anyhow::anyhow!("x: {e:?}"))?;
    let h32: Vec<f32> = hist_lit.to_vec().map_err(|e| anyhow::anyhow!("hist: {e:?}"))?;
    Ok((
        x32[..a.n].iter().map(|&v| v as f64).collect(),
        h32.iter().map(|&v| v as f64).collect(),
    ))
}

/// Iterations to reach `tol` according to a residual history (1-based);
/// `None` if never reached.
pub fn iterations_to_tol(history: &[f64], tol: f64) -> Option<usize> {
    history.iter().position(|&r| r <= tol).map(|i| i + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iterations_to_tol_finds_first() {
        let h = [0.5, 0.1, 0.01, 0.001, 0.0001];
        assert_eq!(iterations_to_tol(&h, 1e-2), Some(3));
        assert_eq!(iterations_to_tol(&h, 1e-9), None);
        assert_eq!(iterations_to_tol(&h, 0.5), Some(1));
    }
}
