//! Runtime layer: PJRT client wrapper, ELL conversion/buckets, and the
//! XLA-backed PCG paths executing the AOT-compiled Pallas kernel.

pub mod ell;
pub mod executor;
pub mod pcg_xla;

pub use ell::{pick_k, pick_n_bucket, EllMatrix};
pub use executor::{client, ManifestRow, Runtime, XlaSpmv};
pub use pcg_xla::{iterations_to_tol, jacobi_pcg_xla, pcg_xla, prepare_spmv};
