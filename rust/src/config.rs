//! Configuration: a minimal TOML-subset parser + the typed run config.
//!
//! The offline vendor set has no `serde`/`toml`, so this module implements
//! the subset the launcher needs: `[sections]`, `key = value` with
//! strings, integers, floats, booleans, and flat arrays. Unknown keys are
//! reported as errors (catching config typos), matching what a production
//! launcher would do. All failures are the typed [`crate::error::Error`]
//! ([`Error::Config`] for malformed files, [`Error::BadParam`] for values
//! that parse but fail validation).

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::graph::Relabel;
use crate::recovery::{Pipeline, Strategy};
use crate::session::RecoverOpts;

/// A parsed TOML-subset value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// Quoted string.
    Str(String),
    /// Integer.
    Int(i64),
    /// Float.
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// Flat array of values.
    Array(Vec<Value>),
}

impl Value {
    /// As f64 (ints widen).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// As usize.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    /// As str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: `section.key → value` (top-level keys use `""`).
#[derive(Clone, Debug, Default)]
pub struct Doc {
    entries: HashMap<String, Value>,
}

impl Doc {
    /// Parse a TOML-subset string.
    pub fn parse(text: &str) -> Result<Doc> {
        let mut entries = HashMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(Error::Config(format!("line {}: bad section header", lineno + 1)));
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected key = value", lineno + 1))
            })?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let value = parse_value(v.trim())
                .map_err(|e| Error::Config(format!("line {}: {e}", lineno + 1)))?;
            entries.insert(key, value);
        }
        Ok(Doc { entries })
    }

    /// Load from a file.
    pub fn load(path: &std::path::Path) -> Result<Doc> {
        Doc::parse(&std::fs::read_to_string(path)?)
    }

    /// Fetch a value by dotted key.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    /// All keys (sorted, for validation).
    pub fn keys(&self) -> Vec<&str> {
        let mut ks: Vec<&str> = self.entries.keys().map(|s| s.as_str()).collect();
        ks.sort_unstable();
        ks
    }
}

/// Cut a trailing `# comment` off a line, ignoring `#` characters inside
/// quoted strings (`graphs = ["a#b"]  # real comment`).
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> std::result::Result<Value, String> {
    if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
        return Ok(Value::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') && s.ends_with(']') {
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let p = part.trim();
            if !p.is_empty() {
                items.push(parse_value(p)?);
            }
        }
        return Ok(Value::Array(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value: {s:?}"))
}

fn split_top_level(s: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut cur = String::new();
    let mut in_str = false;
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth -= 1;
                cur.push(c);
            }
            ',' if depth == 0 && !in_str => {
                parts.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        parts.push(cur);
    }
    parts
}

/// Typed experiment configuration (maps onto
/// [`crate::coordinator::PipelineConfig`] plus run selection, and onto
/// [`RecoverOpts`] via [`RunConfig::recover_opts`]).
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// α values to sweep.
    pub alphas: Vec<f64>,
    /// Suite rows to run (names); empty = all 18.
    pub graphs: Vec<String>,
    /// Suite scale factor.
    pub scale: f64,
    /// Seed.
    pub seed: u64,
    /// PCG tolerance.
    pub tol: f64,
    /// PCG iteration cap.
    pub maxit: usize,
    /// Timing trials.
    pub trials: usize,
    /// Evaluate PCG quality.
    pub quality: bool,
    /// Recovery threads (0 = auto: `par::num_threads()`).
    pub threads: usize,
    /// Step-4 parallel strategy.
    pub strategy: Strategy,
    /// BFS step-size constant `c` (Def. 3).
    pub beta_cap: u32,
    /// Shard size for `strategy = "sharded"` (must be ≥ 1).
    pub shard_min: usize,
    /// Stage-handoff discipline (`"barrier"` or `"streamed"`) applied to
    /// both preparation and recovery.
    pub pipeline: Pipeline,
    /// Vertex-locality relabeling (`"none"`, `"bfs"`, or `"degree"`)
    /// applied at prepare time; outputs stay in original ids.
    pub relabel: Relabel,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            alphas: vec![0.02, 0.05, 0.10],
            graphs: Vec::new(),
            scale: 1.0,
            seed: crate::gen::DEFAULT_SEED,
            tol: 1e-3,
            maxit: 50_000,
            trials: 3,
            quality: true,
            threads: 0,
            strategy: Strategy::Mixed,
            beta_cap: 8,
            shard_min: 4096,
            pipeline: Pipeline::Barrier,
            relabel: Relabel::None,
        }
    }
}

impl RunConfig {
    /// Build from a parsed document (`[run]` section), validating keys
    /// and values.
    pub fn from_doc(doc: &Doc) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        let known = [
            "run.alphas", "run.graphs", "run.scale", "run.seed", "run.tol", "run.maxit",
            "run.trials", "run.quality", "run.threads", "run.strategy", "run.beta_cap",
            "run.shard_min", "run.pipeline", "run.relabel",
        ];
        for key in doc.keys() {
            // `audit.*` belongs to `analysis::AuditOptions` and `serve.*`
            // to [`ServeConfig`]; one config file may carry all three
            // sections.
            if !known.contains(&key) && !key.starts_with("audit.") && !key.starts_with("serve.") {
                return Err(Error::Config(format!("unknown config key: {key}")));
            }
        }
        if let Some(Value::Array(items)) = doc.get("run.alphas") {
            cfg.alphas = items
                .iter()
                .map(|i| {
                    i.as_f64().ok_or_else(|| Error::BadParam {
                        name: "run.alphas",
                        why: "not a number".into(),
                    })
                })
                .collect::<Result<_>>()?;
            if let Some(&bad) = cfg.alphas.iter().find(|a| !a.is_finite() || **a <= 0.0) {
                return Err(Error::BadParam {
                    name: "run.alphas",
                    why: format!("alphas must be positive, got {bad}"),
                });
            }
        }
        if let Some(Value::Array(items)) = doc.get("run.graphs") {
            cfg.graphs = items
                .iter()
                .map(|i| {
                    i.as_str().map(|s| s.to_string()).ok_or_else(|| Error::BadParam {
                        name: "run.graphs",
                        why: "not a string".into(),
                    })
                })
                .collect::<Result<_>>()?;
        }
        if let Some(v) = doc.get("run.scale") {
            cfg.scale = v
                .as_f64()
                .ok_or_else(|| Error::BadParam { name: "run.scale", why: "not a number".into() })?;
            if !cfg.scale.is_finite() || cfg.scale <= 0.0 {
                return Err(Error::BadParam {
                    name: "run.scale",
                    why: format!("must be positive, got {}", cfg.scale),
                });
            }
        }
        if let Some(v) = doc.get("run.seed") {
            cfg.seed = v
                .as_usize()
                .ok_or_else(|| Error::BadParam { name: "run.seed", why: "not an int".into() })?
                as u64;
        }
        if let Some(v) = doc.get("run.tol") {
            cfg.tol = v
                .as_f64()
                .ok_or_else(|| Error::BadParam { name: "run.tol", why: "not a number".into() })?;
            if !cfg.tol.is_finite() || cfg.tol <= 0.0 {
                return Err(Error::BadParam {
                    name: "run.tol",
                    why: format!("must be positive, got {}", cfg.tol),
                });
            }
        }
        if let Some(v) = doc.get("run.maxit") {
            cfg.maxit = v
                .as_usize()
                .ok_or_else(|| Error::BadParam { name: "run.maxit", why: "not an int".into() })?;
        }
        if let Some(v) = doc.get("run.trials") {
            cfg.trials = v
                .as_usize()
                .ok_or_else(|| Error::BadParam { name: "run.trials", why: "not an int".into() })?;
            if cfg.trials == 0 {
                return Err(Error::BadParam {
                    name: "run.trials",
                    why: "must be at least 1".into(),
                });
            }
        }
        if let Some(v) = doc.get("run.quality") {
            cfg.quality = v
                .as_bool()
                .ok_or_else(|| Error::BadParam { name: "run.quality", why: "not a bool".into() })?;
        }
        if let Some(v) = doc.get("run.threads") {
            cfg.threads = v.as_usize().ok_or_else(|| Error::BadParam {
                name: "run.threads",
                why: "not a non-negative int".into(),
            })?;
        }
        if let Some(v) = doc.get("run.strategy") {
            let s = v.as_str().ok_or_else(|| Error::BadParam {
                name: "run.strategy",
                why: "not a string".into(),
            })?;
            cfg.strategy = s.parse()?;
        }
        if let Some(v) = doc.get("run.beta_cap") {
            let b = v.as_usize().ok_or_else(|| Error::BadParam {
                name: "run.beta_cap",
                why: "not a non-negative int".into(),
            })?;
            cfg.beta_cap = u32::try_from(b).map_err(|_| Error::BadParam {
                name: "run.beta_cap",
                why: format!("{b} exceeds u32 range"),
            })?;
        }
        if let Some(v) = doc.get("run.shard_min") {
            cfg.shard_min = v.as_usize().ok_or_else(|| Error::BadParam {
                name: "run.shard_min",
                why: "not a non-negative int".into(),
            })?;
            if cfg.shard_min == 0 {
                return Err(Error::BadParam {
                    name: "run.shard_min",
                    why: "must be at least 1".into(),
                });
            }
        }
        if let Some(v) = doc.get("run.pipeline") {
            let s = v.as_str().ok_or_else(|| Error::BadParam {
                name: "run.pipeline",
                why: "not a string".into(),
            })?;
            cfg.pipeline = s.parse()?;
        }
        if let Some(v) = doc.get("run.relabel") {
            let s = v.as_str().ok_or_else(|| Error::BadParam {
                name: "run.relabel",
                why: "not a string".into(),
            })?;
            cfg.relabel = s.parse()?;
        }
        Ok(cfg)
    }

    /// Convert into a pipeline config.
    pub fn pipeline(&self) -> crate::coordinator::PipelineConfig {
        crate::coordinator::PipelineConfig {
            alpha: self.alphas.first().copied().unwrap_or(0.02),
            beta_cap: self.beta_cap,
            tol: self.tol,
            maxit: self.maxit,
            scale: self.scale,
            seed: self.seed,
            trials: self.trials,
            evaluate_quality: self.quality,
            pipeline: self.pipeline,
            relabel: self.relabel,
            ..Default::default()
        }
    }

    /// Recovery options at `alpha` per this config: `threads`/`strategy`/
    /// `beta_cap`/`shard_min`/`pipeline` map straight onto
    /// [`RecoverOpts`] (`threads == 0` resolves to the environment's
    /// thread count). Range validation happens when the options are used
    /// against a graph ([`RecoverOpts::validate`]).
    pub fn recover_opts(&self, alpha: f64) -> RecoverOpts {
        let threads = self.resolved_threads();
        RecoverOpts {
            alpha,
            beta_cap: self.beta_cap,
            strategy: self.strategy,
            shard_min: self.shard_min,
            pipeline: self.pipeline,
            ..RecoverOpts::with_threads(alpha, threads)
        }
    }

    /// The run's thread count with `0` (auto) resolved to the
    /// environment's [`crate::par::num_threads`] — the value the session
    /// builders ([`crate::Sparsify::threads`]) and thus the PCG
    /// evaluation path should be handed, matching what
    /// [`RunConfig::recover_opts`] resolves for recovery.
    pub fn resolved_threads(&self) -> usize {
        if self.threads == 0 {
            crate::par::num_threads()
        } else {
            self.threads
        }
    }
}

/// Typed `[serve]` section for the daemon (`pdgrass serve`); see
/// [`crate::serve`] for the subsystem it configures.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Unix-domain socket path the daemon binds.
    pub socket: std::path::PathBuf,
    /// Max resident [`crate::Prepared`] states (LRU beyond this; ≥ 1).
    pub cache_capacity: usize,
    /// Admission cap: concurrent compute requests before typed
    /// `Overloaded` rejection (≥ 1).
    pub max_in_flight: usize,
    /// Default per-request deadline, ms (0 = none; requests may carry
    /// their own `deadline_ms`).
    pub deadline_ms: u64,
    /// Consecutive prepare failures per graph spec before fast-rejection
    /// (0 = unlimited).
    pub failure_cap: u32,
    /// Summary-log sink: `"stderr"`, `"off"`, or a file path.
    pub log: String,
    /// Default worker threads per request (0 = auto:
    /// [`crate::par::num_threads`]).
    pub threads: usize,
    /// Cross-process warm-start directory: cache misses first try to
    /// load `<fingerprint>.pdsnap` from here ([`crate::snapshot`]), and
    /// successful prepares are written back, so a restarted daemon
    /// answers its first request without re-running Algorithm-1 steps
    /// 1–3. `None` (default) disables snapshotting.
    pub snapshot_dir: Option<std::path::PathBuf>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            socket: std::path::PathBuf::from("/tmp/pdgrass.sock"),
            cache_capacity: 8,
            max_in_flight: 4,
            deadline_ms: 0,
            failure_cap: 3,
            log: "stderr".to_string(),
            threads: 0,
            snapshot_dir: None,
        }
    }
}

impl ServeConfig {
    /// Build from a parsed document (`[serve]` section), validating keys
    /// and values. Other sections (`run.*`, `audit.*`, top-level) are
    /// ignored so one file can configure the whole binary.
    pub fn from_doc(doc: &Doc) -> Result<ServeConfig> {
        let mut cfg = ServeConfig::default();
        let known = [
            "serve.socket", "serve.cache_capacity", "serve.max_in_flight", "serve.deadline_ms",
            "serve.failure_cap", "serve.log", "serve.threads", "serve.snapshot_dir",
        ];
        for key in doc.keys() {
            if key.starts_with("serve.") && !known.contains(&key) {
                return Err(Error::Config(format!("unknown config key: {key}")));
            }
        }
        if let Some(v) = doc.get("serve.socket") {
            let s = v.as_str().ok_or_else(|| Error::BadParam {
                name: "serve.socket",
                why: "not a string".into(),
            })?;
            cfg.socket = std::path::PathBuf::from(s);
        }
        if let Some(v) = doc.get("serve.cache_capacity") {
            cfg.cache_capacity = v.as_usize().ok_or_else(|| Error::BadParam {
                name: "serve.cache_capacity",
                why: "not a non-negative int".into(),
            })?;
            if cfg.cache_capacity == 0 {
                return Err(Error::BadParam {
                    name: "serve.cache_capacity",
                    why: "must be at least 1".into(),
                });
            }
        }
        if let Some(v) = doc.get("serve.max_in_flight") {
            cfg.max_in_flight = v.as_usize().ok_or_else(|| Error::BadParam {
                name: "serve.max_in_flight",
                why: "not a non-negative int".into(),
            })?;
            if cfg.max_in_flight == 0 {
                return Err(Error::BadParam {
                    name: "serve.max_in_flight",
                    why: "must be at least 1".into(),
                });
            }
        }
        if let Some(v) = doc.get("serve.deadline_ms") {
            cfg.deadline_ms = v.as_usize().ok_or_else(|| Error::BadParam {
                name: "serve.deadline_ms",
                why: "not a non-negative int".into(),
            })? as u64;
        }
        if let Some(v) = doc.get("serve.failure_cap") {
            let f = v.as_usize().ok_or_else(|| Error::BadParam {
                name: "serve.failure_cap",
                why: "not a non-negative int".into(),
            })?;
            cfg.failure_cap = u32::try_from(f).map_err(|_| Error::BadParam {
                name: "serve.failure_cap",
                why: format!("{f} exceeds u32 range"),
            })?;
        }
        if let Some(v) = doc.get("serve.log") {
            cfg.log = v
                .as_str()
                .ok_or_else(|| Error::BadParam { name: "serve.log", why: "not a string".into() })?
                .to_string();
        }
        if let Some(v) = doc.get("serve.threads") {
            cfg.threads = v.as_usize().ok_or_else(|| Error::BadParam {
                name: "serve.threads",
                why: "not a non-negative int".into(),
            })?;
        }
        if let Some(v) = doc.get("serve.snapshot_dir") {
            let s = v.as_str().ok_or_else(|| Error::BadParam {
                name: "serve.snapshot_dir",
                why: "not a string".into(),
            })?;
            if s.is_empty() {
                return Err(Error::BadParam {
                    name: "serve.snapshot_dir",
                    why: "must be a non-empty path".into(),
                });
            }
            cfg.snapshot_dir = Some(std::path::PathBuf::from(s));
        }
        Ok(cfg)
    }

    /// The daemon's default thread count with `0` (auto) resolved to the
    /// environment's [`crate::par::num_threads`].
    pub fn resolved_threads(&self) -> usize {
        if self.threads == 0 {
            crate::par::num_threads()
        } else {
            self.threads
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = Doc::parse(
            "# comment\ntop = 1\n[run]\nscale = 0.5\nseed = 42\nquality = true\n\
             graphs = [\"01-mi2010\", \"15-M6\"]\nalphas = [0.02, 0.05]\n",
        )
        .unwrap();
        assert_eq!(doc.get("top"), Some(&Value::Int(1)));
        assert_eq!(doc.get("run.scale"), Some(&Value::Float(0.5)));
        assert_eq!(doc.get("run.quality"), Some(&Value::Bool(true)));
        match doc.get("run.graphs") {
            Some(Value::Array(items)) => assert_eq!(items.len(), 2),
            other => panic!("bad graphs: {other:?}"),
        }
    }

    #[test]
    fn run_config_roundtrip() {
        let doc = Doc::parse(
            "[run]\nalphas = [0.1]\nscale = 0.25\nseed = 7\ntol = 0.001\nmaxit = 100\n\
             trials = 1\nquality = false\ngraphs = [\"15-M6\"]\nthreads = 4\n\
             strategy = \"sharded\"\nbeta_cap = 6\nshard_min = 512\n",
        )
        .unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.alphas, vec![0.1]);
        assert_eq!(cfg.scale, 0.25);
        assert_eq!(cfg.seed, 7);
        assert!(!cfg.quality);
        assert_eq!(cfg.graphs, vec!["15-M6"]);
        assert_eq!(cfg.threads, 4);
        assert_eq!(cfg.strategy, Strategy::Sharded);
        assert_eq!(cfg.beta_cap, 6);
        assert_eq!(cfg.shard_min, 512);
        let p = cfg.pipeline();
        assert_eq!(p.alpha, 0.1);
        assert_eq!(p.trials, 1);
        assert_eq!(p.beta_cap, 6);
        let opts = cfg.recover_opts(0.1);
        assert_eq!(opts.threads, 4);
        assert_eq!(opts.block, 4);
        assert_eq!(opts.strategy, Strategy::Sharded);
        assert_eq!(opts.beta_cap, 6);
        assert_eq!(opts.shard_min, 512);
    }

    #[test]
    fn pipeline_key_round_trips_and_rejects_garbage() {
        let doc = Doc::parse("[run]\npipeline = \"streamed\"\n").unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.pipeline, Pipeline::Streamed);
        assert_eq!(cfg.recover_opts(0.05).pipeline, Pipeline::Streamed);
        // default is barrier
        let cfg = RunConfig::from_doc(&Doc::parse("[run]\n").unwrap()).unwrap();
        assert_eq!(cfg.pipeline, Pipeline::Barrier);
        assert_eq!(cfg.recover_opts(0.05).pipeline, Pipeline::Barrier);
        // unknown spellings are typed errors naming the field
        let doc = Doc::parse("[run]\npipeline = \"overlap\"\n").unwrap();
        match RunConfig::from_doc(&doc) {
            Err(Error::BadParam { name, .. }) => assert_eq!(name, "pipeline"),
            other => panic!("expected BadParam, got {other:?}"),
        }
        // non-string values are rejected
        let doc = Doc::parse("[run]\npipeline = 3\n").unwrap();
        match RunConfig::from_doc(&doc) {
            Err(Error::BadParam { name, .. }) => assert_eq!(name, "run.pipeline"),
            other => panic!("expected BadParam, got {other:?}"),
        }
    }

    #[test]
    fn relabel_key_round_trips_and_rejects_garbage() {
        let doc = Doc::parse("[run]\nrelabel = \"bfs\"\n").unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.relabel, Relabel::Bfs);
        assert_eq!(cfg.pipeline().relabel, Relabel::Bfs);
        // default is none
        let cfg = RunConfig::from_doc(&Doc::parse("[run]\n").unwrap()).unwrap();
        assert_eq!(cfg.relabel, Relabel::None);
        assert_eq!(cfg.pipeline().relabel, Relabel::None);
        // unknown spellings are typed errors naming the field
        let doc = Doc::parse("[run]\nrelabel = \"hilbert\"\n").unwrap();
        match RunConfig::from_doc(&doc) {
            Err(Error::BadParam { name, .. }) => assert_eq!(name, "relabel"),
            other => panic!("expected BadParam, got {other:?}"),
        }
        // non-string values are rejected
        let doc = Doc::parse("[run]\nrelabel = 1\n").unwrap();
        match RunConfig::from_doc(&doc) {
            Err(Error::BadParam { name, .. }) => assert_eq!(name, "run.relabel"),
            other => panic!("expected BadParam, got {other:?}"),
        }
    }

    #[test]
    fn shard_min_zero_rejected() {
        let doc = Doc::parse("[run]\nshard_min = 0\n").unwrap();
        match RunConfig::from_doc(&doc) {
            Err(Error::BadParam { name, .. }) => assert_eq!(name, "run.shard_min"),
            other => panic!("expected BadParam, got {other:?}"),
        }
        // default survives when the key is absent
        let cfg = RunConfig::from_doc(&Doc::parse("[run]\n").unwrap()).unwrap();
        assert_eq!(cfg.shard_min, 4096);
        assert_eq!(cfg.recover_opts(0.05).shard_min, 4096);
    }

    #[test]
    fn threads_zero_resolves_to_auto() {
        let cfg = RunConfig::default();
        let opts = cfg.recover_opts(0.05);
        assert!(opts.threads >= 1);
        assert_eq!(opts.block, opts.threads);
    }

    #[test]
    fn unknown_key_rejected() {
        let doc = Doc::parse("[run]\nspeeling_mistake = 1\n").unwrap();
        let err = RunConfig::from_doc(&doc).unwrap_err();
        assert!(err.to_string().contains("unknown config key"), "{err}");
    }

    #[test]
    fn audit_section_keys_are_ignored_by_run_config() {
        let doc =
            Doc::parse("[run]\nscale = 0.5\n[audit]\nroot = \"rust/src\"\n").unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.scale, 0.5);
    }

    #[test]
    fn serve_config_roundtrip_and_defaults() {
        let doc = Doc::parse(
            "[serve]\nsocket = \"/tmp/s.sock\"\ncache_capacity = 2\nmax_in_flight = 3\n\
             deadline_ms = 500\nfailure_cap = 1\nlog = \"off\"\nthreads = 4\n",
        )
        .unwrap();
        let cfg = ServeConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.socket, std::path::PathBuf::from("/tmp/s.sock"));
        assert_eq!(cfg.cache_capacity, 2);
        assert_eq!(cfg.max_in_flight, 3);
        assert_eq!(cfg.deadline_ms, 500);
        assert_eq!(cfg.failure_cap, 1);
        assert_eq!(cfg.log, "off");
        assert_eq!(cfg.threads, 4);
        assert_eq!(cfg.resolved_threads(), 4);

        let d = ServeConfig::default();
        assert_eq!(d.cache_capacity, 8);
        assert_eq!(d.max_in_flight, 4);
        assert_eq!(d.deadline_ms, 0);
        assert!(d.resolved_threads() >= 1);
        assert_eq!(d.snapshot_dir, None);
    }

    #[test]
    fn serve_snapshot_dir_round_trips_and_validates() {
        let doc = Doc::parse("[serve]\nsnapshot_dir = \"/tmp/snaps\"\n").unwrap();
        let cfg = ServeConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.snapshot_dir, Some(std::path::PathBuf::from("/tmp/snaps")));
        // Absent key → disabled.
        let cfg = ServeConfig::from_doc(&Doc::parse("[serve]\n").unwrap()).unwrap();
        assert_eq!(cfg.snapshot_dir, None);
        // Wrong type and empty string are typed errors naming the key.
        let doc = Doc::parse("[serve]\nsnapshot_dir = 3\n").unwrap();
        match ServeConfig::from_doc(&doc) {
            Err(Error::BadParam { name, .. }) => assert_eq!(name, "serve.snapshot_dir"),
            other => panic!("expected BadParam, got {other:?}"),
        }
        let doc = Doc::parse("[serve]\nsnapshot_dir = \"\"\n").unwrap();
        match ServeConfig::from_doc(&doc) {
            Err(Error::BadParam { name, .. }) => assert_eq!(name, "serve.snapshot_dir"),
            other => panic!("expected BadParam, got {other:?}"),
        }
    }

    #[test]
    fn serve_config_validates() {
        let doc = Doc::parse("[serve]\ncache_capacity = 0\n").unwrap();
        match ServeConfig::from_doc(&doc) {
            Err(Error::BadParam { name, .. }) => assert_eq!(name, "serve.cache_capacity"),
            other => panic!("expected BadParam, got {other:?}"),
        }
        let doc = Doc::parse("[serve]\nmax_in_flight = 0\n").unwrap();
        match ServeConfig::from_doc(&doc) {
            Err(Error::BadParam { name, .. }) => assert_eq!(name, "serve.max_in_flight"),
            other => panic!("expected BadParam, got {other:?}"),
        }
        let doc = Doc::parse("[serve]\nspeeling = 1\n").unwrap();
        assert!(ServeConfig::from_doc(&doc).is_err());
        // Non-serve sections pass through untouched.
        let doc = Doc::parse("[run]\nscale = 0.5\n[serve]\nlog = \"off\"\n").unwrap();
        assert_eq!(ServeConfig::from_doc(&doc).unwrap().log, "off");
        assert_eq!(RunConfig::from_doc(&doc).unwrap().scale, 0.5);
    }

    #[test]
    fn serve_section_keys_are_ignored_by_run_config() {
        let doc = Doc::parse("[run]\nscale = 0.5\n[serve]\ncache_capacity = 2\n").unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.scale, 0.5);
    }

    #[test]
    fn bad_strategy_rejected_with_typed_error() {
        let doc = Doc::parse("[run]\nstrategy = \"warp\"\n").unwrap();
        match RunConfig::from_doc(&doc) {
            Err(Error::BadParam { name, .. }) => assert_eq!(name, "strategy"),
            other => panic!("expected BadParam, got {other:?}"),
        }
    }

    #[test]
    fn bad_value_errors() {
        assert!(Doc::parse("x = @nope\n").is_err());
        assert!(Doc::parse("[broken\nx = 1\n").is_err());
    }

    #[test]
    fn hash_inside_quoted_string_is_not_a_comment() {
        // regression: the old strip_comment truncated at any '#'
        let doc = Doc::parse(
            "[run]\ngraphs = [\"a#b\", \"c\"]  # trailing comment\nscale = 0.5 # another\n",
        )
        .unwrap();
        match doc.get("run.graphs") {
            Some(Value::Array(items)) => {
                assert_eq!(items.len(), 2);
                assert_eq!(items[0].as_str(), Some("a#b"));
                assert_eq!(items[1].as_str(), Some("c"));
            }
            other => panic!("bad graphs: {other:?}"),
        }
        assert_eq!(doc.get("run.scale"), Some(&Value::Float(0.5)));
    }

    #[test]
    fn strip_comment_is_string_aware() {
        assert_eq!(strip_comment("x = 1 # c"), "x = 1 ");
        assert_eq!(strip_comment("s = \"a#b\""), "s = \"a#b\"");
        assert_eq!(strip_comment("s = \"a#b\" # c"), "s = \"a#b\" ");
        assert_eq!(strip_comment("# whole line"), "");
        assert_eq!(strip_comment("plain"), "plain");
    }
}
