//! Opt-in locality relabeling: permute vertex ids at session ingest so
//! the whole Algorithm-1 pipeline — CSR walks, tree BFS, SpMV — touches
//! memory in a cache-friendlier order on giant graphs.
//!
//! A permutation is represented as `perm[new] = old` (the convention of
//! [`crate::solver::order::rcm`]). The session applies it **once** at
//! [`crate::session::Sparsify::prepare`]: the pipeline then runs entirely
//! in the permuted id space, and the session maps the final sparsifier's
//! endpoints back through `perm` so callers only ever see original ids
//! (PCG evaluation in particular runs in the original space — floating
//! point is not permutation-invariant, so evaluating in permuted space
//! would change residual histories).
//!
//! # Equivariance
//!
//! Both modes assign new id 0 to the graph's canonical root
//! ([`Graph::max_degree_vertex`] — smallest id among the maximum-degree
//! vertices), so the relabeled pipeline roots its spanning tree at the
//! *same original vertex*. Effective weights (Def. 1) are a closed-form
//! per-edge formula over integer BFS hop counts and degrees — bitwise
//! permutation-invariant — and resistance scores follow the tree, so on
//! inputs whose effective weights and criticality scores are tie-free
//! (ties break by edge id, which relabeling reorders) the recovered edge
//! set and the PCG iteration count match the unrelabeled run exactly.

use super::csr::Graph;
use crate::error::{Error, Result};

/// Vertex relabeling mode applied at session ingest (default: none).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Relabel {
    /// Keep the input ids (the historical behavior; bit-for-bit inert).
    #[default]
    None,
    /// BFS order from the max-degree vertex: neighbors in CSR order,
    /// unreached components appended in ascending first-vertex order.
    /// Tree-heavy walks see mostly-sequential ids.
    Bfs,
    /// Degree order, descending (stable: equal-degree vertices keep
    /// ascending id order). Hub rows cluster at the front, which is what
    /// the cache-blocked SpMV's heavy-row tiling likes.
    Degree,
}

impl Relabel {
    /// True for [`Relabel::None`] — no permutation is materialized.
    pub fn is_none(self) -> bool {
        self == Relabel::None
    }
}

impl std::str::FromStr for Relabel {
    type Err = Error;

    /// Parse a mode name (case-insensitive): `none`, `bfs`, or `degree`
    /// — the config-file / CLI spelling.
    fn from_str(s: &str) -> Result<Relabel> {
        match s.to_ascii_lowercase().as_str() {
            "none" => Ok(Relabel::None),
            "bfs" => Ok(Relabel::Bfs),
            "degree" => Ok(Relabel::Degree),
            _ => Err(Error::BadParam {
                name: "relabel",
                why: format!("unknown relabel mode {s:?} (expected none|bfs|degree)"),
            }),
        }
    }
}

impl std::fmt::Display for Relabel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Relabel::None => "none",
            Relabel::Bfs => "bfs",
            Relabel::Degree => "degree",
        })
    }
}

/// The `mode` permutation for `g`, as `perm[new] = old`; `None` for
/// [`Relabel::None`] (no permutation is materialized, so the inert mode
/// costs nothing). Deterministic: depends only on the graph.
pub fn relabel_perm(g: &Graph, mode: Relabel) -> Option<Vec<u32>> {
    match mode {
        Relabel::None => None,
        Relabel::Bfs => Some(bfs_perm(g)),
        Relabel::Degree => Some(degree_perm(g)),
    }
}

/// BFS order from [`Graph::max_degree_vertex`]; any vertices BFS cannot
/// reach (disconnected inputs) are appended by restarting from the
/// smallest unvisited id, so the result is always a full permutation.
fn bfs_perm(g: &Graph) -> Vec<u32> {
    let n = g.num_vertices();
    if n == 0 {
        return Vec::new();
    }
    let mut order: Vec<u32> = Vec::with_capacity(n);
    let mut seen = vec![false; n];
    let mut bfs_from = |start: u32, order: &mut Vec<u32>, seen: &mut Vec<bool>| {
        seen[start as usize] = true;
        let mut head = order.len();
        order.push(start);
        while head < order.len() {
            let u = order[head];
            head += 1;
            for &v in g.neighbor_ids(u) {
                if !seen[v as usize] {
                    seen[v as usize] = true;
                    order.push(v);
                }
            }
        }
    };
    bfs_from(g.max_degree_vertex(), &mut order, &mut seen);
    for v in 0..n as u32 {
        if !seen[v as usize] {
            bfs_from(v, &mut order, &mut seen);
        }
    }
    order
}

/// Degree-descending order; the sort is stable so equal-degree vertices
/// keep ascending id order (and new id 0 is exactly
/// [`Graph::max_degree_vertex`]).
fn degree_perm(g: &Graph) -> Vec<u32> {
    let mut ids: Vec<u32> = (0..g.num_vertices() as u32).collect();
    ids.sort_by_key(|&v| std::cmp::Reverse(g.degree(v)));
    ids
}

/// Inverse of `perm[new] = old`: `inv[old] = new`. Caller guarantees
/// `perm` is a bijection (see [`validate_perm`]).
pub fn invert_perm(perm: &[u32]) -> Vec<u32> {
    let mut inv = vec![0u32; perm.len()];
    for (new, &old) in perm.iter().enumerate() {
        inv[old as usize] = new as u32;
    }
    inv
}

/// Check that `perm` is a bijection on `0..n` — the snapshot decoder's
/// gate before trusting a deserialized permutation.
pub fn validate_perm(perm: &[u32], n: usize) -> Result<()> {
    if perm.len() != n {
        return Err(Error::BadParam {
            name: "perm",
            why: format!("length {} does not match vertex count {n}", perm.len()),
        });
    }
    let mut seen = vec![false; n];
    for &old in perm {
        if old as usize >= n || seen[old as usize] {
            return Err(Error::BadParam {
                name: "perm",
                why: format!("not a bijection on 0..{n}: entry {old} out of range or repeated"),
            });
        }
        seen[old as usize] = true;
    }
    Ok(())
}

/// `g` rewritten into the permuted id space: original vertex `perm[i]`
/// becomes vertex `i`. Weights pass through untouched and the CSR is
/// rebuilt canonically, so the result is exactly the graph a caller
/// would have built had they numbered their vertices this way.
pub fn apply_perm(g: &Graph, perm: &[u32]) -> Graph {
    let inv = invert_perm(perm);
    let edges: Vec<(u32, u32, f64)> =
        g.edges().iter().map(|e| (inv[e.u as usize], inv[e.v as usize], e.w)).collect();
    Graph::from_edges(g.num_vertices(), &edges)
}

/// Inverse of [`apply_perm`]: a graph living in the permuted id space
/// mapped back to original ids. `unapply_perm(&apply_perm(g, p), p)` is
/// bitwise identical to `g` (weights untouched, CSR canonical).
pub fn unapply_perm(g: &Graph, perm: &[u32]) -> Graph {
    let edges: Vec<(u32, u32, f64)> =
        g.edges().iter().map(|e| (perm[e.u as usize], perm[e.v as usize], e.w)).collect();
    Graph::from_edges(g.num_vertices(), &edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_graph(seed: u64) -> Graph {
        crate::gen::community(
            crate::gen::CommunityParams {
                n: 400,
                mean_size: 9.0,
                tail: 1.7,
                intra_p: 0.5,
                bridges: 2,
                max_size: 60,
            },
            &mut Rng::new(seed),
        )
    }

    #[test]
    fn parses_all_spellings() {
        for (s, want) in [
            ("none", Relabel::None),
            ("NONE", Relabel::None),
            ("bfs", Relabel::Bfs),
            ("Bfs", Relabel::Bfs),
            ("degree", Relabel::Degree),
            ("DEGREE", Relabel::Degree),
        ] {
            assert_eq!(s.parse::<Relabel>().unwrap(), want, "{s}");
            assert_eq!(want.to_string().parse::<Relabel>().unwrap(), want);
        }
        assert!("rcm".parse::<Relabel>().is_err());
        assert_eq!(Relabel::default(), Relabel::None);
        assert!(Relabel::None.is_none() && !Relabel::Bfs.is_none());
    }

    #[test]
    fn perms_are_bijections() {
        crate::util::proptest::check_default("relabel_bijection", |rng: &mut Rng| {
            let g = crate::gen::community(
                crate::gen::CommunityParams {
                    n: 50 + rng.below(300),
                    mean_size: 8.0,
                    tail: 1.6,
                    intra_p: 0.4,
                    bridges: 1,
                    max_size: 40,
                },
                rng,
            );
            for mode in [Relabel::Bfs, Relabel::Degree] {
                let perm = relabel_perm(&g, mode).unwrap();
                validate_perm(&perm, g.num_vertices())
                    .map_err(|e| format!("{mode}: {e}"))?;
                let inv = invert_perm(&perm);
                for (new, &old) in perm.iter().enumerate() {
                    if inv[old as usize] as usize != new {
                        return Err(format!("{mode}: invert mismatch at new={new}"));
                    }
                }
            }
            assert!(relabel_perm(&g, Relabel::None).is_none());
            Ok(())
        });
    }

    #[test]
    fn both_modes_put_the_canonical_root_first() {
        let g = random_graph(11);
        for mode in [Relabel::Bfs, Relabel::Degree] {
            let perm = relabel_perm(&g, mode).unwrap();
            assert_eq!(perm[0], g.max_degree_vertex(), "{mode}");
        }
    }

    #[test]
    fn degree_perm_descends_with_stable_ties() {
        let g = random_graph(3);
        let perm = relabel_perm(&g, Relabel::Degree).unwrap();
        for w in perm.windows(2) {
            let (da, db) = (g.degree(w[0]), g.degree(w[1]));
            assert!(da > db || (da == db && w[0] < w[1]), "order violated at {w:?}");
        }
    }

    #[test]
    fn bfs_perm_covers_disconnected_graphs() {
        // Two components: a triangle and a path. BFS starts in the
        // triangle (max degree) and must restart to cover the path.
        let g = Graph::from_edges(
            6,
            &[(0, 1, 1.0), (1, 2, 1.0), (0, 2, 1.0), (3, 4, 1.0), (4, 5, 1.0)],
        );
        let perm = relabel_perm(&g, Relabel::Bfs).unwrap();
        validate_perm(&perm, 6).unwrap();
        // The triangle occupies the first three slots, the path the rest.
        let first: std::collections::BTreeSet<u32> = perm[..3].iter().copied().collect();
        assert_eq!(first, [0u32, 1, 2].into_iter().collect());
    }

    #[test]
    fn validate_perm_rejects_non_bijections() {
        assert!(validate_perm(&[0, 1, 2], 3).is_ok());
        assert!(validate_perm(&[0, 1], 3).is_err(), "wrong length");
        assert!(validate_perm(&[0, 1, 1], 3).is_err(), "repeated entry");
        assert!(validate_perm(&[0, 1, 3], 3).is_err(), "out of range");
    }

    #[test]
    fn apply_unapply_round_trips_bitwise() {
        let g = random_graph(7);
        for mode in [Relabel::Bfs, Relabel::Degree] {
            let perm = relabel_perm(&g, mode).unwrap();
            let permuted = apply_perm(&g, &perm);
            assert_eq!(permuted.num_vertices(), g.num_vertices());
            assert_eq!(permuted.num_edges(), g.num_edges());
            let back = unapply_perm(&permuted, &perm);
            assert_eq!(
                crate::graph::fingerprint(&back),
                crate::graph::fingerprint(&g),
                "{mode}: round trip changed the graph"
            );
            // Bitwise: identical edge lists, not just equal fingerprints.
            for (a, b) in back.edges().iter().zip(g.edges()) {
                assert_eq!((a.u, a.v), (b.u, b.v));
                assert_eq!(a.w.to_bits(), b.w.to_bits());
            }
        }
    }

    #[test]
    fn relabeling_changes_the_fingerprint_but_preserves_structure() {
        let g = random_graph(9);
        let perm = relabel_perm(&g, Relabel::Bfs).unwrap();
        let permuted = apply_perm(&g, &perm);
        // Degrees are preserved as a multiset.
        let mut dg: Vec<usize> = (0..g.num_vertices() as u32).map(|v| g.degree(v)).collect();
        let mut dp: Vec<usize> =
            (0..permuted.num_vertices() as u32).map(|v| permuted.degree(v)).collect();
        dg.sort_unstable();
        dp.sort_unstable();
        assert_eq!(dg, dp);
        assert_eq!(permuted.max_degree_vertex(), 0, "root must map to new id 0");
    }
}
