//! Deterministic graph fingerprints — the serving layer's cache key.
//!
//! The daemon (`crate::serve`) caches one `Prepared` state per distinct
//! input graph, so the key must be a pure function of the graph
//! *content* and byte-stable across platforms and compilations:
//! [`fingerprint`] is 64-bit FNV-1a over an explicit little-endian
//! encoding of the CSR arrays. Nothing here depends on pointer values,
//! `HashMap` iteration order, or the platform's endianness — the same
//! graph hashes to the same digest on every machine, so a fleet of
//! daemons (or a daemon and its clients) can agree on keys without
//! exchanging the graphs themselves.
//!
//! The encoding hashes, in order: `|V|` and `|E|` (as `u64` LE), then
//! for each vertex its CSR row — degree (`u64` LE) followed by each
//! neighbor id (`u32` LE) and edge weight (IEEE-754 bit pattern as
//! `u64` LE) in CSR slot order. CSR slot order is itself deterministic
//! (rows are filled from the canonically sorted unique edge list), so
//! two graphs built from the same edge multiset — in any input order —
//! fingerprint identically, while any change to a vertex count,
//! endpoint, or weight bit changes the digest.

use super::Graph;

/// Incremental 64-bit FNV-1a hasher over explicit byte encodings.
///
/// Kept public because the serving layer reuses it for response
/// checksums (e.g. the recover response's `edges_hash`); use the
/// `write_*` helpers so every integer is committed little-endian.
#[derive(Clone, Debug)]
pub struct Fnv1a(u64);

/// FNV-1a 64-bit offset basis.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

impl Fnv1a {
    /// Fresh hasher at the FNV-1a offset basis.
    pub fn new() -> Fnv1a {
        Fnv1a(FNV_OFFSET)
    }

    /// Absorb raw bytes.
    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
    }

    /// Absorb a `u32` as little-endian bytes.
    pub fn write_u32(&mut self, v: u32) {
        self.write(&v.to_le_bytes());
    }

    /// Absorb a `u64` as little-endian bytes.
    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    /// The digest so far.
    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// Byte-stable content hash of a graph: FNV-1a over the little-endian
/// CSR encoding described in the module docs. Pure function of the
/// graph content; identical across platforms, processes, and input edge
/// orderings (construction canonicalizes the edge list).
pub fn fingerprint(g: &Graph) -> u64 {
    let n = g.num_vertices();
    let mut h = Fnv1a::new();
    h.write_u64(n as u64);
    h.write_u64(g.num_edges() as u64);
    for u in 0..n as u32 {
        h.write_u64(g.degree(u) as u64);
        for (v, w, _eid) in g.neighbors(u) {
            h.write_u32(v);
            h.write_u64(w.to_bits());
        }
    }
    h.finish()
}

/// Canonical hex rendering of a fingerprint (`0x` + 16 lowercase hex
/// digits) — the wire form used by the serve protocol.
pub fn fingerprint_hex(fp: u64) -> String {
    format!("0x{fp:016x}")
}

/// Parse the canonical hex rendering back to a fingerprint. Accepts the
/// `0x` prefix optionally; rejects anything that is not pure hex.
pub fn parse_fingerprint(s: &str) -> Option<u64> {
    let digits = s.strip_prefix("0x").unwrap_or(s);
    if digits.is_empty() || digits.len() > 16 {
        return None;
    }
    u64::from_str_radix(digits, 16).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> Graph {
        Graph::from_edges(3, &[(0, 1, 1.0), (1, 2, 2.0), (2, 0, 3.0)])
    }

    /// Pin the exact digest of a known small graph. The expected values
    /// were computed independently (FNV-1a over the documented LE byte
    /// stream); any change to the encoding, the hash constants, or CSR
    /// construction order breaks this test — which is the point: cached
    /// `Prepared` state keyed by fingerprint must never silently re-key
    /// across versions or platforms.
    #[test]
    fn digest_is_pinned_for_known_graphs() {
        assert_eq!(fingerprint(&triangle()), 0x2b4d_ac9c_d7c1_de97);
        let path2 = Graph::from_edges(2, &[(0, 1, 1.5)]);
        assert_eq!(fingerprint(&path2), 0xeeb2_ed3d_af25_0bf7);
    }

    #[test]
    fn input_edge_order_does_not_matter() {
        let a = triangle();
        let b = Graph::from_edges(3, &[(2, 0, 3.0), (0, 1, 1.0), (2, 1, 2.0)]);
        assert_eq!(fingerprint(&a), fingerprint(&b));
    }

    #[test]
    fn content_changes_change_the_digest() {
        let base = fingerprint(&triangle());
        // One weight bit different.
        let w = Graph::from_edges(3, &[(0, 1, 1.0), (1, 2, 2.0), (2, 0, 3.0000000001)]);
        assert_ne!(fingerprint(&w), base);
        // Same edges, one extra isolated-vertex slot... is rejected by
        // prepare anyway, but must still hash differently.
        let n4 = Graph::from_edges(4, &[(0, 1, 1.0), (1, 2, 2.0), (2, 0, 3.0)]);
        assert_ne!(fingerprint(&n4), base);
        // Different topology, same counts.
        let star = Graph::from_edges(3, &[(0, 1, 1.0), (0, 2, 2.0)]);
        let path = Graph::from_edges(3, &[(0, 1, 1.0), (1, 2, 2.0)]);
        assert_ne!(fingerprint(&star), fingerprint(&path));
    }

    #[test]
    fn hex_roundtrip() {
        let fp = fingerprint(&triangle());
        let hex = fingerprint_hex(fp);
        assert!(hex.starts_with("0x") && hex.len() == 18, "{hex}");
        assert_eq!(parse_fingerprint(&hex), Some(fp));
        assert_eq!(parse_fingerprint("2b4dac9cd7c1de97"), Some(0x2b4d_ac9c_d7c1_de97));
        assert_eq!(parse_fingerprint(""), None);
        assert_eq!(parse_fingerprint("0x"), None);
        assert_eq!(parse_fingerprint("0xnope"), None);
        assert_eq!(parse_fingerprint("0x12345678123456781"), None);
    }

    #[test]
    fn fnv_helpers_match_bytewise_absorption() {
        let mut a = Fnv1a::new();
        a.write_u32(0x0403_0201);
        a.write_u64(0x0c0b_0a09_0807_0605);
        let mut b = Fnv1a::new();
        b.write(&[1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12]);
        assert_eq!(a.finish(), b.finish());
        assert_eq!(Fnv1a::new().finish(), 0xcbf2_9ce4_8422_2325);
    }
}
