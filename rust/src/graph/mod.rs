//! Graph substrate: CSR graphs, MatrixMarket I/O, connectivity, Laplacians.

pub mod connect;
pub mod csr;
pub mod laplacian;
pub mod mmio;

pub use connect::{components, is_connected, largest_component};
pub use csr::{Edge, Graph};
pub use laplacian::{grounded_laplacian, laplacian, CsrMatrix};
pub use mmio::{read_mtx, write_mtx};
