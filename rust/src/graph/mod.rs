//! Graph substrate: CSR graphs, MatrixMarket I/O, connectivity, Laplacians.

pub mod connect;
pub mod csr;
pub mod fingerprint;
pub mod laplacian;
pub mod mmio;
pub mod relabel;

pub use connect::{components, is_connected, largest_component};
pub use csr::{Edge, Graph};
pub use fingerprint::{fingerprint, fingerprint_hex, parse_fingerprint, Fnv1a};
pub use laplacian::{grounded_laplacian, laplacian, CsrMatrix};
pub use mmio::{read_mtx, write_mtx};
pub use relabel::{apply_perm, invert_perm, relabel_perm, unapply_perm, validate_perm, Relabel};
