//! Weighted undirected graph in CSR form plus a canonical edge list.
//!
//! This is the substrate every stage of the pipeline consumes: spanning
//! tree generation (BFS over CSR), off-tree edge recovery (edge list), and
//! Laplacian assembly (CSR).
//!
//! Row offsets are compact `u32` (`xadj`): any graph with
//! `2|E| + 1 < u32::MAX` CSR slots fits, which halves index traffic in the
//! BFS/SpMV hot loops relative to `usize` offsets. Construction is checked —
//! [`Graph::try_from_edges`] returns the typed
//! [`Error::IndexOverflow`](crate::error::Error::IndexOverflow) beyond the
//! u32 range instead of silently truncating.

use crate::error::{Error, Result};

/// Edge-count cutoff above which [`Graph::from_edges`] dispatches the
/// canonical `(u, v)` sort to the pool. Duplicate `(u, v)` keys are merged
/// by summing immediately after the sort, so even for equal keys the
/// output is independent of which stable order the sort produced — the
/// parallel path is bitwise equal to the serial one.
const PAR_SORT_CUTOFF: usize = 1 << 15;

/// An undirected weighted edge with canonical orientation `u < v`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Edge {
    /// Smaller endpoint.
    pub u: u32,
    /// Larger endpoint.
    pub v: u32,
    /// Positive weight (conductance, in the electrical-network reading).
    pub w: f64,
}

/// Weighted undirected graph: CSR adjacency + unique edge list.
#[derive(Clone, Debug)]
pub struct Graph {
    /// Vertex count.
    n: usize,
    /// CSR row offsets, length `n + 1`, compact `u32` (construction
    /// rejects graphs with `2|E| + 1 ≥ u32::MAX` slots).
    xadj: Vec<u32>,
    /// CSR neighbor ids, length `2|E|`.
    adj: Vec<u32>,
    /// CSR edge weights, parallel to `adj`.
    wgt: Vec<f64>,
    /// For each CSR slot, index of the undirected edge in `edges`.
    eid: Vec<u32>,
    /// Unique undirected edges, canonical `u < v`.
    edges: Vec<Edge>,
}

impl Graph {
    /// Build a graph from an undirected edge list.
    ///
    /// Self loops are dropped; parallel edges are merged by *summing*
    /// weights (conductances in parallel add). Weights must be positive
    /// and finite. Panics if the CSR slot count overflows the compact
    /// u32 index space — use [`Graph::try_from_edges`] for a typed error.
    pub fn from_edges(n: usize, raw: &[(u32, u32, f64)]) -> Graph {
        Self::try_from_edges(n, raw).expect("graph exceeds u32 index space")
    }

    /// As [`Graph::from_edges`], but returns the typed
    /// [`Error::IndexOverflow`] when the vertex count or the CSR slot
    /// count (`2|E| + 1`) does not fit the compact u32 row offsets,
    /// instead of panicking. Malformed *edges* (out-of-range endpoints,
    /// non-positive weights) still panic: those are caller bugs, not
    /// input-scale limits.
    pub fn try_from_edges(n: usize, raw: &[(u32, u32, f64)]) -> Result<Graph> {
        if n > u32::MAX as usize {
            return Err(Error::IndexOverflow { what: "vertex count", needed: n as u64 });
        }
        let mut canon: Vec<Edge> = Vec::with_capacity(raw.len());
        for &(a, b, w) in raw {
            assert!((a as usize) < n && (b as usize) < n, "edge endpoint out of range");
            assert!(w.is_finite() && w > 0.0, "edge weight must be positive and finite");
            if a == b {
                continue; // self loop: no effect on the Laplacian off-diagonal
            }
            let (u, v) = if a < b { (a, b) } else { (b, a) };
            canon.push(Edge { u, v, w });
        }
        // Merge duplicates: sort by (u, v), sum weights. The sort is
        // stable either way, so duplicate runs keep input order and the
        // weight sums are bitwise identical serial vs. pooled.
        if canon.len() >= PAR_SORT_CUTOFF {
            crate::par::sort::par_sort_by(&mut canon, crate::par::num_threads(), &|x, y| {
                (x.u, x.v).cmp(&(y.u, y.v))
            });
        } else {
            canon.sort_by(|x, y| (x.u, x.v).cmp(&(y.u, y.v)));
        }
        let mut edges: Vec<Edge> = Vec::with_capacity(canon.len());
        for e in canon {
            match edges.last_mut() {
                Some(last) if last.u == e.u && last.v == e.v => last.w += e.w,
                _ => edges.push(e),
            }
        }
        let slots = 2 * edges.len() as u64 + 1;
        if slots >= u32::MAX as u64 {
            return Err(Error::IndexOverflow { what: "CSR slots", needed: slots });
        }
        Ok(Self::from_unique_edges(n, edges))
    }

    /// Build from edges already unique + canonical (`u < v`, no loops).
    pub fn from_unique_edges(n: usize, edges: Vec<Edge>) -> Graph {
        let m = edges.len();
        assert!(n <= u32::MAX as usize, "vertex count exceeds u32 index space");
        assert!(2 * m as u64 + 1 < u32::MAX as u64, "CSR slots exceed u32 index space");
        let mut deg = vec![0u32; n];
        for e in &edges {
            deg[e.u as usize] += 1;
            deg[e.v as usize] += 1;
        }
        let mut xadj = vec![0u32; n + 1];
        for i in 0..n {
            xadj[i + 1] = xadj[i] + deg[i];
        }
        let mut adj = vec![0u32; 2 * m];
        let mut wgt = vec![0f64; 2 * m];
        let mut eid = vec![0u32; 2 * m];
        let mut cursor = xadj.clone();
        for (k, e) in edges.iter().enumerate() {
            let cu = cursor[e.u as usize] as usize;
            adj[cu] = e.v;
            wgt[cu] = e.w;
            eid[cu] = k as u32;
            cursor[e.u as usize] += 1;
            let cv = cursor[e.v as usize] as usize;
            adj[cv] = e.u;
            wgt[cv] = e.w;
            eid[cv] = k as u32;
            cursor[e.v as usize] += 1;
        }
        Graph { n, xadj, adj, wgt, eid, edges }
    }

    /// Vertex count |V|.
    pub fn num_vertices(&self) -> usize {
        self.n
    }

    /// Undirected edge count |E|.
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Degree of vertex `u` (number of incident unique edges).
    pub fn degree(&self, u: u32) -> usize {
        (self.xadj[u as usize + 1] - self.xadj[u as usize]) as usize
    }

    /// Weighted degree (sum of incident weights) — the Laplacian diagonal.
    pub fn weighted_degree(&self, u: u32) -> f64 {
        let (s, e) = (self.xadj[u as usize] as usize, self.xadj[u as usize + 1] as usize);
        self.wgt[s..e].iter().sum()
    }

    /// Vertex of maximum degree (ties → smallest id). Used as BFS root for
    /// the effective-weight computation (Definition 1).
    pub fn max_degree_vertex(&self) -> u32 {
        (0..self.n as u32)
            .max_by_key(|&u| (self.degree(u), std::cmp::Reverse(u)))
            .expect("empty graph")
    }

    /// Neighbors of `u` with weights: iterator of `(v, w, edge_id)`.
    pub fn neighbors(&self, u: u32) -> impl Iterator<Item = (u32, f64, u32)> + '_ {
        let (s, e) = (self.xadj[u as usize] as usize, self.xadj[u as usize + 1] as usize);
        (s..e).map(move |i| (self.adj[i], self.wgt[i], self.eid[i]))
    }

    /// Neighbor ids only (fast path for BFS).
    pub fn neighbor_ids(&self, u: u32) -> &[u32] {
        &self.adj[self.xadj[u as usize] as usize..self.xadj[u as usize + 1] as usize]
    }

    /// All unique undirected edges.
    pub fn edges(&self) -> &[Edge] {
        &self.edges
    }

    /// Edge by id.
    pub fn edge(&self, id: u32) -> Edge {
        self.edges[id as usize]
    }

    /// Total weight of all edges.
    pub fn total_weight(&self) -> f64 {
        self.edges.iter().map(|e| e.w).sum()
    }

    /// Average degree `2|E| / |V|`.
    pub fn avg_degree(&self) -> f64 {
        2.0 * self.num_edges() as f64 / self.n.max(1) as f64
    }

    /// Maximum degree.
    pub fn max_degree(&self) -> usize {
        (0..self.n as u32).map(|u| self.degree(u)).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> Graph {
        Graph::from_edges(3, &[(0, 1, 1.0), (1, 2, 2.0), (2, 0, 3.0)])
    }

    #[test]
    fn csr_roundtrip() {
        let g = triangle();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.degree(0), 2);
        let mut nbrs: Vec<u32> = g.neighbor_ids(1).to_vec();
        nbrs.sort();
        assert_eq!(nbrs, vec![0, 2]);
    }

    #[test]
    fn merges_parallel_edges_and_drops_loops() {
        let g = Graph::from_edges(3, &[(0, 1, 1.0), (1, 0, 2.5), (2, 2, 9.0), (1, 2, 1.0)]);
        assert_eq!(g.num_edges(), 2);
        let e = g.edges()[0];
        assert_eq!((e.u, e.v), (0, 1));
        assert!((e.w - 3.5).abs() < 1e-12);
    }

    #[test]
    fn weighted_degree_matches() {
        let g = triangle();
        assert!((g.weighted_degree(0) - 4.0).abs() < 1e-12);
        assert!((g.weighted_degree(1) - 3.0).abs() < 1e-12);
        assert!((g.weighted_degree(2) - 5.0).abs() < 1e-12);
        assert!((g.total_weight() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn max_degree_vertex_breaks_ties_low() {
        let g = triangle();
        assert_eq!(g.max_degree_vertex(), 0); // all degree 2, lowest id wins
        let star = Graph::from_edges(4, &[(3, 0, 1.0), (3, 1, 1.0), (3, 2, 1.0)]);
        assert_eq!(star.max_degree_vertex(), 3);
    }

    #[test]
    fn edge_ids_consistent_in_csr() {
        let g = triangle();
        for u in 0..3u32 {
            for (v, w, id) in g.neighbors(u) {
                let e = g.edge(id);
                assert!(e.u == u.min(v) && e.v == u.max(v));
                assert_eq!(e.w, w);
            }
        }
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn rejects_nonpositive_weight() {
        Graph::from_edges(2, &[(0, 1, 0.0)]);
    }

    #[test]
    fn try_from_edges_rejects_oversized_vertex_count() {
        // The check fires before any O(n) allocation, so an absurd n is a
        // cheap test.
        let err = Graph::try_from_edges(u32::MAX as usize + 1, &[]).unwrap_err();
        match err {
            crate::error::Error::IndexOverflow { what, needed } => {
                assert_eq!(what, "vertex count");
                assert_eq!(needed, u32::MAX as u64 + 1);
            }
            other => panic!("expected IndexOverflow, got {other}"),
        }
    }

    #[test]
    fn try_from_edges_matches_from_edges() {
        let raw = [(0u32, 1u32, 1.0), (1, 0, 2.5), (2, 2, 9.0), (1, 2, 1.0)];
        let a = Graph::from_edges(3, &raw);
        let b = Graph::try_from_edges(3, &raw).unwrap();
        assert_eq!(a.num_edges(), b.num_edges());
        for (x, y) in a.edges().iter().zip(b.edges()) {
            assert_eq!((x.u, x.v), (y.u, y.v));
            assert_eq!(x.w.to_bits(), y.w.to_bits());
        }
    }

    #[test]
    fn parallel_canonical_sort_is_bitwise_equal() {
        // Build an edge list well above PAR_SORT_CUTOFF with duplicates so
        // the merge-by-summing path is exercised, and compare against a
        // serially-sorted reference construction.
        let n = 2_000usize;
        let mut rng = crate::util::Rng::new(42);
        let mut raw: Vec<(u32, u32, f64)> = Vec::new();
        for i in 0..n as u32 - 1 {
            raw.push((i, i + 1, 1.0)); // keep it connected
        }
        while raw.len() < super::PAR_SORT_CUTOFF + 10_000 {
            let u = (rng.next_u64() % n as u64) as u32;
            let v = (rng.next_u64() % n as u64) as u32;
            if u != v {
                raw.push((u, v, 0.5 + (rng.next_u64() % 1000) as f64 / 1000.0));
            }
        }
        let par = Graph::from_edges(n, &raw);
        // Serial reference: canonicalize + stable serial sort + merge.
        let mut canon: Vec<Edge> = raw
            .iter()
            .map(|&(a, b, w)| {
                let (u, v) = if a < b { (a, b) } else { (b, a) };
                Edge { u, v, w }
            })
            .collect();
        canon.sort_by(|x, y| (x.u, x.v).cmp(&(y.u, y.v)));
        let mut merged: Vec<Edge> = Vec::new();
        for e in canon {
            match merged.last_mut() {
                Some(last) if last.u == e.u && last.v == e.v => last.w += e.w,
                _ => merged.push(e),
            }
        }
        assert_eq!(par.num_edges(), merged.len());
        for (x, y) in par.edges().iter().zip(&merged) {
            assert_eq!((x.u, x.v), (y.u, y.v));
            assert_eq!(x.w.to_bits(), y.w.to_bits(), "weight sums must be bitwise equal");
        }
    }
}
