//! Connectivity: components, largest-component extraction.
//!
//! The paper's suite uses graphs with a single connected component; the
//! generators occasionally emit stragglers (RMAT), so the suite registry
//! extracts the largest component before use — as the paper does when
//! selecting SuiteSparse matrices.

use super::csr::{Edge, Graph};

/// Label connected components; returns `(labels, count)` with labels in
/// `0..count` assigned in discovery order.
pub fn components(g: &Graph) -> (Vec<u32>, usize) {
    let n = g.num_vertices();
    let mut label = vec![u32::MAX; n];
    let mut count = 0u32;
    let mut stack = Vec::new();
    for s in 0..n as u32 {
        if label[s as usize] != u32::MAX {
            continue;
        }
        label[s as usize] = count;
        stack.push(s);
        while let Some(u) = stack.pop() {
            for &v in g.neighbor_ids(u) {
                if label[v as usize] == u32::MAX {
                    label[v as usize] = count;
                    stack.push(v);
                }
            }
        }
        count += 1;
    }
    (label, count as usize)
}

/// True iff the graph is connected (and non-empty).
pub fn is_connected(g: &Graph) -> bool {
    g.num_vertices() > 0 && components(g).1 == 1
}

/// Extract the largest connected component as a new graph with vertices
/// relabeled compactly (order preserved). Returns the graph and the map
/// `new_id -> old_id`.
pub fn largest_component(g: &Graph) -> (Graph, Vec<u32>) {
    let n = g.num_vertices();
    let (label, count) = components(g);
    if count <= 1 {
        return (g.clone(), (0..n as u32).collect());
    }
    let mut size = vec![0usize; count];
    for &l in &label {
        size[l as usize] += 1;
    }
    let big = (0..count).max_by_key(|&c| size[c]).unwrap() as u32;
    let mut old_of_new = Vec::with_capacity(size[big as usize]);
    let mut new_of_old = vec![u32::MAX; n];
    for v in 0..n as u32 {
        if label[v as usize] == big {
            new_of_old[v as usize] = old_of_new.len() as u32;
            old_of_new.push(v);
        }
    }
    let edges: Vec<Edge> = g
        .edges()
        .iter()
        .filter(|e| label[e.u as usize] == big)
        .map(|e| Edge { u: new_of_old[e.u as usize], v: new_of_old[e.v as usize], w: e.w })
        .collect();
    (Graph::from_unique_edges(old_of_new.len(), edges), old_of_new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_component() {
        let g = Graph::from_edges(3, &[(0, 1, 1.0), (1, 2, 1.0)]);
        assert!(is_connected(&g));
        let (labels, c) = components(&g);
        assert_eq!(c, 1);
        assert!(labels.iter().all(|&l| l == 0));
    }

    #[test]
    fn counts_components() {
        // {0,1}, {2,3,4}, {5}
        let g = Graph::from_edges(6, &[(0, 1, 1.0), (2, 3, 1.0), (3, 4, 1.0)]);
        let (_, c) = components(&g);
        assert_eq!(c, 3);
        assert!(!is_connected(&g));
    }

    #[test]
    fn extracts_largest() {
        let g = Graph::from_edges(6, &[(0, 1, 1.0), (2, 3, 2.0), (3, 4, 3.0)]);
        let (cc, old) = largest_component(&g);
        assert_eq!(cc.num_vertices(), 3);
        assert_eq!(cc.num_edges(), 2);
        assert_eq!(old, vec![2, 3, 4]);
        // weights preserved
        assert!((cc.total_weight() - 5.0).abs() < 1e-12);
        assert!(is_connected(&cc));
    }

    #[test]
    fn connected_graph_identity() {
        let g = Graph::from_edges(4, &[(0, 1, 1.0), (1, 2, 1.0), (2, 3, 1.0)]);
        let (cc, old) = largest_component(&g);
        assert_eq!(cc.num_vertices(), 4);
        assert_eq!(old, vec![0, 1, 2, 3]);
    }
}
