//! MatrixMarket I/O for graphs.
//!
//! The paper's datasets come from the SuiteSparse Matrix Collection as
//! `.mtx` files (symmetric coordinate matrices read as undirected graphs).
//! This reader accepts `matrix coordinate (real|pattern|integer) symmetric
//! |general` headers; pattern matrices get weight 1.0 (the suite registry
//! then assigns random weights in [1, 10] as the paper does). A `general`
//! file stores *both* triangles, so each undirected edge usually appears
//! twice — as (i,j) and (j,i); the reader collapses those mirror pairs
//! (averaging the two triangles, i.e. reading `(A + Aᵀ)/2`) instead of
//! letting the duplicate double every edge weight. The writer emits
//! `coordinate real symmetric`, lower-triangular entries.

use super::csr::Graph;
use crate::util::FxHashMap;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// Parse a MatrixMarket file into a graph. Off-diagonal entries become
/// undirected edges with `w = |value|`; diagonal entries are ignored.
pub fn read_mtx(path: &Path) -> anyhow::Result<Graph> {
    let f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("open {}: {e}", path.display()))?;
    read_mtx_from(BufReader::new(f))
}

/// Parse MatrixMarket content from any reader.
pub fn read_mtx_from<R: BufRead>(mut r: R) -> anyhow::Result<Graph> {
    let mut line = String::new();
    r.read_line(&mut line)?;
    let header = line.trim().to_ascii_lowercase();
    if !header.starts_with("%%matrixmarket matrix coordinate") {
        anyhow::bail!("unsupported MatrixMarket header: {header}");
    }
    let pattern = header.contains("pattern");
    let general = header.contains("general");
    if header.contains("complex") {
        anyhow::bail!("complex matrices unsupported");
    }
    // Skip comments.
    let dims = loop {
        line.clear();
        if r.read_line(&mut line)? == 0 {
            anyhow::bail!("missing size line");
        }
        let t = line.trim();
        if !t.is_empty() && !t.starts_with('%') {
            break t.to_string();
        }
    };
    let mut it = dims.split_whitespace();
    let nrows: usize = it.next().ok_or_else(|| anyhow::anyhow!("bad size line"))?.parse()?;
    let ncols: usize = it.next().ok_or_else(|| anyhow::anyhow!("bad size line"))?.parse()?;
    let nnz: usize = it.next().ok_or_else(|| anyhow::anyhow!("bad size line"))?.parse()?;
    if nrows != ncols {
        anyhow::bail!("matrix not square: {nrows}x{ncols}");
    }
    let mut raw: Vec<(u32, u32, f64)> = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        line.clear();
        if r.read_line(&mut line)? == 0 {
            anyhow::bail!("truncated entries");
        }
        let t = line.trim();
        if t.is_empty() {
            anyhow::bail!("blank entry line");
        }
        let mut it = t.split_whitespace();
        let i: usize = it.next().ok_or_else(|| anyhow::anyhow!("bad entry"))?.parse()?;
        let j: usize = it.next().ok_or_else(|| anyhow::anyhow!("bad entry"))?.parse()?;
        let w: f64 = if pattern {
            1.0
        } else {
            it.next().ok_or_else(|| anyhow::anyhow!("missing value"))?.parse()?
        };
        if i == 0 || j == 0 || i > nrows || j > nrows {
            anyhow::bail!("entry out of range: ({i}, {j})");
        }
        if i != j {
            let w = w.abs(); // Laplacian off-diagonals are stored negative
            if w > 0.0 {
                raw.push((i as u32 - 1, j as u32 - 1, w));
            }
        }
    }
    if general {
        raw = dedup_general(raw);
    }
    Ok(Graph::from_edges(nrows, &raw))
}

/// Collapse the two triangles of a `general` coordinate file.
///
/// A symmetric matrix stored as `general` lists every off-diagonal entry
/// twice — (i,j) and (j,i). `Graph::from_edges` merges duplicates by
/// *summing* (parallel conductances), which would silently double every
/// edge weight, so mirror pairs are combined here first: per canonical
/// pair, sum each triangle's contributions and divide by the number of
/// triangles present — `(A + Aᵀ)/2` — which also reads one-sided
/// (genuinely asymmetric) entries at face value. Genuine parallel entries
/// *within* one triangle still sum.
fn dedup_general(raw: Vec<(u32, u32, f64)>) -> Vec<(u32, u32, f64)> {
    // value: [lower-triangle sum, upper-triangle sum], NaN = side absent.
    let mut acc: FxHashMap<(u32, u32), [f64; 2]> = FxHashMap::default();
    for (i, j, w) in raw {
        let key = (i.min(j), i.max(j));
        let side = usize::from(i < j);
        let sides = acc.entry(key).or_insert([f64::NAN; 2]);
        if sides[side].is_nan() {
            sides[side] = w;
        } else {
            sides[side] += w;
        }
    }
    let mut out: Vec<(u32, u32, f64)> = acc
        .into_iter()
        .map(|((u, v), sides)| {
            let present: Vec<f64> = sides.into_iter().filter(|s| !s.is_nan()).collect();
            (u, v, present.iter().sum::<f64>() / present.len() as f64)
        })
        .collect();
    // Hash order is nondeterministic; edge ids must not be.
    out.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
    out
}

/// Write a graph as `coordinate real symmetric` MatrixMarket. The only
/// failure mode is I/O, so the error type says exactly that (the session
/// layer maps it into `error::Error::Io`).
pub fn write_mtx(g: &Graph, path: &Path) -> std::io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "%%MatrixMarket matrix coordinate real symmetric")?;
    writeln!(w, "% written by pdgrass")?;
    writeln!(w, "{} {} {}", g.num_vertices(), g.num_vertices(), g.num_edges())?;
    for e in g.edges() {
        // lower triangular: row > col, 1-based
        writeln!(w, "{} {} {}", e.v + 1, e.u + 1, e.w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_symmetric_real() {
        let src = "%%MatrixMarket matrix coordinate real symmetric\n\
                   % a comment\n\
                   3 3 4\n\
                   2 1 1.5\n\
                   3 1 -2.0\n\
                   3 2 0.5\n\
                   1 1 4.0\n";
        let g = read_mtx_from(Cursor::new(src)).unwrap();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 3); // diagonal dropped
        // -2.0 becomes weight 2.0
        let e = g.edges().iter().find(|e| e.u == 0 && e.v == 2).unwrap();
        assert_eq!(e.w, 2.0);
    }

    #[test]
    fn parses_pattern() {
        let src = "%%MatrixMarket matrix coordinate pattern symmetric\n\
                   2 2 1\n\
                   2 1\n";
        let g = read_mtx_from(Cursor::new(src)).unwrap();
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.edges()[0].w, 1.0);
    }

    #[test]
    fn general_mirror_pairs_are_deduplicated() {
        // Both triangles stored: every off-diagonal appears as (i,j) AND
        // (j,i). The duplicate must not double the edge weight (the seed
        // reader pushed both copies into the edge list, and from_edges
        // summed them).
        let src = "%%MatrixMarket matrix coordinate real general\n\
                   3 3 8\n\
                   1 1 4.0\n\
                   2 1 -1.5\n\
                   1 2 -1.5\n\
                   3 2 -0.5\n\
                   2 3 -0.5\n\
                   3 1 -2.0\n\
                   1 3 -2.0\n\
                   2 2 3.0\n";
        let g = read_mtx_from(Cursor::new(src)).unwrap();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 3, "mirror pairs must collapse to one edge");
        let w = |u: u32, v: u32| g.edges().iter().find(|e| e.u == u && e.v == v).unwrap().w;
        assert!((w(0, 1) - 1.5).abs() < 1e-12, "weight doubled: {}", w(0, 1));
        assert!((w(1, 2) - 0.5).abs() < 1e-12);
        assert!((w(0, 2) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn general_one_sided_entries_read_at_face_value() {
        // A general file that only stores one triangle (some exporters do)
        // must keep the stated weights, not halve them.
        let src = "%%MatrixMarket matrix coordinate real general\n\
                   3 3 2\n\
                   2 1 1.25\n\
                   3 2 2.5\n";
        let g = read_mtx_from(Cursor::new(src)).unwrap();
        assert_eq!(g.num_edges(), 2);
        let w = |u: u32, v: u32| g.edges().iter().find(|e| e.u == u && e.v == v).unwrap().w;
        assert!((w(0, 1) - 1.25).abs() < 1e-12);
        assert!((w(1, 2) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn general_pattern_both_triangles() {
        let src = "%%MatrixMarket matrix coordinate pattern general\n\
                   2 2 2\n\
                   1 2\n\
                   2 1\n";
        let g = read_mtx_from(Cursor::new(src)).unwrap();
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.edges()[0].w, 1.0);
    }

    #[test]
    fn rejects_nonsquare() {
        let src = "%%MatrixMarket matrix coordinate real general\n2 3 0\n";
        assert!(read_mtx_from(Cursor::new(src)).is_err());
    }

    #[test]
    fn roundtrip_via_tempfile() {
        let g = Graph::from_edges(4, &[(0, 1, 1.25), (1, 2, 2.0), (2, 3, 0.5), (0, 3, 3.0)]);
        let dir = std::env::temp_dir().join("pdgrass_mmio_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.mtx");
        write_mtx(&g, &path).unwrap();
        let h = read_mtx(&path).unwrap();
        assert_eq!(h.num_vertices(), 4);
        assert_eq!(h.num_edges(), 4);
        for (a, b) in g.edges().iter().zip(h.edges()) {
            assert_eq!((a.u, a.v), (b.u, b.v));
            assert!((a.w - b.w).abs() < 1e-12);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_file_errors() {
        let src = "%%MatrixMarket matrix coordinate real symmetric\n3 3 2\n2 1 1.0\n";
        assert!(read_mtx_from(Cursor::new(src)).is_err());
    }
}
