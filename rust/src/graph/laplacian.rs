//! Graph Laplacian assembly (Equation 1 of the paper) and grounding.
//!
//! The PCG evaluation solves `L_G x = b` preconditioned by `L_P`. Graph
//! Laplacians are singular (the all-ones vector spans the null space), so
//! both are *grounded*: one vertex's row/column is deleted, yielding a
//! symmetric positive-definite M-matrix — the standard trick used by power
//! grid analysis (feGRASS's domain) where the ground node is literal.

use super::csr::Graph;

/// Symmetric sparse matrix in CSR format (full storage, both triangles).
///
/// Row offsets are compact `u32`, matching [`Graph`]'s `xadj`: halving
/// offset width halves the index bytes the SpMV and triangular-solve hot
/// loops stream. Construction asserts the nnz count fits.
#[derive(Clone, Debug)]
pub struct CsrMatrix {
    /// Dimension.
    pub n: usize,
    /// Row offsets, length `n + 1`, compact `u32`.
    pub rowptr: Vec<u32>,
    /// Column indices per entry.
    pub colidx: Vec<u32>,
    /// Values per entry.
    pub vals: Vec<f64>,
}

impl CsrMatrix {
    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Number of nonzeros in row `i`.
    pub fn row_nnz(&self, i: usize) -> usize {
        (self.rowptr[i + 1] - self.rowptr[i]) as usize
    }

    /// Build from unsorted triplets, summing duplicates.
    pub fn from_triplets(n: usize, mut t: Vec<(u32, u32, f64)>) -> CsrMatrix {
        t.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        let mut merged: Vec<(u32, u32, f64)> = Vec::with_capacity(t.len());
        for (r, c, v) in t {
            match merged.last_mut() {
                Some(last) if last.0 == r && last.1 == c => last.2 += v,
                _ => merged.push((r, c, v)),
            }
        }
        assert!(
            merged.len() as u64 + 1 < u32::MAX as u64,
            "CSR nnz exceeds u32 index space"
        );
        let mut rowptr = vec![0u32; n + 1];
        for &(r, _, _) in &merged {
            rowptr[r as usize + 1] += 1;
        }
        for i in 0..n {
            rowptr[i + 1] += rowptr[i];
        }
        let colidx = merged.iter().map(|x| x.1).collect();
        let vals = merged.iter().map(|x| x.2).collect();
        CsrMatrix { n, rowptr, colidx, vals }
    }

    /// Row `i` as (cols, vals) slices.
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (s, e) = (self.rowptr[i] as usize, self.rowptr[i + 1] as usize);
        (&self.colidx[s..e], &self.vals[s..e])
    }

    /// Diagonal entries (0 where absent).
    pub fn diagonal(&self) -> Vec<f64> {
        let mut d = vec![0.0; self.n];
        for i in 0..self.n {
            let (cols, vals) = self.row(i);
            for (c, v) in cols.iter().zip(vals) {
                if *c as usize == i {
                    d[i] = *v;
                }
            }
        }
        d
    }

    /// Dense copy (for small-matrix test oracles only).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        let mut m = vec![vec![0.0; self.n]; self.n];
        for i in 0..self.n {
            let (cols, vals) = self.row(i);
            for (c, v) in cols.iter().zip(vals) {
                m[i][*c as usize] = *v;
            }
        }
        m
    }
}

/// Assemble the full (singular) Laplacian `L_G` of a graph.
pub fn laplacian(g: &Graph) -> CsrMatrix {
    let n = g.num_vertices();
    let mut t: Vec<(u32, u32, f64)> = Vec::with_capacity(2 * g.num_edges() + n);
    for e in g.edges() {
        t.push((e.u, e.v, -e.w));
        t.push((e.v, e.u, -e.w));
    }
    for u in 0..n as u32 {
        t.push((u, u, g.weighted_degree(u)));
    }
    CsrMatrix::from_triplets(n, t)
}

/// Assemble the grounded Laplacian: delete row/column `ground`.
///
/// Vertices keep their order; ids above `ground` shift down by one. The
/// result is SPD when the graph is connected.
pub fn grounded_laplacian(g: &Graph, ground: u32) -> CsrMatrix {
    let n = g.num_vertices();
    assert!((ground as usize) < n);
    let map = |v: u32| -> Option<u32> {
        if v == ground {
            None
        } else if v > ground {
            Some(v - 1)
        } else {
            Some(v)
        }
    };
    let mut t: Vec<(u32, u32, f64)> = Vec::with_capacity(2 * g.num_edges() + n);
    for e in g.edges() {
        if let (Some(u), Some(v)) = (map(e.u), map(e.v)) {
            t.push((u, v, -e.w));
            t.push((v, u, -e.w));
        }
    }
    for u in 0..n as u32 {
        if let Some(ug) = map(u) {
            // Diagonal keeps the FULL weighted degree, including edges to
            // ground — that's what makes the grounded system definite.
            t.push((ug, ug, g.weighted_degree(u)));
        }
    }
    CsrMatrix::from_triplets(n - 1, t)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path3() -> Graph {
        Graph::from_edges(3, &[(0, 1, 2.0), (1, 2, 3.0)])
    }

    #[test]
    fn laplacian_row_sums_zero() {
        let l = laplacian(&path3());
        for i in 0..l.n {
            let (_, vals) = l.row(i);
            let s: f64 = vals.iter().sum();
            assert!(s.abs() < 1e-12, "row {i} sums to {s}");
        }
    }

    #[test]
    fn laplacian_entries() {
        let l = laplacian(&path3()).to_dense();
        assert_eq!(l[0], vec![2.0, -2.0, 0.0]);
        assert_eq!(l[1], vec![-2.0, 5.0, -3.0]);
        assert_eq!(l[2], vec![0.0, -3.0, 3.0]);
    }

    #[test]
    fn grounded_is_minor() {
        let lg = grounded_laplacian(&path3(), 0).to_dense();
        assert_eq!(lg, vec![vec![5.0, -3.0], vec![-3.0, 3.0]]);
        let lg2 = grounded_laplacian(&path3(), 1).to_dense();
        assert_eq!(lg2, vec![vec![2.0, 0.0], vec![0.0, 3.0]]);
    }

    #[test]
    fn grounded_is_positive_definite_small() {
        // 2x2 minor: check eigen-positivity by det/trace.
        let m = grounded_laplacian(&path3(), 2).to_dense();
        let det = m[0][0] * m[1][1] - m[0][1] * m[1][0];
        let tr = m[0][0] + m[1][1];
        assert!(det > 0.0 && tr > 0.0);
    }

    #[test]
    fn triplets_sum_duplicates() {
        let m = CsrMatrix::from_triplets(2, vec![(0, 0, 1.0), (0, 0, 2.0), (1, 0, -1.0)]);
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.to_dense(), vec![vec![3.0, 0.0], vec![-1.0, 0.0]]);
        assert_eq!(m.diagonal(), vec![3.0, 0.0]);
    }
}
