//! Deterministic work–span scheduling simulator.
//!
//! This container has one physical core, but the paper's scaling results
//! (Table IV, Figs. 6–8) are *structural*: they follow from the subtask
//! size distribution and the blocked inner-parallel dependency shape. The
//! recovery is instrumented with exact per-edge work counters
//! ([`crate::recovery::CostTrace`]); this module replays those traces
//! under a p-thread schedule:
//!
//! * **outer part** — small subtasks are list-scheduled greedily (in the
//!   size-sorted order the implementation uses) onto `p` threads; the
//!   simulated time is the makespan.
//! * **inner part** — a large subtask is replayed block by block: the
//!   judge + commit chain is serial; each block's explorations run on `p`
//!   threads (the block size is `p`, as in the paper), so a block costs
//!   `max(explore_i)`. Without Judge-before-Parallel, blocks are formed
//!   from *all* edges (skipped edges occupy slots and idle their thread),
//!   which is exactly the bubble penalty of Appendix C.
//! * **sharded part** — with `shard_min > 0` a large subtask is replayed
//!   under the Sharded strategy instead: the same deterministic
//!   `shard_ranges` split the implementation uses, each shard's explore
//!   work list-scheduled onto the `p` workers (speculation has no
//!   cross-shard dependencies), plus the serial commit spine of cheap
//!   checks. This attributes shard work to workers, where the blocked
//!   model charges one `max(explore)` barrier per block.
//!
//! * **prepare pipeline** — [`PrepSim`] models Algorithm-1 steps 1–3 as
//!   the implementation runs them: scoring chunks on workers, run merges
//!   on the consumer, grouping fused into the final pass.
//!   [`prep_barrier_makespan`] charges the stage-sum (produce, join,
//!   merge, group); [`prep_streamed_makespan`] lets production overlap
//!   merging as `par::produce_stream` does — the quantified payoff of
//!   the streamed pipeline knob (`pipeline = streamed`).
//!
//! Calibration: simulated unit counts are converted to milliseconds with
//! the measured single-thread unit rate, so `T_1(sim) == T_1(measured)`
//! by construction and `T_p` inherits the shape.

use crate::recovery::subtask::shard_ranges;
use crate::recovery::CostTrace;

/// Simulation parameters (mirror of the recovery params that matter).
#[derive(Clone, Copy, Debug)]
pub struct SimParams {
    /// Simulated thread count `p`.
    pub threads: usize,
    /// Block size for inner parallelism (paper: `p`).
    pub block: usize,
    /// Large-subtask cutoff in edges.
    pub cutoff_edges: usize,
    /// Large-subtask cutoff as a fraction of all off-tree edges.
    pub cutoff_frac: f64,
    /// Judge-before-Parallel enabled.
    pub jbp: bool,
    /// Shard size for the Sharded-strategy model; `0` keeps the blocked
    /// inner-parallel (Mixed) model for large subtasks.
    pub shard_min: usize,
}

impl SimParams {
    /// Paper defaults at `p` threads (blocked inner-parallel model).
    pub fn new(threads: usize) -> SimParams {
        SimParams {
            threads,
            block: threads.max(1),
            cutoff_edges: 100_000,
            cutoff_frac: 0.10,
            jbp: true,
            shard_min: 0,
        }
    }

    /// As [`SimParams::new`], but large subtasks replay under the Sharded
    /// strategy with the given shard size.
    pub fn sharded(threads: usize, shard_min: usize) -> SimParams {
        SimParams { shard_min: shard_min.max(1), ..SimParams::new(threads) }
    }
}

/// Simulated timing decomposition, in work units.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimResult {
    /// Units on the serial spine of inner-parallel subtasks (judge+commit).
    pub inner_serial: u64,
    /// Units on the parallel explore phases of inner subtasks (after
    /// dividing across threads: Σ blocks max-explore).
    pub inner_parallel: u64,
    /// Makespan units of the outer-parallel small subtasks.
    pub outer: u64,
    /// Total serial work units (p = 1 reference).
    pub serial_total: u64,
}

impl SimResult {
    /// Simulated wall time in units: inner subtasks run one-by-one, then
    /// the outer group.
    pub fn time(&self) -> u64 {
        self.inner_serial + self.inner_parallel + self.outer
    }

    /// Simulated speedup vs the serial total.
    pub fn speedup(&self) -> f64 {
        self.serial_total as f64 / self.time().max(1) as f64
    }
}

/// Total serial units of a per-edge cost list.
fn serial_units(costs: &[(u32, u32)]) -> u64 {
    costs.iter().map(|&(c, e)| c as u64 + e as u64).sum()
}

/// Simulate one large subtask under blocked inner parallelism.
/// Returns (serial_spine_units, parallel_units).
pub fn simulate_inner(costs: &[(u32, u32)], p: &SimParams) -> (u64, u64) {
    let block = p.block.max(1);
    let mut serial = 0u64;
    let mut parallel = 0u64;
    if p.jbp {
        // Judge walks every edge serially (cheap checks); blocks contain
        // only exploring edges.
        let mut explores: Vec<u64> = Vec::new();
        for &(c, e) in costs {
            serial += c as u64;
            if e > 0 {
                explores.push(e as u64);
            }
        }
        for chunk in explores.chunks(block) {
            // block of ≤ p explores across p threads → max
            parallel += chunk.iter().copied().max().unwrap_or(0);
        }
    } else {
        // Blocks are consecutive edges; skipped edges idle their slot.
        for chunk in costs.chunks(block) {
            serial += chunk.iter().map(|&(c, _)| c as u64).sum::<u64>();
            parallel += chunk.iter().map(|&(_, e)| e as u64).max().unwrap_or(0);
        }
    }
    (serial, parallel)
}

/// Simulate one large subtask under sharded speculation: the shard
/// layout is the implementation's own deterministic [`shard_ranges`],
/// each shard's explore work runs wherever a worker is free (greedy list
/// scheduling → makespan), and the cheap checks form the serial commit
/// spine. Returns `(serial_spine_units, parallel_units)`.
///
/// Model caveat: the trace cannot distinguish commit-miss explores
/// (which the implementation runs *serially* inside the commit — see
/// `Stats::commit_misses`) from speculative ones, so every committed
/// explore is charged to the parallel phase. On miss-heavy traces
/// (heavy cross-shard marking with small shards) this overstates the
/// sharded speedup; misses are rare at realistic shard sizes, and the
/// star-graph worst case this model exists for has none.
pub fn simulate_sharded(costs: &[(u32, u32)], p: &SimParams) -> (u64, u64) {
    let serial: u64 = costs.iter().map(|&(c, _)| c as u64).sum();
    let shard_units: Vec<u64> = shard_ranges(costs.len(), p.shard_min.max(1))
        .into_iter()
        .map(|r| costs[r].iter().map(|&(_, e)| e as u64).sum())
        .collect();
    (serial, simulate_outer(&shard_units, p.threads))
}

/// Greedy list scheduling of small subtasks onto `p` threads (the order is
/// the size-sorted order the implementation processes them in). Returns
/// the makespan in units.
pub fn simulate_outer(subtask_units: &[u64], threads: usize) -> u64 {
    let threads = threads.max(1);
    let mut load = vec![0u64; threads];
    for &w in subtask_units {
        // assign to least-loaded thread (dynamic scheduling)
        let t = (0..threads).min_by_key(|&t| load[t]).unwrap();
        load[t] += w;
    }
    load.into_iter().max().unwrap_or(0)
}

/// Simulate the full mixed-strategy recovery from a cost trace.
pub fn simulate(trace: &CostTrace, p: &SimParams) -> SimResult {
    let total_edges: usize = trace.subtask_costs.iter().map(|c| c.len()).sum();
    let frac_cut = (p.cutoff_frac * total_edges as f64).ceil() as usize;
    let mut res = SimResult::default();
    let mut small_units = Vec::new();
    for costs in &trace.subtask_costs {
        let su = serial_units(costs);
        res.serial_total += su;
        let is_large =
            costs.len() >= p.cutoff_edges || (frac_cut > 0 && costs.len() >= frac_cut);
        if is_large && p.threads > 1 {
            let (s, par) = if p.shard_min > 0 {
                simulate_sharded(costs, p)
            } else {
                simulate_inner(costs, p)
            };
            res.inner_serial += s;
            res.inner_parallel += par;
        } else {
            small_units.push(su);
        }
    }
    res.outer = simulate_outer(&small_units, p.threads);
    res
}

/// Simulate only the inner part (Fig. 7): the largest subtask's speedup.
pub fn inner_part_speedup(trace: &CostTrace, threads: usize) -> f64 {
    let costs = match trace.subtask_costs.iter().max_by_key(|c| c.len()) {
        Some(c) if !c.is_empty() => c,
        _ => return 1.0,
    };
    let serial = serial_units(costs);
    let (s, par) = simulate_inner(costs, &SimParams::new(threads));
    serial as f64 / (s + par).max(1) as f64
}

/// Simulate only the sharded replay of the largest subtask — the
/// Sharded-strategy analogue of [`inner_part_speedup`]. Under Outer the
/// same subtask is one indivisible unit (speedup 1 by definition), so
/// this ratio is exactly what sharding buys on the skewed worst cases.
pub fn sharded_part_speedup(trace: &CostTrace, threads: usize, shard_min: usize) -> f64 {
    let costs = match trace.subtask_costs.iter().max_by_key(|c| c.len()) {
        Some(c) if !c.is_empty() => c,
        _ => return 1.0,
    };
    let serial = serial_units(costs);
    let (s, par) = simulate_sharded(costs, &SimParams::sharded(threads, shard_min));
    serial as f64 / (s + par).max(1) as f64
}

/// Structural model of the **prepare pipeline** (Algorithm-1 steps 1–3):
/// scoring chunks produced on workers, runs merged on the consumer, and
/// the grouping spine — mirroring the implementation's
/// `par::produce_stream` + `RunMerger` + `SubtaskBuilder` shape, in
/// abstract work units.
///
/// `chunk_units[i]` is the worker-side cost of scoring + locally sorting
/// chunk `i`; `merge_units[i]` is the consumer-side merge work triggered
/// by consuming chunk `i` (binary-counter merges); `final_units` is the
/// final merge + grouping spine (consumer-side, after the last chunk).
#[derive(Clone, Debug)]
pub struct PrepSim {
    /// Worker-side cost per chunk (scoring + leaf sort).
    pub chunk_units: Vec<u64>,
    /// Consumer-side merge cost charged when chunk `i` is consumed.
    pub merge_units: Vec<u64>,
    /// Consumer-side tail: final merge pass + fused subtask grouping.
    pub final_units: u64,
}

impl PrepSim {
    /// Build the model for `n` edges in fixed `chunk`-sized chunks with
    /// unit per-edge scoring cost — the exact chunk layout and
    /// binary-counter merge schedule the implementation uses, so the
    /// modeled merge work equals the real element moves.
    pub fn uniform(n: usize, chunk: usize) -> PrepSim {
        let chunk = chunk.max(1);
        let n_chunks = n.div_ceil(chunk);
        let mut chunk_units = Vec::with_capacity(n_chunks);
        let mut merge_units = Vec::with_capacity(n_chunks);
        // Replay the RunMerger binary counter on run *sizes*.
        let mut stack: Vec<(u32, u64)> = Vec::new();
        for i in 0..n_chunks {
            let len = chunk.min(n - i * chunk) as u64;
            chunk_units.push(len);
            let mut level = 0u32;
            let mut cur = len;
            let mut merged = 0u64;
            while let Some(&(top_level, top_len)) = stack.last() {
                if top_level != level {
                    break;
                }
                stack.pop();
                cur += top_len;
                merged += cur;
                level += 1;
            }
            stack.push((level, cur));
            merge_units.push(merged);
        }
        // finish_with: collapse the stack; the last merge doubles as the
        // grouping pass (one emit per element).
        let mut final_units = 0u64;
        while stack.len() > 1 {
            let (_, a) = stack.pop().expect("len checked");
            let (lvl, b) = stack.pop().expect("len checked");
            let m = a + b;
            final_units += m;
            stack.push((lvl, m));
        }
        final_units += n as u64; // grouping spine fused into the emit pass
        PrepSim { chunk_units, merge_units, final_units }
    }

    /// Total serial units (every cost paid by one thread).
    pub fn serial_total(&self) -> u64 {
        self.chunk_units.iter().sum::<u64>()
            + self.merge_units.iter().sum::<u64>()
            + self.final_units
    }
}

/// Barrier-pipeline makespan: the scoring stage list-schedules its chunks
/// across `threads` workers and **joins**, then the consumer performs all
/// merge work, then the grouping tail — the stage-sum the streamed
/// pipeline is measured against.
pub fn prep_barrier_makespan(sim: &PrepSim, threads: usize) -> u64 {
    simulate_outer(&sim.chunk_units, threads)
        + sim.merge_units.iter().sum::<u64>()
        + sim.final_units
}

/// Streamed-pipeline makespan: chunks are produced greedily on
/// `threads - 1` workers (the consumer owns the merge timeline, as in
/// `par::produce_stream` where the caller consumes); the consumer picks
/// up chunk `i` at `max(ready_i, its own clock)` and immediately pays the
/// chunk's merge work — production of later chunks overlaps merging of
/// earlier ones. At one thread the model degenerates to the serial
/// stage-sum exactly (streaming costs nothing serially).
pub fn prep_streamed_makespan(sim: &PrepSim, threads: usize) -> u64 {
    if threads <= 1 {
        return prep_barrier_makespan(sim, 1);
    }
    let workers = threads - 1;
    let mut load = vec![0u64; workers];
    let mut clock = 0u64;
    for (i, &c) in sim.chunk_units.iter().enumerate() {
        let w = (0..workers).min_by_key(|&w| load[w]).expect("workers >= 1");
        load[w] += c;
        clock = clock.max(load[w]) + sim.merge_units[i];
    }
    clock + sim.final_units
}

/// Simulate only the outer part (Figs. 6, 8): every subtask except those
/// above the cutoff, list-scheduled.
pub fn outer_part_speedup(trace: &CostTrace, threads: usize, p: &SimParams) -> f64 {
    let total_edges: usize = trace.subtask_costs.iter().map(|c| c.len()).sum();
    let frac_cut = (p.cutoff_frac * total_edges as f64).ceil() as usize;
    let units: Vec<u64> = trace
        .subtask_costs
        .iter()
        .filter(|c| c.len() < p.cutoff_edges && (frac_cut == 0 || c.len() < frac_cut))
        .map(|c| serial_units(c))
        .collect();
    let serial: u64 = units.iter().sum();
    if serial == 0 {
        return 1.0;
    }
    serial as f64 / simulate_outer(&units, threads).max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(subtasks: Vec<Vec<(u32, u32)>>) -> CostTrace {
        CostTrace { subtask_costs: subtasks }
    }

    #[test]
    fn single_thread_matches_serial() {
        let t = trace(vec![vec![(1, 10), (1, 0), (2, 5)], vec![(1, 3)]]);
        let r = simulate(&t, &SimParams::new(1));
        assert_eq!(r.time(), r.serial_total);
        assert_eq!(r.serial_total, 23);
        assert!((r.speedup() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn outer_scales_with_uniform_subtasks() {
        // 64 equal subtasks of 10 units → near-ideal scaling
        let t = trace((0..64).map(|_| vec![(5, 5)]).collect());
        let r1 = simulate(&t, &SimParams::new(1));
        let r8 = simulate(&t, &SimParams::new(8));
        assert_eq!(r1.time(), 640);
        assert_eq!(r8.time(), 80);
        assert!((r8.speedup() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn inner_parallel_max_per_block() {
        // one large subtask, all explores equal: block of p=4 costs max=e
        let costs: Vec<(u32, u32)> = (0..16).map(|_| (1, 8)).collect();
        let mut p = SimParams::new(4);
        p.cutoff_edges = 10; // force inner
        let t = trace(vec![costs]);
        let r = simulate(&t, &p);
        // serial spine = 16 checks, parallel = 4 blocks × 8
        assert_eq!(r.inner_serial, 16);
        assert_eq!(r.inner_parallel, 32);
        assert_eq!(r.serial_total, 16 + 128);
    }

    #[test]
    fn jbp_beats_no_jbp_on_skippy_traces() {
        // Alternating skip/explore: without JBP half the block slots idle.
        let costs: Vec<(u32, u32)> = (0..64)
            .map(|i| if i % 2 == 0 { (1, 10) } else { (1, 0) })
            .collect();
        let mut with = SimParams::new(8);
        with.cutoff_edges = 10;
        let mut without = with;
        without.jbp = false;
        let t = trace(vec![costs]);
        let rw = simulate(&t, &with);
        let rwo = simulate(&t, &without);
        assert!(rw.time() < rwo.time(), "jbp {} !< nojbp {}", rw.time(), rwo.time());
    }

    #[test]
    fn skewed_outer_plateaus() {
        // One giant subtask (inner-parallel, excluded from the outer part)
        // plus skewed "small" ones: the biggest small subtask bounds the
        // outer makespan, so the outer speedup plateaus (Fig. 8 shape).
        let edge = |n: usize| vec![(5u32, 5u32); n];
        let subtasks = vec![edge(60), edge(20), edge(10), edge(6)];
        let t = trace(subtasks);
        let mut p2 = SimParams::new(2);
        p2.cutoff_frac = 0.5; // only the 60-edge subtask is "large"
        let mut p32 = SimParams::new(32);
        p32.cutoff_frac = 0.5;
        let s2 = outer_part_speedup(&t, 2, &p2);
        let s32 = outer_part_speedup(&t, 32, &p32);
        assert!(s2 > 1.2, "got {s2}");
        assert!(s32 < 2.1, "plateau expected, got {s32}");
        // plateau: 32 threads barely better than 2
        assert!(s32 - s2 < 0.5);
    }

    #[test]
    fn inner_part_speedup_grows() {
        let costs: Vec<(u32, u32)> = (0..256).map(|_| (1, 20)).collect();
        let t = trace(vec![costs]);
        let s4 = inner_part_speedup(&t, 4);
        let s16 = inner_part_speedup(&t, 16);
        assert!(s16 > s4, "{s16} !> {s4}");
    }

    #[test]
    fn prep_model_serial_equivalence_and_coverage() {
        for (n, chunk) in [(0usize, 4096usize), (100, 4096), (10_000, 512), (100_000, 4096)] {
            let sim = PrepSim::uniform(n, chunk);
            assert_eq!(sim.chunk_units.len(), n.div_ceil(chunk.max(1)));
            assert_eq!(sim.chunk_units.iter().sum::<u64>(), n as u64, "n={n}");
            // Serially, streaming is free: both disciplines pay the exact
            // stage-sum.
            assert_eq!(
                prep_streamed_makespan(&sim, 1),
                prep_barrier_makespan(&sim, 1),
                "n={n} chunk={chunk}"
            );
            assert_eq!(prep_barrier_makespan(&sim, 1), sim.serial_total(), "n={n}");
        }
    }

    #[test]
    fn prep_streamed_beats_barrier_sum_when_chunks_outnumber_workers() {
        // Many chunks, merge-bound consumer: streaming hides production
        // behind merging; the barrier pays the production phase up front.
        let sim = PrepSim::uniform(200_000, 4096);
        assert!(sim.chunk_units.len() > 16, "model needs chunks > workers");
        for threads in [2usize, 4, 8, 16] {
            let b = prep_barrier_makespan(&sim, threads);
            let s = prep_streamed_makespan(&sim, threads);
            assert!(s < b, "threads={threads}: streamed {s} !< barrier {b}");
        }
        // More threads never hurt the streamed makespan.
        let mut last = u64::MAX;
        for threads in [1usize, 2, 4, 8, 16] {
            let s = prep_streamed_makespan(&sim, threads);
            assert!(s <= last, "threads={threads}: {s} > {last}");
            last = s;
        }
    }

    #[test]
    fn prep_model_single_chunk_degenerates() {
        // One chunk: nothing to overlap; both disciplines agree at every
        // thread count.
        let sim = PrepSim::uniform(1000, 4096);
        assert_eq!(sim.chunk_units.len(), 1);
        for threads in [1usize, 2, 8] {
            assert_eq!(
                prep_streamed_makespan(&sim, threads),
                prep_barrier_makespan(&sim, threads),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn sharded_single_thread_matches_serial() {
        let costs: Vec<(u32, u32)> = (0..100).map(|i| (1, (i % 5) as u32)).collect();
        let serial = serial_units(&costs);
        let (s, par) = simulate_sharded(&costs, &SimParams::sharded(1, 10));
        assert_eq!(s + par, serial);
    }

    #[test]
    fn sharded_beats_blocked_on_ragged_explores() {
        // Ragged explore costs: the blocked scheme pays max(explore) per
        // block (bubbles), sharding only pays shard imbalance.
        let costs: Vec<(u32, u32)> =
            (0..512).map(|i| (1, if i % 8 == 0 { 64 } else { 1 })).collect();
        let mut blocked = SimParams::new(8);
        blocked.cutoff_edges = 10;
        let (bs, bp) = simulate_inner(&costs, &blocked);
        let (ss, spar) = simulate_sharded(&costs, &SimParams::sharded(8, 64));
        assert!(ss + spar < bs + bp, "sharded {} !< blocked {}", ss + spar, bs + bp);
    }

    #[test]
    fn sharded_part_speedup_scales_on_giant_subtask() {
        // One giant subtask: Outer is stuck at 1x; sharding approaches p
        // as long as shards outnumber workers.
        let costs: Vec<(u32, u32)> = (0..4096).map(|_| (1, 20)).collect();
        let t = trace(vec![costs]);
        let s2 = sharded_part_speedup(&t, 2, 64);
        let s8 = sharded_part_speedup(&t, 8, 64);
        assert!(s2 > 1.5, "got {s2}");
        assert!(s8 > s2, "{s8} !> {s2}");
        assert!(s8 <= 8.0 + 1e-9, "no superlinear artifacts: {s8}");
    }

    #[test]
    fn simulate_picks_sharded_model_for_large_subtasks() {
        let costs: Vec<(u32, u32)> = (0..64).map(|_| (1, 10)).collect();
        let t = trace(vec![costs]);
        let mut blocked = SimParams::new(4);
        blocked.cutoff_edges = 10;
        let mut sharded = SimParams::sharded(4, 8);
        sharded.cutoff_edges = 10;
        let rb = simulate(&t, &blocked);
        let rs = simulate(&t, &sharded);
        // Both route the subtask through the inner/sharded path…
        assert_eq!(rb.outer, 0);
        assert_eq!(rs.outer, 0);
        // …and on perfectly uniform explores the two models agree:
        // blocked pays ceil(64/4) = 16 blocks × max 10; sharded pays the
        // makespan of 8 shards × 80 units over 4 workers — 160 either way
        // (the models only diverge on ragged costs, tested above).
        assert_eq!(rb.inner_parallel, 160);
        assert_eq!(rs.inner_parallel, 160);
        assert_eq!(rb.serial_total, rs.serial_total);
        // thread monotonicity holds in the sharded model too
        let mut last = u64::MAX;
        for p in [1usize, 2, 4, 8, 16] {
            let mut sp = SimParams::sharded(p, 8);
            sp.cutoff_edges = 10;
            let tm = simulate(&t, &sp).time();
            assert!(tm <= last, "p={p}: {tm} > {last}");
            last = tm;
        }
    }
}
