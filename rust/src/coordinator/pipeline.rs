//! End-to-end pipeline: graph → spanning tree → recovery (feGRASS &
//! pdGRASS) → PCG quality evaluation → simulated multi-thread timing.
//!
//! This is the measurement engine behind every experiment driver
//! (`coordinator::experiments`) and the CLI.

use super::schedsim::{simulate, SimParams};
use crate::gen;
use crate::graph::Graph;
use crate::recovery::{self, Params, Strategy};
use crate::solver;
use crate::tree::{build_spanning, Spanning};


/// Pipeline configuration (defaults follow §V of the paper).
#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    /// Edge-recovery ratio α.
    pub alpha: f64,
    /// BFS step-size constant c.
    pub beta_cap: u32,
    /// PCG tolerance (paper: 1e-3).
    pub tol: f64,
    /// PCG iteration cap.
    pub maxit: usize,
    /// Suite scale factor.
    pub scale: f64,
    /// Generator / RHS seed.
    pub seed: u64,
    /// Timing trials (paper reports min over 5).
    pub trials: usize,
    /// Run the PCG quality evaluation (slowest part; benches can skip).
    pub evaluate_quality: bool,
    /// Thread counts to simulate for T_p (e.g. [8, 32]).
    pub sim_threads: [usize; 2],
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            alpha: 0.02,
            beta_cap: 8,
            tol: 1e-3,
            maxit: 50_000,
            scale: 1.0,
            seed: gen::DEFAULT_SEED,
            trials: 3,
            evaluate_quality: true,
            sim_threads: [8, 32],
        }
    }
}

/// Everything measured for one (graph, α) pair.
#[derive(Clone, Debug)]
pub struct GraphReport {
    /// Suite row name.
    pub name: String,
    /// Vertices.
    pub v: usize,
    /// Edges.
    pub e: usize,
    /// feGRASS recovery time, ms (min over trials).
    pub t_fe_ms: f64,
    /// feGRASS passes.
    pub fe_passes: usize,
    /// PCG iterations with the feGRASS sparsifier.
    pub iter_fe: usize,
    /// pdGRASS single-thread recovery time, ms (min over trials).
    pub t_pd1_ms: f64,
    /// pdGRASS passes (expected 1).
    pub pd_passes: usize,
    /// PCG iterations with the pdGRASS sparsifier.
    pub iter_pd: usize,
    /// Simulated pdGRASS time at `sim_threads[i]` threads, ms.
    pub t_pd_sim_ms: [f64; 2],
    /// Simulated speedups vs T_1 at the same thread counts.
    pub sim_speedup: [f64; 2],
    /// Recovery stats from the pdGRASS run.
    pub stats: recovery::Stats,
    /// pdGRASS per-step times (serial run), ms.
    pub step_ms: [f64; 4],
}

/// Build a suite graph per config.
pub fn build_graph(name: &str, cfg: &PipelineConfig) -> Graph {
    gen::suite::build(name, cfg.scale, cfg.seed)
}

/// Recovery params for pdGRASS at `threads` under this config.
pub fn recovery_params(cfg: &PipelineConfig, threads: usize, strategy: Strategy) -> Params {
    Params {
        alpha: cfg.alpha,
        beta_cap: cfg.beta_cap,
        strategy,
        threads,
        block: threads.max(1),
        cutoff_edges: 100_000,
        cutoff_frac: 0.10,
        jbp: true,
    }
}

/// Run both algorithms + evaluation on one suite graph.
pub fn run_graph(name: &str, cfg: &PipelineConfig) -> anyhow::Result<GraphReport> {
    let g = build_graph(name, cfg);
    let sp = build_spanning(&g);
    run_prepared(name, &g, &sp, cfg)
}

/// As [`run_graph`] but with a prebuilt graph + spanning tree.
pub fn run_prepared(
    name: &str,
    g: &Graph,
    sp: &Spanning,
    cfg: &PipelineConfig,
) -> anyhow::Result<GraphReport> {
    let params_serial = recovery_params(cfg, 1, Strategy::Serial);

    // --- feGRASS baseline (serial, multi-pass) ---
    let (fe, t_fe_ms) =
        crate::util::min_of(cfg.trials, || recovery::fegrass(g, sp, &params_serial));

    // --- pdGRASS serial run with trace (simulator input) ---
    let (pd, t_pd1_ms) = crate::util::min_of(cfg.trials, || {
        recovery::pdgrass::pdgrass_traced(g, sp, &params_serial, true)
    });
    let trace = pd.trace.as_ref().expect("trace requested");

    // --- simulated multi-thread timing, calibrated on the serial run ---
    let steps123: f64 = pd.step_ms[0] + pd.step_ms[1] + pd.step_ms[2];
    let serial_units = simulate(trace, &SimParams::new(1)).time().max(1);
    let ms_per_unit = pd.step_ms[3] / serial_units as f64;
    let mut t_pd_sim_ms = [0f64; 2];
    let mut sim_speedup = [0f64; 2];
    for (i, &p) in cfg.sim_threads.iter().enumerate() {
        let sim = simulate(trace, &SimParams::new(p));
        let t4 = sim.time() as f64 * ms_per_unit;
        // steps 1–3 are standard parallel primitives (O(lg²) span): model
        // them as ideally scaled; they are a small fraction of the total.
        t_pd_sim_ms[i] = steps123 / p as f64 + t4;
        let t1 = steps123 + pd.step_ms[3];
        sim_speedup[i] = t1 / t_pd_sim_ms[i].max(1e-9);
    }

    // --- PCG quality evaluation (same RHS seed for both sparsifiers) ---
    let (mut iter_fe, mut iter_pd) = (0usize, 0usize);
    if cfg.evaluate_quality {
        let p_fe = recovery::sparsifier(g, sp, &fe.edges);
        let p_pd = recovery::sparsifier(g, sp, &pd.edges);
        let (ife, conv_fe) =
            solver::pcg_iterations(g, &p_fe, cfg.seed ^ 0xb, cfg.tol, cfg.maxit)?;
        let (ipd, conv_pd) =
            solver::pcg_iterations(g, &p_pd, cfg.seed ^ 0xb, cfg.tol, cfg.maxit)?;
        anyhow::ensure!(conv_fe && conv_pd, "PCG did not converge on {name}");
        iter_fe = ife;
        iter_pd = ipd;
    }

    Ok(GraphReport {
        name: name.to_string(),
        v: g.num_vertices(),
        e: g.num_edges(),
        t_fe_ms,
        fe_passes: fe.passes,
        iter_fe,
        t_pd1_ms,
        pd_passes: pd.passes,
        iter_pd,
        t_pd_sim_ms,
        sim_speedup,
        stats: pd.stats.clone(),
        step_ms: pd.step_ms,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> PipelineConfig {
        PipelineConfig { scale: 0.02, trials: 1, ..Default::default() }
    }

    #[test]
    fn pipeline_runs_a_census_row() {
        let cfg = quick_cfg();
        let r = run_graph("01-mi2010", &cfg).unwrap();
        assert!(r.v > 0 && r.e > 0);
        assert!(r.t_fe_ms >= 0.0 && r.t_pd1_ms >= 0.0);
        assert!(r.iter_fe > 0 && r.iter_pd > 0);
        assert_eq!(r.pd_passes, 1);
        // simulated 32-thread time must not exceed serial time
        assert!(r.t_pd_sim_ms[1] <= r.t_pd1_ms * 1.5);
    }

    #[test]
    fn quality_skip_flag() {
        let mut cfg = quick_cfg();
        cfg.evaluate_quality = false;
        let r = run_graph("15-M6", &cfg).unwrap();
        assert_eq!(r.iter_fe, 0);
        assert_eq!(r.iter_pd, 0);
    }

    #[test]
    fn sim_speedup_monotone_in_threads() {
        let cfg = quick_cfg();
        let r = run_graph("15-M6", &cfg).unwrap();
        assert!(
            r.sim_speedup[1] >= r.sim_speedup[0] * 0.9,
            "32t {} vs 8t {}",
            r.sim_speedup[1],
            r.sim_speedup[0]
        );
    }
}
