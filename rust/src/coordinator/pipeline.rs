//! End-to-end pipeline: one prepared session → recovery (feGRASS &
//! pdGRASS) → PCG quality evaluation → simulated multi-thread timing.
//!
//! This is the measurement engine behind every experiment driver
//! (`coordinator::experiments`) and the CLI. All sparsifier construction
//! goes through the session API ([`crate::session`]): [`run_prepared`]
//! is a thin orchestration over one [`Prepared`], so α-sweep drivers can
//! pay steps 1–3 once per graph and call it once per α.

use super::schedsim::{simulate, SimParams};
use crate::error::Error;
use crate::gen;
use crate::graph::Relabel;
use crate::recovery::{self, Pipeline, Strategy};
use crate::session::{Prepared, RecoverOpts, Sparsify};

/// Pipeline configuration (defaults follow §V of the paper).
#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    /// Edge-recovery ratio α.
    pub alpha: f64,
    /// BFS step-size constant c.
    pub beta_cap: u32,
    /// PCG tolerance (paper: 1e-3).
    pub tol: f64,
    /// PCG iteration cap.
    pub maxit: usize,
    /// Suite scale factor.
    pub scale: f64,
    /// Generator / RHS seed.
    pub seed: u64,
    /// Timing trials (paper reports min over 5).
    pub trials: usize,
    /// Run the PCG quality evaluation (slowest part; benches can skip).
    pub evaluate_quality: bool,
    /// Thread counts to simulate for T_p (e.g. [8, 32]).
    pub sim_threads: [usize; 2],
    /// Stage-handoff discipline for preparation and recovery.
    pub pipeline: Pipeline,
    /// Vertex-locality relabeling applied at prepare time
    /// ([`crate::graph::relabel`]); sparsifiers and PCG evaluation stay
    /// in the original id space regardless.
    pub relabel: Relabel,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            alpha: 0.02,
            beta_cap: 8,
            tol: 1e-3,
            maxit: 50_000,
            scale: 1.0,
            seed: gen::DEFAULT_SEED,
            trials: 3,
            evaluate_quality: true,
            sim_threads: [8, 32],
            pipeline: Pipeline::Barrier,
            relabel: Relabel::None,
        }
    }
}

/// Everything measured for one (graph, α) pair.
#[derive(Clone, Debug)]
pub struct GraphReport {
    /// Suite row name.
    pub name: String,
    /// Vertices.
    pub v: usize,
    /// Edges.
    pub e: usize,
    /// feGRASS recovery time, ms (shared steps 1–2 + min-over-trials core).
    pub t_fe_ms: f64,
    /// feGRASS passes.
    pub fe_passes: usize,
    /// PCG iterations with the feGRASS sparsifier.
    pub iter_fe: usize,
    /// pdGRASS single-thread recovery time, ms (steps 1–3 + min-over-trials
    /// step 4).
    pub t_pd1_ms: f64,
    /// pdGRASS passes (expected 1).
    pub pd_passes: usize,
    /// PCG iterations with the pdGRASS sparsifier.
    pub iter_pd: usize,
    /// Simulated pdGRASS time at `sim_threads[i]` threads, ms.
    pub t_pd_sim_ms: [f64; 2],
    /// Simulated speedups vs T_1 at the same thread counts.
    pub sim_speedup: [f64; 2],
    /// Recovery stats from the pdGRASS run.
    pub stats: recovery::Stats,
    /// pdGRASS per-step times (serial run), ms. The first three entries
    /// come from the shared [`Prepared`] — reports produced from the same
    /// session carry identical values there.
    pub step_ms: [f64; 4],
    /// Id of the [`Prepared`] session this report was measured against.
    /// Equal ids across an α-sweep prove steps 1–3 were paid once.
    pub prepared_id: u64,
    /// Stage-handoff discipline the preparation ran under. Under
    /// [`Pipeline::Streamed`], `step_ms[0]` holds the fused
    /// annotate+sort stage and `step_ms[1]` is zero (no separate sort
    /// stage exists — the overlap removed the boundary).
    pub pipeline: Pipeline,
}

/// Recovery options for this config at `threads` / `strategy`.
pub fn recover_opts(cfg: &PipelineConfig, threads: usize, strategy: Strategy) -> RecoverOpts {
    RecoverOpts {
        alpha: cfg.alpha,
        beta_cap: cfg.beta_cap,
        strategy,
        pipeline: cfg.pipeline,
        ..RecoverOpts::with_threads(cfg.alpha, threads)
    }
}

/// Prepare a suite row under this config (honoring `cfg.pipeline`). The
/// step-3 sort runs at one thread, matching what the pre-session pipeline
/// timed for its serial calibration run (the other prepare stages have no
/// per-call thread knob and behave as before).
pub fn prepare_graph(name: &str, cfg: &PipelineConfig) -> Result<Prepared, Error> {
    Sparsify::suite(name, cfg.scale, cfg.seed)?
        .threads(1)
        .pipeline(cfg.pipeline)
        .relabel(cfg.relabel)
        .prepare()
}

/// Run both algorithms + evaluation on one suite graph.
pub fn run_graph(name: &str, cfg: &PipelineConfig) -> Result<GraphReport, Error> {
    let prepared = prepare_graph(name, cfg)?;
    run_prepared(&prepared, cfg)
}

/// As [`run_graph`] but against an existing [`Prepared`] session — the
/// α-sweep entry point: steps 1–3 are read from the session; only step 4
/// and the PCG evaluation run here.
pub fn run_prepared(prepared: &Prepared, cfg: &PipelineConfig) -> Result<GraphReport, Error> {
    let opts = recover_opts(cfg, 1, Strategy::Serial);
    let prep = prepared.prep_ms();

    // --- feGRASS baseline (serial, multi-pass; shares steps 1–2) ---
    let (fe, t_fe_core) = crate::util::min_of(cfg.trials, || prepared.fegrass(&opts));
    let fe = fe?;
    let t_fe_ms = prep[0] + prep[1] + t_fe_core;

    // --- pdGRASS serial step 4 with trace (simulator input) ---
    let (pd, t4_ms) = crate::util::min_of(cfg.trials, || prepared.recover_traced(&opts));
    let pd = pd?;
    let trace = pd.trace().expect("trace requested");
    let step_ms = [prep[0], prep[1], prep[2], t4_ms];

    // --- simulated multi-thread timing, calibrated on the serial run ---
    let steps123: f64 = prep.iter().sum();
    let serial_units = simulate(trace, &SimParams::new(1)).time().max(1);
    let ms_per_unit = t4_ms / serial_units as f64;
    let mut t_pd_sim_ms = [0f64; 2];
    let mut sim_speedup = [0f64; 2];
    for (i, &p) in cfg.sim_threads.iter().enumerate() {
        let sim = simulate(trace, &SimParams::new(p));
        let t4 = sim.time() as f64 * ms_per_unit;
        // steps 1–3 are standard parallel primitives (O(lg²) span): model
        // them as ideally scaled; they are a small fraction of the total.
        t_pd_sim_ms[i] = steps123 / p as f64 + t4;
        let t1 = steps123 + t4_ms;
        sim_speedup[i] = t1 / t_pd_sim_ms[i].max(1e-9);
    }

    // --- PCG quality evaluation (same RHS seed for both sparsifiers) ---
    let (mut iter_fe, mut iter_pd) = (0usize, 0usize);
    if cfg.evaluate_quality {
        let o_fe = fe.sparsifier().pcg(cfg.seed ^ 0xb, cfg.tol, cfg.maxit)?.require_converged()?;
        let o_pd = pd.sparsifier().pcg(cfg.seed ^ 0xb, cfg.tol, cfg.maxit)?.require_converged()?;
        iter_fe = o_fe.iterations;
        iter_pd = o_pd.iterations;
    }

    Ok(GraphReport {
        name: prepared.name().unwrap_or("graph").to_string(),
        v: prepared.graph().num_vertices(),
        e: prepared.graph().num_edges(),
        t_fe_ms,
        fe_passes: fe.passes(),
        iter_fe,
        t_pd1_ms: steps123 + t4_ms,
        pd_passes: pd.passes(),
        iter_pd,
        t_pd_sim_ms,
        sim_speedup,
        stats: pd.stats().clone(),
        step_ms,
        prepared_id: prepared.id(),
        pipeline: prepared.pipeline(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> PipelineConfig {
        PipelineConfig { scale: 0.02, trials: 1, ..Default::default() }
    }

    #[test]
    fn pipeline_runs_a_census_row() {
        let cfg = quick_cfg();
        let r = run_graph("01-mi2010", &cfg).unwrap();
        assert!(r.v > 0 && r.e > 0);
        assert!(r.t_fe_ms >= 0.0 && r.t_pd1_ms >= 0.0);
        assert!(r.iter_fe > 0 && r.iter_pd > 0);
        assert_eq!(r.pd_passes, 1);
        // simulated 32-thread time must not exceed serial time
        assert!(r.t_pd_sim_ms[1] <= r.t_pd1_ms * 1.5);
    }

    #[test]
    fn quality_skip_flag() {
        let mut cfg = quick_cfg();
        cfg.evaluate_quality = false;
        let r = run_graph("15-M6", &cfg).unwrap();
        assert_eq!(r.iter_fe, 0);
        assert_eq!(r.iter_pd, 0);
    }

    #[test]
    fn sim_speedup_monotone_in_threads() {
        let cfg = quick_cfg();
        let r = run_graph("15-M6", &cfg).unwrap();
        assert!(
            r.sim_speedup[1] >= r.sim_speedup[0] * 0.9,
            "32t {} vs 8t {}",
            r.sim_speedup[1],
            r.sim_speedup[0]
        );
    }

    #[test]
    fn run_prepared_reuses_the_session_across_alphas() {
        let prepared = prepare_graph("15-M6", &quick_cfg()).unwrap();
        let mut cfg = quick_cfg();
        cfg.alpha = 0.02;
        let a = run_prepared(&prepared, &cfg).unwrap();
        cfg.alpha = 0.10;
        let b = run_prepared(&prepared, &cfg).unwrap();
        assert_eq!(a.prepared_id, b.prepared_id);
        assert_eq!(a.step_ms[..3], b.step_ms[..3], "shared steps 1–3 timings");
        assert!(b.iter_pd <= a.iter_pd + 2, "more recovered edges must not hurt quality much");
    }

    #[test]
    fn streamed_config_reports_same_results_as_barrier() {
        let barrier = run_graph("15-M6", &quick_cfg()).unwrap();
        let mut cfg = quick_cfg();
        cfg.pipeline = Pipeline::Streamed;
        let streamed = run_graph("15-M6", &cfg).unwrap();
        assert_eq!(barrier.pipeline, Pipeline::Barrier);
        assert_eq!(streamed.pipeline, Pipeline::Streamed);
        // Identical graphs, recoveries, and quality — only timings and
        // stage attribution may differ.
        assert_eq!(streamed.v, barrier.v);
        assert_eq!(streamed.e, barrier.e);
        assert_eq!(streamed.iter_pd, barrier.iter_pd);
        assert_eq!(streamed.iter_fe, barrier.iter_fe);
        assert_eq!(streamed.pd_passes, barrier.pd_passes);
        assert_eq!(format!("{:?}", streamed.stats), format!("{:?}", barrier.stats));
        // Streamed stage attribution: no separate sort stage.
        assert_eq!(streamed.step_ms[1], 0.0);
    }

    #[test]
    fn relabeled_config_reports_same_quality_as_identity() {
        // Locality relabeling is a layout change, not an algorithmic one:
        // the recovered sparsifier is mapped back to original ids, so the
        // PCG evaluation (which runs in original id space) must see
        // bitwise-identical systems and converge in the same iterations.
        let base = run_graph("15-M6", &quick_cfg()).unwrap();
        for mode in [Relabel::Bfs, Relabel::Degree] {
            let mut cfg = quick_cfg();
            cfg.relabel = mode;
            let r = run_graph("15-M6", &cfg).unwrap();
            assert_eq!(r.v, base.v);
            assert_eq!(r.e, base.e);
            assert_eq!(r.iter_pd, base.iter_pd, "{mode:?}");
            assert_eq!(r.iter_fe, base.iter_fe, "{mode:?}");
            assert_eq!(r.pd_passes, base.pd_passes, "{mode:?}");
        }
    }

    #[test]
    fn typed_error_for_unknown_graph() {
        match run_graph("no-such-row", &quick_cfg()) {
            Err(Error::UnknownGraph { name }) => assert_eq!(name, "no-such-row"),
            other => panic!("expected UnknownGraph, got {other:?}"),
        }
    }
}
