//! Experiment drivers: one function per paper table/figure.
//!
//! Each driver prints the same rows/series the paper reports and returns
//! the underlying data so benches and tests can assert on shapes. The
//! drivers are invoked by the CLI (`pdgrass table2 …`) and by `benches/`.
//!
//! Every driver constructs sparsifiers through the session API
//! ([`crate::session`]): each graph is prepared **once** (steps 1–3 of
//! Algorithm 1) and the α-sweep drivers ([`table2`], [`fig1`]) reuse that
//! [`Prepared`] for every α — only step 4 and the PCG evaluation are
//! re-run per α. `GraphReport::prepared_id` carries the proof (asserted
//! in the tests below).

use super::pipeline::{prepare_graph, recover_opts, run_prepared, GraphReport, PipelineConfig};
use super::schedsim::{
    inner_part_speedup, outer_part_speedup, prep_barrier_makespan, prep_streamed_makespan,
    simulate, PrepSim, SimParams,
};
use crate::gen::SUITE;
use crate::recovery::{self, Pipeline, Strategy};
use crate::session::Prepared;
use crate::util::{geomean, sci, sig3, Table};

fn prepare_or_die(name: &str, cfg: &PipelineConfig) -> Prepared {
    prepare_graph(name, cfg).unwrap_or_else(|e| panic!("{name}: {e}"))
}

/// Table II: runtime + quality per graph per α. Steps 1–3 run once per
/// graph; each α recovers from the shared session.
pub fn table2(
    names: &[&str],
    alphas: &[f64],
    cfg_base: &PipelineConfig,
) -> Vec<(f64, Vec<GraphReport>)> {
    let mut by_alpha: Vec<Vec<GraphReport>> = alphas.iter().map(|_| Vec::new()).collect();
    for name in names {
        let prepared = prepare_or_die(name, cfg_base);
        for (ai, &alpha) in alphas.iter().enumerate() {
            let mut cfg = *cfg_base;
            cfg.alpha = alpha;
            let r = run_prepared(&prepared, &cfg).unwrap_or_else(|e| panic!("{name}: {e}"));
            by_alpha[ai].push(r);
        }
    }

    let mut out = Vec::new();
    for (&alpha, reports) in alphas.iter().zip(by_alpha) {
        let mut t = Table::new(&[
            "Graph", "|V|", "|E|", "T_fe(ms)", "Pass", "iter_fe", "T_pd-32(ms)", "iter_pd",
            "iter_fe/iter_pd", "T_fe/T_pd32",
        ]);
        for r in &reports {
            t.row(vec![
                r.name.clone(),
                sci(r.v as f64),
                sci(r.e as f64),
                sig3(r.t_fe_ms),
                r.fe_passes.to_string(),
                r.iter_fe.to_string(),
                sig3(r.t_pd_sim_ms[1]),
                r.iter_pd.to_string(),
                sig3(safe_ratio(r.iter_fe as f64, r.iter_pd as f64)),
                sig3(safe_ratio(r.t_fe_ms, r.t_pd_sim_ms[1])),
            ]);
        }
        println!("\n=== Table II (alpha = {alpha}) ===");
        println!("{}", t.render());
        let speedups: Vec<f64> = reports
            .iter()
            .map(|r| safe_ratio(r.t_fe_ms, r.t_pd_sim_ms[1]))
            .filter(|s| s.is_finite() && *s > 0.0)
            .collect();
        let ratios: Vec<f64> = reports
            .iter()
            .filter(|r| r.iter_pd > 0)
            .map(|r| r.iter_fe as f64 / r.iter_pd as f64)
            .collect();
        println!(
            "avg speedup T_fe/T_pd-32 (geomean): {:.2}x   avg iter ratio: {:.2}x",
            geomean(&speedups),
            geomean(&ratios)
        );
        out.push((alpha, reports));
    }
    out
}

/// Fig. 1 scatter: (T_fe/T_pd32, iter_fe/iter_pd) per graph per α, CSV.
/// Shares one prepared session per graph across the α sweep.
pub fn fig1(
    names: &[&str],
    alphas: &[f64],
    cfg_base: &PipelineConfig,
) -> Vec<(String, f64, f64, f64)> {
    let mut by_alpha: Vec<Vec<(String, f64, f64, f64)>> =
        alphas.iter().map(|_| Vec::new()).collect();
    for name in names {
        let prepared = prepare_or_die(name, cfg_base);
        for (ai, &alpha) in alphas.iter().enumerate() {
            let mut cfg = *cfg_base;
            cfg.alpha = alpha;
            let r = run_prepared(&prepared, &cfg).unwrap_or_else(|e| panic!("{name}: {e}"));
            let rel_time = safe_ratio(r.t_fe_ms, r.t_pd_sim_ms[1]);
            let rel_iters = safe_ratio(r.iter_fe as f64, r.iter_pd as f64);
            by_alpha[ai].push((name.to_string(), alpha, rel_time, rel_iters));
        }
    }
    println!("graph,alpha,rel_time,rel_iters");
    let mut pts = Vec::new();
    for per_alpha in by_alpha {
        for (name, alpha, rel_time, rel_iters) in per_alpha {
            println!("{name},{alpha},{rel_time:.3},{rel_iters:.3}");
            pts.push((name, alpha, rel_time, rel_iters));
        }
    }
    pts
}

/// Table III: Judge-before-Parallel statistics on the com-Youtube row.
/// One prepared session serves both the with- and without-JbP recoveries.
pub fn table3(cfg: &PipelineConfig) -> (recovery::Stats, recovery::Stats) {
    let prepared = prepare_or_die("09-com-Youtube", cfg);
    let mut opts = recover_opts(cfg, 32, Strategy::Inner);
    // exercise the blocked path on every subtask (as the paper's table
    // instruments the biggest task)
    opts.block = 32;
    opts.jbp = false;
    let without = prepared
        .recover(&opts)
        .unwrap_or_else(|e| panic!("09-com-Youtube: {e}"))
        .stats()
        .clone();
    opts.jbp = true;
    let with = prepared
        .recover(&opts)
        .unwrap_or_else(|e| panic!("09-com-Youtube: {e}"))
        .stats()
        .clone();
    let mut t = Table::new(&["Statistic (com-Youtube analogue)", "Without", "With"]);
    t.row(vec![
        "# off-tree edges in biggest task".into(),
        without.biggest_subtask.to_string(),
        with.biggest_subtask.to_string(),
    ]);
    t.row(vec![
        "# edges in parallel blocks".into(),
        without.edges_in_blocks.to_string(),
        with.edges_in_blocks.to_string(),
    ]);
    t.row(vec![
        "# edges skipped in parallel".into(),
        format!(
            "{} ({:.0}%)",
            without.skipped_in_parallel,
            100.0 * without.skipped_in_parallel as f64 / without.edges_in_blocks.max(1) as f64
        ),
        with.skipped_in_parallel.to_string(),
    ]);
    t.row(vec![
        "# edges explored in parallel".into(),
        format!(
            "{} ({:.0}%)",
            without.explored_in_parallel,
            100.0 * without.explored_in_parallel as f64 / without.edges_in_blocks.max(1) as f64
        ),
        format!(
            "{} ({:.0}%)",
            with.explored_in_parallel,
            100.0 * with.explored_in_parallel as f64 / with.edges_in_blocks.max(1) as f64
        ),
    ]);
    t.row(vec![
        "# false positive edges".into(),
        without.false_positives.to_string(),
        with.false_positives.to_string(),
    ]);
    println!("\n=== Table III (Judge-before-Parallel) ===");
    println!("{}", t.render());
    (without, with)
}

/// Table IV: feGRASS vs pdGRASS at 1/8/32 threads, α = 0.02.
pub fn table4(names: &[&str], cfg_base: &PipelineConfig) -> Vec<GraphReport> {
    let mut cfg = *cfg_base;
    cfg.alpha = 0.02;
    cfg.evaluate_quality = false;
    cfg.sim_threads = [8, 32];
    let mut t = Table::new(&[
        "Graph", "T_fe", "T_1", "T_fe/T_1", "T_8", "T_1/T_8", "T_32", "T_1/T_32", "T_fe/T_32",
    ]);
    let mut reports = Vec::new();
    for name in names {
        let prepared = prepare_or_die(name, &cfg);
        let r = run_prepared(&prepared, &cfg).unwrap_or_else(|e| panic!("{name}: {e}"));
        t.row(vec![
            r.name.clone(),
            sig3(r.t_fe_ms),
            sig3(r.t_pd1_ms),
            sig3(safe_ratio(r.t_fe_ms, r.t_pd1_ms)),
            sig3(r.t_pd_sim_ms[0]),
            sig3(r.sim_speedup[0]),
            sig3(r.t_pd_sim_ms[1]),
            sig3(r.sim_speedup[1]),
            sig3(safe_ratio(r.t_fe_ms, r.t_pd_sim_ms[1])),
        ]);
        reports.push(r);
    }
    println!("\n=== Table IV (runtimes, alpha = 0.02; T_8/T_32 simulated) ===");
    println!("{}", t.render());
    let s8: Vec<f64> = reports.iter().map(|r| r.sim_speedup[0]).collect();
    let s32: Vec<f64> = reports.iter().map(|r| r.sim_speedup[1]).collect();
    println!(
        "avg parallel speedup: {:.1}x (8t), {:.1}x (32t)",
        s8.iter().sum::<f64>() / s8.len() as f64,
        s32.iter().sum::<f64>() / s32.len() as f64
    );
    reports
}

/// Figs. 6–8: strong-scaling curves. Returns (label, [(p, speedup)]).
pub fn fig6_7_8(cfg: &PipelineConfig) -> Vec<(String, Vec<(usize, f64)>)> {
    let threads = [1usize, 2, 4, 8, 16, 32];
    let mut curves = Vec::new();

    // Fig. 6: uniform input (M6), entire outer parallel part.
    {
        let prepared = prepare_or_die("15-M6", cfg);
        let opts = recover_opts(cfg, 1, Strategy::Serial);
        let r = prepared.recover_traced(&opts).unwrap_or_else(|e| panic!("15-M6: {e}"));
        let trace = r.trace().expect("trace requested");
        let pts: Vec<(usize, f64)> = threads
            .iter()
            .map(|&p| {
                let sim = simulate(trace, &SimParams::new(p));
                (p, sim.speedup())
            })
            .collect();
        curves.push(("fig6: M6 entire outer".to_string(), pts));
    }

    // Figs. 7–8: skewed input (com-Youtube), inner and outer parts.
    {
        let prepared = prepare_or_die("09-com-Youtube", cfg);
        let opts = recover_opts(cfg, 1, Strategy::Serial);
        let r =
            prepared.recover_traced(&opts).unwrap_or_else(|e| panic!("09-com-Youtube: {e}"));
        let trace = r.trace().expect("trace requested");
        let inner: Vec<(usize, f64)> =
            threads.iter().map(|&p| (p, inner_part_speedup(trace, p))).collect();
        curves.push(("fig7: com-Youtube inner part".to_string(), inner));
        let outer: Vec<(usize, f64)> = threads
            .iter()
            .map(|&p| {
                let mut sp_ = SimParams::new(p);
                // the biggest subtask is the inner part; outer covers the rest
                sp_.cutoff_frac = 0.10;
                (p, outer_part_speedup(trace, p, &sp_))
            })
            .collect();
        curves.push(("fig8: com-Youtube outer part".to_string(), outer));
    }

    for (label, pts) in &curves {
        println!("\n=== {label} ===");
        println!("threads,speedup");
        for (p, s) in pts {
            println!("{p},{s:.2}");
        }
    }
    curves
}

/// Per-graph overlap report row: measured prepare wall-times under both
/// stage-handoff disciplines, plus the structural overlap model's
/// makespans at the simulated thread counts.
#[derive(Clone, Debug)]
pub struct OverlapReport {
    /// Suite row name.
    pub name: String,
    /// Off-tree edge count (the streamed stage's input size).
    pub off_tree: usize,
    /// Measured barrier prepare wall, ms, decomposed as
    /// `[spanning, resistance, sort, subtasks]`.
    pub barrier_ms: [f64; 4],
    /// Measured streamed prepare wall, ms, decomposed as
    /// `[spanning, fused annotate+sort, subtasks]`.
    pub streamed_ms: [f64; 3],
    /// Modeled `(barrier, streamed)` makespans in work units at each of
    /// `cfg.sim_threads`.
    pub sim_units: [(u64, u64); 2],
}

/// Barrier vs streamed prepare: measure both disciplines per graph
/// (identical `Prepared` output, asserted structurally) and replay the
/// overlap model at the configured simulated thread counts — the
/// stage-overlap analogue of the Table IV scaling replay.
pub fn pipeline_overlap(names: &[&str], cfg: &PipelineConfig) -> Vec<OverlapReport> {
    let mut t = Table::new(&[
        "Graph", "off-tree", "T_prep_barrier(ms)", "T_prep_streamed(ms)", "sim overlap gain",
    ]);
    let mut reports = Vec::new();
    for name in names {
        let mut bcfg = *cfg;
        bcfg.pipeline = Pipeline::Barrier;
        let barrier = prepare_or_die(name, &bcfg);
        let mut scfg = *cfg;
        scfg.pipeline = Pipeline::Streamed;
        let streamed = prepare_or_die(name, &scfg);
        assert_eq!(
            streamed.num_off_tree(),
            barrier.num_off_tree(),
            "{name}: pipelines disagree on prepared state"
        );
        let off_tree = barrier.num_off_tree();
        let bp = barrier.prep_ms();
        let sp = streamed.prep_ms();
        let barrier_ms = [barrier.spanning_ms(), bp[0], bp[1], bp[2]];
        let streamed_ms = [streamed.spanning_ms(), sp[0], sp[2]];
        let sim = PrepSim::uniform(off_tree, crate::recovery::score::SCORE_CHUNK);
        let mut sim_units = [(0u64, 0u64); 2];
        for (i, &p) in cfg.sim_threads.iter().enumerate() {
            sim_units[i] = (prep_barrier_makespan(&sim, p), prep_streamed_makespan(&sim, p));
        }
        let gain: Vec<String> = cfg
            .sim_threads
            .iter()
            .zip(&sim_units)
            .map(|(p, &(b, s))| format!("{p}t {:.2}x", b as f64 / s.max(1) as f64))
            .collect();
        t.row(vec![
            name.to_string(),
            sci(off_tree as f64),
            sig3(barrier_ms.iter().sum()),
            sig3(streamed_ms.iter().sum()),
            gain.join("  "),
        ]);
        reports.push(OverlapReport {
            name: name.to_string(),
            off_tree,
            barrier_ms,
            streamed_ms,
            sim_units,
        });
    }
    println!("\n=== Pipeline overlap (barrier stage-sum vs streamed) ===");
    println!("{}", t.render());
    reports
}

/// All 18 suite names in paper order.
pub fn suite_names() -> Vec<&'static str> {
    SUITE.iter().map(|e| e.name).collect()
}

fn safe_ratio(a: f64, b: f64) -> f64 {
    if b <= 0.0 {
        f64::NAN
    } else {
        a / b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> PipelineConfig {
        PipelineConfig { scale: 0.02, trials: 1, ..Default::default() }
    }

    #[test]
    fn table4_runs_on_subset() {
        let reports = table4(&["01-mi2010", "15-M6"], &tiny_cfg());
        assert_eq!(reports.len(), 2);
        for r in &reports {
            assert!(r.sim_speedup[1] >= 1.0, "{}: {}", r.name, r.sim_speedup[1]);
        }
    }

    #[test]
    fn fig6_curves_shape() {
        let mut cfg = tiny_cfg();
        cfg.scale = 0.05;
        let curves = fig6_7_8(&cfg);
        assert_eq!(curves.len(), 3);
        // M6 outer curve must scale decently (uniform subtasks)
        let m6 = &curves[0].1;
        let s32 = m6.iter().find(|(p, _)| *p == 32).unwrap().1;
        let s1 = m6.iter().find(|(p, _)| *p == 1).unwrap().1;
        assert!((s1 - 1.0).abs() < 1e-9);
        assert!(s32 > 4.0, "uniform input should scale, got {s32}");
    }

    #[test]
    fn table3_shapes() {
        let mut cfg = tiny_cfg();
        cfg.scale = 0.1;
        let (without, with) = table3(&cfg);
        assert_eq!(with.skipped_in_parallel, 0);
        assert!(without.skipped_in_parallel > 0);
        assert_eq!(with.edges_in_blocks, with.explored_in_parallel);
    }

    #[test]
    fn pipeline_overlap_reports_modeled_gain() {
        let mut cfg = tiny_cfg();
        cfg.scale = 0.3; // large enough that the off-tree list spans many chunks
        let reports = pipeline_overlap(&["07-com-DBLP"], &cfg);
        assert_eq!(reports.len(), 1);
        let r = &reports[0];
        assert!(r.off_tree > 0);
        // Acceptance shape: with chunks outnumbering even the widest
        // simulated worker count, the modeled streamed makespan strictly
        // beats the barrier stage-sum at both simulated thread counts.
        if r.off_tree > 33 * crate::recovery::score::SCORE_CHUNK {
            for &(b, s) in &r.sim_units {
                assert!(s < b, "streamed {s} !< barrier {b}");
            }
        }
        for &(b, s) in &r.sim_units {
            assert!(s <= b, "streamed {s} must never exceed the barrier sum {b}");
        }
    }

    #[test]
    fn alpha_sweeps_prepare_once_per_graph() {
        // Two graphs × two alphas → exactly two prepared sessions; every
        // per-α report for the same graph carries the same session id and
        // bitwise-identical steps-1–3 timings (they were measured once).
        let out = table2(&["01-mi2010", "15-M6"], &[0.02, 0.05], &tiny_cfg());
        assert_eq!(out.len(), 2);
        for gi in 0..2 {
            let a = &out[0].1[gi];
            let b = &out[1].1[gi];
            assert_eq!(a.prepared_id, b.prepared_id, "{}: re-prepared between alphas", a.name);
            assert_eq!(a.step_ms[..3], b.step_ms[..3], "{}: steps 1–3 re-timed", a.name);
        }
        // distinct graphs use distinct sessions
        assert_ne!(out[0].1[0].prepared_id, out[0].1[1].prepared_id);
    }
}
