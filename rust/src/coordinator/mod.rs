//! Coordinator: pipeline orchestration, experiment drivers, and the
//! work–span scheduling simulator (the substitute for the paper's 64-core
//! testbed).

pub mod experiments;
pub mod pipeline;
pub mod schedsim;

pub use pipeline::{run_graph, run_prepared, GraphReport, PipelineConfig};
pub use schedsim::{
    prep_barrier_makespan, prep_streamed_makespan, simulate, PrepSim, SimParams, SimResult,
};
