//! Spanning-tree bundle: ties together effective weights, Kruskal,
//! rooting, and the LCA skip table — "step 1" of both feGRASS and pdGRASS
//! (the paper reuses feGRASS's tree so the recovery comparison is fair;
//! so do we).

use super::effweight::{effective_weights, mst_key_cmp, scored_order_chunks};
use super::lca::SkipTable;
use super::mst::{kruskal_from_order, max_spanning_tree};
use super::rooted::RootedTree;
use crate::graph::Graph;
use crate::par::sort::RunMerger;

/// Everything downstream recovery needs about the spanning tree.
#[derive(Clone, Debug)]
pub struct Spanning {
    /// Rooted tree with depths and resistive depths.
    pub tree: RootedTree,
    /// Binary-lifting LCA table.
    pub skip: SkipTable,
    /// Per-graph-edge flag: is this edge in the tree?
    pub is_tree_edge: Vec<bool>,
    /// BFS root = maximum-degree vertex.
    pub root: u32,
}

/// Build the spanning tree: effective weights (Def. 1) → maximum spanning
/// tree (Kruskal) → root at the max-degree vertex → skip table.
pub fn build_spanning(g: &Graph) -> Spanning {
    let (eff, root) = effective_weights(g);
    let is_tree_edge = max_spanning_tree(g, &eff);
    let tree = RootedTree::build(g, &is_tree_edge, root);
    let skip = SkipTable::build(&tree);
    Spanning { tree, skip, is_tree_edge, root }
}

/// Streamed spanning-tree build: effective-weight scoring chunks are
/// produced on the pool and **merged into the Kruskal order while later
/// chunks are still being scored** — the weight stage and the sort stage
/// overlap instead of barrier-syncing (`tree::effweight::
/// scored_order_chunks` + `par::sort::RunMerger`). The MST key is a
/// strict total order (weight desc, edge id asc), so the merged order —
/// and therefore `is_tree_edge` and everything downstream — is bitwise
/// identical to [`build_spanning`] at every thread count.
pub fn build_spanning_streamed(g: &Graph, threads: usize) -> Spanning {
    let mut merger = RunMerger::new(&mst_key_cmp);
    let root = scored_order_chunks(g, threads, |_, run| merger.push(run));
    let order: Vec<u32> = merger.finish().into_iter().map(|(_, id)| id).collect();
    let is_tree_edge = kruskal_from_order(g, &order);
    let tree = RootedTree::build(g, &is_tree_edge, root);
    let skip = SkipTable::build(&tree);
    Spanning { tree, skip, is_tree_edge, root }
}

impl Spanning {
    /// Number of off-tree edges.
    pub fn num_off_tree(&self) -> usize {
        self.is_tree_edge.iter().filter(|&&b| !b).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::util::Rng;

    #[test]
    fn spans_and_roots_at_max_degree() {
        let g = gen::grid(12, 12, 0.3, &mut Rng::new(3));
        let sp = build_spanning(&g);
        assert_eq!(sp.is_tree_edge.iter().filter(|&&b| b).count(), g.num_vertices() - 1);
        assert_eq!(sp.root, g.max_degree_vertex());
        assert_eq!(sp.tree.root, sp.root);
        assert_eq!(sp.num_off_tree(), g.num_edges() - (g.num_vertices() - 1));
    }

    #[test]
    fn streamed_build_matches_barrier_bitwise() {
        let g = gen::grid(50, 50, 0.4, &mut Rng::new(7));
        let barrier = build_spanning(&g);
        for threads in [1usize, 2, 8] {
            let streamed = build_spanning_streamed(&g, threads);
            assert_eq!(streamed.root, barrier.root, "threads={threads}");
            assert_eq!(streamed.is_tree_edge, barrier.is_tree_edge, "threads={threads}");
            for v in 0..g.num_vertices() {
                assert_eq!(streamed.tree.parent[v], barrier.tree.parent[v], "threads={threads}");
                assert_eq!(
                    streamed.tree.rdepth[v].to_bits(),
                    barrier.tree.rdepth[v].to_bits(),
                    "threads={threads}"
                );
            }
        }
    }

    #[test]
    fn tree_depths_consistent_with_parents() {
        let g = gen::tri_mesh(15, 15, &mut Rng::new(4));
        let sp = build_spanning(&g);
        for v in 0..g.num_vertices() as u32 {
            if v == sp.root {
                assert_eq!(sp.tree.depth[v as usize], 0);
            } else {
                let p = sp.tree.parent[v as usize];
                assert_eq!(sp.tree.depth[v as usize], sp.tree.depth[p as usize] + 1);
                assert!(sp.tree.rdepth[v as usize] > sp.tree.rdepth[p as usize]);
            }
        }
    }
}
