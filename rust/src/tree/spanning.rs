//! Spanning-tree bundle: ties together effective weights, Kruskal,
//! rooting, and the LCA skip table — "step 1" of both feGRASS and pdGRASS
//! (the paper reuses feGRASS's tree so the recovery comparison is fair;
//! so do we).

use super::effweight::effective_weights;
use super::lca::SkipTable;
use super::mst::max_spanning_tree;
use super::rooted::RootedTree;
use crate::graph::Graph;

/// Everything downstream recovery needs about the spanning tree.
#[derive(Clone, Debug)]
pub struct Spanning {
    /// Rooted tree with depths and resistive depths.
    pub tree: RootedTree,
    /// Binary-lifting LCA table.
    pub skip: SkipTable,
    /// Per-graph-edge flag: is this edge in the tree?
    pub is_tree_edge: Vec<bool>,
    /// BFS root = maximum-degree vertex.
    pub root: u32,
}

/// Build the spanning tree: effective weights (Def. 1) → maximum spanning
/// tree (Kruskal) → root at the max-degree vertex → skip table.
pub fn build_spanning(g: &Graph) -> Spanning {
    let (eff, root) = effective_weights(g);
    let is_tree_edge = max_spanning_tree(g, &eff);
    let tree = RootedTree::build(g, &is_tree_edge, root);
    let skip = SkipTable::build(&tree);
    Spanning { tree, skip, is_tree_edge, root }
}

impl Spanning {
    /// Number of off-tree edges.
    pub fn num_off_tree(&self) -> usize {
        self.is_tree_edge.iter().filter(|&&b| !b).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::util::Rng;

    #[test]
    fn spans_and_roots_at_max_degree() {
        let g = gen::grid(12, 12, 0.3, &mut Rng::new(3));
        let sp = build_spanning(&g);
        assert_eq!(sp.is_tree_edge.iter().filter(|&&b| b).count(), g.num_vertices() - 1);
        assert_eq!(sp.root, g.max_degree_vertex());
        assert_eq!(sp.tree.root, sp.root);
        assert_eq!(sp.num_off_tree(), g.num_edges() - (g.num_vertices() - 1));
    }

    #[test]
    fn tree_depths_consistent_with_parents() {
        let g = gen::tri_mesh(15, 15, &mut Rng::new(4));
        let sp = build_spanning(&g);
        for v in 0..g.num_vertices() as u32 {
            if v == sp.root {
                assert_eq!(sp.tree.depth[v as usize], 0);
            } else {
                let p = sp.tree.parent[v as usize];
                assert_eq!(sp.tree.depth[v as usize], sp.tree.depth[p as usize] + 1);
                assert!(sp.tree.rdepth[v as usize] > sp.tree.rdepth[p as usize]);
            }
        }
    }
}
