//! Effective weight of edges (Definition 1 of the paper):
//!
//! `W_eff(e=(u,v)) = w(u,v) · log(max(deg u, deg v)) /
//!                   (dist_G(root,u) + dist_G(root,v))`
//!
//! where `root` is the maximum-degree vertex and distances are unweighted
//! BFS hop counts. The maximum spanning tree under `W_eff` favors heavy
//! edges between high-degree vertices close to the root — feGRASS's
//! low-stretch-ish tree heuristic, kept identical here so the recovery
//! comparison is apples-to-apples (the paper reuses feGRASS's tree).

use super::bfs::bfs_distances;
use crate::graph::Graph;
use crate::par;

/// Effective weights for all edges, in edge-id order, plus the chosen root.
///
/// The per-edge formula evaluation is a `par_fill` on the persistent
/// pool (coarse 4096-index grain: the body is a few loads and an `ln`,
/// so the win is bandwidth, not latency).
pub fn effective_weights(g: &Graph) -> (Vec<f64>, u32) {
    let root = g.max_degree_vertex();
    let dist = bfs_distances(g, root);
    let mut w = vec![0f64; g.num_edges()];
    let edges = g.edges();
    let threads = par::num_threads();
    par::par_fill(&mut w, threads, 4096, |i| {
        let e = edges[i];
        let du = dist[e.u as usize];
        let dv = dist[e.v as usize];
        debug_assert!(du != u32::MAX && dv != u32::MAX, "graph must be connected");
        let maxdeg = g.degree(e.u).max(g.degree(e.v)) as f64;
        // root-root never happens (no self loops); du + dv >= 1.
        let denom = (du + dv) as f64;
        e.w * maxdeg.ln().max(f64::MIN_POSITIVE) / denom
    });
    (w, root)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_weight_formula() {
        // star with one extra edge: root = 0 (degree 3)
        let g = Graph::from_edges(4, &[(0, 1, 2.0), (0, 2, 1.0), (0, 3, 1.0), (1, 2, 4.0)]);
        let (w, root) = effective_weights(&g);
        assert_eq!(root, 0);
        // edge (0,1): dist 0+1, maxdeg = max(3,2)=3 → 2*ln3/1
        let e01 = g.edges().iter().position(|e| (e.u, e.v) == (0, 1)).unwrap();
        assert!((w[e01] - 2.0 * 3f64.ln()).abs() < 1e-12);
        // edge (1,2): dist 1+1, maxdeg = 2 → 4*ln2/2
        let e12 = g.edges().iter().position(|e| (e.u, e.v) == (1, 2)).unwrap();
        assert!((w[e12] - 4.0 * 2f64.ln() / 2.0).abs() < 1e-12);
    }

    #[test]
    fn heavier_edges_get_heavier_effweight() {
        let g = Graph::from_edges(4, &[(0, 1, 1.0), (0, 2, 10.0), (1, 3, 1.0), (2, 3, 1.0)]);
        let (w, _) = effective_weights(&g);
        let light = g.edges().iter().position(|e| (e.u, e.v) == (0, 1)).unwrap();
        let heavy = g.edges().iter().position(|e| (e.u, e.v) == (0, 2)).unwrap();
        assert!(w[heavy] > w[light]);
    }
}
