//! Effective weight of edges (Definition 1 of the paper):
//!
//! `W_eff(e=(u,v)) = w(u,v) · log(max(deg u, deg v)) /
//!                   (dist_G(root,u) + dist_G(root,v))`
//!
//! where `root` is the maximum-degree vertex and distances are unweighted
//! BFS hop counts. The maximum spanning tree under `W_eff` favors heavy
//! edges between high-degree vertices close to the root — feGRASS's
//! low-stretch-ish tree heuristic, kept identical here so the recovery
//! comparison is apples-to-apples (the paper reuses feGRASS's tree).
//!
//! Two evaluation shapes share the same [`effective_weight_at`] formula:
//!
//! * [`effective_weights`] — the barrier path: one `par_fill` over all
//!   edges, the full weight array returned at once.
//! * [`scored_order_chunks`] — the streamed path: fixed 4096-edge chunks
//!   are weighted **and locally sorted into the MST's (weight desc, id
//!   asc) order** on pool workers, and handed to the consumer in
//!   ascending chunk order via [`crate::par::produce_stream`] — so the
//!   spanning-tree build can merge completed runs while later chunks are
//!   still being scored, instead of barrier-syncing weight computation
//!   and sort. The chunk layout depends only on `|E|`, and the sort key
//!   is a strict total order (ties broken by edge id), so both paths
//!   yield the identical Kruskal edge order.

use super::bfs::bfs_distances;
use crate::graph::Graph;
use crate::par;

/// Fixed chunk size for the streamed scoring producer (shape depends
/// only on `|E|`, never on the thread count).
pub const EFF_CHUNK: usize = 4096;

/// Definition-1 effective weight of edge `eid`, given the BFS hop
/// distances from the chosen root.
#[inline]
pub fn effective_weight_at(g: &Graph, dist: &[u32], eid: usize) -> f64 {
    let e = g.edges()[eid];
    let du = dist[e.u as usize];
    let dv = dist[e.v as usize];
    debug_assert!(du != u32::MAX && dv != u32::MAX, "graph must be connected");
    let maxdeg = g.degree(e.u).max(g.degree(e.v)) as f64;
    // root-root never happens (no self loops); du + dv >= 1.
    let denom = (du + dv) as f64;
    e.w * maxdeg.ln().max(f64::MIN_POSITIVE) / denom
}

/// MST ordering over `(W_eff, edge id)` pairs: weight descending, ties by
/// edge id ascending — a strict total order (edge ids are unique), so any
/// correct sort produces the one canonical Kruskal order.
#[inline]
pub fn mst_key_cmp(a: &(f64, u32), b: &(f64, u32)) -> std::cmp::Ordering {
    b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
}

/// Effective weights for all edges, in edge-id order, plus the chosen root.
///
/// The per-edge formula evaluation is a `par_fill` on the persistent
/// pool (coarse 4096-index grain: the body is a few loads and an `ln`,
/// so the win is bandwidth, not latency).
pub fn effective_weights(g: &Graph) -> (Vec<f64>, u32) {
    let root = g.max_degree_vertex();
    let dist = bfs_distances(g, root);
    let mut w = vec![0f64; g.num_edges()];
    let threads = par::num_threads();
    par::par_fill(&mut w, threads, EFF_CHUNK, |i| effective_weight_at(g, &dist, i));
    (w, root)
}

/// Streamed stage-1 scoring: weight every edge chunk-by-chunk on the
/// pool, locally sorted by [`mst_key_cmp`], feeding `consume` with each
/// `(W_eff, edge id)` run in ascending chunk order while later chunks are
/// still in flight. Returns the chosen root.
pub fn scored_order_chunks<C>(g: &Graph, threads: usize, consume: C) -> u32
where
    C: FnMut(usize, Vec<(f64, u32)>) + Send,
{
    let root = g.max_degree_vertex();
    let dist = bfs_distances(g, root);
    let m = g.num_edges();
    par::stream::produce_sorted_runs(
        m,
        EFF_CHUNK,
        threads,
        |eid| (effective_weight_at(g, &dist, eid), eid as u32),
        &mst_key_cmp,
        consume,
    );
    root
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::par::sort::RunMerger;

    #[test]
    fn effective_weight_formula() {
        // star with one extra edge: root = 0 (degree 3)
        let g = Graph::from_edges(4, &[(0, 1, 2.0), (0, 2, 1.0), (0, 3, 1.0), (1, 2, 4.0)]);
        let (w, root) = effective_weights(&g);
        assert_eq!(root, 0);
        // edge (0,1): dist 0+1, maxdeg = max(3,2)=3 → 2*ln3/1
        let e01 = g.edges().iter().position(|e| (e.u, e.v) == (0, 1)).unwrap();
        assert!((w[e01] - 2.0 * 3f64.ln()).abs() < 1e-12);
        // edge (1,2): dist 1+1, maxdeg = 2 → 4*ln2/2
        let e12 = g.edges().iter().position(|e| (e.u, e.v) == (1, 2)).unwrap();
        assert!((w[e12] - 4.0 * 2f64.ln() / 2.0).abs() < 1e-12);
    }

    #[test]
    fn heavier_edges_get_heavier_effweight() {
        let g = Graph::from_edges(4, &[(0, 1, 1.0), (0, 2, 10.0), (1, 3, 1.0), (2, 3, 1.0)]);
        let (w, _) = effective_weights(&g);
        let light = g.edges().iter().position(|e| (e.u, e.v) == (0, 1)).unwrap();
        let heavy = g.edges().iter().position(|e| (e.u, e.v) == (0, 2)).unwrap();
        assert!(w[heavy] > w[light]);
    }

    #[test]
    fn streamed_chunks_reproduce_the_barrier_order() {
        // Large enough that the stream spans several 4096-edge chunks.
        let g = crate::gen::grid(60, 60, 0.5, &mut crate::util::Rng::new(9));
        assert!(g.num_edges() > 2 * EFF_CHUNK, "test graph must span multiple chunks");
        // Barrier order: full weight array, one global sort.
        let (w, root_b) = effective_weights(&g);
        let mut barrier: Vec<u32> = (0..g.num_edges() as u32).collect();
        barrier.sort_by(|&a, &b| mst_key_cmp(&(w[a as usize], a), &(w[b as usize], b)));
        // Streamed order: chunk runs merged as they arrive.
        for threads in [1usize, 2, 8] {
            let mut merger = RunMerger::new(&mst_key_cmp);
            let root_s = scored_order_chunks(&g, threads, |_, run| merger.push(run));
            assert_eq!(root_s, root_b);
            let streamed: Vec<u32> = merger.finish().into_iter().map(|(_, id)| id).collect();
            assert_eq!(streamed, barrier, "threads={threads}");
            // …and the weights agree bitwise with the formula array.
            let mut merger = RunMerger::new(&mst_key_cmp);
            scored_order_chunks(&g, threads, |_, run| merger.push(run));
            for (wt, id) in merger.finish() {
                assert_eq!(wt.to_bits(), w[id as usize].to_bits(), "threads={threads}");
            }
        }
    }
}
