//! Maximum spanning tree (Kruskal over effective weights) + union-find.

use crate::graph::Graph;
use crate::par;

/// Disjoint-set union with path halving and union by rank.
#[derive(Clone, Debug)]
pub struct UnionFind {
    parent: Vec<u32>,
    rank: Vec<u8>,
}

impl UnionFind {
    /// `n` singleton sets.
    pub fn new(n: usize) -> UnionFind {
        UnionFind { parent: (0..n as u32).collect(), rank: vec![0; n] }
    }

    /// Find representative with path halving.
    pub fn find(&mut self, mut x: u32) -> u32 {
        while self.parent[x as usize] != x {
            let gp = self.parent[self.parent[x as usize] as usize];
            self.parent[x as usize] = gp;
            x = gp;
        }
        x
    }

    /// Union by rank; returns false if already in the same set.
    pub fn union(&mut self, a: u32, b: u32) -> bool {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        let (ra, rb) = if self.rank[ra as usize] < self.rank[rb as usize] {
            (rb, ra)
        } else {
            (ra, rb)
        };
        self.parent[rb as usize] = ra;
        if self.rank[ra as usize] == self.rank[rb as usize] {
            self.rank[ra as usize] += 1;
        }
        true
    }

    /// Are `a` and `b` in the same set?
    pub fn same(&mut self, a: u32, b: u32) -> bool {
        self.find(a) == self.find(b)
    }
}

/// Kruskal maximum spanning tree under per-edge `keys`.
///
/// Returns `is_tree_edge` flags (len |E|). Panics if the graph is
/// disconnected (the pipeline extracts the largest component first).
pub fn max_spanning_tree(g: &Graph, keys: &[f64]) -> Vec<bool> {
    let m = g.num_edges();
    assert_eq!(keys.len(), m);
    let mut order: Vec<u32> = (0..m as u32).collect();
    // Descending by key; stable so equal-key edges keep id order (matches
    // the serial feGRASS implementation's deterministic tie-break). The
    // sort moves the u32 ids through its scratch buffer — no clones.
    par::sort::par_sort_by(&mut order, par::num_threads(), &|&a, &b| {
        keys[b as usize]
            .partial_cmp(&keys[a as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    kruskal_from_order(g, &order)
}

/// The Kruskal union-find sweep over an already-sorted edge order
/// (best-first). Split out of [`max_spanning_tree`] so the streamed
/// spanning-tree build — which merges (weight, id) runs while weights are
/// still being scored — can feed its merged order straight in without
/// materializing a key array.
///
/// Panics if the graph is disconnected.
pub fn kruskal_from_order(g: &Graph, order: &[u32]) -> Vec<bool> {
    let mut uf = UnionFind::new(g.num_vertices());
    let mut in_tree = vec![false; g.num_edges()];
    let mut picked = 0usize;
    let need = g.num_vertices() - 1;
    for &id in order {
        let e = g.edge(id);
        if uf.union(e.u, e.v) {
            in_tree[id as usize] = true;
            picked += 1;
            if picked == need {
                break;
            }
        }
    }
    assert_eq!(picked, need, "graph is disconnected: {picked} < {need} tree edges");
    in_tree
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn union_find_basics() {
        let mut uf = UnionFind::new(5);
        assert!(uf.union(0, 1));
        assert!(uf.union(1, 2));
        assert!(!uf.union(0, 2));
        assert!(uf.same(0, 2));
        assert!(!uf.same(0, 3));
    }

    #[test]
    fn picks_max_tree() {
        // square with diagonal; keys favor the diagonal + two heavy sides
        let g = Graph::from_edges(
            4,
            &[(0, 1, 1.0), (1, 2, 1.0), (2, 3, 1.0), (3, 0, 1.0), (0, 2, 1.0)],
        );
        let keys = vec![5.0, 1.0, 4.0, 3.0, 10.0];
        let t = max_spanning_tree(&g, &keys);
        assert_eq!(t.iter().filter(|&&b| b).count(), 3);
        assert!(t[4]); // diagonal (key 10)
        assert!(t[0]); // key 5
        assert!(t[2]); // key 4
        assert!(!t[1] && !t[3]);
    }

    #[test]
    fn tree_of_tree_is_identity() {
        let g = Graph::from_edges(4, &[(0, 1, 1.0), (1, 2, 2.0), (2, 3, 0.5)]);
        let keys: Vec<f64> = g.edges().iter().map(|e| e.w).collect();
        let t = max_spanning_tree(&g, &keys);
        assert!(t.iter().all(|&b| b));
    }

    #[test]
    #[should_panic(expected = "disconnected")]
    fn disconnected_panics() {
        let g = Graph::from_edges(4, &[(0, 1, 1.0), (2, 3, 1.0)]);
        let keys = vec![1.0, 1.0];
        max_spanning_tree(&g, &keys);
    }
}
