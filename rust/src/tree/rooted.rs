//! Rooted spanning tree representation.
//!
//! After Kruskal picks the tree edges, we root the tree at the
//! maximum-degree vertex (the same root used for effective weights) and
//! precompute, per vertex:
//!
//! * `parent` and the weight of the parent edge,
//! * `depth` — unweighted hop depth (for LCA and β* caps),
//! * `rdepth` — *resistive* depth `Σ 1/w` along the root path, so the
//!   resistance distance of Definition 2 is
//!   `R_T(u,v) = rdepth(u) + rdepth(v) − 2·rdepth(lca)`,
//! * a children-CSR so β-hop tree BFS (similarity neighborhoods) is cheap.

use crate::graph::Graph;

/// Rooted spanning tree with per-vertex ancestry data.
#[derive(Clone, Debug)]
pub struct RootedTree {
    /// Root vertex id.
    pub root: u32,
    /// Parent of each vertex (`parent[root] == root`).
    pub parent: Vec<u32>,
    /// Weight of the edge to the parent (`0` for the root).
    pub parent_w: Vec<f64>,
    /// Unweighted depth from the root.
    pub depth: Vec<u32>,
    /// Resistive depth: `Σ 1/w` along the root path.
    pub rdepth: Vec<f64>,
    /// BFS order from the root (root first).
    pub order: Vec<u32>,
    /// Children CSR offsets (compact u32 — a tree has `n − 1` slots).
    cxadj: Vec<u32>,
    /// Children CSR ids.
    cadj: Vec<u32>,
}

impl RootedTree {
    /// Build the rooted tree from `is_tree_edge` flags over `g`'s edges.
    pub fn build(g: &Graph, is_tree_edge: &[bool], root: u32) -> RootedTree {
        let n = g.num_vertices();
        assert_eq!(is_tree_edge.len(), g.num_edges());
        // Tree adjacency restricted to tree edges.
        let mut parent = vec![u32::MAX; n];
        let mut parent_w = vec![0f64; n];
        let mut depth = vec![0u32; n];
        let mut rdepth = vec![0f64; n];
        let mut order = Vec::with_capacity(n);
        parent[root as usize] = root;
        order.push(root);
        let mut head = 0usize;
        while head < order.len() {
            let u = order[head];
            head += 1;
            for (v, w, eid) in g.neighbors(u) {
                if is_tree_edge[eid as usize] && parent[v as usize] == u32::MAX {
                    parent[v as usize] = u;
                    parent_w[v as usize] = w;
                    depth[v as usize] = depth[u as usize] + 1;
                    rdepth[v as usize] = rdepth[u as usize] + 1.0 / w;
                    order.push(v);
                }
            }
        }
        assert_eq!(order.len(), n, "tree does not span the graph");
        // children CSR
        let mut cnt = vec![0u32; n];
        for v in 0..n as u32 {
            if v != root {
                cnt[parent[v as usize] as usize] += 1;
            }
        }
        let mut cxadj = vec![0u32; n + 1];
        for i in 0..n {
            cxadj[i + 1] = cxadj[i] + cnt[i];
        }
        let mut cadj = vec![0u32; n - 1];
        let mut cur = cxadj.clone();
        for &v in &order {
            if v != root {
                let p = parent[v as usize] as usize;
                cadj[cur[p] as usize] = v;
                cur[p] += 1;
            }
        }
        RootedTree { root, parent, parent_w, depth, rdepth, order, cxadj, cadj }
    }

    /// Reassemble a tree from its per-vertex arrays (snapshot load path).
    ///
    /// The children CSR is derived here with the same counting-sort fill
    /// `build` uses, so a tree round-tripped through flat arrays is
    /// field-for-field identical to the original — `children()` order
    /// included. Callers (the snapshot decoder) must have validated the
    /// arrays first: equal lengths, in-range parents, `parent[root] ==
    /// root`, and `order` a root-first traversal in which every
    /// non-root's parent precedes it.
    pub fn from_parts(
        root: u32,
        parent: Vec<u32>,
        parent_w: Vec<f64>,
        depth: Vec<u32>,
        rdepth: Vec<f64>,
        order: Vec<u32>,
    ) -> RootedTree {
        let n = parent.len();
        let mut cnt = vec![0u32; n];
        for v in 0..n as u32 {
            if v != root {
                cnt[parent[v as usize] as usize] += 1;
            }
        }
        let mut cxadj = vec![0u32; n + 1];
        for i in 0..n {
            cxadj[i + 1] = cxadj[i] + cnt[i];
        }
        let mut cadj = vec![0u32; n.saturating_sub(1)];
        let mut cur = cxadj.clone();
        for &v in &order {
            if v != root {
                let p = parent[v as usize] as usize;
                cadj[cur[p] as usize] = v;
                cur[p] += 1;
            }
        }
        RootedTree { root, parent, parent_w, depth, rdepth, order, cxadj, cadj }
    }

    /// Number of vertices.
    pub fn len(&self) -> usize {
        self.parent.len()
    }

    /// True when the tree has no vertices.
    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }

    /// Children of `v`.
    pub fn children(&self, v: u32) -> &[u32] {
        &self.cadj[self.cxadj[v as usize] as usize..self.cxadj[v as usize + 1] as usize]
    }

    /// Tree-adjacent vertices of `v` (parent, then children).
    pub fn tree_neighbors(&self, v: u32) -> impl Iterator<Item = u32> + '_ {
        let p = self.parent[v as usize];
        let par = if p == v { None } else { Some(p) };
        par.into_iter().chain(self.children(v).iter().copied())
    }

    /// β-hop tree neighborhood of `u` (all vertices within `beta` tree
    /// hops, including `u`), via bounded BFS. Used by both similarity
    /// conditions (Definitions 4 and 5).
    pub fn neighborhood(&self, u: u32, beta: u32) -> Vec<u32> {
        let mut out = vec![u];
        if beta == 0 {
            return out;
        }
        // Tree BFS is cycle-free apart from the parent pointer, so a
        // "came-from" check replaces a visited set.
        let mut frontier: Vec<(u32, u32)> = vec![(u, u)]; // (vertex, from)
        for _ in 0..beta {
            let mut next = Vec::new();
            for &(v, from) in &frontier {
                for nb in self.tree_neighbors(v) {
                    if nb != from {
                        out.push(nb);
                        next.push((nb, v));
                    }
                }
            }
            if next.is_empty() {
                break;
            }
            frontier = next;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Path 0-1-2-3 with weights 1, 2, 4 → rooted at 0.
    fn path_tree() -> (Graph, RootedTree) {
        let g = Graph::from_edges(4, &[(0, 1, 1.0), (1, 2, 2.0), (2, 3, 4.0)]);
        let t = RootedTree::build(&g, &[true, true, true], 0);
        (g, t)
    }

    #[test]
    fn depths_and_parents() {
        let (_, t) = path_tree();
        assert_eq!(t.parent, vec![0, 0, 1, 2]);
        assert_eq!(t.depth, vec![0, 1, 2, 3]);
        assert_eq!(t.rdepth, vec![0.0, 1.0, 1.5, 1.75]);
        assert_eq!(t.order[0], 0);
    }

    #[test]
    fn children_csr() {
        let g = Graph::from_edges(5, &[(0, 1, 1.0), (0, 2, 1.0), (2, 3, 1.0), (2, 4, 1.0)]);
        let t = RootedTree::build(&g, &[true; 4], 0);
        let mut c0 = t.children(0).to_vec();
        c0.sort();
        assert_eq!(c0, vec![1, 2]);
        let mut c2 = t.children(2).to_vec();
        c2.sort();
        assert_eq!(c2, vec![3, 4]);
        assert!(t.children(1).is_empty());
    }

    #[test]
    fn tree_neighbors_parent_and_children() {
        let (_, t) = path_tree();
        let n1: Vec<u32> = t.tree_neighbors(1).collect();
        assert_eq!(n1, vec![0, 2]);
        let n0: Vec<u32> = t.tree_neighbors(0).collect();
        assert_eq!(n0, vec![1]); // root has no parent
    }

    #[test]
    fn neighborhood_hops() {
        let (_, t) = path_tree();
        let mut nb = t.neighborhood(1, 1);
        nb.sort();
        assert_eq!(nb, vec![0, 1, 2]);
        let mut nb2 = t.neighborhood(0, 2);
        nb2.sort();
        assert_eq!(nb2, vec![0, 1, 2]);
        assert_eq!(t.neighborhood(3, 0), vec![3]);
    }

    #[test]
    fn from_parts_round_trips_build_exactly() {
        let g = Graph::from_edges(
            6,
            &[(0, 1, 1.0), (0, 2, 2.0), (2, 3, 1.5), (2, 4, 0.5), (4, 5, 3.0)],
        );
        let t = RootedTree::build(&g, &[true; 5], 2);
        let r = RootedTree::from_parts(
            t.root,
            t.parent.clone(),
            t.parent_w.clone(),
            t.depth.clone(),
            t.rdepth.clone(),
            t.order.clone(),
        );
        assert_eq!(r.root, t.root);
        assert_eq!(r.parent, t.parent);
        assert_eq!(r.parent_w, t.parent_w);
        assert_eq!(r.depth, t.depth);
        assert_eq!(r.rdepth, t.rdepth);
        assert_eq!(r.order, t.order);
        // The derived children CSR must match too — order included.
        for v in 0..t.len() as u32 {
            assert_eq!(r.children(v), t.children(v), "children of {v}");
        }
    }

    #[test]
    fn skips_off_tree_edges() {
        // square: tree = 3 edges, off-tree edge (0,3) excluded from BFS.
        // NB: from_edges canonicalizes edge order to (0,1),(0,3),(1,2),(2,3).
        let g = Graph::from_edges(4, &[(0, 1, 1.0), (1, 2, 1.0), (2, 3, 1.0), (0, 3, 1.0)]);
        let t = RootedTree::build(&g, &[true, false, true, true], 0);
        assert_eq!(t.depth[3], 3);
        let mut nb = t.neighborhood(0, 1);
        nb.sort();
        assert_eq!(nb, vec![0, 1]); // 3 is NOT a tree neighbor of 0
    }
}
