//! Resistance distance of off-tree edges (Definition 2) and spectral
//! criticality scoring.
//!
//! For an off-tree edge `e = (u, v)` with spanning-tree LCA `l`:
//! `R_T(u,v) = dist_re(u,l) + dist_re(v,l)` where resistive weights are
//! `1/w`. With precomputed resistive depths this is
//! `rdepth(u) + rdepth(v) − 2·rdepth(l)` — one LCA query per edge
//! (Table I step 1: `O(|E| lg |V|)` work, `O(lg² |V|)` span).
//!
//! The recovery order uses the *criticality* `w(e) · R_T(e)` — the
//! approximate leverage score / stretch of the edge over the tree, which
//! is how feGRASS ranks spectrally-critical edges.

use super::spanning::Spanning;
use crate::graph::Graph;
use crate::par;

/// An off-tree edge annotated with its LCA and resistance data.
#[derive(Clone, Copy, Debug)]
pub struct OffTreeEdge {
    /// Edge id in the graph's edge list.
    pub eid: u32,
    /// Endpoint (canonical `u < v`).
    pub u: u32,
    /// Endpoint.
    pub v: u32,
    /// Weight.
    pub w: f64,
    /// LCA of `u` and `v` on the spanning tree.
    pub lca: u32,
    /// Resistance distance `R_T(u, v)`.
    pub resistance: f64,
    /// Criticality score `w · R_T` (recovery priority, descending).
    pub score: f64,
}

/// Annotate one off-tree edge: LCA query, resistance distance, and the
/// criticality score. A pure function of `(g, sp, eid)` — the barrier
/// `par_map` and the streamed chunk producer share it, so both pipelines
/// compute bitwise-identical annotations.
#[inline]
pub fn annotate_off_tree_edge(g: &Graph, sp: &Spanning, eid: u32) -> OffTreeEdge {
    let e = g.edge(eid);
    let lca = sp.skip.lca(e.u, e.v);
    let resistance = sp.tree.rdepth[e.u as usize] + sp.tree.rdepth[e.v as usize]
        - 2.0 * sp.tree.rdepth[lca as usize];
    OffTreeEdge { eid, u: e.u, v: e.v, w: e.w, lca, resistance, score: e.w * resistance }
}

/// Annotate every off-tree edge with LCA, resistance and score.
/// Order matches the graph edge-list order (filtered to off-tree).
pub fn off_tree_edges(g: &Graph, sp: &Spanning) -> Vec<OffTreeEdge> {
    let ids: Vec<u32> = (0..g.num_edges() as u32)
        .filter(|&i| !sp.is_tree_edge[i as usize])
        .collect();
    let threads = par::num_threads();
    par::par_map(&ids, threads, |&eid| annotate_off_tree_edge(g, sp, eid))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::spanning::build_spanning;

    #[test]
    fn square_with_diagonal() {
        // 0-1-2-3 path is the tree (heavy weights); off-tree: (0,3), (0,2)
        let g = Graph::from_edges(
            4,
            &[(0, 1, 10.0), (1, 2, 10.0), (2, 3, 10.0), (0, 3, 0.1), (0, 2, 0.2)],
        );
        let sp = build_spanning(&g);
        assert_eq!(sp.is_tree_edge.iter().filter(|&&b| b).count(), 3);
        let off = off_tree_edges(&g, &sp);
        assert_eq!(off.len(), 2);
        for e in &off {
            // tree is the path; R_T = path resistance between endpoints
            let hops = (e.v - e.u) as f64;
            assert!((e.resistance - hops * 0.1).abs() < 1e-9, "{e:?}");
            assert!((e.score - e.w * e.resistance).abs() < 1e-12);
        }
    }

    #[test]
    fn lca_assignment() {
        //     0
        //    / \    tree edges heavy; off-tree (3,4) has LCA 0
        //   1   2
        //   |   |
        //   3   4
        let g = Graph::from_edges(
            5,
            &[(0, 1, 5.0), (0, 2, 5.0), (1, 3, 5.0), (2, 4, 5.0), (3, 4, 0.01)],
        );
        let sp = build_spanning(&g);
        let off = off_tree_edges(&g, &sp);
        assert_eq!(off.len(), 1);
        assert_eq!(off[0].lca, 0);
        assert!((off[0].resistance - 4.0 * 0.2).abs() < 1e-9);
    }
}
