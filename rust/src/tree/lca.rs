//! Binary-lifting LCA skip table (step 1 of Algorithm 1).
//!
//! The paper computes off-tree edge LCAs dynamically (footnote 3: no tree
//! partitioning / offline Tarjan needed) with a skip table: `up[k][v]` is
//! the `2^k`-th ancestor of `v`. Construction is `O(n log n)` work and the
//! level-by-level fill parallelizes (`O(lg² V)` span, Table I row 1).

use super::rooted::RootedTree;
use crate::par;

/// Binary-lifting ancestor table over a rooted tree.
#[derive(Clone, Debug)]
pub struct SkipTable {
    /// `up[k][v]` = 2^k-th ancestor of `v` (saturating at the root).
    up: Vec<Vec<u32>>,
    /// Unweighted depths (copied from the tree for cache-friendly queries).
    depth: Vec<u32>,
}

impl SkipTable {
    /// Build the table; `levels = ceil(log2(max_depth + 1)) + 1`.
    pub fn build(tree: &RootedTree) -> SkipTable {
        let n = tree.len();
        let max_depth = tree.depth.iter().copied().max().unwrap_or(0);
        let levels = (32 - max_depth.leading_zeros()).max(1) as usize;
        let mut up: Vec<Vec<u32>> = Vec::with_capacity(levels);
        up.push(tree.parent.clone());
        let threads = par::num_threads();
        for k in 1..levels {
            let prev = &up[k - 1];
            let mut next = vec![0u32; n];
            par::par_fill(&mut next, threads, 8192, |v| {
                prev[prev[v] as usize]
            });
            up.push(next);
        }
        SkipTable { up, depth: tree.depth.clone() }
    }

    /// Number of levels in the table.
    pub fn levels(&self) -> usize {
        self.up.len()
    }

    /// The `d`-th ancestor of `v` (saturating at the root).
    pub fn ancestor(&self, mut v: u32, mut d: u32) -> u32 {
        let mut k = 0;
        while d > 0 {
            if d & 1 == 1 {
                v = self.up[k.min(self.up.len() - 1)][v as usize];
            }
            d >>= 1;
            k += 1;
        }
        v
    }

    /// Lowest common ancestor of `u` and `v`.
    pub fn lca(&self, mut u: u32, mut v: u32) -> u32 {
        let (du, dv) = (self.depth[u as usize], self.depth[v as usize]);
        if du > dv {
            u = self.ancestor(u, du - dv);
        } else if dv > du {
            v = self.ancestor(v, dv - du);
        }
        if u == v {
            return u;
        }
        for k in (0..self.up.len()).rev() {
            let (au, av) = (self.up[k][u as usize], self.up[k][v as usize]);
            if au != av {
                u = au;
                v = av;
            }
        }
        self.up[0][u as usize]
    }

    /// Unweighted tree distance between `u` and `v`.
    pub fn dist(&self, u: u32, v: u32) -> u32 {
        let l = self.lca(u, v);
        self.depth[u as usize] + self.depth[v as usize] - 2 * self.depth[l as usize]
    }

    /// Depth accessor.
    pub fn depth(&self, v: u32) -> u32 {
        self.depth[v as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::tree::RootedTree;
    use crate::util::Rng;

    /// Balanced-ish test tree:
    ///        0
    ///       / \
    ///      1   2
    ///     / \   \
    ///    3   4   5
    ///   /
    ///  6
    fn sample() -> (RootedTree, SkipTable) {
        let g = Graph::from_edges(
            7,
            &[(0, 1, 1.0), (0, 2, 1.0), (1, 3, 1.0), (1, 4, 1.0), (2, 5, 1.0), (3, 6, 1.0)],
        );
        let t = RootedTree::build(&g, &[true; 6], 0);
        let s = SkipTable::build(&t);
        (t, s)
    }

    #[test]
    fn ancestors() {
        let (_, s) = sample();
        assert_eq!(s.ancestor(6, 1), 3);
        assert_eq!(s.ancestor(6, 2), 1);
        assert_eq!(s.ancestor(6, 3), 0);
        assert_eq!(s.ancestor(6, 10), 0); // saturates
        assert_eq!(s.ancestor(0, 5), 0);
    }

    #[test]
    fn lca_cases() {
        let (_, s) = sample();
        assert_eq!(s.lca(3, 4), 1);
        assert_eq!(s.lca(6, 4), 1);
        assert_eq!(s.lca(6, 5), 0);
        assert_eq!(s.lca(1, 6), 1); // ancestor case
        assert_eq!(s.lca(2, 2), 2); // identity
        assert_eq!(s.lca(0, 5), 0);
    }

    #[test]
    fn dist_cases() {
        let (_, s) = sample();
        assert_eq!(s.dist(3, 4), 2);
        assert_eq!(s.dist(6, 5), 5);
        assert_eq!(s.dist(0, 6), 3);
        assert_eq!(s.dist(4, 4), 0);
    }

    /// Property: LCA from the skip table matches a naive parent-walk LCA
    /// on random trees.
    #[test]
    fn matches_naive_on_random_trees() {
        crate::util::proptest::check_default("lca_naive", |rng: &mut Rng| {
            let n = 2 + rng.below(300);
            // random attachment tree
            let mut edges = Vec::with_capacity(n - 1);
            for v in 1..n {
                let p = rng.below(v);
                edges.push((p as u32, v as u32, 1.0 + rng.next_f64()));
            }
            let g = Graph::from_edges(n, &edges);
            let flags = vec![true; g.num_edges()];
            let t = RootedTree::build(&g, &flags, 0);
            let s = SkipTable::build(&t);
            for _ in 0..50 {
                let u = rng.below(n) as u32;
                let v = rng.below(n) as u32;
                let naive = naive_lca(&t, u, v);
                if s.lca(u, v) != naive {
                    return Err(format!("lca({u},{v}) = {} != naive {naive}", s.lca(u, v)));
                }
            }
            Ok(())
        });
    }

    fn naive_lca(t: &RootedTree, mut u: u32, mut v: u32) -> u32 {
        while t.depth[u as usize] > t.depth[v as usize] {
            u = t.parent[u as usize];
        }
        while t.depth[v as usize] > t.depth[u as usize] {
            v = t.parent[v as usize];
        }
        while u != v {
            u = t.parent[u as usize];
            v = t.parent[v as usize];
        }
        u
    }
}
