//! Unweighted breadth-first search over the graph.
//!
//! Definition 1 (effective weight) needs `dist_G(root, ·)` — unweighted
//! hop distances from the maximum-degree root.

use crate::graph::Graph;

/// Hop distances from `root`; unreachable vertices get `u32::MAX`.
pub fn bfs_distances(g: &Graph, root: u32) -> Vec<u32> {
    let n = g.num_vertices();
    let mut dist = vec![u32::MAX; n];
    let mut queue = std::collections::VecDeque::with_capacity(n / 4 + 1);
    dist[root as usize] = 0;
    queue.push_back(root);
    while let Some(u) = queue.pop_front() {
        let du = dist[u as usize];
        for &v in g.neighbor_ids(u) {
            if dist[v as usize] == u32::MAX {
                dist[v as usize] = du + 1;
                queue.push_back(v);
            }
        }
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_distances() {
        let g = Graph::from_edges(4, &[(0, 1, 1.0), (1, 2, 1.0), (2, 3, 1.0)]);
        assert_eq!(bfs_distances(&g, 0), vec![0, 1, 2, 3]);
        assert_eq!(bfs_distances(&g, 2), vec![2, 1, 0, 1]);
    }

    #[test]
    fn unreachable_is_max() {
        let g = Graph::from_edges(3, &[(0, 1, 1.0)]);
        let d = bfs_distances(&g, 0);
        assert_eq!(d[2], u32::MAX);
    }

    #[test]
    fn ignores_weights() {
        let g = Graph::from_edges(3, &[(0, 1, 100.0), (1, 2, 0.001)]);
        assert_eq!(bfs_distances(&g, 0), vec![0, 1, 2]);
    }
}
