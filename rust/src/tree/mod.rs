//! Spanning-tree substrate: BFS, effective weights (Def. 1), maximum
//! spanning tree, rooted representation, binary-lifting LCA, resistance
//! distances (Def. 2).

pub mod bfs;
pub mod effweight;
pub mod lca;
pub mod mst;
pub mod resistance;
pub mod rooted;
pub mod spanning;

pub use bfs::bfs_distances;
pub use effweight::effective_weights;
pub use lca::SkipTable;
pub use mst::{kruskal_from_order, max_spanning_tree, UnionFind};
pub use resistance::{annotate_off_tree_edge, off_tree_edges, OffTreeEdge};
pub use rooted::RootedTree;
pub use spanning::{build_spanning, build_spanning_streamed, Spanning};
