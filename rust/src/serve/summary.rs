//! Structured per-request run summaries and daemon counters.
//!
//! Every handled request — success or failure — emits one JSON line to
//! the summary sink: request id and verb, graph fingerprint (when
//! resolved), cache `hit`/`miss`, per-stage timings, and outcome. This
//! is where *non-deterministic* observability lives: response bodies are
//! restricted to deterministic content so identical requests stay
//! byte-identical (see `protocol`), and anything wall-clock-shaped —
//! timings, hit/miss, error text — goes here and into the `stats` verb.
//!
//! ```json
//! {"ts_ms":5123,"id":2,"verb":"recover","fingerprint":"0x9ae1…","cache":"hit",
//!  "ok":true,"recovered":410,"prepare_ms":0.0,"recover_ms":3.2,"pcg_ms":0.0,"total_ms":3.4}
//! ```
//!
//! The sink is selected by `[serve] log`: `"stderr"` (default, keeps
//! stdout clean for the CLI), `"off"`, or a file path (appended,
//! created on demand).

use std::io::Write;
use std::sync::Mutex;
use std::time::Instant;

use super::json::{int, num, obj, str as jstr, Value};
use crate::graph::fingerprint_hex;

/// Everything one request contributes to the summary log. Fields left
/// at their defaults are omitted from the line.
#[derive(Debug, Default)]
pub struct RequestSummary {
    pub id: Option<u64>,
    pub verb: &'static str,
    pub fingerprint: Option<u64>,
    /// `Some(true)` = served from cache, `Some(false)` = miss (prepared
    /// on demand), `None` = not a cache-addressed verb.
    pub cache_hit: Option<bool>,
    /// Snapshot warm-start outcome on a cache miss, when `[serve]
    /// snapshot_dir` is configured: `"hit"` (loaded, steps 1–3 skipped),
    /// `"miss"` (no file; full prepare), or `"load-failure"` (file
    /// present but rejected; full prepare). `None` when snapshotting is
    /// off or the cache already held the state.
    pub snapshot: Option<&'static str>,
    pub ok: bool,
    /// Wire error kind when `!ok` (e.g. `"overloaded"`).
    pub error: Option<String>,
    pub prepare_ms: f64,
    pub recover_ms: f64,
    pub pcg_ms: f64,
    pub total_ms: f64,
    /// Recovered edge count (recover/pcg verbs).
    pub recovered: Option<usize>,
    /// PCG iterations (pcg verb).
    pub iterations: Option<usize>,
}

impl RequestSummary {
    /// Render the JSON line (without trailing newline). `ts_ms` is
    /// daemon uptime at emit — relative time, so logs are comparable
    /// across runs.
    pub fn render(&self, ts_ms: u64) -> String {
        let mut fields: Vec<(&str, Value)> = vec![
            ("ts_ms", int(ts_ms)),
            ("id", self.id.map(int).unwrap_or(Value::Null)),
            ("verb", jstr(self.verb)),
        ];
        if let Some(fp) = self.fingerprint {
            fields.push(("fingerprint", jstr(fingerprint_hex(fp))));
        }
        if let Some(hit) = self.cache_hit {
            fields.push(("cache", jstr(if hit { "hit" } else { "miss" })));
        }
        if let Some(snap) = self.snapshot {
            fields.push(("snapshot", jstr(snap)));
        }
        fields.push(("ok", Value::Bool(self.ok)));
        if let Some(e) = &self.error {
            fields.push(("error", jstr(e.clone())));
        }
        if let Some(n) = self.recovered {
            fields.push(("recovered", int(n as u64)));
        }
        if let Some(n) = self.iterations {
            fields.push(("iterations", int(n as u64)));
        }
        fields.push(("prepare_ms", num(round3(self.prepare_ms))));
        fields.push(("recover_ms", num(round3(self.recover_ms))));
        fields.push(("pcg_ms", num(round3(self.pcg_ms))));
        fields.push(("total_ms", num(round3(self.total_ms))));
        obj(fields).render()
    }
}

fn round3(ms: f64) -> f64 {
    (ms * 1000.0).round() / 1000.0
}

enum Sink {
    Off,
    Stderr,
    File(Box<std::fs::File>),
}

/// Serialized summary sink: one line per request, whole lines only (the
/// mutex spans the write, so concurrent handlers never interleave
/// mid-line).
pub struct SummaryLog {
    sink: Mutex<Sink>,
    started: Instant,
}

impl SummaryLog {
    /// Open the sink named by the `[serve] log` config value.
    pub fn open(target: &str) -> std::io::Result<SummaryLog> {
        let sink = match target {
            "off" => Sink::Off,
            "stderr" => Sink::Stderr,
            path => {
                let file = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
                Sink::File(Box::new(file))
            }
        };
        Ok(SummaryLog { sink: Mutex::new(sink), started: Instant::now() })
    }

    /// Milliseconds since the log (≈ the daemon) started.
    pub fn uptime_ms(&self) -> u64 {
        self.started.elapsed().as_millis() as u64
    }

    /// Emit one summary line. I/O errors are swallowed: observability
    /// must never fail a request.
    pub fn emit(&self, summary: &RequestSummary) {
        let line = summary.render(self.uptime_ms());
        let mut sink = self.sink.lock().unwrap();
        let _ = match &mut *sink {
            Sink::Off => Ok(()),
            Sink::Stderr => writeln!(std::io::stderr(), "{line}"),
            Sink::File(f) => writeln!(f, "{line}"),
        };
    }
}

/// Per-verb request counters for the `stats` verb. Mutex-only, like the
/// other serve bookkeeping.
#[derive(Default)]
pub struct ServerCounters {
    inner: Mutex<Counters>,
}

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Counters {
    pub prepare: u64,
    pub recover: u64,
    pub pcg: u64,
    pub stats: u64,
    pub evict: u64,
    pub errors: u64,
    pub overloaded: u64,
    pub deadline_exceeded: u64,
}

impl ServerCounters {
    /// Count one handled request by verb name; failures also bump
    /// `errors` and the dedicated overload/deadline counters by kind.
    pub fn record(&self, verb: &str, error_kind: Option<&str>) {
        let mut c = self.inner.lock().unwrap();
        match verb {
            "prepare" => c.prepare += 1,
            "recover" => c.recover += 1,
            "pcg" => c.pcg += 1,
            "stats" => c.stats += 1,
            "evict" => c.evict += 1,
            _ => {}
        }
        if let Some(kind) = error_kind {
            c.errors += 1;
            match kind {
                "overloaded" => c.overloaded += 1,
                "deadline_exceeded" => c.deadline_exceeded += 1,
                _ => {}
            }
        }
    }

    /// Counter snapshot.
    pub fn snapshot(&self) -> Counters {
        *self.inner.lock().unwrap()
    }
}

/// Warm-start bookkeeping for the `[serve] snapshot_dir` path,
/// surfaced by the `stats` verb. Mutex-only, like the other serve
/// bookkeeping (no new atomics — the audit allowlist stays untouched).
#[derive(Default)]
pub struct SnapshotCounters {
    inner: Mutex<SnapStats>,
}

/// Snapshot warm-start counters: all cache misses with snapshotting
/// enabled fall into exactly one of `hits` / `misses` / `load_failures`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SnapStats {
    /// Cache misses answered by a validated snapshot load.
    pub hits: u64,
    /// Cache misses with no snapshot file on disk (full prepare).
    pub misses: u64,
    /// Cache misses where a snapshot file existed but was rejected
    /// (corrupt, stale version, wrong fingerprint) — fell back to a full
    /// prepare without poisoning anything.
    pub load_failures: u64,
    /// Snapshots written back after a full prepare.
    pub saves: u64,
}

impl SnapshotCounters {
    /// Count a warm load.
    pub fn record_hit(&self) {
        self.inner.lock().unwrap().hits += 1;
    }

    /// Count a probe that found no snapshot file.
    pub fn record_miss(&self) {
        self.inner.lock().unwrap().misses += 1;
    }

    /// Count a rejected snapshot file (typed fall-back to full prepare).
    pub fn record_load_failure(&self) {
        self.inner.lock().unwrap().load_failures += 1;
    }

    /// Count a snapshot written back to the directory.
    pub fn record_save(&self) {
        self.inner.lock().unwrap().saves += 1;
    }

    /// Counter snapshot.
    pub fn snapshot(&self) -> SnapStats {
        *self.inner.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::json;

    #[test]
    fn summary_line_is_valid_json_with_expected_fields() {
        let s = RequestSummary {
            id: Some(7),
            verb: "recover",
            fingerprint: Some(0xab),
            cache_hit: Some(true),
            ok: true,
            recovered: Some(410),
            prepare_ms: 0.0,
            recover_ms: 3.21544,
            total_ms: 3.4,
            ..RequestSummary::default()
        };
        let line = s.render(5123);
        let v = json::parse(&line).unwrap();
        assert_eq!(v.get("ts_ms").unwrap().as_u64(), Some(5123));
        assert_eq!(v.get("id").unwrap().as_u64(), Some(7));
        assert_eq!(v.get("verb").unwrap().as_str(), Some("recover"));
        assert_eq!(v.get("fingerprint").unwrap().as_str(), Some("0x00000000000000ab"));
        assert_eq!(v.get("cache").unwrap().as_str(), Some("hit"));
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("recovered").unwrap().as_u64(), Some(410));
        assert_eq!(v.get("recover_ms").unwrap().as_f64(), Some(3.215));
        assert!(v.get("error").is_none());
        assert!(v.get("iterations").is_none());
    }

    #[test]
    fn failure_summaries_carry_the_kind() {
        let s = RequestSummary {
            id: None,
            verb: "recover",
            ok: false,
            error: Some("overloaded".into()),
            ..RequestSummary::default()
        };
        let v = json::parse(&s.render(1)).unwrap();
        assert_eq!(v.get("id"), Some(&json::Value::Null));
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("error").unwrap().as_str(), Some("overloaded"));
        assert!(v.get("cache").is_none(), "no cache field when not resolved");
    }

    #[test]
    fn file_sink_appends_one_line_per_emit() {
        let path = std::env::temp_dir().join(format!("pdgrass-sum-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let log = SummaryLog::open(path.to_str().unwrap()).unwrap();
            log.emit(&RequestSummary { id: Some(1), verb: "stats", ok: true, ..Default::default() });
            log.emit(&RequestSummary { id: Some(2), verb: "stats", ok: true, ..Default::default() });
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            json::parse(line).unwrap();
        }
        let _ = std::fs::remove_file(&path);
        // "off" and "stderr" sinks must open and emit without error.
        SummaryLog::open("off").unwrap().emit(&RequestSummary::default());
    }

    #[test]
    fn snapshot_field_renders_only_when_set() {
        let s = RequestSummary {
            id: Some(3),
            verb: "recover",
            cache_hit: Some(false),
            snapshot: Some("hit"),
            ok: true,
            ..RequestSummary::default()
        };
        let v = json::parse(&s.render(9)).unwrap();
        assert_eq!(v.get("cache").unwrap().as_str(), Some("miss"));
        assert_eq!(v.get("snapshot").unwrap().as_str(), Some("hit"));
        // Absent when snapshotting didn't participate.
        let s = RequestSummary { verb: "recover", ok: true, ..RequestSummary::default() };
        assert!(json::parse(&s.render(9)).unwrap().get("snapshot").is_none());
    }

    #[test]
    fn snapshot_counters_accumulate() {
        let c = SnapshotCounters::default();
        c.record_hit();
        c.record_miss();
        c.record_miss();
        c.record_load_failure();
        c.record_save();
        let s = c.snapshot();
        assert_eq!(s, SnapStats { hits: 1, misses: 2, load_failures: 1, saves: 1 });
    }

    #[test]
    fn counters_accumulate_by_verb_and_kind() {
        let c = ServerCounters::default();
        c.record("prepare", None);
        c.record("recover", None);
        c.record("recover", Some("overloaded"));
        c.record("pcg", Some("deadline_exceeded"));
        c.record("stats", None);
        c.record("evict", Some("bad_param"));
        let s = c.snapshot();
        assert_eq!(s.prepare, 1);
        assert_eq!(s.recover, 2);
        assert_eq!(s.pcg, 1);
        assert_eq!(s.stats, 1);
        assert_eq!(s.evict, 1);
        assert_eq!(s.errors, 3);
        assert_eq!(s.overloaded, 1);
        assert_eq!(s.deadline_exceeded, 1);
    }
}
