//! Minimal JSON value, parser, and renderer — std-only, like the rest of
//! the vendor policy (same spirit as `config`'s hand-rolled TOML subset).
//!
//! The serve protocol is line-delimited JSON, so this module only needs
//! (a) a recursive-descent parser for a full JSON document on one line
//! and (b) a deterministic renderer. Objects are a `Vec<(String, Value)>`
//! rather than a map: field order is preserved exactly as built, which is
//! what makes identical responses **byte**-identical — the protocol's
//! bitwise-determinism contract would be unverifiable over a `HashMap`'s
//! iteration order.
//!
//! Number rendering: values that are mathematically integers with
//! magnitude below 2⁵³ print as integers (`42`, not `42.0`), everything
//! else prints via Rust's shortest-roundtrip `f64` formatting. Both are
//! deterministic functions of the bit pattern.

use std::fmt::Write as _;

/// A JSON document. Objects preserve insertion order (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Object field lookup (first match; protocol objects never repeat
    /// keys).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Numeric field as `f64`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric field as a non-negative integer (rejects fractions,
    /// negatives, and magnitudes above 2⁵³ where `f64` loses exactness).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(n) if n.fract() == 0.0 && *n >= 0.0 && *n <= 9_007_199_254_740_992.0 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    /// String field.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean field.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array field.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Render as compact JSON (no whitespace). Deterministic: object
    /// fields print in insertion order, numbers as documented on the
    /// module.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(n) => render_num(*n, out),
            Value::Str(s) => render_str(s, out),
            Value::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.render_into(out);
                }
                out.push(']');
            }
            Value::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    render_str(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors so protocol code reads declaratively.
pub fn num(n: f64) -> Value {
    Value::Num(n)
}
pub fn int(n: u64) -> Value {
    Value::Num(n as f64)
}
pub fn str(s: impl Into<String>) -> Value {
    Value::Str(s.into())
}
pub fn obj(fields: Vec<(&str, Value)>) -> Value {
    Value::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn render_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no NaN/Inf; the protocol never emits them, but render
        // defensively as null rather than producing invalid JSON.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9_007_199_254_740_992.0 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn render_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse one complete JSON document. Trailing whitespace is allowed;
/// trailing non-whitespace is an error (a protocol line is one document).
pub fn parse(input: &str) -> Result<Value, String> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(format!("trailing content at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected `{}` at byte {}", b as char, self.pos.saturating_sub(1)))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        match self.peek() {
            Some(b'n') => self.literal("null", Value::Null),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected `{}` at byte {}", c as char, self.pos)),
            None => Err("unexpected end of input".to_string()),
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(format!("expected `,` or `]` at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(fields)),
                _ => return Err(format!("expected `,` or `}}` at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            // Consume one UTF-8 scalar: re-borrow as str from pos.
            let rest = std::str::from_utf8(&self.bytes[self.pos..])
                .map_err(|_| "invalid utf-8".to_string())?;
            let mut chars = rest.chars();
            let c = chars.next().ok_or("unterminated string")?;
            self.pos += c.len_utf8();
            match c {
                '"' => return Ok(s),
                '\\' => {
                    let esc = chars.next().ok_or("unterminated escape")?;
                    self.pos += esc.len_utf8();
                    match esc {
                        '"' => s.push('"'),
                        '\\' => s.push('\\'),
                        '/' => s.push('/'),
                        'n' => s.push('\n'),
                        'r' => s.push('\r'),
                        't' => s.push('\t'),
                        'b' => s.push('\u{0008}'),
                        'f' => s.push('\u{000c}'),
                        'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or("truncated \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            self.pos += 4;
                            // Surrogate pairs: \uD800-\uDBFF must pair
                            // with a following \uDC00-\uDFFF.
                            let cp = if (0xd800..0xdc00).contains(&cp) {
                                if self.bytes.get(self.pos..self.pos + 2) != Some(&b"\\u"[..]) {
                                    return Err("lone high surrogate".to_string());
                                }
                                self.pos += 2;
                                let hex2 = self
                                    .bytes
                                    .get(self.pos..self.pos + 4)
                                    .and_then(|h| std::str::from_utf8(h).ok())
                                    .ok_or("truncated surrogate pair")?;
                                let lo = u32::from_str_radix(hex2, 16)
                                    .map_err(|_| "bad \\u escape".to_string())?;
                                self.pos += 4;
                                if !(0xdc00..0xe000).contains(&lo) {
                                    return Err("invalid low surrogate".to_string());
                                }
                                0x10000 + ((cp - 0xd800) << 10) + (lo - 0xdc00)
                            } else if (0xdc00..0xe000).contains(&cp) {
                                return Err("lone low surrogate".to_string());
                            } else {
                                cp
                            };
                            s.push(char::from_u32(cp).ok_or("invalid code point")?);
                        }
                        other => return Err(format!("unknown escape `\\{other}`")),
                    }
                }
                c => s.push(c),
            }
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| format!("invalid number `{text}` at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrips_protocol_shaped_documents() {
        let line = r#"{"id":7,"verb":"recover","alpha":0.05,"opts":{"shard_min":4096,"jbp":true},"tags":["a","b"],"note":null}"#;
        let v = parse(line).unwrap();
        assert_eq!(v.get("id").unwrap().as_u64(), Some(7));
        assert_eq!(v.get("verb").unwrap().as_str(), Some("recover"));
        assert_eq!(v.get("alpha").unwrap().as_f64(), Some(0.05));
        assert_eq!(v.get("opts").unwrap().get("shard_min").unwrap().as_u64(), Some(4096));
        assert_eq!(v.get("opts").unwrap().get("jbp").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("tags").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("note"), Some(&Value::Null));
        // Render → parse is a fixed point (field order preserved).
        assert_eq!(parse(&v.render()).unwrap(), v);
        assert_eq!(v.render(), parse(&v.render()).unwrap().render());
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(int(42).render(), "42");
        assert_eq!(num(42.0).render(), "42");
        assert_eq!(num(-3.0).render(), "-3");
        assert_eq!(num(0.5).render(), "0.5");
        assert_eq!(int(u64::MAX >> 12).render(), format!("{}", u64::MAX >> 12));
        assert_eq!(num(f64::NAN).render(), "null");
    }

    #[test]
    fn string_escapes_roundtrip() {
        let s = "quote\" slash\\ newline\n tab\t unicode☃ ctrl\u{0001}";
        let rendered = str(s).render();
        assert_eq!(parse(&rendered).unwrap().as_str(), Some(s));
        // Incoming escaped forms parse too.
        assert_eq!(parse(r#""☃""#).unwrap().as_str(), Some("☃"));
        assert_eq!(parse(r#""😀""#).unwrap().as_str(), Some("😀"));
        assert_eq!(parse(r#""\/""#).unwrap().as_str(), Some("/"));
    }

    #[test]
    fn malformed_documents_are_errors_not_panics() {
        for bad in [
            "", "{", "}", "[1,", r#"{"a"}"#, r#"{"a":}"#, "nul", "tru", "01x", "\"unterminated",
            r#""\q""#, r#""\ud800""#, r#""\udc00""#, "{} trailing", "1 2",
        ] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn as_u64_is_strict() {
        assert_eq!(parse("3.5").unwrap().as_u64(), None);
        assert_eq!(parse("-1").unwrap().as_u64(), None);
        assert_eq!(parse("1e300").unwrap().as_u64(), None);
        assert_eq!(parse("12").unwrap().as_u64(), Some(12));
        assert_eq!(parse("12").unwrap().as_f64(), Some(12.0));
    }

    #[test]
    fn object_field_order_is_preserved_bytewise() {
        let a = obj(vec![("z", int(1)), ("a", int(2))]);
        assert_eq!(a.render(), r#"{"z":1,"a":2}"#);
        let b = obj(vec![("a", int(2)), ("z", int(1))]);
        assert_eq!(b.render(), r#"{"a":2,"z":1}"#);
        assert_ne!(a.render(), b.render());
    }
}
