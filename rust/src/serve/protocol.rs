//! Wire protocol of the serve daemon: line-delimited JSON over a
//! Unix-domain socket.
//!
//! Each request is one JSON object on one line; each response is one
//! JSON object on one line, in request order per connection. Requests
//! carry a client-chosen `id` that the response echoes, a verb, and
//! verb-specific fields:
//!
//! ```json
//! {"id":1,"verb":"prepare","graph":{"name":"15-M6","scale":0.05},"pipeline":"streamed"}
//! {"id":2,"verb":"recover","fingerprint":"0x9ae1d0...","alpha":0.05,"strategy":"sharded"}
//! {"id":3,"verb":"pcg","graph":{"name":"15-M6","scale":0.05},"alpha":0.05,"tol":1e-3,"maxit":500}
//! {"id":4,"verb":"stats"}
//! {"id":5,"verb":"evict","fingerprint":"0x9ae1d0..."}
//! {"id":6,"verb":"shutdown"}
//! ```
//!
//! `recover` and `pcg` address their graph either by a full spec
//! (`"graph"`, which the daemon prepares and caches on miss) or by bare
//! `"fingerprint"` (cache-only; a miss is a typed `unknown_graph`
//! error — the client must send the spec at least once). Graph
//! fingerprints travel as `"0x"`-prefixed 16-digit hex strings
//! ([`crate::graph::fingerprint_hex`]), never as JSON numbers — `f64`
//! cannot hold 64 bits exactly.
//!
//! **Determinism contract:** success responses contain only values that
//! are deterministic functions of the request content — fingerprints,
//! edge counts, edge hashes, PCG iterates. Timings and cache hit/miss
//! live in the daemon's JSON-lines summary log and the `stats` verb
//! instead, so two identical requests always produce **byte-identical**
//! response lines (the integration test asserts this against a direct
//! in-process `Prepared::recover`).
//!
//! Failures are `{"ok":false,"error":<kind>,"message":...}` with the
//! typed kinds of [`enum@Error`] (`overloaded` and `deadline_exceeded`
//! carry their fields); lines that don't parse as a valid request get
//! kind `protocol` and the connection stays open.

use std::io::{BufRead, BufReader, Read, Write};

use super::json::{self, int, obj, str as jstr, Value};
use crate::error::Error;
use crate::graph::{fingerprint_hex, parse_fingerprint};
use crate::recovery::{Pipeline, Strategy};
use crate::session::RecoverOpts;

/// Default α when a recover/pcg request omits it (paper's sparsest
/// operating point).
pub const DEFAULT_ALPHA: f64 = 0.02;
/// Default PCG tolerance / iteration cap when a pcg request omits them.
pub const DEFAULT_TOL: f64 = 1e-3;
pub const DEFAULT_MAXIT: usize = 1000;

/// How a request names its graph.
#[derive(Clone, Debug, PartialEq)]
pub enum Target {
    /// Full spec: prepare (and cache) on miss.
    Spec(GraphSpec),
    /// Bare fingerprint: cache-only, `unknown_graph` on miss.
    Fingerprint(u64),
}

/// A generatable suite graph: `(name, scale, seed)` fully determines the
/// edge list, so the spec is as good as shipping the graph.
#[derive(Clone, Debug, PartialEq)]
pub struct GraphSpec {
    pub name: String,
    pub scale: f64,
    pub seed: u64,
}

/// Step-4 knobs a recover/pcg request may override. `threads == 0`
/// means "the daemon's configured default".
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReqOpts {
    pub alpha: f64,
    pub strategy: Strategy,
    pub pipeline: Pipeline,
    pub shard_min: usize,
    pub threads: usize,
}

impl ReqOpts {
    /// Resolve into full [`RecoverOpts`] given the daemon's default
    /// thread count.
    pub fn recover_opts(&self, default_threads: usize) -> RecoverOpts {
        let threads = if self.threads == 0 { default_threads } else { self.threads };
        RecoverOpts {
            strategy: self.strategy,
            pipeline: self.pipeline,
            shard_min: self.shard_min,
            ..RecoverOpts::with_threads(self.alpha, threads)
        }
    }
}

/// One parsed request.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    /// Client-chosen id, echoed in the response.
    pub id: u64,
    /// Per-request deadline override, ms (`None` → daemon default).
    pub deadline_ms: Option<u64>,
    pub verb: Verb,
}

/// The request verbs.
#[derive(Clone, Debug, PartialEq)]
pub enum Verb {
    /// Run Algorithm-1 steps 1–3 for a graph and cache the result.
    Prepare { spec: GraphSpec, pipeline: Pipeline, threads: usize },
    /// Step 4 at the requested (α, strategy, pipeline, shard_min) off
    /// the cached prepared state (filling the cache on a spec miss).
    Recover { target: Target, opts: ReqOpts, return_edges: bool },
    /// Recover, assemble the sparsifier, and run the PCG quality metric.
    Pcg { target: Target, opts: ReqOpts, rhs_seed: u64, tol: f64, maxit: usize },
    /// Daemon counters: per-verb totals, cache and admission stats.
    Stats,
    /// Drop one cached entry (by fingerprint) or all of them.
    Evict { fingerprint: Option<u64> },
    /// Stop accepting, drain, unlink the socket, exit.
    Shutdown,
}

impl Request {
    /// Parse one protocol line. Errors are protocol-level (malformed
    /// JSON, missing/mistyped fields) and are reported with kind
    /// `protocol`; they carry the offending request's id when one could
    /// be read.
    pub fn parse(line: &str) -> Result<Request, (Option<u64>, String)> {
        let v = json::parse(line).map_err(|e| (None, format!("malformed JSON: {e}")))?;
        let id = v.get("id").and_then(Value::as_u64);
        Request::from_value(&v).map_err(|msg| (id, msg))
    }

    fn from_value(v: &Value) -> Result<Request, String> {
        let id = field_u64(v, "id")?.ok_or("missing `id`")?;
        let deadline_ms = field_u64(v, "deadline_ms")?;
        let verb_name = field_str(v, "verb")?.ok_or("missing `verb`")?;
        let verb = match verb_name {
            "prepare" => {
                let spec = graph_spec(v)?.ok_or("prepare requires a `graph` object")?;
                Verb::Prepare {
                    spec,
                    pipeline: field_pipeline(v)?,
                    threads: field_u64(v, "threads")?.unwrap_or(0) as usize,
                }
            }
            "recover" => Verb::Recover {
                target: target(v)?,
                opts: req_opts(v)?,
                return_edges: field_bool(v, "return_edges")?.unwrap_or(false),
            },
            "pcg" => Verb::Pcg {
                target: target(v)?,
                opts: req_opts(v)?,
                rhs_seed: field_u64(v, "rhs_seed")?.unwrap_or(1),
                tol: field_f64(v, "tol")?.unwrap_or(DEFAULT_TOL),
                maxit: field_u64(v, "maxit")?.unwrap_or(DEFAULT_MAXIT as u64) as usize,
            },
            "stats" => Verb::Stats,
            "evict" => Verb::Evict { fingerprint: field_fingerprint(v)? },
            "shutdown" => Verb::Shutdown,
            other => return Err(format!("unknown verb {other:?}")),
        };
        Ok(Request { id, deadline_ms, verb })
    }
}

fn field_u64(v: &Value, key: &str) -> Result<Option<u64>, String> {
    match v.get(key) {
        None | Some(Value::Null) => Ok(None),
        Some(f) => f.as_u64().map(Some).ok_or(format!("`{key}` must be a non-negative integer")),
    }
}

fn field_f64(v: &Value, key: &str) -> Result<Option<f64>, String> {
    match v.get(key) {
        None | Some(Value::Null) => Ok(None),
        Some(f) => f.as_f64().map(Some).ok_or(format!("`{key}` must be a number")),
    }
}

fn field_bool(v: &Value, key: &str) -> Result<Option<bool>, String> {
    match v.get(key) {
        None | Some(Value::Null) => Ok(None),
        Some(f) => f.as_bool().map(Some).ok_or(format!("`{key}` must be a boolean")),
    }
}

fn field_str<'v>(v: &'v Value, key: &str) -> Result<Option<&'v str>, String> {
    match v.get(key) {
        None | Some(Value::Null) => Ok(None),
        Some(f) => f.as_str().map(Some).ok_or(format!("`{key}` must be a string")),
    }
}

fn field_fingerprint(v: &Value) -> Result<Option<u64>, String> {
    match field_str(v, "fingerprint")? {
        None => Ok(None),
        Some(s) => parse_fingerprint(s)
            .map(Some)
            .ok_or(format!("`fingerprint` must be 0x-prefixed hex, got {s:?}")),
    }
}

fn field_pipeline(v: &Value) -> Result<Pipeline, String> {
    match field_str(v, "pipeline")? {
        None => Ok(Pipeline::Barrier),
        Some(s) => s.parse::<Pipeline>().map_err(|e| e.to_string()),
    }
}

fn graph_spec(v: &Value) -> Result<Option<GraphSpec>, String> {
    let Some(g) = v.get("graph") else {
        return Ok(None);
    };
    if !matches!(g, Value::Obj(_)) {
        return Err("`graph` must be an object".to_string());
    }
    let name = field_str(g, "name")?.ok_or("`graph` requires a `name`")?.to_string();
    let scale = field_f64(g, "scale")?.unwrap_or(1.0);
    let seed = field_u64(g, "seed")?.unwrap_or(crate::gen::DEFAULT_SEED);
    Ok(Some(GraphSpec { name, scale, seed }))
}

fn target(v: &Value) -> Result<Target, String> {
    let fp = field_fingerprint(v)?;
    let spec = graph_spec(v)?;
    match (fp, spec) {
        (Some(_), Some(_)) => Err("give either `graph` or `fingerprint`, not both".to_string()),
        (Some(fp), None) => Ok(Target::Fingerprint(fp)),
        (None, Some(spec)) => Ok(Target::Spec(spec)),
        (None, None) => Err("missing target: give `graph` or `fingerprint`".to_string()),
    }
}

fn req_opts(v: &Value) -> Result<ReqOpts, String> {
    let strategy = match field_str(v, "strategy")? {
        None => Strategy::Mixed,
        Some(s) => s.parse::<Strategy>().map_err(|e| e.to_string())?,
    };
    Ok(ReqOpts {
        alpha: field_f64(v, "alpha")?.unwrap_or(DEFAULT_ALPHA),
        strategy,
        pipeline: field_pipeline(v)?,
        shard_min: field_u64(v, "shard_min")?.unwrap_or(4096) as usize,
        threads: field_u64(v, "threads")?.unwrap_or(0) as usize,
    })
}

/// Stable wire name of each typed error kind.
pub fn error_kind(e: &Error) -> &'static str {
    match e {
        Error::Overloaded { .. } => "overloaded",
        Error::DeadlineExceeded { .. } => "deadline_exceeded",
        Error::BadParam { .. } => "bad_param",
        Error::Disconnected { .. } => "disconnected",
        Error::UnknownGraph { .. } => "unknown_graph",
        Error::NoConvergence { .. } => "no_convergence",
        Error::NotPositiveDefinite { .. } => "not_positive_definite",
        Error::Snapshot { .. } => "snapshot",
        Error::Config(_) => "config",
        Error::Io(_) => "io",
    }
}

/// Build a success response: `{"id":..,"ok":true, <fields>}`.
pub fn ok_response(id: u64, fields: Vec<(&str, Value)>) -> Value {
    let mut all = vec![("id", int(id)), ("ok", Value::Bool(true))];
    all.extend(fields);
    obj(all)
}

/// Build a typed error response. `overloaded` and `deadline_exceeded`
/// carry their structured fields so clients can back off / re-budget
/// without parsing the message.
pub fn error_response(id: Option<u64>, e: &Error) -> Value {
    let mut fields = vec![
        ("id", id.map(int).unwrap_or(Value::Null)),
        ("ok", Value::Bool(false)),
        ("error", jstr(error_kind(e))),
        ("message", jstr(e.to_string())),
    ];
    match e {
        Error::Overloaded { in_flight, cap } => {
            fields.push(("in_flight", int(*in_flight as u64)));
            fields.push(("cap", int(*cap as u64)));
        }
        Error::DeadlineExceeded { elapsed_ms, deadline_ms } => {
            fields.push(("elapsed_ms", int(*elapsed_ms)));
            fields.push(("deadline_ms", int(*deadline_ms)));
        }
        _ => {}
    }
    obj(fields)
}

/// Build a protocol-level error response (the line was not a valid
/// request). The connection stays open after these.
pub fn protocol_error_response(id: Option<u64>, message: &str) -> Value {
    obj(vec![
        ("id", id.map(int).unwrap_or(Value::Null)),
        ("ok", Value::Bool(false)),
        ("error", jstr("protocol")),
        ("message", jstr(message)),
    ])
}

/// Render a fingerprint the way every response field does.
pub fn fp_value(fp: u64) -> Value {
    jstr(fingerprint_hex(fp))
}

/// Blocking protocol client over a Unix-domain socket — used by the
/// bombard load generator, the integration tests, and scriptable from
/// `pdgrass bombard`'s building blocks.
pub struct Client {
    writer: std::os::unix::net::UnixStream,
    reader: BufReader<std::os::unix::net::UnixStream>,
}

impl Client {
    /// Connect to a daemon's socket.
    pub fn connect(path: &std::path::Path) -> std::io::Result<Client> {
        let stream = std::os::unix::net::UnixStream::connect(path)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { writer: stream, reader })
    }

    /// Send one raw request line, receive one raw response line (without
    /// the trailing newline). The raw-line form exists so tests can
    /// assert byte identity of responses.
    pub fn call_line(&mut self, line: &str) -> std::io::Result<String> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut resp = String::new();
        let n = self.reader.read_line(&mut resp)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed the connection",
            ));
        }
        while resp.ends_with('\n') || resp.ends_with('\r') {
            resp.pop();
        }
        Ok(resp)
    }

    /// Send a request document, parse the response document.
    pub fn call(&mut self, request: &Value) -> std::io::Result<Value> {
        let line = self.call_line(&request.render())?;
        json::parse(&line)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))
    }
}

/// Read one line (newline-stripped) from a buffered reader — the
/// server-side receive primitive; `Ok(None)` is a clean EOF.
pub fn read_line<R: Read>(reader: &mut BufReader<R>) -> std::io::Result<Option<String>> {
    let mut line = String::new();
    let n = reader.read_line(&mut line)?;
    if n == 0 {
        return Ok(None);
    }
    while line.ends_with('\n') || line.ends_with('\r') {
        line.pop();
    }
    Ok(Some(line))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_documented_examples() {
        let r = Request::parse(
            r#"{"id":1,"verb":"prepare","graph":{"name":"15-M6","scale":0.05},"pipeline":"streamed"}"#,
        )
        .unwrap();
        assert_eq!(r.id, 1);
        assert_eq!(r.deadline_ms, None);
        match r.verb {
            Verb::Prepare { spec, pipeline, threads } => {
                assert_eq!(spec.name, "15-M6");
                assert_eq!(spec.scale, 0.05);
                assert_eq!(spec.seed, crate::gen::DEFAULT_SEED);
                assert_eq!(pipeline, Pipeline::Streamed);
                assert_eq!(threads, 0);
            }
            other => panic!("expected Prepare, got {other:?}"),
        }

        let r = Request::parse(
            r#"{"id":2,"verb":"recover","fingerprint":"0x2b4dac9cd7c1de97","alpha":0.05,"strategy":"sharded","return_edges":true,"deadline_ms":250}"#,
        )
        .unwrap();
        assert_eq!(r.deadline_ms, Some(250));
        match r.verb {
            Verb::Recover { target, opts, return_edges } => {
                assert_eq!(target, Target::Fingerprint(0x2b4d_ac9c_d7c1_de97));
                assert_eq!(opts.alpha, 0.05);
                assert_eq!(opts.strategy, Strategy::Sharded);
                assert_eq!(opts.pipeline, Pipeline::Barrier);
                assert_eq!(opts.shard_min, 4096);
                assert_eq!(opts.threads, 0);
                assert!(return_edges);
            }
            other => panic!("expected Recover, got {other:?}"),
        }

        let r = Request::parse(
            r#"{"id":3,"verb":"pcg","graph":{"name":"15-M6","scale":0.05,"seed":7},"tol":0.001,"maxit":500}"#,
        )
        .unwrap();
        match r.verb {
            Verb::Pcg { target, opts, rhs_seed, tol, maxit } => {
                assert_eq!(
                    target,
                    Target::Spec(GraphSpec { name: "15-M6".into(), scale: 0.05, seed: 7 })
                );
                assert_eq!(opts.alpha, DEFAULT_ALPHA);
                assert_eq!(rhs_seed, 1);
                assert_eq!(tol, 1e-3);
                assert_eq!(maxit, 500);
            }
            other => panic!("expected Pcg, got {other:?}"),
        }

        assert_eq!(Request::parse(r#"{"id":4,"verb":"stats"}"#).unwrap().verb, Verb::Stats);
        assert_eq!(
            Request::parse(r#"{"id":5,"verb":"evict","fingerprint":"0xdeadbeef"}"#).unwrap().verb,
            Verb::Evict { fingerprint: Some(0xdead_beef) }
        );
        assert_eq!(
            Request::parse(r#"{"id":5,"verb":"evict"}"#).unwrap().verb,
            Verb::Evict { fingerprint: None }
        );
        assert_eq!(Request::parse(r#"{"id":6,"verb":"shutdown"}"#).unwrap().verb, Verb::Shutdown);
    }

    #[test]
    fn protocol_errors_carry_the_id_when_readable() {
        // Unreadable id → None.
        assert_eq!(Request::parse("not json").unwrap_err().0, None);
        // Readable id, bad verb → Some(id).
        let (id, msg) = Request::parse(r#"{"id":9,"verb":"explode"}"#).unwrap_err();
        assert_eq!(id, Some(9));
        assert!(msg.contains("explode"), "{msg}");
        // Missing verb / id.
        assert!(Request::parse(r#"{"id":1}"#).is_err());
        assert!(Request::parse(r#"{"verb":"stats"}"#).is_err());
        // Both graph and fingerprint.
        let (_, msg) = Request::parse(
            r#"{"id":1,"verb":"recover","graph":{"name":"g"},"fingerprint":"0x1"}"#,
        )
        .unwrap_err();
        assert!(msg.contains("not both"), "{msg}");
        // Neither.
        assert!(Request::parse(r#"{"id":1,"verb":"recover"}"#).is_err());
        // Mistyped fields.
        assert!(Request::parse(r#"{"id":"one","verb":"stats"}"#).is_err());
        assert!(Request::parse(r#"{"id":1,"verb":"recover","fingerprint":17}"#).is_err());
        assert!(Request::parse(r#"{"id":1,"verb":"recover","graph":"g"}"#).is_err());
        assert!(Request::parse(r#"{"id":1,"verb":"prepare"}"#).is_err());
        assert!(Request::parse(r#"{"id":1,"verb":"recover","graph":{"name":"g"},"strategy":"bogus"}"#).is_err());
    }

    #[test]
    fn req_opts_resolve_against_daemon_defaults() {
        let opts = ReqOpts {
            alpha: 0.05,
            strategy: Strategy::Sharded,
            pipeline: Pipeline::Streamed,
            shard_min: 512,
            threads: 0,
        };
        let r = opts.recover_opts(6);
        assert_eq!(r.threads, 6);
        assert_eq!(r.block, 6);
        assert_eq!(r.strategy, Strategy::Sharded);
        assert_eq!(r.pipeline, Pipeline::Streamed);
        assert_eq!(r.shard_min, 512);
        let r = ReqOpts { threads: 3, ..opts }.recover_opts(6);
        assert_eq!(r.threads, 3);
        assert_eq!(r.block, 3);
    }

    #[test]
    fn error_responses_are_typed_and_structured() {
        let v = error_response(Some(4), &Error::Overloaded { in_flight: 8, cap: 8 });
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("error").unwrap().as_str(), Some("overloaded"));
        assert_eq!(v.get("in_flight").unwrap().as_u64(), Some(8));
        assert_eq!(v.get("cap").unwrap().as_u64(), Some(8));

        let v = error_response(None, &Error::DeadlineExceeded { elapsed_ms: 9, deadline_ms: 5 });
        assert_eq!(v.get("id"), Some(&Value::Null));
        assert_eq!(v.get("error").unwrap().as_str(), Some("deadline_exceeded"));
        assert_eq!(v.get("elapsed_ms").unwrap().as_u64(), Some(9));
        assert_eq!(v.get("deadline_ms").unwrap().as_u64(), Some(5));

        let v = protocol_error_response(Some(1), "nope");
        assert_eq!(v.get("error").unwrap().as_str(), Some("protocol"));

        let v = ok_response(3, vec![("fingerprint", fp_value(0xab))]);
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("fingerprint").unwrap().as_str(), Some("0x00000000000000ab"));
    }

    #[test]
    fn every_error_kind_has_a_stable_wire_name() {
        let kinds = [
            error_kind(&Error::Overloaded { in_flight: 1, cap: 1 }),
            error_kind(&Error::DeadlineExceeded { elapsed_ms: 1, deadline_ms: 1 }),
            error_kind(&Error::BadParam { name: "x", why: String::new() }),
            error_kind(&Error::Disconnected { components: 2 }),
            error_kind(&Error::UnknownGraph { name: String::new() }),
            error_kind(&Error::NoConvergence { iters: 1, residual: 1.0 }),
            error_kind(&Error::NotPositiveDefinite { at: 0, pivot: 0.0 }),
            error_kind(&Error::Snapshot { why: String::new() }),
            error_kind(&Error::Config(String::new())),
            error_kind(&Error::Io(std::io::Error::other("x"))),
        ];
        let mut unique: Vec<&str> = kinds.to_vec();
        unique.sort_unstable();
        unique.dedup();
        assert_eq!(unique.len(), kinds.len(), "kinds must be distinct: {kinds:?}");
    }
}
