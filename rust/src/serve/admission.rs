//! Admission control: a bounded in-flight counter with RAII permits.
//!
//! The daemon shares one compute pool; letting every connection queue
//! unbounded work would trade rejection for unbounded latency. Instead,
//! compute verbs (`prepare`/`recover`/`pcg`) must [`Admission::try_acquire`]
//! a permit first; past the cap the request is rejected immediately with
//! the typed [`Error::Overloaded`] — the client sees a structured
//! `{in_flight, cap}` rejection it can back off on, and the requests
//! already admitted keep their latency. Control verbs
//! (`stats`/`evict`/`shutdown`) bypass admission: they are O(µs)
//! bookkeeping and must work *especially* when the daemon is saturated.
//!
//! A plain `Mutex` around four counters — the hot path is one lock per
//! request, dwarfed by the work the permit admits, and keeping it
//! mutex-only means no new entries in the reviewed atomics allowlist.

use std::sync::Mutex;

use crate::error::{Error, Result};

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AdmissionStats {
    pub in_flight: usize,
    pub cap: usize,
    pub accepted: u64,
    pub rejected: u64,
    /// High-water mark of concurrent in-flight requests.
    pub peak: usize,
}

struct State {
    in_flight: usize,
    cap: usize,
    accepted: u64,
    rejected: u64,
    peak: usize,
}

/// Bounded admission gate; see the module docs.
pub struct Admission {
    state: Mutex<State>,
}

impl Admission {
    /// Gate admitting at most `cap` (≥ 1, validated by config)
    /// concurrent permits.
    pub fn new(cap: usize) -> Admission {
        Admission {
            state: Mutex::new(State {
                in_flight: 0,
                cap: cap.max(1),
                accepted: 0,
                rejected: 0,
                peak: 0,
            }),
        }
    }

    /// Try to admit one request. At the cap this fails immediately with
    /// [`Error::Overloaded`] — no queuing. Dropping the returned permit
    /// releases the slot.
    pub fn try_acquire(&self) -> Result<Permit<'_>> {
        let mut s = self.state.lock().unwrap();
        if s.in_flight >= s.cap {
            s.rejected += 1;
            return Err(Error::Overloaded { in_flight: s.in_flight, cap: s.cap });
        }
        s.in_flight += 1;
        s.accepted += 1;
        s.peak = s.peak.max(s.in_flight);
        Ok(Permit { admission: self })
    }

    /// Counter snapshot.
    pub fn stats(&self) -> AdmissionStats {
        let s = self.state.lock().unwrap();
        AdmissionStats {
            in_flight: s.in_flight,
            cap: s.cap,
            accepted: s.accepted,
            rejected: s.rejected,
            peak: s.peak,
        }
    }

    fn release(&self) {
        let mut s = self.state.lock().unwrap();
        debug_assert!(s.in_flight > 0, "permit released twice");
        s.in_flight = s.in_flight.saturating_sub(1);
    }
}

/// RAII admission slot: held for the duration of one compute request,
/// released on drop (including unwinds — a panicking handler must not
/// leak its slot or the daemon would ratchet toward permanent overload).
pub struct Permit<'a> {
    admission: &'a Admission,
}

impl Drop for Permit<'_> {
    fn drop(&mut self) {
        self.admission.release();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_to_cap_then_rejects_typed() {
        let adm = Admission::new(2);
        let p1 = adm.try_acquire().unwrap();
        let p2 = adm.try_acquire().unwrap();
        match adm.try_acquire() {
            Err(Error::Overloaded { in_flight, cap }) => {
                assert_eq!((in_flight, cap), (2, 2));
            }
            Err(e) => panic!("expected Overloaded, got {e:?}"),
            Ok(_) => panic!("expected Overloaded, got a permit"),
        }
        let s = adm.stats();
        assert_eq!((s.in_flight, s.accepted, s.rejected, s.peak), (2, 2, 1, 2));
        drop(p1);
        let _p3 = adm.try_acquire().expect("slot freed by drop");
        drop(p2);
        let s = adm.stats();
        assert_eq!(s.in_flight, 1);
        assert_eq!(s.peak, 2, "peak is a high-water mark");
    }

    #[test]
    fn cap_zero_clamps_to_one() {
        let adm = Admission::new(0);
        let _p = adm.try_acquire().unwrap();
        assert!(adm.try_acquire().is_err());
    }

    #[test]
    fn permit_released_on_unwind() {
        let adm = Admission::new(1);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _p = adm.try_acquire().unwrap();
            panic!("handler died");
        }));
        assert!(result.is_err());
        assert_eq!(adm.stats().in_flight, 0, "unwind must release the slot");
        let _p = adm.try_acquire().unwrap();
    }
}
