//! Load-replay harness: seeded deterministic traffic against a daemon.
//!
//! `pdgrass bombard` replays a heavy-traffic request mix — recover-heavy
//! with periodic `pcg` and `stats` interleaves — against a running
//! daemon and reports throughput plus p50/p95/p99 latency. The mix is a
//! pure function of the [`BombardConfig`] (graph/α picks come from the
//! repo's deterministic [`Rng`]), so two runs with the same config send
//! byte-identical request lines in the same per-client order: a
//! reproducible load for regression-hunting, not a fuzzer.
//!
//! Outcomes are counted in four disjoint buckets:
//!
//! - `ok` — served; only these contribute latency samples (designed-fast
//!   rejections would skew the percentiles low),
//! - `overloaded` / `deadline_exceeded` — the daemon's typed
//!   back-pressure working as intended, *not* failures,
//! - `failed` — everything that should never happen under a correct
//!   daemon: protocol errors, unexpected typed errors, dead sockets.
//!
//! The CI smoke job runs a small mix and asserts `failed == 0`.
//!
//! Client connections ride the shared [`crate::par`] pool via
//! [`par_for`] (one index per client), so the harness obeys the
//! repo-wide "no threads outside the pool" rule; against an in-process
//! server the pool's caller-participation guarantees progress even when
//! every worker is parked on socket I/O.

use std::sync::Mutex;

use super::json::{int, num, obj, str as jstr, Value};
use super::protocol::Client;
use crate::error::{Error, Result};
use crate::par::par_for;
use crate::util::stats::percentile_sorted;
use crate::util::{Rng, Timer};

/// Parameters of one replay run.
#[derive(Clone, Debug)]
pub struct BombardConfig {
    /// Daemon socket to replay against.
    pub socket: std::path::PathBuf,
    /// Total requests across all clients.
    pub requests: usize,
    /// Concurrent client connections.
    pub clients: usize,
    /// Suite graph names the mix draws from.
    pub graphs: Vec<String>,
    /// α values the mix draws from.
    pub alphas: Vec<f64>,
    /// Suite scale for every drawn graph.
    pub scale: f64,
    /// Mix seed: same seed, same request lines.
    pub seed: u64,
    /// Per-request deadline to attach, ms (0 = none).
    pub deadline_ms: u64,
    /// Send a `shutdown` request after the run completes.
    pub shutdown: bool,
}

impl Default for BombardConfig {
    fn default() -> BombardConfig {
        BombardConfig {
            socket: std::path::PathBuf::from("/tmp/pdgrass.sock"),
            requests: 64,
            clients: 4,
            graphs: vec!["15-M6".to_string()],
            alphas: vec![0.02, 0.05, 0.10],
            scale: 0.02,
            seed: 42,
            deadline_ms: 0,
            shutdown: false,
        }
    }
}

/// Aggregated outcome of a replay run.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BombardReport {
    pub sent: usize,
    pub ok: usize,
    pub overloaded: usize,
    pub deadline_exceeded: usize,
    /// Requests that failed in a way back-pressure does not explain —
    /// the CI smoke job requires this to be zero.
    pub failed: usize,
    pub elapsed_ms: f64,
    /// Latency percentiles over `ok` responses, microseconds.
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    /// Served (`ok`) requests per wall-clock second.
    pub throughput_rps: f64,
}

impl BombardReport {
    /// Human-readable multi-line report for the CLI.
    pub fn render(&self) -> String {
        format!(
            "bombard: {} sent, {} ok, {} overloaded, {} deadline_exceeded, {} failed\n\
             elapsed {:.1} ms, throughput {:.1} req/s\n\
             latency p50 {:.0} us, p95 {:.0} us, p99 {:.0} us",
            self.sent,
            self.ok,
            self.overloaded,
            self.deadline_exceeded,
            self.failed,
            self.elapsed_ms,
            self.throughput_rps,
            self.p50_us,
            self.p95_us,
            self.p99_us,
        )
    }
}

/// Generate the full deterministic request-line sequence for a config.
/// Request `i` (0-based, wire id `i+1`) is: every 16th a `stats`, every
/// 16th a capped `pcg`, otherwise a `recover`, with graph and α drawn
/// from the seeded [`Rng`]. Public so tests can assert replay identity.
pub fn request_lines(cfg: &BombardConfig) -> Vec<String> {
    let mut rng = Rng::new(cfg.seed);
    (0..cfg.requests)
        .map(|i| {
            let id = int((i + 1) as u64);
            if i % 16 == 15 {
                return obj(vec![("id", id), ("verb", jstr("stats"))]).render();
            }
            let name = cfg.graphs[rng.below(cfg.graphs.len())].clone();
            let alpha = cfg.alphas[rng.below(cfg.alphas.len())];
            let graph = obj(vec![("name", jstr(name)), ("scale", num(cfg.scale))]);
            let verb = if i % 16 == 7 { "pcg" } else { "recover" };
            let mut fields = vec![
                ("id", id),
                ("verb", jstr(verb)),
                ("graph", graph),
                ("alpha", num(alpha)),
            ];
            if verb == "pcg" {
                // Cap the quality probe so one hard graph cannot stall
                // the whole replay.
                fields.push(("maxit", int(500)));
            }
            if cfg.deadline_ms > 0 {
                fields.push(("deadline_ms", int(cfg.deadline_ms)));
            }
            obj(fields).render()
        })
        .collect()
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum Outcome {
    Ok,
    Overloaded,
    Deadline,
    Failed,
}

/// Classify one raw response line into an outcome bucket.
fn classify(line: &str) -> Outcome {
    let Ok(v) = super::json::parse(line) else {
        return Outcome::Failed;
    };
    if v.get("ok").and_then(Value::as_bool) == Some(true) {
        return Outcome::Ok;
    }
    match v.get("error").and_then(Value::as_str) {
        Some("overloaded") => Outcome::Overloaded,
        Some("deadline_exceeded") => Outcome::Deadline,
        _ => Outcome::Failed,
    }
}

#[derive(Clone, Copy, Debug, Default)]
struct Counts {
    sent: usize,
    ok: usize,
    overloaded: usize,
    deadline_exceeded: usize,
    failed: usize,
}

/// One client's share of the replay: requests `c, c+clients, …` in
/// order, on its own connection. A dead socket counts the request
/// failed and reconnects once per subsequent request.
fn client_loop(cfg: &BombardConfig, c: usize, lines: &[String]) -> (Counts, Vec<f64>) {
    let stride = cfg.clients.max(1);
    let mut counts = Counts::default();
    let mut lats = Vec::new();
    let mut client = Client::connect(&cfg.socket).ok();
    let mut i = c;
    while i < lines.len() {
        counts.sent += 1;
        if client.is_none() {
            client = Client::connect(&cfg.socket).ok();
        }
        match client.as_mut() {
            None => counts.failed += 1,
            Some(cl) => {
                let t = Timer::start();
                match cl.call_line(&lines[i]) {
                    Ok(resp) => match classify(&resp) {
                        Outcome::Ok => {
                            counts.ok += 1;
                            lats.push(t.us());
                        }
                        Outcome::Overloaded => counts.overloaded += 1,
                        Outcome::Deadline => counts.deadline_exceeded += 1,
                        Outcome::Failed => counts.failed += 1,
                    },
                    Err(_) => {
                        counts.failed += 1;
                        client = None;
                    }
                }
            }
        }
        i += stride;
    }
    (counts, lats)
}

/// Run the replay. Fails up front (typed) on an empty mix or an
/// unreachable daemon; individual request failures are *counted*, not
/// propagated, so the report always covers the full mix.
pub fn run(cfg: &BombardConfig) -> Result<BombardReport> {
    if cfg.requests == 0 {
        return Err(Error::BadParam { name: "requests", why: "must be at least 1".into() });
    }
    if cfg.clients == 0 {
        return Err(Error::BadParam { name: "clients", why: "must be at least 1".into() });
    }
    if cfg.graphs.is_empty() {
        return Err(Error::BadParam { name: "graphs", why: "need at least one graph".into() });
    }
    if cfg.alphas.is_empty() {
        return Err(Error::BadParam { name: "alphas", why: "need at least one alpha".into() });
    }
    // Probe before fanning out: "daemon not running" should be one
    // clear error, not `requests` counted failures.
    Client::connect(&cfg.socket)?;
    let lines = request_lines(cfg);
    let merged: Mutex<(Counts, Vec<f64>)> = Mutex::new((Counts::default(), Vec::new()));
    let t = Timer::start();
    par_for(cfg.clients, cfg.clients, 1, |c| {
        let (counts, lats) = client_loop(cfg, c, &lines);
        let mut m = merged.lock().unwrap();
        m.0.sent += counts.sent;
        m.0.ok += counts.ok;
        m.0.overloaded += counts.overloaded;
        m.0.deadline_exceeded += counts.deadline_exceeded;
        m.0.failed += counts.failed;
        m.1.extend(lats);
    });
    let elapsed_ms = t.ms();
    if cfg.shutdown {
        let mut cl = Client::connect(&cfg.socket)?;
        let line =
            obj(vec![("id", int(cfg.requests as u64 + 1)), ("verb", jstr("shutdown"))]).render();
        let _ = cl.call_line(&line);
    }
    let (counts, mut lats) = merged.into_inner().unwrap();
    lats.sort_unstable_by(f64::total_cmp);
    let pct = |p: f64| if lats.is_empty() { 0.0 } else { percentile_sorted(&lats, p) };
    Ok(BombardReport {
        sent: counts.sent,
        ok: counts.ok,
        overloaded: counts.overloaded,
        deadline_exceeded: counts.deadline_exceeded,
        failed: counts.failed,
        elapsed_ms,
        p50_us: pct(50.0),
        p95_us: pct(95.0),
        p99_us: pct(99.0),
        throughput_rps: if elapsed_ms > 0.0 {
            counts.ok as f64 / (elapsed_ms / 1000.0)
        } else {
            0.0
        },
    })
}

/// Cold-vs-warm replay comparison (`pdgrass bombard --warm-compare`).
///
/// Both passes replay the *same* deterministic mix; the only difference
/// is what the daemon's prepare path finds. See [`run_compare`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CompareReport {
    /// First pass: in-memory cache evicted up front, so every spec pays
    /// a full prepare (which, with a `snapshot_dir`, writes back).
    pub cold: BombardReport,
    /// Second pass: cache evicted again — with a `snapshot_dir` the
    /// prepares are now warm snapshot loads; without one this measures
    /// a plain re-prepare and the comparison should be ~1×.
    pub warm: BombardReport,
}

impl CompareReport {
    /// Human-readable comparison for the CLI: both reports plus the
    /// cold/warm elapsed ratio.
    pub fn render(&self) -> String {
        let speedup = if self.warm.elapsed_ms > 0.0 {
            self.cold.elapsed_ms / self.warm.elapsed_ms
        } else {
            0.0
        };
        format!(
            "cold (evicted cache, full prepare):\n{}\n\
             warm (evicted cache, snapshot load):\n{}\n\
             cold/warm elapsed ratio: {:.2}x",
            self.cold.render(),
            self.warm.render(),
            speedup,
        )
    }
}

/// Drop every cached entry on the daemon so the next request of each
/// spec goes through the prepare path again.
fn evict_all(socket: &std::path::Path) -> Result<()> {
    let mut cl = Client::connect(socket)?;
    let line = obj(vec![("id", int(1)), ("verb", jstr("evict"))]).render();
    cl.call_line(&line)?;
    Ok(())
}

/// Replay the mix twice — evict-all, cold pass, evict-all, warm pass —
/// and report both. Pointed at a daemon with a configured
/// `snapshot_dir`, the cold pass populates the snapshot directory and
/// the warm pass quantifies what the warm-start cache buys: the request
/// mixes are byte-identical, so the elapsed ratio isolates prepare cost.
/// `cfg.shutdown` is honored only after the warm pass.
pub fn run_compare(cfg: &BombardConfig) -> Result<CompareReport> {
    evict_all(&cfg.socket)?;
    let cold = run(&BombardConfig { shutdown: false, ..cfg.clone() })?;
    evict_all(&cfg.socket)?;
    let warm = run(cfg)?;
    Ok(CompareReport { cold, warm })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_is_deterministic_and_covers_the_verbs() {
        let cfg = BombardConfig {
            requests: 48,
            graphs: vec!["a".into(), "b".into()],
            alphas: vec![0.02, 0.1],
            ..BombardConfig::default()
        };
        let lines = request_lines(&cfg);
        assert_eq!(lines, request_lines(&cfg), "same seed, same bytes");
        assert_eq!(lines.len(), 48);
        let count = |needle: &str| lines.iter().filter(|l| l.contains(needle)).count();
        assert_eq!(count(r#""verb":"stats""#), 3);
        assert_eq!(count(r#""verb":"pcg""#), 3);
        assert_eq!(count(r#""verb":"recover""#), 42);
        // Every compute line parses as a valid protocol request.
        for line in &lines {
            super::super::protocol::Request::parse(line).unwrap();
        }
        // A different seed reorders the graph/α draws.
        let other = request_lines(&BombardConfig { seed: 43, ..cfg });
        assert_ne!(lines, other);
    }

    #[test]
    fn deadline_is_attached_when_configured() {
        let cfg =
            BombardConfig { requests: 4, deadline_ms: 250, ..BombardConfig::default() };
        for line in request_lines(&cfg) {
            if !line.contains(r#""verb":"stats""#) {
                assert!(line.contains(r#""deadline_ms":250"#), "{line}");
            }
        }
    }

    #[test]
    fn classify_buckets_are_disjoint_and_total() {
        assert_eq!(classify(r#"{"id":1,"ok":true,"recovered":4}"#), Outcome::Ok);
        assert_eq!(
            classify(r#"{"id":1,"ok":false,"error":"overloaded","in_flight":4,"cap":4}"#),
            Outcome::Overloaded
        );
        assert_eq!(
            classify(r#"{"id":1,"ok":false,"error":"deadline_exceeded"}"#),
            Outcome::Deadline
        );
        assert_eq!(classify(r#"{"id":1,"ok":false,"error":"bad_param"}"#), Outcome::Failed);
        assert_eq!(classify("not json"), Outcome::Failed);
    }

    #[test]
    fn run_rejects_empty_mix_and_missing_daemon() {
        let cfg = BombardConfig { requests: 0, ..BombardConfig::default() };
        assert!(matches!(run(&cfg), Err(Error::BadParam { name: "requests", .. })));
        let cfg = BombardConfig { alphas: vec![], ..BombardConfig::default() };
        assert!(matches!(run(&cfg), Err(Error::BadParam { name: "alphas", .. })));
        let cfg = BombardConfig {
            socket: std::path::PathBuf::from("/tmp/pdgrass-no-such-daemon.sock"),
            ..BombardConfig::default()
        };
        assert!(matches!(run(&cfg), Err(Error::Io(_))));
    }

    #[test]
    fn compare_requires_a_daemon_and_renders_the_ratio() {
        let cfg = BombardConfig {
            socket: std::path::PathBuf::from("/tmp/pdgrass-no-such-daemon.sock"),
            ..BombardConfig::default()
        };
        assert!(matches!(run_compare(&cfg), Err(Error::Io(_))));
        let report = CompareReport {
            cold: BombardReport { elapsed_ms: 300.0, ..BombardReport::default() },
            warm: BombardReport { elapsed_ms: 100.0, ..BombardReport::default() },
        };
        let text = report.render();
        assert!(text.contains("cold/warm elapsed ratio: 3.00x"), "{text}");
        assert!(text.contains("cold (evicted cache, full prepare):"), "{text}");
    }
}
