//! Sparsification-as-a-service: a multi-graph session daemon.
//!
//! Algorithm 1 splits cleanly into an expensive, graph-pure half (steps
//! 1–3: Laplacian, spanning tree, density ordering — [`crate::Prepared`])
//! and a cheap, parameter-sensitive half (step 4: recovery at some α /
//! strategy / pipeline). That shape *is* a serving layer: prepare once,
//! cache by content, answer many recover/PCG requests against the cached
//! state. This module is that daemon.
//!
//! # Architecture
//!
//! - [`protocol`] — line-delimited JSON over a Unix-domain socket
//!   (std-only; no serde, no tokio). Verbs: `prepare`, `recover`, `pcg`,
//!   `stats`, `evict`, `shutdown`. Success responses are restricted to
//!   deterministic content so identical requests produce byte-identical
//!   lines; [`protocol::Client`] is the blocking client.
//! - [`cache`] — LRU [`cache::PreparedCache`] keyed by the deterministic
//!   graph fingerprint ([`crate::graph::fingerprint`]), with a spec memo
//!   and per-spec consecutive-failure caps.
//! - [`admission`] — bounded in-flight gate: past `max_in_flight`,
//!   requests get a typed `overloaded` rejection instead of queueing.
//! - [`server`] — socket lifecycle, per-connection handler threads (via
//!   [`crate::par::spawn_service`]; compute still runs on the shared
//!   pool), per-request deadlines, graceful shutdown.
//! - [`summary`] — JSON-lines per-request run summaries (timings, cache
//!   hit/miss, outcome) and the daemon counters behind `stats`.
//! - [`bombard`] — seeded deterministic load replay reporting throughput
//!   and p50/p95/p99 latency.
//! - [`json`] — the minimal JSON value/parser the wire format rides on.
//!
//! # Quickstart
//!
//! Serve (defaults: socket `/tmp/pdgrass.sock`, 8 cached graphs, 4
//! in-flight requests, summaries to stderr):
//!
//! ```text
//! pdgrass serve --socket /tmp/pdgrass.sock --cache-capacity 8 --max-in-flight 4
//! ```
//!
//! Talk to it (any newline-framed socket client works):
//!
//! ```text
//! {"id":1,"verb":"prepare","graph":{"name":"15-M6","scale":0.05}}
//! {"id":2,"verb":"recover","graph":{"name":"15-M6","scale":0.05},"alpha":0.05}
//! {"id":3,"verb":"stats"}
//! ```
//!
//! Replay a deterministic load and print percentiles (exits nonzero if
//! any request fails for a reason back-pressure does not explain):
//!
//! ```text
//! pdgrass bombard --socket /tmp/pdgrass.sock --requests 64 --clients 4 \
//!     --graphs 15-M6 --alphas 0.02,0.05 --scale 0.02 --seed 42
//! ```
//!
//! # Warm starts: `snapshot_dir`
//!
//! With a snapshot directory configured (`[serve] snapshot_dir` in the
//! config file, or `--snapshot-dir`), the daemon becomes restartable
//! without re-paying steps 1–3: every successful prepare is written back
//! as a fingerprint-keyed [`crate::snapshot`] container
//! (`<dir>/<fingerprint>.pdsnap`), and every cache miss *first* tries a
//! snapshot load — full validation included — before falling back to a
//! full prepare. A corrupt or stale file is counted (`load_failures` in
//! the `stats` verb's `snapshot` block, `"snapshot":"load-failure"` in
//! run summaries) and then healed by the fallback prepare's write-back;
//! it never poisons the cache or fails the request.
//!
//! ```text
//! pdgrass serve --socket /tmp/pdgrass.sock --snapshot-dir /var/cache/pdgrass
//! # ... daemon restarts (crash, deploy, reboot) ...
//! pdgrass serve --socket /tmp/pdgrass.sock --snapshot-dir /var/cache/pdgrass
//! # first request per known graph is now a warm load, not a prepare
//! pdgrass bombard --socket /tmp/pdgrass.sock --warm-compare   # quantify it
//! ```
//!
//! Or in-process:
//!
//! ```no_run
//! use pdgrass::config::ServeConfig;
//! use pdgrass::serve::{protocol::Client, server::Server};
//!
//! let mut cfg = ServeConfig::default();
//! cfg.socket = std::path::PathBuf::from("/tmp/pdgrass-demo.sock");
//! let server = Server::start(cfg)?;
//! let mut client = Client::connect(server.socket())?;
//! let resp = client.call_line(
//!     r#"{"id":1,"verb":"recover","graph":{"name":"15-M6","scale":0.02},"alpha":0.05}"#,
//! )?;
//! assert!(resp.contains(r#""ok":true"#));
//! server.stop();
//! server.wait();
//! # Ok::<(), Box<dyn std::error::Error>>(())
//! ```

pub mod admission;
pub mod bombard;
pub mod cache;
pub mod json;
pub mod protocol;
pub mod server;
pub mod summary;

pub use admission::{Admission, AdmissionStats};
pub use bombard::{BombardConfig, BombardReport, CompareReport};
pub use cache::{CacheStats, PreparedCache};
pub use protocol::Client;
pub use server::Server;
pub use summary::{RequestSummary, SnapStats, SnapshotCounters, SummaryLog};
