//! The daemon's LRU cache of [`Prepared`] states.
//!
//! Keyed by the deterministic graph fingerprint
//! ([`crate::graph::fingerprint`]): steps 1–3 of Algorithm 1 are a pure
//! function of the graph, so equal fingerprints mean interchangeable
//! prepared state — a hit serves a recover at any (α, strategy,
//! pipeline) combo without re-preparing. Entries are `Arc<Prepared>` so
//! a handler can keep recovering off an entry that was concurrently
//! evicted: eviction drops the cache's reference, never the state under
//! a running request.
//!
//! A spec memo maps `(name, scale, seed)` → fingerprint so repeat
//! spec-addressed requests skip graph regeneration entirely; the memo is
//! advisory (pruned with its entry on eviction) and never consulted for
//! fingerprint-addressed requests.
//!
//! **Failure containment:** a *prepare* failure (unknown graph, bad
//! scale, disconnected input) is recorded per spec; after
//! `failure_cap` consecutive failures the spec is fast-rejected without
//! burning pool time, until an `evict` resets it. A *recover/pcg*
//! failure never counts against the entry — bad α on a healthy graph
//! must not poison the cached prepared state (the graceful-degradation
//! requirement; the integration test exercises exactly this).
//!
//! All coordination is one plain `Mutex` — the critical sections are
//! pointer-sized bookkeeping (the expensive prepare runs *outside* the
//! lock), so there is nothing here for the atomics allowlist.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::session::Prepared;

/// Identity of a generatable graph spec: name, scale (by bit pattern —
/// the memo must distinguish any two floats the generator would), seed.
type SpecKey = (String, u64, u64);

/// Cumulative cache counters, snapshot via [`PreparedCache::stats`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CacheStats {
    pub entries: usize,
    pub capacity: usize,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

struct Entry {
    prepared: Arc<Prepared>,
    /// Logical clock of the last touch — smallest is evicted first.
    last_used: u64,
    /// Requests served off this entry (diagnostics via `stats`).
    uses: u64,
}

#[derive(Default)]
struct FailureRecord {
    consecutive: u32,
    last_error: String,
}

struct Inner {
    capacity: usize,
    failure_cap: u32,
    entries: HashMap<u64, Entry>,
    spec_memo: HashMap<SpecKey, u64>,
    failures: HashMap<SpecKey, FailureRecord>,
    clock: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

/// Bounded, thread-safe LRU cache of prepared sessions. See the module
/// docs for semantics.
pub struct PreparedCache {
    inner: Mutex<Inner>,
}

impl PreparedCache {
    /// A cache holding at most `capacity` entries (≥ 1, validated by
    /// config). `failure_cap` = consecutive prepare failures per spec
    /// before fast-rejection (0 disables the cap).
    pub fn new(capacity: usize, failure_cap: u32) -> PreparedCache {
        PreparedCache {
            inner: Mutex::new(Inner {
                capacity: capacity.max(1),
                failure_cap,
                entries: HashMap::new(),
                spec_memo: HashMap::new(),
                failures: HashMap::new(),
                clock: 0,
                hits: 0,
                misses: 0,
                evictions: 0,
            }),
        }
    }

    fn spec_key(name: &str, scale: f64, seed: u64) -> SpecKey {
        (name.to_string(), scale.to_bits(), seed)
    }

    /// Look up by fingerprint, counting a hit or miss and refreshing
    /// recency on hit.
    pub fn get(&self, fingerprint: u64) -> Option<Arc<Prepared>> {
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let clock = inner.clock;
        match inner.entries.get_mut(&fingerprint) {
            Some(e) => {
                e.last_used = clock;
                e.uses += 1;
                inner.hits += 1;
                Some(e.prepared.clone())
            }
            None => {
                inner.misses += 1;
                None
            }
        }
    }

    /// Look up by spec memo (no graph regeneration on hit). Counts like
    /// [`PreparedCache::get`]. A memo pointing at an evicted entry is
    /// pruned and reported as a miss.
    pub fn get_spec(&self, name: &str, scale: f64, seed: u64) -> Option<Arc<Prepared>> {
        let key = PreparedCache::spec_key(name, scale, seed);
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let clock = inner.clock;
        let Some(&fp) = inner.spec_memo.get(&key) else {
            inner.misses += 1;
            return None;
        };
        match inner.entries.get_mut(&fp) {
            Some(e) => {
                e.last_used = clock;
                e.uses += 1;
                inner.hits += 1;
                Some(e.prepared.clone())
            }
            None => {
                inner.spec_memo.remove(&key);
                inner.misses += 1;
                None
            }
        }
    }

    /// If the spec has hit its consecutive-prepare-failure cap, the
    /// recorded reason; the caller fast-rejects without preparing.
    pub fn failure_capped(&self, name: &str, scale: f64, seed: u64) -> Option<String> {
        let key = PreparedCache::spec_key(name, scale, seed);
        let inner = self.inner.lock().unwrap();
        if inner.failure_cap == 0 {
            return None;
        }
        inner
            .failures
            .get(&key)
            .filter(|r| r.consecutive >= inner.failure_cap)
            .map(|r| r.last_error.clone())
    }

    /// Record a prepare failure for the spec (consecutive count; reset
    /// by success or evict).
    pub fn record_prepare_failure(&self, name: &str, scale: f64, seed: u64, error: &str) {
        let key = PreparedCache::spec_key(name, scale, seed);
        let mut inner = self.inner.lock().unwrap();
        let rec = inner.failures.entry(key).or_default();
        rec.consecutive += 1;
        rec.last_error = error.to_string();
    }

    /// Insert a freshly prepared state, evicting least-recently-used
    /// entries beyond capacity. If the fingerprint is already present
    /// (two handlers raced the same miss), the existing entry wins and
    /// is returned — both handlers then share one state. A spec memo is
    /// recorded when the insert came from a spec-addressed request, and
    /// any failure record for that spec is cleared.
    pub fn insert(
        &self,
        prepared: Arc<Prepared>,
        spec: Option<(&str, f64, u64)>,
    ) -> (Arc<Prepared>, Vec<u64>) {
        let fp = prepared.fingerprint();
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let clock = inner.clock;
        if let Some((name, scale, seed)) = spec {
            let key = PreparedCache::spec_key(name, scale, seed);
            inner.failures.remove(&key);
            inner.spec_memo.insert(key, fp);
        }
        let kept = match inner.entries.get_mut(&fp) {
            Some(existing) => {
                existing.last_used = clock;
                existing.uses += 1;
                existing.prepared.clone()
            }
            None => {
                inner
                    .entries
                    .insert(fp, Entry { prepared: prepared.clone(), last_used: clock, uses: 1 });
                prepared
            }
        };
        let mut evicted = Vec::new();
        while inner.entries.len() > inner.capacity {
            let victim = inner
                .entries
                .iter()
                .filter(|(k, _)| **k != fp)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k);
            let Some(victim) = victim else { break };
            inner.entries.remove(&victim);
            inner.spec_memo.retain(|_, v| *v != victim);
            inner.evictions += 1;
            evicted.push(victim);
        }
        (kept, evicted)
    }

    /// Drop one entry (returning whether it existed) and clear every
    /// failure record whose memo pointed at it. Explicit evictions do
    /// not count in the `evictions` stat (that tracks LRU pressure).
    pub fn evict(&self, fingerprint: u64) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let existed = inner.entries.remove(&fingerprint).is_some();
        let stale: Vec<SpecKey> = inner
            .spec_memo
            .iter()
            .filter(|(_, v)| **v == fingerprint)
            .map(|(k, _)| k.clone())
            .collect();
        for key in stale {
            inner.spec_memo.remove(&key);
            inner.failures.remove(&key);
        }
        existed
    }

    /// Drop every entry, memo, and failure record. Returns how many
    /// entries were dropped.
    pub fn evict_all(&self) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let n = inner.entries.len();
        inner.entries.clear();
        inner.spec_memo.clear();
        inner.failures.clear();
        n
    }

    /// Counter snapshot.
    pub fn stats(&self) -> CacheStats {
        let inner = self.inner.lock().unwrap();
        CacheStats {
            entries: inner.entries.len(),
            capacity: inner.capacity,
            hits: inner.hits,
            misses: inner.misses,
            evictions: inner.evictions,
        }
    }

    /// Resident fingerprints with their use counts, sorted by
    /// fingerprint so the `stats` response is deterministic.
    pub fn resident(&self) -> Vec<(u64, u64)> {
        let inner = self.inner.lock().unwrap();
        let mut rows: Vec<(u64, u64)> =
            inner.entries.iter().map(|(fp, e)| (*fp, e.uses)).collect();
        rows.sort_unstable_by_key(|(fp, _)| *fp);
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::Sparsify;
    use crate::util::Rng;

    fn prep(seed: u64) -> Arc<Prepared> {
        let g = crate::gen::grid(8, 8, 0.5, &mut Rng::new(seed));
        Arc::new(Sparsify::graph(g).prepare().unwrap())
    }

    #[test]
    fn hit_miss_and_recency_accounting() {
        let cache = PreparedCache::new(4, 0);
        let a = prep(1);
        let fp = a.fingerprint();
        assert!(cache.get(fp).is_none());
        cache.insert(a.clone(), Some(("a", 1.0, 1)));
        assert!(cache.get(fp).is_some());
        assert!(cache.get_spec("a", 1.0, 1).is_some());
        assert!(cache.get_spec("a", 2.0, 1).is_none());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (2, 2, 1));
        assert_eq!(cache.resident().len(), 1);
        assert_eq!(cache.resident()[0].0, fp);
    }

    #[test]
    fn lru_evicts_least_recently_used_at_capacity() {
        let cache = PreparedCache::new(2, 0);
        let (a, b, c) = (prep(1), prep(2), prep(3));
        let (fa, fb, fc) = (a.fingerprint(), b.fingerprint(), c.fingerprint());
        assert_ne!(fa, fb);
        cache.insert(a, None);
        cache.insert(b, None);
        // Touch a, so b is now least recently used.
        assert!(cache.get(fa).is_some());
        let (_, evicted) = cache.insert(c, None);
        assert_eq!(evicted, vec![fb]);
        assert!(cache.get(fb).is_none());
        assert!(cache.get(fa).is_some());
        assert!(cache.get(fc).is_some());
        assert_eq!(cache.stats().evictions, 1);
        assert_eq!(cache.stats().entries, 2);
    }

    #[test]
    fn racing_inserts_share_one_entry() {
        let cache = PreparedCache::new(4, 0);
        let g = crate::gen::grid(8, 8, 0.5, &mut Rng::new(9));
        let first = Arc::new(Sparsify::graph(g.clone()).prepare().unwrap());
        let second = Arc::new(Sparsify::graph(g).prepare().unwrap());
        assert_eq!(first.fingerprint(), second.fingerprint());
        let (kept1, _) = cache.insert(first.clone(), None);
        let (kept2, _) = cache.insert(second, None);
        // The first insert wins; the racing duplicate is discarded.
        assert!(Arc::ptr_eq(&kept1, &first));
        assert!(Arc::ptr_eq(&kept2, &first));
        assert_eq!(cache.stats().entries, 1);
    }

    #[test]
    fn failure_cap_trips_and_evict_resets() {
        let cache = PreparedCache::new(2, 2);
        assert!(cache.failure_capped("bad", 1.0, 7).is_none());
        cache.record_prepare_failure("bad", 1.0, 7, "boom");
        assert!(cache.failure_capped("bad", 1.0, 7).is_none(), "below cap");
        cache.record_prepare_failure("bad", 1.0, 7, "boom again");
        assert_eq!(cache.failure_capped("bad", 1.0, 7).as_deref(), Some("boom again"));
        // Distinct specs are tracked independently.
        assert!(cache.failure_capped("bad", 2.0, 7).is_none());
        // A successful insert for the spec clears its record.
        let a = prep(1);
        cache.insert(a.clone(), Some(("bad", 1.0, 7)));
        assert!(cache.failure_capped("bad", 1.0, 7).is_none());
        // Trip it again, then evict-by-fingerprint also resets (the
        // documented operator escape hatch).
        cache.record_prepare_failure("bad", 1.0, 7, "x");
        cache.record_prepare_failure("bad", 1.0, 7, "x");
        assert!(cache.failure_capped("bad", 1.0, 7).is_some());
        assert!(cache.evict(a.fingerprint()));
        assert!(cache.failure_capped("bad", 1.0, 7).is_none());
        assert!(!cache.evict(a.fingerprint()), "second evict is a no-op");
    }

    #[test]
    fn failure_cap_zero_disables() {
        let cache = PreparedCache::new(2, 0);
        for _ in 0..10 {
            cache.record_prepare_failure("bad", 1.0, 7, "boom");
        }
        assert!(cache.failure_capped("bad", 1.0, 7).is_none());
    }

    #[test]
    fn evict_all_clears_everything() {
        let cache = PreparedCache::new(4, 1);
        cache.insert(prep(1), Some(("a", 1.0, 1)));
        cache.insert(prep(2), Some(("b", 1.0, 1)));
        assert_eq!(cache.evict_all(), 2);
        assert_eq!(cache.stats().entries, 0);
        assert!(cache.get_spec("a", 1.0, 1).is_none());
    }

    #[test]
    fn evicted_entry_survives_for_holders() {
        let cache = PreparedCache::new(1, 0);
        let a = prep(1);
        cache.insert(a.clone(), None);
        let held = cache.get(a.fingerprint()).unwrap();
        let (_, evicted) = cache.insert(prep(2), None);
        assert_eq!(evicted, vec![a.fingerprint()]);
        // The held Arc still recovers fine after eviction.
        let r = held.recover(&crate::session::RecoverOpts::new(0.05)).unwrap();
        assert!(!r.edges().is_empty());
    }
}
