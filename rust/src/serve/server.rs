//! The daemon: socket lifecycle, connection handling, request dispatch.
//!
//! # Lifecycle
//!
//! [`Server::start`] binds the configured Unix-domain socket (recovering
//! a stale socket file left by a killed daemon: if nothing answers a
//! probe connect, the file is unlinked and re-bound; if something
//! answers, startup fails rather than hijacking a live daemon) and
//! starts an acceptor on a [`spawn_service`] thread. Each connection
//! gets its own service thread reading line-delimited requests;
//! **compute** runs on the shared [`crate::par::ThreadPool`] via the
//! ordinary session API, so a daemon with 30 connections still schedules
//! work across one pool rather than 30× oversubscribing the machine.
//!
//! Shutdown is cooperative (pure std cannot install signal handlers):
//! the `shutdown` verb — or [`Server::stop`] in-process — sets a flag
//! and pokes the acceptor with a self-connect; connection readers poll
//! the flag every 200 ms read-timeout tick. [`Server::wait`] joins the
//! acceptor and every handler, then unlinks the socket. A daemon killed
//! by SIGTERM instead simply dies; the stale-socket recovery above makes
//! the next start clean, which is what the CI smoke job asserts.
//!
//! # Dispatch
//!
//! Compute verbs (`prepare`/`recover`/`pcg`) pass admission control
//! first ([`Admission`]) — past `max_in_flight` they are rejected with
//! the typed `overloaded` error immediately. Admitted requests check
//! their deadline between stages (after prepare, after recover, after
//! PCG): a blown deadline abandons the *response*, never the work
//! already absorbed into the cache — the entry stays warm for the
//! retry. Control verbs (`stats`/`evict`/`shutdown`) bypass admission.
//!
//! A failed request never poisons state: a recover error (e.g. a bad α)
//! leaves the cache entry intact; a prepare failure is recorded per
//! spec and only fast-rejects that spec after `failure_cap` consecutive
//! failures (reset by `evict` or a later success); a handler panic is
//! confined to its connection and releases its admission permit.
//!
//! # Response determinism
//!
//! Compute-verb success responses carry only deterministic values
//! (fingerprints, counts, edge ids/hashes, PCG iterates) — identical
//! requests get byte-identical response lines regardless of cache
//! state, thread count, or concurrency. `stats` is the explicit
//! exception (it reports live counters and uptime); timings and cache
//! hit/miss per request go to the summary log ([`SummaryLog`]).

use std::io::{BufReader, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::admission::Admission;
use super::cache::PreparedCache;
use super::json::{int, num, obj, str as jstr, Value};
use super::protocol::{
    error_kind, error_response, fp_value, ok_response, protocol_error_response, GraphSpec,
    ReqOpts, Request, Target, Verb,
};
use super::summary::{RequestSummary, ServerCounters, SnapshotCounters, SummaryLog};
use crate::config::ServeConfig;
use crate::error::{Error, Result};
use crate::graph::{fingerprint_hex, Fnv1a};
use crate::par::{spawn_service, ServiceHandle};
use crate::recovery::Pipeline;
use crate::session::{Prepared, Sparsify};
use crate::util::Timer;

/// How often blocked connection readers wake to check the shutdown flag.
const READ_TICK: Duration = Duration::from_millis(200);

struct Shared {
    config: ServeConfig,
    /// `config.threads` with 0 resolved, once, at startup.
    default_threads: usize,
    cache: PreparedCache,
    admission: Admission,
    counters: ServerCounters,
    /// Warm-start bookkeeping for the `snapshot_dir` path.
    snap: SnapshotCounters,
    log: SummaryLog,
    shutdown: Mutex<bool>,
    handlers: Mutex<Vec<ServiceHandle>>,
}

/// A running daemon. Hold it and [`Server::wait`] to serve until a
/// `shutdown` request (the `pdgrass serve` verb does exactly this), or
/// drive it in-process from tests via the accessors.
pub struct Server {
    shared: Arc<Shared>,
    acceptor: Option<ServiceHandle>,
}

impl Server {
    /// Bind the socket and start accepting. See the module docs for the
    /// stale-socket recovery semantics.
    pub fn start(config: ServeConfig) -> Result<Server> {
        let socket = config.socket.clone();
        if socket.exists() {
            match UnixStream::connect(&socket) {
                Ok(_) => {
                    return Err(Error::Config(format!(
                        "socket {} is in use by a running daemon",
                        socket.display()
                    )));
                }
                Err(_) => {
                    // Stale file from a killed daemon — reclaim it.
                    std::fs::remove_file(&socket)?;
                }
            }
        }
        let listener = UnixListener::bind(&socket)?;
        let log = SummaryLog::open(&config.log)?;
        if let Some(dir) = &config.snapshot_dir {
            std::fs::create_dir_all(dir)?;
        }
        let shared = Arc::new(Shared {
            default_threads: config.resolved_threads(),
            cache: PreparedCache::new(config.cache_capacity, config.failure_cap),
            admission: Admission::new(config.max_in_flight),
            counters: ServerCounters::default(),
            snap: SnapshotCounters::default(),
            log,
            shutdown: Mutex::new(false),
            handlers: Mutex::new(Vec::new()),
            config,
        });
        let accept_shared = shared.clone();
        let acceptor = spawn_service("accept", move || accept_loop(listener, accept_shared));
        Ok(Server { shared, acceptor: Some(acceptor) })
    }

    /// The socket path this daemon is bound to.
    pub fn socket(&self) -> &std::path::Path {
        &self.shared.config.socket
    }

    /// The admission gate — exposed so tests can pin the daemon at its
    /// cap deterministically (pre-acquire permits, then assert a
    /// client's request is rejected typed).
    pub fn admission(&self) -> &Admission {
        &self.shared.admission
    }

    /// The prepared-state cache (test/diagnostic access).
    pub fn cache(&self) -> &PreparedCache {
        &self.shared.cache
    }

    /// Warm-start counters for the `snapshot_dir` path
    /// (test/diagnostic access).
    pub fn snapshot_stats(&self) -> super::summary::SnapStats {
        self.shared.snap.snapshot()
    }

    /// Request shutdown from in-process: set the flag and poke the
    /// acceptor awake. Follow with [`Server::wait`].
    pub fn stop(&self) {
        *self.shared.shutdown.lock().unwrap() = true;
        // The poke connection exists only to unblock `accept`; it is
        // dropped by the acceptor after the flag check.
        let _ = UnixStream::connect(&self.shared.config.socket);
    }

    /// Block until shutdown (the `shutdown` verb or [`Server::stop`]),
    /// join the acceptor and every connection handler, and unlink the
    /// socket file.
    pub fn wait(mut self) {
        if let Some(acceptor) = self.acceptor.take() {
            acceptor.join();
        }
        // The acceptor is dead, so no new handlers can appear.
        let handlers = std::mem::take(&mut *self.shared.handlers.lock().unwrap());
        for h in handlers {
            h.join();
        }
        let _ = std::fs::remove_file(&self.shared.config.socket);
    }
}

fn accept_loop(listener: UnixListener, shared: Arc<Shared>) {
    loop {
        let stream = match listener.accept() {
            Ok((stream, _)) => stream,
            Err(_) => {
                if *shared.shutdown.lock().unwrap() {
                    break;
                }
                continue;
            }
        };
        if *shared.shutdown.lock().unwrap() {
            // The stream is the shutdown poke (or a client racing it);
            // either way, stop accepting.
            break;
        }
        let conn_shared = shared.clone();
        let handle = spawn_service("conn", move || handle_connection(conn_shared, stream));
        let mut handlers = shared.handlers.lock().unwrap();
        handlers.retain(|h| !h.is_finished());
        handlers.push(handle);
    }
}

fn handle_connection(shared: Arc<Shared>, stream: UnixStream) {
    if stream.set_read_timeout(Some(READ_TICK)).is_err() {
        return;
    }
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    let mut buf: Vec<u8> = Vec::new();
    loop {
        use std::io::BufRead;
        match reader.read_until(b'\n', &mut buf) {
            Ok(0) => break,
            Ok(_) => {
                let complete = buf.last() == Some(&b'\n');
                if !complete {
                    // Ok without a delimiter is EOF mid-line; serve the
                    // partial line, then close.
                    let line = String::from_utf8_lossy(&buf).into_owned();
                    let _ = serve_line(&shared, line.trim_end_matches(['\n', '\r']), &mut writer);
                    break;
                }
                let line = String::from_utf8_lossy(&buf).into_owned();
                buf.clear();
                let line = line.trim_end_matches(['\n', '\r']);
                if line.trim().is_empty() {
                    continue;
                }
                match serve_line(&shared, line, &mut writer) {
                    Ok(keep_open) if keep_open => {}
                    _ => break,
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // Timeout tick: partial bytes (if any) stay in `buf` and
                // the next read_until continues the same line.
                if *shared.shutdown.lock().unwrap() {
                    break;
                }
            }
            Err(_) => break,
        }
    }
}

/// Handle one request line: dispatch, respond, log, count. Returns
/// `Ok(false)` when the connection should close (shutdown verb),
/// `Err` on a dead client socket.
fn serve_line(shared: &Shared, line: &str, writer: &mut UnixStream) -> std::io::Result<bool> {
    let t = Timer::start();
    let (response, mut summary, keep_open) = match Request::parse(line) {
        Err((id, msg)) => {
            let summary = RequestSummary {
                id,
                verb: "protocol",
                ok: false,
                error: Some("protocol".to_string()),
                ..RequestSummary::default()
            };
            (protocol_error_response(id, &msg), summary, true)
        }
        Ok(req) => dispatch(shared, &req),
    };
    summary.total_ms = t.ms();
    shared
        .counters
        .record(summary.verb, if summary.ok { None } else { summary.error.as_deref() });
    shared.log.emit(&summary);
    writer.write_all(response.render().as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()?;
    Ok(keep_open)
}

/// Per-request deadline: 0 = none. Checked between stages — compute is
/// never interrupted mid-stage, so a blown deadline costs at most one
/// stage of latency and abandons only the response.
struct Deadline {
    start: Instant,
    limit_ms: u64,
}

impl Deadline {
    fn new(limit_ms: u64) -> Deadline {
        Deadline { start: Instant::now(), limit_ms }
    }

    fn check(&self) -> Result<()> {
        if self.limit_ms == 0 {
            return Ok(());
        }
        let elapsed = self.start.elapsed().as_millis() as u64;
        if elapsed > self.limit_ms {
            Err(Error::DeadlineExceeded { elapsed_ms: elapsed, deadline_ms: self.limit_ms })
        } else {
            Ok(())
        }
    }
}

fn dispatch(shared: &Shared, req: &Request) -> (Value, RequestSummary, bool) {
    let mut summary = RequestSummary {
        id: Some(req.id),
        verb: verb_name(&req.verb),
        ok: true,
        ..RequestSummary::default()
    };
    let deadline = Deadline::new(req.deadline_ms.unwrap_or(shared.config.deadline_ms));
    let mut keep_open = true;
    let result = match &req.verb {
        Verb::Prepare { spec, pipeline, threads } => {
            handle_prepare(shared, &deadline, &mut summary, spec, *pipeline, *threads)
        }
        Verb::Recover { target, opts, return_edges } => {
            handle_recover(shared, &deadline, &mut summary, target, opts, *return_edges)
        }
        Verb::Pcg { target, opts, rhs_seed, tol, maxit } => {
            handle_pcg(shared, &deadline, &mut summary, target, opts, *rhs_seed, *tol, *maxit)
        }
        Verb::Stats => Ok(stats_fields(shared)),
        Verb::Evict { fingerprint } => {
            let evicted = match fingerprint {
                Some(fp) => {
                    summary.fingerprint = Some(*fp);
                    usize::from(shared.cache.evict(*fp))
                }
                None => shared.cache.evict_all(),
            };
            Ok(vec![("evicted", int(evicted as u64))])
        }
        Verb::Shutdown => {
            *shared.shutdown.lock().unwrap() = true;
            let _ = UnixStream::connect(&shared.config.socket);
            keep_open = false;
            Ok(vec![("stopping", Value::Bool(true))])
        }
    };
    let response = match result {
        Ok(fields) => ok_response(req.id, fields),
        Err(e) => {
            summary.ok = false;
            summary.error = Some(error_kind(&e).to_string());
            error_response(Some(req.id), &e)
        }
    };
    (response, summary, keep_open)
}

fn verb_name(verb: &Verb) -> &'static str {
    match verb {
        Verb::Prepare { .. } => "prepare",
        Verb::Recover { .. } => "recover",
        Verb::Pcg { .. } => "pcg",
        Verb::Stats => "stats",
        Verb::Evict { .. } => "evict",
        Verb::Shutdown => "shutdown",
    }
}

/// Try to warm-load `fp` from the configured snapshot directory.
///
/// Returns `Some(prepared)` only for a snapshot that decoded *and* whose
/// content fingerprint matches the probed one — a valid snapshot stored
/// under the wrong filename must not poison the cache. Counter and
/// summary classification: decoded + matching → `hit`; missing file (or
/// any other I/O error) → `miss`; a typed [`Error::Snapshot`] rejection
/// or a fingerprint mismatch → `load-failure`. Never fails the request.
fn try_snapshot_load(shared: &Shared, summary: &mut RequestSummary, fp: u64) -> Option<Prepared> {
    let dir = shared.config.snapshot_dir.as_ref()?;
    let path = crate::snapshot::file_path(dir, fp);
    match Prepared::load(&path) {
        Ok(p) if p.fingerprint() == fp => {
            shared.snap.record_hit();
            summary.snapshot = Some("hit");
            Some(p)
        }
        Ok(_) => {
            // Decoded fine but holds a different graph: the file was
            // renamed or copied under the wrong key. Treat as corrupt.
            shared.snap.record_load_failure();
            summary.snapshot = Some("load-failure");
            None
        }
        Err(Error::Snapshot { .. }) => {
            shared.snap.record_load_failure();
            summary.snapshot = Some("load-failure");
            None
        }
        Err(_) => {
            shared.snap.record_miss();
            summary.snapshot = Some("miss");
            None
        }
    }
}

/// Best-effort snapshot write-back after a successful prepare. Save
/// errors are swallowed: the request already has its answer in memory.
fn try_snapshot_save(shared: &Shared, prepared: &Prepared) {
    if let Some(dir) = shared.config.snapshot_dir.as_ref() {
        let path = crate::snapshot::file_path(dir, prepared.fingerprint());
        if prepared.save(&path).is_ok() {
            shared.snap.record_save();
        }
    }
}

/// Resolve a target to cached prepared state, preparing (and caching) on
/// a spec miss. With a configured `snapshot_dir`, cache misses first try
/// a snapshot load, and freshly prepared state is written back. Updates
/// the summary's fingerprint / cache / snapshot / prepare_ms fields as a
/// side effect.
fn resolve_target(
    shared: &Shared,
    summary: &mut RequestSummary,
    target: &Target,
    pipeline: Pipeline,
    threads: usize,
) -> Result<Arc<Prepared>> {
    match target {
        Target::Fingerprint(fp) => {
            summary.fingerprint = Some(*fp);
            match shared.cache.get(*fp) {
                Some(p) => {
                    summary.cache_hit = Some(true);
                    Ok(p)
                }
                None => {
                    summary.cache_hit = Some(false);
                    let t = Timer::start();
                    if let Some(p) = try_snapshot_load(shared, summary, *fp) {
                        summary.prepare_ms = t.ms();
                        let threads =
                            if threads == 0 { shared.default_threads } else { threads };
                        let (kept, _evicted) =
                            shared.cache.insert(Arc::new(p.with_threads(threads)), None);
                        return Ok(kept);
                    }
                    Err(Error::UnknownGraph { name: fingerprint_hex(*fp) })
                }
            }
        }
        Target::Spec(spec) => {
            if let Some(p) = shared.cache.get_spec(&spec.name, spec.scale, spec.seed) {
                summary.cache_hit = Some(true);
                summary.fingerprint = Some(p.fingerprint());
                return Ok(p);
            }
            summary.cache_hit = Some(false);
            if let Some(reason) =
                shared.cache.failure_capped(&spec.name, spec.scale, spec.seed)
            {
                return Err(Error::BadParam {
                    name: "graph",
                    why: format!(
                        "spec disabled after {} consecutive prepare failures (last: {reason}); \
                         `evict` to re-enable",
                        shared.config.failure_cap
                    ),
                });
            }
            let t = Timer::start();
            let threads = if threads == 0 { shared.default_threads } else { threads };
            let session = match Sparsify::suite(&spec.name, spec.scale, spec.seed) {
                Ok(s) => s.threads(threads).pipeline(pipeline),
                Err(e) => {
                    summary.prepare_ms = t.ms();
                    shared.cache.record_prepare_failure(
                        &spec.name,
                        spec.scale,
                        spec.seed,
                        &e.to_string(),
                    );
                    return Err(e);
                }
            };
            if let Some(p) = try_snapshot_load(shared, summary, session.fingerprint()) {
                summary.prepare_ms = t.ms();
                let (kept, _evicted) = shared.cache.insert(
                    Arc::new(p.with_threads(threads)),
                    Some((&spec.name, spec.scale, spec.seed)),
                );
                summary.fingerprint = Some(kept.fingerprint());
                return Ok(kept);
            }
            let prepared = session.prepare();
            summary.prepare_ms = t.ms();
            match prepared {
                Ok(p) => {
                    let mine = Arc::new(p);
                    let (kept, _evicted) = shared
                        .cache
                        .insert(mine.clone(), Some((&spec.name, spec.scale, spec.seed)));
                    summary.fingerprint = Some(kept.fingerprint());
                    // Only the insert-race winner writes the snapshot, so
                    // concurrent preparers don't stampede the same file.
                    if Arc::ptr_eq(&kept, &mine) {
                        try_snapshot_save(shared, &kept);
                    }
                    Ok(kept)
                }
                Err(e) => {
                    shared.cache.record_prepare_failure(
                        &spec.name,
                        spec.scale,
                        spec.seed,
                        &e.to_string(),
                    );
                    Err(e)
                }
            }
        }
    }
}

fn handle_prepare(
    shared: &Shared,
    deadline: &Deadline,
    summary: &mut RequestSummary,
    spec: &GraphSpec,
    pipeline: Pipeline,
    threads: usize,
) -> Result<Vec<(&'static str, Value)>> {
    let _permit = shared.admission.try_acquire()?;
    deadline.check()?;
    let prepared =
        resolve_target(shared, summary, &Target::Spec(spec.clone()), pipeline, threads)?;
    deadline.check()?;
    Ok(vec![
        ("fingerprint", fp_value(prepared.fingerprint())),
        ("vertices", int(prepared.graph().num_vertices() as u64)),
        ("edges", int(prepared.graph().num_edges() as u64)),
        ("off_tree", int(prepared.num_off_tree() as u64)),
        ("subtasks", int(prepared.subtasks().len() as u64)),
    ])
}

#[allow(clippy::too_many_arguments)]
fn handle_recover(
    shared: &Shared,
    deadline: &Deadline,
    summary: &mut RequestSummary,
    target: &Target,
    opts: &ReqOpts,
    return_edges: bool,
) -> Result<Vec<(&'static str, Value)>> {
    let _permit = shared.admission.try_acquire()?;
    deadline.check()?;
    let prepared = resolve_target(shared, summary, target, opts.pipeline, opts.threads)?;
    deadline.check()?;
    let t = Timer::start();
    let recover_opts = opts.recover_opts(shared.default_threads);
    let recovered = prepared.recover(&recover_opts);
    summary.recover_ms = t.ms();
    let recovered = recovered?;
    deadline.check()?;
    summary.recovered = Some(recovered.edges().len());
    let mut fields = vec![
        ("fingerprint", fp_value(prepared.fingerprint())),
        ("recovered", int(recovered.edges().len() as u64)),
        ("edges_hash", jstr(edges_hash(recovered.edges()))),
    ];
    if return_edges {
        let ids = recovered.edges().iter().map(|&e| int(e as u64)).collect();
        fields.push(("edges", Value::Arr(ids)));
    }
    Ok(fields)
}

#[allow(clippy::too_many_arguments)]
fn handle_pcg(
    shared: &Shared,
    deadline: &Deadline,
    summary: &mut RequestSummary,
    target: &Target,
    opts: &ReqOpts,
    rhs_seed: u64,
    tol: f64,
    maxit: usize,
) -> Result<Vec<(&'static str, Value)>> {
    let _permit = shared.admission.try_acquire()?;
    deadline.check()?;
    let prepared = resolve_target(shared, summary, target, opts.pipeline, opts.threads)?;
    deadline.check()?;
    let t = Timer::start();
    let recovered = prepared.recover(&opts.recover_opts(shared.default_threads));
    summary.recover_ms = t.ms();
    let recovered = recovered?;
    summary.recovered = Some(recovered.edges().len());
    deadline.check()?;
    let t = Timer::start();
    let outcome = recovered.sparsifier().pcg(rhs_seed, tol, maxit);
    summary.pcg_ms = t.ms();
    let outcome = outcome?;
    deadline.check()?;
    summary.iterations = Some(outcome.iterations);
    // Non-convergence is data, not an error: the sparsifier quality
    // metric legitimately reports "did not converge in maxit".
    Ok(vec![
        ("fingerprint", fp_value(prepared.fingerprint())),
        ("recovered", int(recovered.edges().len() as u64)),
        ("iterations", int(outcome.iterations as u64)),
        ("relres", num(outcome.relres)),
        ("converged", Value::Bool(outcome.converged)),
    ])
}

/// FNV-1a digest of the recovered edge-id sequence — the compact
/// deterministic witness clients (and the bitwise-identity test) compare
/// without shipping the full id list.
fn edges_hash(edges: &[u32]) -> String {
    let mut h = Fnv1a::new();
    h.write_u64(edges.len() as u64);
    for &e in edges {
        h.write_u32(e);
    }
    fingerprint_hex(h.finish())
}

fn stats_fields(shared: &Shared) -> Vec<(&'static str, Value)> {
    let cache = shared.cache.stats();
    let adm = shared.admission.stats();
    let c = shared.counters.snapshot();
    let snap = shared.snap.snapshot();
    let resident: Vec<Value> = shared
        .cache
        .resident()
        .into_iter()
        .map(|(fp, uses)| {
            obj(vec![("fingerprint", fp_value(fp)), ("uses", int(uses))])
        })
        .collect();
    vec![
        ("uptime_ms", int(shared.log.uptime_ms())),
        (
            "requests",
            obj(vec![
                ("prepare", int(c.prepare)),
                ("recover", int(c.recover)),
                ("pcg", int(c.pcg)),
                ("stats", int(c.stats)),
                ("evict", int(c.evict)),
                ("errors", int(c.errors)),
                ("overloaded", int(c.overloaded)),
                ("deadline_exceeded", int(c.deadline_exceeded)),
            ]),
        ),
        (
            "cache",
            obj(vec![
                ("entries", int(cache.entries as u64)),
                ("capacity", int(cache.capacity as u64)),
                ("hits", int(cache.hits)),
                ("misses", int(cache.misses)),
                ("evictions", int(cache.evictions)),
                ("resident", Value::Arr(resident)),
            ]),
        ),
        (
            "admission",
            obj(vec![
                ("in_flight", int(adm.in_flight as u64)),
                ("cap", int(adm.cap as u64)),
                ("accepted", int(adm.accepted)),
                ("rejected", int(adm.rejected)),
                ("peak", int(adm.peak as u64)),
            ]),
        ),
        (
            "snapshot",
            obj(vec![
                ("hits", int(snap.hits)),
                ("misses", int(snap.misses)),
                ("load_failures", int(snap.load_failures)),
                ("saves", int(snap.saves)),
            ]),
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deadline_zero_never_fires() {
        let d = Deadline::new(0);
        std::thread::sleep(Duration::from_millis(2));
        d.check().unwrap();
    }

    #[test]
    fn deadline_fires_typed_after_limit() {
        let d = Deadline::new(1);
        std::thread::sleep(Duration::from_millis(5));
        match d.check() {
            Err(Error::DeadlineExceeded { elapsed_ms, deadline_ms }) => {
                assert!(elapsed_ms >= 2, "elapsed {elapsed_ms}");
                assert_eq!(deadline_ms, 1);
            }
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
    }

    #[test]
    fn edges_hash_is_order_and_content_sensitive() {
        let a = edges_hash(&[1, 2, 3]);
        assert_eq!(a, edges_hash(&[1, 2, 3]), "deterministic");
        assert_ne!(a, edges_hash(&[3, 2, 1]), "order matters");
        assert_ne!(a, edges_hash(&[1, 2]), "length matters");
        assert_ne!(edges_hash(&[]), edges_hash(&[0]), "empty vs zero id");
        assert!(a.starts_with("0x") && a.len() == 18);
    }

    #[test]
    fn stale_socket_is_reclaimed_but_live_socket_is_not() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("pdgrass-stale-{}.sock", std::process::id()));
        let _ = std::fs::remove_file(&path);
        // Plant a stale socket file nothing is listening on.
        drop(UnixListener::bind(&path).unwrap());
        assert!(path.exists(), "bind leaves a socket file behind");
        let cfg = ServeConfig {
            socket: path.clone(),
            log: "off".to_string(),
            ..ServeConfig::default()
        };
        let server = Server::start(cfg.clone()).expect("stale socket must be reclaimed");
        // A second daemon on the same live socket must refuse.
        match Server::start(cfg) {
            Err(Error::Config(msg)) => assert!(msg.contains("in use"), "{msg}"),
            Err(e) => panic!("expected Config error, got {e:?}"),
            Ok(_) => panic!("expected Config error, got a second live daemon"),
        }
        server.stop();
        server.wait();
        assert!(!path.exists(), "wait() unlinks the socket");
    }
}
