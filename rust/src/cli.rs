//! Command-line interface (hand-rolled; no `clap` in the offline vendor
//! set).
//!
//! Subcommands map one-to-one onto the experiment drivers plus a few
//! utility verbs. The `sparsify`/`evaluate` verbs are thin wrappers over
//! the session API (`Sparsify → Prepared → recover → Sparsifier`); all
//! library failures arrive as the typed `error::Error` and convert to
//! `anyhow` only here, at the binary boundary.
//!
//! ```text
//! pdgrass sparsify --graph 15-M6 --alpha 0.05 [--out P.mtx]
//! pdgrass evaluate --graph 15-M6 --alpha 0.05 [--xla]
//! pdgrass suite    [--scale S] [--quick]
//! pdgrass table2 | table3 | table4 | fig1 | fig6-8   [--scale S] [--config F]
//! pdgrass list     # suite rows
//! pdgrass audit    [--root DIR] [--allowlist FILE]   # static analysis
//! pdgrass prepare  --graph NAME [--save FILE.pdsnap | --load FILE.pdsnap]
//! pdgrass serve    [--socket P] [--cache-capacity N] [--snapshot-dir D]
//! pdgrass bombard  [--socket P] [--requests N] [--clients N] [--warm-compare]
//! pdgrass benchdiff OLD.json NEW.json [--tolerance T] [--models-only]
//! ```
//!
//! `benchdiff` is the one verb taking positional arguments (the two
//! artifact paths), so it is routed before the strict `--key value`
//! parser.

use crate::config::{Doc, RunConfig, ServeConfig};
use crate::coordinator::{experiments, PipelineConfig};
use crate::session::Sparsify;
use crate::util::{sci, Timer};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    /// Subcommand verb.
    pub verb: String,
    /// `--key value` options.
    pub opts: std::collections::HashMap<String, String>,
    /// Bare `--flag`s.
    pub flags: Vec<String>,
}

impl Cli {
    /// Parse `args` (not including `argv[0]`).
    pub fn parse(args: &[String]) -> anyhow::Result<Cli> {
        let mut cli = Cli::default();
        let mut it = args.iter().peekable();
        cli.verb = it.next().cloned().unwrap_or_else(|| "help".to_string());
        while let Some(a) = it.next() {
            let Some(name) = a.strip_prefix("--") else {
                anyhow::bail!("unexpected argument: {a}");
            };
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    cli.opts.insert(name.to_string(), it.next().unwrap().clone());
                }
                _ => cli.flags.push(name.to_string()),
            }
        }
        Ok(cli)
    }

    /// Option as f64.
    pub fn f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.opts.get(key) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|e| anyhow::anyhow!("--{key}: {e}")),
        }
    }

    /// Option as string.
    pub fn str(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// Flag present?
    pub fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }
}

/// Build the pipeline config from CLI options (+ optional `--config`).
fn pipeline_cfg(cli: &Cli) -> anyhow::Result<(PipelineConfig, RunConfig)> {
    let mut run = match cli.str("config") {
        Some(path) => RunConfig::from_doc(&Doc::load(std::path::Path::new(path))?)?,
        None => RunConfig::default(),
    };
    if let Some(s) = cli.str("scale") {
        run.scale = s.parse()?;
    }
    if cli.has("quick") {
        run.scale = run.scale.min(0.05);
        run.trials = 1;
    }
    if let Some(s) = cli.str("seed") {
        run.seed = s.parse()?;
    }
    if let Some(s) = cli.str("threads") {
        run.threads = s.parse()?;
    }
    if let Some(s) = cli.str("strategy") {
        run.strategy = s.parse()?;
    }
    if let Some(s) = cli.str("shard-min") {
        run.shard_min = s.parse()?;
        if run.shard_min == 0 {
            anyhow::bail!("--shard-min: must be at least 1");
        }
    }
    if let Some(s) = cli.str("pipeline") {
        run.pipeline = s.parse()?;
    }
    if let Some(s) = cli.str("relabel") {
        run.relabel = s.parse()?;
    }
    let mut p = run.pipeline();
    p.alpha = cli.f64("alpha", p.alpha)?;
    Ok((p, run))
}

/// Split a `--graphs a,b,c`-style comma list.
fn csv_list(s: &str) -> Vec<String> {
    s.split(',').map(|p| p.trim().to_string()).filter(|p| !p.is_empty()).collect()
}

fn graph_names(run: &RunConfig) -> Vec<&str> {
    if run.graphs.is_empty() {
        experiments::suite_names()
    } else {
        run.graphs.iter().map(|s| s.as_str()).collect()
    }
}

/// `pdgrass benchdiff OLD.json NEW.json [--tolerance T] [--models-only]`:
/// compare two `benches/micro.rs` artifacts. Takes positional paths, so
/// it parses its own arguments instead of going through [`Cli::parse`].
fn run_benchdiff(args: &[String]) -> anyhow::Result<()> {
    let mut paths: Vec<&str> = Vec::new();
    let mut tolerance = crate::benchdiff::DEFAULT_TOLERANCE;
    let mut models_only = false;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--tolerance" => {
                let v = it.next().ok_or_else(|| anyhow::anyhow!("--tolerance: missing value"))?;
                tolerance = v.parse().map_err(|e| anyhow::anyhow!("--tolerance: {e}"))?;
            }
            "--models-only" => models_only = true,
            flag if flag.starts_with("--") => anyhow::bail!("benchdiff: unknown option {flag}"),
            path => paths.push(path),
        }
    }
    if paths.len() != 2 {
        anyhow::bail!("usage: pdgrass benchdiff OLD.json NEW.json [--tolerance T] [--models-only]");
    }
    let (old_path, new_path) = (paths[0], paths[1]);
    let old = crate::benchdiff::BenchReport::load(std::path::Path::new(old_path))?;
    let new = crate::benchdiff::BenchReport::load(std::path::Path::new(new_path))?;
    let d = crate::benchdiff::diff(&old, &new, tolerance, models_only)?;
    print!("{}", d.render());
    if !d.ok() {
        anyhow::bail!("benchdiff: {} regression(s) vs {old_path}", d.violations.len());
    }
    Ok(())
}

/// Entry point for `main`.
pub fn run(args: &[String]) -> anyhow::Result<()> {
    if args.first().map(String::as_str) == Some("benchdiff") {
        return run_benchdiff(&args[1..]);
    }
    let cli = Cli::parse(args)?;
    match cli.verb.as_str() {
        "list" => {
            for e in &crate::gen::SUITE {
                println!(
                    "{:24} family={:?} paper |V|={} |E|={}",
                    e.name,
                    e.family,
                    sci(e.paper_v),
                    sci(e.paper_e)
                );
            }
            Ok(())
        }
        "sparsify" => {
            let (cfg, run) = pipeline_cfg(&cli)?;
            let name = cli.str("graph").unwrap_or("15-M6");
            // build the graph before the timer: report sparsification
            // time, not generator time
            let session = Sparsify::suite(name, cfg.scale, cfg.seed)?
                .pipeline(run.pipeline)
                .relabel(run.relabel)
                .threads(run.resolved_threads());
            let t = Timer::start();
            let prepared = session.prepare()?;
            let r = prepared.recover(&run.recover_opts(cfg.alpha))?;
            let p = r.sparsifier();
            println!(
                "{name}: |V|={} |E|={} -> sparsifier |E|={} ({} tree + {} recovered) in {:.1} ms, {} pass(es)",
                prepared.graph().num_vertices(),
                prepared.graph().num_edges(),
                p.num_edges(),
                prepared.graph().num_vertices() - 1,
                r.edges().len(),
                t.ms(),
                r.passes()
            );
            if let Some(out) = cli.str("out") {
                p.write_mtx(std::path::Path::new(out))?;
                println!("wrote {out}");
            }
            Ok(())
        }
        "evaluate" => {
            let (cfg, run) = pipeline_cfg(&cli)?;
            let name = cli.str("graph").unwrap_or("15-M6");
            let prepared = Sparsify::suite(name, cfg.scale, cfg.seed)?
                .pipeline(run.pipeline)
                .relabel(run.relabel)
                .threads(run.resolved_threads())
                .prepare()?;
            let r = prepared.recover(&run.recover_opts(cfg.alpha))?;
            let p = r.sparsifier();
            if cli.has("xla") {
                let rt = crate::runtime::Runtime::open_default()?;
                let lg = crate::graph::grounded_laplacian(prepared.graph(), 0);
                let m = crate::solver::SparsifierPrecond::new(p.graph())
                    .map_err(|e| anyhow::anyhow!("factorization: {e}"))?;
                let mut rng = crate::util::Rng::new(cfg.seed ^ 0xb);
                let b: Vec<f64> = (0..lg.n).map(|_| rng.normal()).collect();
                let res = crate::runtime::pcg_xla(&rt, &lg, &b, &m, cfg.tol, cfg.maxit)?;
                println!(
                    "{name} (XLA SpMV path): {} PCG iterations, relres {:.2e}, converged={}",
                    res.iterations, res.relres, res.converged
                );
            } else {
                let out = p.pcg(cfg.seed ^ 0xb, cfg.tol, cfg.maxit)?;
                println!(
                    "{name}: {} PCG iterations (converged={})",
                    out.iterations, out.converged
                );
            }
            Ok(())
        }
        "prepare" => {
            let (cfg, run) = pipeline_cfg(&cli)?;
            let prepared = match cli.str("load") {
                Some(path) => {
                    let t = Timer::start();
                    let p = crate::session::Prepared::load(std::path::Path::new(path))?
                        .with_threads(run.resolved_threads());
                    println!("loaded snapshot {path} in {:.1} ms", t.ms());
                    p
                }
                None => {
                    let name = cli.str("graph").unwrap_or("15-M6");
                    let t = Timer::start();
                    let p = Sparsify::suite(name, cfg.scale, cfg.seed)?
                        .pipeline(run.pipeline)
                        .relabel(run.relabel)
                        .threads(run.resolved_threads())
                        .prepare()?;
                    println!("prepared {name} in {:.1} ms", t.ms());
                    p
                }
            };
            println!(
                "fingerprint {} |V|={} |E|={} off-tree={} subtasks={}",
                crate::graph::fingerprint_hex(prepared.fingerprint()),
                prepared.graph().num_vertices(),
                prepared.graph().num_edges(),
                prepared.num_off_tree(),
                prepared.subtasks().len(),
            );
            if let Some(out) = cli.str("save") {
                prepared.save(std::path::Path::new(out))?;
                println!("wrote {out}");
            }
            Ok(())
        }
        "suite" | "table2" => {
            let (cfg, run) = pipeline_cfg(&cli)?;
            experiments::table2(&graph_names(&run), &run.alphas, &cfg);
            Ok(())
        }
        "table3" => {
            let (cfg, _) = pipeline_cfg(&cli)?;
            experiments::table3(&cfg);
            Ok(())
        }
        "table4" => {
            let (cfg, run) = pipeline_cfg(&cli)?;
            experiments::table4(&graph_names(&run), &cfg);
            Ok(())
        }
        "fig1" => {
            let (cfg, run) = pipeline_cfg(&cli)?;
            experiments::fig1(&graph_names(&run), &run.alphas, &cfg);
            Ok(())
        }
        "fig6-8" | "fig678" => {
            let (cfg, _) = pipeline_cfg(&cli)?;
            experiments::fig6_7_8(&cfg);
            Ok(())
        }
        "pipeline" => {
            let (cfg, run) = pipeline_cfg(&cli)?;
            experiments::pipeline_overlap(&graph_names(&run), &cfg);
            Ok(())
        }
        "audit" => {
            let mut opts = match cli.str("config") {
                Some(path) => crate::analysis::AuditOptions::from_doc(&Doc::load(
                    std::path::Path::new(path),
                )?)?,
                None => crate::analysis::AuditOptions::default(),
            };
            if let Some(root) = cli.str("root") {
                opts.root = root.to_string();
            }
            if let Some(allow) = cli.str("allowlist") {
                opts.allowlist = allow.to_string();
            }
            let report = crate::analysis::run_audit(
                std::path::Path::new(&opts.root),
                std::path::Path::new(&opts.allowlist),
            )?;
            print!("{}", report.render());
            if !report.ok() {
                anyhow::bail!("audit failed: {} violation(s)", report.violations.len());
            }
            Ok(())
        }
        "serve" => {
            let mut cfg = match cli.str("config") {
                Some(path) => ServeConfig::from_doc(&Doc::load(std::path::Path::new(path))?)?,
                None => ServeConfig::default(),
            };
            if let Some(s) = cli.str("socket") {
                cfg.socket = std::path::PathBuf::from(s);
            }
            if let Some(s) = cli.str("cache-capacity") {
                cfg.cache_capacity = s.parse()?;
                if cfg.cache_capacity == 0 {
                    anyhow::bail!("--cache-capacity: must be at least 1");
                }
            }
            if let Some(s) = cli.str("max-in-flight") {
                cfg.max_in_flight = s.parse()?;
                if cfg.max_in_flight == 0 {
                    anyhow::bail!("--max-in-flight: must be at least 1");
                }
            }
            if let Some(s) = cli.str("deadline-ms") {
                cfg.deadline_ms = s.parse()?;
            }
            if let Some(s) = cli.str("failure-cap") {
                cfg.failure_cap = s.parse()?;
            }
            if let Some(s) = cli.str("log") {
                cfg.log = s.to_string();
            }
            if let Some(s) = cli.str("threads") {
                cfg.threads = s.parse()?;
            }
            if let Some(s) = cli.str("snapshot-dir") {
                if s.is_empty() {
                    anyhow::bail!("--snapshot-dir: must not be empty");
                }
                cfg.snapshot_dir = Some(std::path::PathBuf::from(s));
            }
            println!(
                "pdgrass serve: listening on {} (cache {}, in-flight {}, {} thread(s))",
                cfg.socket.display(),
                cfg.cache_capacity,
                cfg.max_in_flight,
                cfg.resolved_threads()
            );
            let server = crate::serve::Server::start(cfg)?;
            server.wait();
            println!("pdgrass serve: shut down");
            Ok(())
        }
        "bombard" => {
            let mut cfg = crate::serve::BombardConfig::default();
            if let Some(s) = cli.str("socket") {
                cfg.socket = std::path::PathBuf::from(s);
            }
            if let Some(s) = cli.str("requests") {
                cfg.requests = s.parse()?;
            }
            if let Some(s) = cli.str("clients") {
                cfg.clients = s.parse()?;
            }
            if let Some(s) = cli.str("graphs") {
                cfg.graphs = csv_list(s);
            }
            if let Some(s) = cli.str("alphas") {
                cfg.alphas = csv_list(s)
                    .iter()
                    .map(|a| a.parse::<f64>().map_err(|e| anyhow::anyhow!("--alphas: {e}")))
                    .collect::<anyhow::Result<_>>()?;
            }
            cfg.scale = cli.f64("scale", cfg.scale)?;
            if let Some(s) = cli.str("seed") {
                cfg.seed = s.parse()?;
            }
            if let Some(s) = cli.str("deadline-ms") {
                cfg.deadline_ms = s.parse()?;
            }
            cfg.shutdown = cli.has("shutdown");
            if cli.has("warm-compare") {
                let report = crate::serve::bombard::run_compare(&cfg)?;
                println!("{}", report.render());
                let failed = report.cold.failed + report.warm.failed;
                if failed > 0 {
                    anyhow::bail!("bombard: {failed} failed request(s)");
                }
            } else {
                let report = crate::serve::bombard::run(&cfg)?;
                println!("{}", report.render());
                if report.failed > 0 {
                    anyhow::bail!("bombard: {} failed request(s)", report.failed);
                }
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand: {other}\n{HELP}"),
    }
}

const HELP: &str = "pdgrass — parallel density-aware graph spectral sparsification

USAGE: pdgrass <verb> [options]

VERBS
  list                      show the 18-row evaluation suite
  sparsify  --graph NAME --alpha A [--out FILE.mtx]
  evaluate  --graph NAME --alpha A [--xla]      PCG quality (XLA SpMV path)
  suite | table2            Table II  (runtime + quality, all alphas)
  table3                    Table III (Judge-before-Parallel stats)
  table4                    Table IV  (1/8/32-thread runtimes)
  fig1                      Fig. 1 scatter (CSV)
  fig6-8                    Figs. 6-8 strong-scaling curves (CSV)
  pipeline                  barrier vs streamed prepare timings + overlap model
  audit     [--root DIR] [--allowlist FILE]   concurrency/determinism lints
  prepare   --graph NAME [--save F] [--load F]  prepared-state snapshots
  serve                     sparsification daemon on a Unix socket
  bombard                   deterministic load replay against a daemon
  benchdiff OLD.json NEW.json [--tolerance T] [--models-only]
                            bench no-regression gate: model_units exact,
                            bench_ms within the band (default +50%)

OPTIONS
  --scale S      suite scale factor (default 1.0)
  --seed N       generator/RHS seed
  --alpha A      recovery ratio (default 0.02)
  --threads N    recovery + PCG-evaluation threads (0 = auto)
  --strategy S   serial|outer|inner|mixed|sharded (default mixed)
  --shard-min N  sharded-strategy target shard size (default 4096)
  --pipeline P   barrier|streamed stage handoff (default barrier)
  --relabel R    none|bfs|degree vertex-locality relabeling at ingest
                 (outputs stay in original ids; default none)
  --config F     TOML run config ([run]/[serve] sections)
  --quick        tiny scale + 1 trial (smoke)

SERVE OPTIONS ([serve] config keys; flags override)
  --socket P         Unix socket path (default /tmp/pdgrass.sock)
  --cache-capacity N resident prepared graphs before LRU eviction (default 8)
  --max-in-flight N  concurrent compute requests before typed rejection (default 4)
  --deadline-ms N    default per-request deadline, 0 = none (default 0)
  --failure-cap N    consecutive prepare failures per spec before fast-reject
  --log TARGET       request summaries: stderr | off | file path (default stderr)
  --snapshot-dir D   cross-process warm-start cache of <fingerprint>.pdsnap
                     snapshots: cache misses try a snapshot load before a full
                     prepare; successful prepares are written back (default off)

BOMBARD OPTIONS
  --requests N       total requests in the mix (default 64)
  --clients N        concurrent client connections (default 4)
  --graphs A,B       suite graphs the mix draws from (default 15-M6)
  --alphas X,Y       alpha values the mix draws from (default 0.02,0.05,0.10)
  --deadline-ms N    attach a per-request deadline to compute requests
  --shutdown         send a shutdown request after the run
  --warm-compare     replay the mix twice with an evict-all before each pass:
                     cold (full prepare, snapshot write-back) vs warm
                     (snapshot load); prints both reports + elapsed ratio

PREPARE OPTIONS
  --graph NAME       suite graph to prepare (default 15-M6)
  --save F.pdsnap    write the prepared state as a versioned snapshot
  --load F.pdsnap    load a snapshot instead of preparing (skips steps 1-3)
";

#[cfg(test)]
mod tests {
    use super::*;

    fn s(args: &[&str]) -> Vec<String> {
        args.iter().map(|a| a.to_string()).collect()
    }

    #[test]
    fn parses_opts_and_flags() {
        let cli = Cli::parse(&s(&["table2", "--scale", "0.5", "--quick"])).unwrap();
        assert_eq!(cli.verb, "table2");
        assert_eq!(cli.str("scale"), Some("0.5"));
        assert!(cli.has("quick"));
        assert_eq!(cli.f64("alpha", 0.02).unwrap(), 0.02);
    }

    #[test]
    fn rejects_bare_positional() {
        assert!(Cli::parse(&s(&["table2", "oops"])).is_err());
    }

    #[test]
    fn unknown_verb_is_error() {
        assert!(run(&s(&["frobnicate"])).is_err());
    }

    #[test]
    fn bad_strategy_is_a_clean_error() {
        let err = run(&s(&["sparsify", "--graph", "15-M6", "--scale", "0.02", "--strategy", "warp"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("strategy"), "{err}");
    }

    #[test]
    fn streamed_pipeline_runs_end_to_end() {
        // Tiny scale smoke: the streamed prepare/recover path through the
        // whole CLI stack.
        run(&s(&[
            "sparsify", "--graph", "07-com-DBLP", "--scale", "0.02", "--alpha", "0.05",
            "--pipeline", "streamed",
        ]))
        .unwrap();
    }

    #[test]
    fn relabeled_sparsify_runs_end_to_end() {
        // Tiny scale smoke: both relabel modes through the whole CLI
        // stack (ingest permutation, permuted-space pipeline, mapped-back
        // sparsifier).
        for mode in ["bfs", "degree"] {
            run(&s(&[
                "sparsify", "--graph", "07-com-DBLP", "--scale", "0.02", "--alpha", "0.05",
                "--relabel", mode,
            ]))
            .unwrap();
        }
    }

    #[test]
    fn bad_relabel_is_a_clean_error() {
        let err = run(&s(&[
            "sparsify", "--graph", "15-M6", "--scale", "0.02", "--relabel", "hilbert",
        ]))
        .unwrap_err()
        .to_string();
        assert!(err.contains("relabel"), "{err}");
    }

    #[test]
    fn benchdiff_gates_on_models_and_bands() {
        let dir =
            std::env::temp_dir().join(format!("pdgrass-cli-benchdiff-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let write = |name: &str, pr: u64, ms: f64, units: u64| -> String {
            let path = dir.join(name);
            std::fs::write(
                &path,
                format!(
                    "{{\n  \"schema\": \"pdgrass-bench-v1\",\n  \"pr\": {pr},\n  \
                     \"bench_ms\": {{\n    \"spmv\": {ms:.4}\n  }},\n  \
                     \"model_units\": {{\n    \"makespan\": {units}\n  }}\n}}\n"
                ),
            )
            .unwrap();
            path.to_str().unwrap().to_string()
        };
        let old = write("old.json", 9, 10.0, 100);
        // Within the band, models equal: passes.
        let ok = write("ok.json", 10, 12.0, 100);
        run(&s(&["benchdiff", &old, &ok])).unwrap();
        // Wall clock out of band: fails, unless --models-only.
        let slow = write("slow.json", 10, 100.0, 100);
        let err = run(&s(&["benchdiff", &old, &slow])).unwrap_err().to_string();
        assert!(err.contains("regression"), "{err}");
        run(&s(&["benchdiff", &old, &slow, "--models-only"])).unwrap();
        // A wider band also admits it.
        run(&s(&["benchdiff", &old, &slow, "--tolerance", "10"])).unwrap();
        // Model drift always fails, even under --models-only.
        let drift = write("drift.json", 10, 10.0, 101);
        let err = run(&s(&["benchdiff", &old, &drift, "--models-only"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("regression"), "{err}");
        // Arity and option validation.
        assert!(run(&s(&["benchdiff", &old])).unwrap_err().to_string().contains("usage"));
        assert!(run(&s(&["benchdiff", &old, &ok, "--frob"]))
            .unwrap_err()
            .to_string()
            .contains("unknown option"));
        let err = run(&s(&["benchdiff", &old, "/tmp/pdgrass-no-such-bench.json"]))
            .unwrap_err()
            .to_string();
        assert!(!err.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_pipeline_is_a_clean_error() {
        let err = run(&s(&[
            "sparsify", "--graph", "15-M6", "--scale", "0.02", "--pipeline", "warp",
        ]))
        .unwrap_err()
        .to_string();
        assert!(err.contains("pipeline"), "{err}");
    }

    #[test]
    fn sharded_strategy_runs_end_to_end() {
        // Tiny scale smoke: the sharded path through the whole CLI stack.
        run(&s(&[
            "sparsify", "--graph", "09-com-Youtube", "--scale", "0.02", "--alpha", "0.05",
            "--strategy", "sharded", "--shard-min", "32",
        ]))
        .unwrap();
    }

    #[test]
    fn zero_shard_min_is_a_clean_error() {
        let err = run(&s(&[
            "sparsify", "--graph", "15-M6", "--scale", "0.02", "--shard-min", "0",
        ]))
        .unwrap_err()
        .to_string();
        assert!(err.contains("shard-min"), "{err}");
    }

    #[test]
    fn list_and_help_run() {
        run(&s(&["list"])).unwrap();
        run(&s(&["help"])).unwrap();
    }

    #[test]
    fn serve_flag_validation_fails_before_binding() {
        let err = run(&s(&["serve", "--cache-capacity", "0"])).unwrap_err().to_string();
        assert!(err.contains("cache-capacity"), "{err}");
        let err = run(&s(&["serve", "--max-in-flight", "0"])).unwrap_err().to_string();
        assert!(err.contains("max-in-flight"), "{err}");
    }

    #[test]
    fn bombard_without_a_daemon_is_a_clean_error() {
        let err = run(&s(&[
            "bombard", "--socket", "/tmp/pdgrass-cli-no-daemon.sock", "--requests", "2",
        ]))
        .unwrap_err()
        .to_string();
        assert!(!err.is_empty());
        let err = run(&s(&["bombard", "--alphas", "zero"])).unwrap_err().to_string();
        assert!(err.contains("alphas"), "{err}");
    }

    #[test]
    fn prepare_saves_and_loads_a_snapshot() {
        let dir =
            std::env::temp_dir().join(format!("pdgrass-cli-prepare-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cli.pdsnap");
        let p = path.to_str().unwrap();
        run(&s(&["prepare", "--graph", "15-M6", "--scale", "0.02", "--save", p])).unwrap();
        run(&s(&["prepare", "--load", p])).unwrap();
        let err = run(&s(&["prepare", "--load", "/tmp/pdgrass-no-such.pdsnap"]))
            .unwrap_err()
            .to_string();
        assert!(!err.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_list_splits_and_trims() {
        assert_eq!(csv_list("a, b ,c"), vec!["a", "b", "c"]);
        assert_eq!(csv_list("a,,"), vec!["a"]);
        assert!(csv_list("").is_empty());
    }
}
