//! Schedule-chaos equivalence: the bitwise-determinism claims must
//! survive adversarial scheduling. `par::chaos` injects seeded
//! yield/sleep noise at the pool's claim/steal/park sites and the
//! stream's claim/await sites; this suite re-runs the equivalence
//! checks under several distinct chaos seeds and requires outputs
//! identical to a chaos-free baseline, bit for bit.
//!
//! Everything lives in ONE `#[test]`: the chaos override is
//! process-global (`chaos::set_seed`), and libtest runs tests in the same
//! binary concurrently — two tests flipping the override would race.
//! A failure message names the seed; replay it standalone with
//! `PDGRASS_CHAOS_SEED=<seed> cargo test --test session`.

use pdgrass::graph::{grounded_laplacian, Graph};
use pdgrass::par::chaos;
use pdgrass::recovery::{self, Strategy};
use pdgrass::solver::{pcg_par, Preconditioner, SparsifierPrecond};
use pdgrass::util::Rng;
use pdgrass::{Pipeline, RecoverOpts, Sparsify};

/// Everything the determinism claim covers, folded into one string:
/// prepared state (score bits), recovered edges, pass count, stats,
/// session PCG history bits, plus the low-level `SparsifierPrecond`
/// path — one `apply_par` application (level-scheduled triangular
/// solves) and a full `pcg_par` run, both as raw `f64` bits.
fn fingerprint(g: &Graph, threads: usize, pipeline: Pipeline) -> String {
    let sess = Sparsify::graph(g.clone()).threads(threads).pipeline(pipeline);
    let prepared =
        if pipeline == Pipeline::Streamed { sess.prepare_streamed() } else { sess.prepare() }
            .unwrap();
    let mut s = String::new();
    for e in prepared.off_tree() {
        s.push_str(&format!(
            "{}:{:x}:{:x};",
            e.eid,
            e.score.to_bits(),
            e.resistance.to_bits()
        ));
    }
    let opts = RecoverOpts {
        strategy: Strategy::Sharded,
        cutoff_edges: 200,
        shard_min: 64,
        block: 4,
        pipeline,
        ..RecoverOpts::with_threads(0.10, threads)
    };
    let r = prepared.recover(&opts).unwrap();
    s.push_str(&format!("|edges={:?}|passes={}|stats={:?}", r.edges(), r.passes(), r.stats()));
    let pcg = r.sparsifier().pcg(42, 1e-3, 20_000).unwrap();
    s.push_str(&format!("|iters={}|conv={}", pcg.iterations, pcg.converged));
    for h in &pcg.history {
        s.push_str(&format!("{:x};", h.to_bits()));
    }
    // Direct low-level parity: the preconditioner's level-scheduled
    // triangular solves (`apply_par`) and the fully-pooled `pcg_par`
    // must be as schedule-immune as the session path above.
    let p = recovery::sparsifier(prepared.graph(), prepared.spanning(), r.edges());
    let lg = grounded_laplacian(prepared.graph(), 0);
    let m = SparsifierPrecond::new(&p).unwrap();
    let mut rng = Rng::new(42);
    let rhs: Vec<f64> = (0..lg.n).map(|_| rng.normal()).collect();
    let mut z = vec![0.0; lg.n];
    m.apply_par(&rhs, &mut z, threads);
    s.push_str("|precond=");
    for v in &z {
        s.push_str(&format!("{:x};", v.to_bits()));
    }
    let par = pcg_par(&lg, &rhs, &m, 1e-3, 20_000, threads);
    s.push_str(&format!("|par_iters={}|par_conv={}|", par.iterations, par.converged));
    for h in &par.history {
        s.push_str(&format!("{:x};", h.to_bits()));
    }
    s
}

fn chaos_graphs() -> Vec<(&'static str, Graph)> {
    let community = pdgrass::gen::community(
        pdgrass::gen::CommunityParams {
            n: 600,
            mean_size: 10.0,
            tail: 1.7,
            intra_p: 0.5,
            bridges: 2,
            max_size: 60,
        },
        &mut pdgrass::util::Rng::new(23),
    );
    let hub = pdgrass::gen::hub_graph(1500, 1, 1200, &mut pdgrass::util::Rng::new(7));
    vec![("community", community), ("hub-star", hub)]
}

#[test]
fn outputs_are_bitwise_stable_under_chaotic_schedules() {
    let graphs = chaos_graphs();
    let cases: Vec<(usize, Pipeline)> = vec![
        (2, Pipeline::Barrier),
        (2, Pipeline::Streamed),
        (8, Pipeline::Barrier),
        (8, Pipeline::Streamed),
    ];

    // Chaos-free baseline (overrides any ambient PDGRASS_CHAOS_SEED,
    // so the baseline is a real baseline even in a chaos CI job).
    chaos::set_seed(None);
    let mut baseline = Vec::new();
    for (label, g) in &graphs {
        for &(threads, pipeline) in &cases {
            baseline.push((label, threads, pipeline, fingerprint(g, threads, pipeline)));
        }
    }

    for seed in [7u64, 0xC0FFEE, 1234] {
        chaos::set_seed(Some(seed));
        assert_eq!(chaos::seed(), Some(seed));
        for (label, threads, pipeline, expect) in &baseline {
            let g = &graphs.iter().find(|(l, _)| l == *label).unwrap().1;
            let got = fingerprint(g, *threads, *pipeline);
            assert_eq!(
                &got, expect,
                "output diverged under chaos — replay with \
                 PDGRASS_CHAOS_SEED={seed} (graph={label}, threads={threads}, \
                 pipeline={pipeline:?})"
            );
        }
    }
    chaos::set_seed(None);
}
