//! Tests for the `pdgrass audit` static-analysis pass: every rule
//! against its seeded violation/clean fixture pair
//! (`rust/tests/analysis_fixtures/`), plus the self-audit — the real
//! source tree must come back clean with zero stale allowlist entries.

use pdgrass::analysis::{audit_sources, run_audit, Allowlist, AuditConfig};
use std::path::{Path, PathBuf};

fn repo() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn fixtures() -> PathBuf {
    repo().join("rust/tests/analysis_fixtures")
}

/// Load one fixture as the `(relative path, contents)` pair
/// `audit_sources` expects.
fn fx(rel: &str) -> (String, String) {
    let text = std::fs::read_to_string(fixtures().join(rel))
        .unwrap_or_else(|e| panic!("fixture {rel}: {e}"));
    (rel.to_string(), text)
}

fn fixture_allow() -> Allowlist {
    Allowlist::load(&fixtures().join("fixtures.allow")).unwrap()
}

/// Audit the named fixtures under the repo's default config and return
/// the violation rule ids, sorted.
fn scan(rels: &[&str]) -> Vec<&'static str> {
    let sources: Vec<_> = rels.iter().map(|r| fx(r)).collect();
    let allow = fixture_allow();
    let report = audit_sources(&sources, &allow, &AuditConfig::default());
    let mut rules: Vec<&'static str> = report.violations.iter().map(|v| v.rule).collect();
    rules.sort_unstable();
    rules
}

#[test]
fn safety_rule_flags_violation_fixture_and_passes_clean() {
    assert_eq!(scan(&["safety_violation.rs"]), vec!["safety-comment"; 3]);
    assert_eq!(scan(&["safety_clean.rs"]), Vec::<&str>::new());
}

#[test]
fn thread_rule_flags_violation_fixture_and_honors_exemptions() {
    assert_eq!(scan(&["thread_violation.rs"]), vec!["thread-outside-pool"; 3]);
    assert_eq!(scan(&["thread_clean.rs"]), Vec::<&str>::new());
    // Same spawn shapes are fine in the exempt file.
    assert_eq!(scan(&["par/pool.rs"]), Vec::<&str>::new());
}

#[test]
fn atomic_rule_requires_an_allowlist_entry() {
    assert_eq!(scan(&["atomics_violation.rs"]), vec!["atomic-allowlist"]);
    assert_eq!(scan(&["atomics_clean.rs"]), Vec::<&str>::new());
    // The violation message carries the copy-pasteable allowlist line.
    let report =
        audit_sources(&[fx("atomics_violation.rs")], &fixture_allow(), &AuditConfig::default());
    let msg = &report.violations[0].msg;
    assert!(msg.contains("atomics_violation.rs | Counter::bump | SeqCst"), "{msg}");
}

#[test]
fn det_rules_flag_violation_fixture_and_pass_clean() {
    assert_eq!(
        scan(&["recovery/det_violation.rs"]),
        vec![
            "det-collections",
            "det-collections",
            "det-collections",
            "det-float-fold",
            "det-float-fold",
            "det-timing",
        ]
    );
    assert_eq!(scan(&["recovery/det_clean.rs"]), Vec::<&str>::new());
}

#[test]
fn whole_fixture_tree_tallies_every_rule() {
    let report =
        run_audit(&fixtures(), &fixtures().join("fixtures.allow")).unwrap();
    assert!(!report.ok());
    let count = |rule: &str| report.violations.iter().filter(|v| v.rule == rule).count();
    assert_eq!(count("safety-comment"), 3, "{}", report.render());
    assert_eq!(count("thread-outside-pool"), 3, "{}", report.render());
    assert_eq!(count("atomic-allowlist"), 1, "{}", report.render());
    assert_eq!(count("det-collections"), 3, "{}", report.render());
    assert_eq!(count("det-timing"), 1, "{}", report.render());
    assert_eq!(count("det-float-fold"), 2, "{}", report.render());
    assert_eq!(report.violations.len(), 13, "{}", report.render());
}

#[test]
fn unused_allowlist_entries_warn_without_failing() {
    // Audit only the violation fixture: the clean fixture's entry goes
    // unused — reported as a warning, not a violation.
    let report =
        audit_sources(&[fx("thread_violation.rs")], &fixture_allow(), &AuditConfig::default());
    assert_eq!(report.unused_allow.len(), 1, "{}", report.render());
    assert!(report.render().contains("unused allowlist entry"), "{}", report.render());
}

#[test]
fn self_audit_source_tree_is_clean() {
    let report = run_audit(
        &repo().join("rust/src"),
        &repo().join("rust/analysis/atomics.allow"),
    )
    .unwrap();
    assert!(report.ok(), "self-audit failed:\n{}", report.render());
    assert!(
        report.unused_allow.is_empty(),
        "stale allowlist entries:\n{}",
        report.render()
    );
    // Sanity: the scan actually covered the tree.
    assert!(report.files > 30, "only {} files scanned", report.files);
    assert!(report.allow_entries > 20);
}

fn cli(args: &[&str]) -> anyhow::Result<()> {
    pdgrass::cli::run(&args.iter().map(|a| a.to_string()).collect::<Vec<_>>())
}

#[test]
fn cli_audit_fails_on_the_fixture_tree() {
    let root = fixtures();
    let allow = fixtures().join("fixtures.allow");
    let err = cli(&[
        "audit",
        "--root",
        root.to_str().unwrap(),
        "--allowlist",
        allow.to_str().unwrap(),
    ])
    .unwrap_err();
    assert!(err.to_string().contains("violation"), "{err}");
}

#[test]
fn cli_audit_passes_on_the_repo_tree() {
    let root = repo().join("rust/src");
    let allow = repo().join("rust/analysis/atomics.allow");
    cli(&[
        "audit",
        "--root",
        root.to_str().unwrap(),
        "--allowlist",
        allow.to_str().unwrap(),
    ])
    .unwrap();
}

#[test]
fn cli_audit_reports_missing_allowlist_cleanly() {
    let err = cli(&["audit", "--allowlist", "no/such/file.allow", "--root", "rust/src"])
        .unwrap_err()
        .to_string();
    assert!(err.contains("no/such/file.allow") || err.contains("cannot"), "{err}");
}

#[test]
fn audit_config_file_round_trips() {
    // `[audit]` keys resolve through the same Doc parser as `[run]`.
    let dir = std::env::temp_dir().join(format!("pdgrass-audit-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("audit.toml");
    let root = fixtures();
    let allow = fixtures().join("fixtures.allow");
    std::fs::write(
        &cfg,
        format!(
            "[audit]\nroot = \"{}\"\nallowlist = \"{}\"\n",
            root.display(),
            allow.display()
        ),
    )
    .unwrap();
    let err = cli(&["audit", "--config", cfg.to_str().unwrap()]).unwrap_err();
    assert!(err.to_string().contains("violation"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn allowlist_rejects_malformed_lines() {
    assert!(Allowlist::parse("only | three | fields\n", "t").is_err());
    assert!(Allowlist::parse("a.rs | f | NotAnOrdering | why\n", "t").is_err());
    assert!(Allowlist::parse(
        "a.rs | f | Relaxed | once\na.rs | f | Relaxed | twice\n",
        "t"
    )
    .is_err());
}

#[test]
fn missing_audit_root_is_a_clean_error() {
    let missing = Path::new("definitely/not/a/dir");
    assert!(run_audit(missing, &repo().join("rust/analysis/atomics.allow")).is_err());
}
