//! Relabel equivalence suite: locality relabeling ([`Sparsify::relabel`])
//! is a memory-layout optimization and must be invisible in every
//! result. The pipeline runs in permuted vertex ids, but the recovered
//! sparsifier is mapped back to original ids and PCG evaluates in
//! original space — so on tie-free inputs the sparsifier graph is
//! bitwise identical to the unrelabeled run and PCG converges in exactly
//! the same iterations, for both relabel modes, across strategies,
//! pipelines, and thread counts.
//!
//! The tie-free precondition (no two edges share an effective weight or
//! score — the only place edge-id tie-breaks could interact with the
//! permutation) holds with probability 1 here: every generator draws
//! continuous random weights. `rust/src/graph/relabel.rs` documents the
//! full equivariance argument.

use pdgrass::graph::{self, Relabel};
use pdgrass::session::{RecoverOpts, Sparsify};
use pdgrass::util::proptest::{check, Config};
use pdgrass::{Pipeline, Strategy};

const MODES: [Relabel; 2] = [Relabel::Bfs, Relabel::Degree];

/// Small cutoff/shard knobs so test-scale graphs exercise the
/// large-subtask and sharded paths (as in `recovery_props.rs`).
fn opts(alpha: f64, strategy: Strategy, pipeline: Pipeline) -> RecoverOpts {
    RecoverOpts {
        strategy,
        pipeline,
        cutoff_edges: 40,
        shard_min: 16,
        ..RecoverOpts::with_threads(alpha, 4)
    }
}

fn community(rng: &mut pdgrass::util::Rng) -> graph::Graph {
    pdgrass::gen::community(
        pdgrass::gen::CommunityParams {
            n: 300 + rng.below(300),
            mean_size: 10.0,
            tail: 1.7,
            intra_p: 0.5,
            bridges: 2,
            max_size: 80,
        },
        rng,
    )
}

#[test]
fn relabeled_sparsifiers_are_bitwise_identical_in_original_ids() {
    check(Config { cases: 4, base_seed: 0xA11 }, "relabel_equivalence", |rng| {
        let g = community(rng);
        let input_fp = graph::fingerprint(&g);
        let base = Sparsify::graph(g.clone()).prepare().map_err(|e| e.to_string())?;
        for mode in MODES {
            for pipeline in [Pipeline::Barrier, Pipeline::Streamed] {
                let p = Sparsify::graph(g.clone())
                    .relabel(mode)
                    .pipeline(pipeline)
                    .prepare()
                    .map_err(|e| e.to_string())?;
                if p.original_fingerprint() != input_fp {
                    return Err(format!("{mode:?}/{pipeline:?}: original fingerprint drifted"));
                }
                for strategy in [Strategy::Serial, Strategy::Mixed, Strategy::Sharded] {
                    let o = opts(0.1, strategy, pipeline);
                    let want = base.recover(&o).map_err(|e| e.to_string())?;
                    let got = p.recover(&o).map_err(|e| e.to_string())?;
                    if got.edges().len() != want.edges().len() {
                        return Err(format!(
                            "{mode:?}/{pipeline:?}/{strategy:?}: recovered {} edges, want {}",
                            got.edges().len(),
                            want.edges().len()
                        ));
                    }
                    let want_fp = graph::fingerprint(want.sparsifier().graph());
                    let got_fp = graph::fingerprint(got.sparsifier().graph());
                    if got_fp != want_fp {
                        return Err(format!(
                            "{mode:?}/{pipeline:?}/{strategy:?}: sparsifier diverged \
                             ({got_fp:#x} vs {want_fp:#x})"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn relabeled_pcg_converges_in_identical_iterations() {
    // PCG is the expensive half, so one graph per mode: the sparsifier
    // equality above already covers the breadth; this pins the actual
    // paper metric end to end (grounding, RHS seeding, and the solve all
    // happen in original ids).
    let mut rng = pdgrass::util::Rng::new(0xA12);
    let g = community(&mut rng);
    let o = opts(0.05, Strategy::Mixed, Pipeline::Barrier);
    let base = Sparsify::graph(g.clone()).prepare().unwrap();
    let want = base.recover(&o).unwrap().sparsifier().pcg(42, 1e-3, 10_000).unwrap();
    assert!(want.converged);
    for mode in MODES {
        let p = Sparsify::graph(g.clone()).relabel(mode).prepare().unwrap();
        let got = p.recover(&o).unwrap().sparsifier().pcg(42, 1e-3, 10_000).unwrap();
        assert_eq!(got.iterations, want.iterations, "{mode:?}");
        assert_eq!(got.relres.to_bits(), want.relres.to_bits(), "{mode:?}");
    }
}

#[test]
fn relabel_survives_the_fegrass_baseline_too() {
    // The baseline shares the permuted-space prepared state and the same
    // map-back; its sparsifier must be equally unaffected.
    let mut rng = pdgrass::util::Rng::new(0xA13);
    let g = community(&mut rng);
    let o = opts(0.05, Strategy::Serial, Pipeline::Barrier);
    let base = Sparsify::graph(g.clone()).prepare().unwrap();
    let want = base.fegrass(&o).unwrap();
    for mode in MODES {
        let p = Sparsify::graph(g.clone()).relabel(mode).prepare().unwrap();
        let got = p.fegrass(&o).unwrap();
        assert_eq!(got.passes(), want.passes(), "{mode:?}");
        assert_eq!(
            graph::fingerprint(got.sparsifier().graph()),
            graph::fingerprint(want.sparsifier().graph()),
            "{mode:?}"
        );
    }
}
