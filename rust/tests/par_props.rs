//! Property-test suite for the parallel substrate (ISSUE 2).
//!
//! Randomized invariants over `par::par_reduce`, `par::sort::par_sort_by`
//! / `par_sort_by_key`, and the fully-pooled `solver::pcg_par`, driven by
//! `util::proptest::check` (failures report the case seed for replay):
//!
//! * reductions are **bitwise self-reproducible** across repeated runs
//!   and thread counts, and within 1e-12 relative of the serial fold;
//! * the no-`Clone` merge sort agrees with `slice::sort_by` on random
//!   and adversarial inputs, including a payload type that does not
//!   implement `Clone`;
//! * `pcg_par` reproduces the serial `pcg` iterate sequence exactly on
//!   random SPD graph Laplacians.

use pdgrass::graph::grounded_laplacian;
use pdgrass::par::{par_reduce, sort::par_sort_by, sort::par_sort_by_key};
use pdgrass::solver::{dot, dot_par, norm2, norm2_par, pcg, pcg_par, Jacobi};
use pdgrass::util::proptest::{check, Config};
use pdgrass::util::Rng;
use std::ops::Range;

/// Scale an input-size bound by `PDGRASS_TEST_SCALE` (a float in
/// `(0, 1]`). The nightly Miri and ThreadSanitizer jobs set this to
/// shrink the property suite to interpreter/instrumentation-feasible
/// sizes — the invariants themselves are size-independent. Combine with
/// `PDGRASS_SORT_CUTOFF` so the parallel sort paths still fork at the
/// reduced sizes.
fn scaled(n: usize) -> usize {
    static SCALE: std::sync::OnceLock<f64> = std::sync::OnceLock::new();
    let f = *SCALE.get_or_init(|| {
        std::env::var("PDGRASS_TEST_SCALE")
            .ok()
            .and_then(|s| s.trim().parse::<f64>().ok())
            .filter(|f| *f > 0.0 && *f <= 1.0)
            .unwrap_or(1.0)
    });
    ((n as f64 * f) as usize).max(8)
}

/// (a) `par_reduce`-backed dot/norm2: deterministic across runs and
/// thread counts at fixed length, and ≤ 1e-12 relative error vs serial.
#[test]
fn prop_reduce_deterministic_and_close_to_serial() {
    check(Config { cases: 48, base_seed: 0xD07 }, "reduce_determinism", |rng| {
        let n = rng.below(scaled(40_000));
        let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let serial = dot(&a, &b);
        let reference = dot_par(&a, &b, 1);
        let tol = 1e-12 * serial.abs().max(norm2(&a) * norm2(&b)).max(1.0);
        if (reference - serial).abs() > tol {
            return Err(format!("n={n}: tree dot {reference} vs serial {serial}"));
        }
        for threads in [2usize, 3, 4, 8] {
            for _rerun in 0..2 {
                let d = dot_par(&a, &b, threads);
                if d.to_bits() != reference.to_bits() {
                    return Err(format!(
                        "n={n} threads={threads}: dot not bitwise reproducible: {d} vs {reference}"
                    ));
                }
                let nn = norm2_par(&a, threads);
                if nn.to_bits() != norm2_par(&a, 1).to_bits() {
                    return Err(format!("n={n} threads={threads}: norm2 not reproducible"));
                }
            }
        }
        Ok(())
    });
}

/// (a′) the raw primitive under random grains and thread counts: the
/// chunk tree depends only on `(n, grain)`, so any two runs at the same
/// grain agree bitwise no matter the thread count.
#[test]
fn prop_par_reduce_shape_depends_only_on_n_and_grain() {
    check(Config { cases: 48, base_seed: 0x9EED }, "reduce_shape", |rng| {
        let n = rng.below(scaled(20_000));
        let grain = 1 + rng.below(5000);
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let sum = |r: Range<usize>| {
            let mut s = 0.0;
            for i in r {
                s += xs[i];
            }
            s
        };
        let reference = par_reduce(n, 1, grain, sum, |p, q| p + q);
        for _ in 0..4 {
            let threads = 1 + rng.below(12);
            let got = par_reduce(n, threads, grain, sum, |p, q| p + q);
            if got.to_bits() != reference.to_bits() {
                return Err(format!(
                    "n={n} grain={grain} threads={threads}: {got} != {reference}"
                ));
            }
        }
        Ok(())
    });
}

/// Payload that deliberately does not implement `Clone`.
struct Opaque {
    key: i64,
    tag: u32,
}

/// (b) the merge sort matches `slice::sort_by` on random inputs with a
/// non-`Clone` payload, preserving stability and the element multiset.
#[test]
fn prop_sort_matches_std_on_random_nonclone_input() {
    check(Config { cases: 32, base_seed: 0x50BB }, "sort_random", |rng| {
        let n = rng.below(scaled(30_000));
        let threads = 1 + rng.below(8);
        let keyspace = 1 + rng.below(200) as i64;
        let keys: Vec<i64> =
            (0..n).map(|_| (rng.next_u64() % keyspace as u64) as i64 - keyspace / 2).collect();
        let mut v: Vec<Opaque> =
            keys.iter().enumerate().map(|(i, &k)| Opaque { key: k, tag: i as u32 }).collect();
        // Expected order from std on a (key, tag) mirror.
        let mut mirror: Vec<(i64, u32)> =
            keys.iter().enumerate().map(|(i, &k)| (k, i as u32)).collect();
        mirror.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.cmp(&b.1)));
        par_sort_by(&mut v, threads, &|a: &Opaque, b: &Opaque| a.key.cmp(&b.key));
        for (got, want) in v.iter().zip(&mirror) {
            if got.key != want.0 || got.tag != want.1 {
                return Err(format!(
                    "n={n} threads={threads}: ({}, {}) != ({}, {})",
                    got.key, got.tag, want.0, want.1
                ));
            }
        }
        Ok(())
    });
}

/// (b′) adversarial shapes: sorted, reversed, all-equal, organ-pipe,
/// 0- and 1-element, at parallel-path sizes.
#[test]
fn prop_sort_adversarial_shapes() {
    check(Config { cases: 12, base_seed: 0xADE2 }, "sort_adversarial", |rng| {
        let n = scaled(4096) * (1 + rng.below(3)) + rng.below(97);
        let threads = 2 + rng.below(7);
        let shapes: Vec<Vec<i64>> = vec![
            (0..n as i64).collect(),
            (0..n as i64).rev().collect(),
            vec![13; n],
            (0..n as i64).map(|i| (i).min(n as i64 - i)).collect(),
            vec![],
            vec![-7],
        ];
        for (si, keys) in shapes.into_iter().enumerate() {
            let mut v: Vec<Opaque> = keys
                .iter()
                .enumerate()
                .map(|(i, &k)| Opaque { key: k, tag: i as u32 })
                .collect();
            let mut mirror: Vec<(i64, u32)> =
                keys.iter().enumerate().map(|(i, &k)| (k, i as u32)).collect();
            mirror.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.cmp(&b.1)));
            par_sort_by(&mut v, threads, &|a: &Opaque, b: &Opaque| a.key.cmp(&b.key));
            for (got, want) in v.iter().zip(&mirror) {
                if got.key != want.0 || got.tag != want.1 {
                    return Err(format!("shape {si} n={n} threads={threads}: mismatch"));
                }
            }
        }
        Ok(())
    });
}

/// (b″) `par_sort_by_key` agrees with `slice::sort_by_key` and evaluates
/// the key function exactly once per element.
#[test]
fn prop_sort_by_key_matches_std_with_cached_keys() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    check(Config { cases: 24, base_seed: 0x4EE5 }, "sort_by_key", |rng| {
        let n = rng.below(scaled(20_000));
        let threads = 1 + rng.below(8);
        let mut v: Vec<u64> = (0..n).map(|_| rng.next_u64() % 1000).collect();
        let mut expect = v.clone();
        expect.sort_by_key(|x| *x % 64);
        let calls = AtomicUsize::new(0);
        par_sort_by_key(&mut v, threads, |x: &u64| {
            calls.fetch_add(1, Ordering::Relaxed);
            *x % 64
        });
        // Both sorts are stable under the same key, so the outputs must
        // be identical element-wise, not just key-wise.
        if v != expect {
            return Err(format!("n={n} threads={threads}: output differs from std"));
        }
        let c = calls.load(Ordering::Relaxed);
        if n > 1 && c != n {
            return Err(format!("key evaluated {c} times for {n} elements"));
        }
        Ok(())
    });
}

/// (c) fully-pooled PCG reproduces the serial iterate sequence exactly —
/// first 5 iterations (and the full history/x when it converges sooner)
/// on random SPD grounded Laplacians.
#[test]
fn prop_pcg_par_matches_serial_iterates() {
    check(Config { cases: 12, base_seed: 0x9C61 }, "pcg_parity", |rng| {
        let w = 6 + rng.below(10);
        let h = 6 + rng.below(10);
        let g = pdgrass::gen::grid(w, h, 0.3 + 0.4 * rng.next_f64(), rng);
        let lg = grounded_laplacian(&g, 0);
        let b: Vec<f64> = (0..lg.n).map(|_| rng.normal()).collect();
        let m = Jacobi::new(&lg).map_err(|e| e.to_string())?;
        let serial = pcg(&lg, &b, &m, 1e-30, 5);
        for threads in [2usize, 4, 8] {
            let par = pcg_par(&lg, &b, &m, 1e-30, 5, threads);
            if par.history != serial.history {
                return Err(format!(
                    "{w}x{h} threads={threads}: history diverged: {:?} vs {:?}",
                    par.history, serial.history
                ));
            }
            if par.x != serial.x {
                return Err(format!("{w}x{h} threads={threads}: iterate x diverged"));
            }
            if par.iterations != serial.iterations || par.converged != serial.converged {
                return Err(format!("{w}x{h} threads={threads}: outcome diverged"));
            }
        }
        Ok(())
    });
}
