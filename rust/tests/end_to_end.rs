//! End-to-end pipeline integration tests over the suite families, plus
//! the golden regression snapshot pinning per-(graph, α) recovered-edge
//! counts and PCG iteration counts.

use pdgrass::coordinator::{run_graph, PipelineConfig};
use pdgrass::recovery::{self, Params, Strategy};
use pdgrass::tree::build_spanning;
use pdgrass::{Pipeline, RecoverOpts, Sparsify};

fn cfg(scale: f64) -> PipelineConfig {
    PipelineConfig { scale, trials: 1, ..Default::default() }
}

/// One row per family, full pipeline, structural assertions.
#[test]
fn one_row_per_family() {
    for (name, skewed) in [
        ("01-mi2010", false),
        ("07-com-DBLP", false),
        ("09-com-Youtube", true),
        ("15-M6", false),
    ] {
        let r = run_graph(name, &cfg(0.05)).unwrap();
        assert_eq!(r.pd_passes, 1, "{name}: pdGRASS must finish in one pass");
        assert!(r.iter_fe > 0 && r.iter_pd > 0, "{name}: PCG must converge");
        assert!(r.fe_passes >= 1);
        if skewed {
            assert!(
                r.fe_passes > 3,
                "{name}: skewed input should force multiple feGRASS passes, got {}",
                r.fe_passes
            );
            // skewed input → one dominant subtask
            assert!(
                r.stats.biggest_subtask * 3 > r.e / 10,
                "{name}: expected a dominant subtask, biggest={} |E|={}",
                r.stats.biggest_subtask,
                r.e
            );
        }
    }
}

/// Sparsifier size law: |E_P| = |V| − 1 + α|V| exactly (when enough
/// off-tree edges exist).
#[test]
fn sparsifier_size_law() {
    for alpha in [0.02, 0.05, 0.10] {
        let g = pdgrass::gen::suite::build("14-NACA0015", 0.05, 7);
        let sp = build_spanning(&g);
        let params = Params::new(alpha, 2);
        let r = recovery::pdgrass(&g, &sp, &params);
        let p = recovery::sparsifier(&g, &sp, &r.edges);
        let expect = g.num_vertices() - 1 + params.target(g.num_vertices());
        assert_eq!(p.num_edges(), expect, "alpha={alpha}");
        assert!(pdgrass::graph::is_connected(&p));
    }
}

/// Quality monotonicity: more recovered edges → no worse PCG iterations
/// (the paper's central quality claim, Fig. 1 upward drift).
#[test]
fn quality_improves_with_alpha() {
    let g = pdgrass::gen::suite::build("15-M6", 0.05, 11);
    let sp = build_spanning(&g);
    let mut iters = Vec::new();
    for alpha in [0.0, 0.05, 0.20] {
        let r = recovery::pdgrass(&g, &sp, &Params::new(alpha, 2));
        let p = recovery::sparsifier(&g, &sp, &r.edges);
        let (it, conv) = pdgrass::solver::pcg_iterations(&g, &p, 99, 1e-3, 50_000).unwrap();
        assert!(conv);
        iters.push(it);
    }
    assert!(
        iters[2] < iters[0],
        "alpha=0.20 ({}) must beat tree-only ({})",
        iters[2],
        iters[0]
    );
    assert!(iters[1] <= iters[0] + 2);
}

/// pdGRASS vs feGRASS quality at growing α: the iteration ratio
/// iter_fe/iter_pd must not shrink as α grows (Table II trend).
#[test]
fn iter_ratio_trend() {
    let mut ratios = Vec::new();
    for alpha in [0.02, 0.10] {
        let mut c = cfg(0.08);
        c.alpha = alpha;
        let r = run_graph("14-NACA0015", &c).unwrap();
        ratios.push(r.iter_fe as f64 / r.iter_pd as f64);
    }
    assert!(
        ratios[1] >= ratios[0] * 0.8,
        "iteration ratio should grow (or hold) with alpha: {ratios:?}"
    );
}

/// feGRASS and pdGRASS recover the same number of edges (the target), so
/// quality comparisons are apples-to-apples.
#[test]
fn equal_edge_budgets() {
    let g = pdgrass::gen::suite::build("10-coAuthorsCiteseer", 0.05, 13);
    let sp = build_spanning(&g);
    let params = Params::new(0.05, 2);
    let fe = recovery::fegrass(&g, &sp, &params);
    let pd = recovery::pdgrass(&g, &sp, &params);
    assert_eq!(fe.edges.len(), pd.edges.len());
}

/// Golden regression snapshot: exact recovered-edge counts and PCG
/// iteration counts per (suite graph, α), pinned in
/// `rust/tests/golden/recovery_snapshot.txt` so sparsifier-quality drift
/// fails tier-1 instead of passing the looser structural bounds above.
///
/// Both quantities are deterministic across strategies *and* thread
/// counts (recovery is scheduling-independent; PCG reduces over a fixed
/// chunk tree), so the pins hold under every `PDGRASS_THREADS` in the CI
/// matrix. The recovery runs `strategy=sharded`, and every row is also
/// cross-checked against the streamed pipeline (`prepare_streamed` +
/// `pipeline=streamed` recovery) before pinning, so the snapshot
/// exercises both the sharded path and the stage-overlap path end to end
/// in tier-1.
///
/// Bootstrap/regeneration: writing the computed rows (and passing) is
/// allowed only when the checked-in file carries the explicit
/// `bootstrap-pending` marker, or `PDGRASS_UPDATE_GOLDEN` is set. A
/// missing, truncated, or otherwise row-less snapshot without the marker
/// FAILS — deleting the file cannot silently disarm the pin.
#[test]
fn golden_recovery_snapshot() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/golden/recovery_snapshot.txt");
    let seed = pdgrass::gen::DEFAULT_SEED;
    let mut rows: Vec<String> = Vec::new();
    for name in ["01-mi2010", "09-com-Youtube", "15-M6"] {
        let scale = 0.05;
        let prepared = Sparsify::suite(name, scale, seed).unwrap().threads(1).prepare().unwrap();
        let streamed =
            Sparsify::suite(name, scale, seed).unwrap().threads(2).prepare_streamed().unwrap();
        for alpha in [0.02, 0.10] {
            let opts = RecoverOpts {
                strategy: Strategy::Sharded,
                shard_min: 256,
                cutoff_edges: 1000,
                ..RecoverOpts::with_threads(alpha, 2)
            };
            let r = prepared.recover(&opts).unwrap();
            let pcg = r.sparsifier().pcg(seed ^ 0xb, 1e-3, 50_000).unwrap();
            assert!(pcg.converged, "{name} alpha={alpha}: PCG must converge");
            // The streamed pipeline must agree bitwise before any row is
            // pinned or compared — the snapshot covers both disciplines.
            let s_opts = RecoverOpts { pipeline: Pipeline::Streamed, ..opts };
            let sr = streamed.recover(&s_opts).unwrap();
            assert_eq!(sr.edges(), r.edges(), "{name} alpha={alpha}: streamed diverged");
            let s_pcg = sr.sparsifier().pcg(seed ^ 0xb, 1e-3, 50_000).unwrap();
            assert_eq!(s_pcg.iterations, pcg.iterations, "{name} alpha={alpha}: streamed PCG");
            rows.push(format!(
                "{name} scale={scale} alpha={alpha} off={} recovered={} iters={}",
                prepared.num_off_tree(),
                r.edges().len(),
                pcg.iterations
            ));
        }
    }
    let existing = std::fs::read_to_string(&path).unwrap_or_else(|e| {
        panic!("golden snapshot missing at {}: {e} (restore it from git)", path.display())
    });
    let pinned: Vec<&str> = existing
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .collect();
    let bootstrap_armed = existing.contains("bootstrap-pending");
    if std::env::var("PDGRASS_UPDATE_GOLDEN").is_ok() || (pinned.is_empty() && bootstrap_armed) {
        let header = "# pdGRASS golden recovery snapshot — consumed by \
                      end_to_end::golden_recovery_snapshot.\n\
                      # One row per (suite graph, alpha): off-tree edge count, recovered-edge\n\
                      # count, and PCG iteration count, all bitwise-deterministic across\n\
                      # strategies and thread counts. Regenerate with PDGRASS_UPDATE_GOLDEN=1\n\
                      # and commit the result.\n";
        std::fs::write(&path, format!("{header}{}\n", rows.join("\n"))).unwrap();
        println!("golden snapshot bootstrapped at {} — commit it to pin", path.display());
        return;
    }
    assert!(
        !pinned.is_empty(),
        "golden snapshot at {} has no data rows and no bootstrap-pending marker — \
         it was truncated or corrupted; restore it from git or regenerate with \
         PDGRASS_UPDATE_GOLDEN=1",
        path.display()
    );
    assert_eq!(
        pinned,
        rows.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        "sparsifier-quality drift vs golden snapshot \
         (set PDGRASS_UPDATE_GOLDEN=1 and commit to accept new values)"
    );
}

/// MatrixMarket round trip through the real pipeline: write the
/// sparsifier, read it back, equal PCG behaviour.
#[test]
fn mtx_roundtrip_pipeline() {
    let g = pdgrass::gen::suite::build("01-mi2010", 0.03, 17);
    let sp = build_spanning(&g);
    let r = recovery::pdgrass(&g, &sp, &Params::new(0.05, 1));
    let p = recovery::sparsifier(&g, &sp, &r.edges);
    let dir = std::env::temp_dir().join("pdgrass_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("sparsifier.mtx");
    pdgrass::graph::write_mtx(&p, &path).unwrap();
    let p2 = pdgrass::graph::read_mtx(&path).unwrap();
    assert_eq!(p.num_edges(), p2.num_edges());
    let (i1, _) = pdgrass::solver::pcg_iterations(&g, &p, 5, 1e-3, 50_000).unwrap();
    let (i2, _) = pdgrass::solver::pcg_iterations(&g, &p2, 5, 1e-3, 50_000).unwrap();
    assert_eq!(i1, i2);
    std::fs::remove_file(&path).ok();
}
